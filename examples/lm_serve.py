"""Serve a (reduced) LM with batched requests: prefill + greedy decode.

Demonstrates the serving half of the substrate — KV/SSM caches, batched
prefill, token-by-token decode — on any of the ten assigned architectures:

    PYTHONPATH=src python examples/lm_serve.py --arch mamba2-1.3b --steps 16
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_reduced
from repro.models.model import build
from repro.train.serve_step import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    opts = ap.parse_args()

    cfg = get_reduced(opts.arch)
    model = build(cfg)
    params = model.init(jax.random.key(0))

    rng = jax.random.key(42)
    batch = {
        "tokens": jax.random.randint(
            rng, (opts.batch, opts.prompt_len), 0, cfg.vocab, jnp.int32
        )
    }
    if cfg.family == "vlm":
        batch["media"] = 0.1 * jnp.ones(
            (opts.batch, cfg.n_media_tokens, cfg.d_model), cfg.np_dtype
        )
    if cfg.family == "audio":
        batch = {
            "tokens": batch["tokens"][:, :1],
            "src_embeds": 0.1 * jnp.ones(
                (opts.batch, opts.prompt_len, cfg.d_model), cfg.np_dtype
            ),
        }

    t0 = time.time()
    out = generate(model, params, batch, steps=opts.steps,
                   cache_len=opts.prompt_len + opts.steps + 8)
    dt = time.time() - t0
    print(f"arch={opts.arch} generated {out.shape} tokens in {dt:.2f}s "
          f"({opts.batch * opts.steps / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
