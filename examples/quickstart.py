"""Quickstart: train ReckOn's RSNN with e-prop on cue accumulation (§4.2).

Runs in under a minute on CPU:
    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core.controller import ControllerConfig, OnlineLearner
from repro.core.rsnn import Presets
from repro.data.cue import CueConfig, make_cue_dataset
from repro.data.pipeline import make_pipeline
from repro.optim.eprop_opt import EpropSGDConfig


def main():
    ccfg = CueConfig()
    data = make_cue_dataset(n_train=50, n_val=50, cfg=ccfg)

    # X-HEEP mode: the whole (AER-encoded) dataset lives on device, like the
    # BRAM-resident datasets of the paper's first SoC.
    pipe = make_pipeline("xheep", data)

    cfg = Presets.cue_accumulation(num_ticks=ccfg.num_ticks)
    learner = OnlineLearner(
        cfg,
        ControllerConfig(num_epochs=10, samples_per_epoch=50),
        EpropSGDConfig(lr=0.01, clip=10.0),
        jax.random.key(0),
    )
    log = learner.fit(pipe, verbose=True)
    print(f"\nfinal validation accuracy: {log.val_acc[-1]:.1%} "
          f"(paper: 96.8%/96.4%, silicon: 96.4%)")


if __name__ == "__main__":
    main()
