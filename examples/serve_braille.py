"""Train-then-serve Braille demo: the ARM-mode SoC as an inference service.

Trains ReckOn on the Braille task with online e-prop (exactly like
``braille_online_learning.py``), then serves the learner's network through
the batched serving runtime (:mod:`repro.serve`) as a ragged AER request
stream — reporting classification accuracy, throughput, and request-latency
percentiles.  ``BatchedEngine.from_learner`` shares the learner's
:class:`~repro.core.backend.ExecutionBackend`, so when training continues
mid-serve the engine hot-swaps the live weights (``update_weights``) with
zero recompilation — the paper's online-learning-while-serving experiment at
service scale (the interleaved feed is
:func:`repro.data.pipeline.interleave_train_serve`).

    PYTHONPATH=src python examples/serve_braille.py \
        [--classes AEU|SAEU|AEOU] [--epochs 20] [--batch 32]
"""

import argparse

import jax

from repro.core.controller import ControllerConfig, OnlineLearner
from repro.core.rsnn import Presets
from repro.data.braille import SUBSETS, make_braille_dataset
from repro.data.pipeline import EventStream, interleave_train_serve, make_pipeline
from repro.optim.eprop_opt import EpropSGDConfig
from repro.serve import BatchedEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--classes", default="AEU", choices=list(SUBSETS))
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--batch", type=int, default=32)
    opts = ap.parse_args()

    data = make_braille_dataset(opts.classes)
    print(f"dataset source: {data['train']['source']} "
          f"({data['train']['events'].shape[0]} train samples)")

    # --- train (ARM mode, online e-prop) -----------------------------------
    cfg = Presets.braille(n_classes=len(SUBSETS[opts.classes]),
                          num_ticks=data["train"]["num_ticks"])
    pipe = make_pipeline("arm", data, samples_per_batch=70, prefetch=2)
    learner = OnlineLearner(
        cfg, ControllerConfig(num_epochs=opts.epochs, eval_every=5),
        EpropSGDConfig(lr=0.01, clip=10.0), jax.random.key(1),
    )
    for ep in range(opts.epochs):
        tr = learner.train_epoch(pipe, ep)
        if (ep + 1) % 5 == 0:
            print(f"epoch {ep:3d}  train={tr:.3f}", flush=True)

    # --- serve -------------------------------------------------------------
    engine = BatchedEngine.from_learner(learner, max_batch=opts.batch)
    stream = EventStream(data, "test", repeat=4, shuffle=True, seed=0)
    engine.warmup(data["test"]["num_ticks"], opts.batch)

    results, stats = engine.serve(iter(stream))
    correct = sum(int(r.pred == r.label) for r in results)
    print(f"\nserved {stats.requests} requests in {stats.wall_s*1e3:.1f} ms "
          f"({stats.samples_per_sec:.0f} samples/s, {stats.batches} tiles, "
          f"mean batch {stats.mean_batch:.1f})")
    print(f"latency: p50={stats.p50_latency_s*1e3:.2f} ms  "
          f"p99={stats.p99_latency_s*1e3:.2f} ms")
    print(f"serving accuracy: {correct / max(stats.requests, 1):.1%} "
          f"(paper: AEU 90%, SAEU 78.8%, AEOU 60%)")

    # --- online learning while serving: one backend, live weights ----------
    # from_learner shared the learner's ExecutionBackend, so training commits
    # and serving tiles interleave through one jit cache — no recompiles.
    shapes_before = stats.compiled_shapes
    results2 = []
    for kind, item in interleave_train_serve(
        pipe, EventStream(data, "test"), epoch=opts.epochs, serve_per_batch=16
    ):
        if kind == "train":
            learner.train_batch(item)
            engine.update_weights(learner.weights)   # live weights, hot
        else:
            engine.submit(item)
            for tile in engine.scheduler.ready_tiles():
                results2.extend(engine.run_tile(tile))
    for tile in engine.scheduler.drain():
        results2.extend(engine.run_tile(tile))
    correct2 = sum(int(r.pred == r.label) for r in results2)
    print(f"interleaved train+serve epoch (shared backend, "
          f"{engine.engine.compiled_shapes('inference') - shapes_before} new "
          f"compiled shapes): accuracy {correct2 / max(len(results2), 1):.1%}")


if __name__ == "__main__":
    main()
