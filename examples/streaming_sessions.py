"""Stateful streaming sessions: the neuromorphic edge scenario end-to-end.

The paper's headline deployment is an unbounded per-user AER event stream
classified *online* — recurrent state persists between event bursts, and
nothing ever arrives as a whole padded sample.  This demo drives that path:

1. trains ReckOn on Braille with online e-prop (briefly),
2. opens one session per simulated user (``engine.open_session()``),
3. replays each user's AER words in small interleaved bursts
   (``handle.feed`` + ``engine.pump()`` — the engine continuously batches
   whichever sessions have processable ticks into shared device tiles,
   with every session's membrane/trace state resident in the device
   session pool, LRU-evicted under capacity pressure),
4. polls incremental classifications mid-stream (``handle.poll()``), and
5. closes each stream for its final result (``handle.result()``) —
   bit-identical to serving the whole sample at once.

    PYTHONPATH=src python examples/streaming_sessions.py \
        [--classes AEU|SAEU|AEOU] [--users 64] [--bursts 6] [--tick-tile 16]
"""

import argparse

import jax
import numpy as np

from repro.core import aer
from repro.core.controller import ControllerConfig, OnlineLearner
from repro.core.rsnn import Presets
from repro.data.braille import SUBSETS, make_braille_dataset
from repro.data.pipeline import EventStream, make_pipeline
from repro.optim.eprop_opt import EpropSGDConfig
from repro.serve import BatchedEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--classes", default="AEU", choices=list(SUBSETS))
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--users", type=int, default=64)
    ap.add_argument("--bursts", type=int, default=6,
                    help="feed each user's stream in this many increments")
    ap.add_argument("--tick-tile", type=int, default=16,
                    help="fixed tick length of streaming tiles "
                         "(latency-bounded mode)")
    opts = ap.parse_args()

    data = make_braille_dataset(opts.classes)

    # --- train (ARM mode, online e-prop) -----------------------------------
    cfg = Presets.braille(n_classes=len(SUBSETS[opts.classes]),
                          num_ticks=data["train"]["num_ticks"])
    pipe = make_pipeline("arm", data, samples_per_batch=70, prefetch=2)
    learner = OnlineLearner(
        cfg, ControllerConfig(num_epochs=opts.epochs, eval_every=5),
        EpropSGDConfig(lr=0.01, clip=10.0), jax.random.key(1),
    )
    for ep in range(opts.epochs):
        learner.train_epoch(pipe, ep)

    # --- stream ------------------------------------------------------------
    # Shares the learner's ExecutionBackend: the streaming tiles reuse its
    # jit cache, and update_weights would hot-swap mid-stream if training
    # continued.
    engine = BatchedEngine.from_learner(
        learner, max_batch=32, tick_tile=opts.tick_tile
    )
    test = list(EventStream(data, "test", repeat=8, shuffle=True, seed=0))
    users = []
    for i in range(opts.users):
        ev = np.asarray(test[i % len(test)], np.uint32)
        users.append(ev[np.argsort(ev & aer.MAX_TICK, kind="stable")])
    cuts = [np.linspace(0, len(ev), opts.bursts + 1).astype(int)
            for ev in users]

    handles = [engine.open_session(meta={"user": i})
               for i in range(opts.users)]
    for b in range(opts.bursts):
        for h, ev, c in zip(handles, users, cuts):
            h.feed(ev[c[b]:c[b + 1]])
        engine.pump()
        snap = handles[0].poll()
        if snap is not None:
            print(f"burst {b + 1}/{opts.bursts}: user 0 @ tick {snap.ticks:3d} "
                  f"-> class {snap.pred} (label {snap.label})")
    engine.pump(drain=True)

    correct = 0
    for h in handles:
        final = h.result()
        correct += int(final.pred == final.label)
    stats = engine.stream_stats(wall_s=1.0)   # counters only, not a bench
    print(f"\n{opts.users} sessions closed: "
          f"accuracy {correct}/{opts.users} "
          f"({100.0 * correct / opts.users:.1f}%)")
    print(f"tiles={stats.tiles}  mean lanes={stats.mean_lanes:.1f}  "
          f"evictions={stats.evictions}  "
          f"compiled shapes={stats.compiled_shapes}")


if __name__ == "__main__":
    main()
