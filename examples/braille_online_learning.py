"""Braille digit classification with on-line e-prop learning (§4.3).

Mirrors the paper's ARM-mode SoC: the dataset lives host-side; batches of
samples are offloaded to a device buffer (the shared BRAM) with prefetch;
the AER-decoder loop trains on each sample as it streams through.
``--commit sample`` (default) updates weights at every end-of-sample — true
online learning; ``--commit batch`` runs each offloaded batch as one
rectangular tile through the execution backend and commits the summed
update at the END_B boundary (multi-x faster, see
``benchmarks/bench_braille.py --smoke``).

    PYTHONPATH=src python examples/braille_online_learning.py \
        [--classes AEU|SAEU|AEOU] [--epochs 50] [--commit sample|batch] [--quant]
"""

import argparse

import jax

from repro.core.controller import ControllerConfig, OnlineLearner
from repro.core.quant import WEIGHT_SPEC
from repro.core.rsnn import Presets
from repro.data.braille import SUBSETS, make_braille_dataset
from repro.data.pipeline import make_pipeline
from repro.optim.eprop_opt import EpropSGDConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--classes", default="AEU", choices=list(SUBSETS))
    ap.add_argument("--epochs", type=int, default=50)
    ap.add_argument("--commit", default="sample", choices=["sample", "batch"],
                    help="END_S per-sample commit (chip-faithful) or END_B "
                         "batch commit (one tile per offloaded batch)")
    ap.add_argument("--quant", action="store_true",
                    help="8-bit weight grid with accumulate-then-round commits "
                         "(the chip's weight-SRAM behaviour)")
    opts = ap.parse_args()

    data = make_braille_dataset(opts.classes)
    print(f"dataset source: {data['train']['source']} "
          f"({data['train']['events'].shape[0]} train samples)")

    # ARM mode: batched offload through a BRAM-sized device buffer.
    pipe = make_pipeline("arm", data, samples_per_batch=70, prefetch=2)

    cfg = Presets.braille(n_classes=len(SUBSETS[opts.classes]),
                          num_ticks=data["train"]["num_ticks"])
    opt_cfg = EpropSGDConfig(
        # batch commits take a tuned 2x lr (see bench_braille._opt_cfg)
        lr=0.01 if opts.commit == "sample" else 0.02, clip=10.0,
        quant=WEIGHT_SPEC if opts.quant else None,
        stochastic_round=opts.quant,
    )
    learner = OnlineLearner(
        cfg, ControllerConfig(num_epochs=opts.epochs, eval_every=5,
                              commit=opts.commit),
        opt_cfg, jax.random.key(1),
    )
    for ep in range(opts.epochs):
        tr = learner.train_epoch(pipe, ep)
        if (ep + 1) % 5 == 0:
            va = learner.eval_epoch(pipe, ep)
            print(f"epoch {ep:3d}  train={tr:.3f}  val={va:.3f}", flush=True)
    test = learner.eval_epoch(pipe, 0, split="test")
    print(f"\n{opts.classes} test accuracy: {test:.1%} "
          f"(paper: AEU 90%, SAEU 78.8%, AEOU 60%)")


if __name__ == "__main__":
    main()
