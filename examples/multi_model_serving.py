"""Two SRAM programs, one fabric: multi-model serving while one model learns.

The paper's SoC is runtime-reprogrammable — the host reloads ReckOn's weight
SRAM over SPI, so the same accelerator runs the Braille classifier and the
cue-accumulation task as two programs.  This demo is that scenario at
service scale:

1. trains a Braille classifier and registers it (frozen) in a
   :class:`~repro.serve.registry.ModelRegistry`;
2. attaches a cue-accumulation :class:`~repro.core.controller.OnlineLearner`
   to the *same* registry (``registry=``/``model_id=`` — the learner shares
   its execution backend with the registry pool and publishes its live
   weights after every END_B commit: the SPI weight reload, mid-serve);
3. serves **mixed Braille + cue traffic through one**
   :class:`~repro.serve.BatchedEngine` while the cue model keeps training —
   every request routed by ``model_id``, every tile single-model, weight
   hot-swaps with zero recompilation.

    PYTHONPATH=src python examples/multi_model_serving.py \
        [--braille-epochs 20] [--cue-epochs 4] [--batch 16]
"""

import argparse

import jax

from repro.configs import reckon_cue
from repro.core.controller import ControllerConfig, OnlineLearner
from repro.core.rsnn import Presets
from repro.data.braille import SUBSETS, make_braille_dataset
from repro.data.cue import CueConfig, make_cue_dataset
from repro.data.pipeline import EventStream, make_pipeline
from repro.optim.eprop_opt import EpropSGDConfig
from repro.serve import BatchedEngine, ModelRegistry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--braille-epochs", type=int, default=20)
    ap.add_argument("--cue-epochs", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    opts = ap.parse_args()
    reg = ModelRegistry()

    # --- program 1: Braille, trained then frozen ---------------------------
    b_data = make_braille_dataset("AEU")
    b_cfg = Presets.braille(n_classes=len(SUBSETS["AEU"]),
                            num_ticks=b_data["train"]["num_ticks"])
    b_learner = OnlineLearner(
        b_cfg, ControllerConfig(num_epochs=opts.braille_epochs),
        EpropSGDConfig(lr=0.01, clip=10.0), jax.random.key(1),
    )
    b_pipe = make_pipeline("arm", b_data, samples_per_batch=70)
    for ep in range(opts.braille_epochs):
        b_learner.train_epoch(b_pipe, ep)
    reg.register("braille", b_cfg, b_learner.inference_params(),
                 backend=b_learner.backend)
    print(f"registered 'braille' (frozen, {opts.braille_epochs} epochs)")

    # --- program 2: cue accumulation, learning *while* serving -------------
    ccfg = CueConfig()
    c_data = make_cue_dataset(50, 50, cfg=ccfg)
    c_cfg = reckon_cue.config_for(num_ticks=ccfg.num_ticks)
    c_learner = OnlineLearner(
        c_cfg,
        ControllerConfig(num_epochs=opts.cue_epochs, samples_per_epoch=50,
                         commit="batch"),
        EpropSGDConfig(lr=0.01, clip=10.0), jax.random.key(2),
        registry=reg, model_id="cue",      # <- registered, auto-publishing
    )
    c_pipe = make_pipeline("arm", c_data, samples_per_batch=10)
    print(f"registered 'cue' (live) — models: {reg.ids()}")

    # --- one engine, both models -------------------------------------------
    engine = BatchedEngine(registry=reg, max_batch=opts.batch)

    def mixed_stream():
        """Alternate Braille and cue requests — worst-case interleaving."""
        streams = [
            ("braille", iter(EventStream(b_data, "test", shuffle=True))),
            ("cue", iter(EventStream(c_data, "val", shuffle=True))),
        ]
        while streams:
            for mid, it in list(streams):
                ev = next(it, None)
                if ev is None:
                    streams.remove((mid, it))
                else:
                    yield ev, mid

    swaps0 = reg.get("cue").swaps
    for ep in range(opts.cue_epochs):
        # train one cue epoch: every END_B commit hot-swaps the registry
        # image the engine serves from its next tile — no recompiles
        tr = c_learner.train_epoch(c_pipe, ep)
        results, stats = engine.serve(mixed_stream())
        acc = {
            mid: [int(r.pred == r.label) for r in results if r.model_id == mid]
            for mid in reg.ids()
        }
        line = "  ".join(
            f"{mid}: {sum(v) / max(len(v), 1):.1%} ({len(v)} reqs)"
            for mid, v in acc.items()
        )
        print(f"epoch {ep}: cue train={tr:.3f} | served {line} "
              f"[{stats.batches} tiles, {stats.compiled_shapes} shapes]")
        if stats.per_model:
            for mid, s in stats.per_model.items():
                print(f"    {mid:8s} {s.samples_per_sec:8.0f} samples/s  "
                      f"p99 {s.p99_latency_s * 1e3:.2f} ms")

    print(f"\ncue hot-swaps while serving: {reg.get('cue').swaps - swaps0} "
          f"(compiled tile shapes total: {reg.compiled_shapes()})")
    print("one engine, two SRAM programs — the paper's runtime "
          "reprogrammability at service scale")


if __name__ == "__main__":
    main()
