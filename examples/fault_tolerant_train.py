"""End-to-end fault-tolerant training driver (checkpoint / kill / resume).

Phase 1 trains an LM for N steps with async checkpoints, then simulates a
node failure by abandoning the process state. Phase 2 constructs everything
from scratch and resumes from the newest atomic checkpoint — losses continue
where they left off. A final phase reshards the checkpoint onto a different
(elastic) mesh.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""

import tempfile

import jax
import numpy as np

from repro.configs.base import get_reduced
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.elastic import reshard
from repro.distributed.sharding import BASE_RULES, ShardingRules, use_mesh
from repro.launch.mesh import make_debug_mesh
from repro.models.model import build
from repro.optim.adamw import AdamW, AdamWConfig
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def build_everything(ckpt_dir, steps, seed=0):
    cfg = get_reduced("qwen3-1.7b")
    model = build(cfg)
    params = model.init(jax.random.key(seed))
    opt = AdamW(AdamWConfig(lr=1e-3, warmup_steps=5, decay_steps=steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    stream = TokenStream(TokenStreamConfig(vocab=cfg.vocab, batch=4, seq_len=64))
    trainer = Trainer(
        step_fn, params, opt_state, iter(stream),
        TrainerConfig(total_steps=steps, ckpt_every=5, ckpt_dir=ckpt_dir,
                      log_every=1),
    )
    return cfg, model, trainer, stream


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ft_")
    print("checkpoints:", ckpt_dir)

    # Phase 1: train 12 steps, checkpoints at 5 and 10, then "crash".
    _, _, trainer, _ = build_everything(ckpt_dir, steps=12)
    trainer.run()
    losses1 = [s.metrics["loss"] for s in trainer.metrics.history]
    print(f"phase 1 done at step {trainer.step}; loss {losses1[0]:.3f} -> {losses1[-1]:.3f}")
    del trainer  # simulated node failure: all device state lost

    # Phase 2: fresh process state; resume from newest atomic checkpoint.
    _, _, trainer2, stream2 = build_everything(ckpt_dir, steps=20, seed=1)
    assert trainer2.restore(), "no checkpoint found!"
    stream2.position = trainer2.step
    print(f"restored at step {trainer2.step}")
    trainer2.run()
    losses2 = [s.metrics["loss"] for s in trainer2.metrics.history]
    print(f"phase 2 done at step {trainer2.step}; last loss {losses2[-1]:.3f}")
    assert trainer2.step == 20

    # Phase 3: elastic re-mesh — reload the final checkpoint onto a 1x1 mesh
    # (on real hardware: the survivor mesh after dropping failed hosts).
    cfg, model, trainer3, _ = build_everything(ckpt_dir, steps=20)
    mgr = CheckpointManager(ckpt_dir)
    state_template = jax.tree.map(np.asarray, jax.device_get(
        {"params": trainer3.params, "opt_state": trainer3.opt_state}))
    host_state, manifest = mgr.restore(mgr.latest_step(), state_template)
    mesh = make_debug_mesh(1, 1)
    _, specs = model.abstract()
    from repro.train.train_step import opt_state_specs
    full_specs = {"params": specs, "opt_state": opt_state_specs(specs)}
    rules = ShardingRules(BASE_RULES)
    with use_mesh(mesh, rules):
        placed = reshard(host_state, full_specs, mesh, rules)
    print(f"elastic reshard onto mesh {mesh.shape} ok "
          f"(step {manifest['step']}, {len(jax.tree.leaves(placed))} leaves)")
    print("fault-tolerance drill complete")


if __name__ == "__main__":
    main()
