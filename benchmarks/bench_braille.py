"""Paper §4.3 / Figs. 7–8 — Braille digit classification (online learning).

ReckOn network per the paper: 12 inputs, 38 recurrent (reset-to-zero),
N-class readout, SPI registers threshold=0x03F0, alpha=0x0FE, kappa=0x37,
ARM-mode batched offload, validation every 5 epochs.

Paper numbers (test): AEU 90% (best val 93% @45, avg val 78.9%);
Space+AEU 78.8%; AEOU 60%.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.controller import ControllerConfig, OnlineLearner
from repro.core.rsnn import Presets
from repro.data.braille import SUBSETS, make_braille_dataset
from repro.data.pipeline import make_pipeline
from repro.optim.eprop_opt import EpropSGDConfig

PAPER = {"AEU": 0.90, "SAEU": 0.788, "AEOU": 0.60}


def run(subset: str, epochs: int = 200, seed: int = 1, eval_every: int = 5,
        verbose: bool = False):
    data = make_braille_dataset(subset)
    n_classes = len(SUBSETS[subset])
    cfg = Presets.braille(n_classes=n_classes, num_ticks=data["train"]["num_ticks"])
    pipe = make_pipeline("arm", data, samples_per_batch=70)
    n_train = data["train"]["events"].shape[0]
    learner = OnlineLearner(
        cfg,
        ControllerConfig(num_epochs=epochs, eval_every=eval_every),
        # 1/(1+t/τ) decay with τ ≈ 25 epochs of updates stabilises the long
        # online run (fixed-lr e-prop oscillates past ~30 epochs).
        EpropSGDConfig(lr=0.01, clip=10.0, decay_tau=25.0 * n_train),
        jax.random.key(seed),
    )
    t0 = time.time()
    for ep in range(epochs):
        tr = learner.train_epoch(pipe, ep)
        if (ep + 1) % eval_every == 0:
            va = learner.eval_epoch(pipe, ep)
            if verbose:
                print(f"  epoch {ep:3d} train={tr:.3f} val={va:.3f}", flush=True)
    test = learner.eval_epoch(pipe, 0, split="test")
    return {
        "subset": subset,
        "classes": n_classes,
        "source": data["train"]["source"],
        "test_acc": float(test),
        "val_best": float(np.max(learner.log.val_acc)),
        "val_avg": float(np.mean(learner.log.val_acc)),
        "paper_test": PAPER[subset],
        "seconds": time.time() - t0,
        "epochs": epochs,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--classes", default="AEU,SAEU,AEOU")
    ap.add_argument("--epochs", type=int, default=200)
    ap.add_argument("--verbose", action="store_true")
    opts = ap.parse_args(argv)
    rows = []
    for subset in opts.classes.split(","):
        r = run(subset, epochs=opts.epochs, verbose=opts.verbose)
        rows.append(r)
        print(
            f"{subset:5s} [{r['source']}] test={r['test_acc']:.3f} "
            f"(paper {r['paper_test']:.3f})  val_best={r['val_best']:.3f} "
            f"val_avg={r['val_avg']:.3f}  {r['seconds']:.0f}s/{r['epochs']}ep"
        )
    print("name,us_per_call,derived")
    for r in rows:
        per_epoch = r["seconds"] / r["epochs"] * 1e6
        print(f"braille_{r['subset']},{per_epoch:.0f},test={r['test_acc']:.3f}")
    return rows


if __name__ == "__main__":
    main()
