"""Paper §4.3 / Figs. 7–8 — Braille online learning, both commit modes.

ReckOn network per the paper: 12 inputs, 38 recurrent (reset-to-zero),
N-class readout, SPI registers threshold=0x03F0, alpha=0x0FE, kappa=0x37,
ARM-mode batched offload, validation every 5 epochs.

Two training loops (ISSUE 2 tentpole):

* ``--commit sample`` — the END_S scan: one e-prop commit per sample,
  bit-faithful to the chip's fully-online walk (the paper protocol);
* ``--commit batch``  — the END_B commit: each BRAM-sized batch runs as one
  rectangular ``(T, B, N)`` tile through the execution backend and the
  summed ``dw`` commits once per batch.  The optimizer scales its clip
  threshold by sqrt(K) (so the effective per-commit step grows ~sqrt(K)
  where clipping binds, as it does on Braille), and batch mode additionally
  takes an empirically tuned 2x lr — matched-accuracy-validated at K=70 by
  this smoke, not a K-dependent rule.

``--smoke`` runs the CI acceptance check on the AEU subset at the 12-epoch
budget: steady-state training throughput of both modes on device-resident
batches (decode/offload excluded on both sides, as ``bench_serve`` excludes
compile) must show ≥3x for batch-commit, with test accuracy within 0.10 of
the sequential run at the same seed.

``--quant`` arms the hardware-equivalence mode (ISSUE 3 tentpole): the SPI
registers drive ReckOn's fixed-point datapath (8-bit weight SRAM with
accumulate-then-round e-prop commits, saturating 12-bit membrane grid) end
to end.  ``--quant --smoke`` is the equivalence acceptance gate: quantized
END_S online-learning accuracy must land within 2 points of the float END_S
baseline at the same seed/budget — the paper's software↔chip equivalence
margin — with quantized END_B within the usual 0.10 of quantized END_S.

Paper numbers (test, 200 epochs): AEU 90% (best val 93% @45, avg val 78.9%);
Space+AEU 78.8%; AEOU 60%.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import ExecutionBackend
from repro.core.controller import (
    ControllerConfig,
    OnlineLearner,
    decode_events_to_batch,
    make_batch_commit_train_fn,
    make_train_batch_fn,
)
from repro.core.rsnn import Presets, init_params, trainable
from repro.core.quant import WEIGHT_SPEC
from repro.data.braille import SUBSETS, make_braille_dataset
from repro.data.pipeline import make_pipeline
from repro.optim.eprop_opt import EpropSGD, EpropSGDConfig

PAPER = {"AEU": 0.90, "SAEU": 0.788, "AEOU": 0.60}
REPS = 5   # best-of-N timing passes (noisy shared-CPU containers)


def _opt_cfg(n_train: int, commit: str, quantized: bool = False) -> EpropSGDConfig:
    # 1/(1+t/τ) decay with τ ≈ 25 epochs of per-sample updates stabilises the
    # long online run (fixed-lr e-prop oscillates past ~30 epochs); the decay
    # counter advances per *sample* in both commit modes (num_updates).
    # Batch commits take a tuned 2x lr (fewer, larger, stale-gradient steps;
    # the sqrt(K) part of the large-batch step comes from the optimizer's
    # clip-threshold scaling, which binds on this task) — validated against
    # the sequential run's accuracy at samples_per_batch=70 by the smoke.
    # Quantized mode additionally puts the weights on the 8-bit SRAM grid
    # with a float residual accumulator and the chip's stochastic-rounding
    # commits (round-nearest lands a hair outside the 2-point margin at the
    # smoke budget; stochastic matches/beats float).  The batch-mode 2x lr
    # is a float-only tuning — with stochastic SRAM commits the larger,
    # staler steps lose ~0.2 accuracy, so quantized keeps lr=0.01 in both
    # commit modes (validated by `--quant --smoke`).
    lr = 0.01 if (commit == "sample" or quantized) else 0.02
    return EpropSGDConfig(lr=lr, clip=10.0, decay_tau=25.0 * n_train,
                          quant=WEIGHT_SPEC if quantized else None,
                          stochastic_round=quantized)


def run(subset: str, epochs: int = 200, seed: int = 1, eval_every: int = 5,
        verbose: bool = False, commit: str = "sample", backend: str = "auto",
        samples_per_batch: int = 70, quantized: bool = False, mesh=None):
    data = make_braille_dataset(subset)
    n_classes = len(SUBSETS[subset])
    cfg = Presets.braille(n_classes=n_classes, num_ticks=data["train"]["num_ticks"],
                          quantized=quantized)
    pipe = make_pipeline("arm", data, samples_per_batch=samples_per_batch)
    n_train = data["train"]["events"].shape[0]
    learner = OnlineLearner(
        cfg,
        ControllerConfig(num_epochs=epochs, eval_every=eval_every, commit=commit),
        _opt_cfg(n_train, commit, quantized),
        jax.random.key(seed),
        backend=backend,
        mesh=mesh,
    )
    t0 = time.time()
    for ep in range(epochs):
        tr = learner.train_epoch(pipe, ep)
        if (ep + 1) % eval_every == 0:
            va = learner.eval_epoch(pipe, ep)
            if verbose:
                print(f"  epoch {ep:3d} train={tr:.3f} val={va:.3f}", flush=True)
    test = learner.eval_epoch(pipe, 0, split="test")
    return {
        "subset": subset,
        "classes": n_classes,
        "source": data["train"]["source"],
        "commit": commit,
        "backend": learner.backend.backend,
        "quantized": bool(quantized),
        "test_acc": float(test),
        # epochs < eval_every leaves the val log empty — report NaN, don't crash
        "val_best": float(np.max(learner.log.val_acc)) if learner.log.val_acc
        else float("nan"),
        "val_avg": float(np.mean(learner.log.val_acc)) if learner.log.val_acc
        else float("nan"),
        "paper_test": PAPER[subset],
        "seconds": time.time() - t0,
        "epochs": epochs,
    }


def measure_train_throughput(subset: str = "AEU", spb: int = 70, seed: int = 1,
                             backend: str = "auto"):
    """Steady-state training samples/sec of both commit modes on
    device-resident decoded batches (offload/decode and compile excluded on
    both sides — the tile-compute comparison the tentpole targets)."""
    data = make_braille_dataset(subset)
    n_classes = len(SUBSETS[subset])
    cfg = Presets.braille(n_classes=n_classes, num_ticks=data["train"]["num_ticks"])
    full = decode_events_to_batch(
        jnp.asarray(data["train"]["events"]), cfg.n_in, cfg.num_ticks
    )
    n_train = int(full["label"].shape[0])
    chunks = [
        {k: v[i:i + spb] for k, v in full.items()}
        for i in range(0, n_train - n_train % spb, spb)
    ]
    be = ExecutionBackend(cfg, backend)
    weights = trainable(init_params(jax.random.key(seed), cfg))
    out = {"backend": be.backend, "samples_per_batch": spb}
    for commit, builder in (("sample", make_train_batch_fn),
                            ("batch", make_batch_commit_train_fn)):
        opt = EpropSGD(_opt_cfg(n_train, commit))
        fn = builder(cfg, opt, be)
        state, key = opt.init(weights), jax.random.key(0)
        jax.block_until_ready(fn(weights, state, chunks[0], key)[0]["w_in"])
        best = float("inf")
        for _ in range(REPS):
            t0 = time.perf_counter()
            for chunk in chunks:
                w, _, _ = fn(weights, state, chunk, key)
            jax.block_until_ready(w["w_in"])
            best = min(best, time.perf_counter() - t0)
        n = spb * len(chunks)
        out[commit] = {"samples_per_sec": n / best, "wall_s": best, "n": n}
    out["speedup"] = (
        out["batch"]["samples_per_sec"] / out["sample"]["samples_per_sec"]
    )
    return out


def measure_sharded_throughput(subset: str = "AEU", spb: int = 70,
                               seed: int = 1, backend: str = "auto"):
    """Aggregate END_B training samples/sec of the data-parallel backend:
    the single-device chunk replicated once per device (weak scaling — the
    per-device tile stays the single-device tile), sharded over the mesh's
    data axis by the execution backend, dw psum'd per commit."""
    from repro.launch.mesh import make_data_mesh

    ndev = len(jax.devices())
    mesh = make_data_mesh()
    data = make_braille_dataset(subset)
    n_classes = len(SUBSETS[subset])
    cfg = Presets.braille(n_classes=n_classes,
                          num_ticks=data["train"]["num_ticks"])
    full = decode_events_to_batch(
        jnp.asarray(data["train"]["events"]), cfg.n_in, cfg.num_ticks
    )
    chunk1 = {k: v[:spb] for k, v in full.items()}
    chunkN = {k: jnp.concatenate([v[:spb]] * ndev, axis=0)
              for k, v in full.items()}
    n_train = int(full["label"].shape[0])
    weights = trainable(init_params(jax.random.key(seed), cfg))
    out = {"num_devices": ndev, "samples_per_batch": spb}
    for name, be, chunk in (
        ("single", ExecutionBackend(cfg, backend), chunk1),
        ("sharded", ExecutionBackend(cfg, backend, mesh=mesh), chunkN),
    ):
        opt = EpropSGD(_opt_cfg(n_train, "batch"))
        fn = make_batch_commit_train_fn(cfg, opt, be)
        state, key = opt.init(weights), jax.random.key(0)
        jax.block_until_ready(fn(weights, state, chunk, key)[0]["w_in"])
        n = int(chunk["label"].shape[0])
        best = float("inf")
        for _ in range(REPS):
            t0 = time.perf_counter()
            for _ in range(4):
                w, _, _ = fn(weights, state, chunk, key)
            jax.block_until_ready(w["w_in"])
            best = min(best, time.perf_counter() - t0)
        out[name] = {"samples_per_sec": 4 * n / best, "wall_s": best, "n": n}
    out["device_scaling"] = (
        out["sharded"]["samples_per_sec"] / out["single"]["samples_per_sec"]
    )
    return out


def sharded_smoke(seed: int = 1, epochs: int = 12, backend: str = "auto",
                  out_dir: str = ".", verbose: bool = False):
    """CI acceptance for the data-parallel backend (multi-device lane):
    a sharded END_B training run must match the single-device END_B smoke
    accuracy (dw is psum'd, so the commits are mathematically identical),
    and the aggregate sharded samples/s must be ≥4x the END_S sequential
    per-sample baseline.  Raw device scaling (sharded vs single-device
    END_B at the same per-device batch) is recorded alongside — on an
    N-core CPU host it is bounded by core count, on real multi-chip
    hardware it approaches the device count."""
    import os

    from pathlib import Path

    ndev = len(jax.devices())
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh()
    thr = measure_train_throughput("AEU", spb=70, seed=seed, backend=backend)
    thr_sh = measure_sharded_throughput("AEU", spb=70, seed=seed,
                                        backend=backend)
    agg = thr_sh["sharded"]["samples_per_sec"]
    agg_vs_sequential = agg / thr["sample"]["samples_per_sec"]
    print(f"[{thr['backend']}] END_S sequential commit  : "
          f"{thr['sample']['samples_per_sec']:9.1f} samples/s")
    print(f"[{thr['backend']}] END_B single device      : "
          f"{thr_sh['single']['samples_per_sec']:9.1f} samples/s")
    print(f"[{thr['backend']}] END_B sharded x{ndev} dev : "
          f"{agg:9.1f} samples/s aggregate "
          f"(x{thr_sh['device_scaling']:.2f} device scaling, "
          f"x{agg_vs_sequential:.2f} vs END_S, {os.cpu_count()} host cores)")

    # Per-commit the sharded dw is the psum of the per-shard sums — equal to
    # the single-device commit to float tolerance (asserted in
    # tests/test_backend.py) — but spiking trajectories are chaotic, so a
    # single 12-epoch run is a high-variance accuracy estimate on either
    # side.  Gate on the 3-seed mean, the variance-reduced comparison.
    seeds = (seed, seed + 1, seed + 2)
    rows = []
    for mode, mesh_i in (("single", None), ("sharded", mesh)):
        for sd in seeds:
            r = run("AEU", epochs=epochs, seed=sd, eval_every=epochs,
                    commit="batch", backend=backend, verbose=verbose,
                    mesh=mesh_i)
            r.update(name=f"END_B {mode}" + (f" x{ndev}" if mesh_i else ""),
                     seed=sd)
            rows.append(r)
            print(f"  END_B {mode:7s} seed {sd}: test={r['test_acc']:.3f}")
    mean_single = sum(r["test_acc"] for r in rows[:3]) / 3
    mean_shard = sum(r["test_acc"] for r in rows[3:]) / 3
    acc_gap = abs(mean_single - mean_shard)
    print(f"  mean over seeds: single={mean_single:.3f} "
          f"sharded={mean_shard:.3f} (gap {acc_gap:.3f})")

    # The wall-clock half of the gate only binds on real accelerator
    # devices: virtual CPU devices share the host cores whatever their
    # count, so aggregate wall-clock there measures the runner, not the
    # sharding (same policy as bench_serve's --sharded gate).  The number
    # is still measured and recorded either way.
    from benchmarks.bench_chaos import record_overhead_section

    ckpt_overhead = record_overhead_section()

    virtual = jax.default_backend() == "cpu"
    if ndev == 1 or virtual:
        ok = acc_gap <= 0.10
        why = ("1 device" if ndev == 1 else
               f"{ndev} virtual CPU devices on {os.cpu_count()} cores")
        print(f"acceptance: aggregate wall-clock gate n/a ({why}; recorded "
              f"x{agg_vs_sequential:.2f} vs END_S); accuracy parity "
              f"{'PASS' if ok else 'FAIL'} (gap {acc_gap:.3f})")
    else:
        ok = acc_gap <= 0.10 and agg_vs_sequential >= 4.0
        print(f"acceptance (sharded END_B mean within 0.10 of single-device "
              f"mean, aggregate >= 4x the END_S sequential baseline): "
              f"{'PASS' if ok else 'FAIL'} "
              f"(gap {acc_gap:.3f}, aggregate x{agg_vs_sequential:.2f})")
    payload = {
        "schema": 1,
        "benchmark": "braille_training_sharded",
        "jax_backend": jax.default_backend(),
        "host_cpu_count": os.cpu_count(),
        "mean_test_acc_single": mean_single,
        "mean_test_acc_sharded": mean_shard,
        "rows": rows,
        "throughput": thr,
        "sharded_throughput": thr_sh,
        "aggregate_vs_sequential": agg_vs_sequential,
        "device_scaling": thr_sh["device_scaling"],
        "checkpoint_overhead": ckpt_overhead,
        "rc": 0 if ok else 1,
    }
    Path(out_dir).mkdir(parents=True, exist_ok=True)
    out = Path(out_dir) / "BENCH_train.json"
    # merge alongside sections other benches own (e.g. bench_cue's "cue")
    merged = {}
    if out.exists():
        try:
            merged = json.loads(out.read_text())
        except (json.JSONDecodeError, OSError):
            merged = {}
    merged.update(payload)
    out.write_text(json.dumps(merged, indent=2, sort_keys=True) + "\n")
    print(f"wrote {out}")
    return payload


def smoke(seed: int = 1, epochs: int = 12, backend: str = "auto", verbose=False):
    """CI acceptance: END_B ≥3x END_S throughput at matched accuracy."""
    thr = measure_train_throughput("AEU", spb=70, seed=seed, backend=backend)
    print(f"[{thr['backend']}] END_S sequential commit : "
          f"{thr['sample']['samples_per_sec']:9.1f} samples/s")
    print(f"[{thr['backend']}] END_B batch commit      : "
          f"{thr['batch']['samples_per_sec']:9.1f} samples/s "
          f"(speedup {thr['speedup']:.2f}x)")

    rows = []
    for commit in ("sample", "batch"):
        r = run("AEU", epochs=epochs, seed=seed, eval_every=epochs,
                commit=commit, backend=backend, verbose=verbose)
        r.update(train_samples_per_sec=thr[commit]["samples_per_sec"])
        rows.append(r)
        print(f"  {commit:6s} commit: test={r['test_acc']:.3f} "
              f"val_best={r['val_best']:.3f} ({r['seconds']:.1f}s/{epochs}ep)")
    acc_gap = rows[0]["test_acc"] - rows[1]["test_acc"]
    ok = thr["speedup"] >= 3.0 and acc_gap <= 0.10
    print(f"acceptance (≥3x, batch within 0.10 of sequential accuracy): "
          f"{'PASS' if ok else 'FAIL'} "
          f"(speedup {thr['speedup']:.2f}x, acc gap {acc_gap:+.3f})")
    return {"rc": 0 if ok else 1, "rows": rows, "throughput": thr}


def quant_smoke(seed: int = 1, epochs: int = 12, backend: str = "auto",
                verbose: bool = False):
    """CI acceptance for the hardware-equivalence mode: quantized END_S
    online learning within 2 points of the float END_S baseline (the paper's
    float↔chip margin), quantized END_B within 0.10 of quantized END_S."""
    rows = []
    for name, commit, quantized in (("float END_S", "sample", False),
                                    ("quant END_S", "sample", True),
                                    ("quant END_B", "batch", True)):
        r = run("AEU", epochs=epochs, seed=seed, eval_every=epochs,
                commit=commit, backend=backend, verbose=verbose,
                quantized=quantized)
        r.update(name=name)
        rows.append(r)
        print(f"  {name:12s}: test={r['test_acc']:.3f} "
              f"val_best={r['val_best']:.3f} [{r['backend']}] "
              f"({r['seconds']:.1f}s/{epochs}ep)")
    float_s, quant_s, quant_b = (r["test_acc"] for r in rows)
    gap_s = float_s - quant_s              # >0 means quantization lost points
    gap_b = abs(quant_s - quant_b)
    ok = gap_s <= 0.02 and gap_b <= 0.10
    print(f"acceptance (quant END_S within 2 points of float END_S, "
          f"END_B within 0.10 of quant END_S): {'PASS' if ok else 'FAIL'} "
          f"(END_S gap {gap_s:+.3f}, END_B gap {gap_b:.3f})")
    return {"rc": 0 if ok else 1, "rows": rows}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--classes", default="AEU,SAEU,AEOU")
    ap.add_argument("--epochs", type=int, default=200)
    ap.add_argument("--commit", default="sample", choices=["sample", "batch"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "scan", "kernel"])
    ap.add_argument("--smoke", action="store_true",
                    help="AEU 12-epoch acceptance check (throughput + parity)")
    ap.add_argument("--sharded", action="store_true",
                    help="data-parallel END_B over every visible device "
                         "(with --smoke: the multi-device acceptance gate)")
    ap.add_argument("--out-dir", default=".",
                    help="where --sharded --smoke writes BENCH_train.json")
    ap.add_argument("--quant", action="store_true",
                    help="hardware-equivalence mode: fixed-point datapath + "
                         "8-bit SRAM weight commits (with --smoke: the "
                         "float↔quant equivalence acceptance gate)")
    ap.add_argument("--verbose", action="store_true")
    opts = ap.parse_args(argv)

    if opts.smoke and opts.quant:
        if opts.sharded:
            print("note: --sharded is not part of the quantized smoke gate; "
                  "ignoring it (run --sharded --smoke for the sharded gate)")
        return quant_smoke(backend=opts.backend, verbose=opts.verbose)
    if opts.smoke and opts.sharded:
        return sharded_smoke(backend=opts.backend, out_dir=opts.out_dir,
                             verbose=opts.verbose)
    if opts.smoke:
        return smoke(backend=opts.backend, verbose=opts.verbose)

    mesh = None
    if opts.sharded:
        from repro.launch.mesh import make_data_mesh

        mesh = make_data_mesh()
        print(f"data-parallel END_B over {len(jax.devices())} device(s)")
    rows = []
    for subset in opts.classes.split(","):
        r = run(subset, epochs=opts.epochs, verbose=opts.verbose,
                commit=opts.commit, backend=opts.backend,
                quantized=opts.quant, mesh=mesh)
        rows.append(r)
        print(
            f"{subset:5s} [{r['source']}] {r['commit']} commit "
            f"test={r['test_acc']:.3f} (paper {r['paper_test']:.3f})  "
            f"val_best={r['val_best']:.3f} val_avg={r['val_avg']:.3f}  "
            f"{r['seconds']:.0f}s/{r['epochs']}ep"
        )
    print("name,us_per_call,derived")
    for r in rows:
        per_epoch = r["seconds"] / r["epochs"] * 1e6
        print(f"braille_{r['subset']},{per_epoch:.0f},test={r['test_acc']:.3f}")
    return {"rc": 0, "rows": rows}


if __name__ == "__main__":
    import sys

    out = main()
    sys.exit(out["rc"] if isinstance(out, dict) else 0)
