"""Paper §4.2 / Fig. 6 — cue-accumulation (binary decision navigation).

Reproduces the 40-input / 100-recurrent / 2-output network trained with
e-prop for 10 epochs on 50-sample train/validation sets, in BOTH controller
modes (X-HEEP resident / ARM batched offload).  Paper numbers: train 92.4%
(X-HEEP) / 92.2% (ARM); validation 96.8% / 96.4%; RTL 97.4%; silicon 96.4%.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.controller import ControllerConfig, OnlineLearner
from repro.core.rsnn import Presets
from repro.data.cue import CueConfig, make_cue_dataset
from repro.data.pipeline import make_pipeline
from repro.optim.eprop_opt import EpropSGDConfig


def run(mode: str, epochs: int = 10, seed: int = 0, verbose: bool = False):
    ccfg = CueConfig()
    data = make_cue_dataset(50, 50, cfg=ccfg)
    cfg = Presets.cue_accumulation(num_ticks=ccfg.num_ticks)
    pipe = make_pipeline(mode, data, samples_per_batch=10)
    learner = OnlineLearner(
        cfg,
        ControllerConfig(num_epochs=epochs, samples_per_epoch=50),
        EpropSGDConfig(lr=0.01, clip=10.0),
        jax.random.key(seed),
    )
    t0 = time.time()
    log = learner.fit(pipe, verbose=verbose)
    elapsed = time.time() - t0
    return {
        "mode": mode,
        "train_avg": float(np.mean(log.train_acc)),
        "val_avg": float(np.mean(log.val_acc)),
        "val_best": float(np.max(log.val_acc)),
        "val_final": float(log.val_acc[-1]),
        "seconds": elapsed,
        "s_per_epoch": elapsed / epochs,
        "h2d_bytes": pipe.stats.h2d_bytes,
        "resident_bytes": pipe.stats.resident_bytes,
    }


def main(argv=None):
    print("cue accumulation — paper: train 92.4/92.2%, val 96.8/96.4% (XHEEP/ARM)")
    rows = []
    for mode in ("xheep", "arm"):
        r = run(mode)
        rows.append(r)
        print(
            f"{mode:6s} train_avg={r['train_avg']:.3f} val_avg={r['val_avg']:.3f} "
            f"val_best={r['val_best']:.3f} ({r['s_per_epoch']:.2f}s/epoch)"
        )
    print("name,us_per_call,derived")
    for r in rows:
        print(f"cue_{r['mode']},{r['s_per_epoch']*1e6:.0f},val_avg={r['val_avg']:.3f}")
    return rows


if __name__ == "__main__":
    main()
