"""Paper §4.2 / Fig. 6 — cue-accumulation (binary decision navigation).

Reproduces the 40-input / 100-recurrent / 2-output network trained with
e-prop for 10 epochs on 50-sample train/validation sets, in BOTH controller
modes (X-HEEP resident / ARM batched offload).  Paper numbers: train 92.4%
(X-HEEP) / 92.2% (ARM); validation 96.8% / 96.4%; RTL 97.4%; silicon 96.4%.

``--commit batch`` trains with the END_B batch commit (each BRAM-sized
batch as one rectangular tile, summed dw committed at the batch boundary)
instead of the chip-faithful per-sample END_S scan.  ``--quant`` arms the
hardware-equivalence mode (``configs/reckon_cue.py``: the tuned registers
on ReckOn's fixed-point datapath under reset-by-subtraction, 8-bit SRAM
weights with stochastic-rounding commits).

``--smoke`` is the CI acceptance gate (same tolerance policy as
``bench_braille --sharded --smoke``): spiking trajectories are chaotic and
the cue sets are 50 samples, so a single run is a high-variance accuracy
estimate — the gate compares the **3-seed mean** END_B validation accuracy
(ARM batched offload) against the 3-seed mean END_S scan baseline and
requires the gap ≤ 0.10.  With ``--out-dir`` the result is merged into
``BENCH_train.json`` under the ``"cue"`` key (alongside the Braille
sections), so the artifact carries both of the paper's workloads.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import reckon_cue
from repro.core.controller import ControllerConfig, OnlineLearner
from repro.data.cue import CueConfig, make_cue_dataset
from repro.data.pipeline import make_pipeline
from repro.optim.eprop_opt import EpropSGDConfig

N_TRAIN = N_VAL = 50   # the paper's 50/50 cue splits
SPB = 10               # ARM-mode BRAM batch (END_B commit granularity)


def _opt_cfg(quantized: bool = False) -> EpropSGDConfig:
    # lr=1e-2 in both commit modes: cue END_B batches are small (K=10), so
    # the batch commit stays close to the online walk and needs no separate
    # lr tuning (unlike Braille's K=70 2x); quantized runs take the shared
    # SRAM-grid optimizer contract from configs/reckon_cue.py.
    if quantized:
        return reckon_cue.QUANT_OPT
    return EpropSGDConfig(lr=0.01, clip=10.0)


def run(mode: str, epochs: int = 10, seed: int = 0, verbose: bool = False,
        commit: str = "sample", backend: str = "auto",
        quantized: bool = False):
    ccfg = CueConfig()
    data = make_cue_dataset(N_TRAIN, N_VAL, cfg=ccfg)
    cfg = reckon_cue.config_for(
        quantized=quantized, num_ticks=ccfg.num_ticks
    )
    pipe = make_pipeline(mode, data, samples_per_batch=SPB)
    learner = OnlineLearner(
        cfg,
        ControllerConfig(
            num_epochs=epochs, samples_per_epoch=N_TRAIN, commit=commit
        ),
        _opt_cfg(quantized),
        jax.random.key(seed),
        backend=backend,
    )
    t0 = time.time()
    log = learner.fit(pipe, verbose=verbose)
    elapsed = time.time() - t0
    return {
        "mode": mode,
        "commit": commit,
        "backend": learner.backend.backend,
        "quantized": bool(quantized),
        "seed": seed,
        "train_avg": float(np.mean(log.train_acc)),
        "val_avg": float(np.mean(log.val_acc)),
        "val_best": float(np.max(log.val_acc)),
        "val_final": float(log.val_acc[-1]),
        "seconds": elapsed,
        "s_per_epoch": elapsed / epochs,
        "h2d_bytes": pipe.stats.h2d_bytes,
        "resident_bytes": pipe.stats.resident_bytes,
    }


def smoke(seeds=(0, 1, 2), epochs: int = 10, backend: str = "auto",
          out_dir=None, quantized: bool = False, verbose: bool = False):
    """CI acceptance: cue END_B (ARM batched offload) 3-seed mean val
    accuracy within 0.10 of the END_S scan baseline's 3-seed mean —
    bench_braille's sharded-smoke tolerance policy, applied to the
    commit-mode comparison this workload ships with."""
    rows = []
    for commit, mode in (("sample", "xheep"), ("batch", "arm")):
        for sd in seeds:
            r = run(mode, epochs=epochs, seed=sd, commit=commit,
                    backend="scan" if commit == "sample" else backend,
                    quantized=quantized, verbose=verbose)
            r["name"] = f"END_{'S' if commit == 'sample' else 'B'} {mode}"
            rows.append(r)
            print(f"  {r['name']:12s} seed {sd}: val_avg={r['val_avg']:.3f} "
                  f"val_best={r['val_best']:.3f} [{r['backend']}] "
                  f"({r['s_per_epoch']:.2f}s/epoch)")
    k = len(seeds)
    mean_s = sum(r["val_avg"] for r in rows[:k]) / k
    mean_b = sum(r["val_avg"] for r in rows[k:]) / k
    gap = abs(mean_s - mean_b)
    ok = gap <= 0.10
    print(f"  mean over seeds: END_S={mean_s:.3f} END_B={mean_b:.3f} "
          f"(gap {gap:.3f})")
    print(f"acceptance (cue END_B 3-seed mean within 0.10 of the END_S scan "
          f"baseline): {'PASS' if ok else 'FAIL'} (gap {gap:.3f})")
    payload = {
        "benchmark": "cue_training",
        "jax_backend": jax.default_backend(),
        "quantized": bool(quantized),
        "epochs": epochs,
        "mean_val_acc_end_s": mean_s,
        "mean_val_acc_end_b": mean_b,
        "acc_gap": gap,
        "rows": rows,
        "rc": 0 if ok else 1,
    }
    if out_dir is not None:
        # merge alongside the Braille sections rather than clobbering them
        Path(out_dir).mkdir(parents=True, exist_ok=True)
        path = Path(out_dir) / "BENCH_train.json"
        merged = {}
        if path.exists():
            try:
                merged = json.loads(path.read_text())
            except (json.JSONDecodeError, OSError):
                merged = {}
        merged["cue"] = payload
        merged["checkpoint_overhead"] = _checkpoint_overhead()
        merged.setdefault("schema", 1)
        path.write_text(json.dumps(merged, indent=2, sort_keys=True) + "\n")
        print(f"wrote {path} (cue + checkpoint_overhead sections)")
    return {"rc": payload["rc"], "cue": payload}


def _checkpoint_overhead():
    from benchmarks.bench_chaos import record_overhead_section

    return record_overhead_section()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--commit", default="sample", choices=["sample", "batch"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "scan", "kernel"])
    ap.add_argument("--quant", action="store_true",
                    help="hardware-equivalence mode: fixed-point datapath "
                         "(reset-by-subtraction) + 8-bit SRAM weight commits")
    ap.add_argument("--smoke", action="store_true",
                    help="3-seed END_B vs END_S acceptance gate")
    ap.add_argument("--out-dir", default=None,
                    help="with --smoke: merge the cue section into "
                         "BENCH_train.json here")
    ap.add_argument("--verbose", action="store_true")
    opts = ap.parse_args(argv)

    if opts.smoke:
        return smoke(epochs=opts.epochs, backend=opts.backend,
                     out_dir=opts.out_dir, quantized=opts.quant,
                     verbose=opts.verbose)

    print("cue accumulation — paper: train 92.4/92.2%, val 96.8/96.4% (XHEEP/ARM)")
    rows = []
    for mode in ("xheep", "arm"):
        r = run(mode, epochs=opts.epochs, commit=opts.commit,
                backend=opts.backend, quantized=opts.quant,
                verbose=opts.verbose)
        rows.append(r)
        print(
            f"{mode:6s} train_avg={r['train_avg']:.3f} val_avg={r['val_avg']:.3f} "
            f"val_best={r['val_best']:.3f} ({r['s_per_epoch']:.2f}s/epoch)"
        )
    print("name,us_per_call,derived")
    for r in rows:
        print(f"cue_{r['mode']},{r['s_per_epoch']*1e6:.0f},val_avg={r['val_avg']:.3f}")
    return {"rc": 0, "rows": rows}


if __name__ == "__main__":
    import sys

    out = main()
    sys.exit(out["rc"] if isinstance(out, dict) else 0)
