"""Batched serving vs the sequential controller loop (ISSUE 1 tentpole bench).

Measures end-to-end classification throughput on the Braille config:

* **sequential** — the FSM-faithful baseline: one sample at a time through
  the jit'd single-sample inference entry
  (:func:`repro.core.controller.make_infer_fn`), host-decoded per request —
  how the chip serves its AER bus;
* **batched**    — :class:`repro.serve.BatchedEngine`: requests bucketed by
  tick length, padded into batch tiles, one jit'd forward per tile shape.

Reports samples/sec for both, the speedup (acceptance: ≥ 4× at batch ≥ 32),
and the batched path's p50/p99 request latency.  Compile time is excluded
from both sides via warmup.  A ragged-stream mode exercises the bucketing
scheduler with mixed tick lengths.

``--streaming`` switches to the stateful session path (ISSUE 6 tentpole
gate): N concurrent sessions fed their AER streams in interleaved
increments through ``open_session()/feed()/pump()``, with carry state
resident in the device session pool.  Reports events/s, session-ticks/s and
p50/p99 tick-tile latency, spot-checks a sample of sessions bitwise against
the whole-sample path, and records everything under the ``"streaming"`` key
of ``BENCH_serve.json``.  The full run drives ≥ 10k concurrent sessions on
CPU; ``--smoke`` shrinks the fleet for the CI lanes (correctness always
gates; the throughput floor only on the single-device lane).

    PYTHONPATH=src python -m benchmarks.bench_serve [--fast] [--batch 64]
    PYTHONPATH=src python -m benchmarks.bench_serve --streaming [--sessions N]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import aer
from repro.core.controller import make_infer_fn
from repro.core.rsnn import Presets, init_params, trainable
from repro.data.braille import BrailleConfig, make_braille_dataset
from repro.data.cue import CueConfig, make_cue_dataset
from repro.data.pipeline import EventStream
from repro.serve import BatchedEngine, ModelRegistry
from repro.serve.batching import decode_events_host, request_ticks

REPS = 3   # best-of-N measurement passes (noisy shared-CPU containers)


def _ragged_stream(base_stream, num_ticks, seed=0):
    """Re-encode each sample truncated to a random length — mixed-tick
    traffic for the bucketing scheduler."""
    rng = np.random.default_rng(seed)
    out = []
    for ev in base_stream:
        t = int(rng.integers(num_ticks // 2, num_ticks + 1))
        kind = np.asarray(ev, np.uint32) >> 24
        ticks = np.asarray(ev, np.uint32) & aer.MAX_TICK
        keep = (ticks < t) | (kind == aer.EVT_LABEL)
        words = np.asarray(ev, np.uint32)[keep & (kind != aer.EVT_END)]
        words = np.minimum(words, (words & ~np.uint32(aer.MAX_TICK)) | (t - 1))
        end = np.uint32((aer.EVT_END << 24) | (t - 1))
        out.append(np.concatenate([words, [end]]))
    return out


def run_sequential(cfg, weights, stream):
    infer = make_infer_fn(cfg)
    # pre-compile every tick-length the stream contains (steady-state timing,
    # same treatment the batched side gets)
    for ticks in sorted({request_ticks(ev) for ev in stream}):
        r, v, _ = decode_events_host([stream[0]], cfg.n_in, ticks, cfg.label_delay)
        jax.block_until_ready(infer(weights, r[:, 0], v[:, 0])["acc_y"])

    best_wall, preds = float("inf"), []
    for _ in range(REPS):  # best-of-N: the container CPU is noisy
        run = []
        t0 = time.perf_counter()
        for ev in stream:
            ticks = request_ticks(ev)
            raster, valid, _ = decode_events_host([ev], cfg.n_in, ticks, cfg.label_delay)
            out = infer(weights, raster[:, 0], valid[:, 0])
            run.append(int(jax.block_until_ready(out["pred"])))
        wall = time.perf_counter() - t0
        if wall < best_wall:
            best_wall, preds = wall, run
    return preds, len(stream) / best_wall, best_wall


def run_batched(cfg, params, stream, batch, granularity=32, mesh=None):
    eng = BatchedEngine(
        cfg, params, backend="auto", max_batch=batch,
        tick_granularity=granularity, mesh=mesh,
    )
    eng.serve(iter(stream))      # warm pass: compiles every tile shape
    best = None
    for _ in range(REPS):        # best-of-N steady-state pass
        results, stats = eng.serve(iter(stream))
        if best is None or stats.wall_s < best[1].wall_s:
            best = (results, stats)
    return best


def run_streaming(cfg, params, stream, n_sessions, batch, tick_tile,
                  phases=4, spot_check=64, seed=0, mesh=None):
    """Drive ``n_sessions`` concurrent stateful sessions through the
    continuous-batching pump, feeding each stream in ``phases`` interleaved
    increments (the adversarial arrival pattern: no session ever has its
    whole sample available at once)."""
    from repro.serve.batching import max_sessions_for

    # Every session must be resident at once — the gate is *concurrent*
    # sessions, so size the pool to the fleet (and report its byte cost).
    capacity = max(n_sessions, max_sessions_for(cfg))
    eng = BatchedEngine(
        cfg, params, backend="auto", max_batch=batch,
        max_sessions=capacity, tick_tile=tick_tile, mesh=mesh,
    )
    rng = np.random.default_rng(seed)
    bufs = []
    for i in range(n_sessions):
        ev = np.asarray(stream[i % len(stream)], np.uint32)
        bufs.append(ev[np.argsort(ev & aer.MAX_TICK, kind="stable")])
    cuts = [np.linspace(0, len(ev), phases + 1).astype(int) for ev in bufs]

    # warm pass compiles the tile shapes the fleet will hit
    warm = [eng.open_session() for _ in range(min(batch, n_sessions))]
    for h, ev in zip(warm, bufs):
        h.feed(ev)
    eng.pump(drain=True)
    for h in warm:
        h.result()

    eng.reset_stream_stats()
    t0 = time.perf_counter()
    handles = [eng.open_session() for _ in range(n_sessions)]
    for p in range(phases):
        for h, ev, c in zip(handles, bufs, cuts):
            h.feed(ev[c[p]:c[p + 1]])
        eng.pump()
    eng.pump(drain=True)
    snaps = [h.result() for h in handles]
    wall = time.perf_counter() - t0
    stats = eng.stream_stats(wall)

    # correctness spot check: a sample of sessions vs the whole-sample path
    idx = rng.choice(n_sessions, size=min(spot_check, n_sessions),
                     replace=False)
    ref_eng = BatchedEngine(cfg, params, backend="auto", max_batch=batch,
                            mesh=mesh)
    ref, _ = ref_eng.serve(iter([bufs[i] for i in idx]))
    mism = sum(
        int(not np.array_equal(np.asarray(r.logits), snaps[i].logits))
        for r, i in zip(ref, idx)
    )
    return snaps, stats, eng, mism, len(idx)


# Throughput floor for the single-device CI smoke lane (events/s).  Set an
# order of magnitude under what the container CPU sustains (~55k events/s at
# 1024 sessions) so the gate only trips on real regressions (a serialized
# pump, a per-session launch), not machine noise.
STREAM_SMOKE_FLOOR_EPS = 5_000.0


def main_streaming(opts):
    import os

    num_ticks = 64
    n_sessions = opts.sessions or (1024 if opts.fast else 10_000)
    cfg = Presets.braille(n_classes=3, num_ticks=num_ticks)
    params = init_params(jax.random.key(0), cfg)
    data = make_braille_dataset(
        "AEU", BrailleConfig(num_ticks=num_ticks, samples_per_class=32)
    )
    stream = list(EventStream(data, "train"))
    tick_tile = opts.tick_tile or None

    mesh = None
    if opts.sharded:
        from repro.launch.mesh import make_data_mesh

        mesh = make_data_mesh()
        print(f"sharded streaming over {len(jax.devices())} device(s)")
    print(f"streaming sessions: {n_sessions} concurrent  "
          f"batch={opts.batch}  tick_tile={tick_tile or 'drain'}  "
          f"T={num_ticks}")
    snaps, stats, eng, mism, checked = run_streaming(
        cfg, params, stream, n_sessions, opts.batch, tick_tile, mesh=mesh
    )
    pool_bytes = eng.pool.state_bytes()
    print(f"events    : {stats.events:9d} consumed   "
          f"{stats.events_per_sec:12.1f} events/s")
    print(f"ticks     : {stats.ticks:9d} advanced   "
          f"{stats.ticks_per_sec:12.1f} session-ticks/s")
    print(f"tiles     : {stats.tiles:9d} launched   "
          f"mean lanes {stats.mean_lanes:.1f}  "
          f"{stats.compiled_shapes} shapes")
    print(f"tile latency: p50={stats.p50_tile_latency_s*1e3:.2f} ms  "
          f"p99={stats.p99_tile_latency_s*1e3:.2f} ms")
    print(f"pool      : {len(eng.pool._free) + len(eng.pool._resident)} slots "
          f"({pool_bytes/2**20:.1f} MiB)  evictions={stats.evictions}  "
          f"readmissions={stats.readmissions}")
    print(f"correctness: {checked - mism}/{checked} spot-checked sessions "
          f"bitwise equal to the whole-sample path")

    summary = {
        "sessions": n_sessions,
        "batch": opts.batch,
        "tick_tile": opts.tick_tile or None,
        "events": stats.events,
        "events_per_sec": stats.events_per_sec,
        "ticks_per_sec": stats.ticks_per_sec,
        "tiles": stats.tiles,
        "mean_lanes": stats.mean_lanes,
        "p50_tile_latency_s": stats.p50_tile_latency_s,
        "p99_tile_latency_s": stats.p99_tile_latency_s,
        "compiled_shapes": stats.compiled_shapes,
        "evictions": stats.evictions,
        "readmissions": stats.readmissions,
        "pool_bytes": pool_bytes,
        "wall_s": stats.wall_s,
        "spot_checked": checked,
        "mismatches": mism,
    }
    if opts.out_dir:
        out_dir = Path(opts.out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        out = out_dir / "BENCH_serve.json"
        payload = {"schema": 1, "benchmark": "batched_serving",
                   "jax_backend": jax.default_backend()}
        if out.exists():     # merge alongside the whole-sample numbers
            payload = json.loads(out.read_text())
        payload["streaming"] = summary
        out.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
        print(f"wrote {out}")

    # Virtual CPU devices oversubscribe the physical cores — the
    # 8-virtual-device lane gates correctness only, like the sharded serve.
    virtual = len(jax.devices()) > 1 and jax.default_backend() == "cpu"
    ok = mism == 0
    if opts.fast and not virtual:
        ok = ok and stats.events_per_sec >= STREAM_SMOKE_FLOOR_EPS
        print(f"acceptance (bitwise correctness, ≥ "
              f"{STREAM_SMOKE_FLOOR_EPS:.0f} events/s): "
              f"{'PASS' if ok else 'FAIL'}")
    else:
        why = (f"{len(jax.devices())} virtual CPU devices on "
               f"{os.cpu_count()} cores" if virtual else "full run")
        print(f"acceptance: throughput floor n/a ({why}) "
              f"(outputs match: {'yes' if mism == 0 else 'NO'})")
    return {"rc": 0 if ok else 1, "streaming": summary}


def main_multi_model(opts):
    """Multi-model serving smoke (ISSUE 8): Braille + cue registered in one
    :class:`~repro.serve.ModelRegistry`, served concurrently from one
    :class:`~repro.serve.BatchedEngine` over a mixed ``(events, model_id)``
    stream.  Gates bitwise equality of every per-model result against two
    dedicated single-model engines, and records per-model throughput under
    the ``"multi_model"`` key of ``BENCH_serve.json``."""
    num_ticks = 128
    n_req = 48 if opts.fast else 256    # per model
    cfg_b = Presets.braille(n_classes=3, num_ticks=num_ticks)
    params_b = init_params(jax.random.key(0), cfg_b)
    ccfg = CueConfig()
    cfg_c = Presets.cue_accumulation(num_ticks=ccfg.num_ticks)
    params_c = init_params(jax.random.key(1), cfg_c)

    data_b = make_braille_dataset(
        "AEU", BrailleConfig(num_ticks=num_ticks,
                             samples_per_class=max(2, n_req // 3))
    )
    stream_b = list(EventStream(data_b, "train"))[:n_req]
    data_c = make_cue_dataset(n_req, 2, cfg=ccfg)
    stream_c = list(EventStream(data_c, "train"))[:n_req]

    registry = ModelRegistry()
    registry.register("braille", cfg_b, params_b, backend="auto")
    registry.register("cue", cfg_c, params_c, backend="auto")
    eng = BatchedEngine(registry=registry, max_batch=opts.batch)

    # interleaved mixed-model traffic: requests alternate model per arrival
    mixed = []
    for evb, evc in zip(stream_b, stream_c):
        mixed.append((evb, "braille"))
        mixed.append((evc, "cue"))

    print(f"multi-model serving: braille(T={num_ticks}) + cue(T={ccfg.num_ticks}) "
          f"— {len(mixed)} mixed requests, batch={opts.batch}")
    eng.serve(iter(mixed))       # warm pass: compiles every tile shape
    best = None
    for _ in range(REPS):
        results, stats = eng.serve(iter(mixed))
        if best is None or stats.wall_s < best[1].wall_s:
            best = (results, stats)
    results, stats = best
    per = stats.per_model or {}
    for mid in ("braille", "cue"):
        s = per.get(mid)
        if s:
            print(f"  {mid:8s}: {s.requests:4d} requests  "
                  f"{s.samples_per_sec:9.1f} samples/s  {s.batches} tiles  "
                  f"p99={s.p99_latency_s*1e3:.2f} ms")

    # bitwise gate: per-model results vs two dedicated single-model engines
    ded_b = BatchedEngine(cfg_b, params_b, backend="auto",
                          max_batch=opts.batch)
    ded_c = BatchedEngine(cfg_c, params_c, backend="auto",
                          max_batch=opts.batch)
    ref_b, _ = ded_b.serve(iter(stream_b))
    ref_c, _ = ded_c.serve(iter(stream_c))
    mism = 0
    for mid, refs in (("braille", ref_b), ("cue", ref_c)):
        got = [r for r in results if r.model_id == mid]
        for g, r in zip(got, refs):
            if not np.array_equal(np.asarray(g.logits), np.asarray(r.logits)):
                mism += 1
    print(f"correctness: {len(results) - mism}/{len(results)} mixed-engine "
          f"results bitwise equal to the dedicated single-model engines")

    summary = {
        "requests": len(results),
        "batch": opts.batch,
        "models": {
            mid: {
                "requests": s.requests,
                "samples_per_sec": s.samples_per_sec,
                "batches": s.batches,
                "p50_latency_s": s.p50_latency_s,
                "p99_latency_s": s.p99_latency_s,
                "compiled_shapes": s.compiled_shapes,
                "hbm_bytes_streamed": s.hbm_bytes_streamed,
            }
            for mid, s in per.items()
        },
        "samples_per_sec": stats.samples_per_sec,
        "compiled_shapes": stats.compiled_shapes,
        "mismatches": mism,
    }
    if opts.out_dir:
        out_dir = Path(opts.out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        out = out_dir / "BENCH_serve.json"
        payload = {"schema": 1, "benchmark": "batched_serving",
                   "jax_backend": jax.default_backend()}
        if out.exists():     # merge alongside the other serving sections
            payload = json.loads(out.read_text())
        payload["multi_model"] = summary
        out.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
        print(f"wrote {out}")
    ok = mism == 0
    print(f"acceptance (per-model results bitwise equal to dedicated "
          f"engines): {'PASS' if ok else 'FAIL'}")
    return {"rc": 0 if ok else 1, "multi_model": summary}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer requests")
    ap.add_argument("--smoke", action="store_true",
                    help="alias for --fast (the CI smoke lanes)")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--ragged", action="store_true",
                    help="mixed tick lengths (exercises bucketing)")
    ap.add_argument("--sharded", action="store_true",
                    help="serve through a data mesh over every visible "
                         "device (admission scales with device count)")
    ap.add_argument("--streaming", action="store_true",
                    help="stateful session streaming instead of the "
                         "whole-sample comparison")
    ap.add_argument("--multi-model", action="store_true",
                    help="Braille + cue registered in one engine, served "
                         "over a mixed stream (bitwise-gated vs dedicated "
                         "engines; per-model throughput recorded)")
    ap.add_argument("--sessions", type=int, default=0,
                    help="concurrent sessions for --streaming "
                         "(default 10000, or 1024 under --smoke/--fast)")
    ap.add_argument("--tick-tile", type=int, default=0,
                    help="fixed streaming tile tick length (0 = throughput "
                         "mode: each tile drains what its sessions have)")
    ap.add_argument("--out-dir", default="",
                    help="also write BENCH_serve.json here")
    opts = ap.parse_args(argv)
    opts.fast = opts.fast or opts.smoke

    if opts.streaming:
        return main_streaming(opts)
    if opts.multi_model:
        return main_multi_model(opts)

    num_ticks = 128
    n_req = 128 if opts.fast else 512
    cfg = Presets.braille(n_classes=3, num_ticks=num_ticks)
    params = init_params(jax.random.key(0), cfg)
    weights = trainable(params)

    per_class = max(2, n_req // 3)
    data = make_braille_dataset(
        "AEU", BrailleConfig(num_ticks=num_ticks, samples_per_class=per_class)
    )
    stream = list(EventStream(data, "train"))[:n_req]
    if opts.ragged:
        stream = _ragged_stream(stream, num_ticks)

    print(f"braille config: n_in={cfg.n_in} n_hid={cfg.n_hid} n_out={cfg.n_out} "
          f"T={num_ticks}  requests={len(stream)}  batch={opts.batch}")

    seq_preds, seq_sps, seq_wall = run_sequential(cfg, weights, stream)
    print(f"sequential controller loop : {seq_sps:9.1f} samples/s  "
          f"({seq_wall*1e3:8.1f} ms wall)")

    mesh = None
    if opts.sharded:
        from repro.launch.mesh import make_data_mesh

        mesh = make_data_mesh()
        print(f"sharded serving over {len(jax.devices())} device(s)")
    results, stats = run_batched(cfg, params, stream, opts.batch, mesh=mesh)
    print(f"batched engine (B≤{opts.batch:3d})   : {stats.samples_per_sec:9.1f} samples/s  "
          f"({stats.wall_s*1e3:8.1f} ms wall, {stats.batches} tiles, "
          f"{stats.compiled_shapes} shapes)")
    print(f"request latency            : p50={stats.p50_latency_s*1e3:.2f} ms  "
          f"p99={stats.p99_latency_s*1e3:.2f} ms  mean_batch={stats.mean_batch:.1f}")

    speedup = stats.samples_per_sec / seq_sps
    mism = sum(int(a != b.pred) for a, b in zip(seq_preds, results))
    print(f"speedup: {speedup:.1f}x   prediction mismatches vs sequential: "
          f"{mism}/{len(stream)}")
    # machine-readable summary for benchmarks/run.py → BENCH_serve.json
    summary = {
        "requests": len(stream),
        "batch": opts.batch,
        "num_devices": len(jax.devices()) if opts.sharded else 1,
        "samples_per_sec": stats.samples_per_sec,
        "sequential_samples_per_sec": seq_sps,
        "speedup": speedup,
        "p50_latency_s": stats.p50_latency_s,
        "p99_latency_s": stats.p99_latency_s,
        "mean_batch": stats.mean_batch,
        "compiled_shapes": stats.compiled_shapes,
        "hbm_bytes_streamed": stats.hbm_bytes_streamed,
        "mismatches": mism,
    }
    if opts.out_dir:
        out = Path(opts.out_dir) / "BENCH_serve.json"
        out.write_text(json.dumps(
            {"schema": 1, "benchmark": "batched_serving",
             "jax_backend": jax.default_backend(), **summary},
            indent=2, sort_keys=True) + "\n")
        print(f"wrote {out}")
    import os

    # virtual CPU devices are never the wall-clock target (they share the
    # host cores regardless of count) — the single-device lane gates speedup
    virtual_devices = opts.sharded and jax.default_backend() == "cpu"
    if opts.batch < 32 or virtual_devices:
        # the ≥4x bar is defined for batch ≥ 32 on comparable hardware;
        # smaller tiles are latency-oriented configurations, and virtual CPU
        # devices oversubscribing the physical cores make wall-clock
        # speedup meaningless (the single-device lane gates throughput) —
        # the sharded run still gates correctness per request
        why = (f"batch {opts.batch} < 32" if opts.batch < 32 else
               f"{len(jax.devices())} virtual CPU devices on "
               f"{os.cpu_count()} cores")
        print(f"acceptance: speedup gate n/a ({why}) "
              f"(outputs match: {'yes' if mism == 0 else 'NO'})")
        return {"rc": 0 if mism == 0 else 1, "serve": summary}
    status = "PASS" if (speedup >= 4.0 and mism == 0) else "FAIL"
    print(f"acceptance (≥4x at batch ≥ 32, outputs match): {status}")
    return {"rc": 0 if status == "PASS" else 1, "serve": summary}


if __name__ == "__main__":
    import sys

    out = main()
    sys.exit(out["rc"] if isinstance(out, dict) else out)
