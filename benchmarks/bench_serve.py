"""Batched serving vs the sequential controller loop (ISSUE 1 tentpole bench).

Measures end-to-end classification throughput on the Braille config:

* **sequential** — the FSM-faithful baseline: one sample at a time through
  the jit'd single-sample inference entry
  (:func:`repro.core.controller.make_infer_fn`), host-decoded per request —
  how the chip serves its AER bus;
* **batched**    — :class:`repro.serve.BatchedEngine`: requests bucketed by
  tick length, padded into batch tiles, one jit'd forward per tile shape.

Reports samples/sec for both, the speedup (acceptance: ≥ 4× at batch ≥ 32),
and the batched path's p50/p99 request latency.  Compile time is excluded
from both sides via warmup.  A ragged-stream mode exercises the bucketing
scheduler with mixed tick lengths.

    PYTHONPATH=src python -m benchmarks.bench_serve [--fast] [--batch 64]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import aer
from repro.core.controller import make_infer_fn
from repro.core.rsnn import Presets, init_params, trainable
from repro.data.braille import BrailleConfig, make_braille_dataset
from repro.data.pipeline import EventStream
from repro.serve import BatchedEngine
from repro.serve.batching import decode_events_host, request_ticks

REPS = 3   # best-of-N measurement passes (noisy shared-CPU containers)


def _ragged_stream(base_stream, num_ticks, seed=0):
    """Re-encode each sample truncated to a random length — mixed-tick
    traffic for the bucketing scheduler."""
    rng = np.random.default_rng(seed)
    out = []
    for ev in base_stream:
        t = int(rng.integers(num_ticks // 2, num_ticks + 1))
        kind = np.asarray(ev, np.uint32) >> 24
        ticks = np.asarray(ev, np.uint32) & aer.MAX_TICK
        keep = (ticks < t) | (kind == aer.EVT_LABEL)
        words = np.asarray(ev, np.uint32)[keep & (kind != aer.EVT_END)]
        words = np.minimum(words, (words & ~np.uint32(aer.MAX_TICK)) | (t - 1))
        end = np.uint32((aer.EVT_END << 24) | (t - 1))
        out.append(np.concatenate([words, [end]]))
    return out


def run_sequential(cfg, weights, stream):
    infer = make_infer_fn(cfg)
    # pre-compile every tick-length the stream contains (steady-state timing,
    # same treatment the batched side gets)
    for ticks in sorted({request_ticks(ev) for ev in stream}):
        r, v, _ = decode_events_host([stream[0]], cfg.n_in, ticks, cfg.label_delay)
        jax.block_until_ready(infer(weights, r[:, 0], v[:, 0])["acc_y"])

    best_wall, preds = float("inf"), []
    for _ in range(REPS):  # best-of-N: the container CPU is noisy
        run = []
        t0 = time.perf_counter()
        for ev in stream:
            ticks = request_ticks(ev)
            raster, valid, _ = decode_events_host([ev], cfg.n_in, ticks, cfg.label_delay)
            out = infer(weights, raster[:, 0], valid[:, 0])
            run.append(int(jax.block_until_ready(out["pred"])))
        wall = time.perf_counter() - t0
        if wall < best_wall:
            best_wall, preds = wall, run
    return preds, len(stream) / best_wall, best_wall


def run_batched(cfg, params, stream, batch, granularity=32, mesh=None):
    eng = BatchedEngine(
        cfg, params, backend="auto", max_batch=batch,
        tick_granularity=granularity, mesh=mesh,
    )
    eng.serve(iter(stream))      # warm pass: compiles every tile shape
    best = None
    for _ in range(REPS):        # best-of-N steady-state pass
        results, stats = eng.serve(iter(stream))
        if best is None or stats.wall_s < best[1].wall_s:
            best = (results, stats)
    return best


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer requests")
    ap.add_argument("--smoke", action="store_true",
                    help="alias for --fast (the CI smoke lanes)")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--ragged", action="store_true",
                    help="mixed tick lengths (exercises bucketing)")
    ap.add_argument("--sharded", action="store_true",
                    help="serve through a data mesh over every visible "
                         "device (admission scales with device count)")
    ap.add_argument("--out-dir", default="",
                    help="also write BENCH_serve.json here")
    opts = ap.parse_args(argv)
    opts.fast = opts.fast or opts.smoke

    num_ticks = 128
    n_req = 128 if opts.fast else 512
    cfg = Presets.braille(n_classes=3, num_ticks=num_ticks)
    params = init_params(jax.random.key(0), cfg)
    weights = trainable(params)

    per_class = max(2, n_req // 3)
    data = make_braille_dataset(
        "AEU", BrailleConfig(num_ticks=num_ticks, samples_per_class=per_class)
    )
    stream = list(EventStream(data, "train"))[:n_req]
    if opts.ragged:
        stream = _ragged_stream(stream, num_ticks)

    print(f"braille config: n_in={cfg.n_in} n_hid={cfg.n_hid} n_out={cfg.n_out} "
          f"T={num_ticks}  requests={len(stream)}  batch={opts.batch}")

    seq_preds, seq_sps, seq_wall = run_sequential(cfg, weights, stream)
    print(f"sequential controller loop : {seq_sps:9.1f} samples/s  "
          f"({seq_wall*1e3:8.1f} ms wall)")

    mesh = None
    if opts.sharded:
        from repro.launch.mesh import make_data_mesh

        mesh = make_data_mesh()
        print(f"sharded serving over {len(jax.devices())} device(s)")
    results, stats = run_batched(cfg, params, stream, opts.batch, mesh=mesh)
    print(f"batched engine (B≤{opts.batch:3d})   : {stats.samples_per_sec:9.1f} samples/s  "
          f"({stats.wall_s*1e3:8.1f} ms wall, {stats.batches} tiles, "
          f"{stats.compiled_shapes} shapes)")
    print(f"request latency            : p50={stats.p50_latency_s*1e3:.2f} ms  "
          f"p99={stats.p99_latency_s*1e3:.2f} ms  mean_batch={stats.mean_batch:.1f}")

    speedup = stats.samples_per_sec / seq_sps
    mism = sum(int(a != b.pred) for a, b in zip(seq_preds, results))
    print(f"speedup: {speedup:.1f}x   prediction mismatches vs sequential: "
          f"{mism}/{len(stream)}")
    # machine-readable summary for benchmarks/run.py → BENCH_serve.json
    summary = {
        "requests": len(stream),
        "batch": opts.batch,
        "num_devices": len(jax.devices()) if opts.sharded else 1,
        "samples_per_sec": stats.samples_per_sec,
        "sequential_samples_per_sec": seq_sps,
        "speedup": speedup,
        "p50_latency_s": stats.p50_latency_s,
        "p99_latency_s": stats.p99_latency_s,
        "mean_batch": stats.mean_batch,
        "compiled_shapes": stats.compiled_shapes,
        "hbm_bytes_streamed": stats.hbm_bytes_streamed,
        "mismatches": mism,
    }
    if opts.out_dir:
        out = Path(opts.out_dir) / "BENCH_serve.json"
        out.write_text(json.dumps(
            {"schema": 1, "benchmark": "batched_serving",
             "jax_backend": jax.default_backend(), **summary},
            indent=2, sort_keys=True) + "\n")
        print(f"wrote {out}")
    import os

    # virtual CPU devices are never the wall-clock target (they share the
    # host cores regardless of count) — the single-device lane gates speedup
    virtual_devices = opts.sharded and jax.default_backend() == "cpu"
    if opts.batch < 32 or virtual_devices:
        # the ≥4x bar is defined for batch ≥ 32 on comparable hardware;
        # smaller tiles are latency-oriented configurations, and virtual CPU
        # devices oversubscribing the physical cores make wall-clock
        # speedup meaningless (the single-device lane gates throughput) —
        # the sharded run still gates correctness per request
        why = (f"batch {opts.batch} < 32" if opts.batch < 32 else
               f"{len(jax.devices())} virtual CPU devices on "
               f"{os.cpu_count()} cores")
        print(f"acceptance: speedup gate n/a ({why}) "
              f"(outputs match: {'yes' if mism == 0 else 'NO'})")
        return {"rc": 0 if mism == 0 else 1, "serve": summary}
    status = "PASS" if (speedup >= 4.0 and mism == 0) else "FAIL"
    print(f"acceptance (≥4x at batch ≥ 32, outputs match): {status}")
    return {"rc": 0 if status == "PASS" else 1, "serve": summary}


if __name__ == "__main__":
    import sys

    out = main()
    sys.exit(out["rc"] if isinstance(out, dict) else out)
