"""Chaos / fault-tolerance benchmark: recovery time, checkpoint overhead,
and the bitwise-recovery gate — the measurement half of
``tests/test_fault_tolerance.py`` and ``tests/test_robustness.py``.

``--smoke`` (the CI acceptance run) does three things at Braille smoke
scale and writes ``BENCH_chaos.json``:

1. **bitwise gate** — SIGKILL a subprocess training run at a commit
   boundary, restart it until it exits clean, and require the final
   quantized weights to equal an uninterrupted run bit for bit;
2. **recovery time** — how long the restarted worker takes from process
   start to its first post-resume commit (restore + recompile + replay);
3. **checkpoint overhead** — per-commit wall time with checkpointing off /
   async / blocking at the smoke policy cadence, reported as p50/p99
   commit-stall milliseconds, added-ms-per-commit, and a samples-per-second
   overhead percentage.  The acceptance gate — async checkpointing costs
   **<10%** samples/s vs no checkpointing — enforces on real accelerator
   devices; on shared-CPU CI runners the number is recorded, not enforced
   (the repo-wide wall-clock-gate policy, see ``bench_braille --sharded``).

``--serve --smoke`` is the serving-path chaos drill (ISSUE 10): per
backend/quant config it runs a clean streaming baseline, then the same
workload under (a) malformed-stream fuzzing at the guard boundary, (b)
injected launch faults (lane restart + bit-exact session re-seat), and
(c) an overload storm against bounded shed queues — gating that healthy
sessions stay **bitwise equal** to the clean run, queue memory stays
bounded, and the engine never dies.  It also measures the clean-path
guard overhead (gated **<5%** samples/s on accelerator devices, recorded
on shared-CPU CI), and merges a ``"serve"`` section into the same
``BENCH_chaos.json``.

Usage:
    python -m benchmarks.bench_chaos --smoke [--out-dir .]
    python -m benchmarks.bench_chaos --serve --smoke [--out-dir .]
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path
from typing import Dict, Optional

import numpy as np

from repro.train import chaos

def merge_write(out_dir: Optional[str], updates: Dict) -> Optional[Path]:
    """Merge ``updates`` into ``BENCH_chaos.json`` (training smoke and the
    serve drill each own their top-level keys, so either can run alone
    without clobbering the other's section)."""
    if out_dir is None:
        return None
    Path(out_dir).mkdir(parents=True, exist_ok=True)
    path = Path(out_dir) / "BENCH_chaos.json"
    payload: Dict = {"benchmark": "chaos", "schema": 1}
    if path.exists():
        try:
            payload = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            pass   # unreadable artifact: rewrite from scratch
    payload.update(updates)
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {path}")
    return path


SMOKE_KW = dict(epochs=4, samples_per_class=12, num_ticks=48, spb=16)
# Overhead is measured at the paper's operating point (256-tick Braille
# samples, BRAM batch depth 50) while the kill drill stays tiny for
# wall-clock; the 6 epochs give enough steady-state intervals for stable
# statistics.  every=2 is the smoke checkpoint cadence (one durable cut
# per 100 samples — still far hotter than any production policy).
OVERHEAD_KW = dict(epochs=6, samples_per_class=50, num_ticks=256, spb=50)
OVERHEAD_EVERY = 2
OVERHEAD_REPEATS = 3
OVERHEAD_GATE_PCT = 10.0


def measure_checkpoint_overhead(
    mode: str,
    ckpt_dir: Optional[str],
    checkpoint_every: int = OVERHEAD_EVERY,
    **kw,
) -> Dict[str, float]:
    """Per-commit wall-time stats for one checkpointing mode (one run).

    ``mode`` is ``"off"`` (no policy), ``"async"`` (saves queued to the
    background writer) or ``"sync"`` (blocking saves).  The timing hook
    blocks on the committed weights, so each interval between consecutive
    commits is the true commit stall — compute plus the save path that
    runs inside it.  Throughput comes from the mean of *intra-epoch*
    intervals (epoch boundaries carry offload/decode spikes that belong
    to the pipeline, not the checkpointer); p50/p99 commit-stall use all
    steady-state intervals.  Warm-up (jit compile) intervals are dropped.
    """
    import jax

    assert mode in ("off", "async", "sync"), mode
    learner, pipeline = chaos.build_learner(
        ckpt_dir if mode != "off" else None,
        async_save=(mode == "async"),
        checkpoint_every=checkpoint_every,
        **kw,
    )
    spb = learner.ctrl.samples_per_batch
    marks = []

    def hook(lrn, commits):
        jax.block_until_ready(lrn.weights)
        marks.append((time.perf_counter(), lrn.cursor.epoch))

    learner.fit(pipeline, on_commit=hook)
    if learner.ckpt is not None:
        learner.ckpt.wait()
    deltas = np.asarray([b[0] - a[0] for a, b in zip(marks, marks[1:])])[3:]
    clean = np.asarray(
        [b[0] - a[0] for a, b in zip(marks, marks[1:]) if a[1] == b[1]]
    )[3:]
    assert clean.size >= 5, "need a few steady-state commits to measure"
    return {
        "mode": mode,
        "commits": int(len(marks)),
        "checkpoint_every": int(checkpoint_every),
        "p50_commit_ms": float(np.percentile(deltas, 50) * 1e3),
        "p99_commit_ms": float(np.percentile(deltas, 99) * 1e3),
        "mean_commit_ms": float(np.mean(clean) * 1e3),
        "samples_per_s": float(spb / np.mean(clean)),
    }


def overhead_suite(
    ckpt_root: str,
    repeats: int = OVERHEAD_REPEATS,
    checkpoint_every: int = OVERHEAD_EVERY,
    **kw,
) -> Dict[str, Dict[str, float]]:
    """off/async/sync overhead, interleaved and best-of-``repeats``.

    Single-shot mode comparisons on a shared CPU carry large run-order
    noise (frequency/cache warm-up, co-tenant load); interleaving the
    modes and keeping each mode's best throughput cancels the drift.
    Returns ``{mode: stats}`` plus ``async_overhead_pct`` /
    ``sync_overhead_pct`` relative to ``off`` and the transferable
    ``*_added_ms_per_commit`` (overhead percentages shrink as the commit
    tile grows; the added milliseconds are what the checkpointer costs).
    """
    best: Dict[str, Dict[str, float]] = {}
    for rep in range(repeats):
        for mode in ("off", "async", "sync"):
            r = measure_checkpoint_overhead(
                mode, str(Path(ckpt_root) / f"{mode}{rep}"),
                checkpoint_every=checkpoint_every, **kw,
            )
            if (mode not in best
                    or r["samples_per_s"] > best[mode]["samples_per_s"]):
                best[mode] = r
    base = best["off"]["samples_per_s"]
    for mode in ("async", "sync"):
        best[f"{mode}_overhead_pct"] = 100.0 * (
            base - best[mode]["samples_per_s"]) / base
        best[f"{mode}_added_ms_per_commit"] = (
            best[mode]["mean_commit_ms"] - best["off"]["mean_commit_ms"])
    return best


def record_overhead_section() -> Dict[str, Dict[str, float]]:
    """Durability-cost record for the BENCH_train.json artifact: p50/p99
    commit-stall ms and samples/s with async saves on vs off, measured at
    the Braille smoke scale (the ISSUE-9 acceptance operating point) —
    ``bench_braille``/``bench_cue`` call this so the cost is tracked
    across PRs; the <10% gate itself runs in ``bench_chaos --smoke``."""
    print("== checkpoint overhead (Braille smoke scale, async writer vs off) ==")
    with tempfile.TemporaryDirectory() as d:
        suite = overhead_suite(d, **OVERHEAD_KW)
    for mode in ("off", "async", "sync"):
        r = suite[mode]
        print(f"  {mode:6s}: p50={r['p50_commit_ms']:7.2f}ms "
              f"p99={r['p99_commit_ms']:7.2f}ms "
              f"{r['samples_per_s']:8.1f} samples/s")
    print(f"  async overhead {suite['async_overhead_pct']:+.1f}% "
          f"(+{suite['async_added_ms_per_commit']:.2f}ms/commit), "
          f"blocking {suite['sync_overhead_pct']:+.1f}% "
          f"(gated <10% on accelerator devices by bench_chaos --smoke)")
    return suite


def smoke(out_dir: Optional[str] = None, seed: Optional[int] = None) -> Dict:
    rng = np.random.default_rng(seed)
    t0 = time.time()

    print("== golden (uninterrupted) run ==")
    gold = chaos.golden_run(**SMOKE_KW)

    print("== chaos drill: SIGKILL at a commit boundary, restart ==")
    wargs = [
        "--epochs", SMOKE_KW["epochs"],
        "--samples-per-class", SMOKE_KW["samples_per_class"],
        "--ticks", SMOKE_KW["num_ticks"],
        "--spb", SMOKE_KW["spb"],
    ]
    kill_at = int(rng.integers(1, 6))
    with tempfile.TemporaryDirectory() as d:
        out = str(Path(d) / "result")
        res = chaos.run_chaos(
            str(Path(d) / "ck"), out, ["--kill-at-commit", kill_at], wargs
        )
        got = chaos.load_result_weights(out)
    bitwise_ok = sorted(got) == sorted(gold) and all(
        np.array_equal(got[k], gold[k]) for k in gold
    )
    print(f"  killed at commit {kill_at}, resumed from "
          f"{res['resumed_from']}, restarts={res['restarts']}, "
          f"recovery={res['recovery_s']:.2f}s, bitwise_ok={bitwise_ok}")

    print("== checkpoint overhead: off vs async vs blocking ==")
    with tempfile.TemporaryDirectory() as d:
        overhead = overhead_suite(d, **OVERHEAD_KW)
    for mode in ("off", "async", "sync"):
        r = overhead[mode]
        print(f"  {mode:6s}: p50={r['p50_commit_ms']:8.2f}ms "
              f"p99={r['p99_commit_ms']:8.2f}ms "
              f"{r['samples_per_s']:8.1f} samples/s")
    async_pct = overhead["async_overhead_pct"]
    sync_pct = overhead["sync_overhead_pct"]
    print(f"  async overhead {async_pct:+.1f}% "
          f"(+{overhead['async_added_ms_per_commit']:.2f}ms/commit), "
          f"blocking {sync_pct:+.1f}% "
          f"(+{overhead['sync_added_ms_per_commit']:.2f}ms/commit)")

    # The bitwise-recovery gate binds everywhere.  The <10% overhead gate
    # is wall-clock: per the repo's policy (bench_braille --sharded, the
    # bench_serve floors), wall-clock gates enforce on real accelerator
    # devices only — shared-CPU CI runners carry co-tenant load that
    # swings a ~1ms/commit cost by more than the gate width, so there the
    # number is measured and recorded, not enforced.
    import jax

    gate_enforced = jax.default_backend() != "cpu"
    overhead_ok = (not gate_enforced) or async_pct < OVERHEAD_GATE_PCT

    rc = 0 if (bitwise_ok and overhead_ok) else 1
    if gate_enforced:
        print(f"acceptance (bitwise recovery AND async ckpt overhead "
              f"<{OVERHEAD_GATE_PCT}%): {'PASS' if rc == 0 else 'FAIL'}")
    else:
        print(f"acceptance: overhead gate n/a (shared CPU host; recorded "
              f"async {async_pct:+.1f}%); bitwise recovery "
              f"{'PASS' if rc == 0 else 'FAIL'}")
    payload = {
        "kill_at_commit": kill_at,
        "resumed_from": res["resumed_from"],
        "restarts": res["restarts"],
        "recovery_s": res["recovery_s"],
        "bitwise_ok": bool(bitwise_ok),
        "checkpoint_overhead": overhead,
        "overhead_gate_pct": OVERHEAD_GATE_PCT,
        "overhead_gate_enforced": bool(gate_enforced),
        "async_overhead_pct": async_pct,
        "sync_overhead_pct": sync_pct,
        "wall_s": time.time() - t0,
        "rc": rc,
    }
    merge_write(out_dir, payload)
    return payload


# --------------------------------------------------------------------------
# serving-path chaos (ISSUE 10): fuzz, faults, overload against the engine
# --------------------------------------------------------------------------

SERVE_GUARD_GATE_PCT = 5.0
SERVE_CONFIGS = (("scan", False), ("scan", True), ("kernel", False))


def _serve_setup(seed: int, n: int = 6, ticks: int = 48, quantized=False):
    import jax

    from repro.core import aer
    from repro.core.rsnn import Presets, init_params

    cfg = Presets.braille(n_classes=3, num_ticks=ticks, quantized=quantized)
    params = init_params(jax.random.key(seed), cfg)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        t = int(rng.integers(12, ticks + 1))
        raster = (rng.random((t, cfg.n_in)) < 0.25).astype(np.float32)
        ev = np.asarray(
            aer.encode_sample(raster, i % 3, label_tick=t // 4,
                              end_tick=t - 1),
            np.uint32,
        )
        reqs.append(ev[np.argsort(ev & aer.MAX_TICK, kind="stable")])
    return cfg, params, reqs, rng


def _stream_once(engine, reqs, abuse=None):
    """Run the streaming workload (two ragged feeds per session) and
    return the final logits per session; ``abuse(engine, handles, step)``
    injects hostile behaviour between feed rounds."""
    handles = [engine.open_session() for _ in reqs]
    for step in range(2):
        for h, ev in zip(handles, reqs):
            mid = len(ev) // 2
            h.feed(ev[:mid] if step == 0 else ev[mid:])
        if abuse is not None:
            abuse(engine, handles, step)
        engine.pump()
    engine.pump(drain=True)
    return [h.result() for h in handles]


def _bitwise_equal(got, want) -> bool:
    return len(got) == len(want) and all(
        g.status == w.status and g.pred == w.pred
        and np.array_equal(g.logits, w.logits)
        for g, w in zip(got, want)
    )


def _fuzz_words(rng, size: int) -> np.ndarray:
    """Raw 32-bit noise — virtually always malformed somewhere."""
    return rng.integers(0, 2**32, size=size, dtype=np.uint32)


def serve_chaos_config(backend: str, quantized: bool, seed: int) -> Dict:
    """One backend/quant config's serve drill: clean baseline, fuzz storm,
    injected launch faults, overload storm, guard overhead."""
    from repro.core.aer import AEREncodingError
    from repro.serve import BatchedEngine, OverloadError, ServeStatus

    cfg, params, reqs, rng = _serve_setup(seed, quantized=quantized)
    eng_kw = dict(backend=backend, max_batch=4, tick_tile=8)

    def engine(**kw):
        return BatchedEngine(cfg, params, **{**eng_kw, **kw})

    clean = _stream_once(engine(), reqs)

    # -- malformed-stream fuzzing: hostile feeds at the guard boundary.
    # Every rejection must be a typed AEREncodingError and the *same*
    # sessions' results must stay bitwise identical to the clean run.
    fuzz_stats = {"attempts": 0, "typed": 0}

    def fuzz_abuse(eng, handles, step):
        for _ in range(8):
            fuzz_stats["attempts"] += 1
            try:
                handles[0].feed(_fuzz_words(rng, int(rng.integers(1, 32))))
            except AEREncodingError:
                fuzz_stats["typed"] += 1
        try:
            eng.submit(_fuzz_words(rng, 16))
            fuzz_stats["attempts"] += 1
        except AEREncodingError:
            fuzz_stats["attempts"] += 1
            fuzz_stats["typed"] += 1

    eng = engine()
    fuzzed = _stream_once(eng, reqs, abuse=fuzz_abuse)
    fuzz_ok = (
        _bitwise_equal(fuzzed, clean)
        and fuzz_stats["typed"] == fuzz_stats["attempts"] > 0
    )

    # -- injected launch faults: every 3rd streaming launch dies; the lane
    # restarts (fresh backend, sessions re-seated from bit-exact eviction
    # snapshots) and final results must still match the clean run bitwise.
    count = [0]

    def flaky(model_id, kind):
        if kind != "stream":
            return
        count[0] += 1
        if count[0] % 3 == 0:
            raise RuntimeError(f"injected launch fault #{count[0]}")

    eng = BatchedEngine(cfg, params, fault_hook=flaky, **eng_kw)
    faulted = _stream_once(eng, reqs)
    restarts = eng.stream_stats(1.0).lane_restarts
    fault_ok = _bitwise_equal(faulted, clean) and restarts >= 1

    # -- overload storm: whole-sample serve() against a tiny bounded shed
    # queue.  Every submitted item must come back as a typed result
    # (OK | REJECTED), the queue must stay within its bound, and the
    # engine must serve cleanly afterwards (never dies).
    storm_reqs = []
    for i in range(24):
        t = 8 * (i % 5 + 1)
        raster = (rng.random((t, cfg.n_in)) < 0.25).astype(np.float32)
        from repro.core import aer
        ev = np.asarray(
            aer.encode_sample(raster, i % 3, label_tick=0, end_tick=t - 1),
            np.uint32,
        )
        storm_reqs.append(ev[np.argsort(ev & aer.MAX_TICK, kind="stable")])
    eng = BatchedEngine(
        cfg, params, backend=backend, max_batch=4, tick_granularity=8,
        max_pending=4, admission="shed", max_inflight_tiles=1,
    )
    res, stats = eng.serve(iter(storm_reqs))
    statuses = {r.status for r in res}
    bounded_ok = (
        len(res) == len(storm_reqs)
        and statuses <= {ServeStatus.OK, ServeStatus.REJECTED}
        and stats.shed > 0
        and eng.scheduler.pending <= 4
    )
    try:
        after, _ = eng.serve(iter(reqs[:2]))
        alive_ok = all(r.status is ServeStatus.OK for r in after)
    except Exception:
        alive_ok = False

    # Hard-reject policy: a full queue raises OverloadError at submit()
    # and admits nothing beyond the bound.
    eng = BatchedEngine(
        cfg, params, backend=backend, max_batch=8, max_pending=2,
    )
    rejected = 0
    for ev in storm_reqs[:6]:
        try:
            eng.submit(ev)
        except OverloadError:
            rejected += 1
    reject_ok = rejected == 4 and eng.scheduler.pending == 2

    ok = fuzz_ok and fault_ok and bounded_ok and alive_ok and reject_ok
    print(f"  {backend:6s} quant={str(quantized):5s}: "
          f"fuzz={'PASS' if fuzz_ok else 'FAIL'} "
          f"faults={'PASS' if fault_ok else 'FAIL'} "
          f"(restarts={restarts}) "
          f"overload={'PASS' if bounded_ok and reject_ok else 'FAIL'} "
          f"(shed={stats.shed}) alive={'PASS' if alive_ok else 'FAIL'}")
    return {
        "backend": backend,
        "quantized": bool(quantized),
        "fuzz_ok": bool(fuzz_ok),
        "fuzz_rejections": int(fuzz_stats["typed"]),
        "fault_ok": bool(fault_ok),
        "lane_restarts": int(restarts),
        "overload_ok": bool(bounded_ok and reject_ok),
        "shed": int(stats.shed),
        "alive_ok": bool(alive_ok),
        "ok": bool(ok),
    }


def measure_guard_overhead(seed: int, repeats: int = 3) -> Dict[str, float]:
    """Clean-path cost of input validation: whole-sample ``serve()``
    samples/s with the guard on vs ``guard=False``, best-of-``repeats``
    interleaved (same drift-cancelling policy as the checkpoint overhead
    suite)."""
    from repro.serve import BatchedEngine

    cfg, params, _, rng = _serve_setup(seed)
    reqs = []
    from repro.core import aer
    for i in range(64):
        t = int(rng.integers(12, 49))
        raster = (rng.random((t, cfg.n_in)) < 0.25).astype(np.float32)
        ev = np.asarray(
            aer.encode_sample(raster, i % 3, label_tick=0, end_tick=t - 1),
            np.uint32,
        )
        reqs.append(ev[np.argsort(ev & aer.MAX_TICK, kind="stable")])
    best = {}
    for _ in range(repeats):
        for mode, guard in (("on", None), ("off", False)):
            eng = BatchedEngine(
                cfg, params, backend="scan", max_batch=8, guard=guard
            )
            eng.warmup(48)
            _, stats = eng.serve(iter(reqs))
            sps = stats.samples_per_sec
            if mode not in best or sps > best[mode]:
                best[mode] = sps
    pct = 100.0 * (best["off"] - best["on"]) / best["off"]
    return {
        "samples_per_s_guard_on": float(best["on"]),
        "samples_per_s_guard_off": float(best["off"]),
        "guard_overhead_pct": float(pct),
    }


def serve_smoke(out_dir: Optional[str] = None, seed: Optional[int] = None) -> Dict:
    """The ``--serve --smoke`` acceptance drill; merges a ``"serve"``
    section into ``BENCH_chaos.json``."""
    import jax

    t0 = time.time()
    seed = 0 if seed is None else seed
    print("== serving chaos: fuzz / launch faults / overload ==")
    configs = [
        serve_chaos_config(be, q, seed) for be, q in SERVE_CONFIGS
    ]
    print("== clean-path guard overhead (scan backend) ==")
    overhead = measure_guard_overhead(seed)
    pct = overhead["guard_overhead_pct"]
    print(f"  guard on {overhead['samples_per_s_guard_on']:8.1f} samples/s, "
          f"off {overhead['samples_per_s_guard_off']:8.1f} samples/s "
          f"({pct:+.1f}%)")

    # Correctness gates (bitwise containment, bounded queues, liveness)
    # bind everywhere; the <5% guard-overhead gate is wall-clock and binds
    # on real accelerator devices only (repo policy, see smoke() above).
    gate_enforced = jax.default_backend() != "cpu"
    chaos_ok = all(c["ok"] for c in configs)
    overhead_ok = (not gate_enforced) or pct < SERVE_GUARD_GATE_PCT
    rc = 0 if (chaos_ok and overhead_ok) else 1
    if gate_enforced:
        print(f"acceptance (containment AND guard overhead "
              f"<{SERVE_GUARD_GATE_PCT}%): {'PASS' if rc == 0 else 'FAIL'}")
    else:
        print(f"acceptance: overhead gate n/a (shared CPU host; recorded "
              f"{pct:+.1f}%); containment "
              f"{'PASS' if chaos_ok else 'FAIL'}")
    section = {
        "configs": configs,
        "guard_overhead": overhead,
        "guard_gate_pct": SERVE_GUARD_GATE_PCT,
        "guard_gate_enforced": bool(gate_enforced),
        "chaos_ok": bool(chaos_ok),
        "wall_s": time.time() - t0,
        "rc": rc,
    }
    merge_write(out_dir, {"serve": section})
    return section


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: bitwise recovery + <10%% async overhead, "
                         "written to BENCH_chaos.json")
    ap.add_argument("--serve", action="store_true",
                    help="serving-path chaos drill: fuzz/fault/overload "
                         "containment + <5%% guard overhead, merged into "
                         "BENCH_chaos.json")
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--seed", type=int, default=None,
                    help="fix the randomized kill commit / serve fuzz seed")
    opts = ap.parse_args(argv)
    if not opts.smoke:
        ap.error("pass --smoke (optionally with --serve for the "
                 "serving-path drill)")
    if opts.serve:
        return serve_smoke(out_dir=opts.out_dir, seed=opts.seed)["rc"]
    return smoke(out_dir=opts.out_dir, seed=opts.seed)["rc"]


if __name__ == "__main__":
    sys.exit(main())
