"""Chaos / fault-tolerance benchmark: recovery time, checkpoint overhead,
and the bitwise-recovery gate — the measurement half of
``tests/test_fault_tolerance.py``.

``--smoke`` (the CI acceptance run) does three things at Braille smoke
scale and writes ``BENCH_chaos.json``:

1. **bitwise gate** — SIGKILL a subprocess training run at a commit
   boundary, restart it until it exits clean, and require the final
   quantized weights to equal an uninterrupted run bit for bit;
2. **recovery time** — how long the restarted worker takes from process
   start to its first post-resume commit (restore + recompile + replay);
3. **checkpoint overhead** — per-commit wall time with checkpointing off /
   async / blocking at the smoke policy cadence, reported as p50/p99
   commit-stall milliseconds, added-ms-per-commit, and a samples-per-second
   overhead percentage.  The acceptance gate — async checkpointing costs
   **<10%** samples/s vs no checkpointing — enforces on real accelerator
   devices; on shared-CPU CI runners the number is recorded, not enforced
   (the repo-wide wall-clock-gate policy, see ``bench_braille --sharded``).

Usage:
    python -m benchmarks.bench_chaos --smoke [--out-dir .]
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path
from typing import Dict, Optional

import numpy as np

from repro.train import chaos

SMOKE_KW = dict(epochs=4, samples_per_class=12, num_ticks=48, spb=16)
# Overhead is measured at the paper's operating point (256-tick Braille
# samples, BRAM batch depth 50) while the kill drill stays tiny for
# wall-clock; the 6 epochs give enough steady-state intervals for stable
# statistics.  every=2 is the smoke checkpoint cadence (one durable cut
# per 100 samples — still far hotter than any production policy).
OVERHEAD_KW = dict(epochs=6, samples_per_class=50, num_ticks=256, spb=50)
OVERHEAD_EVERY = 2
OVERHEAD_REPEATS = 3
OVERHEAD_GATE_PCT = 10.0


def measure_checkpoint_overhead(
    mode: str,
    ckpt_dir: Optional[str],
    checkpoint_every: int = OVERHEAD_EVERY,
    **kw,
) -> Dict[str, float]:
    """Per-commit wall-time stats for one checkpointing mode (one run).

    ``mode`` is ``"off"`` (no policy), ``"async"`` (saves queued to the
    background writer) or ``"sync"`` (blocking saves).  The timing hook
    blocks on the committed weights, so each interval between consecutive
    commits is the true commit stall — compute plus the save path that
    runs inside it.  Throughput comes from the mean of *intra-epoch*
    intervals (epoch boundaries carry offload/decode spikes that belong
    to the pipeline, not the checkpointer); p50/p99 commit-stall use all
    steady-state intervals.  Warm-up (jit compile) intervals are dropped.
    """
    import jax

    assert mode in ("off", "async", "sync"), mode
    learner, pipeline = chaos.build_learner(
        ckpt_dir if mode != "off" else None,
        async_save=(mode == "async"),
        checkpoint_every=checkpoint_every,
        **kw,
    )
    spb = learner.ctrl.samples_per_batch
    marks = []

    def hook(lrn, commits):
        jax.block_until_ready(lrn.weights)
        marks.append((time.perf_counter(), lrn.cursor.epoch))

    learner.fit(pipeline, on_commit=hook)
    if learner.ckpt is not None:
        learner.ckpt.wait()
    deltas = np.asarray([b[0] - a[0] for a, b in zip(marks, marks[1:])])[3:]
    clean = np.asarray(
        [b[0] - a[0] for a, b in zip(marks, marks[1:]) if a[1] == b[1]]
    )[3:]
    assert clean.size >= 5, "need a few steady-state commits to measure"
    return {
        "mode": mode,
        "commits": int(len(marks)),
        "checkpoint_every": int(checkpoint_every),
        "p50_commit_ms": float(np.percentile(deltas, 50) * 1e3),
        "p99_commit_ms": float(np.percentile(deltas, 99) * 1e3),
        "mean_commit_ms": float(np.mean(clean) * 1e3),
        "samples_per_s": float(spb / np.mean(clean)),
    }


def overhead_suite(
    ckpt_root: str,
    repeats: int = OVERHEAD_REPEATS,
    checkpoint_every: int = OVERHEAD_EVERY,
    **kw,
) -> Dict[str, Dict[str, float]]:
    """off/async/sync overhead, interleaved and best-of-``repeats``.

    Single-shot mode comparisons on a shared CPU carry large run-order
    noise (frequency/cache warm-up, co-tenant load); interleaving the
    modes and keeping each mode's best throughput cancels the drift.
    Returns ``{mode: stats}`` plus ``async_overhead_pct`` /
    ``sync_overhead_pct`` relative to ``off`` and the transferable
    ``*_added_ms_per_commit`` (overhead percentages shrink as the commit
    tile grows; the added milliseconds are what the checkpointer costs).
    """
    best: Dict[str, Dict[str, float]] = {}
    for rep in range(repeats):
        for mode in ("off", "async", "sync"):
            r = measure_checkpoint_overhead(
                mode, str(Path(ckpt_root) / f"{mode}{rep}"),
                checkpoint_every=checkpoint_every, **kw,
            )
            if (mode not in best
                    or r["samples_per_s"] > best[mode]["samples_per_s"]):
                best[mode] = r
    base = best["off"]["samples_per_s"]
    for mode in ("async", "sync"):
        best[f"{mode}_overhead_pct"] = 100.0 * (
            base - best[mode]["samples_per_s"]) / base
        best[f"{mode}_added_ms_per_commit"] = (
            best[mode]["mean_commit_ms"] - best["off"]["mean_commit_ms"])
    return best


def record_overhead_section() -> Dict[str, Dict[str, float]]:
    """Durability-cost record for the BENCH_train.json artifact: p50/p99
    commit-stall ms and samples/s with async saves on vs off, measured at
    the Braille smoke scale (the ISSUE-9 acceptance operating point) —
    ``bench_braille``/``bench_cue`` call this so the cost is tracked
    across PRs; the <10% gate itself runs in ``bench_chaos --smoke``."""
    print("== checkpoint overhead (Braille smoke scale, async writer vs off) ==")
    with tempfile.TemporaryDirectory() as d:
        suite = overhead_suite(d, **OVERHEAD_KW)
    for mode in ("off", "async", "sync"):
        r = suite[mode]
        print(f"  {mode:6s}: p50={r['p50_commit_ms']:7.2f}ms "
              f"p99={r['p99_commit_ms']:7.2f}ms "
              f"{r['samples_per_s']:8.1f} samples/s")
    print(f"  async overhead {suite['async_overhead_pct']:+.1f}% "
          f"(+{suite['async_added_ms_per_commit']:.2f}ms/commit), "
          f"blocking {suite['sync_overhead_pct']:+.1f}% "
          f"(gated <10% on accelerator devices by bench_chaos --smoke)")
    return suite


def smoke(out_dir: Optional[str] = None, seed: Optional[int] = None) -> Dict:
    rng = np.random.default_rng(seed)
    t0 = time.time()

    print("== golden (uninterrupted) run ==")
    gold = chaos.golden_run(**SMOKE_KW)

    print("== chaos drill: SIGKILL at a commit boundary, restart ==")
    wargs = [
        "--epochs", SMOKE_KW["epochs"],
        "--samples-per-class", SMOKE_KW["samples_per_class"],
        "--ticks", SMOKE_KW["num_ticks"],
        "--spb", SMOKE_KW["spb"],
    ]
    kill_at = int(rng.integers(1, 6))
    with tempfile.TemporaryDirectory() as d:
        out = str(Path(d) / "result")
        res = chaos.run_chaos(
            str(Path(d) / "ck"), out, ["--kill-at-commit", kill_at], wargs
        )
        got = chaos.load_result_weights(out)
    bitwise_ok = sorted(got) == sorted(gold) and all(
        np.array_equal(got[k], gold[k]) for k in gold
    )
    print(f"  killed at commit {kill_at}, resumed from "
          f"{res['resumed_from']}, restarts={res['restarts']}, "
          f"recovery={res['recovery_s']:.2f}s, bitwise_ok={bitwise_ok}")

    print("== checkpoint overhead: off vs async vs blocking ==")
    with tempfile.TemporaryDirectory() as d:
        overhead = overhead_suite(d, **OVERHEAD_KW)
    for mode in ("off", "async", "sync"):
        r = overhead[mode]
        print(f"  {mode:6s}: p50={r['p50_commit_ms']:8.2f}ms "
              f"p99={r['p99_commit_ms']:8.2f}ms "
              f"{r['samples_per_s']:8.1f} samples/s")
    async_pct = overhead["async_overhead_pct"]
    sync_pct = overhead["sync_overhead_pct"]
    print(f"  async overhead {async_pct:+.1f}% "
          f"(+{overhead['async_added_ms_per_commit']:.2f}ms/commit), "
          f"blocking {sync_pct:+.1f}% "
          f"(+{overhead['sync_added_ms_per_commit']:.2f}ms/commit)")

    # The bitwise-recovery gate binds everywhere.  The <10% overhead gate
    # is wall-clock: per the repo's policy (bench_braille --sharded, the
    # bench_serve floors), wall-clock gates enforce on real accelerator
    # devices only — shared-CPU CI runners carry co-tenant load that
    # swings a ~1ms/commit cost by more than the gate width, so there the
    # number is measured and recorded, not enforced.
    import jax

    gate_enforced = jax.default_backend() != "cpu"
    overhead_ok = (not gate_enforced) or async_pct < OVERHEAD_GATE_PCT

    rc = 0 if (bitwise_ok and overhead_ok) else 1
    if gate_enforced:
        print(f"acceptance (bitwise recovery AND async ckpt overhead "
              f"<{OVERHEAD_GATE_PCT}%): {'PASS' if rc == 0 else 'FAIL'}")
    else:
        print(f"acceptance: overhead gate n/a (shared CPU host; recorded "
              f"async {async_pct:+.1f}%); bitwise recovery "
              f"{'PASS' if rc == 0 else 'FAIL'}")
    payload = {
        "benchmark": "chaos",
        "schema": 1,
        "kill_at_commit": kill_at,
        "resumed_from": res["resumed_from"],
        "restarts": res["restarts"],
        "recovery_s": res["recovery_s"],
        "bitwise_ok": bool(bitwise_ok),
        "checkpoint_overhead": overhead,
        "overhead_gate_pct": OVERHEAD_GATE_PCT,
        "overhead_gate_enforced": bool(gate_enforced),
        "async_overhead_pct": async_pct,
        "sync_overhead_pct": sync_pct,
        "wall_s": time.time() - t0,
        "rc": rc,
    }
    if out_dir is not None:
        Path(out_dir).mkdir(parents=True, exist_ok=True)
        path = Path(out_dir) / "BENCH_chaos.json"
        path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
        print(f"wrote {path}")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: bitwise recovery + <10%% async overhead, "
                         "written to BENCH_chaos.json")
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--seed", type=int, default=None,
                    help="fix the randomized kill commit")
    opts = ap.parse_args(argv)
    if not opts.smoke:
        ap.error("only --smoke is implemented; pass --smoke")
    return smoke(out_dir=opts.out_dir, seed=opts.seed)["rc"]


if __name__ == "__main__":
    sys.exit(main())
