"""Benchmark entry point: one benchmark per paper table/figure.

  Fig. 6  cue accumulation (both controller modes)  -> bench_cue
  Fig. 7/8 Braille 3/4-class online learning        -> bench_braille
  T1/T2   resource analog (two SoC modes)           -> bench_resources
  kernels allclose + µbench                         -> bench_kernels
  serving batched vs sequential throughput          -> bench_serve
  §Roofline table (from dry-run JSONs, if present)  -> roofline

``python -m benchmarks.run [--fast]`` — default runs the paper's full
200-epoch Braille protocol; ``--fast`` trims it to 25 epochs.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    opts = ap.parse_args(argv)

    from benchmarks import bench_cue, bench_kernels, bench_resources
    from benchmarks import bench_braille, bench_serve, roofline

    jobs = [
        ("kernels", lambda: bench_kernels.main([])),
        ("serve", lambda: bench_serve.main(["--fast"] if opts.fast else [])),
        ("cue", lambda: bench_cue.main([])),
        ("resources", lambda: bench_resources.main([])),
        ("braille", lambda: bench_braille.main(
            ["--epochs", "25"] if opts.fast else ["--epochs", "200"])),
        ("roofline", lambda: roofline.main([])),
    ]
    failures = []
    for name, fn in jobs:
        if opts.only and name not in opts.only.split(","):
            continue
        print(f"\n===== {name} =====", flush=True)
        try:
            rc = fn()
            # benches return data rows for callers; an int is an exit code
            # (bench_serve signals acceptance failure with 1)
            if isinstance(rc, int) and rc != 0:
                failures.append(name)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"\nFAILED: {failures}")
        return 1
    print("\nall benchmarks complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
