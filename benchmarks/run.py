"""Benchmark entry point: one benchmark per paper table/figure.

  Fig. 6  cue accumulation (both controller modes)  -> bench_cue
  Fig. 7/8 Braille online learning (both commits)   -> bench_braille
  T1/T2   resource analog (two SoC modes)           -> bench_resources
  kernels allclose + µbench                         -> bench_kernels
  serving batched vs sequential throughput          -> bench_serve
  stateful session streaming (events/s, tick p99)   -> bench_serve --streaming
  multi-model serving (Braille + cue, one engine)   -> bench_serve --multi-model
  achieved-vs-roofline bandwidth + Bt auto-tune     -> roofline

``--fast`` also swaps the full cue run for its 3-seed END_B-vs-END_S
acceptance smoke (``bench_cue --smoke``); its section folds into
``BENCH_train.json`` under ``"cue"``, and the multi-model per-model
throughput folds into ``BENCH_serve.json`` under ``"multi_model"``.

``python -m benchmarks.run [--fast]`` — default runs the paper's full
200-epoch Braille protocol; ``--fast`` trims braille to its 12-epoch smoke
(throughput + commit-mode parity) and shrinks the serving stream.

Machine-readable outputs (the cross-PR perf trajectory, uploaded as CI
artifacts): ``BENCH_train.json`` (training samples/sec per commit mode +
accuracy), ``BENCH_serve.json`` (serving samples/sec, p50/p99 latency) and
``BENCH_kernels.json`` (per-op samples/s + analytic HBM bytes-streamed,
written by ``bench_kernels`` itself — its traffic-ratio gates are what the
kernels smoke lane enforces) are written to ``--out-dir`` (default: cwd)
for every run that includes the corresponding benchmark.

Benchmarks return either data rows, or a dict with an ``"rc"`` exit code
plus payloads run.py folds into the JSON reports; a non-zero rc (or an
exception) fails the whole run — CI propagates it.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback
from pathlib import Path


def _write_report(path: Path, payload: dict) -> None:
    import jax

    payload = {
        "schema": 1,
        "unix_time": time.time(),
        "jax_backend": jax.default_backend(),
        "host": platform.machine(),
        **payload,
    }
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {path}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--out-dir", default=".")
    opts = ap.parse_args(argv)

    from benchmarks import bench_cue, bench_kernels, bench_resources
    from benchmarks import bench_braille, bench_serve, roofline

    jobs = [
        ("kernels", lambda: bench_kernels.main(
            ["--out-dir", opts.out_dir] + (["--smoke"] if opts.fast else []))),
        ("serve", lambda: bench_serve.main(["--fast"] if opts.fast else [])),
        ("streaming", lambda: bench_serve.main(
            ["--streaming"] + (["--fast"] if opts.fast else []))),
        ("multi_model", lambda: bench_serve.main(
            ["--multi-model"] + (["--fast"] if opts.fast else []))),
        ("cue", lambda: bench_cue.main(["--smoke"] if opts.fast else [])),
        ("resources", lambda: bench_resources.main([])),
        ("braille", lambda: bench_braille.main(
            ["--smoke"] if opts.fast else ["--epochs", "200"])),
        ("roofline", lambda: roofline.main(["--bench-dir", opts.out_dir])),
    ]
    failures = []
    reports = {}
    for name, fn in jobs:
        if opts.only and name not in opts.only.split(","):
            continue
        print(f"\n===== {name} =====", flush=True)
        try:
            rc = fn()
            if isinstance(rc, dict):
                reports[name] = rc
                rc = rc.get("rc", 0)
            # benches return data rows for callers; an int is an exit code
            if isinstance(rc, int) and rc != 0:
                failures.append(name)
        except Exception:
            traceback.print_exc()
            failures.append(name)

    out_dir = Path(opts.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    if "braille" in reports or "cue" in reports:
        payload = {"benchmark": "braille_training"}
        if "braille" in reports:
            r = reports["braille"]
            payload["rows"] = r.get("rows", [])
            payload["throughput"] = r.get("throughput")
        if "cue" in reports and reports["cue"].get("cue"):
            payload["cue"] = reports["cue"]["cue"]
        _write_report(out_dir / "BENCH_train.json", payload)
    if any(
        k in reports and reports[k].get(v)
        for k, v in (("serve", "serve"), ("streaming", "streaming"),
                     ("multi_model", "multi_model"))
    ):
        payload = {"benchmark": "batched_serving"}
        if "serve" in reports:
            payload.update(reports["serve"].get("serve") or {})
        if "streaming" in reports:
            payload["streaming"] = reports["streaming"]["streaming"]
        if "multi_model" in reports:
            payload["multi_model"] = reports["multi_model"]["multi_model"]
        _write_report(out_dir / "BENCH_serve.json", payload)

    if failures:
        print(f"\nFAILED: {failures}")
        return 1
    print("\nall benchmarks complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
