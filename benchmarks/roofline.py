"""§Roofline — turn dry-run JSON records into the three-term roofline table.

  compute term    = HLO_FLOPs / peak_FLOP/s                  (per device)
  memory term     = HLO_bytes / HBM_bw
  collective term = collective_wire_bytes / ICI_bw

Uses the calibrated costs (``cost_corrected``: loop-trip-count de-aliased)
when present; hardware constants from :mod:`repro.launch.mesh` (TPU v5e).
MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (serve).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records(tag: str = "baseline", mesh: str = "16x16", d: Path = DRYRUN_DIR):
    recs = []
    for f in sorted(d.glob(f"*__{mesh}__{tag}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def analyze(rec: dict) -> dict:
    if rec.get("skip"):
        return {"arch": rec["arch"], "shape": rec["shape"], "skip": rec["skip"]}
    if "error" in rec:
        return {"arch": rec["arch"], "shape": rec["shape"], "error": rec["error"]}
    cost = rec.get("cost_corrected") or rec.get("cost") or {}
    n_dev = rec["n_devices"]
    flops = cost.get("flops", -1)
    bytes_acc = cost.get("bytes_accessed", -1)
    coll = cost.get("collective_wire_bytes",
                    rec.get("collectives", {}).get("total_wire_bytes", 0.0))
    mem = rec.get("memory", {})
    live_bytes = (
        mem.get("argument_bytes", 0) + mem.get("output_bytes", 0)
        + mem.get("temp_bytes", 0)
    )
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_acc / HBM_BW           # unfused-HLO bytes: upper bound
    t_memory_live = live_bytes / HBM_BW     # one pass over live data: lower
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound_hi = max(terms.values())                       # conservative
    bound_lo = max(t_compute, t_memory_live, t_coll)     # optimistic (fused)
    model_flops_dev = rec["model_flops"] / n_dev
    ideal = model_flops_dev / PEAK_FLOPS_BF16
    out = {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_live_s": t_memory_live,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": ideal / bound_hi if bound_hi > 0 else 0.0,
        "roofline_fraction_fused": ideal / bound_lo if bound_lo > 0 else 0.0,
        "useful_flops_ratio": model_flops_dev / flops if flops > 0 else 0.0,
        "hbm_gib_per_device": (
            mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
        ) / 2**30,
        "fits_16g": (
            mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
        ) <= 16 * 2**30,
    }
    return out


def fmt_table(rows) -> str:
    hdr = (
        f"{'arch':26s} {'shape':12s} {'compute_s':>9s} {'mem_hi_s':>9s} {'mem_lo_s':>9s} "
        f"{'collect_s':>9s} {'dom':>7s} {'roof%':>6s} {'roof%f':>6s} {'useful%':>7s} {'HBM_GiB':>7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if "skip" in r:
            lines.append(f"{r['arch']:26s} {r['shape']:12s} SKIP: {r['skip'][:70]}")
            continue
        if "error" in r:
            lines.append(f"{r['arch']:26s} {r['shape']:12s} ERROR: {r['error'][:70]}")
            continue
        lines.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['t_compute_s']:>9.4f} "
            f"{r['t_memory_s']:>9.4f} {r['t_memory_live_s']:>9.4f} "
            f"{r['t_collective_s']:>9.4f} {r['dominant']:>7s} "
            f"{100*r['roofline_fraction']:>5.1f}% {100*r['roofline_fraction_fused']:>5.1f}% "
            f"{100*r['useful_flops_ratio']:>6.1f}% {r['hbm_gib_per_device']:>7.2f}"
        )
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json-out")
    opts = ap.parse_args(argv)
    rows = [analyze(r) for r in load_records(opts.tag, opts.mesh)]
    print(fmt_table(rows))
    if opts.json_out:
        Path(opts.json_out).write_text(json.dumps(rows, indent=2))
    return rows


if __name__ == "__main__":
    main()
