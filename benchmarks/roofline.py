"""§Roofline — achieved-vs-roofline bandwidth for the RSNN kernels, plus
`Bt`/`vmem_budget` auto-tuning from the as-executed byte formulas.

Primary mode (the revived one): consume the ``bandwidth_records`` that
``benchmarks/bench_kernels.py`` folds into ``BENCH_kernels.json`` —
``{"op", "bytes", "seconds"}`` per timed launch — and print

  achieved GB/s   = analytic as-executed bytes / measured wall-clock
  roofline GB/s   = the running device's peak HBM bandwidth
                    (:func:`repro.kernels.traffic.device_roofline`)
  roofline frac   = achieved / roofline

On hosts without an accelerator the device resolves to the CPU fallback and
every ``roofline_frac`` is ``-``: interpret-mode wall-clock says nothing
about kernel bandwidth, so the numbers are recorded for trend only (same
policy as the CI serve gate).  Never crashes when no records exist — it
prints how to produce them and moves on.

Auto-tune: instead of the hand-picked ``Bt`` guidance that used to live in
``docs/perf_tuning.md``, sweep the VMEM budget ladder, derive each budget's
batch tile from the kernels' own bytes helpers
(:func:`repro.kernels.rsnn_step.max_forward_tile` /
:func:`max_fused_train_tile` — the single tile-sizing source), evaluate the
as-executed event-streaming bytes per sample at the *measured* density
(:func:`repro.kernels.traffic.infer_dma_tiled_bytes` et al.), and report the
per-op ``(Bt, vmem_budget)`` minimizing bytes/sample on this device.

Legacy mode: the transformer dry-run analysis (HLO-cost three-term roofline
from ``experiments/dryrun/*.json``) is kept behind the same entry point and
silently skipped when the directory does not exist.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.kernels import events, traffic
from repro.kernels.rsnn_step import max_forward_tile, max_fused_train_tile
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, VMEM_BYTES

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

# The budget ladder the auto-tuner sweeps (bytes) — powers of two up to the
# device VMEM; the derived tile is what actually changes between rungs.
_BUDGET_LADDER_MIB = (2, 4, 8, 16, 32, 64, 128)


# ---------------------------------------------------------------------------
# kernel-records mode (primary): BENCH_kernels.json -> bandwidth table
# ---------------------------------------------------------------------------


def load_kernel_records(bench_dir: Path):
    """``(records, meta)`` from ``BENCH_kernels.json`` — empty when the
    bench has not run (never an exception: dormancy was the old bug)."""
    f = Path(bench_dir) / "BENCH_kernels.json"
    if not f.exists():
        return [], {}
    try:
        payload = json.loads(f.read_text())
    except (OSError, json.JSONDecodeError):
        return [], {}
    return payload.get("bandwidth_records", []), payload


def kernel_bandwidth_rows(records, roofline=None):
    return traffic.bandwidth_table(records, roofline)


def fmt_bandwidth(rows, roofline) -> str:
    hdr = (f"{'op':28s} {'samples/s':>10s} {'achieved_GB/s':>13s} "
           f"{'roofline_GB/s':>13s} {'frac':>6s}")
    lines = [f"device: {roofline['kind']}  (measured={roofline['measured']})",
             hdr, "-" * len(hdr)]
    for r in rows:
        frac = "-" if r["roofline_frac"] is None else f"{r['roofline_frac']:.3f}"
        sps = r.get("samples_per_s")
        sps_s = f"{sps:.1f}" if isinstance(sps, (int, float)) else "-"
        lines.append(
            f"{r['op']:28s} {sps_s:>10s} {r['achieved_gbps']:>13.2f} "
            f"{r['roofline_gbps']:>13.1f} {frac:>6s}"
        )
    return "\n".join(lines)


def autotune(T, B, n_in, n_hid, n_out, density, vmem_total=VMEM_BYTES):
    """Per-op ``(Bt, vmem_budget)`` minimizing as-executed event-streaming
    bytes per sample at the measured density — the replacement for the
    hand-picked values the docs used to carry.  Pure analytics (the same
    formulas the CI traffic gates enforce), so it runs identically on the
    CPU fallback; ties break toward the smaller budget (leave VMEM spare)."""
    budgets = [m << 20 for m in _BUDGET_LADDER_MIB if (m << 20) <= vmem_total]
    ops = {
        "infer": (lambda vb: max_forward_tile(n_in, n_hid, n_out, vb),
                  traffic.infer_dma_tiled_bytes),
        "train": (lambda vb: max_fused_train_tile(T, n_in, n_hid, n_out, vb),
                  traffic.train_dma_tiled_bytes),
    }
    out = {}
    for op, (tile_of, bytes_of) in ops.items():
        best = None
        for vb in budgets:
            bt = max(1, min(tile_of(vb), B))
            bd = events.block_density(density, bt, n_in)
            per = bytes_of(T, B, n_in, n_hid, n_out,
                           block_density=bd, batch_tile=bt) / B
            row = {"vmem_budget": vb, "batch_tile": bt,
                   "block_density": bd, "bytes_per_sample": per}
            if best is None or per < best["bytes_per_sample"] - 1e-9:
                best = row
        out[op] = best
    return out


def fmt_autotune(tuned, T, B, density) -> str:
    lines = [f"auto-tuned tiles (T={T}, B={B}, measured density={density:.4f}):"]
    for op, r in tuned.items():
        lines.append(
            f"  {op:6s} Bt={r['batch_tile']:<4d} "
            f"vmem_budget={r['vmem_budget'] >> 20}MiB  "
            f"block_density={r['block_density']:.3f}  "
            f"bytes/sample={r['bytes_per_sample']:.0f}"
        )
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# legacy dry-run mode: HLO-cost three-term roofline (transformer records)
# ---------------------------------------------------------------------------


def load_records(tag: str = "baseline", mesh: str = "16x16", d: Path = DRYRUN_DIR):
    if not Path(d).is_dir():
        return []
    recs = []
    for f in sorted(Path(d).glob(f"*__{mesh}__{tag}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def analyze(rec: dict) -> dict:
    if rec.get("skip"):
        return {"arch": rec["arch"], "shape": rec["shape"], "skip": rec["skip"]}
    if "error" in rec:
        return {"arch": rec["arch"], "shape": rec["shape"], "error": rec["error"]}
    cost = rec.get("cost_corrected") or rec.get("cost") or {}
    n_dev = rec["n_devices"]
    flops = cost.get("flops", -1)
    bytes_acc = cost.get("bytes_accessed", -1)
    coll = cost.get("collective_wire_bytes",
                    rec.get("collectives", {}).get("total_wire_bytes", 0.0))
    mem = rec.get("memory", {})
    live_bytes = (
        mem.get("argument_bytes", 0) + mem.get("output_bytes", 0)
        + mem.get("temp_bytes", 0)
    )
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_acc / HBM_BW           # unfused-HLO bytes: upper bound
    t_memory_live = live_bytes / HBM_BW     # one pass over live data: lower
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound_hi = max(terms.values())                       # conservative
    bound_lo = max(t_compute, t_memory_live, t_coll)     # optimistic (fused)
    model_flops_dev = rec["model_flops"] / n_dev
    ideal = model_flops_dev / PEAK_FLOPS_BF16
    out = {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_live_s": t_memory_live,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": ideal / bound_hi if bound_hi > 0 else 0.0,
        "roofline_fraction_fused": ideal / bound_lo if bound_lo > 0 else 0.0,
        "useful_flops_ratio": model_flops_dev / flops if flops > 0 else 0.0,
        "hbm_gib_per_device": (
            mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
        ) / 2**30,
        "fits_16g": (
            mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
        ) <= 16 * 2**30,
    }
    return out


def fmt_table(rows) -> str:
    hdr = (
        f"{'arch':26s} {'shape':12s} {'compute_s':>9s} {'mem_hi_s':>9s} {'mem_lo_s':>9s} "
        f"{'collect_s':>9s} {'dom':>7s} {'roof%':>6s} {'roof%f':>6s} {'useful%':>7s} {'HBM_GiB':>7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if "skip" in r:
            lines.append(f"{r['arch']:26s} {r['shape']:12s} SKIP: {r['skip'][:70]}")
            continue
        if "error" in r:
            lines.append(f"{r['arch']:26s} {r['shape']:12s} ERROR: {r['error'][:70]}")
            continue
        lines.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['t_compute_s']:>9.4f} "
            f"{r['t_memory_s']:>9.4f} {r['t_memory_live_s']:>9.4f} "
            f"{r['t_collective_s']:>9.4f} {r['dominant']:>7s} "
            f"{100*r['roofline_fraction']:>5.1f}% {100*r['roofline_fraction_fused']:>5.1f}% "
            f"{100*r['useful_flops_ratio']:>6.1f}% {r['hbm_gib_per_device']:>7.2f}"
        )
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-dir", default=".",
                    help="directory holding BENCH_kernels.json")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json-out")
    opts = ap.parse_args(argv)

    roofline = traffic.device_roofline()
    records, payload = load_kernel_records(Path(opts.bench_dir))
    result = {"device": roofline, "rc": 0}

    if records:
        rows = kernel_bandwidth_rows(records, roofline)
        print(fmt_bandwidth(rows, roofline))
        result["bandwidth"] = rows
        tile = payload.get("tile", {})
        density = payload.get("event_density_braille")
        if tile and density is not None:
            tuned = autotune(
                tile["T"], max(tile["B"], 512), tile["n_in"],
                tile["n_hid"], tile["n_out"], float(density),
            )
            print(fmt_autotune(tuned, tile["T"], max(tile["B"], 512),
                               float(density)))
            result["autotune"] = tuned
    else:
        print(f"no kernel records under {opts.bench_dir!r} — run "
              "`python -m benchmarks.bench_kernels` first "
              "(achieved-bandwidth table skipped)")

    legacy = [analyze(r) for r in load_records(opts.tag, opts.mesh)]
    if legacy:
        print(fmt_table(legacy))
        result["dryrun"] = legacy

    if opts.json_out:
        Path(opts.json_out).write_text(json.dumps(result, indent=2) + "\n")
    return result


if __name__ == "__main__":
    main()
