"""Paper §4.1 / Tables 1–2 analog — resource & traffic accounting of the
two controller modes.

LUT/FF/DSP/BRAM columns do not transfer off the FPGA; the TPU-runtime
analog reported here (DESIGN.md §2):

  device-resident dataset bytes   (Table 1's "datasets in BRAM")
  host→device traffic per epoch   (Table 2's batched AXI offload)
  weight-"SRAM" bytes             (8-bit grid weights, both modes)
  step latency per sample         (controller throughput)
"""

from __future__ import annotations

import time

import jax

from repro.core.controller import ControllerConfig, OnlineLearner
from repro.core.rsnn import Presets, sram_bytes
from repro.data.cue import CueConfig, make_cue_dataset
from repro.data.pipeline import make_pipeline
from repro.optim.eprop_opt import EpropSGDConfig


def run_mode(mode: str, epochs: int = 3):
    ccfg = CueConfig()
    data = make_cue_dataset(50, 50, cfg=ccfg)
    cfg = Presets.cue_accumulation(num_ticks=ccfg.num_ticks)
    pipe = make_pipeline(mode, data, samples_per_batch=10)
    learner = OnlineLearner(
        cfg, ControllerConfig(num_epochs=epochs),
        EpropSGDConfig(lr=0.01, clip=10.0), jax.random.key(0),
    )
    learner.fit(pipe)  # includes jit warmup on epoch 0
    t0 = time.time()
    learner.train_epoch(pipe, epochs)
    per_sample = (time.time() - t0) / 50
    return {
        "mode": mode,
        "resident_bytes": pipe.stats.resident_bytes,
        "h2d_bytes_total": pipe.stats.h2d_bytes,
        "h2d_transfers": pipe.stats.transfers,
        "weight_sram_bytes": sram_bytes(cfg),
        "s_per_sample": per_sample,
    }


def main(argv=None):
    print("resource analog of Tables 1/2 (see DESIGN.md §2 for the mapping)")
    rows = [run_mode("xheep"), run_mode("arm")]
    hdr = f"{'mode':6s} {'resident_B':>12s} {'h2d_B':>12s} {'transfers':>9s} {'w_sram_B':>9s} {'ms/sample':>10s}"
    print(hdr)
    for r in rows:
        print(
            f"{r['mode']:6s} {r['resident_bytes']:>12,d} {r['h2d_bytes_total']:>12,d} "
            f"{r['h2d_transfers']:>9d} {r['weight_sram_bytes']:>9d} "
            f"{r['s_per_sample']*1e3:>10.2f}"
        )
    print("name,us_per_call,derived")
    for r in rows:
        print(
            f"resources_{r['mode']},{r['s_per_sample']*1e6:.0f},"
            f"resident_bytes={r['resident_bytes']}"
        )
    return rows


if __name__ == "__main__":
    main()
