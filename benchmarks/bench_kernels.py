"""Per-op kernel benchmarks: samples/s + analytic HBM bytes-streamed.

Four sections, all folded into ``BENCH_kernels.json`` (a CI artifact
alongside the train/serve benches):

* **allclose** — the op-specialized kernels (fused train, inference-only)
  against the split two-kernel pipeline and the jnp oracles.  On CPU the
  Pallas kernels execute under ``interpret=True``, so these are correctness
  artifacts, not speed claims.
* **event parity** — the DMA event-streaming kernels (``stream="dma"``)
  against the blocked kernels, and the scan backend's row-compacted sparse
  input projection against its dense path, asserted **bitwise equal** in the
  same run that records the perf numbers — dispatch never changes results.
* **traffic** — the analytic per-op HBM data-movement table
  (:mod:`repro.kernels.traffic`) for a cue-sized tile, before (two-kernel /
  trace-streaming) vs after (fused), plus the event-driven rows at the
  *measured* Braille density (``data.pipeline.event_density`` — not the
  assumed 2-5% constant).  CI gates: the fused-vs-baseline ratios of PR 5,
  and now (a) the DMA train path must move ≤ 1/1.4 the fused train bytes at
  the measured density (the read-raster-once win is density-independent),
  and (b) the DMA infer path must never move *more* bytes than the dense
  fused one (at high block density the bitmap is its only overhead).
* **wall-clock** — measured samples/s.  On TPU this times the compiled
  kernels and gates the ISSUE 7 speedups (DMA infer ≥ 2x, DMA fused-train
  ≥ 1.5x vs the PR 5 dense kernels at the measured density).  On CPU the
  kernels run interpret-mode, so wall-clock says nothing about them: the
  scan backend is timed instead and every speedup row is **recorded only**
  (same policy as the PR 5 serve gate); the achieved-bandwidth table is
  still written (``BENCH_bandwidth.json``) with ``roofline_frac=None``.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.backend import ExecutionBackend
from repro.core.eprop import EpropConfig
from repro.core.neuron import NeuronConfig
from repro.core.rsnn import RSNNConfig
from repro.kernels import events, ops, ref, traffic
from repro.kernels.rsnn_step import max_forward_tile, max_fused_train_tile

# Cue-accumulation-sized tile — the shape the paper's Fig. 6 protocol runs.
T, B, N, H, O = 100, 16, 40, 100, 2


def _time(fn, *args, iters=5):
    out = fn(*args)  # warmup/compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _tile(key, p=0.2):
    ks = jax.random.split(key, 6)
    raster = (jax.random.uniform(ks[0], (T, B, N)) < p).astype(jnp.float32)
    w_in = jax.random.normal(ks[1], (N, H)) * 0.4
    w_rec = jax.random.normal(ks[2], (H, H)) * 0.2 * (1 - jnp.eye(H))
    w_out = jax.random.normal(ks[3], (H, O)) * 0.3
    label = jax.random.randint(ks[4], (B,), 0, O)
    y_star = jax.nn.one_hot(label, O)
    t = jnp.arange(T)[:, None]
    valid = ((t >= T // 4) & (t <= T - 1)).astype(jnp.float32) * jnp.ones((T, B))
    return raster, w_in, w_rec, w_out, y_star, valid


def measured_braille_density():
    """The *measured* per-channel Braille event density — what the traffic
    gates and the dispatch policy consume instead of the assumed constant."""
    from repro.data.braille import make_braille_dataset

    ds = make_braille_dataset("AEU")
    return float(ds["train"]["event_density"]), str(ds["train"]["source"])


def check_tiled_big_batch(alpha=0.99, kappa=0.78):
    """allclose at B=512 — previously impossible (the kernels rejected
    B > 128): the batch-tiled fused train/infer kernels against the scan
    backend, both on a shortened T=24 tile so the interpret-mode walk stays
    cheap (the traffic-ratio gates cover the full cue-length shape)."""
    B_big, T_train = 512, 24
    cfg = RSNNConfig(
        n_in=N, n_hid=H, n_out=O, num_ticks=T_train,
        neuron=NeuronConfig(alpha=alpha, kappa=kappa),
        eprop=EpropConfig(mode="factored"),
    )
    ks = jax.random.split(jax.random.key(7), 4)
    w = {
        "w_in": jax.random.normal(ks[0], (N, H)) * 0.4,
        "w_rec": jax.random.normal(ks[1], (H, H)) * 0.2 * (1 - jnp.eye(H)),
        "w_out": jax.random.normal(ks[2], (H, O)) * 0.3,
    }
    raster = (jax.random.uniform(ks[3], (T_train, B_big, N)) < 0.2).astype(
        jnp.float32)
    y_star = jax.nn.one_hot(jnp.arange(B_big) % O, O)
    t = jnp.arange(T_train)[:, None]
    valid = ((t >= T_train // 4)).astype(jnp.float32) * jnp.ones((T_train, B_big))

    dw_s, m_s = ExecutionBackend(cfg, "scan").train_tile(w, raster, y_star, valid)
    dw_k, m_k = ExecutionBackend(cfg, "kernel").train_tile(w, raster, y_star, valid)
    err_train = max(
        float(jnp.abs(dw_k[k] - dw_s[k]).max()
              / jnp.maximum(1.0, jnp.abs(dw_s[k]).max()))
        for k in dw_s
    )
    out_s = ExecutionBackend(cfg, "scan").inference(w, raster, valid)
    out_k = ExecutionBackend(cfg, "kernel").inference(w, raster, valid)
    err_inf = float(
        jnp.abs(out_k["acc_y"] - out_s["acc_y"]).max()
        / jnp.maximum(1.0, jnp.abs(out_s["acc_y"]).max())
    )
    pred_mismatch = int((out_k["pred"] != out_s["pred"]).sum())
    return {"train_fused_b512": err_train, "infer_fused_b512": err_inf,
            "pred_mismatch_b512": float(pred_mismatch)}


def check_kernels(alpha=0.99, kappa=0.78):
    """allclose: fused kernels vs the two-kernel pipeline + jnp oracles."""
    raster, w_in, w_rec, w_out, y_star, valid = _tile(jax.random.key(0))

    out_k = ops.rsnn_forward(raster, w_in, w_rec, w_out, alpha=alpha, kappa=kappa)
    out_r = ref.rsnn_forward_ref(raster, w_in, w_rec, w_out, alpha, kappa, 1.0)
    err_fwd = max(float(jnp.abs(out_k[k] - out_r[k]).max()) for k in out_r)

    # two-kernel train baseline: streamed forward -> XLA error -> reverse pass
    err_t = (jax.nn.softmax(out_k["y"], axis=-1) - y_star[None]) * valid[..., None]
    dw_base = ops.eprop_update(
        out_k["h"], out_k["xbar"], out_k["pbar"], out_k["zbar"], err_t, w_out,
        kappa=kappa,
    )
    dw_fused = ops.rsnn_train(
        raster, y_star, valid, w_in, w_rec, w_out, w_out,
        alpha=alpha, kappa=kappa,
    )
    # relative, like err_inf below: dw magnitudes grow with T·B, so an
    # absolute gate would trip on benign reassociation of compiled kernels
    err_train = max(
        float(jnp.abs(a - b).max() / jnp.maximum(1.0, jnp.abs(a).max()))
        for a, b in zip(dw_base, dw_fused[:3])
    )

    acc_base = (out_k["y"] * valid[..., None]).sum(axis=0)
    acc_fused, _ = ops.rsnn_infer(
        raster, valid, w_in, w_rec, w_out, alpha=alpha, kappa=kappa
    )
    # relative: the fused kernel accumulates sequentially, XLA's reduce in
    # pairs — same sums, different float association
    err_inf = float(
        jnp.abs(acc_base - acc_fused).max()
        / jnp.maximum(1.0, jnp.abs(acc_base).max())
    )

    return {"forward": err_fwd, "train_fused": err_train, "infer_fused": err_inf}


def check_event_parity(alpha=0.99, kappa=0.78):
    """The ISSUE 7 dispatch-invariance contract, asserted in the *same run*
    that records the perf numbers: returns per-path mismatch element counts
    (every one must be zero — the paths are bitwise-identical by design).

    * kernel backend: ``stream="dma"`` (double-buffered fetch, quiet-block
      skip) vs the blocked kernels, on a Braille-sparse tile;
    * scan backend: the row-compacted sparse input projection (capacity
      below T·B so the gather path genuinely executes) vs dense.
    """
    # real-recordings sparsity (~3%) so the bitmap actually skips blocks and
    # the sparse gather's capacity sits well below T*B
    raster, w_in, w_rec, w_out, y_star, valid = _tile(jax.random.key(3), p=0.03)
    mism = {}

    acc_b, spk_b = ops.rsnn_infer(
        raster, valid, w_in, w_rec, w_out, alpha=alpha, kappa=kappa)
    acc_d, spk_d = ops.rsnn_infer(
        raster, valid, w_in, w_rec, w_out, alpha=alpha, kappa=kappa,
        stream="dma")
    mism["infer_dma_vs_blocked"] = int(
        (acc_b != acc_d).sum() + (spk_b != spk_d).sum())

    tr_b = ops.rsnn_train(raster, y_star, valid, w_in, w_rec, w_out, w_out,
                          alpha=alpha, kappa=kappa)
    tr_d = ops.rsnn_train(raster, y_star, valid, w_in, w_rec, w_out, w_out,
                          alpha=alpha, kappa=kappa, stream="dma")
    mism["train_dma_vs_blocked"] = int(
        sum(int((a != b).sum()) for a, b in zip(tr_b, tr_d)))

    cfg = RSNNConfig(
        n_in=N, n_hid=H, n_out=O, num_ticks=T,
        neuron=NeuronConfig(alpha=alpha, kappa=kappa),
        eprop=EpropConfig(mode="factored"),
    )
    w = {"w_in": w_in, "w_rec": w_rec, "w_out": w_out}
    d_tile = float(events.raster_density(raster))
    be_dense = ExecutionBackend(cfg, "scan", sparsity="dense")
    be_event = ExecutionBackend(cfg, "scan", sparsity="event",
                                event_density=d_tile)
    o1 = be_dense.inference(w, raster, valid)
    o2 = be_event.inference(w, raster, valid)
    dw1, _ = be_dense.train_tile(w, raster, y_star, valid)
    dw2, _ = be_event.train_tile(w, raster, y_star, valid)
    mism["scan_event_vs_dense"] = int(
        (o1["acc_y"] != o2["acc_y"]).sum()
        + sum(int((dw1[k] != dw2[k]).sum()) for k in dw1))
    return mism


def density_traffic(d_meas, B_big=512):
    """The event-driven data-movement rows at the *measured* density: for
    each launch shape, the as-executed block density of the derived batch
    tile and the DMA-vs-dense byte ratios the CI lane gates."""
    out = {}
    for tag, b in (("b16", B), (f"b{B_big}", B_big)):
        bt_i = max(1, min(max_forward_tile(N, H, O), b))
        bt_t = max(1, min(max_fused_train_tile(T, N, H, O), b))
        bd_i = events.block_density(d_meas, bt_i, N)
        bd_t = events.block_density(d_meas, bt_t, N)
        dense_i = traffic.infer_fused_tiled_bytes(T, b, N, H, O)
        dense_t = traffic.train_fused_tiled_bytes(T, b, N, H, O)
        dma_i = traffic.infer_dma_tiled_bytes(
            T, b, N, H, O, block_density=bd_i, batch_tile=bt_i)
        dma_t = traffic.train_dma_tiled_bytes(
            T, b, N, H, O, block_density=bd_t, batch_tile=bt_t)
        out[tag] = {
            "block_density_infer": bd_i, "block_density_train": bd_t,
            "infer_fused_bytes": dense_i, "infer_dma_bytes": dma_i,
            "train_fused_bytes": dense_t, "train_dma_bytes": dma_t,
            "infer_ratio": dense_i / dma_i, "train_ratio": dense_t / dma_t,
        }
    # edge single-stream point (bt=1): where the per-tick block skip bites —
    # recorded for the serving story, not gated (weights dominate tiny tiles)
    bd1 = events.block_density(d_meas, 1, N)
    out["edge_b1"] = {
        "block_density": bd1,
        "infer_ratio": traffic.infer_fused_tiled_bytes(T, 1, N, H, O)
        / traffic.infer_dma_tiled_bytes(T, 1, N, H, O, block_density=bd1,
                                        batch_tile=1),
        "train_ratio": traffic.train_fused_tiled_bytes(T, 1, N, H, O)
        / traffic.train_dma_tiled_bytes(T, 1, N, H, O, block_density=bd1,
                                        batch_tile=1),
    }
    return out


def wall_clock(d_meas):
    """Measured samples/s per op, as bandwidth-table records
    ``{"op", "bytes", "seconds", "samples_per_s", "measured"}``.

    TPU: the compiled kernels — dense (blocked) vs event (DMA) — gated.
    CPU: the scan backend (dense vs sparse-projection), *recorded only*;
    the interpret-mode kernels are never timed (meaningless wall-clock).
    """
    raster, w_in, w_rec, w_out, y_star, valid = _tile(jax.random.key(1))
    sparse_raster = (_tile(jax.random.key(4), p=d_meas))[0]
    recs = []
    on_tpu = jax.default_backend() == "tpu"

    def rec(op, bts, secs, b, measured):
        recs.append({"op": op, "bytes": int(bts), "seconds": secs,
                     "samples_per_s": b / secs, "measured": measured})

    if on_tpu:
        bt_i = max(1, min(max_forward_tile(N, H, O), B))
        bt_t = max(1, min(max_fused_train_tile(T, N, H, O), B))
        bd_i = events.block_density(d_meas, bt_i, N)
        bd_t = events.block_density(d_meas, bt_t, N)

        def infer_blocked(r):
            return ops.rsnn_infer(r, valid, w_in, w_rec, w_out,
                                  alpha=0.99, kappa=0.78)

        def infer_dma(r):
            return ops.rsnn_infer(r, valid, w_in, w_rec, w_out,
                                  alpha=0.99, kappa=0.78, stream="dma")

        def train_blocked(r):
            return ops.rsnn_train(r, y_star, valid, w_in, w_rec, w_out,
                                  w_out, alpha=0.99, kappa=0.78)

        def train_dma(r):
            return ops.rsnn_train(r, y_star, valid, w_in, w_rec, w_out,
                                  w_out, alpha=0.99, kappa=0.78, stream="dma")

        rec("infer_blocked[tpu]",
            traffic.infer_fused_tiled_bytes(T, B, N, H, O),
            _time(infer_blocked, sparse_raster), B, True)
        rec("infer_dma[tpu]",
            traffic.infer_dma_tiled_bytes(T, B, N, H, O, block_density=bd_i),
            _time(infer_dma, sparse_raster), B, True)
        rec("train_blocked[tpu]",
            traffic.train_fused_tiled_bytes(T, B, N, H, O),
            _time(train_blocked, sparse_raster), B, True)
        rec("train_dma[tpu]",
            traffic.train_dma_tiled_bytes(T, B, N, H, O, block_density=bd_t),
            _time(train_dma, sparse_raster), B, True)
    else:
        cfg = RSNNConfig(
            n_in=N, n_hid=H, n_out=O, num_ticks=T,
            neuron=NeuronConfig(alpha=0.99, kappa=0.78),
            eprop=EpropConfig(mode="factored"),
        )
        w = {"w_in": w_in, "w_rec": w_rec, "w_out": w_out}
        be = ExecutionBackend(cfg, "scan", sparsity="dense")
        be_ev = ExecutionBackend(cfg, "scan", sparsity="event",
                                 event_density=d_meas)
        s_train = _time(lambda: be.train_tile(w, raster, y_star, valid), iters=3)
        s_inf = _time(lambda: be.inference(w, raster, valid), iters=3)
        s_inf_ev = _time(lambda: be_ev.inference(w, sparse_raster, valid),
                         iters=3)
        rec("train_tile[scan-cpu]",
            traffic.train_fused_tiled_bytes(T, B, N, H, O), s_train, B, False)
        rec("inference[scan-cpu]",
            traffic.infer_fused_tiled_bytes(T, B, N, H, O), s_inf, B, False)
        cap = events.suggest_row_capacity(T, B, d_meas, n_in=N)
        rec("inference_event[scan-cpu]",
            traffic.sparse_projection_bytes(T, B, N, H, cap), s_inf_ev, B,
            False)
        # the previously-rejected launch shape, now a single backend call
        B_big = 512
        k = jax.random.key(2)
        raster_b = (jax.random.uniform(k, (T, B_big, N)) < 0.2).astype(
            jnp.float32)
        y_star_b = jax.nn.one_hot(jnp.arange(B_big) % O, O)
        valid_b = valid[:, :1] * jnp.ones((T, B_big))
        s_train_b = _time(
            lambda: be.train_tile(w, raster_b, y_star_b, valid_b), iters=3)
        rec("train_tile_b512[scan-cpu]",
            traffic.train_fused_tiled_bytes(T, B_big, N, H, O), s_train_b,
            B_big, False)
    return recs, on_tpu


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=".")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: skip the interpret-mode B=512 allclose "
                         "walk (tier-1 tests cover batch-tiled parity); all "
                         "traffic + parity gates still run")
    opts = ap.parse_args(argv)

    d_meas, d_source = measured_braille_density()
    errs = check_kernels()
    errs_big = {} if opts.smoke else check_tiled_big_batch()
    parity = check_event_parity()
    table = traffic.op_table(T, B, N, H, O)
    train_ratio = table["train_two_kernel"] / table["train_fused"]
    infer_ratio = table["infer_streamed"] / table["infer_fused"]
    # the previously-impossible launch: B=512, four tiles+ per op
    B_BIG = 512
    table_big = traffic.op_table(T, B_BIG, N, H, O)
    tiles_big = traffic.tile_table(T, B_BIG, N, H, O)
    train_ratio_big = table_big["train_two_kernel"] / table_big["train_fused"]
    infer_ratio_big = table_big["infer_streamed"] / table_big["infer_fused"]
    dens = density_traffic(d_meas, B_BIG)
    records, on_tpu = wall_clock(d_meas)
    roofline = traffic.device_roofline()
    bw_table = traffic.bandwidth_table(records, roofline)

    print(f"measured braille density: {d_meas:.4f} ({d_source})")
    print("op,bytes_per_launch")
    for op, bt in table.items():
        print(f"{op},{bt}")
    print(f"traffic ratio train two-kernel/fused : {train_ratio:.2f}x (gate >= 2)")
    print(f"traffic ratio infer streamed/fused   : {infer_ratio:.2f}x (gate >= 3)")
    print(f"B=512 batch-tiled (train {tiles_big['train_tiles']} tiles x "
          f"{tiles_big['train_tile_rows']} rows, infer {tiles_big['infer_tiles']}"
          f" x {tiles_big['infer_tile_rows']}):")
    print(f"  traffic ratio train              : {train_ratio_big:.2f}x (gate >= 2)")
    print(f"  traffic ratio infer              : {infer_ratio_big:.2f}x (gate >= 3)")
    print(f"event-driven at measured density {d_meas:.3f}:")
    for tag, row in dens.items():
        print(f"  {tag}: train dma {row['train_ratio']:.2f}x "
              f"(gate >= 1.4 for b*), infer dma {row['infer_ratio']:.2f}x "
              f"(gate >= 0.99 for b*)")
    print("op,samples_per_s,achieved_GB/s,roofline_frac")
    for row in bw_table:
        frac = ("-" if row["roofline_frac"] is None
                else f"{row['roofline_frac']:.3f}")
        print(f"{row['op']},{row['samples_per_s']:.1f},"
              f"{row['achieved_gbps']:.2f},{frac}")
    print("event parity mismatches:", parity)
    print("allclose:", ", ".join(f"{k}={v:.2e}"
                                 for k, v in {**errs, **errs_big}.items()))

    rc = 0
    if max(errs.values()) > 3e-4:
        print("FAIL: fused kernels diverge from the two-kernel pipeline")
        rc = 1
    if errs_big and max(errs_big.values()) > 3e-4:
        print("FAIL: batch-tiled kernels diverge from the scan oracle at B=512")
        rc = 1
    if any(parity.values()):
        print("FAIL: event/sparse path is not bitwise-equal to the dense path")
        rc = 1
    if train_ratio < 2.0 or train_ratio_big < 2.0:
        print("FAIL: fused train moves more than half the baseline bytes")
        rc = 1
    if infer_ratio < 3.0 or infer_ratio_big < 3.0:
        print("FAIL: fused inference streams more than a third of baseline")
        rc = 1
    for tag in ("b16", f"b{B_BIG}"):
        if dens[tag]["train_ratio"] < 1.4:
            print(f"FAIL: dma train at measured density moves > 1/1.4 the "
                  f"dense fused bytes ({tag})")
            rc = 1
        if dens[tag]["infer_ratio"] < 0.99:
            print(f"FAIL: dma infer regresses dense fused bytes ({tag})")
            rc = 1
    if on_tpu:
        sps = {r["op"]: r["samples_per_s"] for r in records}
        if sps["infer_dma[tpu]"] < 2.0 * sps["infer_blocked[tpu]"]:
            print("FAIL: dma infer below 2x the dense kernel on TPU")
            rc = 1
        if sps["train_dma[tpu]"] < 1.5 * sps["train_blocked[tpu]"]:
            print("FAIL: dma fused train below 1.5x the dense kernel on TPU")
            rc = 1

    payload = {
        "benchmark": "kernels",
        "tile": {"T": T, "B": B, "n_in": N, "n_hid": H, "n_out": O},
        "bytes_per_launch": table,
        "bytes_per_launch_b512": table_big,
        "tiling_b512": tiles_big,
        "traffic_ratio_train": train_ratio,
        "traffic_ratio_infer": infer_ratio,
        "traffic_ratio_train_b512": train_ratio_big,
        "traffic_ratio_infer_b512": infer_ratio_big,
        "event_density_braille": d_meas,
        "event_density_source": d_source,
        "density_traffic": dens,
        "event_parity_mismatches": parity,
        "samples_per_sec": {r["op"]: r["samples_per_s"] for r in records},
        # raw {op, bytes, seconds} records — benchmarks/roofline.py re-derives
        # the achieved-vs-roofline table from these on whatever device it runs
        "bandwidth_records": records,
        # the ISSUE 7 speedup gates are wall-clock: enforced on real
        # accelerators, recorded-only on CPU (interpret-mode kernels)
        "speedup_gates": {"infer_dma": 2.0, "train_dma": 1.5,
                          "enforced": on_tpu},
        "max_abs_err": {**errs, **errs_big},
        "jax_backend": jax.default_backend(),
        "rc": rc,
    }
    Path(opts.out_dir).mkdir(parents=True, exist_ok=True)
    out = Path(opts.out_dir) / "BENCH_kernels.json"
    out.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {out}")
    bw_payload = {
        "benchmark": "bandwidth",
        "device": roofline,
        "rows": bw_table,
    }
    bw_out = Path(opts.out_dir) / "BENCH_bandwidth.json"
    bw_out.write_text(json.dumps(bw_payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {bw_out}")
    return payload


if __name__ == "__main__":
    import sys

    sys.exit(main().get("rc", 0))
