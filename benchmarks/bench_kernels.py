"""Kernel microbenchmarks + allclose checks vs the pure-jnp oracles.

On CPU the Pallas kernels run in interpret mode, so the µs numbers here
measure the *oracle* path (the jnp reference jitted) — the kernel numbers
are correctness artifacts, not speed claims.  On a TPU backend the same
harness times the compiled kernels.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def bench_rsnn():
    key = jax.random.key(0)
    T, B, N, H, O = 100, 16, 40, 100, 2
    ks = jax.random.split(key, 4)
    raster = (jax.random.uniform(ks[0], (T, B, N)) < 0.2).astype(jnp.float32)
    w_in = jax.random.normal(ks[1], (N, H)) * 0.4
    w_rec = jax.random.normal(ks[2], (H, H)) * 0.2 * (1 - jnp.eye(H))
    w_out = jax.random.normal(ks[3], (H, O)) * 0.3
    out_k = ops.rsnn_forward(raster, w_in, w_rec, w_out, alpha=0.99, kappa=0.78)
    ref_fn = jax.jit(lambda r: ref.rsnn_forward_ref(r, w_in, w_rec, w_out, 0.99, 0.78, 1.0))
    out_r = ref_fn(raster)
    err = max(float(jnp.abs(out_k[k] - out_r[k]).max()) for k in out_r)
    us = _time(ref_fn, raster)
    return "rsnn_step", us, f"max_err={err:.2e}"


def bench_eprop():
    key = jax.random.key(1)
    T, B, N, H, O = 100, 16, 40, 100, 2
    ks = jax.random.split(key, 6)
    h = (jax.random.uniform(ks[0], (T, B, H)) < 0.3).astype(jnp.float32)
    xbar = jax.random.normal(ks[1], (T, B, N))
    pbar = jax.random.normal(ks[2], (T, B, H))
    zbar = jax.random.normal(ks[3], (T, B, H))
    err_t = jax.random.normal(ks[4], (T, B, O)) * 0.1
    b_fb = jax.random.normal(ks[5], (H, O)) * 0.3
    dw_k = ops.eprop_update(h, xbar, pbar, zbar, err_t, b_fb, kappa=0.21)
    ref_fn = jax.jit(lambda *a: ref.eprop_update_ref(*a, 0.21))
    dw_r = ref_fn(h, xbar, pbar, zbar, err_t, b_fb)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(dw_k, dw_r))
    us = _time(ref_fn, h, xbar, pbar, zbar, err_t, b_fb)
    return "eprop_update", us, f"max_err={err:.2e}"


def bench_flash():
    key = jax.random.key(2)
    B, H, Hkv, S, D = 1, 4, 2, 512, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32) * 0.2
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32) * 0.2
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32) * 0.2
    o_k = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    ref_fn = jax.jit(
        lambda q, k, v: ref.attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=True,
        ).transpose(0, 2, 1, 3)
    )
    o_r = ref_fn(q, k, v)
    err = float(jnp.abs(o_k - o_r).max())
    us = _time(ref_fn, q, k, v)
    return "flash_attention", us, f"max_err={err:.2e}"


def main(argv=None):
    rows = [bench_rsnn(), bench_eprop(), bench_flash()]
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    main()
