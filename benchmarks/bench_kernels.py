"""Per-op kernel benchmarks: samples/s + analytic HBM bytes-streamed.

Three sections, all folded into ``BENCH_kernels.json`` (a CI artifact
alongside the train/serve benches):

* **allclose** — the op-specialized kernels (fused train, inference-only)
  against the split two-kernel pipeline and the jnp oracles.  On CPU the
  Pallas kernels execute under ``interpret=True``, so these are correctness
  artifacts, not speed claims.
* **traffic** — the analytic per-op HBM data-movement table
  (:mod:`repro.kernels.traffic`) for a cue-sized tile, before (two-kernel /
  trace-streaming) vs after (fused).  This is what the CI smoke lane
  *gates*: the fused train path must move ≤ 1/2 the bytes of the two-kernel
  baseline (the ≥2x throughput claim at HBM-bound operation) and the fused
  serve path ≤ 1/3 of the streamed one.  Since the batch-tiled grids
  (ISSUE 5) removed the launch-level batch cap, the same gates are enforced
  at ``B=512`` — four times the old ``KERNEL_SAMPLE_CAP``, a launch shape
  that previously could not run at all — using the as-executed tiled
  formulas (pad rows of the last tile included; weights/dw stay
  VMEM-resident across tiles).
* **wall-clock** — measured samples/s.  On a TPU backend this times the
  compiled kernels and additionally gates fused-train ≥ the two-kernel
  baseline; on CPU it times the scan backend (the path CPU CI actually
  measures — which the input-projection hoisting speeds up) and reports the
  kernels' interpret-mode numbers as informational only.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.backend import ExecutionBackend
from repro.core.eprop import EpropConfig
from repro.core.neuron import NeuronConfig
from repro.core.rsnn import RSNNConfig
from repro.kernels import ops, ref, traffic

# Cue-accumulation-sized tile — the shape the paper's Fig. 6 protocol runs.
T, B, N, H, O = 100, 16, 40, 100, 2


def _time(fn, *args, iters=5):
    out = fn(*args)  # warmup/compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _tile(key):
    ks = jax.random.split(key, 6)
    raster = (jax.random.uniform(ks[0], (T, B, N)) < 0.2).astype(jnp.float32)
    w_in = jax.random.normal(ks[1], (N, H)) * 0.4
    w_rec = jax.random.normal(ks[2], (H, H)) * 0.2 * (1 - jnp.eye(H))
    w_out = jax.random.normal(ks[3], (H, O)) * 0.3
    label = jax.random.randint(ks[4], (B,), 0, O)
    y_star = jax.nn.one_hot(label, O)
    t = jnp.arange(T)[:, None]
    valid = ((t >= T // 4) & (t <= T - 1)).astype(jnp.float32) * jnp.ones((T, B))
    return raster, w_in, w_rec, w_out, y_star, valid


def check_tiled_big_batch(alpha=0.99, kappa=0.78):
    """allclose at B=512 — previously impossible (the kernels rejected
    B > 128): the batch-tiled fused train/infer kernels against the scan
    backend, both on a shortened T=24 tile so the interpret-mode walk stays
    cheap (the traffic-ratio gates cover the full cue-length shape)."""
    B_big, T_train = 512, 24
    cfg = RSNNConfig(
        n_in=N, n_hid=H, n_out=O, num_ticks=T_train,
        neuron=NeuronConfig(alpha=alpha, kappa=kappa),
        eprop=EpropConfig(mode="factored"),
    )
    ks = jax.random.split(jax.random.key(7), 4)
    w = {
        "w_in": jax.random.normal(ks[0], (N, H)) * 0.4,
        "w_rec": jax.random.normal(ks[1], (H, H)) * 0.2 * (1 - jnp.eye(H)),
        "w_out": jax.random.normal(ks[2], (H, O)) * 0.3,
    }
    raster = (jax.random.uniform(ks[3], (T_train, B_big, N)) < 0.2).astype(
        jnp.float32)
    y_star = jax.nn.one_hot(jnp.arange(B_big) % O, O)
    t = jnp.arange(T_train)[:, None]
    valid = ((t >= T_train // 4)).astype(jnp.float32) * jnp.ones((T_train, B_big))

    dw_s, m_s = ExecutionBackend(cfg, "scan").train_tile(w, raster, y_star, valid)
    dw_k, m_k = ExecutionBackend(cfg, "kernel").train_tile(w, raster, y_star, valid)
    err_train = max(
        float(jnp.abs(dw_k[k] - dw_s[k]).max()
              / jnp.maximum(1.0, jnp.abs(dw_s[k]).max()))
        for k in dw_s
    )
    out_s = ExecutionBackend(cfg, "scan").inference(w, raster, valid)
    out_k = ExecutionBackend(cfg, "kernel").inference(w, raster, valid)
    err_inf = float(
        jnp.abs(out_k["acc_y"] - out_s["acc_y"]).max()
        / jnp.maximum(1.0, jnp.abs(out_s["acc_y"]).max())
    )
    pred_mismatch = int((out_k["pred"] != out_s["pred"]).sum())
    return {"train_fused_b512": err_train, "infer_fused_b512": err_inf,
            "pred_mismatch_b512": float(pred_mismatch)}


def check_kernels(alpha=0.99, kappa=0.78):
    """allclose: fused kernels vs the two-kernel pipeline + jnp oracles."""
    raster, w_in, w_rec, w_out, y_star, valid = _tile(jax.random.key(0))

    out_k = ops.rsnn_forward(raster, w_in, w_rec, w_out, alpha=alpha, kappa=kappa)
    out_r = ref.rsnn_forward_ref(raster, w_in, w_rec, w_out, alpha, kappa, 1.0)
    err_fwd = max(float(jnp.abs(out_k[k] - out_r[k]).max()) for k in out_r)

    # two-kernel train baseline: streamed forward -> XLA error -> reverse pass
    err_t = (jax.nn.softmax(out_k["y"], axis=-1) - y_star[None]) * valid[..., None]
    dw_base = ops.eprop_update(
        out_k["h"], out_k["xbar"], out_k["pbar"], out_k["zbar"], err_t, w_out,
        kappa=kappa,
    )
    dw_fused = ops.rsnn_train(
        raster, y_star, valid, w_in, w_rec, w_out, w_out,
        alpha=alpha, kappa=kappa,
    )
    # relative, like err_inf below: dw magnitudes grow with T·B, so an
    # absolute gate would trip on benign reassociation of compiled kernels
    err_train = max(
        float(jnp.abs(a - b).max() / jnp.maximum(1.0, jnp.abs(a).max()))
        for a, b in zip(dw_base, dw_fused[:3])
    )

    acc_base = (out_k["y"] * valid[..., None]).sum(axis=0)
    acc_fused, _ = ops.rsnn_infer(
        raster, valid, w_in, w_rec, w_out, alpha=alpha, kappa=kappa
    )
    # relative: the fused kernel accumulates sequentially, XLA's reduce in
    # pairs — same sums, different float association
    err_inf = float(
        jnp.abs(acc_base - acc_fused).max()
        / jnp.maximum(1.0, jnp.abs(acc_base).max())
    )

    return {"forward": err_fwd, "train_fused": err_train, "infer_fused": err_inf}


def wall_clock():
    """Measured samples/s per op.  TPU: the compiled kernels (fused vs
    two-kernel, gated).  CPU: the scan backend — the path CPU CI measures."""
    raster, w_in, w_rec, w_out, y_star, valid = _tile(jax.random.key(1))
    rows = []
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu:
        @jax.jit
        def base(r, ys, va):
            o = ops.rsnn_forward(r, w_in, w_rec, w_out, alpha=0.99, kappa=0.78)
            err = (jax.nn.softmax(o["y"], axis=-1) - ys[None]) * va[..., None]
            return ops.eprop_update(
                o["h"], o["xbar"], o["pbar"], o["zbar"], err, w_out, kappa=0.78
            )

        def fused(r, ys, va):
            return ops.rsnn_train(
                r, ys, va, w_in, w_rec, w_out, w_out, alpha=0.99, kappa=0.78
            )
        s_base = _time(base, raster, y_star, valid)
        s_fused = _time(fused, raster, y_star, valid)
        rows.append(("train_two_kernel[tpu]", B / s_base))
        rows.append(("train_fused[tpu]", B / s_fused))
    else:
        cfg = RSNNConfig(
            n_in=N, n_hid=H, n_out=O, num_ticks=T,
            neuron=NeuronConfig(alpha=0.99, kappa=0.78),
            eprop=EpropConfig(mode="factored"),
        )
        be = ExecutionBackend(cfg, "scan")
        w = {"w_in": w_in, "w_rec": w_rec, "w_out": w_out}
        s_train = _time(lambda: be.train_tile(w, raster, y_star, valid), iters=3)
        s_inf = _time(lambda: be.inference(w, raster, valid), iters=3)
        rows.append(("train_tile[scan-cpu]", B / s_train))
        rows.append(("inference[scan-cpu]", B / s_inf))
        # the previously-rejected launch shape, now a single backend call
        B_big = 512
        k = jax.random.key(2)
        raster_b = (jax.random.uniform(k, (T, B_big, N)) < 0.2).astype(
            jnp.float32)
        y_star_b = jax.nn.one_hot(jnp.arange(B_big) % O, O)
        valid_b = valid[:, :1] * jnp.ones((T, B_big))
        s_train_b = _time(
            lambda: be.train_tile(w, raster_b, y_star_b, valid_b), iters=3)
        rows.append(("train_tile_b512[scan-cpu]", B_big / s_train_b))
    return rows, on_tpu


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=".")
    opts = ap.parse_args(argv)

    errs = check_kernels()
    errs_big = check_tiled_big_batch()
    table = traffic.op_table(T, B, N, H, O)
    train_ratio = table["train_two_kernel"] / table["train_fused"]
    infer_ratio = table["infer_streamed"] / table["infer_fused"]
    # the previously-impossible launch: B=512, four tiles+ per op
    B_BIG = 512
    table_big = traffic.op_table(T, B_BIG, N, H, O)
    tiles_big = traffic.tile_table(T, B_BIG, N, H, O)
    train_ratio_big = table_big["train_two_kernel"] / table_big["train_fused"]
    infer_ratio_big = table_big["infer_streamed"] / table_big["infer_fused"]
    rows, on_tpu = wall_clock()

    print("op,bytes_per_launch")
    for op, bt in table.items():
        print(f"{op},{bt}")
    print(f"traffic ratio train two-kernel/fused : {train_ratio:.2f}x (gate >= 2)")
    print(f"traffic ratio infer streamed/fused   : {infer_ratio:.2f}x (gate >= 3)")
    print(f"B=512 batch-tiled (train {tiles_big['train_tiles']} tiles x "
          f"{tiles_big['train_tile_rows']} rows, infer {tiles_big['infer_tiles']}"
          f" x {tiles_big['infer_tile_rows']}):")
    print(f"  traffic ratio train              : {train_ratio_big:.2f}x (gate >= 2)")
    print(f"  traffic ratio infer              : {infer_ratio_big:.2f}x (gate >= 3)")
    print("op,samples_per_s")
    for name, sps in rows:
        print(f"{name},{sps:.1f}")
    print("allclose:", ", ".join(f"{k}={v:.2e}"
                                 for k, v in {**errs, **errs_big}.items()))

    rc = 0
    if max(errs.values()) > 3e-4:
        print("FAIL: fused kernels diverge from the two-kernel pipeline")
        rc = 1
    if max(errs_big.values()) > 3e-4:
        print("FAIL: batch-tiled kernels diverge from the scan oracle at B=512")
        rc = 1
    if train_ratio < 2.0 or train_ratio_big < 2.0:
        print("FAIL: fused train moves more than half the baseline bytes")
        rc = 1
    if infer_ratio < 3.0 or infer_ratio_big < 3.0:
        print("FAIL: fused inference streams more than a third of baseline")
        rc = 1
    if on_tpu:
        sps = dict(rows)
        if sps["train_fused[tpu]"] < sps["train_two_kernel[tpu]"]:
            print("FAIL: fused train slower than the two-kernel baseline on TPU")
            rc = 1

    payload = {
        "benchmark": "kernels",
        "tile": {"T": T, "B": B, "n_in": N, "n_hid": H, "n_out": O},
        "bytes_per_launch": table,
        "bytes_per_launch_b512": table_big,
        "tiling_b512": tiles_big,
        "traffic_ratio_train": train_ratio,
        "traffic_ratio_infer": infer_ratio,
        "traffic_ratio_train_b512": train_ratio_big,
        "traffic_ratio_infer_b512": infer_ratio_big,
        "samples_per_sec": {name: sps for name, sps in rows},
        "max_abs_err": {**errs, **errs_big},
        "jax_backend": jax.default_backend(),
        "rc": rc,
    }
    out = Path(opts.out_dir) / "BENCH_kernels.json"
    out.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {out}")
    return payload


if __name__ == "__main__":
    import sys

    sys.exit(main().get("rc", 0))
