"""Training launcher CLI — the end-to-end driver for the LM substrate.

Runs a real optimization loop (synthetic Zipf token stream) with the full
production runtime: sharded step function, atomic async checkpointing,
NaN-step rejection, straggler watchdog, SIGTERM-safe shutdown, restart
resume (``--resume``).

On this CPU container use a reduced config:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 30 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
On a pod, drop ``--reduced`` and point ``--mesh`` at the production shape.
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.configs.base import get_config, get_reduced
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.distributed.sharding import (
    BASE_RULES,
    ShardingRules,
    param_shardings,
    use_mesh,
)
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models.model import build
from repro.optim.adamw import AdamW, AdamWConfig
from repro.train.train_step import make_train_step, opt_state_specs
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="", help="e.g. 16x16 or 2x16x16 (default: single device)")
    opts = ap.parse_args(argv)

    cfg = get_reduced(opts.arch) if opts.reduced else get_config(opts.arch)
    model = build(cfg)
    rules = ShardingRules(BASE_RULES)

    if opts.mesh:
        dims = [int(d) for d in opts.mesh.split("x")]
        mesh = (
            make_production_mesh(multi_pod=len(dims) == 3)
            if dims in ([16, 16], [2, 16, 16])
            else make_debug_mesh(*dims[::-1][:2][::-1])
        )
    else:
        mesh = make_debug_mesh(1, 1)

    with use_mesh(mesh, rules):
        params = model.init(jax.random.key(0))
        _, specs = model.abstract()
        opt = AdamW(AdamWConfig(lr=opts.lr, warmup_steps=10, decay_steps=opts.steps))
        opt_state = opt.init(params)
        p_shard = param_shardings(specs, mesh, rules)
        o_shard = param_shardings(opt_state_specs(specs), mesh, rules)
        step_fn = jax.jit(
            make_train_step(model, opt, n_micro=opts.n_micro),
            in_shardings=(p_shard, o_shard, None),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        stream = TokenStream(TokenStreamConfig(
            vocab=cfg.vocab, batch=opts.batch, seq_len=opts.seq,
            d_model=cfg.d_model, family=cfg.family,
            n_media_tokens=cfg.n_media_tokens,
        ))
        trainer = Trainer(
            step_fn, params, opt_state, iter(stream),
            TrainerConfig(
                total_steps=opts.steps, ckpt_every=opts.ckpt_every,
                ckpt_dir=opts.ckpt_dir, log_every=5,
            ),
        )
        trainer.install_signal_handlers()
        if opts.resume and trainer.restore():
            stream.position = trainer.step
            print(f"resumed from step {trainer.step}")
        summary = trainer.run()
        print("training summary:", summary)
        losses = [s.metrics.get("loss") for s in trainer.metrics.history]
        if losses:
            print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
