import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) cell this lowers + compiles
the real step function (train_step / prefill / serve decode_step) against
512 placeholder host devices, prints ``memory_analysis`` / ``cost_analysis``
and records the roofline inputs (FLOPs, bytes, collective wire traffic) as
JSON under ``experiments/dryrun/``.

The XLA_FLAGS line above MUST run before any jax import — jax locks the
device count at first init (this is why smoke tests / benches never import
this module).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all            # every cell, subprocess each
  python -m repro.launch.dryrun --arch ... --shape ... --kv-shard seq \
        --prune-causal --n-micro 4               # §Perf hillclimb knobs
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.distributed.sharding import (
    BASE_RULES,
    ShardingRules,
    logical_spec,
    param_shardings,
    use_mesh,
)
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_analysis import collective_bytes
from repro.models.model import build
from repro.models.transformer import count_params
from repro.optim.adamw import AdamW, AdamWConfig
from repro.train.train_step import (
    abstract_opt_state,
    make_train_step,
    make_train_step_compressed,
    opt_state_specs,
)

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

BATCH_AXES = {
    "tokens": ("batch", "act_seq"),
    "targets": ("batch", "act_seq"),
    "media": ("batch", None, "act_embed"),
    "src_embeds": ("batch", "act_seq", "act_embed"),
    "pos": (),
}


def make_rules(shape, mesh, opts) -> ShardingRules:
    rules = ShardingRules(dict(BASE_RULES))
    kv = opts.kv_shard
    if kv == "auto":
        # Baseline: decode shards the KV-cache sequence dim over `model`
        # (always divisible; GQA head counts like 8 are not 16-divisible).
        kv = "seq" if shape.kind == "decode" else "none"
    if kv == "heads":
        rules = rules.override(act_kv_heads="model")
    elif kv == "seq":
        rules = rules.override(kv_cache_seq="model", act_kv_heads=None)
    # jit arguments must divide evenly: tiny global batches (long_500k B=1)
    # cannot shard over the data axes.
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    if shape.global_batch % dp != 0:
        rules = rules.override(batch=None)
    for ov in opts.rules_override:
        k, v = ov.split("=")
        rules = rules.override(**{k: None if v in ("None", "none", "") else tuple(v.split("+")) if "+" in v else v})
    return rules


def skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return (
            "pure full-attention arch: a 524k-token dense KV decode is the "
            "quadratic regime long_500k excludes (DESIGN.md §5)"
        )
    return None


def tune_cfg(cfg, shape, opts):
    if opts.prune_causal:
        cfg = cfg.replace(prune_causal=True)
    if opts.no_remat:
        cfg = cfg.replace(remat=False)
    if shape.kind != "train":
        cfg = cfg.replace(remat=False)
    if opts.attn_block:
        cfg = cfg.replace(attn_q_block=opts.attn_block, attn_kv_block=opts.attn_block)
    if opts.remat_policy != "full":
        cfg = cfg.replace(remat_policy=opts.remat_policy)
    if opts.moe_groups and cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch_groups=opts.moe_groups))
    if cfg.ssm is not None and (opts.ssd_chunk or opts.ssd_bf16):
        kw = {}
        if opts.ssd_chunk:
            kw["chunk"] = opts.ssd_chunk
        if opts.ssd_bf16:
            kw["compute_dtype"] = "bfloat16"
        cfg = cfg.replace(ssm=dataclasses.replace(cfg.ssm, **kw))
    return cfg


def batch_shardings(specs: dict, mesh, rules):
    return {
        k: NamedSharding(mesh, logical_spec(BATCH_AXES[k], mesh, rules))
        for k in specs
    }


def compile_cell(cfg, shape, mesh, rules, opts, *, want_hlo=True) -> dict:
    """Lower + compile one step function; return analysis fields."""
    model = build(cfg)
    out: dict = {}
    t0 = time.time()
    with use_mesh(mesh, rules):
        params_sds, specs = model.abstract()
        p_shard = param_shardings(specs, mesh, rules)
        inputs_sds = model.input_specs(shape)
        in_shard = batch_shardings(inputs_sds, mesh, rules)

        if shape.kind == "train":
            opt = AdamW(AdamWConfig())
            opt_sds = abstract_opt_state(params_sds)
            opt_shard = param_shardings(opt_state_specs(specs), mesh, rules)
            if opts.compress_pods and "pod" in mesh.axis_names:
                step = make_train_step_compressed(model, opt, mesh, n_micro=opts.n_micro)
                res_sds = jax.tree.map(
                    lambda sd: jax.ShapeDtypeStruct(sd.shape, jnp.float32), params_sds
                )
                jitted = jax.jit(
                    step,
                    in_shardings=(p_shard, opt_shard, p_shard, in_shard),
                    out_shardings=(p_shard, opt_shard, p_shard, None),
                    donate_argnums=(0, 1, 2),
                )
                lowered = jitted.lower(params_sds, opt_sds, res_sds, inputs_sds)
            else:
                step = make_train_step(model, opt, n_micro=opts.n_micro)
                jitted = jax.jit(
                    step,
                    in_shardings=(p_shard, opt_shard, in_shard),
                    out_shardings=(p_shard, opt_shard, None),
                    donate_argnums=(0, 1),
                )
                lowered = jitted.lower(params_sds, opt_sds, inputs_sds)
        elif shape.kind == "prefill":
            jitted = jax.jit(model.prefill, in_shardings=(p_shard, in_shard))
            lowered = jitted.lower(params_sds, inputs_sds)
        else:  # decode
            cache_sds, cache_axes = model.cache_specs(shape.global_batch, shape.seq_len)
            cache_shard = param_shardings(cache_axes, mesh, rules)
            tok_shard = in_shard["tokens"]
            jitted = jax.jit(
                model.decode_step,
                in_shardings=(p_shard, cache_shard, tok_shard, None),
                out_shardings=(None, cache_shard),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                params_sds, cache_sds, inputs_sds["tokens"], inputs_sds["pos"]
            )
        out["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        out["compile_s"] = round(time.time() - t1, 2)

        try:
            mem = compiled.memory_analysis()
            out["memory"] = {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            }
        except Exception as e:  # backend-dependent
            out["memory"] = {"error": str(e)}

        try:
            cost = compiled.cost_analysis()
            out["cost"] = {
                "flops": float(cost.get("flops", -1)),
                "bytes_accessed": float(cost.get("bytes accessed", -1)),
                "transcendentals": float(cost.get("transcendentals", -1)),
            }
        except Exception as e:
            out["cost"] = {"error": str(e)}

        if want_hlo:
            hlo = compiled.as_text()
            stats = collective_bytes(hlo, mesh.size)
            out["collectives"] = {
                "total_wire_bytes": stats.total_wire_bytes,
                "bytes_by_op": stats.bytes_by_op,
                "count_by_op": stats.count_by_op,
            }
    return out


def calib_config(cfg, k: int):
    """A k-period unrolled config whose per-layer HLO matches the scanned
    model's body — used to de-alias while-loop cost undercounting (HLO cost
    analysis visits each loop body once, ignoring trip count)."""
    from repro.models.transformer import _layer_plan

    plan = _layer_plan(cfg)
    n = len(plan.prefix) + k * len(plan.period)
    kw = dict(n_layers=n, scan_layers=False, unroll_loops=True)
    if cfg.family == "audio":
        kw["n_enc_layers"] = k
    return cfg.replace(**kw)


def _combine_cost(f1: dict, f2: dict, repeats: int) -> dict:
    """total = rest + R·body, with body = f2 - f1 and rest = f1 - body."""
    out = {}
    for key in ("flops", "bytes_accessed", "transcendentals"):
        a, b = f1["cost"].get(key, -1), f2["cost"].get(key, -1)
        if a is None or a < 0 or b < 0:
            out[key] = -1
            continue
        body = max(b - a, 0.0)
        out[key] = a + (repeats - 1) * body
    c1 = f1.get("collectives", {}).get("bytes_by_op", {})
    c2 = f2.get("collectives", {}).get("bytes_by_op", {})
    coll = {}
    for op in set(c1) | set(c2):
        a, b = c1.get(op, 0.0), c2.get(op, 0.0)
        coll[op] = a + (repeats - 1) * max(b - a, 0.0)
    out["collective_bytes_by_op"] = coll
    out["collective_wire_bytes"] = sum(coll.values())
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, opts) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    record = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": mesh.size,
        "opts": {
            "kv_shard": opts.kv_shard,
            "prune_causal": opts.prune_causal,
            "n_micro": opts.n_micro,
            "compress_pods": opts.compress_pods,
            "no_remat": opts.no_remat,
            "attn_block": opts.attn_block,
            "rules_override": opts.rules_override,
        },
    }
    reason = skip_reason(cfg, shape)
    if reason:
        record["skip"] = reason
        return record

    cfg = tune_cfg(cfg, shape, opts)
    rules = make_rules(shape, mesh, opts)

    # The real compile: full depth, scan-over-layers — proves the sharding
    # config and yields the per-device memory analysis.
    main = compile_cell(cfg, shape, mesh, rules, opts)
    record.update(main)
    print("memory_analysis:", record.get("memory"))
    print("cost_analysis(raw, loop bodies counted once):", record.get("cost"))

    # Cost calibration: two shallow *unrolled* variants isolate the exact
    # per-period cost; totals are reconstructed as rest + R·body.
    if not opts.no_calibrate:
        from repro.models.transformer import _layer_plan

        repeats = _layer_plan(cfg).repeats
        # Calibration always runs n_micro=1: total FLOPs are invariant to
        # microbatching, and a micro-scan would re-introduce the loop-body
        # undercount the calibration exists to remove.
        copts = argparse.Namespace(**vars(opts))
        copts.n_micro = 1
        ccfg = cfg
        if not opts.attn_block:
            # Bigger attention tiles for calibration: 4× fewer unrolled tile
            # programs (compile time) at ≤3% causal-FLOP overcount.
            ccfg = cfg.replace(attn_q_block=2048, attn_kv_block=2048)
        f1 = compile_cell(calib_config(ccfg, 1), shape, mesh, rules, copts)
        f2 = compile_cell(calib_config(ccfg, 2), shape, mesh, rules, copts)
        record["calibration"] = {"k1": f1, "k2": f2, "repeats": repeats}
        record["cost_corrected"] = _combine_cost(f1, f2, repeats)
        print("cost_corrected:", {k: (f"{v:.4g}" if isinstance(v, float) else v)
                                  for k, v in record["cost_corrected"].items()
                                  if not isinstance(v, dict)})

    record["params_total"] = count_params(cfg)
    record["params_active"] = count_params(cfg, active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind in ("train", "prefill") else 1)
    mult = 6 if shape.kind == "train" else 2
    record["model_flops"] = mult * record["params_active"] * tokens
    record["tokens"] = tokens
    return record


def cell_list(opts):
    cells = []
    for arch in (opts.arch.split(",") if opts.arch else ARCH_IDS):
        for shape in (opts.shape.split(",") if opts.shape else list(SHAPES)):
            for mp in ([opts.multi_pod] if not opts.both_meshes else [False, True]):
                cells.append((arch, shape, mp))
    return cells


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default=str(OUT_DIR))
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in its own process (used by --all)")
    # §Perf knobs
    ap.add_argument("--kv-shard", default="auto", choices=["auto", "heads", "seq", "none"])
    ap.add_argument("--prune-causal", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--attn-block", type=int, default=0)
    ap.add_argument("--compress-pods", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--remat-policy", default="full", choices=["full", "dots"])
    ap.add_argument("--moe-groups", type=int, default=0)
    ap.add_argument("--ssd-chunk", type=int, default=0)
    ap.add_argument("--ssd-bf16", action="store_true")
    ap.add_argument("--rules-override", action="append", default=[])
    opts = ap.parse_args(argv)

    out_dir = Path(opts.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    if opts.all or opts.subprocess or (opts.arch and "," in opts.arch) or not opts.arch or not opts.shape or opts.both_meshes:
        # Parent mode: one subprocess per cell for isolation.
        if opts.all:
            opts.arch = None
            opts.shape = None
            opts.both_meshes = True
        failures = []
        for arch, shape, mp in cell_list(opts):
            mesh_tag = "2x16x16" if mp else "16x16"
            name = f"{arch}__{shape}__{mesh_tag}__{opts.tag}"
            out_file = out_dir / (name + ".json")
            if out_file.exists() and not os.environ.get("DRYRUN_FORCE"):
                print(f"[skip existing] {name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--tag", opts.tag,
                   "--out-dir", str(out_dir), "--kv-shard", opts.kv_shard,
                   "--n-micro", str(opts.n_micro)]
            if mp:
                # Multi-pod proves lower+compile; roofline (calibrated cost)
                # is a single-pod deliverable — skip the calibration compiles.
                cmd += ["--multi-pod", "--no-calibrate"]
            for flag in ("prune_causal", "no_remat", "compress_pods", "no_calibrate"):
                if getattr(opts, flag):
                    cmd.append("--" + flag.replace("_", "-"))
            if opts.attn_block:
                cmd += ["--attn-block", str(opts.attn_block)]
            for ov in opts.rules_override:
                cmd += ["--rules-override", ov]
            print(f"=== {name} ===", flush=True)
            r = subprocess.run(cmd, cwd=str(Path(__file__).resolve().parents[2]))
            if r.returncode != 0:
                failures.append(name)
                print(f"[FAIL] {name}", flush=True)
        print(f"done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    # Child mode: one cell.
    mesh_tag = "2x16x16" if opts.multi_pod else "16x16"
    name = f"{opts.arch}__{opts.shape}__{mesh_tag}__{opts.tag}"
    try:
        record = run_cell(opts.arch, opts.shape, opts.multi_pod, opts)
    except Exception as e:
        record = {
            "arch": opts.arch, "shape": opts.shape, "mesh": mesh_tag,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc(),
        }
        (out_dir / (name + ".json")).write_text(json.dumps(record, indent=2))
        print(record["traceback"], file=sys.stderr)
        return 1
    (out_dir / (name + ".json")).write_text(json.dumps(record, indent=2))
    print(f"[ok] {name}" + (" (skipped: %s)" % record["skip"] if record.get("skip") else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
