"""Production meshes.

Target: TPU v5e pods — 16×16 = 256 chips per pod; 2 pods = 512 chips.
Axes: ``data`` (FSDP + batch), ``model`` (tensor/expert parallel), and on
multi-pod, ``pod`` (pure data parallel across the DCN; the axis gradient
compression targets).

Functions, not module constants — importing this module never touches jax
device state (smoke tests must keep seeing 1 CPU device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1, n_pod: int = 0):
    """Small mesh over however many (host) devices exist — used by tests."""
    if n_pod:
        return jax.make_mesh((n_pod, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_data_mesh(n_data: int | None = None):
    """1-axis pure data-parallel mesh — what the RSNN execution backend
    shards its sample axis over (``ExecutionBackend(mesh=...)``).  Defaults
    to every visible device (8 virtual CPU devices under the CI lane's
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    n = n_data or len(jax.devices())
    return jax.make_mesh((n,), ("data",))


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (~3 usable links per axis)
DCN_BW = 25e9                     # B/s per host-ish (cross-pod; coarse)
VMEM_BYTES = 128 * 2 ** 20
