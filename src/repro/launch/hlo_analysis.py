"""Post-GSPMD HLO analysis: collective-traffic accounting for §Roofline.

``collective_bytes`` parses the compiled (partitioned) HLO text and sums the
wire bytes per device of every communication op, using ring-algorithm cost
models:

  all-gather        (n-1)/n · result_bytes
  reduce-scatter    (n-1)/n · operand_bytes
  all-reduce        2·(n-1)/n · operand_bytes     (reduce-scatter + all-gather)
  all-to-all        (n-1)/n · operand_bytes
  collective-permute  operand_bytes

``n`` is the participant-group size parsed from ``replica_groups`` (both the
explicit ``{{0,1,...}}`` and iota ``[g,s]<=[N]...`` forms).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    return default


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float]
    count_by_op: Dict[str, int]
    total_wire_bytes: float
    ops: List[Tuple[str, float, int]]   # (op, wire bytes, group size)


def collective_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    bytes_by_op: Dict[str, float] = defaultdict(float)
    count_by_op: Dict[str, int] = defaultdict(int)
    ops: List[Tuple[str, float, int]] = []

    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls or "=" not in ls:
            continue
        opname = None
        for op in COLLECTIVE_OPS:
            # Match "op(" or "op-start(" as the instruction, not fusion names.
            if f" {op}(" in ls or f" {op}-start(" in ls:
                opname = op
                break
        if opname is None:
            continue
        if f" {opname}-done" in ls:
            continue
        lhs, rhs = ls.split("=", 1)
        paren = rhs.find("(")
        result_part = rhs[:paren]
        operand_part = rhs[paren:]
        result_bytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result_part))
        operand_bytes = sum(
            _shape_bytes(d, s) for d, s in _SHAPE_RE.findall(operand_part.split(")")[0] + ")")
        )
        n = max(_group_size(ls, n_devices), 1)
        if opname == "all-gather":
            wire = (n - 1) / n * result_bytes
        elif opname == "reduce-scatter":
            wire = (n - 1) / n * operand_bytes
        elif opname == "all-reduce":
            wire = 2 * (n - 1) / n * operand_bytes
        elif opname in ("all-to-all", "ragged-all-to-all"):
            wire = (n - 1) / n * operand_bytes
        elif opname == "collective-broadcast":
            wire = operand_bytes
        else:  # collective-permute
            wire = operand_bytes
        bytes_by_op[opname] += wire
        count_by_op[opname] += 1
        ops.append((opname, wire, n))

    return CollectiveStats(
        bytes_by_op=dict(bytes_by_op),
        count_by_op=dict(count_by_op),
        total_wire_bytes=float(sum(bytes_by_op.values())),
        ops=ops,
    )


def hlo_op_histogram(hlo_text: str, top: int = 20) -> List[Tuple[str, int]]:
    """Crude opcode histogram of the optimized HLO (debugging aid for §Perf)."""
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1]
        m = re.search(r"\)?\s*([a-z][a-z0-9-]*)\(", rhs)
        if m:
            counts[m.group(1)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
