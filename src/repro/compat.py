"""Version shims for the narrow band of jax APIs that moved between releases.

The codebase targets the modern spelling (``jax.shard_map`` with
``axis_names``/``check_vma``); on older jax (< 0.5) that call is translated
to ``jax.experimental.shard_map.shard_map`` (``auto``/``check_rep``).
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, axis_names, in_specs, out_specs, check_vma=True):
    """``jax.shard_map`` with the new keyword surface on any jax version.

    ``axis_names`` is the set of *manual* mesh axes; the remainder of the
    mesh stays under GSPMD ("auto").
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f,
            mesh=mesh,
            axis_names=set(axis_names),
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=check_vma,
        auto=auto,
    )
