"""Input guards and the serving error model: typed rejection instead of
asserts, corruption or hangs.

The deployed SoC fronts untrusted AER traffic: live sensor streams arrive
over the network, and a single malformed word must not take down the packed
tile it shares with a thousand healthy sessions — let alone the engine.
This module is the serving path's trust boundary:

* **Typed exceptions** rooted at :class:`ServeError` /
  :class:`~repro.core.aer.AEREncodingError` — a caller can catch exactly
  the guard layer (and nothing else) and keep its own loop alive.  They
  replace the bare ``assert`` statements the serve path used to rely on,
  which vanish entirely under ``python -O`` (ruff rule S101 now bans
  ``assert`` across ``src/``).
* **Vectorized AER validation** (:func:`validate_events`): 12-bit field
  ranges, known type bytes, in-range spike addresses, tick monotonicity
  (the stream contract), and per-feed size quotas — one NumPy pass, no
  per-word Python loop, so the guard adds O(words) vector work to a path
  that already does an O(words) decode.
* **The result-status error model** (:class:`ServeStatus`):
  ``OK | REJECTED | EXPIRED | FAULT`` on every
  :class:`~repro.serve.engine.ServeResult` and final
  :class:`~repro.serve.session.SessionSnapshot`.  Work the engine drops —
  admission-rejected, deadline-expired, or faulted — surfaces as a result
  with a status, never as a silent hole in the output or an engine-killing
  exception.
* **Numeric health checks on harvest** (:func:`bad_rows`): NaN/inf
  detection in float mode and saturation-storm detection on the quantized
  12-bit membrane grid, applied per *sample* so one poisoned session is
  quarantined while the rest of its tile delivers bitwise-unchanged.

See ``docs/serving.md`` ("Hardened serving") for the operator-facing
semantics and ``benchmarks/bench_chaos.py --serve`` for the chaos gate that
exercises all of it at once.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

import numpy as np

from repro.core.aer import (
    AEREncodingError,
    EVT_END,
    EVT_LABEL,
    EVT_SPIKE,
    MAX_ADDR,
    MAX_TICK,
)

__all__ = [
    "ServeError",
    "GuardError",
    "MalformedEventError",
    "StreamContractError",
    "QuotaExceededError",
    "OverloadError",
    "LaneFaultError",
    "ServeStatus",
    "GuardConfig",
    "validate_events",
    "bad_rows",
]


# --------------------------------------------------------------------------
# exception taxonomy
# --------------------------------------------------------------------------


class ServeError(Exception):
    """Base of every typed serving-layer error."""


class GuardError(ServeError, AEREncodingError):
    """An input buffer was rejected at the guard boundary.

    Subclasses :class:`~repro.core.aer.AEREncodingError` so codec-level
    validation (``aer.encode_sample``) and serve-level validation share one
    catchable root — a caller guarding a feed loop catches
    ``AEREncodingError`` and gets both.
    """


class MalformedEventError(GuardError):
    """Bad word format: wrong dtype/shape, unknown type byte, out-of-range
    address/tick field, or a non-zero payload on a type-0 pad word."""


class StreamContractError(GuardError):
    """A structurally valid buffer that violates the stream contract:
    ticks decreasing within a buffer, a feed regressing behind an earlier
    feed, or feeding a closed session."""


class QuotaExceededError(GuardError):
    """A feed or session exceeded its configured event quota."""


class OverloadError(ServeError):
    """Admission rejected: the bounded queue is full under the
    ``"reject"`` policy.  Back off and retry, or switch the scheduler to
    ``admission="shed"`` to drop the oldest queued work instead."""


class LaneFaultError(ServeError):
    """A model lane exhausted its restart budget — raised only when the
    engine cannot contain a fault to the affected sessions."""


# --------------------------------------------------------------------------
# result status model
# --------------------------------------------------------------------------


class ServeStatus(str, enum.Enum):
    """Terminal status of one unit of serving work.

    ``str``-valued so statuses JSON-serialise and compare against plain
    strings in stats pipelines.
    """

    OK = "ok"             # served; logits/pred are live
    REJECTED = "rejected"  # dropped at admission (guard or overload/shed)
    EXPIRED = "expired"    # deadline passed before launch; never paid for
    FAULT = "fault"        # numeric-health quarantine or unrecoverable lane fault

    def __str__(self) -> str:  # "ok", not "ServeStatus.OK", in messages
        return self.value


# --------------------------------------------------------------------------
# guard configuration + vectorized validation
# --------------------------------------------------------------------------

_KNOWN_KINDS = (0, EVT_END, EVT_LABEL, EVT_SPIKE)


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Validation policy for one engine (per-lane ``n_in`` filled by the
    engine from each model's config when left ``None``).

    The quotas bound *memory*, which is what an overload or a hostile
    caller actually attacks: ``max_words_per_feed`` caps one buffer,
    ``max_pending_events`` caps a session's buffered-but-unprocessed spike
    backlog (the per-session half of the bounded-queue guarantee — the
    per-engine half is the scheduler/packer ``max_pending``).
    """

    n_in: Optional[int] = None          # spike addresses must be < n_in
    max_words_per_feed: int = 1 << 20   # 4 MiB of words per buffer
    max_pending_events: int = 1 << 20   # buffered spikes per session
    monotone: bool = True               # ticks non-decreasing within a buffer
    check_addresses: bool = True        # enforce the n_in bound

    def for_model(self, n_in: int) -> "GuardConfig":
        """The per-lane guard: ``n_in`` resolved from the model config."""
        if self.n_in is not None:
            return self
        return dataclasses.replace(self, n_in=int(n_in))


def validate_events(
    events,
    guard: GuardConfig,
    *,
    min_tick: int = 0,
    what: str = "event buffer",
) -> np.ndarray:
    """Validate one AER word buffer; returns it as a canonical 1-D uint32
    array or raises a :class:`GuardError` subclass naming the first
    violation.

    Checks (all vectorized):

    * coercible to uint32 without value loss (integer dtype, in
      ``[0, 2**32)``), at most ``max_words_per_feed`` words;
    * every non-pad word carries a known type byte
      (``EVT_SPIKE | EVT_LABEL | EVT_END``) — and pad words are *exactly*
      ``0x0`` (a zero type byte over a non-zero payload is a corrupted
      word, not padding);
    * spike addresses below ``n_in`` (the model's input width — an
      out-of-range address would silently scatter into another neuron's
      row or be dropped, depending on the path; both corrupt);
    * ticks non-decreasing within the buffer and ``>= min_tick`` (the
    cross-feed stream contract; pass the session's high-water mark).
    """
    arr = np.asarray(events)
    if arr.dtype == object or not (
        np.issubdtype(arr.dtype, np.integer)
        or np.issubdtype(arr.dtype, np.unsignedinteger)
    ):
        raise MalformedEventError(
            f"{what}: expected an integer array of AER words, got dtype "
            f"{arr.dtype}"
        )
    words = arr.ravel()
    if words.size > guard.max_words_per_feed:
        raise QuotaExceededError(
            f"{what}: {words.size} words exceeds the per-feed quota "
            f"({guard.max_words_per_feed})"
        )
    if words.size == 0:
        return words.astype(np.uint32)
    w64 = words.astype(np.int64)
    if (w64 < 0).any() or (w64 > 0xFFFFFFFF).any():
        bad = w64[(w64 < 0) | (w64 > 0xFFFFFFFF)][0]
        raise MalformedEventError(
            f"{what}: word value {bad} outside the 32-bit AER word range"
        )
    words = words.astype(np.uint32)
    kind = words >> 24
    known = np.isin(kind, _KNOWN_KINDS)
    if not known.all():
        i = int(np.nonzero(~known)[0][0])
        raise MalformedEventError(
            f"{what}: word {i} (0x{int(words[i]):08x}) carries unknown "
            f"type byte 0x{int(kind[i]):02x}"
        )
    pad_payload = (kind == 0) & (words != 0)
    if pad_payload.any():
        i = int(np.nonzero(pad_payload)[0][0])
        raise MalformedEventError(
            f"{what}: word {i} (0x{int(words[i]):08x}) has type byte 0 but "
            "a non-zero payload — corrupted word, not padding"
        )
    live = kind != 0
    if guard.check_addresses and guard.n_in is not None:
        addr = (words >> 12) & MAX_ADDR
        bad_addr = (kind == EVT_SPIKE) & (addr >= guard.n_in)
        if bad_addr.any():
            i = int(np.nonzero(bad_addr)[0][0])
            raise MalformedEventError(
                f"{what}: spike word {i} targets neuron {int(addr[i])}, "
                f"model has n_in={guard.n_in}"
            )
    if guard.monotone and live.any():
        tick = (words & MAX_TICK).astype(np.int64)[live]
        if int(tick[0]) < min_tick:
            raise StreamContractError(
                f"{what}: first tick {int(tick[0])} regresses behind the "
                f"stream's high-water mark {min_tick} (feeds must be "
                "tick-ordered and non-decreasing across buffers)"
            )
        steps = np.diff(tick)
        if (steps < 0).any():
            i = int(np.nonzero(steps < 0)[0][0])
            raise StreamContractError(
                f"{what}: ticks decrease within the buffer "
                f"({int(tick[i])} -> {int(tick[i + 1])} at live word {i + 1})"
            )
    return words


# --------------------------------------------------------------------------
# per-sample numeric health on harvest
# --------------------------------------------------------------------------


def bad_rows(
    acc: np.ndarray,
    quant=None,
    ticks=None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-sample numeric health of one harvested logits tile.

    ``acc`` is ``(B, n_out)`` accumulated readout; ``ticks`` is the ticks
    each row has accumulated over — a scalar or a length-``B`` vector (the
    streaming path passes each session's cumulative tick count).  Returns
    ``(bad, saturated)`` boolean masks over the batch axis:

    * **float mode** (``quant is None``): a row is bad iff it contains a
      non-finite value — NaN poisons the argmax and, for a streaming
      session, the carry chain.
    * **quantized mode**: carries are integers on the 12-bit membrane grid
      held in float32; NaN/inf still marks a row bad, and a row whose
      magnitude exceeds the grid's reachable accumulation bound
      (``|acc_y| > mem_max * ticks`` — the LI readout adds at most one
      full-scale membrane value per valid tick) is a *saturation storm*:
      arithmetic escaped the saturating datapath, which on the chip means a
      stuck-at fault or an SEU, and here means corrupted state.  Saturated
      rows are reported in both masks so stats can count storms
      specifically.
    """
    acc = np.asarray(acc)
    bad = ~np.isfinite(acc).all(axis=-1)
    saturated = np.zeros(acc.shape[:-1], bool)
    if quant is not None:
        mem_max = float(quant.membrane_spec.max_val)
        if ticks is None:
            t = np.float64(MAX_TICK + 1)
        else:
            t = np.maximum(np.asarray(ticks, np.float64), 1.0)
        bound = np.broadcast_to(mem_max * t, acc.shape[:-1])
        with np.errstate(invalid="ignore"):
            saturated = (
                np.abs(acc) > bound[..., None]
            ).any(axis=-1) & ~bad
        bad = bad | saturated
    return bad, saturated
