"""Device-resident session state for streaming serving.

The paper's headline edge scenario is an unbounded per-user AER event stream
classified *online* — persistent recurrent state, events arriving in
arbitrarily small increments.  This module is the state half of that
runtime: a :class:`SessionPool` owns ``(S_cap + 1, ·)`` device arrays
holding every resident session's carry ``(v, z, y, acc_y, n_spk)`` (row
``S_cap`` is the trash slot padded tile lanes read/write so gather/scatter
shapes stay fixed), with LRU + idle-timeout admission control that offloads
cold sessions to host memory bit-exactly — in quantized mode the carries
are integers on the 12-bit membrane grid, so evict → readmit → continue is
indistinguishable from an uninterrupted stream.

The *capacity unit* of streaming serving is the pool, not the batch:
one session costs :func:`repro.kernels.rsnn_step.session_state_bytes`
(``4·(2H + 2O + 1)`` bytes) regardless of how long it lives, and
:func:`repro.serve.batching.max_sessions_for` turns a byte budget into
``S_cap``.  Tiles stay sized by ``vmem_budget`` exactly as before — the two
budgets are independent (HBM-resident pool vs VMEM-resident tile).

Multi-model serving runs **one pool per registered model**: carry shapes
are ``(·, n_hid)`` / ``(·, n_out)``, which differ per network, so a
session is pinned to its model's pool (``_Session.model_id``) for life and
eviction/readmission policy is per-model — capacity math adds up over the
models an engine serves (see ``docs/serving.md``).

Host-side bookkeeping lives in :class:`_Session` (pending spike events,
stream cursor, label/END scalars); the public face is
:class:`repro.serve.engine.SessionHandle` (``feed`` / ``poll`` / ``result``
/ ``close``), handed out by ``BatchedEngine.open_session()``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.aer import EVT_END, EVT_LABEL, EVT_SPIKE, MAX_ADDR, MAX_TICK
from repro.serve.guard import ServeStatus, StreamContractError

STATE_KEYS = ("v", "z", "y", "acc_y", "n_spk")


@dataclasses.dataclass
class SessionSnapshot:
    """One incremental (or final) per-session readout observation."""

    sid: int
    pred: int                 # argmax over the accumulated readout so far
    logits: np.ndarray        # acc_y snapshot, shape (n_out,)
    label: int                # max label address seen in the stream so far
    ticks: int                # stream ticks processed when this was taken
    events: int               # spike events consumed when this was taken
    final: bool = False       # True only for SessionHandle.result()
    status: ServeStatus = ServeStatus.OK


class _Session:
    """Host bookkeeping for one open session (internal to the engine)."""

    __slots__ = (
        "sid", "slot", "meta", "sp_tick", "sp_addr", "sp_ptr", "cursor",
        "max_fed_tick", "label", "label_tick", "label_seen", "end_seen",
        "end_tick", "closed", "n_events", "t_open", "t_last", "snapshot",
        "offloaded", "queued", "gate_label", "model_id", "status",
        "deadline", "retries",
    )

    def __init__(
        self,
        sid: int,
        now: float,
        meta: Optional[dict] = None,
        model_id: str = "default",
    ):
        self.sid = sid
        # Which registered model's network (and therefore which per-model
        # carry pool / stream packer) this stream runs against — state
        # shapes differ per model, so a session is pinned to its model's
        # pool for life.
        self.model_id = model_id
        self.slot: Optional[int] = None    # pool row; None ⇒ offloaded/new
        self.meta = meta
        # pending spike events (absolute ticks, tick-ordered); consumed by
        # advancing sp_ptr, compacted on feed
        self.sp_tick = np.zeros(0, np.int64)
        self.sp_addr = np.zeros(0, np.int64)
        self.sp_ptr = 0
        self.cursor = 0            # next stream tick to process
        self.max_fed_tick = -1     # largest tick any fed word carried
        self.label = 0             # running max of label addresses (decode_events_host semantics)
        self.label_tick = 0
        self.label_seen = False
        self.end_seen = False
        self.end_tick = 0
        self.closed = False
        self.n_events = 0
        self.t_open = now
        self.t_last = now
        self.snapshot: Optional[SessionSnapshot] = None
        self.offloaded: Optional[Dict[str, np.ndarray]] = None
        self.queued = False        # True while sitting in the packer's queue
        self.status = ServeStatus.OK   # FAULT once quarantined (sticky)
        self.deadline: Optional[float] = None  # absolute; None = no deadline
        self.retries = 0           # launch-fault rewinds since last success
        # With infer_window == "valid" the readout window starts at the label
        # announcement, so ticks fed *before* the (single) label word cannot
        # know their final valid bit — the engine sets this flag to hold the
        # stream back until the label (or END/close) arrives, after which the
        # incremental mask is exact.  "all"-window engines leave it False.
        self.gate_label = False

    # ------------------------------------------------------------- feeding

    def feed(self, events: np.ndarray) -> int:
        """Append one AER word buffer.  Words must be tick-ordered within a
        buffer and non-decreasing across buffers (the stream contract).
        Returns the number of spike events admitted."""
        if self.closed:
            raise StreamContractError(
                f"session {self.sid}: feed() on a closed session"
            )
        words = np.asarray(events, np.uint32).ravel()
        kind = words >> 24
        live = kind != 0
        words, kind = words[live], kind[live]
        if words.size == 0:
            return 0
        addr = ((words >> 12) & MAX_ADDR).astype(np.int64)
        tick = (words & MAX_TICK).astype(np.int64)
        sp = kind == EVT_SPIKE
        if sp.any():
            # drop already-processed ticks (stream-contract violations) so
            # the pending arrays stay sorted relative to the cursor
            keep = sp & (tick >= self.cursor)
            self.sp_tick = np.concatenate(
                [self.sp_tick[self.sp_ptr:], tick[keep]]
            )
            self.sp_addr = np.concatenate(
                [self.sp_addr[self.sp_ptr:], addr[keep]]
            )
            self.sp_ptr = 0
            self.n_events += int(keep.sum())
        lab = kind == EVT_LABEL
        if lab.any():
            self.label = max(self.label, int(addr[lab].max()))
            self.label_tick = max(self.label_tick, int(tick[lab].max()))
            self.label_seen = True
        end = kind == EVT_END
        if end.any():
            self.end_seen = True
            self.end_tick = max(self.end_tick, int(tick[end].max()))
        self.max_fed_tick = max(self.max_fed_tick, int(tick.max()))
        return int(sp.sum())

    # ---------------------------------------------------------- scheduling

    def horizon(self) -> int:
        """First tick that is *not* yet processable.  END pins the stream
        length; a closed END-less stream runs to the last fed tick; an open
        stream holds back its newest tick (a later feed may still add words
        at it)."""
        if self.end_seen:
            return self.end_tick + 1
        if self.closed:
            return self.max_fed_tick + 1
        if self.gate_label and not self.label_seen:
            # Supervised readout window undetermined: a label word arriving
            # later would retroactively invalidate any tick processed now.
            return 0
        return max(self.max_fed_tick, 0)

    def processable(self) -> int:
        return max(0, self.horizon() - self.cursor)

    def take_chunk(self, num_ticks: int) -> "SessionChunkRef":
        """Consume up to ``num_ticks`` processable ticks from the cursor —
        the per-session half of building one tick-tile."""
        n = min(self.processable(), num_ticks)
        base = self.cursor
        end = base + n
        hi = int(np.searchsorted(self.sp_tick[self.sp_ptr:], end)) + self.sp_ptr
        ref = SessionChunkRef(
            sp_tick=self.sp_tick[self.sp_ptr:hi],
            sp_addr=self.sp_addr[self.sp_ptr:hi],
            base=base,
            n_live=n,
            label_tick=self.label_tick,
            end_tick=self.end_tick if self.end_seen else None,
        )
        self.sp_ptr = hi
        self.cursor = end
        return ref

    def restore_chunk(self, ref: "SessionChunkRef") -> None:
        """Undo a :meth:`take_chunk` whose tile launch failed: re-prepend
        the chunk's spikes and rewind the cursor so the ticks are re-served
        on the next pack.  Safe against interleaved feeds — anything fed
        after the take carries ticks ``>= ref.base + n_live`` (feed drops
        ticks behind the cursor), so prepending preserves sort order."""
        self.sp_tick = np.concatenate(
            [ref.sp_tick, self.sp_tick[self.sp_ptr:]]
        )
        self.sp_addr = np.concatenate(
            [ref.sp_addr, self.sp_addr[self.sp_ptr:]]
        )
        self.sp_ptr = 0
        self.cursor = ref.base


@dataclasses.dataclass
class SessionChunkRef:
    """One session's slice of a tick-tile: the spikes and masks of stream
    ticks ``[base, base + n_live)``, in absolute tick coordinates
    (:func:`repro.serve.batching.decode_session_chunks` rebases them)."""

    sp_tick: np.ndarray
    sp_addr: np.ndarray
    base: int
    n_live: int                    # dynamics run for ticks < base + n_live
    label_tick: int                # valid from label_tick + label_delay
    end_tick: Optional[int]        # valid through end_tick; None = END unseen


class SessionPool:
    """``S_cap`` device-resident carry rows + admission control.

    The pool owns the state pytree as ``(S_cap + 1, ·)`` arrays — row
    ``S_cap`` is the trash slot every padded tile lane gathers from and
    scatters to, so tile launches never change shape with occupancy.
    Scatters are applied *functionally at launch time* (``state = state.at
    [idx].set(new)`` on the not-yet-ready device values), so ``self.state``
    always reflects every launched tile and eviction needs no in-flight
    tracking: offloading a row merely blocks until the chain resolves.

    Admission control: :meth:`place` seats a batch of sessions, evicting
    least-recently-*packed* residents when full (skipping sessions being
    seated right now); :meth:`sweep` offloads residents idle longer than
    ``idle_timeout``.  Both take their notion of time from the injected
    ``clock`` so policies unit-test against a scripted clock.
    """

    def __init__(
        self,
        backend,                     # repro.core.backend.ExecutionBackend
        capacity: int,
        idle_timeout: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if capacity < 1:
            raise ValueError(f"pool capacity must be >= 1, got {capacity}")
        self.backend = backend
        self.capacity = int(capacity)
        self.trash = self.capacity          # fixed trash row index
        self.idle_timeout = idle_timeout
        self._clock = clock
        self.state = backend.init_session_state(self.capacity + 1)
        self._free: List[int] = list(range(self.capacity - 1, -1, -1))
        self._resident: "OrderedDict[int, _Session]" = OrderedDict()
        self.evictions = 0
        self.readmissions = 0

    # ------------------------------------------------------------ residency

    def __len__(self) -> int:
        return len(self._resident)

    def touch(self, sess: _Session) -> None:
        """Mark a resident session most-recently-used."""
        if sess.sid in self._resident:
            self._resident.move_to_end(sess.sid)
        sess.t_last = self._clock()

    def place(
        self, sessions: List[_Session]
    ) -> Tuple[np.ndarray, Optional[Dict[str, np.ndarray]]]:
        """Seat every session (allocating/evicting as needed) and return
        ``(slots, admit_rows)``: the slot index per session, plus the stacked
        host rows to scatter for the newly seated ones (``None`` when all
        were already resident).  New sessions admit zero rows — a freed slot
        still holds its previous occupant's state, so the scatter is what
        resets it."""
        seating = {s.sid for s in sessions}
        admits: List[Tuple[int, _Session]] = []
        for i, sess in enumerate(sessions):
            if sess.slot is None:
                sess.slot = self._alloc(exclude=seating)
                admits.append((i, sess))
                if sess.offloaded is not None:
                    self.readmissions += 1
                self._resident[sess.sid] = sess
            self.touch(sess)
        slots = np.array([s.slot for s in sessions], np.int32)
        if not admits:
            return slots, None
        zeros = {
            k: np.zeros(v.shape[1:], np.float32) for k, v in self.state.items()
        }
        rows = {
            k: np.stack([
                (s.offloaded or zeros)[k] for _, s in admits
            ]) for k in STATE_KEYS
        }
        rows["idx"] = np.array([s.slot for _, s in admits], np.int32)
        for _, s in admits:
            s.offloaded = None
        return slots, rows

    def _alloc(self, exclude=()) -> int:
        if self._free:
            return self._free.pop()
        for sid, cand in self._resident.items():   # LRU order: oldest first
            if sid not in exclude:
                self.evict(cand)
                return self._free.pop()
        raise RuntimeError(
            f"session pool over capacity ({self.capacity}): every resident "
            "session is in the tile being placed"
        )

    def evict(self, sess: _Session) -> None:
        """Offload one resident session's carry row to host memory and free
        its slot.  Bit-exact: the row is copied verbatim (in quantized mode
        these are integers on the membrane grid), so readmission continues
        the stream as if never interrupted."""
        if sess.slot is None:
            raise RuntimeError(f"evict() on non-resident session {sess.sid}")
        sess.offloaded = {
            k: np.asarray(v[sess.slot]) for k, v in self.state.items()
        }
        self._free.append(sess.slot)
        sess.slot = None
        self._resident.pop(sess.sid, None)
        self.evictions += 1

    def release(self, sess: _Session) -> None:
        """Close-path slot return: the session is done, its state is dead."""
        if sess.slot is not None:
            self._free.append(sess.slot)
            sess.slot = None
            self._resident.pop(sess.sid, None)
        sess.offloaded = None

    def sweep(self, now: Optional[float] = None) -> int:
        """Evict residents idle longer than ``idle_timeout``; returns the
        number offloaded.  No-op when no timeout is configured."""
        if self.idle_timeout is None:
            return 0
        now = self._clock() if now is None else now
        stale = [
            s for s in self._resident.values()
            if now - s.t_last > self.idle_timeout
        ]
        for s in stale:
            self.evict(s)
        return len(stale)

    # --------------------------------------------------------- device state

    def padded_slots(self, slots: np.ndarray, b_pad: int) -> jax.Array:
        """Slot vector padded to the tile's fixed lane count with the trash
        row, so gather/scatter programs see one shape per tile size."""
        idx = np.full((b_pad,), self.trash, np.int32)
        idx[: len(slots)] = slots
        return jax.numpy.asarray(idx)

    def gather(self, idx: jax.Array) -> Dict[str, jax.Array]:
        """Carry rows for one tile's lanes (trash lanes read garbage — their
        ``live``/``valid`` masks are zero, so it never propagates)."""
        return _gather(self.state, idx)

    def scatter(self, idx: jax.Array, new_state: Dict[str, jax.Array]) -> None:
        """Write one tile's final carries back (enqueued immediately — the
        pool state chains on the launch without host synchronisation)."""
        self.state = _scatter(self.state, idx, new_state)

    def admit(self, rows: Dict[str, np.ndarray]) -> None:
        """One batched scatter seating all of a tile's newly placed sessions
        (zeros for fresh sessions, offloaded rows for readmissions)."""
        idx = jax.numpy.asarray(rows["idx"])
        new = {k: jax.numpy.asarray(rows[k]) for k in STATE_KEYS}
        self.state = _scatter(self.state, idx, new)

    def state_bytes(self) -> int:
        """Device bytes the pool occupies (the S_cap capacity unit)."""
        return sum(v.size * v.dtype.itemsize for v in self.state.values())


@jax.jit
def _gather(state, idx):
    return {k: v[idx] for k, v in state.items()}


@jax.jit
def _scatter(state, idx, new):
    # duplicate trash-lane indices are fine: last-write-wins into a row
    # nothing ever reads as signal
    return {k: state[k].at[idx].set(new[k]) for k in state}
