"""Batched AER serving runtime over the fused Pallas RSNN kernel.

Turns the per-sample controller loop (:mod:`repro.core.controller`) into a
throughput-oriented inference service:

* :mod:`repro.serve.batching`  — ragged-stream padding/masking + VMEM sizing;
* :mod:`repro.serve.scheduler` — request queue, tick-count bucketing;
* :mod:`repro.serve.engine`    — jit-cached batched forward, stats.

See ``benchmarks/bench_serve.py`` for the throughput comparison against the
sequential controller loop and ``examples/serve_braille.py`` for an
end-to-end train-then-serve demo.
"""

from repro.serve.batching import (
    DEFAULT_VMEM_BUDGET,
    KERNEL_SAMPLE_CAP,
    decode_events_host,
    max_batch_for,
    request_ticks,
)
from repro.serve.engine import BatchedEngine, ServeResult, ServeStats
from repro.serve.scheduler import BatchTile, BucketingScheduler, ServeRequest
