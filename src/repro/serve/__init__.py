"""Session-first AER serving runtime over the shared execution backend.

The serving model is the **session**: an unbounded per-user AER event
stream with persistent recurrent state — the paper's neuromorphic edge
scenario.  ``BatchedEngine.open_session()`` hands out a
:class:`~repro.serve.engine.SessionHandle` (``feed`` / ``poll`` /
``result`` / ``close``); the engine continuously batches whichever sessions
have pending ticks into fixed-shape tick-tiles, with every session's carry
state resident in a device-side :class:`~repro.serve.session.SessionPool`
(LRU + idle-timeout eviction, bit-exact offload/readmit).  The historical
whole-sample entry points (``submit()`` / ``serve()``) remain supported as
a thin open-feed-close wrapper over the same machinery.

* :mod:`repro.serve.session`   — device-resident state pool, session records;
* :mod:`repro.serve.batching`  — ragged-stream decode/padding + capacity math;
* :mod:`repro.serve.scheduler` — whole-sample bucketing + continuous packing;
* :mod:`repro.serve.engine`    — the engine, session handles, stats.

See ``docs/serving.md`` for the session lifecycle and the migration guide
from the whole-sample API, ``benchmarks/bench_serve.py --streaming`` for
the sustained-throughput gate, and ``examples/streaming_sessions.py`` /
``examples/serve_braille.py`` for end-to-end demos.

This package re-exports exactly the supported public surface (``__all__``
below); everything else — host decode internals, pending-tile records,
pool plumbing — is implementation detail reachable through the submodules.
"""

from repro.serve.batching import (
    DEFAULT_SESSION_STATE_BUDGET,
    DEFAULT_VMEM_BUDGET,
    KERNEL_SAMPLE_CAP,
    max_batch_for,
    max_sessions_for,
    request_ticks,
)
from repro.serve.engine import (
    BatchedEngine,
    ServeResult,
    ServeStats,
    SessionHandle,
    StreamStats,
)
from repro.serve.scheduler import (
    BatchTile,
    BucketingScheduler,
    ServeRequest,
    StreamPacker,
)
from repro.serve.session import SessionPool, SessionSnapshot

__all__ = [
    # engine + handles
    "BatchedEngine",
    "SessionHandle",
    "ServeResult",
    "ServeStats",
    "StreamStats",
    "SessionSnapshot",
    # schedulers
    "BucketingScheduler",
    "StreamPacker",
    "BatchTile",
    "ServeRequest",
    # state pool
    "SessionPool",
    # sizing / capacity math
    "max_batch_for",
    "max_sessions_for",
    "request_ticks",
    "DEFAULT_VMEM_BUDGET",
    "DEFAULT_SESSION_STATE_BUDGET",
    "KERNEL_SAMPLE_CAP",
]
