"""Session-first AER serving runtime over the shared execution backend.

The serving model is the **session**: an unbounded per-user AER event
stream with persistent recurrent state — the paper's neuromorphic edge
scenario.  ``BatchedEngine.open_session()`` hands out a
:class:`~repro.serve.engine.SessionHandle` (``feed`` / ``poll`` /
``result`` / ``close``); the engine continuously batches whichever sessions
have pending ticks into fixed-shape tick-tiles, with every session's carry
state resident in a device-side :class:`~repro.serve.session.SessionPool`
(LRU + idle-timeout eviction, bit-exact offload/readmit).  The historical
whole-sample entry points (``submit()`` / ``serve()``) remain supported as
a thin open-feed-close wrapper over the same machinery.

Multi-model serving: a :class:`~repro.serve.registry.ModelRegistry` holds
any number of ``model_id``-keyed networks (config + quant contract +
weight-SRAM image, hot-swappable mid-serve) over one shared backend pool;
``BatchedEngine(registry=...)`` serves them all concurrently, routing every
``submit``/``open_session``/``serve`` call by ``model_id`` — the paper's
runtime reprogrammability (one fabric, many SRAM programs) at service
scale.

* :mod:`repro.serve.session`   — device-resident state pool, session records;
* :mod:`repro.serve.batching`  — ragged-stream decode/padding + capacity math;
* :mod:`repro.serve.scheduler` — whole-sample bucketing + continuous packing;
* :mod:`repro.serve.registry`  — model registry: specs, hot-swap, routing;
* :mod:`repro.serve.engine`    — the engine, session handles, stats.

See ``docs/serving.md`` for the session lifecycle and the migration guide
from the whole-sample API, ``benchmarks/bench_serve.py --streaming`` for
the sustained-throughput gate, and ``examples/streaming_sessions.py`` /
``examples/serve_braille.py`` for end-to-end demos.

This package re-exports exactly the supported public surface (``__all__``
below); everything else — host decode internals, pending-tile records,
pool plumbing — is implementation detail reachable through the submodules.
"""

from repro.serve.batching import (
    DEFAULT_SESSION_STATE_BUDGET,
    DEFAULT_VMEM_BUDGET,
    KERNEL_SAMPLE_CAP,
    max_batch_for,
    max_sessions_for,
    request_ticks,
)
from repro.serve.engine import (
    BatchedEngine,
    ServeResult,
    ServeStats,
    SessionHandle,
    StreamStats,
)
from repro.serve.guard import (
    GuardConfig,
    GuardError,
    LaneFaultError,
    MalformedEventError,
    OverloadError,
    QuotaExceededError,
    ServeError,
    ServeStatus,
    StreamContractError,
    bad_rows,
    validate_events,
)
from repro.serve.registry import (
    DEFAULT_MODEL,
    SRAM_KEYS,
    ModelRegistry,
    ModelSpec,
    expected_shapes,
)
from repro.serve.scheduler import (
    BatchTile,
    BucketingScheduler,
    ServeRequest,
    StreamPacker,
)
from repro.serve.session import SessionPool, SessionSnapshot

__all__ = [
    # engine + handles
    "BatchedEngine",
    "SessionHandle",
    "ServeResult",
    "ServeStats",
    "StreamStats",
    "SessionSnapshot",
    # model registry (multi-model serving)
    "ModelRegistry",
    "ModelSpec",
    "expected_shapes",
    "DEFAULT_MODEL",
    "SRAM_KEYS",
    # schedulers
    "BucketingScheduler",
    "StreamPacker",
    "BatchTile",
    "ServeRequest",
    # state pool
    "SessionPool",
    # guard layer + error model (hardened serving)
    "GuardConfig",
    "ServeStatus",
    "ServeError",
    "GuardError",
    "MalformedEventError",
    "StreamContractError",
    "QuotaExceededError",
    "OverloadError",
    "LaneFaultError",
    "validate_events",
    "bad_rows",
    # sizing / capacity math
    "max_batch_for",
    "max_sessions_for",
    "request_ticks",
    "DEFAULT_VMEM_BUDGET",
    "DEFAULT_SESSION_STATE_BUDGET",
    "KERNEL_SAMPLE_CAP",
]
