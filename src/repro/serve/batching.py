"""Padding / masking / sizing utilities for the batched serving runtime.

Serving concatenates *ragged* AER sample streams (each request carries its
own tick count) into rectangular ``(T, B, N_in)`` tiles the fused Pallas
kernel (:mod:`repro.kernels.rsnn_step`) consumes.  Correctness under padding
rests on two invariants, both inherited from the controller
(:mod:`repro.core.controller`):

* padded ticks carry **zero input spikes**, so the membrane dynamics of
  ticks ``<= end_tick`` are untouched by the padding that follows them;
* the LI readout is accumulated under the per-sample TARGET_VALID mask
  (:func:`repro.core.aer.supervision_mask` semantics), which is zero on
  padded ticks — so ``acc_y`` is bit-identical to running the sample at its
  native length.

The VMEM budget arithmetic lives with the kernels
(:mod:`repro.kernels.rsnn_step`'s bytes-budget helpers — the same source
``KERNEL_SAMPLE_CAP``, the backend's tile guard and the fused-train scratch
sizing derive from); this module only adapts it to :class:`RSNNConfig`.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.aer import EVT_END, EVT_LABEL, EVT_SPIKE, MAX_ADDR, MAX_TICK
from repro.core.rsnn import RSNNConfig

# Re-exported for tile sizing — both owned by the kernel contract.
from repro.kernels.rsnn_step import (  # noqa: F401
    DEFAULT_VMEM_BUDGET,
    KERNEL_SAMPLE_CAP,
    max_batch_for_dims,
    session_state_bytes,
    state_bytes_per_sample,
    weights_bytes,
)

# Default device-byte budget for the streaming session pool (HBM-resident —
# independent of the VMEM tile budget, deliberately the same magnitude).
# 4 MiB holds ~12k Braille-sized sessions (332 B each); see docs/serving.md
# for the capacity math.  Scale it up explicitly for larger fleets — the
# pool is the capacity unit, so this is the one knob that bounds concurrent
# resident sessions.
DEFAULT_SESSION_STATE_BUDGET = 4 * 1024 * 1024


def round_up(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


def vmem_bytes_per_sample(cfg: RSNNConfig) -> int:
    """VMEM bytes one batch row occupies inside the worst-case tick kernel
    (carry scratch + double-buffered per-tick blocks; f32 throughout)."""
    return state_bytes_per_sample(cfg.n_in, cfg.n_hid, cfg.n_out)


def weights_vmem_bytes(cfg: RSNNConfig) -> int:
    return weights_bytes(cfg.n_in, cfg.n_hid, cfg.n_out)


def max_batch_for(
    cfg: RSNNConfig,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    num_devices: int = 1,
) -> int:
    """Serving admission size: the per-device kernel tile the VMEM budget
    admits (capped by the kernel contract), times the data-parallel device
    count.  Since the kernels batch-tile internally this is a *throughput*
    target (one full tile per device per launch), not a hard VMEM limit.
    """
    per_device = max_batch_for_dims(
        cfg.n_in, cfg.n_hid, cfg.n_out, vmem_budget, cap=KERNEL_SAMPLE_CAP
    )
    return per_device * max(1, num_devices)


def max_sessions_for(
    cfg: RSNNConfig,
    state_budget: int = DEFAULT_SESSION_STATE_BUDGET,
) -> int:
    """Streaming capacity ``S_cap``: how many resident sessions a device
    byte budget admits.  One session's carry ``(v, z, y, acc_y, n_spk)``
    costs :func:`repro.kernels.rsnn_step.session_state_bytes` =
    ``4·(2H + 2O + 1)`` bytes, independent of stream length — the pool, not
    the batch, is the capacity unit of streaming serving."""
    per = session_state_bytes(cfg.n_hid, cfg.n_out)
    return max(1, int(state_budget) // per)


def request_ticks(events: np.ndarray) -> int:
    """Native tick count of an AER request = end-of-sample tick + 1.

    Falls back to the largest event tick when the END word is missing
    (a stream cut mid-sample).
    """
    words = np.asarray(events, np.uint32)
    kind = words >> 24
    ticks = words & MAX_TICK
    is_end = kind == EVT_END
    if is_end.any():
        return int(ticks[is_end].max()) + 1
    live = kind != 0
    return int(ticks[live].max()) + 1 if live.any() else 1


def bucket_ticks(native_ticks: int, granularity: int, cap: int = MAX_TICK + 1) -> int:
    """Padded tick length of the bucket a request lands in."""
    return min(round_up(max(1, native_ticks), granularity), cap)


def decode_events_host(
    events_list: Sequence[np.ndarray],
    n_in: int,
    num_ticks: int,
    label_delay: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side AER decode of one bucket → ``(raster, valid, labels)``.

    NumPy mirror of :func:`repro.core.aer.decode_batch` +
    :func:`repro.core.aer.supervision_mask` (asserted equivalent in
    ``tests/test_serve.py``) that runs on the host CPU — the serving analog
    of the SoC's ARM-side AER handling.  Crucially it is *shape-oblivious*:
    ragged event buffers never force an XLA recompile, only the padded
    ``(T, B)`` tile shape does.

    Returns ``raster (T, B, n_in) f32``, ``valid (T, B) f32``,
    ``labels (B,) i32``.
    """
    B = len(events_list)
    raster = np.zeros((num_ticks, B, n_in), np.float32)
    labels = np.zeros((B,), np.int32)

    # One flat pass over the whole bucket: concatenate every buffer and carry
    # a per-word sample index — no per-sample Python loop on the hot path.
    bufs = [np.asarray(w, np.uint32).ravel() for w in events_list]
    words = np.concatenate(bufs) if bufs else np.zeros(0, np.uint32)
    b_idx = np.repeat(np.arange(B, dtype=np.int64), [len(w) for w in bufs])
    kind = words >> 24
    addr = ((words >> 12) & MAX_ADDR).astype(np.int64)
    tick = (words & MAX_TICK).astype(np.int64)

    sp = (kind == EVT_SPIKE) & (tick < num_ticks) & (addr < n_in)
    raster[tick[sp], b_idx[sp], addr[sp]] = 1.0

    # END-less buffers decode with end_tick = 0, exactly like the device path
    # (aer.decode_sample's masked max) — never the padded bucket length, which
    # would make the valid mask depend on which bucket the request landed in.
    label_tick = np.zeros((B,), np.int64)
    end_tick = np.zeros((B,), np.int64)
    lab = kind == EVT_LABEL
    np.maximum.at(labels, b_idx[lab], addr[lab].astype(np.int32))
    np.maximum.at(label_tick, b_idx[lab], tick[lab])
    end = kind == EVT_END
    np.maximum.at(end_tick, b_idx[end], tick[end])

    t_range = np.arange(num_ticks)[:, None]
    valid = (
        (t_range >= label_tick[None, :] + label_delay)
        & (t_range <= end_tick[None, :])
    ).astype(np.float32)
    return raster, valid, labels


def decode_session_chunks(
    chunks: Sequence,
    n_in: int,
    num_ticks: int,
    label_delay: int = 0,
    b_pad: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side decode of one streaming tick-tile → ``(raster, live,
    valid)``, each lane one session's next stream ticks.

    ``chunks`` are :class:`repro.serve.session.SessionChunkRef` slices in
    absolute stream coordinates; lane ``i``'s tile tick ``t`` is stream tick
    ``chunks[i].base + t``.  Two masks come back:

    * ``live`` — dynamics mask: 1 for ``t < n_live``.  A dead tick freezes
      the session's carry *exactly* (the kernel selects, it does not decay),
      which is how ragged per-session chunk lengths pack into one
      rectangular tile; padded lanes (``b_pad > len(chunks)``) are dead for
      the whole tile.
    * ``valid`` — readout-accumulation mask (⊆ live), the streaming
      continuation of :func:`decode_events_host`'s TARGET_VALID window:
      ``label_tick + label_delay ≤ t_abs``, and ``t_abs ≤ end_tick`` once
      END has been seen.  Because feeds are tick-ordered, the incremental
      mask equals the whole-sample one.
    """
    B = len(chunks)
    b_pad = B if b_pad is None else b_pad
    raster = np.zeros((num_ticks, b_pad, n_in), np.float32)
    if B:
        bufs_t = [c.sp_tick - c.base for c in chunks]
        t = np.concatenate(bufs_t) if bufs_t else np.zeros(0, np.int64)
        a = np.concatenate([c.sp_addr for c in chunks]) if B else t
        b_idx = np.repeat(
            np.arange(B, dtype=np.int64), [len(x) for x in bufs_t]
        )
        ok = (t >= 0) & (t < num_ticks) & (a < n_in)
        raster[t[ok], b_idx[ok], a[ok]] = 1.0

    n_live = np.zeros((b_pad,), np.int64)
    lab0 = np.zeros((b_pad,), np.int64)
    end_rel = np.full((b_pad,), -1, np.int64)
    for i, c in enumerate(chunks):
        n_live[i] = c.n_live
        lab0[i] = c.label_tick + label_delay - c.base
        end_rel[i] = (
            num_ticks - 1 if c.end_tick is None else c.end_tick - c.base
        )
    t_range = np.arange(num_ticks)[:, None]
    live = (t_range < n_live[None, :]).astype(np.float32)
    valid = (
        (t_range >= lab0[None, :]) & (t_range <= end_rel[None, :])
    ).astype(np.float32) * live
    return raster, live, valid


def pad_batch(
    raster: np.ndarray,
    valid: np.ndarray,
    target_b: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Right-pad the batch axis with dead samples (zero input, zero valid).

    Batch sizes are padded to a small set of capacities (powers of two, see
    :func:`padded_batch_size`) so partially-filled buckets reuse compiled
    programs instead of minting one jit cache entry per ragged size.
    """
    T, B, N = raster.shape
    if B == target_b:
        return raster, valid
    if B > target_b:
        raise ValueError(
            f"batch of {B} rows cannot pad down to target_b={target_b}"
        )
    pad_r = np.zeros((T, target_b - B, N), raster.dtype)
    pad_v = np.zeros((T, target_b - B), valid.dtype)
    return np.concatenate([raster, pad_r], axis=1), np.concatenate([valid, pad_v], axis=1)


def padded_batch_size(b: int, max_batch: int) -> int:
    """Next power of two ≥ b, clipped to max_batch."""
    p = 1
    while p < b:
        p <<= 1
    return min(p, max_batch)


def trim_padding(events_row: np.ndarray) -> np.ndarray:
    """Strip the trailing 0x0 pad words a dense event matrix row carries."""
    words = np.asarray(events_row, np.uint32)
    live = np.nonzero(words >> 24)[0]
    return words[: live[-1] + 1] if live.size else words[:0]


def split_into_tiles(
    items: List, max_batch: int
) -> List[List]:
    """FIFO-stable chop of a bucket's queue into ≤ max_batch tiles."""
    return [items[i : i + max_batch] for i in range(0, len(items), max_batch)]
