"""Model registry: runtime-reprogrammable multi-model serving state.

The paper's SoC is runtime-reprogrammable — the host reloads ReckOn's
weight SRAM over SPI, so one accelerator fabric serves many networks (the
Braille classifier and the cue-accumulation task are two programs for the
same chip).  This module is that capability's software twin:

* :class:`ModelSpec` — one deployable model keyed by ``model_id``: its
  :class:`~repro.core.rsnn.RSNNConfig` (the SPI parameter bank), its quant
  contract (the fixed-point datapath registers, via the resolved backend),
  and its weight-SRAM image (snapped onto the 8-bit grid in quantized
  mode).
* :class:`ModelRegistry` — ``register`` / ``deregister`` / ``get`` plus
  :meth:`~ModelRegistry.update_weights`, the **hot-swap**: a jit'd SRAM
  load (buffer-donating on accelerator backends, exactly the PR 5 engine
  path) replaces a registered model's image mid-serve with zero
  recompilation — weights are jit *arguments* everywhere downstream.

Backends come from one shared :class:`~repro.core.backend.BackendPool`:
models whose configs fall in the same execution bucket
(:func:`~repro.core.backend.bucket_key` — the ``(T, N, H, O, quant)``
shape bucket plus every baked trace-time constant) share a single
:class:`~repro.core.backend.ExecutionBackend` and therefore one jit cache.
Registering a second same-shaped model, or hot-swapping any model, never
compiles anything (asserted in ``tests/test_multimodel.py``).

Shape discipline: a registry knows every model's expected weight shapes
from its config, so a mis-shaped SRAM image — the classic symptom of
routing weights to the wrong ``model_id`` — fails at the registry boundary
with a loud :class:`ValueError` naming the model and the per-matrix shape
diff, instead of surfacing as a jit shape error three layers down.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import (
    BackendLike,
    BackendPool,
    ExecutionBackend,
    RuntimeConfig,
    as_backend,
)
from repro.core.rsnn import RSNNConfig

# The model_id single-model entry points act on when the caller doesn't
# route explicitly — what `BatchedEngine(cfg, params)` registers.
DEFAULT_MODEL = "default"

# The weight-SRAM image keys (b_fb is the e-prop feedback matrix — not SRAM
# words on chip, but it rides with the image so a swap replaces the whole
# learnable state consistently).
SRAM_KEYS = ("w_in", "w_rec", "w_out", "b_fb")


def expected_shapes(cfg: RSNNConfig) -> Dict[str, Tuple[int, int]]:
    """Weight-SRAM image shapes a config's datapath requires."""
    shapes = {
        "w_in": (cfg.n_in, cfg.n_hid),
        "w_rec": (cfg.n_hid, cfg.n_hid),
        "w_out": (cfg.n_hid, cfg.n_out),
    }
    if cfg.eprop.feedback == "random":
        shapes["b_fb"] = (cfg.n_hid, cfg.n_out)
    return shapes


@dataclasses.dataclass
class ModelSpec:
    """One registered model: config + quant contract + weight-SRAM image.

    ``weights`` is the live image every launch reads (in quantized mode:
    values already snapped onto the 8-bit SRAM grid, so the spec is
    observable as exactly what the chip's SRAM would hold).  ``backend`` is
    the pooled execution backend — possibly shared with other specs whose
    configs bucket identically.
    """

    model_id: str
    cfg: RSNNConfig
    backend: ExecutionBackend
    weights: Dict[str, jax.Array]
    swaps: int = 0                   # completed hot-swaps (update_weights)

    @property
    def quant(self):
        """The fixed-point contract tiles run under (None = float)."""
        return self.backend.quant

    @property
    def runtime(self) -> RuntimeConfig:
        return self.backend.runtime


class ModelRegistry:
    """``model_id`` → :class:`ModelSpec`, over one shared backend pool.

    The registry owns model *identity* (which configs/weights exist and
    what each is called); execution stays in the pooled backends and
    serving stays in :class:`~repro.serve.engine.BatchedEngine` — an engine
    constructed with ``registry=`` routes every request's ``model_id``
    here.  Registration order is preserved (the first registered model is
    the engine's default route).
    """

    def __init__(self, pool: Optional[BackendPool] = None):
        self.pool = pool if pool is not None else BackendPool()
        self._specs: "OrderedDict[str, ModelSpec]" = OrderedDict()
        # Quantized SRAM loads go through one jit'd snap program per weight
        # grid; on accelerator backends it donates the model's previous SRAM
        # image so hot-swaps reuse those buffers instead of copying.
        self._donate = jax.default_backend() in ("tpu", "gpu")
        self._loaders: Dict[object, object] = {}

    # ------------------------------------------------------------- lookup

    def __contains__(self, model_id: str) -> bool:
        return model_id in self._specs

    def __len__(self) -> int:
        return len(self._specs)

    def __iter__(self) -> Iterator[str]:
        return iter(self._specs)

    def ids(self) -> Tuple[str, ...]:
        """Registered model ids, in registration order."""
        return tuple(self._specs)

    def get(self, model_id: str) -> ModelSpec:
        spec = self._specs.get(model_id)
        if spec is None:
            raise KeyError(
                f"model {model_id!r} is not registered "
                f"(registered: {list(self._specs) or 'none'})"
            )
        return spec

    # ---------------------------------------------------------- lifecycle

    def register(
        self,
        model_id: str,
        cfg: RSNNConfig,
        params: Dict[str, jax.Array],
        *,
        backend: BackendLike = "auto",
        runtime: Optional[RuntimeConfig] = None,
        **loose,
    ) -> ModelSpec:
        """Register a model: resolve its (pooled) backend, validate and
        snap its weight-SRAM image, and make it routable by ``model_id``.

        ``params`` is the learner-side pytree (``w_in/w_rec/w_out`` +
        optional ``b_fb``/scalar ``alpha``); ``backend`` accepts a name, a
        :class:`~repro.core.backend.RuntimeConfig`, or an existing
        :class:`~repro.core.backend.ExecutionBackend` (adopted into the
        pool, so a learner's live jit cache is shared).  Registering into
        an already-bucketed shape constructs nothing new.
        """
        if model_id in self._specs:
            raise ValueError(
                f"model {model_id!r} already registered — deregister it "
                "first, or use update_weights() to hot-swap its SRAM image"
            )
        alpha = loose.pop(
            "alpha", float(np.asarray(params.get("alpha", cfg.neuron.alpha)))
        )
        be = as_backend(
            cfg, backend, alpha=alpha, runtime=runtime,
            model_id=model_id, pool=self.pool, **loose,
        )
        image = self._validated_image(model_id, cfg, params)
        spec = ModelSpec(
            model_id=model_id, cfg=cfg, backend=be,
            weights=self._snap(be, image),
        )
        self._specs[model_id] = spec
        return spec

    def deregister(self, model_id: str) -> ModelSpec:
        """Forget a model (its pooled backend stays — other models may
        bucket onto it, and jit caches are harmless to keep warm)."""
        spec = self.get(model_id)
        del self._specs[model_id]
        return spec

    # -------------------------------------------------------- lane restart

    def rebuild_backend(self, model_id: str) -> ModelSpec:
        """Replace a model's execution backend with a freshly constructed
        one — the registry half of a lane restart after a device/launch
        fault.  The poisoned backend is discarded from the pool; a new
        backend is built for the same config/runtime bucket (recompiling on
        next launch — correctness over warmth); every spec sharing the old
        backend is re-pointed and its weight image re-materialised through
        the new instance, so no downstream launch ever touches the old
        device buffers."""
        spec = self.get(model_id)
        old = spec.backend
        self.pool.discard(old)
        fresh = self.pool.get(old.cfg, old.runtime)
        self._loaders.pop(old.quant, None)   # loader closed over `old`
        for other in self._specs.values():
            if other.backend is old:
                other.backend = fresh
                other.weights = self._snap(
                    fresh, {k: np.asarray(v) for k, v in other.weights.items()}
                )
        return spec

    # ------------------------------------------------------------ hot-swap

    def update_weights(
        self, model_id: str, weights: Dict[str, jax.Array]
    ) -> ModelSpec:
        """Hot-swap a registered model's weight-SRAM image (the SPI weight
        reload, mid-serve): shape-validated against the spec, snapped onto
        the SRAM grid in quantized mode through a jit'd load that donates
        the previous image's buffers on accelerator backends.  Never
        recompiles — weights are jit arguments everywhere downstream, and
        in-flight launches keep the image they were launched with.

        Partial images are allowed (a learner publishing only the trainable
        ``w_in/w_rec/w_out`` leaves a registered feedback matrix in place) —
        provided matrices are validated, missing ones keep their current
        values."""
        spec = self.get(model_id)
        image = self._validated_image(
            model_id, spec.cfg, weights, require_all=False
        )
        old = spec.weights
        if spec.quant is not None and set(old) == set(image):
            loader = self._loader(spec.backend)
            spec.weights = loader(image, old)
        else:
            spec.weights = self._snap(spec.backend, {**old, **image})
        spec.swaps += 1
        return spec

    # ------------------------------------------------------------ plumbing

    def _validated_image(
        self,
        model_id: str,
        cfg: RSNNConfig,
        weights: Dict[str, jax.Array],
        *,
        require_all: bool = True,
    ) -> Dict[str, jax.Array]:
        """Filter a params pytree down to the SRAM image keys and check
        every shape against the registered config — the loud boundary that
        turns a mis-routed image into an actionable error.  An empty image
        is always an error; with ``require_all=False`` (hot-swap) a partial
        image passes as long as what *is* present fits."""
        image = {k: v for k, v in weights.items() if k in SRAM_KEYS}
        want = expected_shapes(cfg)
        missing = (
            [k for k in want if k not in image]
            if require_all or not image
            else []
        )
        fb = (cfg.n_hid, cfg.n_out)   # b_fb rides along even when symmetric
        checked = want if "b_fb" in want else {**want, "b_fb": fb}
        diffs = [
            f"{k}: expected {checked[k]}, got {tuple(image[k].shape)}"
            for k in checked
            if k in image and tuple(image[k].shape) != checked[k]
        ]
        if missing or diffs:
            raise ValueError(
                f"weight-SRAM image mismatch for model {model_id!r} "
                f"(n_in={cfg.n_in}, n_hid={cfg.n_hid}, n_out={cfg.n_out}): "
                + "; ".join(
                    ([f"missing {missing}"] if missing else []) + diffs
                )
            )
        return image

    @staticmethod
    def _sram(backend: ExecutionBackend, k: str, v) -> jax.Array:
        """One image entry as the spec holds it: the 8-bit SRAM grid value
        in quantized mode (the datapath would re-snap anyway — this makes
        the spec observable as the SRAM image), raw otherwise.  Feedback
        matrices are not SRAM words and pass through."""
        q = backend.quant
        if q is None or k == "b_fb":
            return jnp.asarray(v)
        return q.weight_spec.round_nearest(jnp.asarray(v))

    def _snap(self, backend: ExecutionBackend, image: Dict) -> Dict:
        return {k: self._sram(backend, k, v) for k, v in image.items()}

    def _loader(self, backend: ExecutionBackend):
        """The jit'd donated SRAM load for one backend's weight grid (one
        program per quant mode, cached)."""
        key = backend.quant
        fn = self._loaders.get(key)
        if fn is None:
            def load(new, old):
                del old  # only donated for its buffers
                return self._snap(backend, new)

            fn = jax.jit(
                load, donate_argnums=(1,) if self._donate else ()
            )
            self._loaders[key] = fn
        return fn

    # ------------------------------------------------------------- stats

    def compiled_shapes(self, op: Optional[str] = None) -> int:
        """Distinct compiled tile shapes across the shared pool — the
        registry-level recompile counter hot-swap assertions gate on."""
        return self.pool.compiled_shapes(op)
