"""Schedulers for the batched serving runtime: whole-sample bucketing and
continuous session batching.

The FPGA controller serves one AER sample at a time (IDLE → READM → TICK →
… → END_S).  At service scale that FSM becomes a *scheduler*; two live here:

* :class:`BucketingScheduler` — the whole-sample path: concurrent sample
  streams are admitted into a queue, grouped by padded tick length
  ("buckets"), and released as rectangular batch tiles sized to the
  kernel's VMEM budget (:func:`repro.serve.batching.max_batch_for`).
* :class:`StreamPacker` — the streaming path's continuous-batching
  generalization: open *sessions* with pending processable ticks queue FIFO,
  and each call packs whichever ≤ ``max_batch`` sessions are ready into the
  next fixed-shape tick-tile (partially drained sessions immediately
  re-queue), so device tiles stay full while every session advances
  incrementally.

Both queues are **bounded** (``max_pending``) with an explicit admission
policy — ``"reject"`` raises :class:`~repro.serve.guard.OverloadError` at
the caller, ``"shed"`` drops the *oldest* queued work to make room (fresh
work has the best chance of meeting its deadline) — and the bucketing
scheduler tracks per-request **deadlines** so expired work is dropped at
pack time, before a device launch is paid for it.

Determinism contract (tested in ``tests/test_serve.py``): admission order is
FIFO within a bucket/queue, buckets drain in ascending tick length, and the
same request sequence always yields the same tiles — no wall-clock
dependence in tile *composition* (the clock only stamps latency accounting
and deadline checks; with no deadlines set, tiles are clock-independent).
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.serve import batching
from repro.serve.guard import OverloadError

ADMISSION_POLICIES = ("reject", "shed")


def _check_admission(admission: str) -> str:
    if admission not in ADMISSION_POLICIES:
        raise ValueError(
            f"admission must be one of {ADMISSION_POLICIES}, got {admission!r}"
        )
    return admission


@dataclasses.dataclass
class ServeRequest:
    """One admitted AER sample stream."""

    rid: int                      # admission index, unique per scheduler
    events: np.ndarray            # ragged uint32 AER buffer (§3.1 word format)
    native_ticks: int             # end-of-sample tick + 1
    bucket: int                   # padded tick length this request serves at
    t_submit: float               # admission timestamp (latency accounting)
    meta: Optional[dict] = None
    deadline: Optional[float] = None  # absolute clock time; None = no deadline


@dataclasses.dataclass
class BatchTile:
    """A rectangular unit of work: ≤ max_batch requests, one tick length."""

    num_ticks: int
    requests: List[ServeRequest]

    def __len__(self) -> int:
        return len(self.requests)


class BucketingScheduler:
    """FIFO admission → per-tick-length buckets → ≤ ``max_batch`` tiles.

    ``tick_granularity`` trades padding waste against compiled-program
    diversity: every request pays at most ``granularity - 1`` dead ticks,
    and the engine compiles at most ``ceil(max_ticks / granularity)``
    distinct time lengths.

    ``rid_alloc`` injects the request-id counter.  A multi-model engine
    runs one scheduler per registered model (tiles must stay single-model —
    one network per launch, like one SRAM image per chip program) but hands
    every scheduler the same allocator, so rids stay unique and
    admission-ordered across the whole engine.

    ``max_pending`` bounds the queue (``None`` = unbounded, the legacy
    behaviour); on overflow, ``admission="reject"`` refuses the *new*
    request with :class:`OverloadError` while ``admission="shed"`` evicts
    the oldest queued request into :attr:`shed` (the engine converts shed
    rids into REJECTED results).  ``take_expired`` removes deadline-passed
    requests — the engine calls it immediately before packing tiles so an
    expired request never occupies a launch slot.
    """

    def __init__(
        self,
        max_batch: int,
        tick_granularity: int = 32,
        clock: Callable[[], float] = time.monotonic,
        rid_alloc: Optional[Callable[[], int]] = None,
        max_pending: Optional[int] = None,
        admission: str = "reject",
    ):
        if max_batch < 1 or tick_granularity < 1:
            raise ValueError(
                f"max_batch and tick_granularity must be >= 1, got "
                f"({max_batch}, {tick_granularity})"
            )
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.max_batch = max_batch
        self.tick_granularity = tick_granularity
        self.max_pending = max_pending
        self.admission = _check_admission(admission)
        self._clock = clock
        self._buckets: Dict[int, List[ServeRequest]] = OrderedDict()
        self._next_rid = 0
        self._rid_alloc = rid_alloc or self._alloc_rid
        self.shed: List[ServeRequest] = []   # evicted under admission="shed"

    def _alloc_rid(self) -> int:
        rid = self._next_rid
        self._next_rid += 1
        return rid

    def submit(
        self,
        events: np.ndarray,
        meta: Optional[dict] = None,
        deadline: Optional[float] = None,
    ) -> int:
        """Admit one AER sample stream; returns its request id.

        ``deadline`` is an *absolute* clock time (same clock the scheduler
        was built with); a request whose deadline passes before it is
        packed is dropped by :meth:`take_expired` and reported EXPIRED.
        Raises :class:`OverloadError` when the queue is full under the
        ``"reject"`` policy.
        """
        if self.max_pending is not None and self.pending >= self.max_pending:
            if self.admission == "reject":
                raise OverloadError(
                    f"scheduler queue full ({self.pending} pending, "
                    f"max_pending={self.max_pending}); retry later or use "
                    'admission="shed"'
                )
            self.shed.append(self._pop_oldest())
        events = batching.trim_padding(events)
        native = batching.request_ticks(events)
        bucket = batching.bucket_ticks(native, self.tick_granularity)
        req = ServeRequest(
            rid=self._rid_alloc(),
            events=events,
            native_ticks=native,
            bucket=bucket,
            t_submit=self._clock(),
            meta=meta,
            deadline=deadline,
        )
        self._buckets.setdefault(bucket, []).append(req)
        return req.rid

    def _pop_oldest(self) -> ServeRequest:
        """Remove and return the queued request with the lowest rid (the
        oldest admission) — the shed victim."""
        best_key, best_i = None, -1
        for ticks, queue in self._buckets.items():
            # FIFO within a bucket: index 0 is that bucket's oldest.
            if queue and (best_key is None
                          or queue[0].rid < self._buckets[best_key][0].rid):
                best_key = ticks
        queue = self._buckets[best_key]
        victim = queue.pop(0)
        if not queue:
            del self._buckets[best_key]
        return victim

    def take_expired(self, now: Optional[float] = None) -> List[ServeRequest]:
        """Remove and return every queued request whose deadline has
        passed.  Called at pack time so expired work never launches."""
        now = self._clock() if now is None else now
        expired: List[ServeRequest] = []
        for ticks in list(self._buckets):
            queue = self._buckets[ticks]
            keep = []
            for req in queue:
                if req.deadline is not None and now > req.deadline:
                    expired.append(req)
                else:
                    keep.append(req)
            if keep:
                self._buckets[ticks] = keep
            else:
                del self._buckets[ticks]
        return expired

    @property
    def pending(self) -> int:
        return sum(len(v) for v in self._buckets.values())

    def ready_tiles(self) -> Iterator[BatchTile]:
        """Release only *full* tiles (steady-state serving keeps partial
        buckets queued for more arrivals)."""
        yield from self._drain(full_only=True)

    def drain(self) -> Iterator[BatchTile]:
        """Release everything, full tiles first within each bucket —
        end-of-stream flush."""
        yield from self._drain(full_only=False)

    def _drain(self, full_only: bool) -> Iterator[BatchTile]:
        for ticks in sorted(self._buckets):
            queue = self._buckets[ticks]
            tiles = batching.split_into_tiles(queue, self.max_batch)
            keep: List[ServeRequest] = []
            for tile in tiles:
                if full_only and len(tile) < self.max_batch:
                    keep.extend(tile)
                else:
                    yield BatchTile(num_ticks=ticks, requests=tile)
            self._buckets[ticks] = keep
        self._buckets = OrderedDict(
            (k, v) for k, v in self._buckets.items() if v
        )


class StreamPacker:
    """Continuous batching over open sessions.

    Sessions enter the FIFO ready-queue when they gain processable ticks
    (:meth:`enqueue`); :meth:`next_tile` pops up to ``max_batch`` of them
    and picks the tile's tick length: the fixed ``tick_tile`` when one is
    configured (latency-bounded true streaming), otherwise the bucketed
    maximum of the chosen sessions' pending ticks (throughput mode — one
    launch drains everything pending, which is what the whole-sample
    compatibility wrapper uses so its per-launch work matches the old
    bucketing path).  A session whose chunk didn't drain it is re-queued by
    the engine after the tile is cut, preserving FIFO fairness.

    ``max_pending`` bounds the ready-queue *length* (sessions, not events;
    per-session event memory is bounded separately by the guard's
    ``max_pending_events`` quota).  The packer has no shed policy of its
    own — a session is stateful, so "shedding" it is the engine's call
    (the engine pumps inline instead, accounting the stall as admission
    wait); :meth:`enqueue` just reports the overflow via its return value.
    """

    def __init__(
        self,
        max_batch: int,
        tick_tile: Optional[int] = None,
        tick_granularity: int = 32,
        max_pending: Optional[int] = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if tick_tile is not None and tick_tile < 1:
            raise ValueError(f"tick_tile must be >= 1, got {tick_tile}")
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.max_batch = max_batch
        self.tick_tile = tick_tile
        self.tick_granularity = tick_granularity
        self.max_pending = max_pending
        self._queue: deque = deque()

    @property
    def full(self) -> bool:
        return (self.max_pending is not None
                and len(self._queue) >= self.max_pending)

    def enqueue(self, sess) -> bool:
        """Add a session with pending work (idempotent per residence in the
        queue — sessions track their own ``queued`` flag).  Returns False
        when the bounded queue is full and the session was *not* added; the
        engine then drains a tile inline before retrying."""
        if sess.queued:
            return True
        if self.full:
            return False
        sess.queued = True
        self._queue.append(sess)
        return True

    @property
    def pending(self) -> int:
        return len(self._queue)

    def next_tile(self) -> Optional[Tuple[List, int]]:
        """Pop the next ``(sessions, num_ticks)`` tile, or ``None`` when no
        queued session has processable ticks."""
        chosen: List = []
        while self._queue and len(chosen) < self.max_batch:
            sess = self._queue.popleft()
            sess.queued = False
            if sess.processable() > 0:
                chosen.append(sess)
        if not chosen:
            return None
        if self.tick_tile is not None:
            ticks = self.tick_tile
        else:
            ticks = batching.bucket_ticks(
                max(s.processable() for s in chosen), self.tick_granularity
            )
        return chosen, ticks
