"""Batched inference engine over the fused Pallas RSNN kernel.

This is the serving half of the paper's host↔accelerator split: where
:class:`repro.core.controller.OnlineLearner` drives ReckOn one sample at a
time (the FSM's READM → TICK → … → END_S walk), the engine drives the *same*
network as rectangular batch tiles — many AER streams decoded host-side
(:func:`repro.serve.batching.decode_events_host`), bucketed by tick length
(:class:`repro.serve.scheduler.BucketingScheduler`), and pushed through one
jit-compiled forward per ``(T, B)`` tile shape.

Two numerically-identical backends:

* ``"kernel"`` — the fused Pallas tick kernel
  (:func:`repro.kernels.rsnn_step.rsnn_forward` via
  :func:`repro.kernels.ops.rsnn_forward`): whole network state VMEM-resident,
  two MXU matmuls per tick.  Compiled on TPU; interpreted elsewhere (which is
  how the parity tests run it on CPU).
* ``"scan"`` — the controller's own
  :func:`repro.core.eprop.run_sample_inference` ``lax.scan``, vectorized over
  the batch axis.  The CPU-native fast path; also the oracle the kernel
  backend is tested against.

``backend="auto"`` picks ``"kernel"`` on TPU and ``"scan"`` elsewhere.
Weights are jit *arguments*, not closure constants, so
:meth:`BatchedEngine.update_weights` (serving a network that is still
learning online) never recompiles.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eprop
from repro.core.rsnn import RSNNConfig, merge_trainable
from repro.kernels import ops
from repro.serve import batching
from repro.serve.scheduler import BatchTile, BucketingScheduler


@dataclasses.dataclass
class ServeResult:
    """Per-request classification + accounting."""

    rid: int
    pred: int                 # argmax class
    logits: np.ndarray        # accumulated LI readout acc_y, shape (n_out,)
    label: int                # label carried by the AER stream (0 if absent)
    latency_s: float          # admission → tile completion
    bucket_ticks: int         # padded tick length served at
    batch_size: int           # live samples in the tile


@dataclasses.dataclass
class ServeStats:
    requests: int
    batches: int
    wall_s: float
    samples_per_sec: float
    p50_latency_s: float
    p99_latency_s: float
    mean_batch: float
    compiled_shapes: int

    @classmethod
    def collect(
        cls, results: List[ServeResult], wall_s: float, batches: int, shapes: int
    ) -> "ServeStats":
        lat = np.array([r.latency_s for r in results]) if results else np.zeros(1)
        return cls(
            requests=len(results),
            batches=batches,
            wall_s=wall_s,
            samples_per_sec=len(results) / wall_s if wall_s > 0 else float("inf"),
            p50_latency_s=float(np.percentile(lat, 50)),
            p99_latency_s=float(np.percentile(lat, 99)),
            mean_batch=(len(results) / batches) if batches else 0.0,
            compiled_shapes=shapes,
        )


class BatchedEngine:
    """Batched AER classification service for one :class:`RSNNConfig` network.

    Parameters
    ----------
    cfg:
        The network the weights belong to (e.g. ``Presets.braille(...)``).
    params:
        ``{"w_in", "w_rec", "w_out"}`` (+ optional scalar ``"alpha"``) — the
        same pytree :class:`~repro.core.controller.OnlineLearner` trains.
    backend:
        ``"kernel" | "scan" | "auto"`` (see module docstring).
    max_batch:
        Batch-tile cap; defaults to the VMEM budget
        (:func:`repro.serve.batching.max_batch_for`).
    """

    def __init__(
        self,
        cfg: RSNNConfig,
        params: Dict[str, jax.Array],
        *,
        backend: str = "auto",
        max_batch: Optional[int] = None,
        tick_granularity: int = 32,
        vmem_budget: int = batching.DEFAULT_VMEM_BUDGET,
        clock: Callable[[], float] = time.monotonic,
    ):
        if backend == "auto":
            backend = "kernel" if jax.default_backend() == "tpu" else "scan"
        assert backend in ("kernel", "scan"), backend
        self.cfg = cfg
        self.backend = backend
        self.max_batch = max_batch or batching.max_batch_for(cfg, vmem_budget)
        assert self.max_batch <= batching.KERNEL_SAMPLE_CAP
        self.tick_granularity = tick_granularity
        self._clock = clock
        self._alpha = float(np.asarray(params.get("alpha", cfg.neuron.alpha)))
        self._weights = {
            k: jnp.asarray(params[k]) for k in ("w_in", "w_rec", "w_out")
        }
        self._fwd_cache: Dict[Tuple[int, int], Callable] = {}
        self.scheduler = BucketingScheduler(
            self.max_batch, tick_granularity, clock=clock
        )

    @classmethod
    def from_learner(cls, learner, **kw) -> "BatchedEngine":
        """Serve an :class:`~repro.core.controller.OnlineLearner`'s network."""
        return cls(learner.cfg, learner.inference_params(), **kw)

    def update_weights(self, weights: Dict[str, jax.Array]) -> None:
        """Swap in newly-trained weights (no recompilation — weights are
        jit arguments)."""
        self._weights = {
            k: jnp.asarray(weights[k]) for k in ("w_in", "w_rec", "w_out")
        }

    # ---------------------------------------------------------------- forward

    def _rec_mask(self) -> jnp.ndarray:
        if self.cfg.eprop.mask_self_recurrence:
            return 1.0 - jnp.eye(self.cfg.n_hid, dtype=jnp.float32)
        return jnp.ones((self.cfg.n_hid, self.cfg.n_hid), jnp.float32)

    def _forward(self, num_ticks: int, batch: int) -> Callable:
        """jit'd ``fn(weights, raster (T,B,N), valid (T,B)) -> acc_y (B,O)``,
        cached per tile shape."""
        key = (num_ticks, batch)
        fn = self._fwd_cache.get(key)
        if fn is not None:
            return fn
        ncfg, ecfg = self.cfg.neuron, self.cfg.eprop
        alpha = self._alpha
        rec_mask = self._rec_mask()

        if self.backend == "kernel":

            def raw(weights, raster, valid):
                out = ops.rsnn_forward(
                    raster,
                    weights["w_in"],
                    weights["w_rec"] * rec_mask,
                    weights["w_out"],
                    alpha=alpha,
                    kappa=ncfg.kappa,
                    v_th=ncfg.v_th,
                    reset=ncfg.reset,
                    boxcar_width=ncfg.boxcar_width,
                )
                w_inf = (
                    valid[..., None]
                    if ecfg.infer_window == "valid"
                    else jnp.ones_like(valid)[..., None]
                )
                return (out["y"] * w_inf).sum(axis=0)

        else:

            def raw(weights, raster, valid):
                params = merge_trainable(
                    {"alpha": jnp.asarray(alpha, raster.dtype)}, weights
                )
                return eprop.run_sample_inference(params, raster, valid, ncfg, ecfg)[
                    "acc_y"
                ]

        fn = jax.jit(raw)
        self._fwd_cache[key] = fn
        return fn

    # ----------------------------------------------------------------- serving

    def run_tile(self, tile: BatchTile) -> List[ServeResult]:
        """Decode, pad, classify one batch tile; per-request results."""
        events = [r.events for r in tile.requests]
        raster, valid, labels = batching.decode_events_host(
            events, self.cfg.n_in, tile.num_ticks, self.cfg.label_delay
        )
        b_live = len(events)
        b_pad = batching.padded_batch_size(b_live, self.max_batch)
        raster, valid = batching.pad_batch(raster, valid, b_pad)
        fn = self._forward(tile.num_ticks, b_pad)
        acc_y = fn(self._weights, jnp.asarray(raster), jnp.asarray(valid))
        acc_y = np.asarray(jax.block_until_ready(acc_y))[:b_live]
        t_done = self._clock()
        return [
            ServeResult(
                rid=req.rid,
                pred=int(np.argmax(acc_y[i])),
                logits=acc_y[i],
                label=int(labels[i]),
                latency_s=t_done - req.t_submit,
                bucket_ticks=tile.num_ticks,
                batch_size=b_live,
            )
            for i, req in enumerate(tile.requests)
        ]

    def submit(self, events: np.ndarray, meta: Optional[dict] = None) -> int:
        return self.scheduler.submit(events, meta)

    def serve(
        self, stream: Iterable[np.ndarray], flush: bool = True
    ) -> Tuple[List[ServeResult], ServeStats]:
        """Run a whole stream of AER sample buffers; results in admission
        (rid) order plus throughput/latency stats.

        Tiles are released as soon as a bucket fills (steady-state batching);
        ``flush`` drains the partial buckets at end-of-stream.
        """
        t0 = self._clock()
        results: List[ServeResult] = []
        batches = 0
        for events in stream:
            self.submit(events)
            for tile in self.scheduler.ready_tiles():
                results.extend(self.run_tile(tile))
                batches += 1
        if flush:
            for tile in self.scheduler.drain():
                results.extend(self.run_tile(tile))
                batches += 1
        wall = self._clock() - t0
        results.sort(key=lambda r: r.rid)
        stats = ServeStats.collect(results, wall, batches, len(self._fwd_cache))
        return results, stats

    def warmup(self, num_ticks: int, batch: Optional[int] = None) -> None:
        """Pre-compile the forward for one tile shape (excluded-from-bench
        compile time; also useful before latency-sensitive serving)."""
        b = batching.padded_batch_size(batch or self.max_batch, self.max_batch)
        t = batching.bucket_ticks(num_ticks, self.tick_granularity)
        fn = self._forward(t, b)
        raster = jnp.zeros((t, b, self.cfg.n_in), jnp.float32)
        valid = jnp.ones((t, b), jnp.float32)
        jax.block_until_ready(fn(self._weights, raster, valid))
