"""Batched inference engine over the shared execution backend.

This is the serving half of the paper's host↔accelerator split: where
:class:`repro.core.controller.OnlineLearner` drives ReckOn sample-by-sample
or batch-by-batch through an
:class:`~repro.core.backend.ExecutionBackend`, the engine drives the *same*
backend object as rectangular inference tiles — many AER streams decoded
host-side (:func:`repro.serve.batching.decode_events_host`), bucketed by
tick length (:class:`repro.serve.scheduler.BucketingScheduler`), and pushed
through one compiled forward per ``(T, B)`` tile shape.

Backend dispatch (``"kernel"`` = fused Pallas kernels, ``"scan"`` = the
reference ``lax.scan``, ``"auto"`` = kernel on TPU / scan elsewhere) lives in
:mod:`repro.core.backend`, not here; the engine just submits tiles.  Weights
are jit *arguments*, not closure constants, so
:meth:`BatchedEngine.update_weights` (serving a network that is still
learning online) never recompiles — and because an
:class:`~repro.core.backend.ExecutionBackend` instance can be passed in
directly (``BatchedEngine.from_learner`` does exactly that), the engine and
a live :class:`~repro.core.controller.OnlineLearner` share one jit cache:
train, swap weights, serve, no recompile.

Quantized serving: when the backend runs the hardware-equivalence mode
(``cfg.neuron.quant`` / ``ExecutionBackend(quant=...)``), the engine is the
software twin of the FPGA serving path — every tile executes ReckOn's
fixed-point datapath, ``update_weights`` snaps incoming weights onto the
8-bit SRAM grid (the "SRAM load", so serving a float learner's live master
weights is still well-defined), and returned logits are the chip's
membrane-grid readout accumulators (argmax unchanged).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import BackendLike, as_backend
from repro.core.rsnn import RSNNConfig
from repro.kernels import traffic
from repro.serve import batching
from repro.serve.scheduler import BatchTile, BucketingScheduler


@dataclasses.dataclass
class ServeResult:
    """Per-request classification + accounting."""

    rid: int
    pred: int                 # argmax class
    logits: np.ndarray        # accumulated LI readout acc_y, shape (n_out,)
    label: int                # label carried by the AER stream (0 if absent)
    latency_s: float          # admission → result delivery (harvest); see
                              # BatchedEngine.serve — delivery lag behind
                              # device completion is bounded by the polling
                              # cadence and max_inflight_tiles
    bucket_ticks: int         # padded tick length served at
    batch_size: int           # live samples in the tile


@dataclasses.dataclass
class _PendingTile:
    """A launched-but-unsynchronised batch tile: the device is still (or may
    still be) computing ``acc_y`` while the host moves on to later buckets."""

    acc_y: jax.Array          # (b_pad, n_out) device array, possibly in flight
    labels: np.ndarray
    tile: BatchTile
    b_live: int

    def ready(self) -> bool:
        """Non-blocking readiness probe (conservative where unsupported)."""
        is_ready = getattr(self.acc_y, "is_ready", None)
        return bool(is_ready()) if callable(is_ready) else False


@dataclasses.dataclass
class ServeStats:
    requests: int
    batches: int
    wall_s: float
    samples_per_sec: float
    p50_latency_s: float
    p99_latency_s: float
    mean_batch: float
    compiled_shapes: int
    # Analytic HBM bytes the served tiles streamed when the kernel backend
    # runs (:func:`repro.kernels.traffic.infer_fused_bytes` — one (B, O)
    # logits tile per batch instead of seven (T, B, ·) tensors); 0 on the
    # scan backend, which runs no Pallas tile.
    hbm_bytes_streamed: int = 0

    @classmethod
    def collect(
        cls,
        results: List[ServeResult],
        wall_s: float,
        batches: int,
        shapes: int,
        hbm_bytes: int = 0,
    ) -> "ServeStats":
        lat = np.array([r.latency_s for r in results]) if results else np.zeros(1)
        return cls(
            requests=len(results),
            batches=batches,
            wall_s=wall_s,
            samples_per_sec=len(results) / wall_s if wall_s > 0 else float("inf"),
            p50_latency_s=float(np.percentile(lat, 50)),
            p99_latency_s=float(np.percentile(lat, 99)),
            mean_batch=(len(results) / batches) if batches else 0.0,
            compiled_shapes=shapes,
            hbm_bytes_streamed=hbm_bytes,
        )


class BatchedEngine:
    """Batched AER classification service for one :class:`RSNNConfig` network.

    Parameters
    ----------
    cfg:
        The network the weights belong to (e.g. ``Presets.braille(...)``).
    params:
        ``{"w_in", "w_rec", "w_out"}`` (+ optional scalar ``"alpha"``) — the
        same pytree :class:`~repro.core.controller.OnlineLearner` trains.
    backend:
        ``"kernel" | "scan" | "auto"``, or an existing
        :class:`~repro.core.backend.ExecutionBackend` to share its jit cache
        (the online-learning-while-serving configuration).
    max_batch:
        Admission size per tile; defaults to one full per-device kernel tile
        times the data-parallel device count
        (:func:`repro.serve.batching.max_batch_for`).  The kernels batch-tile
        internally, so this is a scheduling knob, not a VMEM cap.
    mesh:
        Data-parallel serving: a mesh whose data axes the backend shards
        every inference tile's sample axis over (weights replicated) —
        admission scales with the device count.
    """

    def __init__(
        self,
        cfg: RSNNConfig,
        params: Dict[str, jax.Array],
        *,
        backend: BackendLike = "auto",
        max_batch: Optional[int] = None,
        tick_granularity: int = 32,
        vmem_budget: Optional[int] = None,
        mesh=None,
        max_inflight_tiles: int = 8,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.cfg = cfg
        alpha = float(np.asarray(params.get("alpha", cfg.neuron.alpha)))
        self.engine = as_backend(
            cfg, backend, alpha=alpha, vmem_budget=vmem_budget, mesh=mesh
        )
        self.backend = self.engine.backend
        # Size admission and traffic accounting from the budget the backend
        # actually tiles with — a shared backend (from_learner) keeps its own
        # (as_backend asserts if the caller explicitly passed a different one).
        budget = self.engine.vmem_budget
        self.max_batch = max_batch or batching.max_batch_for(
            cfg, budget, num_devices=self.engine.num_devices
        )
        # per-kernel-tile rows, for the analytic HBM traffic accounting
        self._tile_rows = batching.max_batch_for(cfg, budget)
        self.tick_granularity = tick_granularity
        # Backpressure for the deferred-sync serve loop: at most this many
        # launched-but-unharvested tiles (each pins its raster + acc_y device
        # buffers) before the host blocks on the oldest.
        self.max_inflight_tiles = max(1, int(max_inflight_tiles))
        self._clock = clock
        self._bytes_streamed = 0
        # Quantized SRAM loads go through one jit'd snap program; on
        # accelerator backends it donates the engine's previous SRAM image so
        # update_weights reuses those buffers instead of copying every swap.
        # (CPU has no buffer donation — donating there only emits warnings.)
        donate = jax.default_backend() in ("tpu", "gpu")
        self._jit_sram_load = jax.jit(
            self._sram_load_impl, donate_argnums=(1,) if donate else ()
        )
        self.update_weights(params)
        self.scheduler = BucketingScheduler(
            self.max_batch, tick_granularity, clock=clock
        )

    @property
    def quantized(self) -> bool:
        """True when tiles execute the fixed-point hardware-equivalence
        datapath (logits are then membrane-grid integers)."""
        return self.engine.quant is not None

    def _sram(self, k: str, v: jax.Array) -> jax.Array:
        """What the engine actually holds per weight: the 8-bit SRAM grid
        value in quantized mode (the datapath would re-snap anyway — this
        makes ``_weights`` observable as the SRAM image), raw otherwise.
        Feedback matrices (``b_fb``) are not SRAM words and pass through."""
        q = self.engine.quant
        if q is None or k == "b_fb":
            return jnp.asarray(v)
        return q.weight_spec.round_nearest(jnp.asarray(v))

    @classmethod
    def from_learner(cls, learner, **kw) -> "BatchedEngine":
        """Serve an :class:`~repro.core.controller.OnlineLearner`'s network
        through the learner's own execution backend — shared jit cache, so
        ``update_weights(learner.weights)`` mid-training re-uses the exact
        programs the learner compiled (and vice versa)."""
        kw.setdefault("backend", learner.backend)
        return cls(learner.cfg, learner.inference_params(), **kw)

    def _sram_load_impl(self, weights, old_weights):
        """One jit'd SRAM load.  ``old_weights`` — the engine's previous
        SRAM image — is donated on accelerator backends so the snapped
        output lands in the old buffers (no per-swap weight copies)."""
        del old_weights  # only donated for its buffers
        return {k: self._sram(k, v) for k, v in weights.items()}

    def update_weights(self, weights: Dict[str, jax.Array]) -> None:
        """Swap in newly-trained weights (no recompilation — weights are
        jit arguments).  In quantized mode this is the SRAM load: weights
        are snapped onto the 8-bit grid, through a jit'd program that
        donates (and thus reuses) the previous SRAM image's buffers."""
        new = {
            k: v for k, v in weights.items()
            if k in ("w_in", "w_rec", "w_out", "b_fb")
        }
        if self.engine.quant is None:
            # float mode: no snap, no copy — the engine aliases the caller's
            # (device-resident) arrays directly
            self._weights = {k: jnp.asarray(v) for k, v in new.items()}
            return
        old = getattr(self, "_weights", None)
        if old is not None and set(old) == set(new):
            self._weights = self._jit_sram_load(new, old)
        else:
            self._weights = {k: self._sram(k, v) for k, v in new.items()}

    # ----------------------------------------------------------------- serving

    def _launch_tile(self, tile: BatchTile) -> "_PendingTile":
        """Decode, pad and *launch* one batch tile — returns without
        synchronising on the device so consecutive buckets overlap host
        decode with device compute."""
        events = [r.events for r in tile.requests]
        raster, valid, labels = batching.decode_events_host(
            events, self.cfg.n_in, tile.num_ticks, self.cfg.label_delay
        )
        b_live = len(events)
        b_pad = batching.padded_batch_size(b_live, self.max_batch)
        raster, valid = batching.pad_batch(raster, valid, b_pad)
        if self.backend == "kernel":
            # analytic accounting for the inference-specialized kernel; the
            # scan backend runs no Pallas tile, so no bytes are attributed.
            # With a data mesh, every device fetches its own replicated
            # weight set and runs its (shard-padded) slice of the batch.
            ndev = self.engine.num_devices
            shard_b = -(-b_pad // ndev)
            self._bytes_streamed += ndev * traffic.infer_fused_tiled_bytes(
                tile.num_ticks, shard_b, self.cfg.n_in, self.cfg.n_hid,
                self.cfg.n_out, batch_tile=self._tile_rows,
            )
        out = self.engine.inference(
            self._weights, jnp.asarray(raster), jnp.asarray(valid)
        )
        return _PendingTile(
            acc_y=out["acc_y"], labels=labels, tile=tile, b_live=b_live
        )

    def _finalize(self, pending: "_PendingTile") -> List[ServeResult]:
        """Materialise one launched tile's results (synchronises on it)."""
        acc_y = np.asarray(pending.acc_y)[: pending.b_live]
        t_done = self._clock()
        return [
            ServeResult(
                rid=req.rid,
                pred=int(np.argmax(acc_y[i])),
                logits=acc_y[i],
                label=int(pending.labels[i]),
                latency_s=t_done - req.t_submit,
                bucket_ticks=pending.tile.num_ticks,
                batch_size=pending.b_live,
            )
            for i, req in enumerate(pending.tile.requests)
        ]

    def run_tile(self, tile: BatchTile) -> List[ServeResult]:
        """Decode, pad, classify one batch tile; per-request results."""
        return self._finalize(self._launch_tile(tile))

    def submit(self, events: np.ndarray, meta: Optional[dict] = None) -> int:
        return self.scheduler.submit(events, meta)

    def serve(
        self, stream: Iterable[np.ndarray], flush: bool = True
    ) -> Tuple[List[ServeResult], ServeStats]:
        """Run a whole stream of AER sample buffers; results in admission
        (rid) order plus throughput/latency stats.

        Tiles are *launched* as soon as a bucket fills (steady-state
        batching) but the host never blocks on them mid-stream: results are
        harvested opportunistically as their device buffers become ready and
        the one mandatory synchronisation happens at the end-of-stream drain
        — host decode of bucket ``k+1`` overlaps device compute of bucket
        ``k``.  ``flush`` drains the partial buckets at end-of-stream.
        """
        t0 = self._clock()
        self._bytes_streamed = 0
        results: List[ServeResult] = []
        pending: List[_PendingTile] = []
        batches = 0

        def harvest(block: bool) -> None:
            while pending and (block or pending[0].ready()):
                results.extend(self._finalize(pending.pop(0)))

        for events in stream:
            self.submit(events)
            for tile in self.scheduler.ready_tiles():
                pending.append(self._launch_tile(tile))
                batches += 1
            harvest(block=False)
            while len(pending) > self.max_inflight_tiles:
                # backpressure: the device fell behind — block on the oldest
                # tile so in-flight buffers stay bounded
                results.extend(self._finalize(pending.pop(0)))
        if flush:
            for tile in self.scheduler.drain():
                pending.append(self._launch_tile(tile))
                batches += 1
        harvest(block=True)   # the single per-drain sync
        wall = self._clock() - t0
        results.sort(key=lambda r: r.rid)
        stats = ServeStats.collect(
            results, wall, batches, self.engine.compiled_shapes("inference"),
            hbm_bytes=self._bytes_streamed,
        )
        return results, stats

    def warmup(self, num_ticks: int, batch: Optional[int] = None) -> None:
        """Pre-compile the forward for one tile shape (excluded-from-bench
        compile time; also useful before latency-sensitive serving)."""
        b = batching.padded_batch_size(batch or self.max_batch, self.max_batch)
        t = batching.bucket_ticks(num_ticks, self.tick_granularity)
        raster = jnp.zeros((t, b, self.cfg.n_in), jnp.float32)
        valid = jnp.ones((t, b), jnp.float32)
        jax.block_until_ready(
            self.engine.inference(self._weights, raster, valid)["acc_y"]
        )
