"""Session-first serving engine over the shared execution backend.

This is the serving half of the paper's host↔accelerator split: where
:class:`repro.core.controller.OnlineLearner` drives ReckOn sample-by-sample
or batch-by-batch through an
:class:`~repro.core.backend.ExecutionBackend`, the engine drives the *same*
backend object for unbounded AER event *streams* — the paper's neuromorphic
edge scenario, where per-user traffic never arrives as whole padded
samples.

The primary model is the **session**: ``engine.open_session()`` returns a
:class:`SessionHandle`; ``handle.feed(events)`` appends AER words to the
stream; the engine's pump packs whichever sessions have processable ticks
into fixed-shape tick-tiles (:class:`repro.serve.scheduler.StreamPacker` —
continuous batching), gathers their device-resident carry state from the
:class:`repro.serve.session.SessionPool`, launches the backend's
``step_sessions`` op (carry in / carry out) and scatters updated state
back; ``handle.poll()`` returns incremental readout snapshots and
``handle.result()`` the final classification.  The historical whole-sample
path (``submit()`` / ``serve()`` over complete event buffers, bucketed by
:class:`repro.serve.scheduler.BucketingScheduler`) is retained as a thin
open-feed-close wrapper over the same session machinery — existing callers
run unmodified, with identical results.

Backend dispatch (``"kernel"`` = fused Pallas kernels, ``"scan"`` = the
reference ``lax.scan``, ``"auto"`` = kernel on TPU / scan elsewhere) lives in
:mod:`repro.core.backend`, not here; the engine just submits tiles.  Weights
are jit *arguments*, not closure constants, so
:meth:`BatchedEngine.update_weights` (serving a network that is still
learning online) never recompiles — and because an
:class:`~repro.core.backend.ExecutionBackend` instance can be passed in
directly (``BatchedEngine.from_learner`` does exactly that), the engine and
a live :class:`~repro.core.controller.OnlineLearner` share one jit cache:
train, swap weights, serve, no recompile.

Quantized serving: when the backend runs the hardware-equivalence mode
(``cfg.neuron.quant`` / ``ExecutionBackend(quant=...)``), the engine is the
software twin of the FPGA serving path — every tile executes ReckOn's
fixed-point datapath, ``update_weights`` snaps incoming weights onto the
8-bit SRAM grid (the "SRAM load", so serving a float learner's live master
weights is still well-defined), and returned logits are the chip's
membrane-grid readout accumulators (argmax unchanged).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import BackendLike, RuntimeConfig, as_backend
from repro.core.rsnn import RSNNConfig
from repro.kernels import traffic
from repro.serve import batching
from repro.serve.scheduler import BatchTile, BucketingScheduler, StreamPacker
from repro.serve.session import SessionPool, SessionSnapshot, _Session


@dataclasses.dataclass
class ServeResult:
    """Per-request classification + accounting."""

    rid: int
    pred: int                 # argmax class
    logits: np.ndarray        # accumulated LI readout acc_y, shape (n_out,)
    label: int                # label carried by the AER stream (0 if absent)
    latency_s: float          # admission → result delivery (harvest); see
                              # BatchedEngine.serve — delivery lag behind
                              # device completion is bounded by the polling
                              # cadence and max_inflight_tiles
    bucket_ticks: int         # padded tick length served at
    batch_size: int           # live samples in the tile


@dataclasses.dataclass
class _PendingTile:
    """A launched-but-unsynchronised batch tile: the device is still (or may
    still be) computing ``acc_y`` while the host moves on to later buckets."""

    acc_y: jax.Array          # (b_pad, n_out) device array, possibly in flight
    labels: np.ndarray
    tile: BatchTile
    b_live: int

    def ready(self) -> bool:
        """Non-blocking readiness probe (conservative where unsupported)."""
        is_ready = getattr(self.acc_y, "is_ready", None)
        return bool(is_ready()) if callable(is_ready) else False


@dataclasses.dataclass
class ServeStats:
    requests: int
    batches: int
    wall_s: float
    samples_per_sec: float
    p50_latency_s: float
    p99_latency_s: float
    mean_batch: float
    compiled_shapes: int
    # Analytic HBM bytes the served tiles streamed when the kernel backend
    # runs (:func:`repro.kernels.traffic.infer_fused_bytes` — one (B, O)
    # logits tile per batch instead of seven (T, B, ·) tensors); 0 on the
    # scan backend, which runs no Pallas tile.
    hbm_bytes_streamed: int = 0

    @classmethod
    def collect(
        cls,
        results: List[ServeResult],
        wall_s: float,
        batches: int,
        shapes: int,
        hbm_bytes: int = 0,
    ) -> "ServeStats":
        lat = np.array([r.latency_s for r in results]) if results else np.zeros(1)
        return cls(
            requests=len(results),
            batches=batches,
            wall_s=wall_s,
            samples_per_sec=len(results) / wall_s if wall_s > 0 else float("inf"),
            p50_latency_s=float(np.percentile(lat, 50)),
            p99_latency_s=float(np.percentile(lat, 99)),
            mean_batch=(len(results) / batches) if batches else 0.0,
            compiled_shapes=shapes,
            hbm_bytes_streamed=hbm_bytes,
        )


@dataclasses.dataclass
class _PendingStreamTile:
    """A launched-but-unharvested streaming tick-tile: the device may still
    be computing while the host packs the next tile."""

    acc_y: jax.Array                 # (b_pad, n_out) post-chunk accumulators
    lanes: List[Tuple["_Session", int, int]]   # (session, ticks, events) at launch
    t_launch: float
    num_ticks: int

    def ready(self) -> bool:
        is_ready = getattr(self.acc_y, "is_ready", None)
        return bool(is_ready()) if callable(is_ready) else False


@dataclasses.dataclass
class StreamStats:
    """Streaming-serving throughput/latency accounting (one pump window)."""

    sessions: int                 # sessions that advanced in the window
    tiles: int                    # tick-tiles launched
    events: int                   # spike events consumed
    ticks: int                    # live session-ticks advanced (Σ chunk lengths)
    wall_s: float
    events_per_sec: float
    ticks_per_sec: float
    p50_tile_latency_s: float     # launch → harvest per tick-tile
    p99_tile_latency_s: float
    mean_lanes: float             # live lanes per tile (packing efficiency)
    evictions: int
    readmissions: int
    compiled_shapes: int          # distinct step_sessions (T, B) programs
    hbm_bytes_streamed: int = 0


class SessionHandle:
    """The public face of one open stream (from ``engine.open_session()``).

    ``feed`` appends AER words (ticks non-decreasing across feeds — the
    stream contract); the engine processes them when its pump next packs
    this session into a tick-tile (``engine.pump()``, or implicitly via
    :meth:`result`).  ``poll`` is non-blocking and returns the latest
    harvested :class:`~repro.serve.session.SessionSnapshot` (or ``None``);
    ``result`` closes the stream, drains every pending tick and returns the
    final snapshot; ``close`` abandons the stream and frees its pool slot.
    """

    def __init__(self, engine: "BatchedEngine", sess: _Session):
        self._engine = engine
        self._sess = sess

    @property
    def sid(self) -> int:
        return self._sess.sid

    @property
    def closed(self) -> bool:
        return self._sess.closed

    def feed(self, events: np.ndarray) -> int:
        """Append one AER word buffer; returns spike events admitted.  Does
        not launch work — call ``engine.pump()`` (or :meth:`result`) to
        advance."""
        return self._engine._feed(self._sess, events)

    def poll(self) -> Optional[SessionSnapshot]:
        """Latest incremental readout snapshot, non-blocking."""
        self._engine._harvest_stream(block=False)
        return self._sess.snapshot

    def result(self) -> SessionSnapshot:
        """Close the stream, process every fed tick, return the final
        classification (synchronises)."""
        return self._engine._finish_session(self._sess)

    def close(self) -> None:
        """Abandon the stream: unprocessed events are dropped and the pool
        slot is freed.  Use :meth:`result` to finish instead."""
        self._engine._abandon_session(self._sess)


class BatchedEngine:
    """Batched AER classification service for one :class:`RSNNConfig` network.

    Parameters
    ----------
    cfg:
        The network the weights belong to (e.g. ``Presets.braille(...)``).
    params:
        ``{"w_in", "w_rec", "w_out"}`` (+ optional scalar ``"alpha"``) — the
        same pytree :class:`~repro.core.controller.OnlineLearner` trains.
    backend:
        ``"kernel" | "scan" | "auto"``, or an existing
        :class:`~repro.core.backend.ExecutionBackend` to share its jit cache
        (the online-learning-while-serving configuration).
    max_batch:
        Admission size per tile; defaults to one full per-device kernel tile
        times the data-parallel device count
        (:func:`repro.serve.batching.max_batch_for`).  The kernels batch-tile
        internally, so this is a scheduling knob, not a VMEM cap.
    mesh:
        Data-parallel serving: a mesh whose data axes the backend shards
        every inference tile's sample axis over (weights replicated) —
        admission scales with the device count.
    max_sessions:
        Streaming capacity ``S_cap`` — resident sessions the device pool
        holds; defaults to :func:`repro.serve.batching.max_sessions_for`'s
        byte-budget sizing.  Sessions beyond it are LRU-evicted to host
        memory (bit-exact) and readmitted on their next packed tile.
    idle_timeout:
        Seconds of inactivity after which a resident session is offloaded
        (``None`` disables the sweep).
    tick_tile:
        Fixed tick length of streaming tiles (latency-bounded mode).  When
        ``None``, each packed tile drains everything its sessions have
        pending (throughput mode — also what the whole-sample ``serve()``
        wrapper uses).
    runtime:
        A :class:`~repro.core.backend.RuntimeConfig` bundling the
        backend/quant/vmem_budget/mesh knobs (the loose kwargs remain as a
        deprecated passthrough; resolution happens in ``as_backend``).
    """

    def __init__(
        self,
        cfg: RSNNConfig,
        params: Dict[str, jax.Array],
        *,
        backend: BackendLike = "auto",
        max_batch: Optional[int] = None,
        tick_granularity: int = 32,
        vmem_budget: Optional[int] = None,
        mesh=None,
        max_inflight_tiles: int = 8,
        clock: Callable[[], float] = time.monotonic,
        max_sessions: Optional[int] = None,
        idle_timeout: Optional[float] = None,
        tick_tile: Optional[int] = None,
        runtime: Optional[RuntimeConfig] = None,
    ):
        self.cfg = cfg
        alpha = float(np.asarray(params.get("alpha", cfg.neuron.alpha)))
        self.engine = as_backend(
            cfg, backend, alpha=alpha, vmem_budget=vmem_budget, mesh=mesh,
            runtime=runtime,
        )
        self.backend = self.engine.backend
        # Size admission and traffic accounting from the budget the backend
        # actually tiles with — a shared backend (from_learner) keeps its own
        # (as_backend asserts if the caller explicitly passed a different one).
        budget = self.engine.vmem_budget
        self.max_batch = max_batch or batching.max_batch_for(
            cfg, budget, num_devices=self.engine.num_devices
        )
        # per-kernel-tile rows, for the analytic HBM traffic accounting
        self._tile_rows = batching.max_batch_for(cfg, budget)
        self.tick_granularity = tick_granularity
        # Backpressure for the deferred-sync serve loop: at most this many
        # launched-but-unharvested tiles (each pins its raster + acc_y device
        # buffers) before the host blocks on the oldest.
        self.max_inflight_tiles = max(1, int(max_inflight_tiles))
        self._clock = clock
        self._bytes_streamed = 0
        # Quantized SRAM loads go through one jit'd snap program; on
        # accelerator backends it donates the engine's previous SRAM image so
        # update_weights reuses those buffers instead of copying every swap.
        # (CPU has no buffer donation — donating there only emits warnings.)
        donate = jax.default_backend() in ("tpu", "gpu")
        self._jit_sram_load = jax.jit(
            self._sram_load_impl, donate_argnums=(1,) if donate else ()
        )
        self.update_weights(params)
        self.scheduler = BucketingScheduler(
            self.max_batch, tick_granularity, clock=clock
        )
        # ---- streaming session machinery -------------------------------
        # Pool capacity must seat one full tile of sessions at once; the
        # trash row on top keeps gather/scatter shapes fixed.
        capacity = max(
            max_sessions or batching.max_sessions_for(cfg), self.max_batch
        )
        self.pool = SessionPool(
            self.engine, capacity, idle_timeout=idle_timeout, clock=clock
        )
        self.packer = StreamPacker(
            self.max_batch, tick_tile=tick_tile,
            tick_granularity=tick_granularity,
        )
        self._sessions: Dict[int, _Session] = {}
        self._next_sid = 0
        self._zero_states: Dict[int, Dict[str, jax.Array]] = {}
        self._stream_pending: List[_PendingStreamTile] = []
        self._tile_lat: List[float] = []
        self._stream_tiles = 0
        self._stream_events = 0
        self._stream_ticks = 0
        self._stream_lanes = 0

    @property
    def quantized(self) -> bool:
        """True when tiles execute the fixed-point hardware-equivalence
        datapath (logits are then membrane-grid integers)."""
        return self.engine.quant is not None

    def _sram(self, k: str, v: jax.Array) -> jax.Array:
        """What the engine actually holds per weight: the 8-bit SRAM grid
        value in quantized mode (the datapath would re-snap anyway — this
        makes ``_weights`` observable as the SRAM image), raw otherwise.
        Feedback matrices (``b_fb``) are not SRAM words and pass through."""
        q = self.engine.quant
        if q is None or k == "b_fb":
            return jnp.asarray(v)
        return q.weight_spec.round_nearest(jnp.asarray(v))

    @classmethod
    def from_learner(cls, learner, **kw) -> "BatchedEngine":
        """Serve an :class:`~repro.core.controller.OnlineLearner`'s network
        through the learner's own execution backend — shared jit cache, so
        ``update_weights(learner.weights)`` mid-training re-uses the exact
        programs the learner compiled (and vice versa)."""
        kw.setdefault("backend", learner.backend)
        return cls(learner.cfg, learner.inference_params(), **kw)

    def _sram_load_impl(self, weights, old_weights):
        """One jit'd SRAM load.  ``old_weights`` — the engine's previous
        SRAM image — is donated on accelerator backends so the snapped
        output lands in the old buffers (no per-swap weight copies)."""
        del old_weights  # only donated for its buffers
        return {k: self._sram(k, v) for k, v in weights.items()}

    def update_weights(self, weights: Dict[str, jax.Array]) -> None:
        """Swap in newly-trained weights (no recompilation — weights are
        jit arguments).  In quantized mode this is the SRAM load: weights
        are snapped onto the 8-bit grid, through a jit'd program that
        donates (and thus reuses) the previous SRAM image's buffers."""
        new = {
            k: v for k, v in weights.items()
            if k in ("w_in", "w_rec", "w_out", "b_fb")
        }
        if self.engine.quant is None:
            # float mode: no snap, no copy — the engine aliases the caller's
            # (device-resident) arrays directly
            self._weights = {k: jnp.asarray(v) for k, v in new.items()}
            return
        old = getattr(self, "_weights", None)
        if old is not None and set(old) == set(new):
            self._weights = self._jit_sram_load(new, old)
        else:
            self._weights = {k: self._sram(k, v) for k, v in new.items()}

    # ----------------------------------------------------------------- serving

    def _launch_tile(self, tile: BatchTile) -> "_PendingTile":
        """Decode, pad and *launch* one batch tile — returns without
        synchronising on the device so consecutive buckets overlap host
        decode with device compute."""
        events = [r.events for r in tile.requests]
        raster, valid, labels = batching.decode_events_host(
            events, self.cfg.n_in, tile.num_ticks, self.cfg.label_delay
        )
        b_live = len(events)
        b_pad = batching.padded_batch_size(b_live, self.max_batch)
        raster, valid = batching.pad_batch(raster, valid, b_pad)
        if self.backend == "kernel":
            # analytic accounting for the inference-specialized kernel; the
            # scan backend runs no Pallas tile, so no bytes are attributed.
            # With a data mesh, every device fetches its own replicated
            # weight set and runs its (shard-padded) slice of the batch.
            ndev = self.engine.num_devices
            shard_b = -(-b_pad // ndev)
            self._bytes_streamed += ndev * traffic.infer_fused_tiled_bytes(
                tile.num_ticks, shard_b, self.cfg.n_in, self.cfg.n_hid,
                self.cfg.n_out, batch_tile=self._tile_rows,
            )
        out = self.engine.inference(
            self._weights, jnp.asarray(raster), jnp.asarray(valid)
        )
        return _PendingTile(
            acc_y=out["acc_y"], labels=labels, tile=tile, b_live=b_live
        )

    def _finalize(self, pending: "_PendingTile") -> List[ServeResult]:
        """Materialise one launched tile's results (synchronises on it)."""
        acc_y = np.asarray(pending.acc_y)[: pending.b_live]
        t_done = self._clock()
        return [
            ServeResult(
                rid=req.rid,
                pred=int(np.argmax(acc_y[i])),
                logits=acc_y[i],
                label=int(pending.labels[i]),
                latency_s=t_done - req.t_submit,
                bucket_ticks=pending.tile.num_ticks,
                batch_size=pending.b_live,
            )
            for i, req in enumerate(pending.tile.requests)
        ]

    def run_tile(self, tile: BatchTile) -> List[ServeResult]:
        """Decode, pad, classify one batch tile; per-request results."""
        return self._finalize(self._launch_tile(tile))

    def submit(self, events: np.ndarray, meta: Optional[dict] = None) -> int:
        return self.scheduler.submit(events, meta)

    # ---------------------------------------------------- session streaming

    def open_session(self, meta: Optional[dict] = None) -> SessionHandle:
        """Open one AER event stream with persistent recurrent state.

        The session's carry ``(v, z, y, acc_y, n_spk)`` lives in the
        device-resident :class:`~repro.serve.session.SessionPool` while hot
        (LRU-evicted to host bit-exactly under capacity pressure) — feed
        events in arbitrary increments; chunking never changes the result.
        """
        sess = _Session(self._next_sid, self._clock(), meta)
        sess.gate_label = self.cfg.eprop.infer_window == "valid"
        self._next_sid += 1
        self._sessions[sess.sid] = sess
        return SessionHandle(self, sess)

    def _feed(self, sess: _Session, events: np.ndarray) -> int:
        n = sess.feed(events)
        if sess.processable() > 0:
            self.packer.enqueue(sess)
        return n

    def _launch_chunks(self, sessions, chunks, num_ticks: int):
        """The shared streaming launch: seat sessions in the pool (one
        batched admission scatter), decode their chunks into one rectangular
        tick-tile, gather carries → ``step_sessions`` → scatter carries.
        Returns the backend's output state (device values, not synced)."""
        b_pad = batching.padded_batch_size(len(sessions), self.max_batch)
        raster, live, valid = batching.decode_session_chunks(
            chunks, self.cfg.n_in, num_ticks, self.cfg.label_delay,
            b_pad=b_pad,
        )
        slots, admit = self.pool.place(sessions)
        if admit is not None:
            self.pool.admit(admit)
        idx = self.pool.padded_slots(slots, b_pad)
        state = self.pool.gather(idx)
        out = self.engine.step_sessions(
            self._weights, jnp.asarray(raster), jnp.asarray(live),
            jnp.asarray(valid), state,
        )
        self.pool.scatter(idx, out)
        if self.backend == "kernel":
            ndev = self.engine.num_devices
            shard_b = -(-b_pad // ndev)
            self._bytes_streamed += ndev * traffic.stream_step_tiled_bytes(
                num_ticks, shard_b, self.cfg.n_in, self.cfg.n_hid,
                self.cfg.n_out, batch_tile=self._tile_rows,
            )
        self._stream_tiles += 1
        self._stream_lanes += len(sessions)
        self._stream_ticks += sum(c.n_live for c in chunks)
        self._stream_events += sum(len(c.sp_tick) for c in chunks)
        return out

    def _pump_once(self) -> bool:
        """Pack and launch one streaming tick-tile; False when no session
        has processable ticks."""
        nxt = self.packer.next_tile()
        if nxt is None:
            return False
        sessions, num_ticks = nxt
        chunks = [s.take_chunk(num_ticks) for s in sessions]
        out = self._launch_chunks(sessions, chunks, num_ticks)
        self._stream_pending.append(_PendingStreamTile(
            acc_y=out["acc_y"],
            lanes=[(s, s.cursor, s.n_events) for s in sessions],
            t_launch=self._clock(),
            num_ticks=num_ticks,
        ))
        for s in sessions:
            if s.processable() > 0:
                self.packer.enqueue(s)
        self._harvest_stream(block=False)
        while len(self._stream_pending) > self.max_inflight_tiles:
            self._harvest_one()   # backpressure: block on the oldest tile
        return True

    def pump(self, drain: bool = False) -> int:
        """Advance every open session through its pending ticks (continuous
        batching: tiles launch asynchronously, harvested opportunistically).
        ``drain`` additionally blocks until all launched tiles are
        harvested.  Returns the number of tiles launched."""
        n = 0
        while self._pump_once():
            n += 1
        self.pool.sweep()
        if drain:
            self._harvest_stream(block=True)
        return n

    def _harvest_one(self) -> None:
        p = self._stream_pending.pop(0)
        acc = np.asarray(p.acc_y)   # synchronises on this tile
        self._tile_lat.append(self._clock() - p.t_launch)
        for i, (sess, ticks, events) in enumerate(p.lanes):
            sess.snapshot = SessionSnapshot(
                sid=sess.sid, pred=int(np.argmax(acc[i])), logits=acc[i],
                label=sess.label, ticks=ticks, events=events,
            )

    def _harvest_stream(self, block: bool) -> None:
        while self._stream_pending and (block or self._stream_pending[0].ready()):
            self._harvest_one()

    def _session_acc(self, sess: _Session) -> np.ndarray:
        """A session's accumulated readout wherever it lives: pool row,
        offloaded host copy, or zeros for a never-run session.  Pool state
        chains on every launched tile, so this is exact without waiting for
        the harvest loop."""
        if sess.slot is not None:
            return np.asarray(self.pool.state["acc_y"][sess.slot])
        if sess.offloaded is not None:
            return np.asarray(sess.offloaded["acc_y"], np.float32)
        return np.zeros((self.cfg.n_out,), np.float32)

    def _finish_session(self, sess: _Session) -> SessionSnapshot:
        sess.closed = True   # extends the horizon to the last fed tick
        if sess.processable() > 0:
            self.packer.enqueue(sess)
        while sess.processable() > 0 and self._pump_once():
            pass
        self._harvest_stream(block=True)
        acc = self._session_acc(sess)
        snap = SessionSnapshot(
            sid=sess.sid, pred=int(np.argmax(acc)), logits=acc,
            label=sess.label, ticks=sess.cursor, events=sess.n_events,
            final=True,
        )
        sess.snapshot = snap
        self.pool.release(sess)
        self._sessions.pop(sess.sid, None)
        return snap

    def _abandon_session(self, sess: _Session) -> None:
        sess.closed = True
        self.pool.release(sess)
        self._sessions.pop(sess.sid, None)

    def reset_stream_stats(self) -> None:
        """Zero the streaming counters (start of a measurement window)."""
        self._tile_lat.clear()
        self._stream_tiles = 0
        self._stream_events = 0
        self._stream_ticks = 0
        self._stream_lanes = 0
        self._bytes_streamed = 0

    def stream_stats(self, wall_s: float) -> StreamStats:
        """Streaming counters since the last :meth:`reset_stream_stats`,
        normalised over the caller-measured wall window."""
        lat = np.array(self._tile_lat) if self._tile_lat else np.zeros(1)
        tiles = self._stream_tiles
        return StreamStats(
            sessions=len(self._sessions),
            tiles=tiles,
            events=self._stream_events,
            ticks=self._stream_ticks,
            wall_s=wall_s,
            events_per_sec=(
                self._stream_events / wall_s if wall_s > 0 else float("inf")
            ),
            ticks_per_sec=(
                self._stream_ticks / wall_s if wall_s > 0 else float("inf")
            ),
            p50_tile_latency_s=float(np.percentile(lat, 50)),
            p99_tile_latency_s=float(np.percentile(lat, 99)),
            mean_lanes=(self._stream_lanes / tiles) if tiles else 0.0,
            evictions=self.pool.evictions,
            readmissions=self.pool.readmissions,
            compiled_shapes=self.engine.compiled_shapes("step_sessions"),
            hbm_bytes_streamed=self._bytes_streamed,
        )

    # ----------------------------------------- whole-sample compat wrapper

    def _launch_session_tile(self, tile: BatchTile) -> "_PendingTile":
        """One whole-sample bucket tile executed through the session-step
        op as a single open-feed-close chunk, with
        :func:`~repro.serve.batching.decode_events_host` semantics exactly:
        the full bucketed tick length runs live (padding ticks advance
        dynamics like the old path) and an END-less buffer pins
        ``end_tick = 0``.

        Each request is a complete stream, so the tile is *stateless* —
        zero carries in (one cached pytree per tile width), carries out
        unobserved — and skips the session pool entirely: whole-sample
        serving pays no pool-sized scatter and no per-request host
        bookkeeping."""
        T = tile.num_ticks
        bufs = [req.events for req in tile.requests]
        b_pad = batching.padded_batch_size(len(bufs), self.max_batch)
        raster, valid, labels = batching.decode_events_host(
            bufs, self.cfg.n_in, T, self.cfg.label_delay
        )
        raster, valid = batching.pad_batch(raster, valid, b_pad)
        live = np.zeros((T, b_pad), np.float32)
        live[:, : len(bufs)] = 1.0
        out = self.engine.step_sessions(
            self._weights, jnp.asarray(raster), jnp.asarray(live),
            jnp.asarray(valid), self._zero_state(b_pad),
        )
        if self.backend == "kernel":
            ndev = self.engine.num_devices
            shard_b = -(-b_pad // ndev)
            self._bytes_streamed += ndev * traffic.stream_step_tiled_bytes(
                T, shard_b, self.cfg.n_in, self.cfg.n_hid, self.cfg.n_out,
                batch_tile=self._tile_rows,
            )
        self._stream_tiles += 1
        self._stream_lanes += len(bufs)
        self._stream_ticks += T * len(bufs)
        return _PendingTile(
            acc_y=out["acc_y"], labels=labels, tile=tile,
            b_live=len(bufs),
        )

    def _zero_state(self, b_pad: int):
        """Cached zero-carry pytree per tile width (a read-only jit input,
        so reusing it across launches is safe)."""
        st = self._zero_states.get(b_pad)
        if st is None:
            st = self._zero_states[b_pad] = self.engine.init_session_state(
                b_pad
            )
        return st

    def serve(
        self, stream: Iterable[np.ndarray], flush: bool = True
    ) -> Tuple[List[ServeResult], ServeStats]:
        """Run a whole stream of AER sample buffers; results in admission
        (rid) order plus throughput/latency stats.

        This is the whole-sample *compatibility wrapper* over the session
        runtime: each bucketed tile (same
        :class:`~repro.serve.scheduler.BucketingScheduler` determinism
        contract as ever) is executed open-feed-close through the session
        machinery — per-request sessions seated in the pool, one
        ``step_sessions`` launch, slots released — producing identical
        results to the historical whole-sample path.  Tiles are *launched*
        as soon as a bucket fills but the host never blocks on them
        mid-stream: results are harvested opportunistically as their device
        buffers become ready and the one mandatory synchronisation happens
        at the end-of-stream drain.  ``flush`` drains the partial buckets
        at end-of-stream.
        """
        t0 = self._clock()
        self._bytes_streamed = 0
        results: List[ServeResult] = []
        pending: List[_PendingTile] = []
        batches = 0

        def harvest(block: bool) -> None:
            while pending and (block or pending[0].ready()):
                results.extend(self._finalize(pending.pop(0)))

        for events in stream:
            self.submit(events)
            for tile in self.scheduler.ready_tiles():
                pending.append(self._launch_session_tile(tile))
                batches += 1
            harvest(block=False)
            while len(pending) > self.max_inflight_tiles:
                # backpressure: the device fell behind — block on the oldest
                # tile so in-flight buffers stay bounded
                results.extend(self._finalize(pending.pop(0)))
        if flush:
            for tile in self.scheduler.drain():
                pending.append(self._launch_session_tile(tile))
                batches += 1
        harvest(block=True)   # the single per-drain sync
        wall = self._clock() - t0
        results.sort(key=lambda r: r.rid)
        stats = ServeStats.collect(
            results, wall, batches,
            self.engine.compiled_shapes("step_sessions"),
            hbm_bytes=self._bytes_streamed,
        )
        return results, stats

    def warmup(self, num_ticks: int, batch: Optional[int] = None) -> None:
        """Pre-compile the forward programs for one tile shape
        (excluded-from-bench compile time; also useful before
        latency-sensitive serving).  Warms both the session-step program
        (the ``serve()``/streaming path) and the whole-sample inference
        program (the direct ``run_tile`` path)."""
        b = batching.padded_batch_size(batch or self.max_batch, self.max_batch)
        t = batching.bucket_ticks(num_ticks, self.tick_granularity)
        raster = jnp.zeros((t, b, self.cfg.n_in), jnp.float32)
        valid = jnp.ones((t, b), jnp.float32)
        jax.block_until_ready(
            self.engine.inference(self._weights, raster, valid)["acc_y"]
        )
        state = self.engine.init_session_state(b)
        jax.block_until_ready(
            self.engine.step_sessions(
                self._weights, raster, valid, valid, state
            )["acc_y"]
        )
