"""Session-first serving engine over the shared execution backend.

This is the serving half of the paper's host↔accelerator split: where
:class:`repro.core.controller.OnlineLearner` drives ReckOn sample-by-sample
or batch-by-batch through an
:class:`~repro.core.backend.ExecutionBackend`, the engine drives the *same*
backend object for unbounded AER event *streams* — the paper's neuromorphic
edge scenario, where per-user traffic never arrives as whole padded
samples.

The primary model is the **session**: ``engine.open_session()`` returns a
:class:`SessionHandle`; ``handle.feed(events)`` appends AER words to the
stream; the engine's pump packs whichever sessions have processable ticks
into fixed-shape tick-tiles (:class:`repro.serve.scheduler.StreamPacker` —
continuous batching), gathers their device-resident carry state from the
:class:`repro.serve.session.SessionPool`, launches the backend's
``step_sessions`` op (carry in / carry out) and scatters updated state
back; ``handle.poll()`` returns incremental readout snapshots and
``handle.result()`` the final classification.  The historical whole-sample
path (``submit()`` / ``serve()`` over complete event buffers, bucketed by
:class:`repro.serve.scheduler.BucketingScheduler`) is retained as a thin
open-feed-close wrapper over the same session machinery — existing callers
run unmodified, with identical results.

**Multi-model serving** (the paper's runtime reprogrammability — one
fabric, many SRAM programs): an engine constructed with ``registry=``
serves every model in a :class:`~repro.serve.registry.ModelRegistry`
concurrently.  Each registered model gets its own *lane* — scheduler,
stream packer and carry pool (state shapes differ per network) — so every
tile stays single-model, like one SRAM image per chip program; the pump
loop interleaves launches across lanes, and request ids stay unique and
admission-ordered engine-wide through one shared allocator.  ``submit``,
``open_session``, ``serve`` and ``warmup`` route by ``model_id``
(defaulting to the first registered model), results carry their model id,
and :class:`ServeStats`/:class:`StreamStats` break out per-model.  The
classic single-model constructor ``BatchedEngine(cfg, params)`` is the
one-lane special case: it builds a private registry under the
``"default"`` id.

Backend dispatch (``"kernel"`` = fused Pallas kernels, ``"scan"`` = the
reference ``lax.scan``, ``"auto"`` = kernel on TPU / scan elsewhere) lives in
:mod:`repro.core.backend`, not here; the engine just submits tiles.  Weights
are jit *arguments*, not closure constants, so
:meth:`BatchedEngine.update_weights` (serving a network that is still
learning online) never recompiles — and because an
:class:`~repro.core.backend.ExecutionBackend` instance can be passed in
directly (``BatchedEngine.from_learner`` does exactly that), the engine and
a live :class:`~repro.core.controller.OnlineLearner` share one jit cache:
train, swap weights, serve, no recompile.  Models whose configs fall in the
same execution bucket share one pooled backend, so a multi-model engine
compiles each tile shape once, not once per model.

Quantized serving: when the backend runs the hardware-equivalence mode
(``cfg.neuron.quant`` / ``ExecutionBackend(quant=...)``), the engine is the
software twin of the FPGA serving path — every tile executes ReckOn's
fixed-point datapath, ``update_weights`` snaps incoming weights onto the
8-bit SRAM grid (the "SRAM load", so serving a float learner's live master
weights is still well-defined), and returned logits are the chip's
membrane-grid readout accumulators (argmax unchanged).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import BackendLike, ExecutionBackend, RuntimeConfig
from repro.core.rsnn import RSNNConfig
from repro.kernels import traffic
from repro.serve import batching
from repro.serve.guard import (
    GuardConfig,
    GuardError,
    OverloadError,
    QuotaExceededError,
    ServeStatus,
    bad_rows,
    validate_events,
)
from repro.serve.registry import DEFAULT_MODEL, ModelRegistry, ModelSpec
from repro.serve.scheduler import (
    BatchTile,
    BucketingScheduler,
    ServeRequest,
    StreamPacker,
)
from repro.serve.session import SessionPool, SessionSnapshot, _Session


@dataclasses.dataclass
class ServeResult:
    """Per-request classification + accounting.

    ``status`` is the error model: :data:`~repro.serve.guard.ServeStatus.OK`
    results carry live logits; REJECTED (guard/overload/shed), EXPIRED
    (deadline passed before launch) and FAULT (numeric quarantine or an
    unrecoverable lane fault) results carry ``pred == -1`` and zero logits —
    dropped work surfaces as a typed result, never as a silent hole or an
    engine-killing exception."""

    rid: int
    pred: int                 # argmax class; -1 when status != OK
    logits: np.ndarray        # accumulated LI readout acc_y, shape (n_out,)
    label: int                # label carried by the AER stream (0 if absent)
    latency_s: float          # admission → result delivery (harvest); see
                              # BatchedEngine.serve — delivery lag behind
                              # device completion is bounded by the polling
                              # cadence and max_inflight_tiles; for non-OK
                              # results: admission → drop decision
    bucket_ticks: int         # padded tick length served at
    batch_size: int           # live samples in the tile
    model_id: str = DEFAULT_MODEL   # which registered model served it
    status: ServeStatus = ServeStatus.OK


@dataclasses.dataclass
class ServeStats:
    requests: int
    batches: int
    wall_s: float
    samples_per_sec: float
    p50_latency_s: float
    p99_latency_s: float
    mean_batch: float
    compiled_shapes: int
    # Analytic HBM bytes the served tiles streamed when the kernel backend
    # runs (:func:`repro.kernels.traffic.infer_fused_bytes` — one (B, O)
    # logits tile per batch instead of seven (T, B, ·) tensors); 0 on the
    # scan backend, which runs no Pallas tile.
    hbm_bytes_streamed: int = 0
    # Error-model counters: how many of `requests` ended non-OK (shed is
    # the subset of rejected evicted by the admission="shed" policy), and
    # how many lane restarts the window absorbed.
    rejected: int = 0
    expired: int = 0
    quarantined: int = 0
    shed: int = 0
    lane_restarts: int = 0
    # model_id → ServeStats for that model's slice of the run; populated by
    # serve() when the window touched more than one model, else None.
    per_model: Optional[Dict[str, "ServeStats"]] = None

    @classmethod
    def collect(
        cls,
        results: List[ServeResult],
        wall_s: float,
        batches: int,
        shapes: int,
        hbm_bytes: int = 0,
        shed: int = 0,
        lane_restarts: int = 0,
    ) -> "ServeStats":
        # Throughput and latency are computed over the *served* (OK)
        # results: a rejected request is decided in microseconds and would
        # otherwise inflate samples/s and deflate the percentiles.
        ok = [r for r in results if r.status is ServeStatus.OK]
        lat = np.array([r.latency_s for r in ok]) if ok else np.zeros(1)
        by = {
            s: sum(1 for r in results if r.status is s) for s in ServeStatus
        }
        return cls(
            requests=len(results),
            batches=batches,
            wall_s=wall_s,
            samples_per_sec=len(ok) / wall_s if wall_s > 0 else float("inf"),
            p50_latency_s=float(np.percentile(lat, 50)),
            p99_latency_s=float(np.percentile(lat, 99)),
            mean_batch=(len(ok) / batches) if batches else 0.0,
            compiled_shapes=shapes,
            hbm_bytes_streamed=hbm_bytes,
            rejected=by[ServeStatus.REJECTED],
            expired=by[ServeStatus.EXPIRED],
            quarantined=by[ServeStatus.FAULT],
            shed=shed,
            lane_restarts=lane_restarts,
        )


@dataclasses.dataclass
class _PendingTile:
    """A launched-but-unsynchronised batch tile: the device is still (or may
    still be) computing ``acc_y`` while the host moves on to later buckets."""

    acc_y: jax.Array          # (b_pad, n_out) device array, possibly in flight
    labels: np.ndarray
    tile: BatchTile
    b_live: int
    lane: "_ModelLane"

    def ready(self) -> bool:
        """Non-blocking readiness probe (conservative where unsupported)."""
        is_ready = getattr(self.acc_y, "is_ready", None)
        return bool(is_ready()) if callable(is_ready) else False


@dataclasses.dataclass
class _PendingStreamTile:
    """A launched-but-unharvested streaming tick-tile: the device may still
    be computing while the host packs the next tile."""

    acc_y: jax.Array                 # (b_pad, n_out) post-chunk accumulators
    lanes: List[Tuple["_Session", int, int]]   # (session, ticks, events) at launch
    t_launch: float
    num_ticks: int
    lane: "_ModelLane"

    def ready(self) -> bool:
        is_ready = getattr(self.acc_y, "is_ready", None)
        return bool(is_ready()) if callable(is_ready) else False


@dataclasses.dataclass
class StreamStats:
    """Streaming-serving throughput/latency accounting (one pump window)."""

    sessions: int                 # sessions that advanced in the window
    tiles: int                    # tick-tiles launched
    events: int                   # spike events consumed
    ticks: int                    # live session-ticks advanced (Σ chunk lengths)
    wall_s: float
    events_per_sec: float         # over wall_s - admission_wait_s: device
                                  # throughput, not caller stall (see below)
    ticks_per_sec: float
    p50_tile_latency_s: float     # launch → harvest per tick-tile
    p99_tile_latency_s: float
    mean_lanes: float             # live lanes per tile (packing efficiency)
    evictions: int
    readmissions: int
    compiled_shapes: int          # distinct step_sessions (T, B) programs
    hbm_bytes_streamed: int = 0
    # Error-model counters (window totals).
    rejected: int = 0             # feeds refused by the guard / overload
    expired: int = 0              # sessions dropped at pack time (deadline)
    shed: int = 0                 # requests evicted by admission="shed"
    quarantined: int = 0          # sessions FAULTed by health checks/faults
    lane_restarts: int = 0        # backend rebuilds the window absorbed
    saturation_storms: int = 0    # quantized rows that escaped the 12-bit grid
    # Wall time callers spent blocked on a full bounded packer queue (the
    # engine pumps inline to make room).  Subtracted from wall_s for
    # events_per_sec/ticks_per_sec so throughput under backpressure
    # reports what the device sustained, not how long callers stalled.
    admission_wait_s: float = 0.0
    # model_id → StreamStats for that model's lane; populated when the
    # engine serves more than one model, else None.
    per_model: Optional[Dict[str, "StreamStats"]] = None


class _ModelLane:
    """Per-model serving state inside a :class:`BatchedEngine`.

    One lane per registered model: its own :class:`BucketingScheduler`
    (whole-sample buckets), :class:`StreamPacker` (streaming ready-queue)
    and :class:`SessionPool` (carry shapes differ per network, so pools
    cannot be shared), plus the model-attributed traffic counters.  Tiles
    never mix models — a launch reads exactly one SRAM image, like the
    chip — but the engine pump interleaves launches across lanes.
    """

    def __init__(self, engine: "BatchedEngine", spec: ModelSpec):
        self.spec = spec
        cfg, be = spec.cfg, spec.backend
        budget = be.vmem_budget
        self.max_batch = engine._max_batch or batching.max_batch_for(
            cfg, budget, num_devices=be.num_devices
        )
        # per-kernel-tile rows, for the analytic HBM traffic accounting
        self.tile_rows = batching.max_batch_for(cfg, budget)
        self.scheduler = BucketingScheduler(
            self.max_batch, engine.tick_granularity, clock=engine._clock,
            rid_alloc=engine._alloc_rid,
            max_pending=engine._max_pending, admission=engine._admission,
        )
        # Pool capacity must seat one full tile of sessions at once; the
        # trash row on top keeps gather/scatter shapes fixed.
        capacity = max(
            engine._max_sessions or batching.max_sessions_for(cfg),
            self.max_batch,
        )
        self.pool = SessionPool(
            be, capacity, idle_timeout=engine._idle_timeout,
            clock=engine._clock,
        )
        self.packer = StreamPacker(
            self.max_batch, tick_tile=engine._tick_tile,
            tick_granularity=engine.tick_granularity,
            max_pending=engine._max_pending_sessions,
        )
        # Per-lane guard: the engine-wide policy with this model's n_in
        # resolved; None when the engine was built with guard=False.
        self.guard: Optional[GuardConfig] = (
            engine._guard.for_model(cfg.n_in)
            if engine._guard is not None else None
        )
        self.zero_states: Dict[int, Dict[str, jax.Array]] = {}
        self.tile_lat: List[float] = []
        # Dropped-work results (REJECTED/EXPIRED/FAULT) accumulated outside
        # a serve() window — drained by BatchedEngine.take_dead_results().
        self.dead: List[ServeResult] = []
        self.reset_counters()

    @property
    def model_id(self) -> str:
        return self.spec.model_id

    @property
    def cfg(self) -> RSNNConfig:
        return self.spec.cfg

    @property
    def backend(self) -> ExecutionBackend:
        return self.spec.backend

    @property
    def weights(self) -> Dict[str, jax.Array]:
        """The live SRAM image — fetched per launch, so a registry hot-swap
        applies to the very next tile."""
        return self.spec.weights

    def reset_counters(self) -> None:
        self.tile_lat.clear()
        self.bytes_streamed = 0
        self.tiles = 0
        self.events = 0
        self.ticks = 0
        self.lanes = 0
        self.rejected = 0
        self.expired = 0
        self.shed = 0
        self.quarantined = 0
        self.lane_restarts = 0
        self.saturation_storms = 0
        self.admission_wait_s = 0.0

    def zero_state(self, b_pad: int):
        """Cached zero-carry pytree per tile width (a read-only jit input,
        so reusing it across launches is safe)."""
        st = self.zero_states.get(b_pad)
        if st is None:
            st = self.zero_states[b_pad] = self.backend.init_session_state(
                b_pad
            )
        return st

    def account_tile_bytes(self, num_ticks: int, b_pad: int, fn) -> None:
        """Attribute one kernel launch's analytic HBM bytes to this lane
        (scan runs no Pallas tile, so nothing is attributed)."""
        if self.backend.backend != "kernel":
            return
        cfg = self.cfg
        ndev = self.backend.num_devices
        shard_b = -(-b_pad // ndev)
        self.bytes_streamed += ndev * fn(
            num_ticks, shard_b, cfg.n_in, cfg.n_hid, cfg.n_out,
            batch_tile=self.tile_rows,
        )


class SessionHandle:
    """The public face of one open stream (from ``engine.open_session()``).

    ``feed`` appends AER words (ticks non-decreasing across feeds — the
    stream contract); the engine processes them when its pump next packs
    this session into a tick-tile (``engine.pump()``, or implicitly via
    :meth:`result`).  ``poll`` is non-blocking and returns the latest
    harvested :class:`~repro.serve.session.SessionSnapshot` (or ``None``);
    ``result`` closes the stream, drains every pending tick and returns the
    final snapshot; ``close`` abandons the stream and frees its pool slot.
    """

    def __init__(self, engine: "BatchedEngine", sess: _Session):
        self._engine = engine
        self._sess = sess

    @property
    def sid(self) -> int:
        return self._sess.sid

    @property
    def model_id(self) -> str:
        return self._sess.model_id

    @property
    def closed(self) -> bool:
        return self._sess.closed

    @property
    def status(self) -> ServeStatus:
        """OK while the stream is healthy; FAULT once quarantined (numeric
        health check or unrecoverable lane fault), EXPIRED once its
        deadline dropped it — both terminal."""
        return self._sess.status

    def feed(self, events: np.ndarray) -> int:
        """Append one AER word buffer; returns spike events admitted.  Does
        not launch work — call ``engine.pump()`` (or :meth:`result`) to
        advance.  Raises a typed
        :class:`~repro.serve.guard.GuardError` subclass when the buffer
        fails validation, exceeds a quota, or the session is closed /
        quarantined — the session itself is untouched by a rejected feed."""
        return self._engine._feed(self._sess, events)

    def poll(self) -> Optional[SessionSnapshot]:
        """Latest incremental readout snapshot, non-blocking."""
        self._engine._harvest_stream(block=False)
        return self._sess.snapshot

    def result(self) -> SessionSnapshot:
        """Close the stream, process every fed tick, return the final
        classification (synchronises)."""
        return self._engine._finish_session(self._sess)

    def close(self) -> None:
        """Abandon the stream: unprocessed events are dropped and the pool
        slot is freed.  Use :meth:`result` to finish instead."""
        self._engine._abandon_session(self._sess)


class BatchedEngine:
    """Batched AER classification service over one or many registered models.

    Parameters
    ----------
    cfg:
        The network the weights belong to (e.g. ``Presets.braille(...)``) —
        the single-model convenience path, mutually exclusive with
        ``registry``.
    params:
        ``{"w_in", "w_rec", "w_out"}`` (+ optional scalar ``"alpha"``) — the
        same pytree :class:`~repro.core.controller.OnlineLearner` trains.
    registry:
        A :class:`~repro.serve.registry.ModelRegistry` to serve instead of a
        single ``(cfg, params)`` pair: every registered model becomes
        routable via the ``model_id=`` arguments (models registered *after*
        construction too — lanes materialise on first use).  The first
        registered model (or ``model_id`` when given) is the default route.
    model_id:
        The id the single-model path registers under, and the default route
        for calls that don't pass ``model_id=``.
    backend:
        ``"kernel" | "scan" | "auto"``, or an existing
        :class:`~repro.core.backend.ExecutionBackend` to share its jit cache
        (the online-learning-while-serving configuration).  With
        ``registry=`` each model already resolved its own pooled backend,
        so this is ignored.
    max_batch:
        Admission size per tile; defaults to one full per-device kernel tile
        times the data-parallel device count
        (:func:`repro.serve.batching.max_batch_for`).  The kernels batch-tile
        internally, so this is a scheduling knob, not a VMEM cap.  Applies
        per lane (an explicit value caps every model's tiles).
    mesh:
        Data-parallel serving: a mesh whose data axes the backend shards
        every inference tile's sample axis over (weights replicated) —
        admission scales with the device count.
    max_sessions:
        Streaming capacity ``S_cap`` — resident sessions each model's
        device pool holds; defaults to
        :func:`repro.serve.batching.max_sessions_for`'s byte-budget sizing
        per model.  Sessions beyond it are LRU-evicted to host memory
        (bit-exact) and readmitted on their next packed tile.
    idle_timeout:
        Seconds of inactivity after which a resident session is offloaded
        (``None`` disables the sweep).
    tick_tile:
        Fixed tick length of streaming tiles (latency-bounded mode).  When
        ``None``, each packed tile drains everything its sessions have
        pending (throughput mode — also what the whole-sample ``serve()``
        wrapper uses).
    runtime:
        A :class:`~repro.core.backend.RuntimeConfig` bundling the
        backend/quant/vmem_budget/mesh knobs (the loose kwargs remain as a
        deprecated passthrough; resolution happens in ``as_backend``).
    guard:
        Input-validation policy: a
        :class:`~repro.serve.guard.GuardConfig` (per-lane ``n_in`` is
        filled from each model's config), ``None`` for the default policy,
        or ``False`` to disable validation entirely (the overhead-bench
        escape hatch — production callers should not).
    max_pending / admission:
        Bounded whole-sample admission queue per lane.  ``max_pending``
        caps queued requests (``None`` = unbounded, the legacy behaviour);
        on overflow ``admission="reject"`` raises
        :class:`~repro.serve.guard.OverloadError` at ``submit()`` while
        ``"shed"`` evicts the *oldest* queued request, which surfaces as a
        REJECTED result.
    default_deadline_s:
        Relative deadline stamped on every admitted request that doesn't
        pass its own ``deadline_s``; expired requests are dropped at pack
        time (before any launch) and surface as EXPIRED results.  ``None``
        disables.
    max_pending_sessions:
        Bounds each lane's streaming ready-queue (sessions).  A ``feed``
        that would overflow it pumps the lane inline until there is room —
        that stall is *admission wait*, excluded from StreamStats
        throughput.
    session_deadline_s:
        Relative deadline stamped on every ``open_session`` that doesn't
        pass its own; checked at pack time — an expired session is dropped
        before launch with a terminal EXPIRED snapshot.
    max_tile_retries:
        Launch-fault budget: how many times faulted work is rewound and
        relaunched (through a lane restart) before the affected
        requests/sessions are FAULTed.
    fault_hook:
        Test/chaos injection point: called as ``fault_hook(model_id,
        kind)`` (``kind ∈ {"tile", "stream"}``) at the top of every launch,
        *before* any state mutation; an exception it raises is handled
        exactly like a device launch fault.  Leave ``None`` in production.
    """

    def __init__(
        self,
        cfg: Optional[RSNNConfig] = None,
        params: Optional[Dict[str, jax.Array]] = None,
        *,
        registry: Optional[ModelRegistry] = None,
        model_id: str = DEFAULT_MODEL,
        backend: BackendLike = "auto",
        max_batch: Optional[int] = None,
        tick_granularity: int = 32,
        vmem_budget: Optional[int] = None,
        mesh=None,
        max_inflight_tiles: int = 8,
        clock: Callable[[], float] = time.monotonic,
        max_sessions: Optional[int] = None,
        idle_timeout: Optional[float] = None,
        tick_tile: Optional[int] = None,
        runtime: Optional[RuntimeConfig] = None,
        guard: Union[GuardConfig, None, bool] = None,
        max_pending: Optional[int] = None,
        admission: str = "reject",
        default_deadline_s: Optional[float] = None,
        max_pending_sessions: Optional[int] = None,
        session_deadline_s: Optional[float] = None,
        max_tile_retries: int = 3,
        fault_hook: Optional[Callable[[str, str], None]] = None,
    ):
        self.tick_granularity = tick_granularity
        # Backpressure for the deferred-sync serve loop: at most this many
        # launched-but-unharvested tiles (each pins its raster + acc_y device
        # buffers) before the host blocks on the oldest.
        self.max_inflight_tiles = max(1, int(max_inflight_tiles))
        self._clock = clock
        self._max_batch = max_batch
        self._max_sessions = max_sessions
        self._idle_timeout = idle_timeout
        self._tick_tile = tick_tile
        if guard is False:
            self._guard: Optional[GuardConfig] = None
        elif guard is None or guard is True:
            self._guard = GuardConfig()
        else:
            self._guard = guard
        self._max_pending = max_pending
        self._admission = admission
        self._default_deadline_s = default_deadline_s
        self._max_pending_sessions = max_pending_sessions
        self._session_deadline_s = session_deadline_s
        self._max_tile_retries = max(0, int(max_tile_retries))
        self._fault_hook = fault_hook
        self._next_rid = 0
        if registry is None:
            if cfg is None or params is None:
                raise ValueError(
                    "BatchedEngine needs either (cfg, params) or registry="
                )
            registry = ModelRegistry()
            registry.register(
                model_id, cfg, params, backend=backend, runtime=runtime,
                vmem_budget=vmem_budget, mesh=mesh,
            )
        else:
            if cfg is not None or params is not None:
                raise ValueError(
                    "pass either (cfg, params) or registry=, not both"
                )
            if len(registry) == 0:
                raise ValueError("registry has no registered models")
        self.registry = registry
        if model_id in registry:
            self.default_model = model_id
        elif model_id == DEFAULT_MODEL:
            self.default_model = registry.ids()[0]
        else:
            registry.get(model_id)   # raises KeyError naming the options
        self._lanes: Dict[str, _ModelLane] = {}
        self._sessions: Dict[int, _Session] = {}
        self._next_sid = 0
        self._stream_pending: List[_PendingStreamTile] = []
        self._in_restart = False   # re-entrancy guard for lane restarts
        self._lane(self.default_model)   # default lane is always live

    # --------------------------------------------------------------- routing

    def _alloc_rid(self) -> int:
        """Engine-wide request ids: every lane's scheduler draws from this
        one counter, so rids stay unique and admission-ordered across
        models."""
        rid = self._next_rid
        self._next_rid += 1
        return rid

    def _lane(self, model_id: Optional[str] = None) -> _ModelLane:
        """The serving lane for a model (default route when ``None``),
        created on first use — so models registered after engine
        construction, e.g. by a learner publishing mid-serve, become
        routable with no engine-side setup."""
        mid = self.default_model if model_id is None else model_id
        lane = self._lanes.get(mid)
        if lane is None:
            lane = self._lanes[mid] = _ModelLane(self, self.registry.get(mid))
        return lane

    def model_ids(self) -> Tuple[str, ...]:
        """Models currently routable through this engine."""
        return self.registry.ids()

    # Single-model compatibility surface: the historical attributes resolve
    # against the default lane, so one-model callers (and the test suite's
    # whole-sample paths) are unchanged.

    @property
    def cfg(self) -> RSNNConfig:
        return self._lane().cfg

    @property
    def engine(self) -> ExecutionBackend:
        return self._lane().backend

    @property
    def backend(self) -> str:
        return self._lane().backend.backend

    @property
    def max_batch(self) -> int:
        return self._lane().max_batch

    @property
    def scheduler(self) -> BucketingScheduler:
        return self._lane().scheduler

    @property
    def packer(self) -> StreamPacker:
        return self._lane().packer

    @property
    def pool(self) -> SessionPool:
        return self._lane().pool

    @property
    def _weights(self) -> Dict[str, jax.Array]:
        return self._lane().weights

    @property
    def quantized(self) -> bool:
        """True when default-route tiles execute the fixed-point
        hardware-equivalence datapath (logits are then membrane-grid
        integers)."""
        return self._lane().backend.quant is not None

    @classmethod
    def from_learner(cls, learner, **kw) -> "BatchedEngine":
        """Serve an :class:`~repro.core.controller.OnlineLearner`'s network
        through the learner's own execution backend — shared jit cache, so
        ``update_weights(learner.weights)`` mid-training re-uses the exact
        programs the learner compiled (and vice versa)."""
        kw.setdefault("backend", learner.backend)
        return cls(learner.cfg, learner.inference_params(), **kw)

    def update_weights(
        self, weights: Dict[str, jax.Array], model_id: Optional[str] = None
    ) -> None:
        """Swap in newly-trained weights for one model (no recompilation —
        weights are jit arguments).  In quantized mode this is the SRAM
        load: weights are snapped onto the 8-bit grid, through a jit'd
        program that donates (and thus reuses) the previous SRAM image's
        buffers.  Delegates to
        :meth:`~repro.serve.registry.ModelRegistry.update_weights`, so a
        mis-shaped image fails loudly at the registry boundary."""
        self.registry.update_weights(
            self.default_model if model_id is None else model_id, weights
        )

    # ----------------------------------------------------------------- serving

    def _launch_tile(self, lane: _ModelLane, tile: BatchTile) -> _PendingTile:
        """Decode, pad and *launch* one batch tile — returns without
        synchronising on the device so consecutive buckets overlap host
        decode with device compute."""
        self._inject_fault(lane, "tile")
        cfg = lane.cfg
        events = [r.events for r in tile.requests]
        raster, valid, labels = batching.decode_events_host(
            events, cfg.n_in, tile.num_ticks, cfg.label_delay
        )
        b_live = len(events)
        b_pad = batching.padded_batch_size(b_live, lane.max_batch)
        raster, valid = batching.pad_batch(raster, valid, b_pad)
        # With a data mesh, every device fetches its own replicated weight
        # set and runs its (shard-padded) slice of the batch.
        lane.account_tile_bytes(
            tile.num_ticks, b_pad, traffic.infer_fused_tiled_bytes
        )
        out = lane.backend.inference(
            lane.weights, jnp.asarray(raster), jnp.asarray(valid)
        )
        return _PendingTile(
            acc_y=out["acc_y"], labels=labels, tile=tile, b_live=b_live,
            lane=lane,
        )

    def _finalize(self, pending: _PendingTile) -> List[ServeResult]:
        """Materialise one launched tile's results (synchronises on it).

        Per-sample numeric health runs here: a row carrying NaN/inf (or,
        quantized, a saturation storm off the 12-bit grid) becomes a FAULT
        result while its tile-mates are delivered unchanged.  A device
        fault surfacing at materialisation FAULTs the whole tile and
        restarts the lane."""
        lane = pending.lane
        try:
            acc_y = np.asarray(pending.acc_y)[: pending.b_live]
        except Exception:
            if not self._in_restart:
                self._restart_lane(lane)
            lane.quarantined += len(pending.tile.requests)
            return [
                self._dead_result(lane, req, ServeStatus.FAULT)
                for req in pending.tile.requests
            ]
        t_done = self._clock()
        bad, sat = bad_rows(
            acc_y, quant=lane.backend.quant, ticks=pending.tile.num_ticks
        )
        lane.saturation_storms += int(sat.sum())
        lane.quarantined += int(bad.sum())
        zeros = np.zeros((lane.cfg.n_out,), np.float32)
        return [
            ServeResult(
                rid=req.rid,
                pred=-1 if bad[i] else int(np.argmax(acc_y[i])),
                logits=zeros if bad[i] else acc_y[i],
                label=int(pending.labels[i]),
                latency_s=t_done - req.t_submit,
                bucket_ticks=pending.tile.num_ticks,
                batch_size=pending.b_live,
                model_id=lane.model_id,
                status=ServeStatus.FAULT if bad[i] else ServeStatus.OK,
            )
            for i, req in enumerate(pending.tile.requests)
        ]

    def run_tile(
        self, tile: BatchTile, model_id: Optional[str] = None
    ) -> List[ServeResult]:
        """Decode, pad, classify one batch tile; per-request results.  The
        tile must come from the same model's scheduler it is run under."""
        return self._finalize(self._launch_tile(self._lane(model_id), tile))

    def submit(
        self,
        events: np.ndarray,
        meta: Optional[dict] = None,
        model_id: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> int:
        """Admit one AER sample for a registered model (default route when
        ``model_id`` is ``None``); returns its engine-unique request id.

        The buffer passes the lane's input guard first — a malformed or
        over-quota buffer raises a typed
        :class:`~repro.serve.guard.GuardError` subclass and admits nothing.
        A full bounded queue raises
        :class:`~repro.serve.guard.OverloadError` under
        ``admission="reject"``; under ``"shed"`` the oldest queued request
        is evicted instead (surfacing as a REJECTED result via
        :meth:`take_dead_results` / ``serve()``).  ``deadline_s`` is
        relative to now (falls back to the engine's ``default_deadline_s``).
        """
        lane = self._lane(model_id)
        events = self._validate_for(lane, events)
        rid = lane.scheduler.submit(
            events, meta, deadline=self._deadline(deadline_s)
        )
        self._collect_dropped(lane)
        return rid

    # ------------------------------------------------- guards + error model

    def _validate_for(self, lane: _ModelLane, events) -> np.ndarray:
        """Run one buffer through the lane's input guard (no-op when the
        engine was built with ``guard=False``)."""
        if lane.guard is None:
            return np.asarray(events)
        return validate_events(
            events, lane.guard, what=f"model {lane.model_id!r} buffer"
        )

    def _deadline(self, deadline_s: Optional[float]) -> Optional[float]:
        rel = (
            deadline_s if deadline_s is not None else self._default_deadline_s
        )
        return None if rel is None else self._clock() + rel

    def _dead_result(
        self, lane: _ModelLane, req: ServeRequest, status: ServeStatus
    ) -> ServeResult:
        """The typed tombstone for one dropped request."""
        return ServeResult(
            rid=req.rid,
            pred=-1,
            logits=np.zeros((lane.cfg.n_out,), np.float32),
            label=0,
            latency_s=self._clock() - req.t_submit,
            bucket_ticks=req.bucket,
            batch_size=0,
            model_id=lane.model_id,
            status=status,
        )

    def _collect_dropped(self, lane: _ModelLane) -> None:
        """Convert the lane's shed and deadline-expired requests into dead
        results (REJECTED / EXPIRED) — called at admission and pack time so
        expired work never occupies a launch slot."""
        for req in lane.scheduler.shed:
            lane.shed += 1
            lane.rejected += 1
            lane.dead.append(
                self._dead_result(lane, req, ServeStatus.REJECTED)
            )
        lane.scheduler.shed.clear()
        for req in lane.scheduler.take_expired():
            lane.expired += 1
            lane.dead.append(self._dead_result(lane, req, ServeStatus.EXPIRED))

    def take_dead_results(
        self, model_id: Optional[str] = None
    ) -> List[ServeResult]:
        """Drain the dropped-work results (REJECTED/EXPIRED/FAULT) for one
        model (or every lane) — the direct ``submit``/``run_tile`` caller's
        window into the error model; ``serve()`` drains them into its
        result list automatically."""
        lanes = (
            [self._lane(model_id)] if model_id is not None
            else list(self._lanes.values())
        )
        out: List[ServeResult] = []
        for lane in lanes:
            self._collect_dropped(lane)
            out.extend(lane.dead)
            lane.dead.clear()
        return out

    # ------------------------------------------------------ lane supervision

    def _inject_fault(self, lane: _ModelLane, kind: str) -> None:
        if self._fault_hook is not None:
            self._fault_hook(lane.model_id, kind)

    def _restart_lane(self, lane: _ModelLane) -> None:
        """Supervisor restart after a device/launch fault: materialise what
        is trustworthy, abandon the rest, rebuild.

        1. every *other* in-flight tile is harvested (their device buffers
           predate the fault);
        2. each resident session is evicted to a bit-exact host snapshot —
           one whose row cannot be materialised (poisoned chain) is
           quarantined instead;
        3. the registry swaps the lane's pooled backend for a freshly
           constructed one (fresh jit state; recompiles on next launch) and
           the lane gets a new pool, so no future launch touches old device
           buffers.  Healthy sessions re-seat from their snapshots on their
           next packed tile, bitwise identical to an undisturbed stream.
        """
        self._in_restart = True
        try:
            self._harvest_stream(block=True)
            for sess in list(lane.pool._resident.values()):
                try:
                    lane.pool.evict(sess)
                except Exception:
                    self._quarantine(lane, sess)
            old_pool = lane.pool
            lane.spec = self.registry.rebuild_backend(lane.model_id)
            lane.pool = SessionPool(
                lane.backend, old_pool.capacity,
                idle_timeout=old_pool.idle_timeout, clock=self._clock,
            )
            lane.pool.evictions = old_pool.evictions
            lane.pool.readmissions = old_pool.readmissions
            lane.zero_states.clear()
            lane.lane_restarts += 1
        finally:
            self._in_restart = False

    def _quarantine(self, lane: _ModelLane, sess: _Session) -> None:
        """Terminally FAULT one session: its stream state is not
        trustworthy, so it is closed with a dead snapshot while the rest of
        its tile (and lane) keeps serving."""
        if sess.status is ServeStatus.FAULT:
            return
        sess.status = ServeStatus.FAULT
        sess.closed = True
        sess.snapshot = SessionSnapshot(
            sid=sess.sid, pred=-1,
            logits=np.zeros((lane.cfg.n_out,), np.float32),
            label=sess.label, ticks=sess.cursor, events=sess.n_events,
            final=True, status=ServeStatus.FAULT,
        )
        lane.quarantined += 1
        try:
            lane.pool.release(sess)
        except Exception:
            sess.slot = None

    def _expire_session(self, lane: _ModelLane, sess: _Session) -> None:
        """Terminal EXPIRED drop at pack time: the session's deadline
        passed before its pending ticks launched."""
        sess.status = ServeStatus.EXPIRED
        sess.closed = True
        sess.snapshot = SessionSnapshot(
            sid=sess.sid, pred=-1,
            logits=np.zeros((lane.cfg.n_out,), np.float32),
            label=sess.label, ticks=sess.cursor, events=sess.n_events,
            final=True, status=ServeStatus.EXPIRED,
        )
        lane.expired += 1
        lane.pool.release(sess)

    # ---------------------------------------------------- session streaming

    def open_session(
        self,
        meta: Optional[dict] = None,
        model_id: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> SessionHandle:
        """Open one AER event stream with persistent recurrent state.

        The session is pinned to its model's lane for life — its carry
        ``(v, z, y, acc_y, n_spk)`` lives in that model's device-resident
        :class:`~repro.serve.session.SessionPool` while hot (LRU-evicted to
        host bit-exactly under capacity pressure) — feed events in
        arbitrary increments; chunking never changes the result.

        ``deadline_s`` (relative; falls back to the engine's
        ``session_deadline_s``) bounds how long the stream may wait for
        device time: a session whose deadline passes before its pending
        ticks are packed is dropped at pack time with a terminal EXPIRED
        snapshot.
        """
        lane = self._lane(model_id)
        sess = _Session(
            self._next_sid, self._clock(), meta, model_id=lane.model_id
        )
        sess.gate_label = lane.cfg.eprop.infer_window == "valid"
        rel = (
            deadline_s if deadline_s is not None else self._session_deadline_s
        )
        sess.deadline = None if rel is None else self._clock() + rel
        self._next_sid += 1
        self._sessions[sess.sid] = sess
        return SessionHandle(self, sess)

    def _feed(self, sess: _Session, events: np.ndarray) -> int:
        lane = self._lanes[sess.model_id]
        if lane.guard is not None:
            try:
                events = validate_events(
                    events, lane.guard,
                    min_tick=max(sess.max_fed_tick, 0),
                    what=f"session {sess.sid} feed",
                )
            except GuardError:
                lane.rejected += 1
                raise
            backlog = len(sess.sp_tick) - sess.sp_ptr
            incoming = int(np.count_nonzero(events >> 24 == 0x03))
            if backlog + incoming > lane.guard.max_pending_events:
                lane.rejected += 1
                raise QuotaExceededError(
                    f"session {sess.sid}: {backlog} buffered + {incoming} "
                    f"incoming spikes exceeds max_pending_events="
                    f"{lane.guard.max_pending_events}"
                )
        n = sess.feed(events)
        if sess.processable() > 0:
            t0 = self._clock()
            stalled = False
            while not lane.packer.enqueue(sess):
                # Bounded ready-queue full: drain a tile inline to make
                # room.  The stall is admission wait — caller backpressure,
                # not device time — and is excluded from throughput stats.
                stalled = True
                if not self._pump_lane_once(lane):
                    break
            if stalled:
                lane.admission_wait_s += self._clock() - t0
        return n

    def _launch_chunks(self, lane: _ModelLane, sessions, chunks, num_ticks):
        """The shared streaming launch: seat sessions in the pool (one
        batched admission scatter), decode their chunks into one rectangular
        tick-tile, gather carries → ``step_sessions`` → scatter carries.
        Returns the backend's output state (device values, not synced)."""
        self._inject_fault(lane, "stream")
        cfg = lane.cfg
        b_pad = batching.padded_batch_size(len(sessions), lane.max_batch)
        raster, live, valid = batching.decode_session_chunks(
            chunks, cfg.n_in, num_ticks, cfg.label_delay, b_pad=b_pad,
        )
        slots, admit = lane.pool.place(sessions)
        if admit is not None:
            lane.pool.admit(admit)
        idx = lane.pool.padded_slots(slots, b_pad)
        state = lane.pool.gather(idx)
        out = lane.backend.step_sessions(
            lane.weights, jnp.asarray(raster), jnp.asarray(live),
            jnp.asarray(valid), state,
        )
        lane.pool.scatter(idx, out)
        lane.account_tile_bytes(
            num_ticks, b_pad, traffic.stream_step_tiled_bytes
        )
        lane.tiles += 1
        lane.lanes += len(sessions)
        lane.ticks += sum(c.n_live for c in chunks)
        lane.events += sum(len(c.sp_tick) for c in chunks)
        return out

    def _pump_lane_once(self, lane: _ModelLane) -> bool:
        """Pack and launch one streaming tick-tile from one model's lane;
        False when none of its sessions has processable ticks.

        Deadlines are enforced here — *pack time*, before any launch pays
        for the work: an expired session is dropped with a terminal
        EXPIRED snapshot and never occupies a tile lane.  A launch fault
        (device error or injected) rewinds every chosen session's chunk,
        restarts the lane, and re-queues the survivors; a session that
        faults more than ``max_tile_retries`` times in a row is
        quarantined."""
        nxt = lane.packer.next_tile()
        if nxt is None:
            return False
        sessions, num_ticks = nxt
        now = self._clock()
        live = []
        for s in sessions:
            if s.deadline is not None and now > s.deadline:
                self._expire_session(lane, s)
            else:
                live.append(s)
        if not live:
            return True   # handled (dropped) work — the pump made progress
        sessions = live
        chunks = [s.take_chunk(num_ticks) for s in sessions]
        try:
            out = self._launch_chunks(lane, sessions, chunks, num_ticks)
        except Exception:
            self._on_stream_launch_fault(lane, sessions, chunks)
            return True
        self._stream_pending.append(_PendingStreamTile(
            acc_y=out["acc_y"],
            lanes=[(s, s.cursor, s.n_events) for s in sessions],
            t_launch=self._clock(),
            num_ticks=num_ticks,
            lane=lane,
        ))
        for s in sessions:
            if s.processable() > 0:
                lane.packer.enqueue(s)
        self._harvest_stream(block=False)
        while len(self._stream_pending) > self.max_inflight_tiles:
            self._harvest_one()   # backpressure: block on the oldest tile
        return True

    def _on_stream_launch_fault(self, lane, sessions, chunks) -> None:
        """Contain one failed streaming launch: rewind every session's
        chunk (bit-exact — the pool was never scattered into), restart the
        lane, re-queue survivors, quarantine repeat offenders."""
        for s, ref in zip(sessions, chunks):
            s.restore_chunk(ref)
            s.retries += 1
        survivors = [
            s for s in sessions if s.retries <= self._max_tile_retries
        ]
        for s in sessions:
            if s.retries > self._max_tile_retries:
                self._quarantine(lane, s)
        self._restart_lane(lane)
        for s in survivors:
            if s.processable() > 0:
                lane.packer.enqueue(s)

    def _pump_once(self) -> bool:
        """One interleaving round: launch at most one tick-tile per model
        lane (fair share across models — no lane starves behind another's
        backlog); False when no session anywhere has processable ticks."""
        launched = False
        for lane in list(self._lanes.values()):
            launched |= self._pump_lane_once(lane)
        return launched

    def pump(self, drain: bool = False) -> int:
        """Advance every open session through its pending ticks (continuous
        batching: tiles launch asynchronously, harvested opportunistically;
        with several models registered, launches interleave across their
        lanes round-robin).  ``drain`` additionally blocks until all
        launched tiles are harvested.  Returns the number of interleaving
        rounds that launched work."""
        n = 0
        while self._pump_once():
            n += 1
        for lane in self._lanes.values():
            lane.pool.sweep()
        if drain:
            self._harvest_stream(block=True)
        return n

    def _harvest_one(self) -> None:
        p = self._stream_pending.pop(0)
        lane = p.lane
        try:
            acc = np.asarray(p.acc_y)   # synchronises on this tile
        except Exception:
            # Async device fault surfacing at materialisation: every
            # session in this tile ran through the faulted op, and the
            # pool's scatter chain is poisoned behind it — quarantine the
            # tile and restart the lane (other residents are evicted
            # best-effort inside the restart).
            for sess, _, _ in p.lanes:
                self._quarantine(lane, sess)
            if not self._in_restart:
                self._restart_lane(lane)
            return
        lane.tile_lat.append(self._clock() - p.t_launch)
        n = len(p.lanes)
        bad, sat = bad_rows(
            acc[:n], quant=lane.backend.quant,
            ticks=np.array([t for _, t, _ in p.lanes], np.int64),
        )
        lane.saturation_storms += int(sat.sum())
        for i, (sess, ticks, events) in enumerate(p.lanes):
            if sess.status is not ServeStatus.OK:
                continue   # terminal snapshot already written
            if bad[i]:
                # One poisoned sample: quarantine it; its tile-mates'
                # results are delivered below, bitwise untouched (each
                # lane of the tile is an independent carry row).
                self._quarantine(lane, sess)
                continue
            sess.retries = 0
            sess.snapshot = SessionSnapshot(
                sid=sess.sid, pred=int(np.argmax(acc[i])), logits=acc[i],
                label=sess.label, ticks=ticks, events=events,
            )

    def _harvest_stream(self, block: bool) -> None:
        while self._stream_pending and (block or self._stream_pending[0].ready()):
            self._harvest_one()

    def _session_acc(self, sess: _Session) -> np.ndarray:
        """A session's accumulated readout wherever it lives: pool row,
        offloaded host copy, or zeros for a never-run session.  Pool state
        chains on every launched tile, so this is exact without waiting for
        the harvest loop."""
        lane = self._lanes[sess.model_id]
        if sess.slot is not None:
            return np.asarray(lane.pool.state["acc_y"][sess.slot])
        if sess.offloaded is not None:
            return np.asarray(sess.offloaded["acc_y"], np.float32)
        return np.zeros((lane.cfg.n_out,), np.float32)

    def _finish_session(self, sess: _Session) -> SessionSnapshot:
        lane = self._lanes[sess.model_id]
        if sess.status is not ServeStatus.OK:
            # Quarantined/expired mid-stream: the terminal snapshot was
            # already written; result() just hands it over.
            self._sessions.pop(sess.sid, None)
            return sess.snapshot
        sess.closed = True   # extends the horizon to the last fed tick
        if sess.processable() > 0:
            while not lane.packer.enqueue(sess):
                if not self._pump_lane_once(lane):
                    break
        while (sess.status is ServeStatus.OK and sess.processable() > 0
               and self._pump_once()):
            pass
        self._harvest_stream(block=True)
        if sess.status is not ServeStatus.OK:
            self._sessions.pop(sess.sid, None)
            return sess.snapshot
        acc = self._session_acc(sess)
        snap = SessionSnapshot(
            sid=sess.sid, pred=int(np.argmax(acc)), logits=acc,
            label=sess.label, ticks=sess.cursor, events=sess.n_events,
            final=True,
        )
        sess.snapshot = snap
        lane.pool.release(sess)
        self._sessions.pop(sess.sid, None)
        return snap

    def _abandon_session(self, sess: _Session) -> None:
        sess.closed = True
        self._lanes[sess.model_id].pool.release(sess)
        self._sessions.pop(sess.sid, None)

    def reset_stream_stats(self) -> None:
        """Zero the streaming counters of every lane (start of a
        measurement window)."""
        for lane in self._lanes.values():
            lane.reset_counters()

    def _lane_stream_stats(self, lane: _ModelLane, wall_s: float) -> StreamStats:
        lat = np.array(lane.tile_lat) if lane.tile_lat else np.zeros(1)
        tiles = lane.tiles
        sessions = sum(
            1 for s in self._sessions.values() if s.model_id == lane.model_id
        )
        # Throughput over *device* time: callers blocked on a full bounded
        # queue (admission wait) are backpressure, not serving work.
        busy = max(wall_s - lane.admission_wait_s, 1e-9)
        return StreamStats(
            sessions=sessions,
            tiles=tiles,
            events=lane.events,
            ticks=lane.ticks,
            wall_s=wall_s,
            events_per_sec=(
                lane.events / busy if wall_s > 0 else float("inf")
            ),
            ticks_per_sec=(
                lane.ticks / busy if wall_s > 0 else float("inf")
            ),
            p50_tile_latency_s=float(np.percentile(lat, 50)),
            p99_tile_latency_s=float(np.percentile(lat, 99)),
            mean_lanes=(lane.lanes / tiles) if tiles else 0.0,
            evictions=lane.pool.evictions,
            readmissions=lane.pool.readmissions,
            compiled_shapes=lane.backend.compiled_shapes("step_sessions"),
            hbm_bytes_streamed=lane.bytes_streamed,
            rejected=lane.rejected,
            expired=lane.expired,
            shed=lane.shed,
            quarantined=lane.quarantined,
            lane_restarts=lane.lane_restarts,
            saturation_storms=lane.saturation_storms,
            admission_wait_s=lane.admission_wait_s,
        )

    def _compiled_step_shapes(self) -> int:
        """Distinct ``step_sessions`` programs across the engine's lanes,
        counting each pooled backend once (same-bucket models share one jit
        cache, and its shapes must not be double-counted)."""
        uniq = {id(l.backend): l.backend for l in self._lanes.values()}
        return sum(
            be.compiled_shapes("step_sessions") for be in uniq.values()
        )

    def stream_stats(
        self, wall_s: float, model_id: Optional[str] = None
    ) -> StreamStats:
        """Streaming counters since the last :meth:`reset_stream_stats`,
        normalised over the caller-measured wall window.  ``model_id``
        selects one lane; otherwise counters aggregate across lanes, with
        the per-lane breakdown attached as ``per_model`` when the engine
        serves several models."""
        if model_id is not None:
            return self._lane_stream_stats(self._lane(model_id), wall_s)
        lanes = list(self._lanes.values())
        per = {l.model_id: self._lane_stream_stats(l, wall_s) for l in lanes}
        lat = [t for l in lanes for t in l.tile_lat]
        arr = np.array(lat) if lat else np.zeros(1)
        tiles = sum(l.tiles for l in lanes)
        events = sum(l.events for l in lanes)
        ticks = sum(l.ticks for l in lanes)
        wait = sum(l.admission_wait_s for l in lanes)
        busy = max(wall_s - wait, 1e-9)
        return StreamStats(
            sessions=len(self._sessions),
            tiles=tiles,
            events=events,
            ticks=ticks,
            wall_s=wall_s,
            events_per_sec=events / busy if wall_s > 0 else float("inf"),
            ticks_per_sec=ticks / busy if wall_s > 0 else float("inf"),
            p50_tile_latency_s=float(np.percentile(arr, 50)),
            p99_tile_latency_s=float(np.percentile(arr, 99)),
            mean_lanes=(sum(l.lanes for l in lanes) / tiles) if tiles else 0.0,
            evictions=sum(l.pool.evictions for l in lanes),
            readmissions=sum(l.pool.readmissions for l in lanes),
            compiled_shapes=self._compiled_step_shapes(),
            hbm_bytes_streamed=sum(l.bytes_streamed for l in lanes),
            rejected=sum(l.rejected for l in lanes),
            expired=sum(l.expired for l in lanes),
            shed=sum(l.shed for l in lanes),
            quarantined=sum(l.quarantined for l in lanes),
            lane_restarts=sum(l.lane_restarts for l in lanes),
            saturation_storms=sum(l.saturation_storms for l in lanes),
            admission_wait_s=wait,
            per_model=per if len(lanes) > 1 else None,
        )

    # ----------------------------------------- whole-sample compat wrapper

    def _launch_session_tile(
        self, lane: _ModelLane, tile: BatchTile
    ) -> _PendingTile:
        """One whole-sample bucket tile executed through the session-step
        op as a single open-feed-close chunk, with
        :func:`~repro.serve.batching.decode_events_host` semantics exactly:
        the full bucketed tick length runs live (padding ticks advance
        dynamics like the old path) and an END-less buffer pins
        ``end_tick = 0``.

        Each request is a complete stream, so the tile is *stateless* —
        zero carries in (one cached pytree per tile width), carries out
        unobserved — and skips the session pool entirely: whole-sample
        serving pays no pool-sized scatter and no per-request host
        bookkeeping."""
        self._inject_fault(lane, "tile")
        cfg = lane.cfg
        T = tile.num_ticks
        bufs = [req.events for req in tile.requests]
        b_pad = batching.padded_batch_size(len(bufs), lane.max_batch)
        raster, valid, labels = batching.decode_events_host(
            bufs, cfg.n_in, T, cfg.label_delay
        )
        raster, valid = batching.pad_batch(raster, valid, b_pad)
        live = np.zeros((T, b_pad), np.float32)
        live[:, : len(bufs)] = 1.0
        out = lane.backend.step_sessions(
            lane.weights, jnp.asarray(raster), jnp.asarray(live),
            jnp.asarray(valid), lane.zero_state(b_pad),
        )
        lane.account_tile_bytes(T, b_pad, traffic.stream_step_tiled_bytes)
        lane.tiles += 1
        lane.lanes += len(bufs)
        lane.ticks += T * len(bufs)
        return _PendingTile(
            acc_y=out["acc_y"], labels=labels, tile=tile,
            b_live=len(bufs), lane=lane,
        )

    def serve(
        self,
        stream: Iterable[Union[np.ndarray, Tuple[np.ndarray, str]]],
        flush: bool = True,
        model_id: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> Tuple[List[ServeResult], ServeStats]:
        """Run a whole stream of AER sample buffers; results in admission
        (rid) order plus throughput/latency stats.

        Stream items are raw event buffers (routed to ``model_id``, default
        route when ``None``) or ``(events, model_id)`` pairs — mixed-model
        traffic interleaves freely; each buffer lands in its own model's
        scheduler and tiles stay single-model.  Per-model stats ride in
        ``stats.per_model`` whenever more than one model served.

        This is the whole-sample *compatibility wrapper* over the session
        runtime: each bucketed tile (same
        :class:`~repro.serve.scheduler.BucketingScheduler` determinism
        contract as ever) is executed open-feed-close through the session
        machinery — per-request sessions seated in the pool, one
        ``step_sessions`` launch, slots released — producing identical
        results to the historical whole-sample path.  Tiles are *launched*
        as soon as a bucket fills but the host never blocks on them
        mid-stream: results are harvested opportunistically as their device
        buffers become ready and the one mandatory synchronisation happens
        at the end-of-stream drain.  ``flush`` drains the partial buckets
        at end-of-stream.

        Robustness semantics: per-item failures never abort the stream.  A
        buffer the guard rejects, a submit refused by a full bounded
        queue, a shed or deadline-expired request, and a faulted tile all
        surface as results with the corresponding non-OK
        :class:`~repro.serve.guard.ServeStatus` — one misbehaving item
        costs exactly one REJECTED result while its neighbours serve
        unaffected.  ``deadline_s`` stamps a per-item relative deadline
        (falling back to the engine's ``default_deadline_s``).
        """
        t0 = self._clock()
        bytes0 = {
            mid: lane.bytes_streamed for mid, lane in self._lanes.items()
        }
        restarts0 = {
            mid: lane.lane_restarts for mid, lane in self._lanes.items()
        }
        shed0 = {mid: lane.shed for mid, lane in self._lanes.items()}
        results: List[ServeResult] = []
        pending: List[_PendingTile] = []
        batches = 0
        batches_by: Dict[str, int] = {}
        touched: Dict[str, _ModelLane] = {}

        def launch(lane: _ModelLane, tile: BatchTile) -> None:
            """Launch with a fault budget: a launch that raises restarts
            the lane and retries; an exhausted budget FAULTs the tile's
            requests instead of killing the stream."""
            nonlocal batches
            for _ in range(self._max_tile_retries + 1):
                try:
                    pending.append(self._launch_session_tile(lane, tile))
                except Exception:
                    if not self._in_restart:
                        self._restart_lane(lane)
                    continue
                batches += 1
                batches_by[lane.model_id] = (
                    batches_by.get(lane.model_id, 0) + 1
                )
                return
            lane.quarantined += len(tile.requests)
            results.extend(
                self._dead_result(lane, req, ServeStatus.FAULT)
                for req in tile.requests
            )

        def harvest(block: bool) -> None:
            while pending and (block or pending[0].ready()):
                results.extend(self._finalize(pending.pop(0)))

        def reap(lane: _ModelLane) -> None:
            """Shed + deadline-expired requests become results, *before*
            tiles pack — expired work never occupies a launch slot."""
            self._collect_dropped(lane)
            results.extend(lane.dead)
            lane.dead.clear()

        for item in stream:
            if isinstance(item, tuple):
                events, mid = item
            else:
                events, mid = item, model_id
            lane = self._lane(mid)
            touched[lane.model_id] = lane
            try:
                ev = self._validate_for(lane, events)
                lane.scheduler.submit(
                    ev, deadline=self._deadline(deadline_s)
                )
            except (GuardError, OverloadError):
                lane.rejected += 1
                results.append(self._dead_result(
                    lane,
                    ServeRequest(
                        rid=self._alloc_rid(),
                        events=np.zeros(0, np.uint32),
                        native_ticks=0, bucket=0, t_submit=self._clock(),
                    ),
                    ServeStatus.REJECTED,
                ))
            reap(lane)
            for tile in lane.scheduler.ready_tiles():
                launch(lane, tile)
            harvest(block=False)
            while len(pending) > self.max_inflight_tiles:
                # backpressure: the device fell behind — block on the oldest
                # tile so in-flight buffers stay bounded
                results.extend(self._finalize(pending.pop(0)))
        if flush:
            for lane in touched.values():
                reap(lane)
                for tile in lane.scheduler.drain():
                    launch(lane, tile)
        harvest(block=True)   # the single per-drain sync
        wall = self._clock() - t0
        results.sort(key=lambda r: r.rid)

        def lane_bytes(lane: _ModelLane) -> int:
            return lane.bytes_streamed - bytes0.get(lane.model_id, 0)

        def lane_restarts(lane: _ModelLane) -> int:
            return lane.lane_restarts - restarts0.get(lane.model_id, 0)

        def lane_shed(lane: _ModelLane) -> int:
            return lane.shed - shed0.get(lane.model_id, 0)

        stats = ServeStats.collect(
            results, wall, batches, self._compiled_step_shapes(),
            hbm_bytes=sum(lane_bytes(l) for l in self._lanes.values()),
            shed=sum(lane_shed(l) for l in touched.values()),
            lane_restarts=sum(lane_restarts(l) for l in touched.values()),
        )
        if len(touched) > 1:
            stats.per_model = {
                mid: ServeStats.collect(
                    [r for r in results if r.model_id == mid],
                    wall,
                    batches_by.get(mid, 0),
                    lane.backend.compiled_shapes("step_sessions"),
                    hbm_bytes=lane_bytes(lane),
                    shed=lane_shed(lane),
                    lane_restarts=lane_restarts(lane),
                )
                for mid, lane in touched.items()
            }
        return results, stats

    def warmup(
        self,
        num_ticks: int,
        batch: Optional[int] = None,
        model_id: Optional[str] = None,
    ) -> None:
        """Pre-compile the forward programs for one tile shape
        (excluded-from-bench compile time; also useful before
        latency-sensitive serving).  Warms both the session-step program
        (the ``serve()``/streaming path) and the whole-sample inference
        program (the direct ``run_tile`` path)."""
        lane = self._lane(model_id)
        b = batching.padded_batch_size(batch or lane.max_batch, lane.max_batch)
        t = batching.bucket_ticks(num_ticks, self.tick_granularity)
        raster = jnp.zeros((t, b, lane.cfg.n_in), jnp.float32)
        valid = jnp.ones((t, b), jnp.float32)
        jax.block_until_ready(
            lane.backend.inference(lane.weights, raster, valid)["acc_y"]
        )
        state = lane.backend.init_session_state(b)
        jax.block_until_ready(
            lane.backend.step_sessions(
                lane.weights, raster, valid, valid, state
            )["acc_y"]
        )
