"""Cue-accumulation ("binary decision navigation") dataset — §4.2.

The task (Bellec et al., NeurIPS'18; shipped with ReckOn's RTL testbench):
a rodent receives a sequence of left/right visual cues, then after a delay a
recall cue asks which side had the majority.  The RSNN must integrate the
cue evidence across the delay — the delayed-supervision benchmark for
e-prop's long-term credit assignment.

Input geometry matches the ReckOn network of the paper: 40 input neurons in
4 groups of 10 — [left cues | right cues | recall cue | background noise].
Each of the 7 cues activates its side's group for ``cue_ticks`` ticks at
Bernoulli rate ``p_active``; the noise group fires at ``p_noise`` for the
whole sample; during the recall window the recall group fires and the
supervision (TARGET_VALID) is asserted.  Labels: 0 = left majority,
1 = right majority (7 cues ⇒ no ties).

Samples are emitted as **bit-faithful AER event buffers** (the BRAM image of
the X-HEEP build) via :func:`repro.core.aer.encode_sample`; the pipelines
decode them back to rasters on device.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.core import aer
from repro.data import pipeline


@dataclasses.dataclass(frozen=True)
class CueConfig:
    num_cues: int = 7
    cue_ticks: int = 10
    gap_ticks: int = 6
    delay_ticks: int = 10
    recall_ticks: int = 20
    p_active: float = 0.4     # firing prob/tick inside an active cue group
    p_noise: float = 0.05     # background group rate
    p_recall: float = 0.4
    group: int = 10           # neurons per group
    seed: int = 0

    @property
    def n_in(self) -> int:
        return 4 * self.group

    @property
    def num_ticks(self) -> int:
        t = self.num_cues * (self.cue_ticks + self.gap_ticks)
        return t + self.delay_ticks + self.recall_ticks

    @property
    def recall_start(self) -> int:
        return self.num_cues * (self.cue_ticks + self.gap_ticks) + self.delay_ticks


def _make_sample(rng: np.random.Generator, cfg: CueConfig) -> Tuple[np.ndarray, int, int, int]:
    T, G = cfg.num_ticks, cfg.group
    raster = np.zeros((T, cfg.n_in), np.float32)
    sides = rng.integers(0, 2, size=cfg.num_cues)          # 0=left, 1=right
    label = int(sides.sum() * 2 > cfg.num_cues)            # majority side
    for i, side in enumerate(sides):
        t0 = i * (cfg.cue_ticks + cfg.gap_ticks)
        block = rng.random((cfg.cue_ticks, G)) < cfg.p_active
        raster[t0 : t0 + cfg.cue_ticks, side * G : (side + 1) * G] = block
    r0 = cfg.recall_start
    raster[r0 : r0 + cfg.recall_ticks, 2 * G : 3 * G] = (
        rng.random((cfg.recall_ticks, G)) < cfg.p_recall
    )
    raster[:, 3 * G :] = rng.random((T, G)) < cfg.p_noise
    label_tick = r0                                        # supervision from recall on
    end_tick = T - 1
    return raster, label, label_tick, end_tick


def make_cue_dataset(
    n_train: int = 50, n_val: int = 50, n_test: int = 0, cfg: CueConfig = CueConfig()
) -> Dict[str, Dict[str, np.ndarray]]:
    """Generate the paper's 50-sample train/validation sets as AER buffers.

    Returns ``{split: {"events": (S, L) uint32, "n_in": int, "num_ticks": int}}``.
    """
    rng = np.random.default_rng(cfg.seed)
    out: Dict[str, Dict[str, np.ndarray]] = {}
    sizes = {"train": n_train, "val": n_val, "test": n_test}
    max_len = 0
    buffers_by_split = {}
    for split, n in sizes.items():
        if n == 0:
            continue
        buffers = []
        for _ in range(n):
            raster, label, label_tick, end_tick = _make_sample(rng, cfg)
            buffers.append(aer.encode_sample(raster, label, label_tick, end_tick))
        buffers_by_split[split] = buffers
        max_len = max(max_len, max(len(b) for b in buffers))
    for split, buffers in buffers_by_split.items():
        out[split] = {
            "events": aer.pad_events(buffers, max_len),
            "n_in": cfg.n_in,
            "num_ticks": cfg.num_ticks,
        }
        # measured per-channel event density (see data.pipeline.event_density)
        out[split]["event_density"] = pipeline.event_density(out[split])
    return out
