"""Braille-digit dataset — §4.3 of the paper.

The real benchmark (Müller-Cleve et al. 2022) slides a sensorised fingertip
with 12 capacitive taxels over embossed Braille characters and encodes the
capacitance changes as spikes; the paper trains ReckOn on subsets
{A,E,U}, {Space,A,E,U}, {A,E,O,U} of the 7-class NIR split.

The recordings are not redistributable offline, so this module:

* loads the real data if the user drops ``braille.npz`` (keys
  ``events/labels/names``) into ``data/braille/``;
* otherwise generates a **calibrated synthetic surrogate**: each character
  is its Braille dot matrix (2 cols × 3 rows); sliding contact turns every
  dot into a spatio-temporal Gaussian activation bump over a 4×3 taxel
  grid (12 sensors), with per-sample jitter in onset, speed, amplitude and
  background noise; spikes are Bernoulli-coded per tick.  The row-blur
  constant ``sigma_row`` is set so the single-dot difference between O
  (dot 1-3-5) and U (dot 1-3-6) lands in the confusable regime — matching
  the paper's difficulty ordering: 3-class ≈ 90% test ≫ 4-class(+Space)
  ≈ 79% ≫ 4-class(A,E,O,U) ≈ 60%.

Samples are emitted as bit-faithful AER buffers like every other dataset.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Sequence

import numpy as np

from repro.core import aer
from repro.data import pipeline

# Braille dot matrices: dot numbering (col, row): 1=(0,0) 2=(0,1) 3=(0,2)
#                                                 4=(1,0) 5=(1,1) 6=(1,2)
DOTS = {
    "A": [(0, 0)],                          # dot 1
    "E": [(0, 0), (1, 1)],                  # dots 1,5
    "I": [(0, 1), (1, 0)],                  # dots 2,4
    "O": [(0, 0), (0, 2), (1, 1)],          # dots 1,3,5
    "U": [(0, 0), (0, 2), (1, 2)],          # dots 1,3,6
    "Y": [(0, 0), (0, 2), (1, 0), (1, 2)],  # dots 1,3,4,6
    "Space": [],
}

SUBSETS = {
    "AEU": ["A", "E", "U"],
    "SAEU": ["Space", "A", "E", "U"],
    "AEOU": ["A", "E", "O", "U"],
}


@dataclasses.dataclass(frozen=True)
class BrailleConfig:
    num_ticks: int = 128
    n_sensor_cols: int = 4
    n_sensor_rows: int = 3          # 4×3 = 12 taxels
    amplitude: float = 0.55         # peak spike prob at perfect alignment
    sigma_t: float = 6.0            # temporal bump width (ticks)
    sigma_row: float = 1.05         # row blur — the O/U confusability dial
    p_noise: float = 0.045
    onset_jitter: float = 9.0
    speed_jitter: float = 0.12
    amp_jitter: float = 0.28
    space_texture: float = 0.35     # faint pseudo-dot amplitude for Space
                                    # (paper-texture drag — makes Space/A
                                    # confusable like the real recordings)
    samples_per_class: int = 200
    seed: int = 7

    @property
    def n_in(self) -> int:
        return self.n_sensor_cols * self.n_sensor_rows


def _sample_profile(rng: np.random.Generator, letter: str, cfg: BrailleConfig) -> np.ndarray:
    """Per-(tick, sensor) spike probabilities for one slide."""
    T = cfg.num_ticks
    p = np.full((T, cfg.n_sensor_rows, cfg.n_sensor_cols), cfg.p_noise)
    onset = T * 0.15 + rng.normal(0.0, cfg.onset_jitter)
    speed = (T * 0.55 / 2.0) * (1.0 + rng.normal(0.0, cfg.speed_jitter))
    amp = cfg.amplitude * (1.0 + rng.normal(0.0, cfg.amp_jitter))
    t = np.arange(T)[:, None, None]
    rows = np.arange(cfg.n_sensor_rows)[None, :, None]
    cols = np.arange(cfg.n_sensor_cols)[None, None, :]
    dots = list(DOTS[letter])
    weights = [1.0] * len(dots)
    if letter == "Space" and cfg.space_texture > 0:
        # surface-texture drag: a couple of faint pseudo-dots per slide
        for _ in range(int(rng.integers(1, 3))):
            dots.append((int(rng.integers(0, 2)), int(rng.integers(0, 3))))
            weights.append(cfg.space_texture)
    for (dcol, drow), w in zip(dots, weights):
        # dot passes sensor column sc at onset + (dcol + sc*0.35)·speed
        t_pass = onset + (dcol + 0.35 * cols) * speed
        bump = np.exp(-0.5 * ((t - t_pass) / cfg.sigma_t) ** 2)
        align = np.exp(-0.5 * ((rows - drow) / cfg.sigma_row) ** 2)
        p = p + w * amp * bump * align
    return np.clip(p.reshape(T, -1), 0.0, 0.95)


def _real_path() -> Path:
    return Path(__file__).resolve().parents[3] / "data" / "braille" / "braille.npz"


def make_braille_dataset(
    subset: str = "AEU",
    cfg: BrailleConfig = BrailleConfig(),
    splits: Sequence[float] = (0.7, 0.2, 0.1),
) -> Dict[str, Dict[str, np.ndarray]]:
    """Returns {"train"/"val"/"test": {"events", "n_in", "num_ticks"}}.

    Split ratios follow the NIR protocol (980/280/140 of 1400 = 70/20/10).
    """
    classes = SUBSETS[subset] if subset in SUBSETS else list(subset)
    rng = np.random.default_rng(cfg.seed)
    real = _real_path()
    per_class: Dict[str, List[np.ndarray]] = {}
    if real.exists():
        with np.load(real, allow_pickle=True) as z:
            names = [str(n) for n in z["names"]]
            for c in classes:
                idx = [i for i, n in enumerate(names) if n == c]
                per_class[c] = [z["events"][i] for i in idx]
        source = "real"
    else:
        for c in classes:
            rasters = [
                (rng.random((cfg.num_ticks, cfg.n_in)) < _sample_profile(rng, c, cfg))
                .astype(np.float32)
                for _ in range(cfg.samples_per_class)
            ]
            per_class[c] = rasters
        source = "synthetic"

    buffers, labels = [], []
    for li, c in enumerate(classes):
        for raster in per_class[c]:
            buffers.append(
                aer.encode_sample(raster, li, label_tick=int(cfg.num_ticks * 0.3),
                                  end_tick=cfg.num_ticks - 1)
            )
            labels.append(li)
    order = rng.permutation(len(buffers))
    buffers = [buffers[i] for i in order]

    n = len(buffers)
    n_tr = int(splits[0] * n)
    n_va = int(splits[1] * n)
    max_len = max(len(b) for b in buffers)
    chunks = {
        "train": buffers[:n_tr],
        "val": buffers[n_tr : n_tr + n_va],
        "test": buffers[n_tr + n_va :],
    }
    out = {}
    for split, bufs in chunks.items():
        out[split] = {
            "events": aer.pad_events(bufs, max_len),
            "n_in": cfg.n_in,
            "num_ticks": cfg.num_ticks,
            "source": source,
            "classes": classes,
        }
        # measured per-channel event density — what the traffic gates and
        # the backend's dense/event dispatch consume (grounds the paper's
        # "~2-5% on Braille" figure instead of assuming it)
        out[split]["event_density"] = pipeline.event_density(out[split])
    return out
