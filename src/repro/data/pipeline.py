"""The two SoC dataflow modes as host↔device pipelines.

* :class:`ResidentPipeline` — **X-HEEP mode**.  The whole encoded dataset is
  moved to the device once ("the datasets are loaded during the bitfile
  writing stage, implemented directly by initializing the BRAMs"), decoded
  once, and every epoch replays the resident tensors.  Zero host↔device
  traffic after startup; capacity bounded by device memory — exactly the
  trade-off of Table 1 (~100% BRAM).

* :class:`BatchedOffloadPipeline` — **ARM mode**.  The dataset stays on the
  host ("safely stored in the internal memory"); batches of
  ``samples_per_batch`` are offloaded to a device-side buffer, processed,
  and the BATCH_DONE/NEW_BATCH GPIO handshake becomes *double-buffered
  asynchronous prefetch*: while the device consumes batch *k*, the host has
  already issued the transfer of batch *k+1* (``jax.device_put`` is async —
  the dispatch returns before the copy completes, so transfer overlaps
  compute).  Capacity unbounded; steady host↔device traffic — Table 2.

Both yield identical decoded batches, so the controller is mode-agnostic —
the same way the paper's AER decoder serves both SoCs.

Replay determinism (the fault-tolerance contract, ``docs/fault_tolerance.md``):
batch order is a pure function of ``(seed, epoch)`` — shuffles derive a
fresh ``np.random.default_rng([seed, epoch])`` per epoch instead of
advancing a process-lifetime generator — so a restarted run that resumes
from a :class:`~repro.distributed.checkpoint.ReplayCursor` consumes exactly
the batches the crashed run would have (``batches(split, epoch,
start_batch=k)`` skips the first ``k`` without consuming entropy).  The
serving-side :class:`EventStream` carries the same property per pass plus
an explicit ``state()``/``seek()`` cursor.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aer
from repro.core.controller import DeviceBatch, decode_events_to_batch
from repro.distributed.checkpoint import ReplayCursor  # noqa: F401  (re-export)


def event_density(events, n_in: Optional[int] = None,
                  num_ticks: Optional[int] = None) -> float:
    """Measured per-channel event density of AER word buffers: spike words
    per ``(tick, channel)`` slot — the fraction of nonzero raster entries
    the buffers decode to.

    This is the *ground truth* behind the "~2-5% on Braille" figure: the
    traffic gates (``benchmarks/bench_kernels.py``) and the backend's
    dense/event dispatch (:func:`repro.kernels.events.resolve_sparsity`)
    both consume this measurement instead of assuming a constant.

    ``events`` is either a padded ``(S, L)`` uint32 word matrix plus
    explicit ``n_in`` / ``num_ticks``, or a dataset split dict
    ``{"events", "n_in", "num_ticks"}`` as the dataset builders emit
    (:func:`repro.data.braille.make_braille_dataset`,
    :func:`repro.data.cue.make_cue_dataset` — both record the measurement
    as ``split["event_density"]``).  Pad (0x0), label and end words are
    excluded by construction — only ``EVT_SPIKE`` words count.
    """
    if isinstance(events, dict):
        n_in = int(events["n_in"])
        num_ticks = int(events["num_ticks"])
        events = events["events"]
    if not (n_in and num_ticks):
        raise ValueError("need n_in and num_ticks (or a split dict)")
    words = np.asarray(events, np.uint32)
    n_samples = words.shape[0] if words.ndim > 1 else 1
    n_spike = int((((words >> 24) & 0xFF) == aer.EVT_SPIKE).sum())
    return n_spike / float(n_samples * num_ticks * n_in)


@dataclasses.dataclass
class PipelineStats:
    """Telemetry for the resource benchmark (Tables 1/2 analog)."""

    h2d_bytes: int = 0        # host→device traffic issued
    resident_bytes: int = 0   # device-resident dataset footprint
    transfers: int = 0        # number of device_put calls


class _Base:
    def __init__(self, dataset: Dict[str, Dict[str, np.ndarray]], label_delay: int = 0):
        self.dataset = dataset
        self.label_delay = label_delay
        self.stats = PipelineStats()

    def _decode(self, words: jax.Array, meta: Dict) -> DeviceBatch:
        return decode_events_to_batch(
            words, meta["n_in"], meta["num_ticks"], self.label_delay
        )


class ResidentPipeline(_Base):
    """X-HEEP mode: one device_put at construction, epochs replay on device."""

    def __init__(self, dataset, label_delay: int = 0):
        super().__init__(dataset, label_delay)
        self._resident: Dict[str, DeviceBatch] = {}
        for split, d in dataset.items():
            words = jax.device_put(jnp.asarray(d["events"]))
            self.stats.h2d_bytes += d["events"].nbytes
            self.stats.transfers += 1
            batch = self._decode(words, d)
            batch = jax.tree.map(jax.device_put, batch)
            self._resident[split] = batch
            self.stats.resident_bytes += sum(
                x.nbytes for x in jax.tree.leaves(batch)
            ) + d["events"].nbytes

    def batches(self, split: str, epoch: int,
                start_batch: int = 0) -> Iterator[DeviceBatch]:
        if split in self._resident and start_batch == 0:
            yield self._resident[split]


class BatchedOffloadPipeline(_Base):
    """ARM mode: host-resident dataset, BRAM-sized chunks, async prefetch."""

    def __init__(
        self,
        dataset,
        samples_per_batch: int,
        label_delay: int = 0,
        prefetch: int = 2,
        shuffle_train: bool = False,
        seed: int = 0,
    ):
        super().__init__(dataset, label_delay)
        self.samples_per_batch = samples_per_batch
        self.prefetch = max(1, prefetch)
        self.shuffle_train = shuffle_train
        self.seed = seed

    def _order(self, split: str, n: int, epoch: int) -> np.ndarray:
        # Pure function of (seed, epoch): a replayed epoch shuffles
        # identically no matter how many batches an earlier run consumed —
        # the replay-cursor determinism contract (a process-lifetime rng
        # here would make resume order depend on crash position).
        if split == "train" and self.shuffle_train:
            return np.random.default_rng([self.seed, epoch]).permutation(n)
        return np.arange(n)

    def batches(self, split: str, epoch: int,
                start_batch: int = 0) -> Iterator[DeviceBatch]:
        """Yield the epoch's decoded device batches; ``start_batch`` skips
        the first ``k`` batches *without offloading them* — resume-with-
        replay lands on the exact batch a crashed run would consume next."""
        if split not in self.dataset:
            return
        d = self.dataset[split]
        events = d["events"]
        order = self._order(split, events.shape[0], epoch)
        spb = self.samples_per_batch
        chunks = [order[i : i + spb] for i in range(0, len(order), spb)]
        chunks = chunks[start_batch:]

        # Double-buffered offload: issue transfer k+1 before yielding k.
        inflight: list = []
        for idx in chunks[: self.prefetch]:
            inflight.append(self._offload(events[idx], d))
        ptr = self.prefetch
        while inflight:
            batch = inflight.pop(0)
            if ptr < len(chunks):
                inflight.append(self._offload(events[chunks[ptr]], d))
                ptr += 1
            yield batch  # NEW_BATCH: device consumes; next copy is in flight

    def _offload(self, chunk: np.ndarray, meta: Dict) -> DeviceBatch:
        words = jax.device_put(jnp.asarray(chunk))   # async dispatch
        self.stats.h2d_bytes += chunk.nbytes
        self.stats.transfers += 1
        return self._decode(words, meta)


class EventStream:
    """Serving-side adapter: a dataset split replayed as ragged per-sample
    AER buffers — the stream of requests a deployed SoC would receive.

    Where the training pipelines above move *batches* toward the device, the
    stream hands out one trimmed uint32 event buffer at a time (trailing 0x0
    pad words stripped), ready for ``repro.serve.BatchedEngine.submit`` /
    ``serve``.  ``repeat`` loops the split to synthesize sustained traffic;
    ``shuffle`` randomizes arrival order per pass (deterministically: each
    pass's order is a pure function of ``(seed, pass)``).

    The stream carries a durable cursor — ``(pass, offset)``, the next
    request to hand out: :meth:`state` snapshots it for a checkpoint
    manifest, :meth:`seek` restores it, and a restarted consumer replays
    exactly the requests the crashed one would have received.  Iteration
    advances the cursor in place, so the stream is single-consumer: a fully
    drained stream yields nothing more until :meth:`reset`.

    With ``guard=`` (a :class:`~repro.serve.guard.GuardConfig`), every
    buffer passes through :func:`~repro.serve.guard.validate_events` before
    it is yielded — the stream becomes the trust boundary for replayed or
    recorded traffic.  ``on_invalid`` picks the policy: ``"raise"``
    propagates the typed :class:`~repro.serve.guard.GuardError` (the cursor
    has already advanced past the bad sample, so a catching consumer
    re-enters ``iter(stream)`` and resumes at the next one), ``"skip"``
    silently drops bad buffers and counts them in :attr:`invalid`.
    """

    def __init__(
        self,
        dataset: Dict[str, Dict[str, np.ndarray]],
        split: str = "test",
        *,
        repeat: int = 1,
        shuffle: bool = False,
        seed: int = 0,
        guard=None,
        on_invalid: str = "raise",
    ):
        if split not in dataset:
            raise KeyError(
                f"split {split!r} not in dataset (have {list(dataset)})"
            )
        if on_invalid not in ("raise", "skip"):
            raise ValueError(
                f"on_invalid must be 'raise' or 'skip', got {on_invalid!r}"
            )
        self.meta = dataset[split]
        self.events = np.asarray(self.meta["events"], np.uint32)
        self.repeat = repeat
        self.shuffle = shuffle
        self.seed = seed
        self.guard = guard
        self.on_invalid = on_invalid
        self.invalid = 0     # buffers rejected by the guard (skip policy)
        self.pass_idx = 0    # cursor: current pass through the split
        self.offset = 0      # cursor: next index into that pass's order

    def __len__(self) -> int:
        return self.events.shape[0] * self.repeat

    # ------------------------------------------------------------- cursor
    def state(self) -> Dict[str, int]:
        """Durable cursor — record in a checkpoint manifest."""
        return {"pass": int(self.pass_idx), "offset": int(self.offset),
                "seed": int(self.seed)}

    def seek(self, state: Dict[str, int]) -> None:
        """Restore a :meth:`state` snapshot (the seed must match — a cursor
        indexes into the order that seed generates)."""
        if int(state.get("seed", self.seed)) != int(self.seed):
            raise ValueError(
                f"EventStream cursor was recorded under seed "
                f"{state['seed']}, this stream uses {self.seed}"
            )
        self.pass_idx = int(state["pass"])
        self.offset = int(state["offset"])

    def reset(self) -> None:
        self.pass_idx = 0
        self.offset = 0

    def _order(self, pass_idx: int) -> np.ndarray:
        n = self.events.shape[0]
        if self.shuffle:
            return np.random.default_rng([self.seed, pass_idx]).permutation(n)
        return np.arange(n)

    def __iter__(self) -> Iterator[np.ndarray]:
        from repro.serve.batching import trim_padding

        n = self.events.shape[0]
        while self.pass_idx < self.repeat:
            order = self._order(self.pass_idx)
            while self.offset < n:
                i = order[self.offset]
                self.offset += 1
                buf = trim_padding(self.events[i])
                if self.guard is not None:
                    buf = self._guarded(buf, int(i))
                    if buf is None:
                        continue
                yield buf
            self.pass_idx += 1
            self.offset = 0

    def _guarded(self, buf: np.ndarray, i: int) -> Optional[np.ndarray]:
        from repro.serve.guard import GuardError, validate_events

        try:
            return validate_events(
                buf, self.guard, what=f"stream sample {i}"
            )
        except GuardError:
            self.invalid += 1
            if self.on_invalid == "raise":
                raise
            return None


def interleave_train_serve(
    pipeline,
    stream,
    epoch: int = 0,
    split: str = "train",
    serve_per_batch: int = 8,
) -> Iterator[tuple]:
    """Online-learning-while-serving feed: the paper's second experiment at
    service scale.

    Yields ``("train", device_batch)`` items from a training pipeline
    interleaved with ``("serve", events)`` request buffers from an
    :class:`EventStream` — the ARM SoC answering live queries between END_B
    commits.  ``serve_per_batch`` requests are released after each training
    batch; leftover requests drain at the end of the epoch.  The consumer
    (see ``examples/serve_braille.py`` and ``tests/test_backend.py``) trains
    an :class:`~repro.core.controller.OnlineLearner` on the train items and
    pushes the serve items through a :class:`repro.serve.BatchedEngine`
    sharing the learner's execution backend.
    """
    requests = iter(stream)
    for batch in pipeline.batches(split, epoch):
        yield ("train", batch)
        for _ in range(serve_per_batch):
            try:
                yield ("serve", next(requests))
            except StopIteration:
                break
    for ev in requests:
        yield ("serve", ev)


def make_pipeline(
    mode: str,
    dataset,
    samples_per_batch: Optional[int] = None,
    label_delay: int = 0,
    **kw,
):
    """Factory keyed on the paper's two controller modes."""
    if mode in ("xheep", "resident"):
        return ResidentPipeline(dataset, label_delay)
    if mode in ("arm", "offload"):
        if not samples_per_batch:
            raise ValueError("ARM mode needs samples_per_batch (BRAM depth)")
        return BatchedOffloadPipeline(dataset, samples_per_batch, label_delay, **kw)
    raise ValueError(f"unknown pipeline mode {mode!r}")
