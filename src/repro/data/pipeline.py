"""The two SoC dataflow modes as host↔device pipelines.

* :class:`ResidentPipeline` — **X-HEEP mode**.  The whole encoded dataset is
  moved to the device once ("the datasets are loaded during the bitfile
  writing stage, implemented directly by initializing the BRAMs"), decoded
  once, and every epoch replays the resident tensors.  Zero host↔device
  traffic after startup; capacity bounded by device memory — exactly the
  trade-off of Table 1 (~100% BRAM).

* :class:`BatchedOffloadPipeline` — **ARM mode**.  The dataset stays on the
  host ("safely stored in the internal memory"); batches of
  ``samples_per_batch`` are offloaded to a device-side buffer, processed,
  and the BATCH_DONE/NEW_BATCH GPIO handshake becomes *double-buffered
  asynchronous prefetch*: while the device consumes batch *k*, the host has
  already issued the transfer of batch *k+1* (``jax.device_put`` is async —
  the dispatch returns before the copy completes, so transfer overlaps
  compute).  Capacity unbounded; steady host↔device traffic — Table 2.

Both yield identical decoded batches, so the controller is mode-agnostic —
the same way the paper's AER decoder serves both SoCs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aer
from repro.core.controller import DeviceBatch, decode_events_to_batch


def event_density(events, n_in: Optional[int] = None,
                  num_ticks: Optional[int] = None) -> float:
    """Measured per-channel event density of AER word buffers: spike words
    per ``(tick, channel)`` slot — the fraction of nonzero raster entries
    the buffers decode to.

    This is the *ground truth* behind the "~2-5% on Braille" figure: the
    traffic gates (``benchmarks/bench_kernels.py``) and the backend's
    dense/event dispatch (:func:`repro.kernels.events.resolve_sparsity`)
    both consume this measurement instead of assuming a constant.

    ``events`` is either a padded ``(S, L)`` uint32 word matrix plus
    explicit ``n_in`` / ``num_ticks``, or a dataset split dict
    ``{"events", "n_in", "num_ticks"}`` as the dataset builders emit
    (:func:`repro.data.braille.make_braille_dataset`,
    :func:`repro.data.cue.make_cue_dataset` — both record the measurement
    as ``split["event_density"]``).  Pad (0x0), label and end words are
    excluded by construction — only ``EVT_SPIKE`` words count.
    """
    if isinstance(events, dict):
        n_in = int(events["n_in"])
        num_ticks = int(events["num_ticks"])
        events = events["events"]
    assert n_in and num_ticks, "need n_in and num_ticks (or a split dict)"
    words = np.asarray(events, np.uint32)
    n_samples = words.shape[0] if words.ndim > 1 else 1
    n_spike = int((((words >> 24) & 0xFF) == aer.EVT_SPIKE).sum())
    return n_spike / float(n_samples * num_ticks * n_in)


@dataclasses.dataclass
class PipelineStats:
    """Telemetry for the resource benchmark (Tables 1/2 analog)."""

    h2d_bytes: int = 0        # host→device traffic issued
    resident_bytes: int = 0   # device-resident dataset footprint
    transfers: int = 0        # number of device_put calls


class _Base:
    def __init__(self, dataset: Dict[str, Dict[str, np.ndarray]], label_delay: int = 0):
        self.dataset = dataset
        self.label_delay = label_delay
        self.stats = PipelineStats()

    def _decode(self, words: jax.Array, meta: Dict) -> DeviceBatch:
        return decode_events_to_batch(
            words, meta["n_in"], meta["num_ticks"], self.label_delay
        )


class ResidentPipeline(_Base):
    """X-HEEP mode: one device_put at construction, epochs replay on device."""

    def __init__(self, dataset, label_delay: int = 0):
        super().__init__(dataset, label_delay)
        self._resident: Dict[str, DeviceBatch] = {}
        for split, d in dataset.items():
            words = jax.device_put(jnp.asarray(d["events"]))
            self.stats.h2d_bytes += d["events"].nbytes
            self.stats.transfers += 1
            batch = self._decode(words, d)
            batch = jax.tree.map(jax.device_put, batch)
            self._resident[split] = batch
            self.stats.resident_bytes += sum(
                x.nbytes for x in jax.tree.leaves(batch)
            ) + d["events"].nbytes

    def batches(self, split: str, epoch: int) -> Iterator[DeviceBatch]:
        if split in self._resident:
            yield self._resident[split]


class BatchedOffloadPipeline(_Base):
    """ARM mode: host-resident dataset, BRAM-sized chunks, async prefetch."""

    def __init__(
        self,
        dataset,
        samples_per_batch: int,
        label_delay: int = 0,
        prefetch: int = 2,
        shuffle_train: bool = False,
        seed: int = 0,
    ):
        super().__init__(dataset, label_delay)
        self.samples_per_batch = samples_per_batch
        self.prefetch = max(1, prefetch)
        self.shuffle_train = shuffle_train
        self._rng = np.random.default_rng(seed)

    def _order(self, split: str, n: int) -> np.ndarray:
        if split == "train" and self.shuffle_train:
            return self._rng.permutation(n)
        return np.arange(n)

    def batches(self, split: str, epoch: int) -> Iterator[DeviceBatch]:
        if split not in self.dataset:
            return
        d = self.dataset[split]
        events = d["events"]
        order = self._order(split, events.shape[0])
        spb = self.samples_per_batch
        chunks = [order[i : i + spb] for i in range(0, len(order), spb)]

        # Double-buffered offload: issue transfer k+1 before yielding k.
        inflight: list = []
        for idx in chunks[: self.prefetch]:
            inflight.append(self._offload(events[idx], d))
        ptr = self.prefetch
        while inflight:
            batch = inflight.pop(0)
            if ptr < len(chunks):
                inflight.append(self._offload(events[chunks[ptr]], d))
                ptr += 1
            yield batch  # NEW_BATCH: device consumes; next copy is in flight

    def _offload(self, chunk: np.ndarray, meta: Dict) -> DeviceBatch:
        words = jax.device_put(jnp.asarray(chunk))   # async dispatch
        self.stats.h2d_bytes += chunk.nbytes
        self.stats.transfers += 1
        return self._decode(words, meta)


class EventStream:
    """Serving-side adapter: a dataset split replayed as ragged per-sample
    AER buffers — the stream of requests a deployed SoC would receive.

    Where the training pipelines above move *batches* toward the device, the
    stream hands out one trimmed uint32 event buffer at a time (trailing 0x0
    pad words stripped), ready for ``repro.serve.BatchedEngine.submit`` /
    ``serve``.  ``repeat`` loops the split to synthesize sustained traffic;
    ``shuffle`` randomizes arrival order per pass.
    """

    def __init__(
        self,
        dataset: Dict[str, Dict[str, np.ndarray]],
        split: str = "test",
        *,
        repeat: int = 1,
        shuffle: bool = False,
        seed: int = 0,
    ):
        assert split in dataset, (split, list(dataset))
        self.meta = dataset[split]
        self.events = np.asarray(self.meta["events"], np.uint32)
        self.repeat = repeat
        self.shuffle = shuffle
        self._rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return self.events.shape[0] * self.repeat

    def __iter__(self) -> Iterator[np.ndarray]:
        from repro.serve.batching import trim_padding

        n = self.events.shape[0]
        for _ in range(self.repeat):
            order = self._rng.permutation(n) if self.shuffle else np.arange(n)
            for i in order:
                yield trim_padding(self.events[i])


def interleave_train_serve(
    pipeline,
    stream,
    epoch: int = 0,
    split: str = "train",
    serve_per_batch: int = 8,
) -> Iterator[tuple]:
    """Online-learning-while-serving feed: the paper's second experiment at
    service scale.

    Yields ``("train", device_batch)`` items from a training pipeline
    interleaved with ``("serve", events)`` request buffers from an
    :class:`EventStream` — the ARM SoC answering live queries between END_B
    commits.  ``serve_per_batch`` requests are released after each training
    batch; leftover requests drain at the end of the epoch.  The consumer
    (see ``examples/serve_braille.py`` and ``tests/test_backend.py``) trains
    an :class:`~repro.core.controller.OnlineLearner` on the train items and
    pushes the serve items through a :class:`repro.serve.BatchedEngine`
    sharing the learner's execution backend.
    """
    requests = iter(stream)
    for batch in pipeline.batches(split, epoch):
        yield ("train", batch)
        for _ in range(serve_per_batch):
            try:
                yield ("serve", next(requests))
            except StopIteration:
                break
    for ev in requests:
        yield ("serve", ev)


def make_pipeline(
    mode: str,
    dataset,
    samples_per_batch: Optional[int] = None,
    label_delay: int = 0,
    **kw,
):
    """Factory keyed on the paper's two controller modes."""
    if mode in ("xheep", "resident"):
        return ResidentPipeline(dataset, label_delay)
    if mode in ("arm", "offload"):
        assert samples_per_batch, "ARM mode needs samples_per_batch (BRAM depth)"
        return BatchedOffloadPipeline(dataset, samples_per_batch, label_delay, **kw)
    raise ValueError(f"unknown pipeline mode {mode!r}")
