from repro.data.cue import CueConfig, make_cue_dataset  # noqa: F401
from repro.data.pipeline import (  # noqa: F401
    BatchedOffloadPipeline,
    ResidentPipeline,
)
