"""Synthetic LM token stream for substrate training runs.

A Zipfian unigram source with a deterministic per-step key — enough to
drive real optimization (losses drop from ln(V) toward the source entropy)
without external data.  The iterator carries an explicit ``position`` so a
restored checkpoint resumes mid-stream (the trainer stores ``data_step``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenStreamConfig:
    vocab: int
    batch: int
    seq_len: int
    zipf_a: float = 1.2
    seed: int = 0
    d_model: int = 0           # for media/src stubs
    family: str = "dense"
    n_media_tokens: int = 0


class TokenStream:
    def __init__(self, cfg: TokenStreamConfig, position: int = 0):
        self.cfg = cfg
        self.position = position
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = (p / p.sum()).astype(np.float64)

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, self.position))
        self.position += 1
        toks = rng.choice(cfg.vocab, size=(cfg.batch, cfg.seq_len + 1), p=self._p)
        batch = {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "targets": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        if cfg.family == "vlm":
            batch["media"] = jnp.asarray(
                rng.standard_normal((cfg.batch, cfg.n_media_tokens, cfg.d_model)) * 0.02,
                jnp.float32,
            )
        if cfg.family == "audio":
            batch["src_embeds"] = jnp.asarray(
                rng.standard_normal((cfg.batch, cfg.seq_len, cfg.d_model)) * 0.02,
                jnp.float32,
            )
        return batch
