"""Mamba2 — SSD (state-space duality) layer, chunked for the MXU.

Training/prefill uses the chunked SSD algorithm (Dao & Gu, 2024): the
sequence is tiled into chunks of ``chunk`` steps; within-chunk interactions
are a masked (decay-weighted) attention-like batched matmul, across-chunk
interactions ride a tiny ``lax.scan`` over per-chunk states.  Everything
heavy is an einsum → MXU-friendly, no per-step recurrence.

Decode holds the recurrent state explicitly: ``state ← exp(dt·A)·state +
dt·B·x`` per token — O(1) in sequence length, which is what makes the
``long_500k`` shape tractable for the SSM/hybrid architectures.

Sharding: ``d_inner`` (and the SSD heads it decomposes into) over ``model``;
B/C projections are per-group (n_groups=1) and replicated — they are
``d_state``-sized, tiny next to ``d_inner``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import const_param, make_param, rms_norm


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 64
    compute_dtype: str = "float32"  # §Perf lever: bf16 for the O(Q²) SSD
                                    # intermediates (decay/score tensors)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


def init_mamba(key: jax.Array, cfg) -> Dict[str, Any]:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.n_heads(d)
    gn = 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 8)

    def dt_init():
        dt0 = jnp.exp(
            jax.random.uniform(ks[6], (h,), jnp.float32)
            * (jnp.log(0.1) - jnp.log(0.001))
            + jnp.log(0.001)
        )
        return dt0 + jnp.log(-jnp.expm1(-dt0))          # softplus^-1

    return {
        "w_x": make_param(ks[0], (d, di), ("embed", "ssm_inner"), cfg.np_dtype),
        "w_z": make_param(ks[1], (d, di), ("embed", "ssm_inner"), cfg.np_dtype),
        "w_bc": make_param(ks[2], (d, gn), ("embed", None), cfg.np_dtype),
        "w_dt": make_param(ks[3], (d, h), ("embed", "ssm_heads"), cfg.np_dtype),
        "dt_bias": const_param((h,), ("ssm_heads",), jnp.float32, dt_init),
        "a_log": const_param((h,), ("ssm_heads",), jnp.float32, 0.0),
        "d_skip": const_param((h,), ("ssm_heads",), jnp.float32, 1.0),
        "conv_x": make_param(ks[4], (s.d_conv, di), (None, "ssm_inner"), cfg.np_dtype,
                             scale=s.d_conv ** -0.5),
        "conv_bc": make_param(ks[5], (s.d_conv, gn), (None, None), cfg.np_dtype,
                              scale=s.d_conv ** -0.5),
        "norm": const_param((di,), ("norm",), cfg.np_dtype, 1.0),
        "w_out": make_param(ks[7], (di, d), ("ssm_inner", "embed"), cfg.np_dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, tail: Optional[jax.Array] = None):
    """Depthwise causal conv along seq.  x: (B,S,C); w: (K,C).

    Returns (y, new_tail) where tail carries the last K-1 inputs for decode.
    """
    K = w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+K-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), xp[:, -(K - 1):, :]


def _ssd_chunked(
    xh: jax.Array,    # (B,S,H,P)
    dt: jax.Array,    # (B,S,H)   f32, post-softplus
    a: jax.Array,     # (H,)      f32, negative
    B_: jax.Array,    # (B,S,G,N)
    C_: jax.Array,    # (B,S,G,N)
    chunk: int,
    init_state: Optional[jax.Array] = None,   # (B,H,P,N)
    compute_dtype=jnp.float32,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S0, H, Pd = xh.shape
    G, N = B_.shape[2], B_.shape[3]
    # Ragged lengths: pad with dt=0 steps (decay 1, increment 0 — state
    # passes through unchanged); padded outputs are sliced off below.
    S = -(-S0 // chunk) * chunk
    if S != S0:
        pad = ((0, 0), (0, S - S0), (0, 0), (0, 0))
        xh = jnp.pad(xh, pad)
        dt = jnp.pad(dt, ((0, 0), (0, S - S0), (0, 0)))
        B_ = jnp.pad(B_, pad)
        C_ = jnp.pad(C_, pad)
    nc = S // chunk
    hg = H // G                                        # heads per group

    r = lambda t, extra: t.reshape(B, nc, chunk, *extra)
    xh_c = r(xh, (H, Pd))
    dt_c = r(dt, (H,)).astype(jnp.float32)
    b_c = r(B_, (G, N))
    c_c = r(C_, (G, N))

    da = dt_c * a                                       # (B,nc,Q,H)
    cum = jnp.cumsum(da, axis=2)                        # within-chunk cumsum
    # Within-chunk decay matrix L[i,j] = exp(cum_i - cum_j), lower-triangular.
    # The O(Q²) tensors may run in bf16 (§Perf lever) — the cross-chunk
    # recurrence below stays f32 for stability.
    cdt = jnp.dtype(compute_dtype)
    cum_c = cum.astype(cdt)              # cast BEFORE the O(Q²) broadcast,
    seg = cum_c[:, :, :, None, :] - cum_c[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), jnp.zeros((), cdt))

    # Diagonal (within-chunk) term: scores over the group, decayed per head.
    scores = jnp.einsum("bcign,bcjgn->bcijg", c_c.astype(cdt), b_c.astype(cdt),
                        preferred_element_type=cdt)
    scores_h = scores[..., :, None].repeat(hg, axis=-1).reshape(
        B, nc, chunk, chunk, H
    )
    w_diag = scores_h * L * dt_c[:, :, None, :, :].astype(cdt)  # (B,nc,Q,Q,H)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w_diag, xh_c.astype(cdt),
                        preferred_element_type=jnp.float32)

    # Per-chunk input state: decay-to-end weighted sum of B x^T.
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (B,nc,Q,H)
    b_h = b_c[..., :, None, :].repeat(hg, axis=-2).reshape(B, nc, chunk, H, N)
    bx = jnp.einsum(
        "bcjhn,bcjhp->bchpn",
        b_h.astype(jnp.float32) * (dt_c * decay_end)[..., None],
        xh_c.astype(jnp.float32),
    )

    # Inter-chunk recurrence over per-chunk states — an associative
    # (decay, increment) scan: s_c = d_c · s_{c-1} + b_c.  associative_scan
    # lowers to a log-depth vectorized program (no while loop): better for
    # the TPU schedule and fully visible to HLO cost analysis.
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (B,nc,H)

    def combine(a, b):
        (da, sa), (db, sb) = a, b
        return da * db, sa * db + sb

    d_full = chunk_decay[:, :, :, None, None]           # (B,nc,H,1,1)
    dd, ss = jax.lax.associative_scan(combine, (d_full, bx), axis=1)
    s0 = (
        jnp.zeros((B, H, Pd, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    # states AFTER chunk c (inclusive); previous-state view shifts by one.
    states_inc = ss + dd * s0[:, None]
    final = states_inc[:, -1]
    prev_states = jnp.concatenate(
        [s0[:, None], states_inc[:, :-1]], axis=1
    )                                                   # (B,nc,H,P,N)

    # Off-diagonal term: contribution of previous chunks' states.
    c_h = c_c[..., :, None, :].repeat(hg, axis=-2).reshape(B, nc, chunk, H, N)
    y_off = jnp.einsum(
        "bcihn,bchpn->bcihp",
        c_h.astype(jnp.float32) * jnp.exp(cum)[..., None],
        prev_states,
    )
    y = (y_diag + y_off).reshape(B, S, H, Pd)[:, :S0]
    return y, final


def mamba_forward(
    p: Dict,
    x: jax.Array,
    cfg,
    *,
    cache: Optional[Dict] = None,
    pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Mamba2 block.  Without cache: chunked SSD over the whole sequence.
    With cache: one-token recurrent update (decode)."""
    s: SSMConfig = cfg.ssm
    B, S, D = x.shape
    di = s.d_inner(D)
    H = s.n_heads(D)
    G, N, Pd = s.n_groups, s.d_state, s.head_dim

    xz = x @ p["w_x"]
    z = x @ p["w_z"]
    bc_raw = x @ p["w_bc"]
    dt = jax.nn.softplus(
        (x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"]
    )                                                   # (B,S,H)
    a = -jnp.exp(p["a_log"])                            # (H,) negative

    xz = shard(xz, "batch", "act_seq", "act_ssm_inner")
    z = shard(z, "batch", "act_seq", "act_ssm_inner")

    if cache is None:
        xc, tail_x = _causal_conv(xz, p["conv_x"])
        bc, tail_bc = _causal_conv(bc_raw, p["conv_bc"])
        B_ = bc[..., : G * N].reshape(B, S, G, N)
        C_ = bc[..., G * N :].reshape(B, S, G, N)
        xh = xc.reshape(B, S, H, Pd)
        xh = shard(xh, "batch", "act_seq", "act_ssm_heads", None)
        y, state = _ssd_chunked(xh, dt, a, B_, C_, s.chunk,
                                compute_dtype=s.compute_dtype)
        y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
        new_cache = None
        if cfg.return_cache:
            new_cache = {"conv_x": tail_x, "conv_bc": tail_bc,
                         "state": state.astype(jnp.float32)}
    else:
        xc, tail_x = _causal_conv(xz, p["conv_x"], cache["conv_x"])
        bc, tail_bc = _causal_conv(bc_raw, p["conv_bc"], cache["conv_bc"])
        B_ = bc[..., : G * N].reshape(B, S, G, N)
        C_ = bc[..., G * N :].reshape(B, S, G, N)
        xh = xc.reshape(B, S, H, Pd)
        # One-step recurrence (S == 1).
        da = jnp.exp(dt[:, 0] * a)                      # (B,H)
        b_h = B_[:, 0, :, None, :].repeat(H // G, axis=-2).reshape(B, H, N)
        c_h = C_[:, 0, :, None, :].repeat(H // G, axis=-2).reshape(B, H, N)
        inc = jnp.einsum(
            "bhp,bhn->bhpn", (dt[:, 0, :, None] * xh[:, 0].astype(jnp.float32)),
            b_h.astype(jnp.float32),
        )
        state = cache["state"] * da[:, :, None, None] + inc
        y = jnp.einsum("bhpn,bhn->bhp", state, c_h.astype(jnp.float32))
        y = (y + p["d_skip"][None, :, None] * xh[:, 0].astype(jnp.float32))[:, None]
        new_cache = {"conv_x": tail_x, "conv_bc": tail_bc, "state": state}

    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"], cfg.norm_eps)
    y = shard(y, "batch", "act_seq", "act_ssm_inner")
    out = y @ p["w_out"]
    return shard(out, "batch", "act_seq", "act_embed"), new_cache


def mamba_cache_spec(cfg, batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
    s: SSMConfig = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    gn = 2 * s.n_groups * s.d_state
    return {
        "conv_x": jax.ShapeDtypeStruct((batch, s.d_conv - 1, di), cfg.np_dtype),
        "conv_bc": jax.ShapeDtypeStruct((batch, s.d_conv - 1, gn), cfg.np_dtype),
        "state": jax.ShapeDtypeStruct(
            (batch, H, s.head_dim, s.d_state), jnp.float32
        ),
    }


MAMBA_CACHE_AXES = {
    "conv_x": ("batch", None, "act_ssm_inner"),
    "conv_bc": ("batch", None, None),
    "state": ("batch", "act_ssm_heads", None, None),
}
