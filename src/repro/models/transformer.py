"""Layer plans and stacks — one engine for all ten assigned architectures.

A stack is described by a :class:`Plan`: an unrolled ``prefix`` (e.g.
DeepSeek-V2's dense first layer) plus a repeated ``period`` of layers that
runs under ``lax.scan`` (scan-over-layers keeps the HLO a single-layer
program regardless of depth — essential for 100-layer dry-run compiles).
Heterogeneous schedules (Jamba's mamba:attn 7:1 interleave with MoE every
2nd layer; the VLM's cross-attention every 5th layer) are expressed as a
multi-layer period, so the scanned unit is always structurally homogeneous.

Layer kinds are ``(mixer, ffn)`` pairs:
  mixer ∈ {"attn", "attn_enc", "mamba", "xattn", "attn_xattn"}
  ffn   ∈ {"dense", "moe", "none"}
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models.layers import (
    abstract_init,
    init_mlp,
    init_rms_norm,
    is_abstract,
    make_param,
    mlp_forward,
    rms_norm,
    split_tree,
)

Kind = Tuple[str, str]


@dataclasses.dataclass(frozen=True)
class Plan:
    prefix: Tuple[Kind, ...]
    period: Tuple[Kind, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.prefix) + len(self.period) * self.repeats


def layer_plan(cfg) -> Plan:
    plan = _layer_plan(cfg)
    if not cfg.scan_layers:
        layers = plan.prefix + plan.period * plan.repeats
        return Plan(tuple(layers), (), 0)
    return plan


def _layer_plan(cfg) -> Plan:
    if cfg.family == "ssm":
        return Plan((), (("mamba", "none"),), cfg.n_layers)
    if cfg.family == "hybrid":
        per = cfg.attn_every
        if cfg.n_layers % per != 0:
            raise ValueError(
                f"n_layers={cfg.n_layers} must divide into attn_every={per}"
            )
        period = []
        for i in range(per):
            mixer = "attn" if i == cfg.attn_offset else "mamba"
            ffn = "dense"
            if cfg.moe is not None and i % cfg.moe.moe_every == cfg.moe.moe_every - 1:
                ffn = "moe"
            period.append((mixer, ffn))
        return Plan((), tuple(period), cfg.n_layers // per)
    if cfg.family == "vlm":
        per = cfg.cross_attn_every
        if cfg.n_layers % per != 0:
            raise ValueError(
                f"n_layers={cfg.n_layers} must divide into "
                f"cross_attn_every={per}"
            )
        period = [("xattn", "dense")] + [("attn", "dense")] * (per - 1)
        return Plan((), tuple(period), cfg.n_layers // per)
    if cfg.family == "moe":
        if cfg.moe.first_dense:
            return Plan((("attn", "dense"),), (("attn", "moe"),), cfg.n_layers - 1)
        return Plan((), (("attn", "moe"),), cfg.n_layers)
    if cfg.family == "audio":
        return Plan((), (("attn_xattn", "dense"),), cfg.n_layers)
    return Plan((), (("attn", "dense"),), cfg.n_layers)  # dense


def encoder_plan(cfg) -> Optional[Plan]:
    if not cfg.encdec:
        return None
    if not cfg.scan_layers:
        return Plan((("attn_enc", "dense"),) * cfg.n_enc_layers, (), 0)
    return Plan((), (("attn_enc", "dense"),), cfg.n_enc_layers)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_layer(key: jax.Array, cfg, kind: Kind) -> Dict[str, Any]:
    mixer, ffn = kind
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": init_rms_norm(d, cfg.np_dtype)}
    if mixer in ("attn", "attn_xattn"):
        p["mixer"] = (
            attn.init_mla(ks[0], cfg) if cfg.mla is not None else attn.init_gqa(ks[0], cfg)
        )
    elif mixer == "attn_enc":
        p["mixer"] = attn.init_gqa(ks[0], cfg)
    elif mixer == "mamba":
        p["mixer"] = mb.init_mamba(ks[0], cfg)
    elif mixer == "xattn":
        p["mixer"] = attn.init_cross_attn(ks[0], cfg)
    else:
        raise ValueError(mixer)
    if mixer == "attn_xattn":
        p["ln_x"] = init_rms_norm(d, cfg.np_dtype)
        p["xattn"] = attn.init_cross_attn(ks[1], cfg)
    if ffn == "dense":
        p["ln2"] = init_rms_norm(d, cfg.np_dtype)
        p["ffn"] = init_mlp(ks[2], d, cfg.d_ff, cfg.np_dtype)
    elif ffn == "moe":
        p["ln2"] = init_rms_norm(d, cfg.np_dtype)
        p["ffn"] = moe_mod.init_moe(ks[2], cfg)
    return p


def _stack_layers(trees: List[Any]):
    """Stack per-repeat param trees along a new leading 'layers' dim."""

    def stk(*leaves):
        vals = [l[0] for l in leaves]
        axes = leaves[0][1]
        if isinstance(vals[0], jax.ShapeDtypeStruct):
            v = jax.ShapeDtypeStruct((len(vals), *vals[0].shape), vals[0].dtype)
        else:
            v = jnp.stack(vals)
        return (v, ("layers", *axes))

    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and not isinstance(x[0], dict)
    return jax.tree.map(stk, *trees, is_leaf=is_leaf)


def init_stack(key: jax.Array, cfg, plan: Plan) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    keys = jax.random.split(key, max(len(plan.prefix) + plan.repeats, 1))
    out["prefix"] = [
        init_layer(keys[i], cfg, kind) for i, kind in enumerate(plan.prefix)
    ]

    if plan.repeats:
        n_pref = len(plan.prefix)

        def one_repeat(k):
            return {
                str(j): init_layer(jax.random.fold_in(k, j), cfg, kind)
                for j, kind in enumerate(plan.period)
            }

        if is_abstract():
            rep = one_repeat(keys[n_pref])
            out["scan"] = _stack_layers([rep] * plan.repeats)
        else:
            out["scan"] = _stack_layers(
                [one_repeat(keys[n_pref + r]) for r in range(plan.repeats)]
            )
    else:
        out["scan"] = {}
    return out


def init_model_tree(key: jax.Array, cfg) -> Dict[str, Any]:
    """Full parameter tree with (value, logical-axes) leaves."""
    k_emb, k_head, k_dec, k_enc = jax.random.split(key, 4)
    tree: Dict[str, Any] = {
        "embed": make_param(k_emb, (cfg.vocab, cfg.d_model), ("vocab", "embed"),
                            cfg.np_dtype, scale=0.02),
        "ln_f": init_rms_norm(cfg.d_model, cfg.np_dtype),
        "layers": init_stack(k_dec, cfg, layer_plan(cfg)),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = make_param(
            k_head, (cfg.d_model, cfg.vocab), ("embed", "vocab"), cfg.np_dtype
        )
    eplan = encoder_plan(cfg)
    if eplan is not None:
        tree["encoder"] = init_stack(k_enc, cfg, eplan)
        tree["enc_ln_f"] = init_rms_norm(cfg.d_model, cfg.np_dtype)
    return tree


def init_model(key: jax.Array, cfg):
    """Returns (params, specs)."""
    return split_tree(init_model_tree(key, cfg))


def abstract_model(cfg):
    """(ShapeDtypeStruct tree, specs tree) without touching device memory."""
    with abstract_init():
        return init_model(jax.random.key(0), cfg)


def count_params(cfg, active_only: bool = False) -> int:
    params, specs = abstract_model(cfg)
    total = 0
    for leaf, ax in zip(jax.tree.leaves(params), jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, tuple))):
        n = 1
        for s in leaf.shape:
            n *= s
        if active_only and "experts" in ax and cfg.moe is not None:
            n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n
    return total


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def block_forward(
    kind: Kind,
    p: Dict[str, Any],
    x: jax.Array,
    cfg,
    *,
    memory: Optional[jax.Array] = None,
    cache: Optional[Dict] = None,
    pos: Optional[jax.Array] = None,
):
    """One layer.  Returns (x, new_cache | None, aux_loss)."""
    mixer, ffn = kind
    aux = jnp.zeros((), jnp.float32)
    c_in = cache or {}
    new_cache: Dict[str, Any] = {}

    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer in ("attn", "attn_xattn"):
        if cfg.mla is not None:
            y, c = attn.mla_forward(p["mixer"], h, cfg, cache=c_in.get("mixer"), pos=pos)
        else:
            y, c = attn.gqa_forward(
                p["mixer"], h, cfg, causal=True, cache=c_in.get("mixer"), pos=pos
            )
    elif mixer == "attn_enc":
        y, c = attn.gqa_forward(p["mixer"], h, cfg, causal=False, cache=None, pos=None)
    elif mixer == "mamba":
        y, c = mb.mamba_forward(p["mixer"], h, cfg, cache=c_in.get("mixer"), pos=pos)
    elif mixer == "xattn":
        y, c = attn.cross_attn_forward(p["mixer"], h, memory, cfg, cache=c_in.get("mixer"))
    else:
        raise ValueError(mixer)
    x = x + y
    if c is not None:
        new_cache["mixer"] = c

    if mixer == "attn_xattn":
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        yx, cx = attn.cross_attn_forward(p["xattn"], hx, memory, cfg, cache=c_in.get("xattn"))
        x = x + yx
        if cx is not None:
            new_cache["xattn"] = cx

    if ffn == "dense":
        x = x + mlp_forward(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    elif ffn == "moe":
        y2, a = moe_mod.moe_forward(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        x = x + y2
        aux = aux + a
    return x, (new_cache or None), aux


def _remat(fn, cfg):
    if not cfg.remat:
        return fn
    if getattr(cfg, "remat_policy", "full") == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn, policy=None)


def stack_forward(
    stack_params: Dict[str, Any],
    x: jax.Array,
    cfg,
    plan: Plan,
    *,
    memory: Optional[jax.Array] = None,
    caches: Optional[Dict] = None,
    pos: Optional[jax.Array] = None,
):
    """Run a stack.  Returns (x, new_caches | None, aux).

    ``caches`` layout: {"prefix": [per-layer], "scan": stacked-per-repeat}.
    Modes: train (no caches in/out) / prefill (cfg.return_cache) / decode
    (caches given).
    """
    aux = jnp.zeros((), jnp.float32)
    want_cache = cfg.return_cache or caches is not None
    new_caches: Dict[str, Any] = {"prefix": [], "scan": None}

    for i, kind in enumerate(plan.prefix):
        c = caches["prefix"][i] if caches is not None else None
        if cfg.remat and not want_cache and c is None:
            def one(p, xx, mem, _kind=kind):
                y, _, a = block_forward(_kind, p, xx, cfg, memory=mem)
                return y, a

            x, a = _remat(one, cfg)(stack_params["prefix"][i], x, memory)
            nc = None
        else:
            x, nc, a = block_forward(
                kind, stack_params["prefix"][i], x, cfg, memory=memory, cache=c, pos=pos
            )
        new_caches["prefix"].append(nc)
        aux = aux + a

    if plan.repeats:
        def period_fn(x, layer_p, layer_c):
            ncs = {}
            aux_l = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(plan.period):
                cj = layer_c[str(j)] if layer_c is not None else None
                x, nc, a = block_forward(
                    kind, layer_p[str(j)], x, cfg, memory=memory, cache=cj, pos=pos
                )
                ncs[str(j)] = nc
                aux_l = aux_l + a
            return x, ncs, aux_l

        if not want_cache:
            def body(carry, layer_p):
                xx, acc = carry
                xx, _, a = period_fn(xx, layer_p, None)
                return (xx, acc + a), None

            body = _remat(body, cfg)
            (x, aux), _ = jax.lax.scan(body, (x, aux), stack_params["scan"])
        elif caches is None:      # prefill: build caches
            def body(xx, layer_p):
                xx, ncs, _ = period_fn(xx, layer_p, None)
                return xx, ncs

            x, scan_caches = jax.lax.scan(body, x, stack_params["scan"])
            new_caches["scan"] = scan_caches
        else:                     # decode: thread caches
            def body(xx, ps_cs):
                layer_p, layer_c = ps_cs
                xx, ncs, _ = period_fn(xx, layer_p, layer_c)
                return xx, ncs

            x, scan_caches = jax.lax.scan(
                body, x, (stack_params["scan"], caches["scan"])
            )
            new_caches["scan"] = scan_caches

    return x, (new_caches if want_cache else None), aux


# ---------------------------------------------------------------------------
# cache specs (ShapeDtypeStructs + logical axes) for serve-mode dry-runs
# ---------------------------------------------------------------------------


def _layer_cache_spec(cfg, kind: Kind, batch: int, max_len: int, mem_len: int):
    mixer, _ = kind
    spec: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    if mixer in ("attn", "attn_xattn"):
        if cfg.mla is not None:
            spec["mixer"] = attn.mla_cache_spec(cfg, batch, max_len)
            axes["mixer"] = attn.MLA_CACHE_AXES
        else:
            spec["mixer"] = attn.gqa_cache_spec(cfg, batch, max_len)
            axes["mixer"] = attn.GQA_CACHE_AXES
    elif mixer == "mamba":
        spec["mixer"] = mb.mamba_cache_spec(cfg, batch)
        axes["mixer"] = mb.MAMBA_CACHE_AXES
    elif mixer == "xattn":
        spec["mixer"] = attn.cross_cache_spec(cfg, batch, mem_len)
        axes["mixer"] = attn.CROSS_CACHE_AXES
    if mixer == "attn_xattn":
        spec["xattn"] = attn.cross_cache_spec(cfg, batch, mem_len)
        axes["xattn"] = attn.CROSS_CACHE_AXES
    return spec, axes


def stack_cache_specs(cfg, plan: Plan, batch: int, max_len: int, mem_len: int = 0):
    spec: Dict[str, Any] = {"prefix": [], "scan": None}
    axes: Dict[str, Any] = {"prefix": [], "scan": None}
    for kind in plan.prefix:
        s, a = _layer_cache_spec(cfg, kind, batch, max_len, mem_len)
        spec["prefix"].append(s)
        axes["prefix"].append(a)
    if plan.repeats:
        per_s, per_a = {}, {}
        for j, kind in enumerate(plan.period):
            s, a = _layer_cache_spec(cfg, kind, batch, max_len, mem_len)
            per_s[str(j)], per_a[str(j)] = s, a
        spec["scan"] = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((plan.repeats, *sd.shape), sd.dtype),
            per_s,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        axes["scan"] = jax.tree.map(
            lambda ax: ("layers", *ax),
            per_a,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x
            ),
        )
    return spec, axes
