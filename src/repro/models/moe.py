"""Mixture-of-Experts FFN with capacity-based dispatch + expert parallelism.

Routing is top-k over a learned router; dispatch is the sort-based
"dropped-token" scheme (Megablocks/Switch style) with static shapes:

  1. expand tokens × top-k hits, stable-sort by expert id;
  2. slot = rank within the expert group (cummax trick); hits beyond the
     per-expert ``capacity`` are dropped;
  3. scatter into an (E, C, D) buffer, run all experts as one batched
     einsum (MXU-friendly), gather back with gate weighting.

Compiled FLOPs therefore scale with ``tokens × top_k × capacity_factor`` —
NOT ``tokens × n_experts`` — which keeps the §Roofline
``MODEL_FLOPS/HLO_FLOPs`` ratio honest.

**Expert parallelism**: inside a mesh context the FFN runs under
``shard_map``; experts are sharded over the ``model`` axis, every rank
dispatches only the hits of its local experts (activations are replicated
across ``model`` between layers, so no all-to-all is needed on the way in),
and the combine is a single ``psum`` over ``model`` — the same collective a
tensor-parallel dense FFN would issue.  Without a mesh the same code runs
single-device (smoke tests).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.distributed.sharding import current_mesh, shard
from repro.models.layers import make_param


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 1024
    n_shared: int = 0            # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    aux_coef: float = 0.01       # load-balance loss coefficient
    z_coef: float = 1e-3         # router z-loss
    moe_every: int = 1           # FFN is MoE on layers where idx % moe_every == 0
    first_dense: bool = False    # layer 0 uses a dense FFN (DeepSeek-V2)
    use_shard_map: bool = False  # manual EP over 'model' (psum combine).
                                 # Preferred on TPU; default off because
                                 # XLA-CPU's AllReducePromotion pass crashes
                                 # on the emitted reducer (DESIGN.md §2).
    dispatch_groups: int = 0     # §Perf: >0 = dp-grouped dispatch — sort/
                                 # capacity computed per data-shard group so
                                 # no token array crosses the data axis
                                 # (kills the global-sort all-gathers).


def init_moe(key: jax.Array, cfg) -> Dict[str, Any]:
    m: MoEConfig = cfg.moe
    d, e, f = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 7)
    p = {
        "w_router": make_param(ks[0], (d, e), ("embed", "experts"), jnp.float32),
        "w_gate": make_param(ks[1], (e, d, f), ("experts", "embed", "expert_mlp"), cfg.np_dtype),
        "w_up": make_param(ks[2], (e, d, f), ("experts", "embed", "expert_mlp"), cfg.np_dtype),
        "w_down": make_param(
            ks[3], (e, f, d), ("experts", "expert_mlp", "embed"), cfg.np_dtype,
            scale=f ** -0.5,
        ),
    }
    if m.n_shared:
        fs = m.n_shared * f
        p["shared"] = {
            "w_gate": make_param(ks[4], (d, fs), ("embed", "mlp"), cfg.np_dtype),
            "w_up": make_param(ks[5], (d, fs), ("embed", "mlp"), cfg.np_dtype),
            "w_down": make_param(ks[6], (fs, d), ("mlp", "embed"), cfg.np_dtype, scale=fs ** -0.5),
        }
    return p


def _route(x32: jax.Array, w_router: jax.Array, top_k: int):
    """Returns (gates (N,k), experts (N,k), aux losses). x32: (N, D) f32."""
    logits = x32 @ w_router                       # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)  # (N, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + z-loss.
    e = w_router.shape[1]
    density = jnp.mean(
        jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32), axis=0
    )
    mean_prob = probs.mean(axis=0)
    aux = e * jnp.sum(density * mean_prob)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return gates.astype(jnp.float32), experts, aux, z


def _dispatch_ffn(
    x: jax.Array,          # (N, D) local tokens (model-replicated)
    gates: jax.Array,      # (N, k) f32
    experts: jax.Array,    # (N, k) int — GLOBAL expert ids
    w_gate: jax.Array,     # (E_local, D, F)
    w_up: jax.Array,
    w_down: jax.Array,
    e_offset: jax.Array,   # first global expert id owned locally
    capacity: int,
) -> jax.Array:
    """Sort-based dispatch → batched expert FFN → weighted combine."""
    n, k = experts.shape
    e_local = w_gate.shape[0]
    flat_e = experts.reshape(-1) - e_offset               # (N*k,)
    flat_gate = gates.reshape(-1)
    flat_src = jnp.repeat(jnp.arange(n), k)
    valid = (flat_e >= 0) & (flat_e < e_local)
    sort_key = jnp.where(valid, flat_e, e_local)          # invalid → sentinel
    order = jnp.argsort(sort_key, stable=True)
    s_e = sort_key[order]
    idx = jnp.arange(n * k)
    is_start = jnp.concatenate([jnp.ones((1,), bool), s_e[1:] != s_e[:-1]])
    group_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    slot = idx - group_start
    ok = (s_e < e_local) & (slot < capacity)
    dest = jnp.where(ok, s_e * capacity + slot, e_local * capacity)

    buf = jnp.zeros((e_local * capacity + 1, x.shape[-1]), x.dtype)
    buf = buf.at[dest].set(x[flat_src[order]], mode="drop")
    buf = buf[:-1].reshape(e_local, capacity, -1)         # (E_local, C, D)
    buf = shard(buf, "act_expert", None, None)

    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, w_gate, preferred_element_type=jnp.float32)
    ).astype(x.dtype) * jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = shard(h, "act_expert", None, None)
    out = jnp.einsum("ecf,efd->ecd", h, w_down)           # (E_local, C, D)

    out_rows = out.reshape(e_local * capacity, -1)
    picked = jnp.where(
        ok[:, None], out_rows[jnp.minimum(dest, e_local * capacity - 1)], 0.0
    )
    y = jnp.zeros_like(x, shape=(n, x.shape[-1]))
    y = y.at[flat_src[order]].add(
        picked * flat_gate[order][:, None].astype(x.dtype)
    )
    return y


def moe_forward(p: Dict, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) → (y, aux_loss).  EP over 'model' when a mesh is active."""
    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    n = B * S
    capacity = max(8, int(n * m.top_k * m.capacity_factor / m.n_experts))
    mesh = current_mesh()

    def local(x_l, w_router, w_gate, w_up, w_down, e_offset):
        xf = x_l.reshape(-1, D)
        gates, experts, aux, z = _route(xf.astype(jnp.float32), w_router, m.top_k)
        cap = max(8, int(xf.shape[0] * m.top_k * m.capacity_factor / m.n_experts))
        y = _dispatch_ffn(
            xf, gates, experts, w_gate, w_up, w_down, e_offset, cap
        )
        return y.reshape(x_l.shape), aux + m.z_coef / max(m.aux_coef, 1e-9) * z

    if m.dispatch_groups and (mesh is None or not m.use_shard_map):
        # dp-grouped dispatch: tokens reshaped (G, n/G, D) with G sharded
        # over the data axes; sort, capacity and scatter are group-local, so
        # GSPMD never moves token arrays across `data` — only the expert
        # einsum and its combine cross `model`.
        G = m.dispatch_groups
        n_flat = B * S
        if n_flat % G != 0:
            raise ValueError(
                f"{n_flat} tokens do not split into dispatch_groups={G}"
            )
        xg = x.reshape(G, n_flat // G, D)
        xg = shard(xg, "batch", None, None)

        def group_fn(x_l):
            return local(
                x_l[None], p["w_router"], p["w_gate"], p["w_up"], p["w_down"],
                jnp.int32(0),
            )

        yg, auxg = jax.vmap(group_fn)(xg)
        y = shard(yg, "batch", None, None, None).reshape(B, S, D)
        aux = auxg.mean()
    elif mesh is None or "model" not in mesh.axis_names or not m.use_shard_map:
        # GSPMD path: experts sharded over `model` via the param specs and
        # the act_expert constraints on the dispatch buffers; GSPMD derives
        # the dispatch/combine collectives.
        y, aux = local(
            x, p["w_router"], p["w_gate"], p["w_up"], p["w_down"], jnp.int32(0)
        )
    else:
        n_model = mesh.shape["model"]
        if m.n_experts % n_model != 0:
            raise ValueError(
                f"{m.n_experts} experts do not shard over "
                f"model axis of {n_model}"
            )
        e_local = m.n_experts // n_model

        def ranked(x_l, w_router, w_gate, w_up, w_down):
            rank = jax.lax.axis_index("model")
            y, aux = local(x_l, w_router, w_gate, w_up, w_down, rank * e_local)
            # f32 combine: numerically safer for k-way partial sums, and
            # sidesteps XLA-CPU's bf16 AllReducePromotion crash.
            y = jax.lax.psum(y.astype(jnp.float32), "model").astype(x_l.dtype)
            aux = jax.lax.pmean(aux, "model")
            return y, aux

        # Only "model" goes manual; pod/data stay under GSPMD ("auto").
        # check_vma=True tracks replication properly — without it shard_map
        # emits a copy-reducer all-reduce that XLA-CPU's promotion pass
        # cannot clone for the bf16 cotangents.
        y, aux = shard_map(
            ranked,
            mesh=mesh,
            axis_names={"model"},
            in_specs=(
                P(None, None, None),
                P(None, None),
                P("model", None, None),
                P("model", None, None),
                P("model", None, None),
            ),
            out_specs=(P(None, None, None), P()),
            check_vma=True,
        )(x, p["w_router"], p["w_gate"], p["w_up"], p["w_down"])

    if "shared" in p:
        sp = p["shared"]
        h = jax.nn.silu((x @ sp["w_gate"]).astype(jnp.float32)).astype(x.dtype) * (
            x @ sp["w_up"]
        )
        h = shard(h, "batch", "act_seq", "act_mlp")
        y = y + h @ sp["w_down"]
    return shard(y, "batch", "act_seq", "act_embed"), m.aux_coef * aux


def moe_forward_dense_ref(p: Dict, x: jax.Array, cfg) -> jax.Array:
    """Oracle: every expert computed for every token, exact soft combine with
    the same top-k gates (no capacity drops).  Used by tests to validate the
    dispatch path (with capacity_factor high enough that nothing drops)."""
    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    gates, experts, _, _ = _route(xf.astype(jnp.float32), p["w_router"], m.top_k)
    h = jax.nn.silu(
        jnp.einsum("nd,edf->nef", xf, p["w_gate"], preferred_element_type=jnp.float32)
    ).astype(x.dtype) * jnp.einsum("nd,edf->nef", xf, p["w_up"])
    out_all = jnp.einsum("nef,efd->ned", h, p["w_down"])    # (N, E, D)
    sel = jnp.take_along_axis(out_all, experts[..., None], axis=1)  # (N, k, D)
    y = (sel * gates[..., None].astype(x.dtype)).sum(axis=1)
    if "shared" in p:
        sp = p["shared"]
        hs = jax.nn.silu((xf @ sp["w_gate"]).astype(jnp.float32)).astype(x.dtype) * (
            xf @ sp["w_up"]
        )
        y = y + hs @ sp["w_down"]
    return y.reshape(B, S, D)
