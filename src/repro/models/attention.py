"""Attention: GQA / MLA / cross-attention with blocked (flash) softmax.

Three execution modes per layer:

* ``train`` / ``prefill``  — full-sequence blocked attention
  (:func:`blocked_attention`): online-softmax over KV tiles, O(S·block)
  activation memory, never materialises the (S×S) score matrix.  With
  ``prune_causal=True`` the query-tile loop is unrolled and each tile only
  visits KV tiles up to the diagonal — halving attention FLOPs (a §Perf
  hillclimb lever; the masked variant is the simple baseline).
* ``decode`` — single new token against a KV cache
  (:func:`decode_attention`), with the cache length masked by ``pos``.

MLA (DeepSeek-V2) caches the *compressed* ``c_kv`` + rope key and uses the
absorbed-matrix formulation at decode time: attention runs in the
``kv_lora_rank`` space, so the cache is ``r + d_rope = 576`` floats/token
instead of ``2·H·d_head``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import apply_rope, const_param, make_param, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# blocked attention (pure-JAX flash) — also the oracle for kernels/flash
# ---------------------------------------------------------------------------


def _attend_tiles(q, k, v, qpos, kpos, causal, scale, kv_len):
    """One (q-tile × kv-tile) online-softmax step. q:(B,qb,Hkv,G,D) k/v:(B,kb,Hkv,D)."""
    s = jnp.einsum("bqhgd,bkhd->bqhgk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    valid = jnp.broadcast_to(kpos[None, :] < kv_len, (qpos.shape[0], kpos.shape[0]))
    if causal:
        valid = valid & (kpos[None, :] <= qpos[:, None])
    s = jnp.where(valid[None, :, None, None, :], s, NEG_INF)
    return s


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 512,
    q_offset: int = 0,
    prune_causal: bool = False,
    unroll: bool = False,
) -> jax.Array:
    """Flash-style attention.  q: (B,Sq,H,D); k/v: (B,Skv,Hkv,D); GQA via H=Hkv·G.

    Returns (B,Sq,H,D) in q.dtype.  Softmax statistics in f32.
    ``unroll`` inlines every tile in the HLO (dry-run cost calibration).
    """
    B, Sq0, H, D = q.shape
    _, Skv0, Hkv, Dv = v.shape
    G = H // Hkv
    scale = k.shape[-1] ** -0.5
    qb = min(q_block, Sq0)
    kb = min(kv_block, Skv0)
    # Pad ragged sequence lengths up to tile multiples; padded KV positions
    # are masked out via kv_len, padded Q rows are sliced off the output.
    Sq = -(-Sq0 // qb) * qb
    Skv = -(-Skv0 // kb) * kb
    if Sq != Sq0:
        q = jnp.pad(q, ((0, 0), (0, Sq - Sq0), (0, 0), (0, 0)))
    if Skv != Skv0:
        k = jnp.pad(k, ((0, 0), (0, Skv - Skv0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv - Skv0), (0, 0), (0, 0)))
    Nq, Nk = Sq // qb, Skv // kb

    q_r = q.reshape(B, Nq, qb, Hkv, G, D)
    k_r = k.reshape(B, Nk, kb, Hkv, D)
    v_r = v.reshape(B, Nk, kb, Hkv, Dv)

    def kv_step(q_tile, qpos, carry, k_t, v_t, kj):
        m, l, acc = carry
        kpos = kj * kb + jnp.arange(kb)
        s = _attend_tiles(q_tile, k_t, v_t, qpos, kpos, causal, scale, Skv0)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(v_t.dtype), v_t,
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    def init_carry():
        return (
            jnp.full((B, qb, Hkv, G), NEG_INF, jnp.float32),
            jnp.zeros((B, qb, Hkv, G), jnp.float32),
            jnp.zeros((B, qb, Hkv, G, Dv), jnp.float32),
        )

    def one_q_tile(qi: jax.Array, q_tile: jax.Array, n_kv: int):
        qpos = q_offset + qi * qb + jnp.arange(qb)
        if unroll:
            carry = init_carry()
            for j in range(n_kv):
                carry = kv_step(q_tile, qpos, carry, k_r[:, j], v_r[:, j],
                                jnp.asarray(j))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                lambda c, inp: (kv_step(q_tile, qpos, c, *inp), None),
                init_carry(),
                (
                    k_r[:, :n_kv].swapaxes(0, 1),
                    v_r[:, :n_kv].swapaxes(0, 1),
                    jnp.arange(n_kv),
                ),
            )
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    if (prune_causal or unroll) and causal and q_offset == 0 and Sq == Skv and qb == kb:
        # Unrolled diagonal walk: q tile i sees kv tiles [0..i] only — exact
        # causal FLOPs (the masked variant below computes the full rectangle).
        outs = [one_q_tile(jnp.asarray(i), q_r[:, i], i + 1) for i in range(Nq)]
        out = jnp.stack(outs, axis=1)
    elif unroll:
        outs = [one_q_tile(jnp.asarray(i), q_r[:, i], Nk) for i in range(Nq)]
        out = jnp.stack(outs, axis=1)
    else:
        out = jax.lax.map(
            lambda args: one_q_tile(args[0], args[1], Nk),
            (jnp.arange(Nq), q_r.swapaxes(0, 1)),
        )
        out = out.swapaxes(0, 1)
    return out.reshape(B, Sq, H, Dv)[:, :Sq0]


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, length: jax.Array
) -> jax.Array:
    """One-token attention over a (possibly partially-filled) KV cache.

    q: (B,1,H,D); caches: (B,Smax,Hkv,D); length: () — #valid cache slots.
    """
    B, _, H, D = q.shape
    _, Smax, Hkv, Dv = v_cache.shape
    G = H // Hkv
    scale = k_cache.shape[-1] ** -0.5
    q_r = q.reshape(B, Hkv, G, D)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", q_r, k_cache, preferred_element_type=jnp.float32
    ) * scale
    mask = jnp.arange(Smax)[None, None, None, :] < length
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------


def init_gqa(key: jax.Array, cfg) -> Dict[str, Any]:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 8)
    if cfg.flat_attn_proj:
        # Flattened (H·Dh) projections: TP-shards evenly when H doesn't
        # divide the model axis (40/56-head archs on a 16-way mesh); GSPMD
        # re-partitions the reshaped per-head view as needed.
        p = {
            "wq": make_param(ks[0], (d, h * dh), ("embed", "attn_flat"), cfg.np_dtype),
            "wk": make_param(ks[1], (d, hkv * dh), ("embed", "attn_flat"), cfg.np_dtype),
            "wv": make_param(ks[2], (d, hkv * dh), ("embed", "attn_flat"), cfg.np_dtype),
            "wo": make_param(ks[3], (h * dh, d), ("attn_flat", "embed"), cfg.np_dtype),
        }
        if cfg.attn_bias:
            p["bq"] = const_param((h * dh,), ("attn_flat",), cfg.np_dtype, 0.0)
            p["bk"] = const_param((hkv * dh,), ("attn_flat",), cfg.np_dtype, 0.0)
            p["bv"] = const_param((hkv * dh,), ("attn_flat",), cfg.np_dtype, 0.0)
    else:
        p = {
            "wq": make_param(ks[0], (d, h, dh), ("embed", "heads", "head_dim"), cfg.np_dtype),
            "wk": make_param(ks[1], (d, hkv, dh), ("embed", "kv_heads", "head_dim"), cfg.np_dtype),
            "wv": make_param(ks[2], (d, hkv, dh), ("embed", "kv_heads", "head_dim"), cfg.np_dtype),
            "wo": make_param(ks[3], (h, dh, d), ("heads", "head_dim", "embed"), cfg.np_dtype),
        }
        if cfg.attn_bias:
            p["bq"] = const_param((h, dh), ("heads", "head_dim"), cfg.np_dtype, 0.0)
            p["bk"] = const_param((hkv, dh), ("kv_heads", "head_dim"), cfg.np_dtype, 0.0)
            p["bv"] = const_param((hkv, dh), ("kv_heads", "head_dim"), cfg.np_dtype, 0.0)
    if cfg.qk_norm:
        p["q_norm"] = const_param((dh,), ("norm",), cfg.np_dtype, 1.0)
        p["k_norm"] = const_param((dh,), ("norm",), cfg.np_dtype, 1.0)
    return p


def _proj_heads(x: jax.Array, w: jax.Array, b, n_heads: int, d_head: int):
    if w.ndim == 2:   # flat projection
        y = x @ w
        if b is not None:
            y = y + b
        return y.reshape(*x.shape[:-1], n_heads, d_head)
    y = jnp.einsum("bsd,dhk->bshk", x, w)
    if b is not None:
        y = y + b
    return y


def _qkv(p: Dict, x: jax.Array, cfg, positions: jax.Array):
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = _proj_heads(x, p["wq"], p.get("bq"), h, dh)
    k = _proj_heads(x, p["wk"], p.get("bk"), hkv, dh)
    v = _proj_heads(x, p["wv"], p.get("bv"), hkv, dh)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if not cfg.flat_attn_proj:
        q = shard(q, "batch", "act_seq", "act_heads", None)
        k = shard(k, "batch", "act_seq", "act_kv_heads", None)
        v = shard(v, "batch", "act_seq", "act_kv_heads", None)
    return q, k, v


def gqa_forward(
    p: Dict,
    x: jax.Array,
    cfg,
    *,
    causal: bool = True,
    cache: Optional[Dict] = None,
    pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Self-attention.  With ``cache`` → decode mode (x is (B,1,D), pos is ())."""
    B, S, _ = x.shape
    if cache is None:
        positions = jnp.arange(S)[None, :]
        q, k, v = _qkv(p, x, cfg, positions)
        out = blocked_attention(
            q, k, v, causal=causal,
            q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
            prune_causal=cfg.prune_causal, unroll=cfg.unroll_loops,
        )
        new_cache = None
        if cfg.return_cache:
            new_cache = {"k": k, "v": v}
    else:
        positions = pos[None, None] if pos.ndim == 0 else pos[:, None]
        q, k, v = _qkv(p, x, cfg, positions)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), pos, axis=1
        )
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), pos, axis=1
        )
        k_cache = shard(k_cache, "batch", "kv_cache_seq", "act_kv_heads", None)
        v_cache = shard(v_cache, "batch", "kv_cache_seq", "act_kv_heads", None)
        out = decode_attention(q, k_cache, v_cache, pos + 1)
        new_cache = {"k": k_cache, "v": v_cache}
    if p["wo"].ndim == 2:  # flat output projection
        Bq, Sq = out.shape[:2]
        y = out.reshape(Bq, Sq, -1) @ p["wo"]
    else:
        out = shard(out, "batch", "act_seq", "act_heads", None)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard(y, "batch", "act_seq", "act_embed"), new_cache


def gqa_cache_spec(cfg, batch: int, max_len: int) -> Dict[str, jax.ShapeDtypeStruct]:
    shp = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jax.ShapeDtypeStruct(shp, cfg.np_dtype),
        "v": jax.ShapeDtypeStruct(shp, cfg.np_dtype),
    }


GQA_CACHE_AXES = {
    "k": ("batch", "kv_cache_seq", "act_kv_heads", None),
    "v": ("batch", "kv_cache_seq", "act_kv_heads", None),
}


# ---------------------------------------------------------------------------
# Cross-attention (VLM media layers; enc-dec decoder)
# ---------------------------------------------------------------------------


def init_cross_attn(key: jax.Array, cfg) -> Dict[str, Any]:
    return init_gqa(key, cfg)  # same projection geometry; memory supplies K/V


def cross_attn_forward(
    p: Dict,
    x: jax.Array,
    memory: Optional[jax.Array],
    cfg,
    cache: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Cross-attention: queries from x, keys/values from ``memory``.

    At decode time the projected memory K/V are precomputed once (prefill)
    and passed in via ``cache`` — memory may then be None.
    """
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if cache is None:
        k = _proj_heads(memory, p["wk"], p.get("bk"), hkv, dh)
        v = _proj_heads(memory, p["wv"], p.get("bv"), hkv, dh)
    else:
        k, v = cache["mk"], cache["mv"]
    q = _proj_heads(x, p["wq"], p.get("bq"), h, dh)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps) if cache is None else k
    if not cfg.flat_attn_proj:
        q = shard(q, "batch", "act_seq", "act_heads", None)
    out = blocked_attention(
        q, k, v, causal=False,
        q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
        unroll=cfg.unroll_loops,
    )
    if p["wo"].ndim == 2:
        Bq, Sq = out.shape[:2]
        y = out.reshape(Bq, Sq, -1) @ p["wo"]
    else:
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    new_cache = {"mk": k, "mv": v} if (cache is not None or cfg.return_cache) else None
    return shard(y, "batch", "act_seq", "act_embed"), new_cache


def cross_cache_spec(cfg, batch: int, mem_len: int) -> Dict[str, jax.ShapeDtypeStruct]:
    shp = (batch, mem_len, cfg.n_kv_heads, cfg.d_head)
    return {
        "mk": jax.ShapeDtypeStruct(shp, cfg.np_dtype),
        "mv": jax.ShapeDtypeStruct(shp, cfg.np_dtype),
    }


CROSS_CACHE_AXES = {
    "mk": ("batch", None, "act_kv_heads", None),
    "mv": ("batch", None, "act_kv_heads", None),
}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key: jax.Array, cfg) -> Dict[str, Any]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    qd = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq": make_param(ks[0], (d, h, qd), ("embed", "heads", "head_dim"), cfg.np_dtype),
        "w_dkv": make_param(
            ks[1], (d, m.kv_lora_rank + m.qk_rope_dim), ("embed", "kv_lora"), cfg.np_dtype
        ),
        "kv_norm": const_param((m.kv_lora_rank,), ("norm",), cfg.np_dtype, 1.0),
        "w_uk": make_param(
            ks[2], (m.kv_lora_rank, h, m.qk_nope_dim), ("kv_lora", "heads", "head_dim"),
            cfg.np_dtype,
        ),
        "w_uv": make_param(
            ks[3], (m.kv_lora_rank, h, m.v_head_dim), ("kv_lora", "heads", "head_dim"),
            cfg.np_dtype,
        ),
        "wo": make_param(ks[4], (h, m.v_head_dim, d), ("heads", "head_dim", "embed"), cfg.np_dtype),
    }


def _mla_compress(p, x, cfg, positions):
    m = cfg.mla
    ckv_pe = x @ p["w_dkv"]
    c_kv, k_pe = ckv_pe[..., : m.kv_lora_rank], ckv_pe[..., m.kv_lora_rank:]
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_pe


def _mla_q(p, x, cfg, positions):
    m = cfg.mla
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_pe = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def mla_forward(
    p: Dict,
    x: jax.Array,
    cfg,
    *,
    cache: Optional[Dict] = None,
    pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    m = cfg.mla
    B, S, _ = x.shape
    if cache is None:
        positions = jnp.arange(S)[None, :]
        q_nope, q_pe = _mla_q(p, x, cfg, positions)
        c_kv, k_pe = _mla_compress(p, x, cfg, positions)
        # Prefill/train: decompress to per-head K/V, run flash (MHA, d=192).
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
        v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (*k_nope.shape[:3], m.qk_rope_dim))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        q = shard(q, "batch", "act_seq", "act_heads", None)
        k = shard(k, "batch", "act_seq", "act_heads", None)
        out = blocked_attention(
            q, k, v, causal=True,
            q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
            prune_causal=cfg.prune_causal, unroll=cfg.unroll_loops,
        )
        new_cache = None
        if cfg.return_cache:
            new_cache = {"c_kv": c_kv, "k_pe": k_pe}
    else:
        # Absorbed decode: attention in the r-dimensional latent space.
        positions = pos[None, None]
        q_nope, q_pe = _mla_q(p, x, cfg, positions)
        c_kv_new, k_pe_new = _mla_compress(p, x, cfg, positions)
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), pos, axis=1
        )
        k_pe = jax.lax.dynamic_update_slice_in_dim(
            cache["k_pe"], k_pe_new.astype(cache["k_pe"].dtype), pos, axis=1
        )
        scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
        q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])      # absorb W_uk
        s = (
            jnp.einsum("bshr,bkr->bshk", q_c, c_kv, preferred_element_type=jnp.float32)
            + jnp.einsum("bshk,bmk->bshm", q_pe, k_pe, preferred_element_type=jnp.float32)
        ) * scale
        mask = jnp.arange(c_kv.shape[1])[None, None, None, :] < pos + 1
        s = jnp.where(mask, s, NEG_INF)
        pattn = jax.nn.softmax(s, axis=-1)
        o_c = jnp.einsum("bshk,bkr->bshr", pattn.astype(c_kv.dtype), c_kv)
        out = jnp.einsum("bshr,rhk->bshk", o_c, p["w_uv"])         # absorb W_uv
        new_cache = {"c_kv": c_kv, "k_pe": k_pe}
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard(y, "batch", "act_seq", "act_embed"), new_cache


def mla_cache_spec(cfg, batch: int, max_len: int) -> Dict[str, jax.ShapeDtypeStruct]:
    m = cfg.mla
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), cfg.np_dtype),
        "k_pe": jax.ShapeDtypeStruct((batch, max_len, m.qk_rope_dim), cfg.np_dtype),
    }


MLA_CACHE_AXES = {
    "c_kv": ("batch", "kv_cache_seq", None),
    "k_pe": ("batch", "kv_cache_seq", None),
}
