"""Shared transformer building blocks (pure JAX, no flax).

Parameters are plain dicts; every init returns ``(params, specs)`` where
``specs`` mirrors the params tree with a tuple of *logical axis names* per
array dimension (resolved to mesh axes by :mod:`repro.distributed.sharding`).

Numerics policy: parameters and activations in ``cfg.dtype`` (bf16 by
default); norms, softmax, rope and the loss in f32.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

ParamsAndSpecs = Tuple[Dict[str, Any], Dict[str, Any]]

_ABS = threading.local()


@contextlib.contextmanager
def abstract_init():
    """Inside this context every param helper returns ShapeDtypeStructs —
    zero-FLOP, zero-memory init used by the multi-pod dry-run."""
    prev = getattr(_ABS, "on", False)
    _ABS.on = True
    try:
        yield
    finally:
        _ABS.on = prev


def is_abstract() -> bool:
    return getattr(_ABS, "on", False)


def make_param(
    key: jax.Array,
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    dtype,
    scale: Optional[float] = None,
) -> Tuple[jax.Array, Tuple[Optional[str], ...]]:
    if len(shape) != len(axes):
        raise ValueError(
            f"shape {shape} and sharding axes {axes} disagree on rank"
        )
    if is_abstract():
        return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype)), axes
    if scale is None:  # fan-in scaling on the first dim by default
        scale = shape[0] ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype), axes


def const_param(
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    dtype,
    fill: Callable[[], jax.Array] | float = 1.0,
) -> Tuple[jax.Array, Tuple[Optional[str], ...]]:
    """Constant / custom-initialised parameter respecting abstract mode."""
    if is_abstract():
        return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype)), axes
    if callable(fill):
        return fill().astype(dtype), axes
    return jnp.full(shape, fill, dtype), axes


def split_tree(tree: Any) -> ParamsAndSpecs:
    """Split a tree whose leaves are (array, axes) into (params, specs)."""
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and hasattr(x[0], "dtype")
    params = jax.tree.map(lambda l: l[0], tree, is_leaf=is_leaf)
    specs = jax.tree.map(lambda l: l[1], tree, is_leaf=is_leaf)
    return params, specs


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma.astype(x.dtype)


def init_rms_norm(dim: int, dtype) -> Tuple[jax.Array, Tuple[Optional[str], ...]]:
    return const_param((dim,), ("norm",), dtype, 1.0)


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, llama-style split-half layout.

    x: (..., S, H, D); positions: broadcastable to (..., S).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                             # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., :, None, :]                   # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
           b: Optional[Dict[str, jax.Array]] = None) -> jax.Array:
    g = x @ w_gate
    u = x @ w_up
    if b is not None:
        g = g + b["gate"]
        u = u + b["up"]
    return (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u) @ w_down


def init_mlp(key: jax.Array, d_model: int, d_ff: int, dtype) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": make_param(k1, (d_model, d_ff), ("embed", "mlp"), dtype),
        "w_up": make_param(k2, (d_model, d_ff), ("embed", "mlp"), dtype),
        "w_down": make_param(k3, (d_ff, d_model), ("mlp", "embed"), dtype),
    }


def mlp_forward(p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    from repro.distributed.sharding import shard

    h = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype) * (
        x @ p["w_up"]
    )
    h = shard(h, "batch", "act_seq", "act_mlp")
    return h @ p["w_down"]


def init_embedding(key: jax.Array, vocab: int, d_model: int, dtype):
    return make_param(key, (vocab, d_model), ("vocab", "embed"), dtype, scale=0.02)


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def logits_from_embedding(table: jax.Array, x: jax.Array) -> jax.Array:
    """Tied readout: x (B,S,D) @ table^T → (B,S,V)."""
    return x @ table.T


def cross_entropy(
    logits: jax.Array, targets: jax.Array, mask: Optional[jax.Array] = None
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Token-mean cross entropy in f32; returns (loss, metrics)."""
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((jnp.argmax(logits32, -1) == targets) * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}
