"""Model facade: build(config) → init / train_loss / prefill / decode_step.

Batch contents by family (all produced by :meth:`Model.input_specs`):

* LM families (dense/moe/hybrid/ssm): ``tokens``, ``targets`` (B,S) int32.
* vlm: + ``media`` (B, n_media_tokens, d_model) — precomputed patch
  embeddings (the modality frontend is a stub per the assignment).
* audio (enc-dec): + ``src_embeds`` (B, S_src, d_model) — precomputed frame
  embeddings; the decoder cross-attends the encoded memory.

Serving:
* ``prefill(params, batch)`` → (last-token logits, caches)
* ``decode_step(params, caches, tokens, pos)`` → (logits, new caches) — one
  new token against a KV/SSM cache (the ``decode_*``/``long_*`` shapes).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import transformer as tf
from repro.models.layers import cross_entropy, embed_lookup, rms_norm


@dataclasses.dataclass
class Model:
    cfg: Any

    def __post_init__(self):
        self.plan = tf.layer_plan(self.cfg)
        self.enc_plan = tf.encoder_plan(self.cfg)

    # ------------------------------------------------------------- init
    def init(self, key: jax.Array):
        params, _ = tf.init_model(key, self.cfg)
        return params

    def abstract(self):
        """(param ShapeDtypeStructs, logical-axes specs) — for the dry-run."""
        return tf.abstract_model(self.cfg)

    def param_specs(self):
        return self.abstract()[1]

    # ------------------------------------------------------------- helpers
    def _memory(self, params, batch, cfg) -> Optional[jax.Array]:
        if cfg.family == "vlm":
            return shard(batch["media"], "batch", None, "act_embed")
        if cfg.family == "audio":
            m = shard(batch["src_embeds"], "batch", "act_seq", "act_embed")
            m, _, _ = tf.stack_forward(
                params["encoder"], m, cfg.replace(return_cache=False), self.enc_plan
            )
            return rms_norm(m, params["enc_ln_f"], cfg.norm_eps)
        return None

    def _logits(self, params, x, cfg) -> jax.Array:
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T
        else:
            logits = x @ params["lm_head"]
        return shard(logits, "batch", "act_seq", "act_vocab")

    # ------------------------------------------------------------- train
    def train_loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg.replace(return_cache=False)
        x = embed_lookup(params["embed"], batch["tokens"])
        x = shard(x, "batch", "act_seq", "act_embed")
        memory = self._memory(params, batch, cfg)
        x, _, aux = tf.stack_forward(params["layers"], x, cfg, self.plan, memory=memory)
        logits = self._logits(params, x, cfg)
        loss, metrics = cross_entropy(logits, batch["targets"])
        metrics["aux_loss"] = aux
        return loss + aux, metrics

    # ------------------------------------------------------------- serve
    def prefill(self, params, batch) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg.replace(return_cache=True)
        x = embed_lookup(params["embed"], batch["tokens"])
        x = shard(x, "batch", "act_seq", "act_embed")
        memory = self._memory(params, batch, cfg)
        x, caches, _ = tf.stack_forward(params["layers"], x, cfg, self.plan, memory=memory)
        logits = self._logits(params, x[:, -1:, :], cfg)
        return logits, caches

    def decode_step(self, params, caches, tokens, pos) -> Tuple[jax.Array, Dict]:
        """tokens: (B,1) int32; pos: () int32 — write position in the cache."""
        cfg = self.cfg.replace(return_cache=False)
        x = embed_lookup(params["embed"], tokens)
        x = shard(x, "batch", "act_seq", "act_embed")
        # Cross-attn memory K/V live inside the caches after prefill.
        x, new_caches, _ = tf.stack_forward(
            params["layers"], x, cfg, self.plan, memory=None, caches=caches, pos=pos
        )
        logits = self._logits(params, x, cfg)
        return logits, new_caches

    # ------------------------------------------------------------- specs
    def input_specs(self, shape) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of a Shape."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "targets": jax.ShapeDtypeStruct((B, S), i32),
            }
            if cfg.family == "vlm":
                specs["media"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_media_tokens, cfg.d_model), cfg.np_dtype
                )
            if cfg.family == "audio":
                specs["src_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.np_dtype)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "vlm":
                specs["media"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_media_tokens, cfg.d_model), cfg.np_dtype
                )
            if cfg.family == "audio":
                # prefill_32k for enc-dec = encode an S-frame source, then
                # prime the decoder with a BOS token.
                specs = {
                    "tokens": jax.ShapeDtypeStruct((B, 1), i32),
                    "src_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.np_dtype),
                }
            return specs
        if shape.kind == "decode":
            return {
                "tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32),
            }
        raise ValueError(shape.kind)

    def cache_specs(self, batch: int, max_len: int):
        """(SDS tree, logical-axes tree) for the decode-shape dry-runs."""
        cfg = self.cfg
        mem_len = cfg.n_media_tokens if cfg.family == "vlm" else (
            cfg.enc_seq if cfg.family == "audio" else 0
        )
        return tf.stack_cache_specs(cfg, self.plan, batch, max_len, mem_len)

    def init_cache(self, batch: int, max_len: int):
        """Zero-initialised cache (for runnable examples, not the dry-run)."""
        spec, _ = self.cache_specs(batch, max_len)
        return jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            spec,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )


def build(cfg) -> Model:
    return Model(cfg)
