"""Elastic scaling: reload any checkpoint into any mesh.

At 1000+-node scale the mesh you restart on is rarely the mesh you saved
from — nodes die, capacity shifts.  Checkpoints are stored as plain host
arrays (full, unsharded logical tensors), so resharding is just re-placing
each leaf with the NamedSharding prescribed by the *new* mesh + rules:

    state = reshard(host_state, specs, new_mesh, rules)

``survive_failure`` implements the failure drill: given a device set with
holes, build the largest feasible (data, model) mesh from the survivors
(keeping the model axis intact — TP degree is a property of the compiled
program) and reshard onto it.  Global batch is preserved by raising the
per-replica batch (gradient accumulation), which is the trainer's job.
"""

from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import ShardingRules, logical_spec


def reshard(host_tree: Any, specs: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    """Place a host (numpy) pytree onto ``mesh`` with logical-axis specs."""

    def place(x, ax):
        sh = NamedSharding(mesh, logical_spec(ax, mesh, rules))
        return jax.device_put(x, sh)

    return jax.tree.map(
        place, host_tree, specs, is_leaf=lambda x: isinstance(x, np.ndarray)
    )


def best_mesh_from(devices: Sequence, model_parallel: int) -> Mesh:
    """Largest (data, model) mesh buildable from surviving devices.

    The model axis is kept at ``model_parallel`` (the compiled program's TP
    degree); surviving devices beyond the largest multiple are left idle.
    """
    n = len(devices)
    data = n // model_parallel
    if data < 1:
        raise ValueError(
            f"{n} surviving devices cannot host model_parallel={model_parallel}"
        )
    use = data * model_parallel
    devs = np.asarray(devices[:use]).reshape(data, model_parallel)
    return Mesh(devs, ("data", "model"))


def survive_failure(
    host_state: Any,
    specs: Any,
    failed_ids: Sequence[int],
    rules: ShardingRules,
    model_parallel: int = 1,
) -> Tuple[Any, Mesh]:
    """Drop failed devices, rebuild the mesh, reshard the state."""
    survivors = [d for d in jax.devices() if d.id not in set(failed_ids)]
    mesh = best_mesh_from(survivors, model_parallel)
    return reshard(host_state, specs, mesh, rules), mesh
