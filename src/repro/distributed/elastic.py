"""Elastic scaling: reload any checkpoint into any mesh.

The RSNN training stack is data-parallel over one ``("data",)`` mesh axis
(:func:`repro.launch.mesh.make_data_mesh`): weights are replicated, the
sample axis is sharded, END_B ``dw`` is ``psum``-med.  Checkpoints store
plain host arrays (full, unsharded logical tensors —
:mod:`repro.distributed.checkpoint`), so restoring onto a *different*
device count is just re-placing each leaf with the NamedSharding the new
mesh + rules prescribe:

    state = reshard(host_state, specs, new_mesh, rules)

``survive_data_failure`` is the drill the fault-tolerance suite exercises:
a run saved on an 8-device data mesh restarts on 1/2/4 survivors — build
the survivors' mesh (:func:`best_data_mesh_from`), resize the execution
backend (:meth:`repro.core.backend.ExecutionBackend.resize` — same config,
new shard_map layout), and reshard the state.  With a ``commit_grid``
runtime (int32 code accumulation, see
:class:`repro.core.quant.DW_COMMIT_SPEC`), the resized run's END_B commits
are **bitwise identical** to the original's; without one they agree to
float-reduction order.  ``reshard``/``best_mesh_from``/``survive_failure``
keep the general (data, model) form for weight layouts that do split a
model axis (none of the paper's RSNNs do — their weight SRAM is a few
hundred KB and always replicated).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import ShardingRules, logical_spec


def reshard(host_tree: Any, specs: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    """Place a host (numpy) pytree onto ``mesh`` with logical-axis specs."""

    def place(x, ax):
        sh = NamedSharding(mesh, logical_spec(ax, mesh, rules))
        return jax.device_put(x, sh)

    return jax.tree.map(
        place, host_tree, specs, is_leaf=lambda x: isinstance(x, np.ndarray)
    )


def best_data_mesh_from(devices: Sequence) -> Optional[Mesh]:
    """The survivors' 1-axis ``("data",)`` mesh — the layout every RSNN
    training/serving path in this repo runs on.  One survivor needs no
    mesh at all (single-device execution): returns ``None``."""
    n = len(devices)
    if n < 1:
        raise ValueError("no surviving devices")
    if n == 1:
        return None
    return Mesh(np.asarray(devices), ("data",))


def survive_data_failure(
    backend,
    failed_ids: Sequence[int],
) -> Tuple[Any, Optional[Mesh]]:
    """The data-mesh failure drill: drop the failed devices, rebuild the
    survivors' ``("data",)`` mesh and resize ``backend`` onto it.

    ``backend`` is an :class:`~repro.core.backend.ExecutionBackend` (duck-
    typed — anything with ``.resize(mesh)``); weights are replicated under
    the data-parallel layout, so no state movement is needed beyond what
    the resized backend's jit placement does on the next launch.  Restore
    the checkpointed host state *after* resizing (``jax.device_put`` under
    the new mesh, or :func:`reshard` for sharded layouts).

    Returns ``(resized_backend, survivors_mesh)``.
    """
    survivors = [d for d in jax.devices() if d.id not in set(failed_ids)]
    mesh = best_data_mesh_from(survivors)
    return backend.resize(mesh), mesh


def best_mesh_from(devices: Sequence, model_parallel: int) -> Mesh:
    """Largest (data, model) mesh buildable from surviving devices.

    The model axis is kept at ``model_parallel`` (the compiled program's
    tensor-parallel degree); surviving devices beyond the largest multiple
    are left idle.  The RSNN stack always uses ``model_parallel=1`` — see
    :func:`best_data_mesh_from` for its 1-axis form.
    """
    n = len(devices)
    data = n // model_parallel
    if data < 1:
        raise ValueError(
            f"{n} surviving devices cannot host model_parallel={model_parallel}"
        )
    use = data * model_parallel
    devs = np.asarray(devices[:use]).reshape(data, model_parallel)
    return Mesh(devs, ("data", "model"))


def survive_failure(
    host_state: Any,
    specs: Any,
    failed_ids: Sequence[int],
    rules: ShardingRules,
    model_parallel: int = 1,
) -> Tuple[Any, Mesh]:
    """Drop failed devices, rebuild the mesh, reshard the state."""
    survivors = [d for d in jax.devices() if d.id not in set(failed_ids)]
    mesh = best_mesh_from(survivors, model_parallel)
    return reshard(host_state, specs, mesh, rules), mesh
