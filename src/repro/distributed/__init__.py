from repro.distributed.checkpoint import (  # noqa: F401
    CheckpointManager,
    CheckpointPolicy,
    ReplayCursor,
)
from repro.distributed.sharding import (  # noqa: F401
    BASE_RULES,
    ShardingRules,
    logical_sharding,
    logical_spec,
    param_shardings,
    shard,
    use_mesh,
)
