"""Pipeline parallelism — GPipe schedule over a mesh axis.

Stages live on consecutive ranks of ``axis`` (on the production mesh the
``pod`` axis, so stage handoffs ride the DCN exactly once per microbatch per
stage boundary).  The schedule is the classic (n_micro + S − 1)-tick GPipe
wavefront: every tick each rank runs its stage on the microbatch in flight
and hands the activation to the next rank with a single
``collective-permute`` — the collective the §Dry-run HLO check looks for.

Implementation: ``jax.shard_map`` manual on ``axis`` (other axes stay under
GSPMD), ``lax.fori_loop`` over ticks, ring buffer carried in registers.
Bubble fraction = (S−1)/(n_micro+S−1); the caller picks n_micro ≫ S.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def gpipe(
    stage_fn: Callable,      # (stage_params, x_mb) -> y_mb  (same shape)
    stage_params,            # pytree; leaves have leading dim = n_stages
    x: jax.Array,            # (n_micro, mb, ...) global microbatched input
    *,
    mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run ``x`` through all stages; returns (n_micro, mb, ...)."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def inner(params_local, x_all):
        params_own = jax.tree.map(lambda p: p[0], params_local)
        s = jax.lax.axis_index(axis)
        ticks = n_micro + n_stages - 1

        def tick(t, carry):
            buf, outs = carry
            # Stage 0 injects microbatch t (clamped; masked by validity below).
            m_in = jnp.clip(t, 0, n_micro - 1)
            my_in = jnp.where(s == 0, x_all[m_in], buf)
            y = stage_fn(params_own, my_in)
            # Handoff to the next stage (one DCN hop per boundary).
            nxt = jax.lax.ppermute(y, axis, perm)
            # Last stage commits microbatch m = t - (S-1) when valid.
            m_out = t - (n_stages - 1)
            valid = (s == n_stages - 1) & (m_out >= 0) & (m_out < n_micro)
            outs = jnp.where(
                valid,
                jax.lax.dynamic_update_index_in_dim(
                    outs, y, jnp.clip(m_out, 0, n_micro - 1), 0
                ),
                outs,
            )
            return nxt, outs

        buf0 = jnp.zeros_like(x_all[0])
        outs0 = jnp.zeros_like(x_all)
        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf0, outs0))
        # Replicate the last stage's result to every rank.
        mask = (s == n_stages - 1).astype(outs.dtype)
        return jax.lax.psum(outs * mask, axis)

    return shard_map(
        inner,
        mesh=mesh,
        axis_names={axis},
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x)


def reference_pipeline(stage_fn, stage_params, x):
    """Oracle: run the stages sequentially on one device."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def run_mb(x_mb):
        y = x_mb
        for i in range(n_stages):
            p_i = jax.tree.map(lambda p: p[i], stage_params)
            y = stage_fn(p_i, y)
        return y

    return jax.vmap(run_mb)(x)
