"""Atomic, asynchronous checkpoints with keep-N retention and manifests.

Layout:
  <dir>/step_000420/
      manifest.json        {step, time, leaves, **extra}
      arrays.npz           one entry per flattened pytree leaf
  <dir>/LATEST             text file naming the newest complete checkpoint

Atomicity: each checkpoint is written into ``step_X.tmp`` and renamed into
place only after every array has been flushed — a crash mid-save never
corrupts the restore path (rename is atomic on POSIX).  Torn ``.tmp`` dirs
left by a crashed process are invisible to ``all_steps``/``latest_step``
and swept on the next manager construction.  ``save_async`` hands the host
snapshot to a single persistent writer thread through a small bounded
queue, so the train loop only blocks on the device→host transfer — never
on the previous write still being on disk (a join-per-save design stalls
every commit once the write time exceeds the commit gap).  The serial
writer keeps saves ordered, so the LATEST pointer and retention pruning
stay race-free; a failed background write is re-raised at the *next*
``save`` / ``save_async`` / ``wait`` call, whichever comes first —
durability errors never wait for an explicit ``wait()``.

Bit-exactness: leaves are stored as raw numpy arrays (``np.savez``), so
every dtype round-trips bit for bit — including the integer-valued float32
carriers of the quantized SRAM weight image and the ``EpropSGD`` float
residual accumulators.  ``restore`` validates every leaf's shape *and*
dtype against the caller's template and fails with a per-leaf diff rather
than letting a stale or foreign checkpoint surface as a jit shape error
three layers down.

Restore targets any mesh: arrays come back as numpy and are re-placed with
whatever shardings the new mesh prescribes (see
:mod:`repro.distributed.elastic`).
"""

from __future__ import annotations

import dataclasses
import json
import os
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


@dataclasses.dataclass
class ReplayCursor:
    """Durable position in a deterministic batch replay.

    ``epoch`` and ``batch`` name the *next* batch a training loop would
    consume: a loop sets ``(epoch, batch) = (e, i + 1)`` immediately before
    committing batch ``i`` of epoch ``e``, so a checkpoint cut after the
    commit resumes at exactly the first unconsumed batch.  Because the
    pipelines derive their per-epoch order from ``(seed, epoch)`` alone
    (see :mod:`repro.data.pipeline`), replaying from a cursor reproduces
    the identical batch sequence the crashed run would have consumed —
    in float and quantized modes alike.
    """

    epoch: int = 0
    batch: int = 0

    def as_manifest(self) -> Dict[str, int]:
        return {"epoch": int(self.epoch), "batch": int(self.batch)}

    @classmethod
    def from_manifest(cls, d: Dict[str, int]) -> "ReplayCursor":
        return cls(epoch=int(d["epoch"]), batch=int(d["batch"]))


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Durability policy a training loop hands to its checkpoint hooks.

    ``every`` is the save cadence in commits (``OnlineLearner``) or steps
    (``Trainer``); ``keep <= 0`` retains every checkpoint; ``async_save``
    selects :meth:`CheckpointManager.save_async` (disk IO overlapped with
    the next commits) over the blocking :meth:`CheckpointManager.save`.
    """

    directory: str | Path
    every: int = 1
    keep: int = 3
    async_save: bool = True

    def manager(self) -> "CheckpointManager":
        return CheckpointManager(self.directory, keep=self.keep)


def _flatten(tree: Any) -> Tuple[List[str], List[Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves


def _unflatten_like(template: Any, names: List[str], arrays: Dict[str, np.ndarray]) -> Any:
    """Rebuild ``template``'s structure from stored arrays, validating every
    leaf's shape and dtype against the template (the registry's mis-shaped-
    image discipline: fail at the restore boundary with a per-leaf diff, not
    three layers down as a jit shape error)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    out, problems = [], []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        want_shape = tuple(np.shape(leaf))
        want_dtype = np.asarray(leaf).dtype
        if tuple(arr.shape) != want_shape or arr.dtype != want_dtype:
            problems.append(
                f"  {key}: checkpoint has {arr.shape} {arr.dtype}, "
                f"template needs {want_shape} {want_dtype}"
            )
        out.append(arr)
    if problems:
        raise ValueError(
            "checkpoint does not match the restore template:\n"
            + "\n".join(problems)
        )
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    # Backpressure bound on queued-but-unwritten async saves: the commit
    # loop may run at most this many checkpoints ahead of the disk before
    # save_async blocks.  Small on purpose — an unbounded queue converts a
    # slow disk into silent unbounded host memory growth.
    MAX_PENDING = 2

    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._queue: Optional[queue.Queue] = None
        self._writer: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # Sweep torn saves from a crashed predecessor: a ``.tmp`` dir is by
        # construction an incomplete checkpoint (the atomic rename never
        # happened), so it is garbage — never a restore candidate.
        for p in self.dir.glob("step_*.tmp"):
            shutil.rmtree(p, ignore_errors=True)

    # ------------------------------------------------------------- save
    def _raise_pending(self) -> None:
        """Surface a failed background write now (without joining a healthy
        in-flight thread) — called at the top of every save entry so a
        durability failure is raised at the next save, not the next wait."""
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> Path:
        """Blocking save (device→host, write, atomic rename, prune).

        Drains any queued async saves first (the serial writer owns the
        LATEST pointer; a second writer would race it) and re-raises their
        error if one failed.
        """
        self._raise_pending()
        self.wait()
        host = jax.tree.map(np.asarray, jax.device_get(tree))
        return self._write(step, host, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        """Device→host happens now; disk IO on the persistent writer thread.

        The caller never waits for earlier writes to finish — queued saves
        drain in order on one thread — unless :data:`MAX_PENDING` saves are
        already queued (backpressure).  A pending error from an earlier
        async save is raised here, at the next save, not at ``wait()``.
        """
        self._raise_pending()
        host = jax.tree.map(np.asarray, jax.device_get(tree))
        if self._queue is None:
            self._queue = queue.Queue(maxsize=self.MAX_PENDING)
            self._writer = threading.Thread(target=self._drain, daemon=True)
            self._writer.start()
        self._queue.put((step, host, dict(extra or {})))

    def _drain(self) -> None:
        """Writer-thread loop: serialize every queued save to disk in
        order; an error parks in ``_error`` for the next save/wait call."""
        while True:
            step, host, extra = self._queue.get()
            try:
                self._write(step, host, extra)
            except BaseException as e:  # surfaced on next save/save_async/wait
                self._error = e
            finally:
                self._queue.task_done()

    def wait(self) -> None:
        if self._queue is not None:
            self._queue.join()
        self._raise_pending()

    def _write(self, step: int, host_tree: Any, extra: Dict) -> Path:
        names, leaves = _flatten(host_tree)
        final = self.dir / f"step_{step:09d}"
        tmp = self.dir / f"step_{step:09d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **{n: l for n, l in zip(names, leaves)})
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": names,
            **extra,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic commit
        latest_tmp = self.dir / "LATEST.tmp"
        latest_tmp.write_text(final.name)
        os.replace(latest_tmp, self.dir / "LATEST")  # atomic pointer update
        self._prune()
        return final

    def _prune(self) -> None:
        if self.keep <= 0:
            return  # keep <= 0 means "keep every checkpoint", explicitly
        ckpts = self.all_steps()
        for step in ckpts[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{step:09d}", ignore_errors=True)

    # ------------------------------------------------------------- load
    def all_steps(self) -> List[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            steps.append(int(p.name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        """The newest complete step: the LATEST pointer when it names a
        complete checkpoint, else (stale/corrupt/missing pointer) a
        directory scan for the newest complete ``step_*`` dir."""
        latest = self.dir / "LATEST"
        if latest.exists():
            name = latest.read_text().strip()
            if (self.dir / name / "manifest.json").exists():
                try:
                    return int(name.split("_")[1])
                except (IndexError, ValueError):
                    pass  # corrupt pointer contents — fall back to the scan
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any) -> Tuple[Any, Dict]:
        """Returns (numpy pytree shaped like template, manifest).

        Every leaf is validated against the template's shape and dtype; a
        mismatch raises :class:`ValueError` naming each offending leaf.
        """
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        with np.load(d / "arrays.npz") as z:
            arrays = {k: z[k] for k in z.files}
        names, _ = _flatten(template)
        tree = _unflatten_like(template, names, arrays)
        return tree, manifest
