"""Atomic, asynchronous checkpoints with keep-N retention and manifests.

Layout:
  <dir>/step_000420/
      manifest.json        {step, time, data_position, rng, leaf index}
      arrays.npz           one entry per flattened pytree leaf
  <dir>/LATEST             text file naming the newest complete checkpoint

Atomicity: each checkpoint is written into ``step_X.tmp`` and renamed into
place only after every array has been flushed — a crash mid-save never
corrupts the restore path (rename is atomic on POSIX).  Saving runs on a
background thread (``save_async``) so the train loop only blocks on the
device→host transfer, not the disk write.  Restore targets any mesh: arrays
come back as numpy and are re-placed with whatever shardings the new mesh
prescribes (see :mod:`repro.distributed.elastic`).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any) -> Tuple[List[str], List[Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves


def _unflatten_like(template: Any, names: List[str], arrays: Dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        out.append(arrays[key])
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> Path:
        """Blocking save (device→host, write, atomic rename, prune)."""
        host = jax.tree.map(np.asarray, jax.device_get(tree))
        return self._write(step, host, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        """Device→host happens now; disk IO on a background thread."""
        self.wait()  # at most one in-flight save
        host = jax.tree.map(np.asarray, jax.device_get(tree))
        ex = dict(extra or {})

        def work():
            try:
                self._write(step, host, ex)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree: Any, extra: Dict) -> Path:
        names, leaves = _flatten(host_tree)
        final = self.dir / f"step_{step:09d}"
        tmp = self.dir / f"step_{step:09d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **{n: l for n, l in zip(names, leaves)})
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": names,
            **extra,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic commit
        latest_tmp = self.dir / "LATEST.tmp"
        latest_tmp.write_text(final.name)
        os.replace(latest_tmp, self.dir / "LATEST")  # atomic pointer update
        self._prune()
        return final

    def _prune(self) -> None:
        ckpts = self.all_steps()
        for step in ckpts[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{step:09d}", ignore_errors=True)

    # ------------------------------------------------------------- load
    def all_steps(self) -> List[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            steps.append(int(p.name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        latest = self.dir / "LATEST"
        if latest.exists():
            name = latest.read_text().strip()
            if (self.dir / name / "manifest.json").exists():
                return int(name.split("_")[1])
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any) -> Tuple[Any, Dict]:
        """Returns (numpy pytree shaped like template, manifest)."""
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        with np.load(d / "arrays.npz") as z:
            arrays = {k: z[k] for k in z.files}
        names, _ = _flatten(template)
        tree = _unflatten_like(template, names, arrays)
        return tree, manifest
