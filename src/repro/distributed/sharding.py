"""Logical-axis sharding rules (MaxText-style) for the production mesh.

The mesh is ``(pod, data, model)`` — multi-pod — or ``(data, model)`` for a
single pod (see :func:`repro.launch.mesh.make_production_mesh`).

Every parameter / activation dimension carries a *logical* name; the rules
table maps logical names to mesh axes.  Swapping the table re-shards the
whole model without touching model code — that is how the §Perf hillclimb
changes sharding schemes.

Baseline scheme (2D "FSDP × TP"):

* params: ``embed → data`` (FSDP: weights gathered just-in-time per layer),
  ``vocab/heads/mlp/experts/ssm_inner → model`` (tensor / expert parallel);
* activations: ``batch → (pod, data)``, head/ff dims → ``model``;
* optimizer state inherits parameter sharding (ZeRO-3-equivalent).

``shard(x, *axes)`` annotates activations inside model code; it is a no-op
when no mesh context is active, so the same model runs single-device smoke
tests unchanged.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]

# Baseline logical→physical rules.  Values may be None (replicated), a mesh
# axis name, or a tuple of axes (dimension sharded over their product).
BASE_RULES: Dict[str, Axes] = {
    # --- activations ---
    "batch": ("pod", "data"),
    "act_seq": None,           # sequence kept whole (SP variants flip this)
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": None,      # kv heads (GQA: few) — replicated
    "act_mlp": "model",
    "act_vocab": "model",
    "act_expert": "model",
    "act_ssm_inner": "model",
    "act_ssm_heads": "model",
    "kv_cache_seq": None,      # flipped to "model" for long-context decode
    # --- parameters ---
    "vocab": "model",
    "embed": "data",           # FSDP shard
    "heads": "model",
    "attn_flat": "model",      # flattened (H·Dh) projections (40/56-head archs)
    "kv_heads": None,
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "kv_lora": None,
    "q_lora": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "conv_dim": None,
    "layers": None,            # stacked scan-over-layers dim
    "norm": None,
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    table: Dict[str, Axes]

    def resolve(self, logical: Optional[str], mesh: Mesh):
        if logical is None:
            return None
        if logical not in self.table:
            raise KeyError(f"unknown logical axis {logical!r}")
        axes = self.table[logical]
        if axes is None:
            return None
        if isinstance(axes, str):
            return axes if axes in mesh.axis_names else None
        # Tuple rules keep tuple form even when only one axis survives, so
        # specs compare stably across meshes with/without the 'pod' axis.
        present = tuple(a for a in axes if a in mesh.axis_names)
        return present or None

    def override(self, **changes: Axes) -> "ShardingRules":
        t = dict(self.table)
        t.update(changes)
        return ShardingRules(t)

    def strip(self, axis: str) -> "ShardingRules":
        """Remove a mesh axis from every rule (used inside shard_map regions
        where that axis is Manual and must not appear in Auto constraints)."""
        t: Dict[str, Axes] = {}
        for k, v in self.table.items():
            if v == axis:
                t[k] = None
            elif isinstance(v, tuple):
                vv = tuple(a for a in v if a != axis)
                t[k] = vv if vv else None
            else:
                t[k] = v
        return ShardingRules(t)


_CTX = threading.local()


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[ShardingRules] = None):
    """Activate a mesh + rules for ``shard`` / ``logical_sharding`` calls.

    All shardings are explicit ``NamedSharding``s (which carry their mesh),
    so no jax-global mesh context is needed — this is pure bookkeeping for
    the ``shard()`` helper.
    """
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, rules or ShardingRules(BASE_RULES))
    try:
        yield
    finally:
        _CTX.state = prev


def _current() -> Optional[Tuple[Mesh, ShardingRules]]:
    return getattr(_CTX, "state", None)


def current_mesh() -> Optional[Mesh]:
    state = _current()
    return state[0] if state else None


def current_rules() -> ShardingRules:
    state = _current()
    return state[1] if state else ShardingRules(BASE_RULES)


def logical_spec(axes: Sequence[Optional[str]], mesh: Mesh, rules: ShardingRules) -> P:
    return P(*(rules.resolve(a, mesh) for a in axes))


def logical_sharding(
    axes: Sequence[Optional[str]],
    mesh: Optional[Mesh] = None,
    rules: Optional[ShardingRules] = None,
) -> NamedSharding:
    if mesh is None:
        mesh, rules = _current()
    rules = rules or ShardingRules(BASE_RULES)
    return NamedSharding(mesh, logical_spec(axes, mesh, rules))


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op outside a mesh ctx)."""
    state = _current()
    if state is None:
        return x
    mesh, rules = state
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, logical_spec(axes, mesh, rules))
    )


def param_shardings(specs: Any, mesh: Mesh, rules: Optional[ShardingRules] = None):
    """Map a tree of logical-axis tuples to a tree of NamedShardings."""
    rules = rules or ShardingRules(BASE_RULES)
    return jax.tree.map(
        lambda ax: NamedSharding(mesh, logical_spec(ax, mesh, rules)),
        specs,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def axis_size(mesh: Mesh, axes: Axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
