"""repro — event-driven online-learning training/serving framework in JAX.

Reproduction of "Heterogeneous SoC Integrating an Open-Source Recurrent SNN
Accelerator for Neuromorphic Edge Computing on FPGA" (CS.AR 2026), adapted
to TPU v5e pods.  See DESIGN.md for the SoC->pod mapping and EXPERIMENTS.md
for results.
"""

__version__ = "0.1.0"
