"""The paper's own network #2 — Braille classification (§4.3): 12 input,
38 recurrent LIF (reset-to-zero), N-class LI readout; SPI registers
threshold=0x03F0, alpha=0x0FE, kappa=0x37.
"""

from repro.core.rsnn import Presets

CONFIG = Presets.braille(n_classes=3)


def config_for(n_classes: int):
    return Presets.braille(n_classes=n_classes)


def reduced():
    return Presets.braille(n_classes=3, n_hid=16, num_ticks=32)
