"""The paper's own network #2 — Braille classification (§4.3): 12 input,
38 recurrent LIF (reset-to-zero), N-class LI readout; SPI registers
threshold=0x03F0, alpha=0x0FE, kappa=0x37.

``CONFIG_QUANT`` / ``config_for(..., quantized=True)`` arm the
hardware-equivalence mode: the same register values interpreted as ReckOn's
fixed-point datapath (8-bit weight SRAM on the Q(8,4) grid, saturating
12-bit membrane grid, leaks as ``reg/256`` floor-multipliers) — the
configuration whose software↔chip bit-equivalence the paper validates.
``QUANT_OPT`` is the matching optimizer config: weights live on the SRAM
grid with accumulate-then-round e-prop commits.
"""

from repro.core.quant import WEIGHT_SPEC, QuantizedMode
from repro.core.rsnn import Presets
from repro.optim.eprop_opt import EpropSGDConfig

# The paper's SPI parameter-bank values, as the quantized datapath reads them.
SPI_REGS = QuantizedMode(threshold=0x03F0, alpha_reg=0x0FE, kappa_reg=0x37)

CONFIG = Presets.braille(n_classes=3)
CONFIG_QUANT = Presets.braille(n_classes=3, quantized=True)

# Chip-faithful weight storage: 8-bit SRAM codes + float residual
# accumulator, committed at every END_S/END_B with the chip's stochastic
# rounding (sub-LSB updates make expected progress).
QUANT_OPT = EpropSGDConfig(lr=1e-2, clip=10.0, quant=WEIGHT_SPEC,
                           stochastic_round=True)


def config_for(n_classes: int, quantized: bool = False):
    return Presets.braille(n_classes=n_classes, quantized=quantized)


def reduced():
    return Presets.braille(n_classes=3, n_hid=16, num_ticks=32)
