"""llama3-8b [dense] — GQA (kv=8), 128k vocab.  [arXiv:2407.21783]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=128256,
    rope_theta=5e5,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=512, dtype="float32",
    )
