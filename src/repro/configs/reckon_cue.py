"""The paper's own network #1 — cue accumulation (§4.2): 40 input,
100 recurrent LIF, 2 LI outputs, reset-by-subtraction, delayed supervision.

``CONFIG_QUANT`` / ``config_for(quantized=True)`` arm the
hardware-equivalence mode: the tuned register values interpreted as
ReckOn's fixed-point datapath (8-bit weight SRAM on the Q(8,4) grid,
saturating 12-bit membrane grid, leaks as ``reg/256`` floor-multipliers),
with the reset-by-subtraction membrane update the cue network uses on
chip.  ``QUANT_OPT`` is the matching optimizer config — identical to the
Braille one, since both tasks share the SRAM numerics (one fabric, two
programs).
"""

from repro.core.quant import WEIGHT_SPEC, QuantizedMode
from repro.core.rsnn import Presets
from repro.optim.eprop_opt import EpropSGDConfig

# The tuned SPI parameter-bank values, as the quantized datapath reads them
# (alpha 254/256, kappa 200/256 — the cue network's slower readout leak).
SPI_REGS = QuantizedMode(threshold=0x03F0, alpha_reg=0x0FE, kappa_reg=0xC8)

CONFIG = Presets.cue_accumulation()
CONFIG_QUANT = Presets.cue_accumulation(quantized=True)

# Chip-faithful weight storage: 8-bit SRAM codes + float residual
# accumulator, committed at every END_S/END_B with the chip's stochastic
# rounding (sub-LSB updates make expected progress).
QUANT_OPT = EpropSGDConfig(lr=1e-2, clip=10.0, quant=WEIGHT_SPEC,
                           stochastic_round=True)


def config_for(quantized: bool = False, **over):
    return Presets.cue_accumulation(quantized=quantized, **over)


def reduced(quantized: bool = False):
    return Presets.cue_accumulation(
        n_in=12, n_hid=20, num_ticks=40, quantized=quantized
    )
