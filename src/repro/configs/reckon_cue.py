"""The paper's own network #1 — cue accumulation (§4.2): 40 input,
100 recurrent LIF, 2 LI outputs, reset-by-subtraction, delayed supervision.
"""

from repro.core.rsnn import Presets

CONFIG = Presets.cue_accumulation()


def reduced():
    return Presets.cue_accumulation(n_in=12, n_hid=20, num_ticks=40)
