from repro.configs.base import ModelConfig, Shape, SHAPES, get_config, list_archs  # noqa: F401
