"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE every 2nd
layer (16 experts, top-2).  [arXiv:2403.19887]

32 layers in 4 periods of 8: one attention layer per period (position 3),
Mamba elsewhere; odd layers carry the 16-expert MoE FFN.  Jamba's SSM uses
d_state=16; we run it through the Mamba2/SSD layer (DESIGN.md §2 —
TPU-native chunked SSD replaces the CUDA selective scan).
"""

from repro.configs.base import ModelConfig
from repro.models.mamba import SSMConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, moe_every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=64),
    attn_every=8,
    attn_offset=3,
    rope_theta=1e6,
    sub_quadratic=True,   # 1:7 attention dilution + SSM state → long_500k runs
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=8,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, moe_every=2),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=8),
        dtype="float32",
    )
