"""qwen1.5-32b [dense] — MHA (kv=40) with QKV bias.  [hf:Qwen/Qwen1.5-*]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_head=128,
    d_ff=27392,
    vocab=152064,
    attn_bias=True,
    rope_theta=1e6,
    flat_attn_proj=True,   # 40 heads ∤ 16-way model axis → flat (H·Dh) TP
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab=512, dtype="float32",
    )
