"""phi3.5-moe-42b-a6.6b [moe] — 16 experts, top-2, GQA (kv=8).
[hf:microsoft/Phi-3.5-MoE-instruct]
"""

from repro.configs.base import ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=6400,
    vocab=32064,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400),
    rope_theta=1e4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128),
        dtype="float32",
    )
