"""seamless-m4t-large-v2 [audio] — encoder-decoder backbone (24+24 layers);
the speech frontend is a stub supplying precomputed frame embeddings.
[arXiv:2308.11596]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,              # decoder depth
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=8192,
    vocab=256256,            # 256206 padded to a multiple of 256 (TP-divisible)
    encdec=True,
    n_enc_layers=24,
    enc_seq=4096,             # encoder memory length for decode shapes
    rope_theta=1e4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_head=16, d_ff=128, vocab=512, enc_seq=32, dtype="float32",
    )
