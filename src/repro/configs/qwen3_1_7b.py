"""qwen3-1.7b [dense] — qk-norm, GQA (kv=8), tied embeddings.  [hf:Qwen/Qwen3-*]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=6144,
    vocab=151936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=512, dtype="float32",
    )
