"""llama-3.2-vision-90b [vlm] — 100-layer text backbone with a gated
cross-attention layer every 5th layer attending precomputed patch
embeddings (the vision frontend is a stub per the assignment brief).
[hf:meta-llama/Llama-3.2-90B-Vision]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab=128256,
    cross_attn_every=5,
    n_media_tokens=1600,      # ~one tile of patch embeddings
    rope_theta=5e5,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=512, cross_attn_every=5, n_media_tokens=16,
        dtype="float32",
    )
