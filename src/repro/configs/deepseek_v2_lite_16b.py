"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + 64 routed experts top-6
with 2 shared experts; dense FFN on the first layer.  [arXiv:2405.04434]
"""

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=192,               # qk_nope (128) + qk_rope (64)
    d_ff=10944,               # the dense first layer
    vocab=102400,
    moe=MoEConfig(
        n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2, first_dense=True
    ),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    rope_theta=1e4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_head=48,
        d_ff=256, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, n_shared=2, first_dense=True),
        mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32),
        dtype="float32",
    )
