"""Typed model configuration — the LM-substrate analog of the SPI parameter
bank: every runtime-tunable quantity is a config field.

``get_config(arch_id)`` loads ``repro.configs.<arch_id>`` (dashes → underscores)
and returns its ``CONFIG``; each arch module also provides ``reduced()`` — a
small same-family config for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax.numpy as jnp

from repro.models.mamba import SSMConfig
from repro.models.moe import MoEConfig


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # transformer details
    qk_norm: bool = False
    attn_bias: bool = False
    flat_attn_proj: bool = False    # store QKV/O projections flattened
                                    # (H·Dh) — TP for head counts (40, 56)
                                    # that don't divide the 16-way model axis
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # mixture-of-experts / latent attention / state space
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid schedule: attention layer once per `attn_every` layers (0 = none)
    attn_every: int = 1
    attn_offset: int = 3            # position of the attn layer in the period
    # vlm: one cross-attn layer per `cross_attn_every` layers
    cross_attn_every: int = 0
    n_media_tokens: int = 0
    # enc-dec (audio): n_layers is the decoder depth
    encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 4096             # stub-frontend memory length for decode shapes
    # execution knobs
    dtype: str = "bfloat16"
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    prune_causal: bool = False      # §Perf lever: exact-causal FLOPs
    return_cache: bool = False      # set by prefill wrapper
    remat: bool = True
    remat_policy: str = "full"      # "full" | "dots" (§Perf lever: save
                                    # matmul outputs, skip fwd recompute)
    scan_layers: bool = True
    unroll_loops: bool = False      # dry-run calibration: unroll attn tiles
                                    # so HLO cost analysis counts every tile
    sub_quadratic: bool = False     # arch supports long_500k decode

    @property
    def np_dtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytical parameter / FLOP accounting (for §Roofline) ----

    def param_count(self) -> int:
        from repro.models.transformer import count_params

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.transformer import count_params

        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "jamba-v0.1-52b",
    "qwen1.5-32b",
    "llama3-8b",
    "yi-34b",
    "qwen3-1.7b",
    "deepseek-v2-lite-16b",
    "phi3.5-moe-42b-a6.6b",
    "llama-3.2-vision-90b",
    "mamba2-1.3b",
    "seamless-m4t-large-v2",
]


def _module(arch: str):
    return importlib.import_module("repro.configs." + arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()


def list_archs():
    return list(ARCH_IDS)
