"""yi-34b [dense] — llama-architecture GQA (kv=8).  [arXiv:2403.04652]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    vocab=64000,
    rope_theta=5e6,
    flat_attn_proj=True,   # 56 heads ∤ 16-way model axis → flat (H·Dh) TP
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=512, dtype="float32",
    )
