"""mamba2-1.3b [ssm] — attention-free SSD (state-space duality), 48 layers,
ssm_state=128, tied embeddings.  [arXiv:2405.21060]
"""

from repro.configs.base import ModelConfig
from repro.models.mamba import SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=64,               # SSD heads = d_inner / head_dim (bookkeeping)
    n_kv_heads=64,
    d_head=64,
    d_ff=0,                   # no MLP — Mamba2 blocks only
    vocab=50304,              # 50280 padded to a multiple of 128 (TP-divisible)
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=64),
    tie_embeddings=True,
    sub_quadratic=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=8, n_kv_heads=8, d_head=16,
        vocab=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=8),
        dtype="float32",
    )
