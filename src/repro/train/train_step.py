"""Train-step builders: grad + AdamW update, microbatch accumulation,
optional cross-pod gradient compression.

Three variants (all pure functions of (params, opt_state, batch)):

* plain          — one jit: value_and_grad → AdamW.  GSPMD inserts the
                   gradient reduce-scatter/all-reduce from the shardings.
* microbatched   — ``lax.scan`` over ``n_micro`` slices of the global batch
                   with an f32 grad accumulator; donated carry lets XLA
                   overlap each slice's gradient collective with the next
                   slice's compute.
* compressed     — the pod axis is lifted out of GSPMD with
                   ``shard_map(..., auto={'data','model'})``: each pod
                   computes grads on its pod-local batch (data/model axes
                   still GSPMD-managed inside), then the cross-pod mean runs
                   through int8 + error-feedback (:mod:`repro.optim.compression`)
                   — the DCN-crossing collective shrinks 4×.
"""

from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.optim.adamw import AdamW
from repro.optim.compression import compressed_psum_mean


def opt_state_specs(param_specs: Any) -> Dict[str, Any]:
    """Logical-axis tree for AdamW state (inherits parameter sharding)."""
    return {
        "mu": param_specs,
        "nu": param_specs,
        "step": (),
    }


def abstract_opt_state(params_sds: Any) -> Dict[str, Any]:
    f32 = lambda sd: jax.ShapeDtypeStruct(sd.shape, jnp.float32)
    return {
        "mu": jax.tree.map(f32, params_sds),
        "nu": jax.tree.map(f32, params_sds),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_train_step(
    model,
    opt: AdamW,
    *,
    n_micro: int = 1,
) -> Callable:
    """Returns ``train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)``."""

    def grads_of(params, batch):
        def loss_fn(p):
            loss, metrics = model.train_loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return grads, metrics

    def accumulate_grads(params, batch):
        if n_micro == 1:
            return grads_of(params, batch)
        micro = jax.tree.map(
            lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]), batch
        )

        def body(acc, mb):
            g, m = grads_of(params, mb)
            acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32), acc, g)
            return acc, m

        acc0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        acc, ms = jax.lax.scan(body, acc0, micro)
        grads = jax.tree.map(lambda a: a / n_micro, acc)
        metrics = jax.tree.map(lambda x: x.mean(), ms)
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = accumulate_grads(params, batch)
        params, opt_state, om = opt.update(params, grads, opt_state)
        metrics.update(om)
        return params, opt_state, metrics

    return train_step


def make_train_step_compressed(
    model,
    opt: AdamW,
    mesh,
    *,
    n_micro: int = 1,
) -> Callable:
    """Pod-axis int8+EF gradient compression; data/model stay under GSPMD.

    State gains a ``residual`` pytree (f32, param-shaped) for error feedback.
    """
    inner = make_train_step_parts(model, opt, n_micro)

    def stepped(params, opt_state, residual, batch):
        def body(params, opt_state, residual, batch):
            # Inside the manual-`pod` region the Auto sharding constraints
            # must not mention `pod` — rescope the rules without it.
            from repro.distributed.sharding import (
                current_mesh, current_rules, use_mesh,
            )

            with use_mesh(current_mesh() or mesh, current_rules().strip("pod")):
                grads, metrics = inner(params, batch)
            grads, residual = compressed_psum_mean(grads, residual, "pod")
            params, opt_state, om = opt.update(params, grads, opt_state)
            metrics.update(om)
            return params, opt_state, residual, metrics

        return shard_map(
            body,
            mesh=mesh,
            axis_names={"pod"},   # data/model stay under GSPMD inside
            in_specs=(P(), P(), P(), P("pod")),
            out_specs=(P(), P(), P(), P()),
            check_vma=False,
        )(params, opt_state, residual, batch)

    return stepped


def make_train_step_parts(model, opt: AdamW, n_micro: int = 1):
    """(params, batch) -> (grads, metrics) — shared by the compressed path."""
    plain = make_train_step(model, opt, n_micro=n_micro)

    def grads_only(params, batch):
        def loss_fn(p):
            loss, metrics = model.train_loss(p, batch)
            return loss, metrics

        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            return grads, metrics
        micro = jax.tree.map(
            lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]), batch
        )

        def body(acc, mb):
            (loss, m), g = jax.value_and_grad(
                lambda p: model.train_loss(p, mb), has_aux=True
            )(params)
            return jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32), acc, g), m

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        acc, ms = jax.lax.scan(body, acc0, micro)
        return (
            jax.tree.map(lambda a: a / n_micro, acc),
            jax.tree.map(lambda x: x.mean(), ms),
        )

    return grads_only
