from repro.train.train_step import make_train_step, opt_state_specs  # noqa: F401
from repro.train.serve_step import make_decode_step, make_prefill  # noqa: F401
