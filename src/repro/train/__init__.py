from repro.train.train_step import make_train_step, opt_state_specs  # noqa: F401
from repro.train.serve_step import make_decode_step, make_prefill  # noqa: F401
from repro.train.eprop_step import epoch_batches, make_eprop_commit_step  # noqa: F401
