"""Telemetry — the TPU analog of the paper's ILA debug unit.

The FPGA system samples an ``EPOCH_ACC`` counter with an integrated logic
analyzer; here, on-device scalars are folded into each step's outputs and a
host-side ring buffer keeps the recent history for the straggler watchdog
and NaN sentinel.
"""

from __future__ import annotations

import dataclasses
import json
import math
from collections import deque
from pathlib import Path
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class StepStats:
    step: int
    wall_s: float
    metrics: Dict[str, float]


class MetricsLogger:
    def __init__(self, log_file: Optional[str] = None, window: int = 256):
        self.history: Deque[StepStats] = deque(maxlen=window)
        self.log_file = Path(log_file) if log_file else None
        self._fh = self.log_file.open("a") if self.log_file else None

    def log(self, step: int, wall_s: float, metrics: Dict) -> StepStats:
        flat = {k: float(v) for k, v in metrics.items()}
        st = StepStats(step, wall_s, flat)
        self.history.append(st)
        if self._fh:
            self._fh.write(json.dumps({"step": step, "wall_s": wall_s, **flat}) + "\n")
            self._fh.flush()
        return st

    def close(self):
        if self._fh:
            self._fh.close()


class StragglerWatchdog:
    """Per-step wall-clock EWMA; flags steps slower than ``k``·σ.

    On a real pod the flagged host feeds the controller's drain/replace
    logic; here it raises the signal the trainer logs and (optionally) acts
    on by re-meshing.
    """

    def __init__(self, k: float = 4.0, alpha: float = 0.05, warmup: int = 8):
        self.k, self.alpha, self.warmup = k, alpha, warmup
        self.mean: Optional[float] = None
        self.var = 0.0
        self.n = 0
        self.flagged: List[int] = []

    def observe(self, step: int, wall_s: float) -> bool:
        self.n += 1
        if self.mean is None:
            self.mean = wall_s
            return False
        delta = wall_s - self.mean
        slow = (
            self.n > self.warmup
            and delta > self.k * math.sqrt(self.var + 1e-12)
            and delta > 0.05 * self.mean
        )
        self.mean += self.alpha * delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        if slow:
            self.flagged.append(step)
        return slow


def finite(x: float) -> bool:
    return math.isfinite(x)
