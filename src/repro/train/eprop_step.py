"""e-prop batch-commit step for the fault-tolerant :class:`~repro.train.trainer.Trainer`.

The generic trainer wants ``step_fn(params, opt_state, batch) -> (params,
opt_state, metrics)`` with finite ``loss``/``grad_norm`` metrics (NaN steps
are rolled back, checkpoints are cut on a cadence).  This module adapts the
SNN online-learning stack to that interface: one END_B batch commit per
trainer step, executed through a shared
:class:`~repro.core.backend.ExecutionBackend` — the same object a
:class:`repro.serve.BatchedEngine` can serve live weights from.

``loss`` is the mean cross-entropy of the accumulated LI readout (the
quantity the e-prop learning signal is derived from) and ``grad_norm`` the
global norm of the committed ``dw`` — so the trainer's non-finite-step
rejection guards the weight SRAM exactly like it guards the LM substrate.
"""

from __future__ import annotations

from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.core.backend import BackendLike, as_backend
from repro.core.controller import batch_commit_update
from repro.core.rsnn import RSNNConfig
from repro.distributed.checkpoint import ReplayCursor
from repro.optim.eprop_opt import EpropSGD


def make_eprop_commit_step(
    cfg: RSNNConfig, opt: EpropSGD, backend: BackendLike = "auto"
) -> Callable:
    """Build a Trainer-compatible END_B step over ``(S, T, N)`` device batches.

    Note: float-weight configurations only — the trainer step carries no rng,
    so ``stochastic_round`` commits are not supported here (use
    :class:`~repro.core.controller.OnlineLearner` for those).
    """
    if opt.cfg.stochastic_round:
        raise ValueError(
            "Trainer steps carry no rng key; stochastic rounding needs "
            "OnlineLearner"
        )
    engine = as_backend(cfg, backend)

    @jax.jit
    def step(weights, opt_state, batch):
        new_w, new_opt, dw, metrics = batch_commit_update(
            cfg, opt, engine, weights, opt_state, batch
        )
        y_star = jax.nn.one_hot(batch["label"], cfg.n_out)
        logp = jax.nn.log_softmax(metrics["acc_y"])
        loss = -(logp * y_star).sum(axis=-1).mean()
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(dw))
        )
        acc = (metrics["pred"] == batch["label"]).mean()
        return new_w, new_opt, {
            "loss": loss,
            "grad_norm": gnorm,
            "accuracy": acc,
            "spike_rate": metrics["spike_rate"],
        }

    return step


def epoch_batches(
    pipeline, split: str = "train", max_epochs: Optional[int] = None,
    cursor: Optional["ReplayCursor"] = None,
) -> Iterator[dict]:
    """Flatten a pipeline's epochs into the endless batch iterator the
    Trainer consumes (``max_epochs`` bounds it for tests).

    ``cursor`` is a :class:`~repro.distributed.checkpoint.ReplayCursor`
    advanced *in place*: before each batch is yielded it is set to that
    batch's position ``(epoch, index + 1)`` — the next batch a consumer
    that commits the yielded one would need — so a checkpoint cut after
    the commit records exactly where to resume.  Pass a restored cursor to
    start mid-stream: the pipeline's ``(seed, epoch)``-derived order makes
    the replayed sequence identical to what the crashed run would have
    consumed (the determinism contract in :mod:`repro.data.pipeline`).
    """
    epoch = cursor.epoch if cursor is not None else 0
    start = cursor.batch if cursor is not None else 0
    while max_epochs is None or epoch < max_epochs:
        yielded = False
        it = (pipeline.batches(split, epoch, start_batch=start)
              if start else pipeline.batches(split, epoch))
        for i, batch in enumerate(it, start=start):
            yielded = True
            if cursor is not None:
                cursor.epoch, cursor.batch = epoch, i + 1
            yield batch
        if not yielded and start == 0:
            return
        epoch += 1
        start = 0
        if cursor is not None:
            cursor.epoch, cursor.batch = epoch, 0
