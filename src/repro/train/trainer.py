"""Fault-tolerant training loop for the LM substrate.

Production posture (1000+ nodes):

* **checkpoint/restart** — atomic async checkpoints every ``ckpt_every``
  steps (params + optimizer state + data position + rng), keep-N retention;
  ``Trainer.restore()`` resumes from the newest complete checkpoint, onto
  *any* mesh (elastic re-meshing via :mod:`repro.distributed.elastic`).
* **NaN/inf step rejection** — a non-finite loss or grad-norm rolls the
  step back (params/opt state are only committed after the check) and
  skips the offending batch; ``max_bad_steps`` consecutive rejections abort.
* **straggler watchdog** — per-step wall-clock EWMA flags >kσ outliers
  (:class:`repro.train.metrics.StragglerWatchdog`); flagged steps are
  logged and counted for the controller to act on.
* **SIGTERM safety** — preemption signals set a flag; the loop finishes the
  current step, writes a final checkpoint, and exits cleanly.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.distributed.checkpoint import (
    CheckpointManager,
    CheckpointPolicy,
    ReplayCursor,
)
from repro.train.metrics import MetricsLogger, StragglerWatchdog


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 1000
    ckpt_every: int = 100
    ckpt_dir: str = "checkpoints"
    keep_ckpts: int = 3
    log_every: int = 10
    max_bad_steps: int = 10          # consecutive NaN/inf rejections allowed
    watchdog_k: float = 4.0
    log_file: Optional[str] = None


class Trainer:
    def __init__(
        self,
        step_fn: Callable,                      # (params, opt, batch) -> (params, opt, metrics)
        params: Any,
        opt_state: Any,
        data_iter: Iterator[Dict[str, jax.Array]],
        cfg: TrainerConfig,
        checkpoint: Optional[CheckpointPolicy] = None,
        cursor: Optional[ReplayCursor] = None,
    ):
        """``checkpoint`` (a :class:`CheckpointPolicy`) overrides the loose
        ``cfg.ckpt_dir``/``keep_ckpts``/``ckpt_every`` knobs and selects
        async vs blocking cadence saves.  ``cursor`` is a
        :class:`ReplayCursor` shared with the data iterator (see
        :func:`repro.train.eprop_step.epoch_batches`): when set, its
        position rides in every manifest and :meth:`restore` brings it
        back — resume-with-replay for the generic step loop."""
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.data = data_iter
        self.cfg = cfg
        self.step = 0
        self.policy = checkpoint
        if checkpoint is not None:
            self.ckpt = checkpoint.manager()
            self.ckpt_every = max(1, int(checkpoint.every))
            self._async = bool(checkpoint.async_save)
        else:
            self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts)
            self.ckpt_every = cfg.ckpt_every
            self._async = True
        self.cursor = cursor
        self.metrics = MetricsLogger(cfg.log_file)
        self.watchdog = StragglerWatchdog(k=cfg.watchdog_k)
        self.bad_steps = 0
        self.rejected_steps = 0
        self.straggler_flags = 0
        self._stop = False
        self._old_handlers = {}

    # ------------------------------------------------------------- signals
    def install_signal_handlers(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._old_handlers[sig] = signal.signal(sig, self._on_term)

    def restore_signal_handlers(self):
        for sig, h in self._old_handlers.items():
            signal.signal(sig, h)
        self._old_handlers = {}

    def _on_term(self, signum, frame):
        self._stop = True   # finish current step, checkpoint, exit

    # ------------------------------------------------------------- ckpt
    def _state(self):
        return {"params": self.params, "opt_state": self.opt_state}

    def save(self, blocking: bool = False):
        extra = {"data_step": self.step}
        if self.cursor is not None:
            extra["cursor"] = self.cursor.as_manifest()
        if blocking:
            self.ckpt.save(self.step, self._state(), extra)
        else:
            self.ckpt.save_async(self.step, self._state(), extra)

    def restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        host, manifest = self.ckpt.restore(latest, jax.tree.map(np.asarray, jax.device_get(self._state())))
        placed = jax.device_put(host, jax.tree.map(lambda x: x.sharding, self._state()))
        self.params, self.opt_state = placed["params"], placed["opt_state"]
        self.step = manifest["step"]
        if self.cursor is not None and "cursor" in manifest:
            restored = ReplayCursor.from_manifest(manifest["cursor"])
            self.cursor.epoch, self.cursor.batch = restored.epoch, restored.batch
        return True

    # ------------------------------------------------------------- loop
    def run(self) -> Dict[str, Any]:
        cfg = self.cfg
        while self.step < cfg.total_steps and not self._stop:
            batch = next(self.data)
            t0 = time.time()
            new_params, new_opt, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            loss = float(metrics["loss"])
            gnorm = float(metrics.get("grad_norm", 0.0))
            wall = time.time() - t0

            if not (np.isfinite(loss) and np.isfinite(gnorm)):
                # Reject: drop the would-be update, keep old state.
                self.bad_steps += 1
                self.rejected_steps += 1
                jax.tree.map(lambda x: None, new_params)  # let buffers free
                if self.bad_steps > cfg.max_bad_steps:
                    self.save(blocking=True)
                    raise RuntimeError(
                        f"{self.bad_steps} consecutive non-finite steps at {self.step}"
                    )
                continue

            self.bad_steps = 0
            self.params, self.opt_state = new_params, new_opt
            self.step += 1

            if self.watchdog.observe(self.step, wall):
                self.straggler_flags += 1
                self.metrics.log(self.step, wall, {"straggler": 1.0, **metrics})
            if self.step % cfg.log_every == 0:
                self.metrics.log(self.step, wall, metrics)
            if self.step % self.ckpt_every == 0:
                self.save(blocking=not self._async)

        self.ckpt.wait()
        self.save(blocking=True)
        return {
            "step": self.step,
            "rejected_steps": self.rejected_steps,
            "straggler_flags": self.straggler_flags,
            "stopped_by_signal": self._stop,
        }
