"""Serving steps: prefill and single-token decode (+ sampling helpers)."""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp


def make_prefill(model) -> Callable:
    def prefill(params, batch):
        return model.prefill(params, batch)

    return prefill


def make_decode_step(model, *, sample: Optional[str] = None, temperature: float = 1.0):
    """decode_step(params, caches, tokens, pos[, rng]) → (next_tokens|logits, caches)."""

    def decode(params, caches, tokens, pos, rng=None):
        logits, caches = model.decode_step(params, caches, tokens, pos)
        if sample is None:
            return logits, caches
        if sample == "greedy":
            nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        elif sample == "temperature":
            nxt = jax.random.categorical(
                rng, logits[:, -1, :].astype(jnp.float32) / temperature
            )[:, None].astype(jnp.int32)
        else:
            raise ValueError(sample)
        return nxt, caches

    return decode


def generate(model, params, prompt_batch, steps: int, cache_len: int):
    """Greedy generation loop for the runnable examples (host-side loop)."""
    decode = jax.jit(make_decode_step(model, sample="greedy"))
    logits, caches = jax.jit(model.prefill)(params, prompt_batch)
    B = prompt_batch["tokens"].shape[0]
    prompt_len = prompt_batch["tokens"].shape[1]
    # Right-pad prefill caches into a cache_len-slot cache.
    full = model.init_cache(B, cache_len)

    def splice(dst, src):
        if src is None:
            return dst
        if dst.shape == src.shape:
            return src
        pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src, pad)

    caches = jax.tree.map(
        splice, full, caches,
        is_leaf=lambda x: x is None or hasattr(x, "shape"),
    )
    tokens = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    out = [tokens]
    pos = prompt_len
    for _ in range(steps - 1):
        tokens, caches = decode(params, caches, tokens, jnp.int32(pos))
        out.append(tokens)
        pos += 1
    return jnp.concatenate(out, axis=1)
