"""Chaos harness: kill a training run at the worst moments, restart it, and
prove the recovered weights are *bitwise* what an uninterrupted run produces.

The harness has two halves:

* **worker** (``python -m repro.train.chaos --ckpt-dir ...``) — a real
  subprocess that builds a Braille END_B :class:`~repro.core.controller.
  OnlineLearner` with a checkpoint policy and runs ``fit(resume=True)``.
  Fault injection rides on the learner's ``on_commit`` hook:

  - ``--kill-at-commit K`` — ``SIGKILL`` itself at commit ``K`` (commit
    boundary: the checkpoint for ``K`` was just cut, possibly still
    in-flight on the async writer — a torn ``.tmp`` is part of the drill);
  - ``--kill-mid-save-step K`` — monkeypatch the checkpoint module's
    ``os.rename`` to ``SIGKILL`` the process the instant step ``K``'s
    atomic rename would commit — the canonical torn-save crash;
  - ``--sigterm-at-commit K`` — the *graceful* preemption drill: the
    installed handler finishes the batch, cuts a final blocking
    checkpoint, and the worker exits with :data:`STOPPED_RC`.

  A worker that reaches the configured epochs writes its final quantized
  weights (npz) + a result manifest (json) to ``--out`` and exits 0.

* **driver** (:func:`run_chaos`, used by ``tests/test_fault_tolerance.py``
  and ``benchmarks/bench_chaos.py``) — spawns the worker with a kill flag,
  watches it die, then respawns it *without* kill flags until it exits
  clean; :func:`golden_run` produces the uninterrupted reference weights
  in-process.  Bitwise comparison is the caller's one-line job.

Determinism contract that makes the bitwise gate possible: batch order is
pure in ``(seed, epoch)`` (:mod:`repro.data.pipeline`), the stochastic-
rounding key chain is checkpointed, and (optionally) END_B accumulates on
the integer commit grid so even the 8→4 mesh-shrink restart is bit-exact
(``--deterministic`` / :data:`repro.core.quant.DW_COMMIT_SPEC`).
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path
from typing import Dict, Optional

import numpy as np

STOPPED_RC = 75        # worker stopped gracefully by SIGTERM (EX_TEMPFAIL)

_SRC = str(Path(__file__).resolve().parents[2])


def build_learner(
    ckpt_dir: Optional[str],
    *,
    backend: str = "scan",
    quantized: bool = True,
    epochs: int = 3,
    spb: int = 16,
    samples_per_class: int = 12,
    num_ticks: int = 48,
    seed: int = 3,
    mesh_devices: int = 0,
    deterministic: bool = False,
    checkpoint_every: int = 1,
    keep: int = 0,
    async_save: bool = True,
    registry=None,
):
    """A small Braille END_B learner + pipeline, identically parameterized
    for golden, interrupted and resumed runs (one construction point so the
    bitwise comparison can't be defeated by config drift)."""
    import jax

    from repro.core.backend import RuntimeConfig
    from repro.core.controller import ControllerConfig, OnlineLearner
    from repro.core.quant import DW_COMMIT_SPEC, WEIGHT_SPEC
    from repro.core.rsnn import Presets
    from repro.data.braille import BrailleConfig, make_braille_dataset
    from repro.data.pipeline import make_pipeline
    from repro.distributed.checkpoint import CheckpointPolicy
    from repro.launch.mesh import make_data_mesh
    from repro.optim.eprop_opt import EpropSGDConfig

    data = make_braille_dataset(
        "AEU", BrailleConfig(samples_per_class=samples_per_class,
                             num_ticks=num_ticks)
    )
    cfg = Presets.braille(n_classes=3, num_ticks=num_ticks,
                          quantized=quantized)
    ctrl = ControllerConfig(
        num_epochs=epochs, samples_per_batch=spb, commit="batch",
        shuffle=True, eval_every=10_000,
    )
    opt = (
        EpropSGDConfig(lr=0.01, clip=10.0, quant=WEIGHT_SPEC,
                       stochastic_round=True)
        if quantized
        else EpropSGDConfig(lr=0.01, clip=10.0)
    )
    mesh = make_data_mesh(mesh_devices) if mesh_devices > 1 else None
    rt = RuntimeConfig(
        backend=backend, mesh=mesh,
        commit_grid=DW_COMMIT_SPEC if deterministic else None,
    )
    policy = (
        CheckpointPolicy(directory=ckpt_dir, every=checkpoint_every,
                         keep=keep, async_save=async_save)
        if ckpt_dir is not None
        else None
    )
    learner = OnlineLearner(
        cfg, ctrl, opt, jax.random.key(seed + 100), runtime=rt,
        registry=registry, checkpoint=policy,
    )
    pipeline = make_pipeline(
        "arm", data, samples_per_batch=spb, shuffle_train=True, seed=seed
    )
    return learner, pipeline


def golden_run(**kw) -> Dict[str, np.ndarray]:
    """The uninterrupted reference: same learner, no checkpoints, no kills.
    Returns the final weights as host numpy."""
    learner, pipeline = build_learner(None, **kw)
    learner.fit(pipeline)
    return {k: np.asarray(v) for k, v in sorted(learner.weights.items())}


# ---------------------------------------------------------------- worker

def _arm_mid_save_kill(at_step: int) -> None:
    """SIGKILL this process the moment checkpoint ``at_step``'s atomic
    rename would land — the write is complete but never committed, leaving
    the torn ``.tmp`` the next manager must sweep."""
    from repro.distributed import checkpoint as ckpt_mod

    real_rename = ckpt_mod.os.rename
    tag = f"step_{at_step:09d}"

    def rename(src, dst):
        if tag == Path(str(dst)).name:
            os.kill(os.getpid(), signal.SIGKILL)
        return real_rename(src, dst)

    ckpt_mod.os.rename = rename


def run_worker(args: argparse.Namespace) -> int:
    t0 = time.time()
    learner, pipeline = build_learner(
        args.ckpt_dir,
        backend=args.backend,
        quantized=not args.float,
        epochs=args.epochs,
        spb=args.spb,
        samples_per_class=args.samples_per_class,
        num_ticks=args.ticks,
        seed=args.seed,
        mesh_devices=args.mesh_devices,
        deterministic=args.deterministic,
        checkpoint_every=args.every,
        async_save=not args.sync,
    )
    if args.kill_mid_save_step is not None:
        _arm_mid_save_kill(args.kill_mid_save_step)
    learner.install_signal_handlers()

    resumed_from = learner._commits if learner.restore_checkpoint() else None
    first_commit_s: Dict[str, float] = {}

    def on_commit(lrn, commits):
        first_commit_s.setdefault("t", time.time() - t0)
        if args.kill_at_commit is not None and commits >= args.kill_at_commit:
            os.kill(os.getpid(), signal.SIGKILL)
        if (
            args.sigterm_at_commit is not None
            and commits >= args.sigterm_at_commit
        ):
            os.kill(os.getpid(), signal.SIGTERM)

    learner.fit(pipeline, on_commit=on_commit)
    learner.restore_signal_handlers()
    if learner.stopped_by_signal:
        return STOPPED_RC

    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        np.savez(
            out.with_suffix(".npz"),
            **{k: np.asarray(v) for k, v in sorted(learner.weights.items())},
        )
        train_acc = learner.log.train_acc[-1] if learner.log.train_acc else None
        out.with_suffix(".json").write_text(json.dumps({
            "commits": int(learner._commits),
            "resumed_from": resumed_from,
            "recovery_s": first_commit_s.get("t"),
            "wall_s": time.time() - t0,
            "train_acc": train_acc,
        }))
    return 0


# ---------------------------------------------------------------- driver

def spawn(
    argv,
    mesh_devices: int = 0,
    timeout: float = 600.0,
) -> subprocess.CompletedProcess:
    """Run one worker subprocess with a pinned JAX environment (CPU platform,
    explicit virtual device count — subprocess determinism must not depend
    on whatever XLA_FLAGS the parent happened to inherit)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env["JAX_PLATFORMS"] = "cpu"
    if mesh_devices > 1:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={mesh_devices}"
        )
    else:
        env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.train.chaos", *map(str, argv)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )


def run_chaos(
    ckpt_dir: str,
    out: str,
    kill_args,
    worker_args,
    mesh_devices: int = 0,
    restart_mesh_devices: Optional[int] = None,
    max_restarts: int = 5,
) -> Dict:
    """The full drill: one doomed worker, then restarts until a clean exit.

    ``kill_args`` ride only on the first spawn; restarts run the identical
    worker without them.  ``restart_mesh_devices`` re-hosts the restarts on
    a different virtual device count (the elastic 8→4 shrink drill).
    Returns the worker's result manifest plus the restart count.
    """
    base = ["--ckpt-dir", ckpt_dir, "--out", out, *map(str, worker_args)]
    first = spawn(base + list(map(str, kill_args)), mesh_devices=mesh_devices)
    if first.returncode == 0:
        raise RuntimeError(
            f"doomed worker exited clean — kill never fired\n{first.stdout}"
            f"\n{first.stderr}"
        )
    restarts = 0
    rc_mesh = mesh_devices if restart_mesh_devices is None else restart_mesh_devices
    while restarts < max_restarts:
        restarts += 1
        proc = spawn(base, mesh_devices=rc_mesh)
        if proc.returncode == 0:
            break
        if proc.returncode not in (-signal.SIGKILL, STOPPED_RC):
            raise RuntimeError(
                f"restart {restarts} died unexpectedly rc={proc.returncode}"
                f"\n{proc.stdout}\n{proc.stderr}"
            )
    else:
        raise RuntimeError(f"no clean exit after {max_restarts} restarts")
    result = json.loads(Path(out).with_suffix(".json").read_text())
    result["restarts"] = restarts
    return result


def load_result_weights(out: str) -> Dict[str, np.ndarray]:
    with np.load(Path(out).with_suffix(".npz")) as z:
        return {k: z[k] for k in z.files}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--out", default=None)
    ap.add_argument("--backend", default="scan")
    ap.add_argument("--float", action="store_true",
                    help="float weights (default: quantized chip mode)")
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--spb", type=int, default=16)
    ap.add_argument("--samples-per-class", type=int, default=12)
    ap.add_argument("--ticks", type=int, default=48)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--mesh-devices", type=int, default=0)
    ap.add_argument("--deterministic", action="store_true",
                    help="END_B on the integer commit grid (mesh-invariant)")
    ap.add_argument("--every", type=int, default=1,
                    help="checkpoint every N commits")
    ap.add_argument("--sync", action="store_true",
                    help="blocking saves (default: async)")
    ap.add_argument("--kill-at-commit", type=int, default=None)
    ap.add_argument("--kill-mid-save-step", type=int, default=None)
    ap.add_argument("--sigterm-at-commit", type=int, default=None)
    return run_worker(ap.parse_args(argv))


if __name__ == "__main__":
    sys.exit(main())
