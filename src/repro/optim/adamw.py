"""AdamW — pure-JAX pytree optimizer for the LM substrate.

Hand-rolled (no optax in the deployment environment): decoupled weight
decay, bias-corrected moments, optional global-norm clipping and a linear
warmup + cosine decay schedule.  State is a flat pytree so it inherits the
parameters' NamedSharding under pjit (ZeRO-3-equivalent: optimizer state is
sharded exactly like the FSDP-sharded params).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip: Optional[float] = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to ``min_lr_ratio * lr``."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


class AdamW:
    def __init__(self, cfg: AdamWConfig):
        self.cfg = cfg

    def init(self, params: Any) -> Dict[str, Any]:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(
        self, params: Any, grads: Any, state: Dict[str, Any]
    ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
        cfg = self.cfg
        step = state["step"] + 1
        gnorm = global_norm(grads)
        if cfg.clip is not None:
            scale = jnp.minimum(1.0, cfg.clip / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        lr = schedule(cfg, step)
        c1 = 1.0 - cfg.beta1 ** step.astype(jnp.float32)
        c2 = 1.0 - cfg.beta2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = cfg.beta1 * m + (1 - cfg.beta1) * g32
            v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g32)
            mh, vh = m / c1, v / c2
            delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
                jnp.float32
            )
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["mu"])
        flat_v = jax.tree.leaves(state["nu"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        new_state = {"mu": new_m, "nu": new_v, "step": step}
        return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
