"""e-prop weight-update rule — the chip's on-line SGD with fixed-point commit.

ReckOn applies the e-prop update at the end of every sample directly into its
8-bit weight SRAM, using an accumulate-then-round scheme so sub-LSB updates
still make progress.  This module packages that as a pytree optimizer:

* float mode (``quant=None``) — plain SGD (+ optional momentum / clipping),
  the configuration used for functional-accuracy experiments;
* quantized mode — weights live on a :class:`~repro.core.quant.QuantSpec`
  grid with a float residual accumulator; every ``update`` is an
  accumulate + commit (round-nearest or stochastic), bit-faithful to the
  chip's weight-SRAM read-modify-write.  Paired with a quantized execution
  backend (``cfg.neuron.quant`` / ``ExecutionBackend(quant=...)``) this is
  the full hardware-equivalence training loop; END_B batch commits pass
  ``num_updates=K`` so clip/decay keep per-sample semantics (tested in
  ``tests/test_quant.py``).  Stochastic rounding is the chip's mode and the
  quantized-config default in ``configs/reckon_braille.py``.

The returned ``dw`` convention follows :mod:`repro.core.eprop`: they are
positive-gradient sums, applied as ``w <- w - lr * dw``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QuantSpec


@dataclasses.dataclass(frozen=True)
class EpropSGDConfig:
    lr: float = 1e-2
    momentum: float = 0.0
    clip: Optional[float] = None          # per-leaf global-norm clip
    quant: Optional[QuantSpec] = None     # None = float weights
    stochastic_round: bool = False        # chip default for sub-LSB commits
    lr_out_scale: float = 1.0             # separate readout learning rate
    decay_tau: float = 0.0                # >0: lr/(1 + updates/tau) schedule
                                          # (stabilises long online runs)


class EpropSGD:
    """Functional optimizer: ``state = init(weights)``; ``update`` is jit-safe."""

    def __init__(self, cfg: EpropSGDConfig):
        self.cfg = cfg

    def init(self, weights: Dict[str, jax.Array]) -> Dict:
        # count is an exact int32 sample counter: a float32 counter stops
        # incrementing at 2^24 samples (x + 1 == x), silently freezing the
        # lr decay schedule on long online runs.  int32 also round-trips a
        # checkpoint bit-for-bit by construction.
        state: Dict = {"count": jnp.zeros((), jnp.int32)}
        if self.cfg.momentum:
            state["mu"] = jax.tree.map(jnp.zeros_like, weights)
        if self.cfg.quant is not None:
            state["acc"] = jax.tree.map(jnp.zeros_like, weights)
        return state

    def _clip(self, dw, num_updates: float = 1.0):
        if self.cfg.clip is None:
            return dw
        gn = jnp.sqrt(
            sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(dw)) + 1e-12
        )
        # An END_B commit is the sum of num_updates per-sample updates.  Under
        # the clipped regime the per-sample steps behave like bounded noisy
        # directions whose sum grows ~sqrt(K), so the commit threshold scales
        # with sqrt(num_updates) — K× would admit single steps on the order of
        # the weight norm itself (empirically divergent on Braille).
        scale = jnp.minimum(1.0, self.cfg.clip * jnp.sqrt(num_updates) / gn)
        return jax.tree.map(lambda g: g * scale, dw)

    def update(
        self,
        weights: Dict[str, jax.Array],
        dw: Dict[str, jax.Array],
        state: Dict,
        key: Optional[jax.Array] = None,
        num_updates: float = 1.0,
    ) -> Tuple[Dict[str, jax.Array], Dict]:
        """Commit one update.  Only keys present in ``dw`` move; extra weight
        entries (e.g. a fixed random-feedback matrix ``b_fb``) pass through.

        ``num_updates`` is how many per-sample e-prop updates this commit
        represents: 1 for an END_S commit, the batch size for an END_B
        batch commit whose ``dw`` is the per-sample sum.  It advances the lr
        decay counter and scales the clip threshold so both commit modes see
        the same per-sample schedule.
        """
        cfg = self.cfg
        keys_w = [k for k in weights if k in dw]
        dw = self._clip({k: dw[k] for k in keys_w}, num_updates)
        count = state["count"]
        # num_updates is a per-commit sample count (1 or the batch size) —
        # integer by nature; keep the counter exact.
        inc = jnp.asarray(round(float(num_updates)), jnp.int32)
        state = dict(state, count=count + inc)
        scale = 1.0 / (1.0 + count / cfg.decay_tau) if cfg.decay_tau > 0 else 1.0
        lr = {
            k: cfg.lr * scale * (cfg.lr_out_scale if k == "w_out" else 1.0)
            for k in keys_w
        }
        step = {k: lr[k] * dw[k] for k in keys_w}

        if cfg.momentum:
            mu = dict(state["mu"])
            mu.update({k: cfg.momentum * state["mu"][k] + step[k] for k in keys_w})
            state = dict(state, mu=mu)
            step = {k: mu[k] for k in keys_w}

        if cfg.quant is None:
            new_w = dict(weights)
            new_w.update({k: weights[k] - step[k] for k in keys_w})
            return new_w, state

        # Quantized path: weights are grid values; accumulate the (negative)
        # update into the float residual, then commit back onto the grid.
        spec: QuantSpec = cfg.quant
        acc = {k: state["acc"][k] - step[k] for k in keys_w}
        new_w, new_acc = dict(weights), dict(state["acc"])
        if cfg.stochastic_round:
            if key is None:
                raise ValueError("stochastic rounding needs an rng key")
            rks = jax.random.split(key, len(keys_w))
            key_map = {k: rks[i] for i, k in enumerate(sorted(keys_w))}
        for k in keys_w:
            tot = weights[k] + acc[k]
            q = (
                spec.round_stochastic(tot, key_map[k])
                if cfg.stochastic_round
                else spec.round_nearest(tot)
            )
            new_w[k] = q
            new_acc[k] = tot - q
        return new_w, dict(state, acc=new_acc)

    def quantize_init(self, weights: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        """Snap freshly-initialised float weights onto the grid (SRAM load)."""
        if self.cfg.quant is None:
            return weights
        return {k: self.cfg.quant.round_nearest(w) for k, w in weights.items()}
