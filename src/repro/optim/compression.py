"""Gradient compression for the cross-pod data-parallel axis.

At 512-chip scale the slowest wire is the inter-pod DCN link, so the pod-axis
gradient all-reduce is the collective to compress.  We use the standard
int8 + error-feedback scheme (1-bit-Adam / PowerSGD family, specialised to
int8):

  1. add the persistent error-feedback residual to the local gradient;
  2. quantize to int8 with a per-tensor max-abs scale;
  3. exchange the **int8 payload** (+ one f32 scale per tensor) with
     ``all_gather`` over the ``pod`` axis — 4× fewer wire bytes than an f32
     ring all-reduce at pod=2 (1 byte/elt vs 4 bytes/elt);
  4. dequantize + mean locally; store ``local - dequant(quant(local))`` as
     the next step's residual.

Error feedback makes the scheme unbiased-in-the-limit: quantization error is
re-injected next step, so SGD converges at the uncompressed rate (Karimireddy
et al., 2019).  Used inside ``shard_map`` over the ``pod`` axis only — the
intra-pod reduce-scatter (fast ICI) stays full-precision.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = True
    bits: int = 8           # int8 payload (the only width implemented)
    axis: str = "pod"       # mesh axis whose all-reduce is compressed


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(grads: Any, residual: Any, axis_name: str) -> Tuple[Any, Any]:
    """int8+EF mean over ``axis_name``.  Call inside shard_map.

    Returns (averaged grads, new residual).  Wire payload per element:
    1 byte × axis_size (all_gather of int8) vs 4 bytes × 2(p-1)/p for an f32
    ring all-reduce.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g32)
        deq_local = dequantize_int8(q, scale)
        new_r = g32 - deq_local                      # error feedback
        qs = jax.lax.all_gather(q, axis_name)        # int8 on the wire
        ss = jax.lax.all_gather(scale, axis_name)
        mean = (
            jnp.tensordot(ss, qs.astype(jnp.float32), axes=((0,), (0,))) / n
        ).astype(g.dtype)
        return mean, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in out]),
        jax.tree.unflatten(treedef, [o[1] for o in out]),
    )


def init_residual(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def wire_bytes_f32_allreduce(n_elements: int, axis_size: int) -> int:
    """Ring all-reduce traffic per device (reduce-scatter + all-gather)."""
    return int(4 * 2 * (axis_size - 1) / axis_size * n_elements)


def wire_bytes_int8_allgather(n_elements: int, axis_size: int) -> int:
    return int(1 * (axis_size - 1) * n_elements / axis_size) * axis_size
