from repro.optim.eprop_opt import EpropSGD, EpropSGDConfig  # noqa: F401
from repro.optim.adamw import AdamW, AdamWConfig  # noqa: F401
