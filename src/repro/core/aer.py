"""AER event codec — the paper's 32-bit packed event-word format.

The SoC stores spikes, labels and end-of-sample markers as 32-bit words in
BRAM; the AER-decoder FSM unpacks them and drives ReckOn's AER bus.  Quoting
the paper (§3.1):

    "The 8 MSBs are dedicated to the type of event: 0x03 identifies a spike,
     0x02 the label of the sample and 0x01 the end of the sample.  Bits from
     23 to 12 tell the address of the target neuron for the spike, or the
     correct label of the current sample. [...] Finally, the 12 LSBs indicate
     the target time tick for the event."

We implement the *identical* word format so that event buffers produced by
this framework are bit-compatible with the FPGA BRAM images, plus vectorised
encode/decode between event buffers and dense spike rasters ``(T, N)`` — the
tensor form the TPU datapath consumes.  The FSM's READM/TICK/SPIKE/LABEL/
END_S walk becomes a scatter over the time axis.

Layout:   [31:24] type | [23:12] address/label | [11:0] tick
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

EVT_END = 0x01
EVT_LABEL = 0x02
EVT_SPIKE = 0x03

ADDR_BITS = 12
TICK_BITS = 12
MAX_ADDR = (1 << ADDR_BITS) - 1   # 4095
MAX_TICK = (1 << TICK_BITS) - 1   # 4095


class AEREncodingError(ValueError):
    """A value does not fit the 32-bit AER word format (12-bit address /
    12-bit tick / known type byte) or violates buffer structure.

    Root of the serving guard hierarchy too — ``serve.guard.GuardError``
    subclasses this, so one ``except AEREncodingError`` covers both
    codec-level and serve-boundary validation.  Raised instead of
    ``assert`` so validation survives ``python -O``.
    """


def pack(kind, addr, tick):
    """Pack event fields into uint32 words (vectorised)."""
    kind = jnp.asarray(kind, jnp.uint32)
    addr = jnp.asarray(addr, jnp.uint32)
    tick = jnp.asarray(tick, jnp.uint32)
    return (kind << 24) | ((addr & MAX_ADDR) << 12) | (tick & MAX_TICK)


def unpack(words) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Unpack uint32 words into ``(kind, addr, tick)``."""
    words = jnp.asarray(words, jnp.uint32)
    return (words >> 24) & 0xFF, (words >> 12) & MAX_ADDR, words & MAX_TICK


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Sample:
    """A decoded sample: dense raster + label metadata (a pytree)."""

    raster: jax.Array      # (T, N) float {0,1}
    label: jax.Array       # () int32
    label_tick: jax.Array  # () int32 — tick at which supervision becomes valid
    end_tick: jax.Array    # () int32 — final tick of the sample (inclusive)


def encode_sample(
    raster: np.ndarray, label: int, label_tick: int, end_tick: int | None = None
) -> np.ndarray:
    """Encode a dense raster into a tick-sorted uint32 event buffer.

    Host-side (NumPy) — this is the "bitfile/BRAM image" builder.  Event
    order matches the FSM's expectation: spike/label events sorted by tick,
    terminated by a single end-of-sample word.
    """
    T, N = raster.shape
    if end_tick is None:
        end_tick = T - 1
    if T - 1 > MAX_TICK or N - 1 > MAX_ADDR:
        raise AEREncodingError(
            f"raster ({T}, {N}) exceeds the 12-bit tick/address fields "
            f"(max {MAX_TICK + 1} ticks x {MAX_ADDR + 1} neurons)"
        )
    # Validate + mask the label/end fields like pack() does.  The seed code
    # OR'd them in raw, so an out-of-range label or tick bled into the type
    # byte and silently corrupted the word stream.
    label, label_tick, end_tick = int(label), int(label_tick), int(end_tick)
    if not 0 <= label <= MAX_ADDR:
        raise AEREncodingError(f"label {label} exceeds the 12-bit field")
    if not 0 <= label_tick <= MAX_TICK:
        raise AEREncodingError(f"label_tick {label_tick} exceeds 12 bits")
    if not 0 <= end_tick <= MAX_TICK:
        raise AEREncodingError(f"end_tick {end_tick} exceeds 12 bits")
    t_idx, n_idx = np.nonzero(raster)
    words = (np.uint32(EVT_SPIKE) << 24) | (n_idx.astype(np.uint32) << 12) | t_idx.astype(
        np.uint32
    )
    label_word = np.uint32(
        (EVT_LABEL << 24) | ((label & MAX_ADDR) << 12) | (label_tick & MAX_TICK)
    )
    end_word = np.uint32((EVT_END << 24) | (end_tick & MAX_TICK))
    # stable sort by tick; label sorts within its tick after spikes (type order
    # is irrelevant to the decode semantics).
    all_words = np.concatenate([words, np.array([label_word], np.uint32)])
    order = np.argsort(all_words & MAX_TICK, kind="stable")
    return np.concatenate([all_words[order], np.array([end_word], np.uint32)])


def decode_sample(words: jax.Array, num_in: int, num_ticks: int) -> Sample:
    """Decode an event buffer into a dense raster (vectorised, jit-able).

    ``words`` may be zero-padded (word 0x0 has type 0 and is ignored), so
    fixed-size buffers batch cleanly.
    """
    kind, addr, tick = unpack(words)
    is_spike = kind == EVT_SPIKE
    is_label = kind == EVT_LABEL
    is_end = kind == EVT_END

    # Scatter spikes into the raster.  Out-of-range / non-spike rows target a
    # dump row (index num_ticks) which is sliced away.
    t = jnp.where(is_spike, tick, num_ticks).astype(jnp.int32)
    n = jnp.where(is_spike, addr, 0).astype(jnp.int32)
    raster = jnp.zeros((num_ticks + 1, num_in), jnp.float32)
    raster = raster.at[t, n].add(1.0)[:num_ticks]
    raster = jnp.minimum(raster, 1.0)  # AER delivers unary spikes

    label = jnp.max(jnp.where(is_label, addr, 0)).astype(jnp.int32)
    label_tick = jnp.max(jnp.where(is_label, tick, 0)).astype(jnp.int32)
    end_tick = jnp.max(jnp.where(is_end, tick, 0)).astype(jnp.int32)
    return Sample(raster=raster, label=label, label_tick=label_tick, end_tick=end_tick)


def decode_batch(words: jax.Array, num_in: int, num_ticks: int) -> Sample:
    """vmap'd :func:`decode_sample` over a batch of fixed-size event buffers."""
    return jax.vmap(lambda w: decode_sample(w, num_in, num_ticks))(words)


def pad_events(buffers: list[np.ndarray], length: int | None = None) -> np.ndarray:
    """Right-pad a list of event buffers with 0x0 words into a dense matrix."""
    length = length or max(len(b) for b in buffers)
    out = np.zeros((len(buffers), length), np.uint32)
    for i, b in enumerate(buffers):
        if len(b) > length:
            raise AEREncodingError(
                f"buffer {i} has {len(b)} words, pad length is {length}"
            )
        out[i, : len(b)] = b
    return out


def supervision_mask(
    label_tick: jax.Array, end_tick: jax.Array, num_ticks: int, label_delay: int = 0
) -> jax.Array:
    """Per-tick TARGET_VALID mask: ticks in ``[label_tick + delay, end_tick]``.

    Mirrors the SPI-configurable "delay with which the inference label should
    be sent" used for the delayed-supervision task.
    """
    t = jnp.arange(num_ticks)
    return ((t >= label_tick + label_delay) & (t <= end_tick)).astype(jnp.float32)
