"""Neuron dynamics of the ReckOn RSNN: LIF hidden neurons, LI readout.

ReckOn (Frenkel & Indiveri, ISSCC'22) simulates up to 256 input + 256
recurrent leaky integrate-and-fire (LIF) neurons and 16 leaky-integrator (LI)
output neurons.  Two firing/reset mechanisms are supported by the chip and
used in the paper:

* ``reset="sub"``  — reset by subtraction of the threshold (cue-accumulation
  experiments, long-memory behaviour);
* ``reset="zero"`` — reset to zero (the Braille experiments: "reset to zero
  firing mechanism, 38 hidden neurons").

The pseudo-derivative used for the eligibility traces is a hardware-friendly
boxcar window (1 inside ``|v - vth| < width``, 0 outside), with Bellec's
triangular surrogate also available for the BPTT cross-checks in the tests.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedMode


@dataclasses.dataclass(frozen=True)
class NeuronConfig:
    alpha: float = 254.0 / 256.0   # hidden-membrane decay (SPI reg 0x0FE)
    kappa: float = 55.0 / 256.0    # readout decay        (SPI reg 0x37)
    v_th: float = 1.0              # normalised threshold (SPI reg 0x03F0)
    reset: str = "sub"             # "sub" | "zero"
    surrogate: str = "boxcar"      # "boxcar" | "triangular"
    boxcar_width: float = 0.5      # half-width of the boxcar, in units of v_th
    gamma: float = 0.3             # surrogate damping (Bellec et al.)
    # Hardware-equivalence mode: when set, lif_step/li_step execute ReckOn's
    # fixed-point datapath (12-bit saturating membrane grid, floor-leak via
    # the 8-bit registers) instead of the float dynamics, with v_th replaced
    # by the raw threshold register.  Membranes, currents and weights are
    # then integer values carried in float32 (see repro.core.quant).
    quant: Optional[QuantizedMode] = None

    def effective_v_th(self) -> float:
        """The spiking threshold the datapath compares against: the raw
        membrane-grid register in quantized mode, ``v_th`` otherwise."""
        return float(self.quant.threshold) if self.quant is not None else self.v_th


def pseudo_derivative(v_pre: jax.Array, cfg: NeuronConfig) -> jax.Array:
    """Surrogate d z / d v evaluated at the pre-reset membrane potential.

    In quantized mode ``v_pre`` lives on the membrane-grid so the window is
    evaluated around the raw threshold register — same boxcar, chip units.
    """
    v_th = cfg.effective_v_th()
    if cfg.surrogate == "boxcar":
        return (jnp.abs(v_pre - v_th) < cfg.boxcar_width * v_th).astype(
            v_pre.dtype
        )
    if cfg.surrogate == "triangular":
        return cfg.gamma * jnp.maximum(
            0.0, 1.0 - jnp.abs(v_pre - v_th) / v_th
        ).astype(v_pre.dtype)
    raise ValueError(f"unknown surrogate {cfg.surrogate!r}")


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def spike(v_pre: jax.Array, v_th: jax.Array, cfg: NeuronConfig) -> jax.Array:
    """Heaviside spike with surrogate gradient (for the BPTT reference path)."""
    return (v_pre >= v_th).astype(v_pre.dtype)


def _spike_fwd(v_pre, v_th, cfg):
    return spike(v_pre, v_th, cfg), (v_pre,)


def _spike_bwd(cfg, res, g):
    (v_pre,) = res
    return (g * pseudo_derivative(v_pre, cfg), jnp.zeros_like(v_pre).sum())


spike.defvjp(_spike_fwd, _spike_bwd)


def lif_step(
    v: jax.Array,
    current: jax.Array,
    alpha: jax.Array,
    cfg: NeuronConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One LIF timestep.

    Args:
      v:       post-reset membrane from the previous tick, shape ``(..., H)``.
      current: synaptic input current this tick, shape ``(..., H)``.
      alpha:   per-neuron (or scalar) membrane decay.

    Returns:
      ``(v_new, z_new, v_pre)`` — post-reset membrane, spikes, and the
      pre-reset membrane (the value the surrogate derivative is evaluated at,
      mirroring what ReckOn's update pipeline exposes to the e-prop unit).

    With ``cfg.quant`` set this is the chip's fixed-point pipeline instead:
    ``v_pre = sat(floor(v * alpha_reg/256) + current)`` on the signed
    membrane grid, threshold/reset against the raw threshold register
    (``alpha`` is ignored — the register drives the leak).
    """
    q = cfg.quant
    if q is not None:
        v_pre = q.sat(q.leak(v, q.alpha_reg) + current)
        v_th = jnp.asarray(float(q.threshold), v.dtype)
    else:
        v_pre = alpha * v + current
        v_th = cfg.v_th
    z = (v_pre >= v_th).astype(v.dtype)
    if cfg.reset == "sub":
        v_new = v_pre - z * v_th
    elif cfg.reset == "zero":
        v_new = v_pre * (1.0 - z)
    else:
        raise ValueError(f"unknown reset mode {cfg.reset!r}")
    return v_new, z, v_pre


def lif_step_surrogate(
    v: jax.Array, current: jax.Array, alpha: jax.Array, cfg: NeuronConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """LIF step using the surrogate-gradient spike (differentiable, for BPTT)."""
    if cfg.quant is not None:
        raise ValueError("the BPTT reference path is float-only")
    v_pre = alpha * v + current
    z = spike(v_pre, jnp.asarray(cfg.v_th, v.dtype), cfg)
    if cfg.reset == "sub":
        v_new = v_pre - z * cfg.v_th
    else:
        v_new = v_pre * (1.0 - jax.lax.stop_gradient(z))
    return v_new, z, v_pre


def li_step(
    y: jax.Array,
    current: jax.Array,
    kappa: jax.Array,
    cfg: Optional[NeuronConfig] = None,
) -> jax.Array:
    """One leaky-integrator readout step: ``y' = kappa * y + current``.

    Quantized mode (``cfg.quant`` set): the readout membranes live on the
    same saturating integer grid as the hidden layer, leaked through the
    8-bit kappa register — ``y' = sat(floor(y * kappa_reg/256) + current)``.
    """
    q = cfg.quant if cfg is not None else None
    if q is not None:
        return q.sat(q.leak(y, q.kappa_reg) + current)
    return kappa * y + current
