"""Integer golden reference for the quantized ReckOn tick datapath.

This is the bit-true oracle of the hardware-equivalence execution mode: a
plain NumPy / int64 walk of the chip's per-tick pipeline exactly as
:class:`repro.core.quant.QuantizedMode` specifies it —

  per tick t:
    current  = x[t] @ W_in + z @ W_rec          (weight codes * w_gain, int)
    v_pre    = sat( floor(v * alpha_reg / 256) + current )
    z_new    = v_pre >= threshold
    v        = v_pre - z_new * threshold        (reset="sub")
             | v_pre * (1 - z_new)              (reset="zero")
    y        = sat( floor(y * kappa_reg / 256) + z_new @ W_out )
    acc_y   += y * valid[t]                     (TARGET_VALID readout window)

with every quantity a signed integer on the 12-bit membrane grid and every
saturation/floor exactly where the RTL puts it.  The quantized ``"scan"``
and ``"kernel"`` backends of :class:`repro.core.backend.ExecutionBackend`
are asserted to reproduce these trajectories tick-for-tick
(``tests/test_quant_equivalence.py``) — that equivalence is the paper's
central software↔chip validation, restated as a unit test.

Everything here is deliberately dumb: Python loop over ticks, int64 NumPy,
no JAX — slow, obvious, and with enough headroom that overflow is
impossible for chip-maximal networks (|current| <= 512 * 128 * w_gain <
2**23 per tick before saturation).
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.quant import QuantizedMode


def weight_codes(w: np.ndarray, mode: QuantizedMode) -> np.ndarray:
    """Float weights → signed SRAM codes (int64), round-to-nearest-even.

    Mirrors :meth:`QuantizedMode.weight_codes` (``jnp.round`` rounds half to
    even, as does ``np.rint``).
    """
    spec = mode.weight_spec
    lo, hi = -(1 << (spec.bits - 1)), (1 << (spec.bits - 1)) - 1
    return np.clip(np.rint(np.asarray(w, np.float64) / spec.lsb), lo, hi).astype(
        np.int64
    )


def _leak(v: np.ndarray, reg: int) -> np.ndarray:
    """``floor(v * reg / 256)`` — multiply + arithmetic shift right by 8."""
    return np.floor_divide(v * (reg & 0xFF), 256)


def golden_forward(
    raster: np.ndarray,          # (T, B, N_in) {0,1}
    w_in: np.ndarray,            # (N_in, H) float weights (any values)
    w_rec: np.ndarray,           # (H, H) float weights — pre-masked
    w_out: np.ndarray,           # (H, O) float weights
    mode: QuantizedMode,
    *,
    reset: str = "sub",
    boxcar_width: float = 0.5,
    valid: Optional[np.ndarray] = None,   # (T, B) TARGET_VALID mask
) -> Dict[str, np.ndarray]:
    """Run the bit-true integer datapath over one ``(T, B)`` tile.

    Returns int64 trajectories: post-reset membrane ``v`` (T, B, H),
    pre-reset ``v_pre``, spikes ``z``, boxcar pseudo-derivative ``h``,
    readout ``y`` (T, B, O), the valid-window readout accumulator ``acc_y``
    (B, O) and its argmax ``pred`` (B,).
    """
    if reset not in ("sub", "zero"):
        raise ValueError(f"unknown reset mode {reset!r}")
    raster = np.asarray(raster)
    T, B, n_in = raster.shape
    H = w_rec.shape[0]
    O = w_out.shape[1]
    x = raster.astype(np.int64)
    if valid is None:
        valid = np.ones((T, B), np.int64)
    valid = np.asarray(valid).astype(np.int64)

    gain = mode.w_gain
    win = weight_codes(w_in, mode) * gain
    wrec = weight_codes(w_rec, mode) * gain
    wout = weight_codes(w_out, mode) * gain
    vth = int(mode.threshold)
    v_lo, v_hi = mode.v_min, mode.v_max
    # boxcar half-width on the membrane grid (float compare, same as the
    # JAX datapaths evaluate it — exact for the integer operands)
    bc = boxcar_width * vth

    v = np.zeros((B, H), np.int64)
    z = np.zeros((B, H), np.int64)
    y = np.zeros((B, O), np.int64)
    acc_y = np.zeros((B, O), np.int64)
    out = {
        "v": np.zeros((T, B, H), np.int64),
        "v_pre": np.zeros((T, B, H), np.int64),
        "z": np.zeros((T, B, H), np.int64),
        "h": np.zeros((T, B, H), np.int64),
        "y": np.zeros((T, B, O), np.int64),
    }
    for t in range(T):
        current = x[t] @ win + z @ wrec
        v_pre = np.clip(_leak(v, mode.alpha_reg) + current, v_lo, v_hi)
        z = (v_pre >= vth).astype(np.int64)
        v = v_pre - z * vth if reset == "sub" else v_pre * (1 - z)
        y = np.clip(_leak(y, mode.kappa_reg) + z @ wout, v_lo, v_hi)
        acc_y += y * valid[t][:, None]
        out["v_pre"][t], out["v"][t], out["z"][t], out["y"][t] = v_pre, v, z, y
        out["h"][t] = (np.abs(v_pre - vth) < bc).astype(np.int64)
    out["acc_y"] = acc_y
    out["pred"] = np.argmax(acc_y, axis=-1)
    return out
