"""The ReckOn RSNN model — input LIF → recurrent LIF → LI readout.

This is the network simulated by the accelerator: up to 256 input and
recurrent LIF neurons and 16 LI output neurons (Frenkel & Indiveri,
ISSCC'22).  The class packages parameter initialisation and the neuron /
e-prop configs into one object the controller (:mod:`repro.core.controller`)
and the optimizer (:mod:`repro.optim.eprop_opt`) consume.

Hardware limits of the chip are enforced (``MAX_IN/MAX_HID/MAX_OUT``) unless
``strict_chip_limits=False`` — the FPGA port in the paper keeps them, so the
default is faithful.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.eprop import EpropConfig
from repro.core.neuron import NeuronConfig
from repro.core.quant import QuantizedMode

MAX_IN = 256
MAX_HID = 256
MAX_OUT = 16


@dataclasses.dataclass(frozen=True)
class RSNNConfig:
    """Full model configuration (the "SPI parameter bank" of the system)."""

    n_in: int = 40
    n_hid: int = 100
    n_out: int = 2
    num_ticks: int = 150            # ticks per sample (12-bit on chip, <=4096)
    neuron: NeuronConfig = dataclasses.field(default_factory=NeuronConfig)
    eprop: EpropConfig = dataclasses.field(default_factory=EpropConfig)
    w_in_gain: float = 1.0
    w_rec_gain: float = 1.0
    w_out_gain: float = 1.0
    label_delay: int = 0            # SPI reg: delayed-supervision offset
    strict_chip_limits: bool = True
    dtype: str = "float32"

    def __post_init__(self):
        if self.strict_chip_limits:
            for got, cap, what in (
                (self.n_in, MAX_IN, "input"),
                (self.n_hid, MAX_HID, "hidden"),
                (self.n_out, MAX_OUT, "output"),
            ):
                if got > cap:
                    raise ValueError(
                        f"{got} {what} neurons > chip max {cap}"
                    )
        if self.num_ticks > 4096:
            raise ValueError("tick counter is 12-bit on the AER bus")


def init_params(key: jax.Array, cfg: RSNNConfig) -> Dict[str, jax.Array]:
    """Initialise the weight SRAM contents.

    Gaussian fan-in scaling (Bellec et al. 2020's initialisation for e-prop
    RSNNs); ``alpha`` is stored as a scalar parameter, mirroring the single
    "alphas LSBs" SPI register the paper programs.
    """
    dt = jnp.dtype(cfg.dtype)
    k_in, k_rec, k_out, k_fb = jax.random.split(key, 4)
    params = {
        "w_in": cfg.w_in_gain
        * jax.random.normal(k_in, (cfg.n_in, cfg.n_hid), dt)
        / jnp.sqrt(jnp.asarray(cfg.n_in, dt)),
        "w_rec": cfg.w_rec_gain
        * jax.random.normal(k_rec, (cfg.n_hid, cfg.n_hid), dt)
        / jnp.sqrt(jnp.asarray(cfg.n_hid, dt)),
        "w_out": cfg.w_out_gain
        * jax.random.normal(k_out, (cfg.n_hid, cfg.n_out), dt)
        / jnp.sqrt(jnp.asarray(cfg.n_hid, dt)),
        "alpha": jnp.asarray(cfg.neuron.alpha, dt),
    }
    if cfg.eprop.feedback == "random":
        params["b_fb"] = jax.random.normal(k_fb, (cfg.n_hid, cfg.n_out), dt) / jnp.sqrt(
            jnp.asarray(cfg.n_hid, dt)
        )
    return params


def trainable(params: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """The subset of params e-prop updates (weights; not alpha / feedback)."""
    return {k: params[k] for k in ("w_in", "w_rec", "w_out")}


def merge_trainable(
    params: Dict[str, jax.Array], weights: Dict[str, jax.Array]
) -> Dict[str, jax.Array]:
    out = dict(params)
    out.update(weights)
    return out


def param_count(cfg: RSNNConfig) -> int:
    return cfg.n_in * cfg.n_hid + cfg.n_hid * cfg.n_hid + cfg.n_hid * cfg.n_out


def sram_bytes(cfg: RSNNConfig, weight_bits: int = 8) -> int:
    """Weight-SRAM footprint in bytes — the TPU analog of the BRAM columns in
    the paper's Tables 1/2 (used by ``benchmarks/bench_resources.py``)."""
    return param_count(cfg) * weight_bits // 8


@dataclasses.dataclass(frozen=True)
class Presets:
    """The two experimental networks of the paper."""

    @staticmethod
    def cue_accumulation(
        num_ticks: int = 150, quantized: bool = False, **over
    ) -> RSNNConfig:
        """§4.2: 40 input, 100 recurrent, 2 output; reset-by-subtraction.

        Tuned registers (grid-searched to the paper's accuracy band —
        avg val ≈96%, avg train ≈92% over 10 epochs on 50/50 splits):
        alpha=0xFE/256, kappa=0xC8/256, lr=1e-2, w_in gain 3.

        ``quantized=True`` arms the hardware-equivalence mode with the same
        register values on ReckOn's fixed-point datapath — threshold
        ``0x03F0``, alpha LSBs ``0x0FE`` (254/256), kappa ``0xC8``
        (200/256) — under reset-by-subtraction (the datapath subtracts the
        threshold word on spike instead of clearing the membrane).
        """
        kw = dict(
            n_in=40,
            n_hid=100,
            n_out=2,
            num_ticks=num_ticks,
            neuron=NeuronConfig(
                alpha=254.0 / 256.0,
                kappa=200.0 / 256.0,
                reset="sub",
                quant=QuantizedMode(
                    threshold=0x03F0, alpha_reg=0x0FE, kappa_reg=0xC8
                ) if quantized else None,
            ),
            eprop=EpropConfig(mode="factored", error="softmax", infer_window="valid"),
            w_in_gain=3.0,
        )
        kw.update(over)
        return RSNNConfig(**kw)

    @staticmethod
    def braille(
        n_classes: int = 3, num_ticks: int = 256, quantized: bool = False, **over
    ) -> RSNNConfig:
        """§4.3: 12 input, 38 recurrent (reset-to-zero), N-class readout.

        Hyperparameters from the paper: threshold ``0x03F0``, alpha LSBs
        ``0x0FE`` (254/256), kappa ``0x37`` (55/256).

        ``quantized=True`` arms the hardware-equivalence mode: the same SPI
        register values drive ReckOn's fixed-point datapath
        (:class:`repro.core.quant.QuantizedMode` — 8-bit weight SRAM,
        saturating 12-bit membrane grid, ``reg/256`` leaks), which every
        :class:`~repro.core.backend.ExecutionBackend` built from this config
        picks up automatically.
        """
        kw = dict(
            n_in=12,
            n_hid=38,
            n_out=n_classes,
            num_ticks=num_ticks,
            neuron=NeuronConfig(
                alpha=254.0 / 256.0,
                kappa=55.0 / 256.0,
                reset="zero",
                quant=QuantizedMode(
                    threshold=0x03F0, alpha_reg=0x0FE, kappa_reg=0x37
                ) if quantized else None,
            ),
            eprop=EpropConfig(mode="factored", error="softmax", infer_window="valid"),
        )
        kw.update(over)
        return RSNNConfig(**kw)
