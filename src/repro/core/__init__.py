"""The paper's primary contribution: the ReckOn RSNN datapath (LIF/LI
neurons + e-prop online learning), the AER event codec, the fixed-point
weight-SRAM numerics, and the AER-decoder controller that drives both of
the paper's SoC modes."""
