"""Backend-dispatched execution engine shared by training, eval and serving.

Before this layer existed, backend choice lived in two places: the serving
engine (:mod:`repro.serve.engine`) hard-coded its own kernel-vs-scan
``_forward`` dispatch, and training always took the pure-JAX scan in
:mod:`repro.core.eprop`.  :class:`ExecutionBackend` absorbs both: one object
owns the jit caches for every rectangular ``(T, B)`` tile the system runs —
an inference tile served to clients, an eval tile of the validation split, or
a training tile whose summed e-prop update commits at the END_B boundary —
so a learner and a serving engine can share compiled programs and live
weights (see ``BatchedEngine.from_learner``).

Operations (all take the weight pytree as an *argument*, never a closure
constant, so swapping in newly-trained weights hits the same compiled
program):

* :meth:`ExecutionBackend.inference`       — classify a padded/masked tile;
* :meth:`ExecutionBackend.forward_traces`  — forward pass emitting the
  O(T·H) per-tick quantities (h, xbar, pbar, zbar, err, …) the factored
  e-prop update consumes;
* :meth:`ExecutionBackend.eprop_update`    — reverse-filter + matmuls turning
  those traces into the batch-summed ``dw`` pytree;
* :meth:`ExecutionBackend.train_tile`      — fused forward + update for one
  training tile (what the END_B batch-commit controller mode calls);
* :meth:`ExecutionBackend.step_sessions`   — session-stateful streaming
  inference: the carry pytree ``(v, z, y, acc_y, n_spk)`` is an argument and
  a result, so one ``(T, B)`` tick-tile advances B resident sessions exactly
  where they left off (the :class:`repro.serve.session.SessionPool` hot
  path).

Runtime knobs (backend name, alpha override, quantized mode, VMEM budget,
mesh/rules) are collected in one :class:`RuntimeConfig`; every constructor
that builds or shares a backend (:class:`ExecutionBackend`,
``OnlineLearner``, ``BatchedEngine``) accepts ``runtime=`` and resolution
happens in exactly one place, :func:`as_backend`.  The individual kwargs
remain as a deprecated passthrough.

Backends:

* ``"kernel"`` — op-specialized Pallas kernels, whole network state
  VMEM-resident, two MXU matmuls per tick; compiled on TPU, interpreted
  elsewhere (which is how the parity tests run it on CPU).  Dispatch is
  per *op*, not forward-everything:

  - ``train_tile`` → :func:`repro.kernels.ops.rsnn_train`, the fused
    forward + in-kernel error + reverse e-prop kernel.  Batch-tiled
    (``grid=(ceil(B/Bt), 2T)``, tile rows from the VMEM bytes helpers):
    per-tile traces live in VMEM scratch, ``dw`` accumulates across tiles
    in the out refs, and only ``dw`` + ``(B, O)`` metrics reach HBM — any
    batch size is admitted, there is no two-kernel fallback.
  - ``inference`` → :func:`repro.kernels.ops.rsnn_infer`: batch-tiled the
    same way, VMEM-accumulated logits/spike counts, zero per-tick HBM
    streams (the serving path).
  - ``forward_traces`` / ``eprop_update`` / ``dynamics`` → the
    trace-streaming ``rsnn_forward`` (+ split ``eprop_update``), for callers
    that need the per-tick tensors themselves.
* ``"scan"``   — the reference ``lax.scan`` implementations in
  :mod:`repro.core.eprop`.  The CPU-native fast path and the oracle the
  kernel backend is tested against.  ``train_tile`` honours
  ``cfg.eprop.mode`` (``"exact"`` per-synapse traces or ``"factored"``);
  ``forward_traces``/``eprop_update`` are factored-only by construction.

``backend="auto"`` resolves to ``"kernel"`` on TPU and ``"scan"`` elsewhere.

Data parallelism: construct with ``mesh=`` (e.g.
:func:`repro.launch.mesh.make_data_mesh`) and the ``inference`` /
``train_tile`` hot paths shard their sample axis over the mesh's data axes
via ``shard_map`` — weights replicated, ``dw`` ``psum``-med, per-sample
outputs gathered — so END_B training and batched serving scale with device
count while committing exactly what a single device would.

Hardware-equivalence mode: pass ``quant=QuantizedMode(...)`` (or set it on
``cfg.neuron.quant``) and every tile executes ReckOn's fixed-point datapath —
weights snapped to their 8-bit SRAM codes, membrane integrate / leak /
threshold / reset on the saturating 12-bit grid, leak registers as
``reg/256`` multipliers.  Both backends then reproduce the integer golden
reference (:mod:`repro.core.quant_ref`) tick-for-tick; the e-prop *traces*
stay float (the chip's trace SRAM is wider than the commit grid) and the
learning signal is evaluated on ``y / threshold`` so lr/clip settings carry
over from the float model.  Readout accumulators (``acc_y``, serving
logits) are then in membrane-grid units — argmax is unaffected.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import eprop
from repro.core.quant import QuantizedMode, QuantSpec
from repro.core.rsnn import RSNNConfig
from repro.distributed import sharding as shardlib
from repro.kernels import events, ops
from repro.kernels.rsnn_step import (
    DEFAULT_VMEM_BUDGET,
    _pad_batch_axis,
    cdiv,
    max_forward_tile,
    max_fused_train_tile,
)

# A traces pytree: the per-tick quantities of one forward pass, all (T, B, ·).
Traces = Dict[str, jax.Array]


def resolve_backend(backend: str) -> str:
    """``"auto"`` → ``"kernel"`` on TPU, ``"scan"`` elsewhere."""
    if backend == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "scan"
    if backend not in ("kernel", "scan"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Execution-runtime knobs, resolved in exactly one place
    (:func:`as_backend`) and carried as one value.

    ``ExecutionBackend``, ``OnlineLearner`` and ``BatchedEngine`` all accept
    ``runtime=RuntimeConfig(...)`` instead of (or alongside) the historical
    ``backend=``/``alpha=``/``quant=``/``vmem_budget=``/``mesh=`` kwargs; the
    loose kwargs remain as a deprecated passthrough that fills fields the
    config leaves unset.  ``None`` (and ``"auto"`` for :attr:`backend`) means
    "unset": defaults come from the :class:`~repro.core.rsnn.RSNNConfig`
    (``alpha``, ``quant``) or the module constants (``vmem_budget``).

    A constructed :class:`ExecutionBackend` exposes its fully-resolved knobs
    as ``backend.runtime`` — that is what sharing paths
    (``BatchedEngine.from_learner``) consume and what
    :meth:`ExecutionBackend.check_compatible` validates callers against.
    """

    backend: str = "auto"
    alpha: Optional[float] = None
    quant: Optional[QuantizedMode] = None
    vmem_budget: Optional[int] = None
    mesh: object = None
    rules: Optional[shardlib.ShardingRules] = None
    # Event-driven dispatch: "dense" | "event" force a path, "auto" / None
    # picks from the measured per-channel event density (event iff
    # density <= events.SPARSE_DENSITY_THRESHOLD) — see
    # repro.kernels.events.resolve_sparsity, the single policy point.
    sparsity: Optional[str] = None
    event_density: Optional[float] = None
    # Deterministic END_B accumulation: snap each *per-sample* dw onto this
    # fixed-point grid before the batch reduction, making the committed dw
    # bitwise invariant to how the sample axis is partitioned (1 vs N mesh
    # devices, any batch tiling) — the property the elastic-resize drill
    # gates on.  None (default) keeps the float reduction: bitwise on a
    # fixed mesh, float-tolerance across mesh sizes.  Costs one B=1 pass
    # per sample (lax.map), so reserve it for runs that need cross-mesh
    # bit-reproducibility.  See repro.core.quant.DW_COMMIT_SPEC.
    commit_grid: Optional[QuantSpec] = None
    # Which registered model this runtime request acts on behalf of —
    # identity metadata for routing/attribution (error messages, per-model
    # serving stats), NEVER part of the execution bucket: two models with
    # equal configs share one compiled backend (see BackendPool), so
    # check_compatible and the pool's bucket key both ignore it.
    model_id: Optional[str] = None


def _resolve_runtime(
    runtime: Optional[RuntimeConfig],
    backend: str,
    alpha: Optional[float],
    quant: Optional[QuantizedMode],
    vmem_budget: Optional[int],
    mesh,
    rules: Optional[shardlib.ShardingRules],
    sparsity: Optional[str] = None,
    event_density: Optional[float] = None,
    model_id: Optional[str] = None,
) -> RuntimeConfig:
    """Merge an explicit :class:`RuntimeConfig` with the deprecated loose
    kwargs: the config wins wherever it sets a field; loose kwargs only fill
    fields it left unset."""
    if runtime is None:
        return RuntimeConfig(backend=backend, alpha=alpha, quant=quant,
                             vmem_budget=vmem_budget, mesh=mesh, rules=rules,
                             sparsity=sparsity, event_density=event_density,
                             model_id=model_id)
    rt = runtime
    if rt.backend == "auto" and backend != "auto":
        rt = dataclasses.replace(rt, backend=backend)
    for name, val in (("alpha", alpha), ("quant", quant),
                      ("vmem_budget", vmem_budget), ("mesh", mesh),
                      ("rules", rules), ("sparsity", sparsity),
                      ("event_density", event_density),
                      ("model_id", model_id)):
        if getattr(rt, name) is None and val is not None:
            rt = dataclasses.replace(rt, **{name: val})
    return rt


class ExecutionBackend:
    """One jit-cache-owning execution object for a single :class:`RSNNConfig`.

    Parameters
    ----------
    cfg:
        The network all tiles run against.
    backend:
        ``"kernel" | "scan" | "auto"`` (see module docstring).
    alpha:
        Scalar membrane decay baked into the compiled programs (the single
        "alphas LSBs" SPI register).  Defaults to ``cfg.neuron.alpha``; the
        factored e-prop maths requires it scalar either way.
    quant:
        Hardware-equivalence mode: a :class:`~repro.core.quant.QuantizedMode`
        describing the chip's fixed-point grids/registers.  Defaults to
        ``cfg.neuron.quant``; passing it here overlays a float config
        without rebuilding it.  When active, ``alpha`` is pinned to the
        register value ``alpha_reg/256``.
    vmem_budget:
        VMEM bytes the batch-tiled kernel grids size their per-tile rows
        against (see the bytes helpers in :mod:`repro.kernels.rsnn_step`).
    mesh / rules:
        Data-parallel execution: pass a :class:`jax.sharding.Mesh` and the
        sample axis of every ``inference`` / ``train_tile`` launch is
        sharded over the mesh axes the sharding rules resolve for the
        logical ``"batch"`` axis (:mod:`repro.distributed.sharding` —
        ``("pod", "data")`` under the base rules; axes absent from the mesh
        are dropped).  Weights stay replicated; ``train_tile`` ``psum``-s
        the three ``dw`` matrices so an END_B commit is identical to the
        single-device commit, and per-sample outputs (``acc_y``, ``pred``)
        come back globally assembled.  Batches that don't divide the device
        count are zero-padded internally (inert rows).  ``rules`` defaults
        to :data:`repro.distributed.sharding.BASE_RULES`.
    runtime:
        A :class:`RuntimeConfig` bundling all of the above; fields it sets
        win over the loose kwargs (which remain as a deprecated
        passthrough).  The resolved knobs are re-exposed as
        ``self.runtime``.
    sparsity / event_density:
        Event-driven dispatch: ``sparsity`` forces ``"dense"``/``"event"``
        or (``"auto"``/``None``) decides from the *measured* per-channel
        ``event_density`` (event iff at most
        :data:`repro.kernels.events.SPARSE_DENSITY_THRESHOLD`).  The event
        path routes the kernel backend to the DMA double-buffered streaming
        kernels and the scan backend to the row-compacted sparse input
        projection — both bitwise-identical to the dense path, so this only
        changes speed, never results.
    """

    def __init__(
        self,
        cfg: RSNNConfig,
        backend: str = "auto",
        alpha: Optional[float] = None,
        quant: Optional[QuantizedMode] = None,
        vmem_budget: Optional[int] = None,
        mesh=None,
        rules: Optional[shardlib.ShardingRules] = None,
        runtime: Optional[RuntimeConfig] = None,
        sparsity: Optional[str] = None,
        event_density: Optional[float] = None,
    ):
        rt = _resolve_runtime(runtime, backend, alpha, quant, vmem_budget,
                              mesh, rules, sparsity, event_density)
        backend, alpha, quant = rt.backend, rt.alpha, rt.quant
        vmem_budget, mesh, rules = rt.vmem_budget, rt.mesh, rt.rules
        self.cfg = cfg
        self.backend = resolve_backend(backend)
        if self.backend == "kernel":
            # The Pallas kernels implement the factored reformulation only;
            # exact mode (per-synapse trace SRAM, bit-faithful) must run the
            # reference scan — fail loudly rather than silently diverge.
            if cfg.eprop.mode != "factored":
                raise ValueError(
                    "kernel backend is factored-only; use backend='scan' "
                    f"for eprop mode={cfg.eprop.mode!r}"
                )
        self.quant = quant if quant is not None else cfg.neuron.quant
        # the neuron config every scan/kernel tile actually runs against
        self._ncfg = (
            cfg.neuron
            if self.quant == cfg.neuron.quant
            else dataclasses.replace(cfg.neuron, quant=self.quant)
        )
        self.alpha = float(cfg.neuron.alpha if alpha is None else alpha)
        if self.quant is not None:
            if alpha is not None and abs(float(alpha) - self.quant.alpha) >= 1e-9:
                raise ValueError(
                    "quantized mode: alpha is driven by alpha_reg "
                    f"({self.quant.alpha}), caller passed {alpha}"
                )
            self.alpha = self.quant.alpha
        # VMEM budget the batch-tiled kernel grids size their tile rows
        # against (max_forward_tile / max_fused_train_tile) — a trace-time
        # static decision; one jit cache entry per launch shape either way.
        self.vmem_budget = int(vmem_budget or DEFAULT_VMEM_BUDGET)
        # Event-driven dispatch, resolved once from the measured density:
        # "event" routes the kernel backend onto the DMA-streaming variants
        # (stream="dma": double-buffered HBM fetch, quiet blocks skipped)
        # and the scan backend onto the row-compacted sparse input
        # projection.  Both are bitwise-identical to the dense path, so this
        # knob only ever changes speed — never results.
        self.event_density = (
            None if rt.event_density is None else float(rt.event_density)
        )
        self.sparsity = events.resolve_sparsity(rt.sparsity, self.event_density)
        self._stream = "dma" if self.sparsity == "event" else "blocked"
        # Data-parallel mesh: resolve the logical "batch" axis to mesh axes
        # via the sharding rules (the same table the production models use).
        self.mesh = mesh
        self.rules = rules or shardlib.ShardingRules(shardlib.BASE_RULES)
        self._batch_axes: Optional[Tuple[str, ...]] = None
        if mesh is not None:
            axes = self.rules.resolve("batch", mesh)
            if isinstance(axes, str):
                axes = (axes,)
            if axes and shardlib.axis_size(mesh, axes) > 1:
                self._batch_axes = tuple(axes)
        self.num_devices = (
            shardlib.axis_size(mesh, self._batch_axes)
            if self._batch_axes
            else 1
        )
        self.commit_grid = rt.commit_grid
        # canonical, fully-resolved runtime description — what sharing paths
        # (BatchedEngine.from_learner) pass around and check_compatible
        # validates callers against
        self.runtime = RuntimeConfig(
            backend=self.backend, alpha=self.alpha, quant=self.quant,
            vmem_budget=self.vmem_budget, mesh=self.mesh, rules=self.rules,
            sparsity=self.sparsity, event_density=self.event_density,
            commit_grid=self.commit_grid,
        )
        if cfg.eprop.mask_self_recurrence:
            self._mask = 1.0 - jnp.eye(cfg.n_hid, dtype=jnp.float32)
        else:
            self._mask = jnp.ones((cfg.n_hid, cfg.n_hid), jnp.float32)
        self._shapes: Dict[str, set] = {}
        sharded = self._batch_axes is not None
        self._jit_inference = jax.jit(
            self._inference_sharded if sharded else self._inference_impl
        )
        self._jit_forward = jax.jit(self._forward_impl)
        self._jit_update = jax.jit(self._update_impl)
        if self.commit_grid is not None:
            self._jit_train = jax.jit(
                self._train_det_sharded if sharded else self._train_det_impl
            )
        else:
            self._jit_train = jax.jit(
                self._train_sharded if sharded else self._train_impl
            )
        self._jit_dynamics = jax.jit(self._dynamics_impl)
        self._jit_step_sessions = jax.jit(
            self._step_sessions_sharded if sharded else self._step_sessions_impl
        )

    # -------------------------------------------------------- compatibility

    def check_compatible(self, rt: RuntimeConfig) -> None:
        """Assert a caller's requested runtime knobs match this (shared)
        backend.  ``None`` / ``"auto"`` fields mean "don't care" — the
        caller inherits whatever this backend resolved.  This is the single
        sharing-path validator (:func:`as_backend` calls it when handed an
        existing instance)."""
        def need(ok: bool, msg: str) -> None:
            if not ok:
                raise ValueError(msg)

        if rt.backend != "auto":
            need(
                resolve_backend(rt.backend) == self.backend,
                f"shared backend runs {self.backend!r}, caller asked for "
                f"{rt.backend!r}",
            )
        need(
            rt.alpha is None or self.alpha == float(rt.alpha) or (
                self.quant is not None
                and abs(self.quant.alpha - float(rt.alpha)) < 1e-9
            ),
            "shared backend baked a different alpha than the caller's params",
        )
        need(
            rt.quant is None or self.quant == rt.quant,
            "shared backend runs a different quantized mode than the caller's",
        )
        need(
            rt.mesh is None or self.mesh == rt.mesh,
            "shared backend was built over a different mesh than the caller's",
        )
        need(
            rt.vmem_budget is None or self.vmem_budget == int(rt.vmem_budget),
            "shared backend tiles against a different vmem_budget "
            f"({self.vmem_budget}) than the caller's ({rt.vmem_budget})",
        )
        # "auto"/None inherit whatever this backend resolved; only a forced
        # path can conflict.
        need(
            rt.sparsity in (None, "auto") or rt.sparsity == self.sparsity,
            f"shared backend resolved sparsity={self.sparsity!r}, caller "
            f"forced {rt.sparsity!r}",
        )
        need(
            rt.event_density is None
            or self.event_density == float(rt.event_density),
            "shared backend was built for a different measured event density "
            f"({self.event_density}) than the caller's ({rt.event_density})",
        )
        need(
            rt.commit_grid is None or self.commit_grid == rt.commit_grid,
            "shared backend accumulates END_B on a different commit grid "
            f"({self.commit_grid}) than the caller's ({rt.commit_grid})",
        )

    def resize(self, mesh) -> "ExecutionBackend":
        """Rebuild this backend over a different (possibly ``None``) data
        mesh, everything else identical — the elastic-restore primitive: a
        checkpoint saved on an 8-device mesh restores onto the survivors'
        mesh by resizing the backend and re-placing host arrays
        (:func:`repro.distributed.elastic.reshard`).  With a ``commit_grid``
        set, END_B commits on the resized backend are bitwise identical to
        the original's; without one they agree to float-reduction order.
        Returns ``self`` when the mesh is unchanged (keeps jit caches)."""
        if mesh is self.mesh or mesh == self.mesh:
            return self
        rt = dataclasses.replace(self.runtime, mesh=mesh)
        return ExecutionBackend(self.cfg, runtime=rt)

    # ------------------------------------------------------------- plumbing

    def _note(self, op: str, shape: Tuple[int, ...]) -> None:
        # No launch-level batch guard any more: the kernels batch-tile
        # internally (tile rows from tile_rows(), derived from the same
        # bytes helpers) — any B runs, only a *tile* must fit VMEM.
        self._shapes.setdefault(op, set()).add(tuple(shape[:2]))

    def tile_rows(self, op: str, T: Optional[int] = None) -> int:
        """Batch rows per kernel tile for ``op`` on this backend's config —
        the per-tile VMEM contract, derived from the bytes helpers in
        :mod:`repro.kernels.rsnn_step` (never re-declared here).  ``train``
        needs the launch's tick count ``T`` (trace scratch is O(T·Bt))."""
        c = self.cfg
        if op == "train":
            if T is None:
                raise ValueError("train tile rows depend on T")
            return max_fused_train_tile(
                T, c.n_in, c.n_hid, c.n_out, self.vmem_budget
            )
        return max_forward_tile(c.n_in, c.n_hid, c.n_out, self.vmem_budget)

    def compiled_shapes(self, op: Optional[str] = None) -> int:
        """Distinct ``(T, B)`` tile shapes this backend has been asked to run
        (per op, or total) — the serving stats' recompile counter."""
        if op is not None:
            return len(self._shapes.get(op, ()))
        return sum(len(s) for s in self._shapes.values())

    def _merge(self, weights: Dict[str, jax.Array], dtype) -> Dict[str, jax.Array]:
        params = dict(weights)
        params.setdefault("alpha", jnp.asarray(self.alpha, dtype))
        return params

    def _feedback(self, weights: Dict[str, jax.Array]) -> jax.Array:
        return (
            weights["b_fb"]
            if self.cfg.eprop.feedback == "random"
            else weights["w_out"]
        )

    def _datapath_weights(self, weights):
        """Weights as the kernel datapath consumes them: snapped onto the
        membrane grid in quantized mode, self-recurrence masked."""
        q = self.quant
        if q is not None:
            return (
                q.to_membrane(weights["w_in"]),
                q.to_membrane(weights["w_rec"]) * self._mask,
                q.to_membrane(weights["w_out"]),
            )
        return (
            weights["w_in"],
            weights["w_rec"] * self._mask,
            weights["w_out"],
        )

    def _scan_sparse_rows(self, T: int, B: int) -> Optional[int]:
        """Static active-row capacity for the scan backend's sparse input
        pre-projection (``None`` → dense).  Sized from the measured density
        via :func:`repro.kernels.events.suggest_row_capacity`; a forced
        ``"event"`` with no measured density degrades to full capacity
        (which :func:`~repro.kernels.events.sparse_input_projection`
        short-circuits to the dense matmul)."""
        if self.sparsity != "event":
            return None
        d = self.event_density
        if d is None:
            d = events.SPARSE_DENSITY_THRESHOLD
        return events.suggest_row_capacity(T, B, d, n_in=self.cfg.n_in)

    def _kernel_forward(self, weights, raster):
        ncfg = self._ncfg
        w_in, w_rec, w_out = self._datapath_weights(weights)
        return ops.rsnn_forward(
            raster,
            w_in,
            w_rec,
            w_out,
            alpha=self.alpha,
            kappa=ncfg.kappa,
            v_th=ncfg.v_th,
            reset=ncfg.reset,
            boxcar_width=ncfg.boxcar_width,
            quant=self.quant,
            vmem_budget=self.vmem_budget,
            stream=self._stream,
        )

    def _spike_rate(self, n_spk, valid):
        """Valid-masked spike rate — the one shared definition
        (padded ticks never count), so both backends report identically."""
        return eprop._spike_rate(n_spk, valid, self.cfg.n_hid)

    def _y_err(self, y: jax.Array) -> jax.Array:
        """Readout values as the error path sees them: normalised units in
        quantized mode (``y / threshold``), identity otherwise."""
        if self.quant is None:
            return y
        return y * (1.0 / float(self.quant.threshold))

    def _infer_weight(self, valid: jax.Array) -> jax.Array:
        if self.cfg.eprop.infer_window == "valid":
            return valid[..., None]
        return jnp.ones_like(valid)[..., None]

    # ------------------------------------------------------------ inference

    def _inference_impl(self, weights, raster, valid):
        ncfg, ecfg = self._ncfg, self.cfg.eprop
        if self.backend == "kernel":
            w_in, w_rec, w_out = self._datapath_weights(weights)
            acc_y, n_spk = ops.rsnn_infer(
                raster, valid, w_in, w_rec, w_out,
                alpha=self.alpha, kappa=ncfg.kappa, v_th=ncfg.v_th,
                reset=ncfg.reset, quant=self.quant,
                infer_window=ecfg.infer_window,
                vmem_budget=self.vmem_budget,
                stream=self._stream,
            )
            return {
                "acc_y": acc_y,
                "pred": jnp.argmax(acc_y, axis=-1),
                "spike_rate": self._spike_rate(n_spk, valid),
            }
        params = self._merge(weights, raster.dtype)
        T, B = raster.shape[:2]
        return eprop.run_sample_inference(
            params, raster, valid, ncfg, ecfg,
            sparse_rows=self._scan_sparse_rows(T, B),
        )

    def inference(
        self, weights: Dict[str, jax.Array], raster: jax.Array, valid: jax.Array
    ) -> Dict[str, jax.Array]:
        """Classify one ``(T, B)`` tile → ``{"acc_y", "pred", "spike_rate"}``.

        The kernel backend runs the inference-specialized kernel: readout
        and spike accumulators live in VMEM and only the ``(B, O)`` logits
        tile (plus per-sample spike counts) is written to HBM — no per-tick
        streams on the serving path.
        """
        self._note("inference", raster.shape)
        return self._jit_inference(weights, raster, valid)

    # ------------------------------------------------------- forward traces

    def _forward_impl(self, weights, raster, y_star, valid):
        ncfg, ecfg = self._ncfg, self.cfg.eprop
        if self.backend == "kernel":
            out = self._kernel_forward(weights, raster)
            err = eprop.readout_error(
                self._y_err(out["y"]), y_star, ecfg) * valid[..., None]
            return {
                "h": out["h"],
                "xbar": out["xbar"],
                "pbar": out["pbar"],
                "zbar": out["zbar"],
                "err": err,
                "y_inf": out["y"] * self._infer_weight(valid),
                "n_spk": (out["z"] * valid[..., None]).sum(axis=(1, 2)),
            }
        params = self._merge(weights, raster.dtype)
        T, B = raster.shape[:2]
        h, xbar, pbar, zbar, err, y_inf, n_spk = eprop.forward_traces(
            params, raster, y_star, valid, ncfg, ecfg,
            sparse_rows=self._scan_sparse_rows(T, B),
        )
        return {
            "h": h, "xbar": xbar, "pbar": pbar, "zbar": zbar,
            "err": err, "y_inf": y_inf, "n_spk": n_spk,
        }

    def forward_traces(
        self,
        weights: Dict[str, jax.Array],
        raster: jax.Array,
        y_star: jax.Array,
        valid: jax.Array,
    ) -> Traces:
        """Forward one ``(T, B)`` tile, emitting the factored-update traces."""
        self._note("forward_traces", raster.shape)
        return self._jit_forward(weights, raster, y_star, valid)

    # --------------------------------------------------------- eprop update

    def _update_impl(self, weights, traces):
        ncfg, ecfg = self._ncfg, self.cfg.eprop
        if self.backend == "kernel":
            dw_in, dw_rec, dw_out = ops.eprop_update(
                traces["h"], traces["xbar"], traces["pbar"], traces["zbar"],
                traces["err"], self._feedback(weights), kappa=ncfg.kappa,
                vmem_budget=self.vmem_budget,
            )
            return {"w_in": dw_in, "w_rec": dw_rec * self._mask, "w_out": dw_out}
        params = self._merge(weights, traces["h"].dtype)
        return eprop.factored_update(
            params, traces["h"], traces["xbar"], traces["pbar"],
            traces["zbar"], traces["err"], ncfg, ecfg,
        )

    def eprop_update(
        self, weights: Dict[str, jax.Array], traces: Traces
    ) -> Dict[str, jax.Array]:
        """Traces → batch-summed positive-gradient ``dw`` pytree."""
        self._note("eprop_update", traces["h"].shape)
        return self._jit_update(weights, traces)

    # ----------------------------------------------------------- train tile

    def _train_impl(self, weights, raster, y_star, valid):
        ncfg, ecfg = self._ncfg, self.cfg.eprop
        if self.backend == "kernel":
            # one batch-tiled two-phase kernel: per-tile traces VMEM-resident,
            # dw accumulated across tiles in the out refs, HBM sees only
            # dw + (B, O) metrics.  Any B runs — no fallback pipeline.
            w_in, w_rec, w_out = self._datapath_weights(weights)
            dw_in, dw_rec, dw_out, acc_y, n_spk = ops.rsnn_train(
                raster, y_star, valid, w_in, w_rec, w_out,
                self._feedback(weights),
                alpha=self.alpha, kappa=ncfg.kappa, v_th=ncfg.v_th,
                reset=ncfg.reset, boxcar_width=ncfg.boxcar_width,
                quant=self.quant, error=ecfg.error,
                target_amplitude=ecfg.target_amplitude,
                infer_window=ecfg.infer_window,
                vmem_budget=self.vmem_budget,
                stream=self._stream,
            )
            dw = {"w_in": dw_in, "w_rec": dw_rec * self._mask,
                  "w_out": dw_out}
            metrics = {
                "acc_y": acc_y,
                "pred": jnp.argmax(acc_y, axis=-1),
                "spike_rate": self._spike_rate(n_spk, valid),
            }
            return dw, metrics
        params = self._merge(weights, raster.dtype)
        T, B = raster.shape[:2]
        return eprop.run_sample(
            params, raster, y_star, valid, ncfg, ecfg,
            sparse_rows=self._scan_sparse_rows(T, B),
        )

    # ------------------------------------------------- data-parallel wrappers

    def _pad_to_shards(self, arrs, batch_axis):
        """Zero-pad each array's sample axis up to a multiple of the data
        axis size (padding rows carry zero input / zero valid — inert).
        Same padding contract (and helper) as the kernels' batch tiling."""
        n = self.num_devices
        B = arrs[0].shape[batch_axis[0]]
        b_pad = cdiv(B, n) * n
        return [
            _pad_batch_axis(x, ax, b_pad) for x, ax in zip(arrs, batch_axis)
        ], B

    def _psum_spike_rate(self, rate, valid):
        """Reassemble the global valid-weighted spike rate from per-shard
        rates: ``rate = Σspikes / (Σvalid · H)`` per shard, so the global
        rate is the valid-weighted mean — an unweighted ``pmean`` would skew
        toward shards that carry padding rows."""
        vs = valid.sum()
        num = jax.lax.psum(rate * jnp.maximum(vs, 1.0), self._batch_axes)
        den = jax.lax.psum(vs, self._batch_axes)
        return num / jnp.maximum(den, 1.0)

    # check_vma=False below: Pallas calls have no replication rule inside
    # shard_map on current jax, and the outputs are made collective-
    # consistent explicitly (psum / per-shard slices) anyway.

    def _train_sharded(self, weights, raster, y_star, valid):
        """:meth:`_train_impl` sharded over the mesh's data axes: each shard
        trains its slice of the sample axis, the three ``dw`` matrices are
        ``psum``-med (so the END_B commit equals the single-device commit)
        and per-sample metrics come back globally assembled."""
        ba = self._batch_axes
        (raster, y_star, valid), B = self._pad_to_shards(
            (raster, y_star, valid), (1, 0, 1)
        )

        def local(weights, raster, y_star, valid):
            dw, m = self._train_impl(weights, raster, y_star, valid)
            dw = jax.tree.map(lambda g: jax.lax.psum(g, ba), dw)
            m = dict(m, spike_rate=self._psum_spike_rate(m["spike_rate"], valid))
            return dw, m

        dw, m = shard_map(
            local,
            mesh=self.mesh,
            axis_names=set(ba),
            in_specs=(P(), P(None, ba, None), P(ba), P(None, ba)),
            out_specs=(
                {"w_in": P(), "w_rec": P(), "w_out": P()},
                {"acc_y": P(ba), "pred": P(ba), "spike_rate": P()},
            ),
            check_vma=False,
        )(weights, raster, y_star, valid)
        if m["acc_y"].shape[0] != B:
            m = dict(m, acc_y=m["acc_y"][:B], pred=m["pred"][:B])
        return dw, m

    # ----------------------------------------------- deterministic END_B path

    def _dw_to_codes(self, dw):
        """Snap a per-sample dw pytree onto the commit grid as int32 codes.

        Integer addition is associative, so summing codes is invariant to
        the order — and therefore to the partitioning — of the sample axis:
        the property that makes the elastic 8→4 restore drill bitwise.  The
        grid mirrors the chip's fixed-point dw accumulator; per-sample dw
        magnitudes sit well inside the ±2^(bits-1-frac) headroom and int32
        sums stay exact for any realistic batch."""
        g = self.commit_grid
        lo = -(2.0 ** (g.bits - 1))
        hi = 2.0 ** (g.bits - 1) - 1
        return jax.tree.map(
            lambda x: jnp.clip(jnp.round(x / g.lsb), lo, hi).astype(jnp.int32),
            dw,
        )

    def _train_det_codes(self, weights, raster, y_star, valid):
        """Per-sample train passes, dw snapped to int32 commit-grid codes.

        ``lax.map`` runs each sample as a B=1 tile through
        :meth:`_train_impl`, so the per-sample arithmetic is literally the
        single-device arithmetic — only the (associative, integer) reduction
        differs between mesh layouts.  Returns per-sample ``(codes, acc_y,
        rate, valid_sum)``."""

        def one(args):
            r, ys, v = args
            dw, m = self._train_impl(
                weights, r[:, None, :], ys[None, :], v[:, None]
            )
            codes = self._dw_to_codes(dw)
            return codes, m["acc_y"][0], m["spike_rate"], v.sum()

        return jax.lax.map(
            one,
            (jnp.swapaxes(raster, 0, 1), y_star, jnp.swapaxes(valid, 0, 1)),
        )

    def _codes_to_dw(self, codes):
        lsb = self.commit_grid.lsb
        return jax.tree.map(lambda c: c.astype(jnp.float32) * lsb, codes)

    def _train_det_impl(self, weights, raster, y_star, valid):
        """Single-device deterministic END_B: grid-snapped per-sample codes
        summed as int32, converted to float once at the end — bitwise equal
        to any sharded layout's commit of the same batch."""
        codes, acc_y, rate, vs = self._train_det_codes(
            weights, raster, y_star, valid
        )
        dw = self._codes_to_dw(
            jax.tree.map(lambda c: c.sum(axis=0), codes)
        )
        num = (rate * jnp.maximum(vs, 1.0)).sum()
        den = jnp.maximum(vs.sum(), 1.0)
        metrics = {
            "acc_y": acc_y,
            "pred": jnp.argmax(acc_y, axis=-1),
            "spike_rate": num / den,
        }
        return dw, metrics

    def _train_det_sharded(self, weights, raster, y_star, valid):
        """:meth:`_train_det_impl` over the data mesh: shards psum *int32
        codes* (order-invariant), the float conversion happens once on the
        replicated sum — so 1-, 4- and 8-shard layouts commit bit-identical
        dw.  Padding rows (zero raster → zero traces → zero dw codes, zero
        valid) are inert in both the code sum and the rate."""
        ba = self._batch_axes
        (raster, y_star, valid), B = self._pad_to_shards(
            (raster, y_star, valid), (1, 0, 1)
        )

        def local(weights, raster, y_star, valid):
            codes, acc_y, rate, vs = self._train_det_codes(
                weights, raster, y_star, valid
            )
            codes = jax.tree.map(
                lambda c: jax.lax.psum(c.sum(axis=0), ba), codes
            )
            num = jax.lax.psum((rate * jnp.maximum(vs, 1.0)).sum(), ba)
            den = jnp.maximum(jax.lax.psum(vs.sum(), ba), 1.0)
            m = {
                "acc_y": acc_y,
                "pred": jnp.argmax(acc_y, axis=-1),
                "spike_rate": num / den,
            }
            return codes, m

        codes, m = shard_map(
            local,
            mesh=self.mesh,
            axis_names=set(ba),
            in_specs=(P(), P(None, ba, None), P(ba), P(None, ba)),
            out_specs=(
                {"w_in": P(), "w_rec": P(), "w_out": P()},
                {"acc_y": P(ba), "pred": P(ba), "spike_rate": P()},
            ),
            check_vma=False,
        )(weights, raster, y_star, valid)
        dw = self._codes_to_dw(codes)
        if m["acc_y"].shape[0] != B:
            m = dict(m, acc_y=m["acc_y"][:B], pred=m["pred"][:B])
        return dw, m

    def _inference_sharded(self, weights, raster, valid):
        ba = self._batch_axes
        (raster, valid), B = self._pad_to_shards((raster, valid), (1, 1))

        def local(weights, raster, valid):
            out = self._inference_impl(weights, raster, valid)
            return dict(
                out,
                spike_rate=self._psum_spike_rate(out["spike_rate"], valid),
            )

        out = shard_map(
            local,
            mesh=self.mesh,
            axis_names=set(ba),
            in_specs=(P(), P(None, ba, None), P(None, ba)),
            out_specs={"acc_y": P(ba), "pred": P(ba), "spike_rate": P()},
            check_vma=False,
        )(weights, raster, valid)
        if out["acc_y"].shape[0] != B:
            out = dict(out, acc_y=out["acc_y"][:B], pred=out["pred"][:B])
        return out

    def train_tile(
        self,
        weights: Dict[str, jax.Array],
        raster: jax.Array,
        y_star: jax.Array,
        valid: jax.Array,
    ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
        """One fused forward + e-prop update over a ``(T, B)`` training tile.

        Returns ``(dw, metrics)`` where ``dw`` is summed over the batch axis —
        the quantity a controller commits at an END_S (B=1) or END_B (B=K)
        boundary.  The scan backend dispatches on ``cfg.eprop.mode`` (exact /
        factored); the kernel backend always runs the batch-tiled fused
        train kernel (error + reverse pass in-kernel, per-tile traces never
        leave VMEM, tile rows sized by ``tile_rows("train", T)``) — any
        batch size is admitted.  With a mesh, the sample axis is first
        sharded over the data axes and ``dw`` is ``psum``-med, so the commit
        is identical to the single-device one.
        """
        self._note("train_tile", raster.shape)
        return self._jit_train(weights, raster, y_star, valid)

    # ------------------------------------------------------------- dynamics

    def _dynamics_impl(self, weights, raster):
        if self.backend == "kernel":
            out = self._kernel_forward(weights, raster)
            return {"v": out["v"], "z": out["z"], "y": out["y"]}
        params = self._merge(weights, raster.dtype)
        T, B = raster.shape[:2]
        out = eprop.forward_dynamics(
            params, raster, self._ncfg, self.cfg.eprop,
            sparse_rows=self._scan_sparse_rows(T, B),
        )
        return {"v": out["v"], "z": out["z"], "y": out["y"]}

    def dynamics(
        self, weights: Dict[str, jax.Array], raster: jax.Array
    ) -> Dict[str, jax.Array]:
        """Full state trajectories for one ``(T, B)`` tile: post-reset
        membrane ``v`` (T, B, H), spikes ``z``, readout ``y`` (T, B, O).

        The hardware-equivalence probe: in quantized mode both backends
        reproduce the integer golden reference
        (:func:`repro.core.quant_ref.golden_forward`) exactly on these —
        asserted in ``tests/test_quant_equivalence.py``.
        """
        self._note("dynamics", raster.shape)
        return self._jit_dynamics(weights, raster)

    # -------------------------------------------------------- step sessions

    _STATE_KEYS = ("v", "z", "y", "acc_y", "n_spk")

    def init_session_state(self, n: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
        """Fresh carry rows for ``n`` sessions — the all-zeros reset state
        every ReckOn sequence starts from (zero is exactly representable on
        the quantized membrane grid, so the quantized path starts bit-true
        too)."""
        c = self.cfg
        return {
            "v": jnp.zeros((n, c.n_hid), dtype),
            "z": jnp.zeros((n, c.n_hid), dtype),
            "y": jnp.zeros((n, c.n_out), dtype),
            "acc_y": jnp.zeros((n, c.n_out), dtype),
            "n_spk": jnp.zeros((n, 1), dtype),
        }

    def _step_sessions_impl(self, weights, raster, live, valid, state):
        ncfg, ecfg = self._ncfg, self.cfg.eprop
        if self.backend == "kernel":
            w_in, w_rec, w_out = self._datapath_weights(weights)
            v, z, y, acc_y, n_spk = ops.rsnn_step_sessions(
                raster, live, valid,
                state["v"], state["z"], state["y"],
                state["acc_y"], state["n_spk"],
                w_in, w_rec, w_out,
                alpha=self.alpha, kappa=ncfg.kappa, v_th=ncfg.v_th,
                reset=ncfg.reset, quant=self.quant,
                infer_window=ecfg.infer_window,
                vmem_budget=self.vmem_budget,
                stream=self._stream,
            )
            return {"v": v, "z": z, "y": y, "acc_y": acc_y, "n_spk": n_spk}
        params = self._merge(weights, raster.dtype)
        T, B = raster.shape[:2]
        return eprop.run_stream_inference(
            params, raster, live, valid, state, ncfg, ecfg,
            sparse_rows=self._scan_sparse_rows(T, B),
        )

    def _step_sessions_sharded(self, weights, raster, live, valid, state):
        """:meth:`_step_sessions_impl` sharded over the mesh's data axes —
        each shard advances its slice of the session rows; no collectives
        are needed because every output is per-session."""
        ba = self._batch_axes
        keys = self._STATE_KEYS
        padded, B = self._pad_to_shards(
            (raster, live, valid, *(state[k] for k in keys)),
            (1, 1, 1, 0, 0, 0, 0, 0),
        )
        raster, live, valid = padded[:3]
        state = dict(zip(keys, padded[3:]))

        out = shard_map(
            self._step_sessions_impl,
            mesh=self.mesh,
            axis_names=set(ba),
            in_specs=(P(), P(None, ba, None), P(None, ba), P(None, ba),
                      {k: P(ba) for k in keys}),
            out_specs={k: P(ba) for k in keys},
            check_vma=False,
        )(weights, raster, live, valid, state)
        if out["v"].shape[0] != B:
            out = {k: a[:B] for k, a in out.items()}
        return out

    def step_sessions(
        self,
        weights: Dict[str, jax.Array],
        raster: jax.Array,
        live: jax.Array,
        valid: jax.Array,
        state: Dict[str, jax.Array],
    ) -> Dict[str, jax.Array]:
        """Advance ``B`` resident sessions through one ``(T, B)`` tick-tile.

        The streaming-serving hot path: ``state`` is the carry pytree
        ``{"v", "z", "y", "acc_y", "n_spk"}`` gathered from the session pool
        (each ``(B, ·)``), and the returned pytree (same keys/shapes) is
        scattered back — carry in / carry out, so chunking a stream into
        tiles is invariant (bit-true in quantized mode).

        ``live`` gates the *dynamics*: a tick with ``live == 0`` leaves that
        session's carry untouched exactly (select, not decay), which is how
        ragged per-session chunk lengths pack into one rectangular tile.
        ``valid`` (⊆ live) gates readout accumulation only, mirroring the
        TARGET_VALID window of the whole-sample path.  Kernel backend runs
        the batch-tiled session kernel; scan backend the reference
        ``lax.scan``; with a mesh, session rows shard over the data axes
        (pure per-session outputs — no collectives).
        """
        self._note("step_sessions", raster.shape)
        return self._jit_step_sessions(weights, raster, live, valid, state)


BackendLike = Union[str, ExecutionBackend]


def bucket_key(cfg: RSNNConfig, rt: RuntimeConfig) -> Tuple:
    """The execution-equality bucket of a ``(cfg, runtime)`` request: two
    requests with equal keys can share one :class:`ExecutionBackend` (and
    therefore its jit caches) without any behavioural difference.

    The key pre-resolves every field exactly as the constructor would
    (``"auto"`` backend, defaulted alpha/quant/vmem, measured-density
    sparsity dispatch), so ``braille`` requested with ``backend="auto"`` on
    CPU and ``backend="scan"`` land in the same bucket.  The full
    :class:`~repro.core.rsnn.RSNNConfig` participates — that is the
    ``(T, N, H, O, quant)`` shape bucket plus every baked-in trace-time
    constant (leaks, reset mode, e-prop window …), which is precisely the
    set of things a traced program closes over.  ``rt.model_id`` is
    deliberately EXCLUDED: which model a request serves never changes the
    compiled program.
    """
    name = resolve_backend(rt.backend)
    quant = rt.quant if rt.quant is not None else cfg.neuron.quant
    if quant is not None:
        alpha = quant.alpha
    else:
        alpha = float(cfg.neuron.alpha if rt.alpha is None else rt.alpha)
    sparsity = events.resolve_sparsity(rt.sparsity, rt.event_density)
    return (
        cfg, name, alpha, quant, int(rt.vmem_budget or DEFAULT_VMEM_BUDGET),
        rt.mesh, None if rt.rules is None else id(rt.rules),
        sparsity, rt.event_density, rt.commit_grid,
    )


class BackendPool:
    """One shared jit cache over shape-bucketed configs.

    Where each engine/learner historically constructed its own
    :class:`ExecutionBackend` (its own jit caches), a pool hands out **one
    backend per execution bucket** (:func:`bucket_key`): registering a
    second model with an equal config compiles nothing, and models whose
    configs differ only in weights trivially share every program — the
    software analog of the paper's runtime reprogrammability, where one
    fabric serves many weight-SRAM images.

    :class:`repro.serve.registry.ModelRegistry` owns one of these; pass
    ``pool=`` to :func:`as_backend` to resolve through it.
    """

    def __init__(self):
        self._by_key: Dict[Tuple, ExecutionBackend] = {}

    def __len__(self) -> int:
        return len(self._by_key)

    def backends(self) -> Tuple[ExecutionBackend, ...]:
        """The distinct pooled backends (one per execution bucket)."""
        return tuple(self._by_key.values())

    def get(self, cfg: RSNNConfig, rt: RuntimeConfig) -> ExecutionBackend:
        """The pooled backend for this bucket — constructed on first request,
        returned as-is (zero new compiled programs) afterwards."""
        key = bucket_key(cfg, rt)
        hit = self._by_key.get(key)
        if hit is not None:
            hit.check_compatible(rt)
            return hit
        be = ExecutionBackend(cfg, runtime=dataclasses.replace(
            rt, model_id=None
        ))
        self._by_key[key] = be
        return be

    def adopt(self, backend: ExecutionBackend) -> ExecutionBackend:
        """Seed the pool with an externally constructed backend (e.g. an
        :class:`~repro.core.controller.OnlineLearner`'s, so registering its
        model shares the learner's live jit cache).  If the bucket is
        already occupied the pooled instance wins — one backend per bucket —
        and the caller should use the returned object."""
        key = bucket_key(backend.cfg, backend.runtime)
        return self._by_key.setdefault(key, backend)

    def discard(self, backend: ExecutionBackend) -> bool:
        """Drop a pooled backend so the next :meth:`get` for its bucket
        constructs a fresh instance (fresh jit caches).  The lane-restart
        primitive: after a device/launch fault, the poisoned backend's
        compiled state is abandoned rather than trusted.  Returns whether
        the backend was actually pooled."""
        key = bucket_key(backend.cfg, backend.runtime)
        if self._by_key.get(key) is backend:
            del self._by_key[key]
            return True
        return False

    def compiled_shapes(self, op: Optional[str] = None) -> int:
        """Distinct ``(T, B)`` tile shapes across every pooled backend —
        the multi-model recompile counter (hot-swapping / registering into
        an existing bucket must not move it)."""
        return sum(be.compiled_shapes(op) for be in self._by_key.values())


def as_backend(
    cfg: RSNNConfig,
    backend: BackendLike = "auto",
    alpha: Optional[float] = None,
    quant: Optional[QuantizedMode] = None,
    vmem_budget: Optional[int] = None,
    mesh=None,
    runtime: Optional[RuntimeConfig] = None,
    sparsity: Optional[str] = None,
    event_density: Optional[float] = None,
    model_id: Optional[str] = None,
    pool: Optional[BackendPool] = None,
) -> ExecutionBackend:
    """The single runtime-resolution point: coerce a backend name, a
    :class:`RuntimeConfig`, or an existing :class:`ExecutionBackend` into a
    constructed backend.

    Passing an existing instance is how a serving engine shares one jit
    cache (and therefore live weights without recompilation) with the
    learner that trains through it — the instance is validated against the
    caller's requested knobs via
    :meth:`ExecutionBackend.check_compatible` and returned as-is.  The
    loose ``alpha``/``quant``/``vmem_budget``/``mesh`` kwargs are the
    deprecated passthrough; new callers bundle them in ``runtime=``.

    ``model_id`` tags the request with the registered model it acts for
    (identity only — never part of the execution bucket).  ``pool=`` routes
    construction through a :class:`BackendPool`, so equal-bucket requests
    from different models share one backend instead of compiling their own.
    """
    if isinstance(backend, RuntimeConfig):
        if runtime is not None:
            raise ValueError("runtime passed twice")
        backend, runtime = backend.backend, backend
    name = backend if isinstance(backend, str) else "auto"
    rt = _resolve_runtime(runtime, name, alpha, quant, vmem_budget, mesh, None,
                          sparsity, event_density, model_id)
    if isinstance(backend, ExecutionBackend):
        if backend.cfg != cfg:
            raise ValueError(
                "shared backend built for a different config"
                + (f" (model {rt.model_id!r})" if rt.model_id else "")
            )
        backend.check_compatible(rt)
        return pool.adopt(backend) if pool is not None else backend
    if pool is not None:
        return pool.get(cfg, rt)
    return ExecutionBackend(cfg, runtime=rt)
