"""Fixed-point numerics matching ReckOn's on-chip representation.

ReckOn stores synaptic weights in 8-bit SRAM words and membrane potentials /
thresholds on a wider integer grid (12-bit in the taped-out chip).  Leakage
factors (alpha for the hidden LIF layer, kappa for the LI readout) are 8-bit
fractional multipliers, i.e. ``decay = reg / 256``.

The paper configures the Braille experiments through the (expanded) SPI
parameter bank with::

    threshold = 0x03F0   # membrane-grid integer
    alpha     = 0x0FE    # "alphas LSBs"  -> 254/256
    kappa     = 0x37     # 55/256

This module provides

* :class:`QuantSpec` — a signed fixed-point grid ``Q(bits, frac)``;
* deterministic and stochastic rounding onto a grid;
* straight-through quantization for use inside differentiable code;
* :func:`from_reckon_regs` — the register-file interpretation above;
* :class:`QuantState` — accumulate-then-round weight storage (the shadow
  accumulator pattern the chip uses for e-prop updates smaller than 1 LSB).

Everything is pure JAX and shape-polymorphic.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Signed fixed-point grid with ``bits`` total bits and ``frac`` fractional bits.

    Representable values: ``k * 2**-frac`` for integer
    ``k in [-2**(bits-1), 2**(bits-1) - 1]``.
    """

    bits: int = 8
    frac: int = 4

    @property
    def lsb(self) -> float:
        return 2.0 ** (-self.frac)

    @property
    def min_val(self) -> float:
        return -(2.0 ** (self.bits - 1)) * self.lsb

    @property
    def max_val(self) -> float:
        return (2.0 ** (self.bits - 1) - 1) * self.lsb

    def clip(self, x: jax.Array) -> jax.Array:
        return jnp.clip(x, self.min_val, self.max_val)

    def round_nearest(self, x: jax.Array) -> jax.Array:
        """Round-to-nearest-even onto the grid, saturating."""
        return self.clip(jnp.round(x / self.lsb) * self.lsb)

    def round_stochastic(self, x: jax.Array, key: jax.Array) -> jax.Array:
        """Stochastic rounding onto the grid (unbiased), saturating.

        This is the rounding mode ReckOn uses for on-chip e-prop updates so
        that sub-LSB updates still make expected progress.
        """
        scaled = x / self.lsb
        floor = jnp.floor(scaled)
        p_up = scaled - floor
        up = jax.random.uniform(key, x.shape) < p_up
        return self.clip((floor + up.astype(x.dtype)) * self.lsb)

    def ste(self, x: jax.Array) -> jax.Array:
        """Straight-through quantization: forward = grid value, grad = identity."""
        return x + jax.lax.stop_gradient(self.round_nearest(x) - x)


# Membrane-potential grid of the taped-out chip (12-bit signed integer grid,
# threshold registers are raw integers on this grid).
MEMBRANE_SPEC = QuantSpec(bits=16, frac=0)
WEIGHT_SPEC = QuantSpec(bits=8, frac=4)


@dataclasses.dataclass(frozen=True)
class ReckonRegs:
    """Decoded SPI parameter-bank values."""

    threshold: float
    alpha: float
    kappa: float


def from_reckon_regs(
    threshold: int = 0x03F0, alpha_lsb: int = 0x0FE, kappa: int = 0x37,
    membrane_scale: Optional[float] = None,
) -> ReckonRegs:
    """Interpret the raw SPI registers reported in the paper.

    * ``threshold`` is an integer on the membrane grid.  When
      ``membrane_scale`` is given, the threshold is mapped into float model
      units (``threshold * membrane_scale``); by default we normalise the
      grid so the threshold is 1.0 — ReckOn's dynamics are scale-free up to
      the weight grid, so normalised units are exact as long as weights are
      scaled consistently (they are: see :class:`QuantState`).
    * leakage registers are 8-bit fractional multipliers ``reg / 256``.
    """
    scale = membrane_scale if membrane_scale is not None else 1.0 / float(threshold)
    return ReckonRegs(
        threshold=float(threshold) * scale,
        alpha=float(alpha_lsb & 0xFF) / 256.0,
        kappa=float(kappa & 0xFF) / 256.0,
    )


class QuantState:
    """Accumulate-then-round weight storage (pytree of (q, acc) pairs).

    ``q``   — weights snapped to ``spec``'s grid (what the "SRAM" holds);
    ``acc`` — float residual accumulator for sub-LSB update fragments.

    ``commit`` folds the accumulator into the grid weights, carrying the
    rounding residue forward, exactly like the chip's read-modify-write of
    weight SRAM words during e-prop.
    """

    @staticmethod
    def init(params, spec: QuantSpec = WEIGHT_SPEC):
        q = jax.tree.map(spec.round_nearest, params)
        acc = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        return {"q": q, "acc": acc}

    @staticmethod
    def accumulate(state, updates):
        acc = jax.tree.map(lambda a, u: a + u, state["acc"], updates)
        return {"q": state["q"], "acc": acc}

    @staticmethod
    def commit(state, spec: QuantSpec = WEIGHT_SPEC,
               key: Optional[jax.Array] = None):
        def _commit(q, a, k=None):
            tot = q + a
            new_q = spec.round_nearest(tot) if k is None else spec.round_stochastic(tot, k)
            return new_q, tot - new_q

        if key is None:
            pairs = jax.tree.map(_commit, state["q"], state["acc"])
        else:
            leaves, treedef = jax.tree.flatten(state["q"])
            acc_leaves = jax.tree.leaves(state["acc"])
            keys = jax.random.split(key, len(leaves))
            pairs_leaves = [_commit(q, a, k) for q, a, k in zip(leaves, acc_leaves, keys)]
            pairs = jax.tree.unflatten(treedef, pairs_leaves)
        q = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        acc = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return {"q": q, "acc": acc}
