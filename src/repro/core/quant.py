"""Fixed-point numerics matching ReckOn's on-chip representation.

ReckOn stores synaptic weights in 8-bit SRAM words and membrane potentials /
thresholds on a wider integer grid (12-bit in the taped-out chip).  Leakage
factors (alpha for the hidden LIF layer, kappa for the LI readout) are 8-bit
fractional multipliers, i.e. ``decay = reg / 256``.

The paper configures the Braille experiments through the (expanded) SPI
parameter bank with::

    threshold = 0x03F0   # membrane-grid integer
    alpha     = 0x0FE    # "alphas LSBs"  -> 254/256
    kappa     = 0x37     # 55/256

This module provides

* :class:`QuantSpec` — a signed fixed-point grid ``Q(bits, frac)``;
* deterministic and stochastic rounding onto a grid;
* straight-through quantization for use inside differentiable code;
* :func:`from_reckon_regs` — the register-file interpretation above;
* :class:`QuantState` — accumulate-then-round weight storage (the shadow
  accumulator pattern the chip uses for e-prop updates smaller than 1 LSB).

Everything is pure JAX and shape-polymorphic.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Signed fixed-point grid with ``bits`` total bits and ``frac`` fractional bits.

    Representable values: ``k * 2**-frac`` for integer
    ``k in [-2**(bits-1), 2**(bits-1) - 1]``.
    """

    bits: int = 8
    frac: int = 4

    @property
    def lsb(self) -> float:
        return 2.0 ** (-self.frac)

    @property
    def min_val(self) -> float:
        return -(2.0 ** (self.bits - 1)) * self.lsb

    @property
    def max_val(self) -> float:
        return (2.0 ** (self.bits - 1) - 1) * self.lsb

    def clip(self, x: jax.Array) -> jax.Array:
        return jnp.clip(x, self.min_val, self.max_val)

    def round_nearest(self, x: jax.Array) -> jax.Array:
        """Round-to-nearest-even onto the grid, saturating."""
        return self.clip(jnp.round(x / self.lsb) * self.lsb)

    def round_stochastic(self, x: jax.Array, key: jax.Array) -> jax.Array:
        """Stochastic rounding onto the grid (unbiased), saturating.

        This is the rounding mode ReckOn uses for on-chip e-prop updates so
        that sub-LSB updates still make expected progress.
        """
        scaled = x / self.lsb
        floor = jnp.floor(scaled)
        p_up = scaled - floor
        up = jax.random.uniform(key, x.shape) < p_up
        return self.clip((floor + up.astype(x.dtype)) * self.lsb)

    def ste(self, x: jax.Array) -> jax.Array:
        """Straight-through quantization: forward = grid value, grad = identity."""
        return x + jax.lax.stop_gradient(self.round_nearest(x) - x)


# Membrane-potential grid of the taped-out chip: 12-bit signed integer grid,
# threshold registers are raw integers on this grid.  The paper's Braille
# threshold 0x03F0 = 1008 must be representable (and is: v_max = 2047); the
# seed carried a 16-bit grid here, which silently gave the membrane 16x the
# chip's headroom — saturation behaviour was wrong (regression-tested in
# tests/test_quant.py::test_membrane_spec_matches_chip).
MEMBRANE_SPEC = QuantSpec(bits=12, frac=0)
WEIGHT_SPEC = QuantSpec(bits=8, frac=4)

# Deterministic END_B commit grid: the fixed-point accumulator each
# per-sample e-prop contribution is snapped onto before the batch reduction
# (``ExecutionBackend(runtime=RuntimeConfig(commit_grid=DW_COMMIT_SPEC))``).
# Integer code sums are associative, so the committed ``dw`` is *bitwise
# invariant* to how the sample axis is partitioned — a batch split across
# 1, 4 or 8 mesh devices (or any tiling) commits the identical weights.
# This is the software analog of the chip's fixed-point e-prop accumulators;
# 24 bits with 12 fractional give per-sample headroom of ±2**11 at an LSB of
# 2**-12 — far below any observed per-sample |dw| on the paper's workloads.
DW_COMMIT_SPEC = QuantSpec(bits=24, frac=12)


@dataclasses.dataclass(frozen=True)
class QuantizedMode:
    """Bit-true configuration of ReckOn's fixed-point tick datapath.

    This is the contract the hardware-equivalence execution mode implements
    (``ExecutionBackend(cfg, quant=QuantizedMode(...))``), and the one the
    integer golden reference (:mod:`repro.core.quant_ref`) is written
    against:

    * **membrane grid** — signed integers on ``membrane_spec``
      (:data:`MEMBRANE_SPEC`: 12-bit on the taped-out chip), saturating
      arithmetic.  Thresholds are raw integers on this grid (the paper
      programs ``0x03F0`` = 1008 for Braille).
    * **leakage** — 8-bit fractional multipliers: one leak step is
      ``v <- floor(v * (reg & 0xFF) / 256)`` (the hardware's multiply +
      arithmetic-shift-right-by-8, which floors toward -inf).
    * **weight SRAM** — 8-bit signed codes on the ``weight_spec`` grid
      (``Q(8, 4)``: float value ``k / 16``).  The datapath accumulates a
      weight word onto the membrane with a fixed gain of
      ``threshold >> weight_spec.frac`` membrane LSBs per weight LSB, so the
      normalised float model (``v_th = 1.0``, weights on the ``Q(8,4)``
      grid) and the integer model are *commensurate*: one weight LSB is
      exactly ``1/2**frac`` of the threshold on both sides.  This is what
      the paper's threshold value buys — ``0x03F0`` is divisible by 16
      (asserted below).

    All derived JAX helpers keep integer values in float32 carriers: every
    quantity that appears in the datapath is an exact integer below 2**24,
    where float32 arithmetic (add, multiply by ``reg/256``, floor, clip) is
    exact — so the same Pallas kernels and ``lax.scan`` programs execute the
    integer datapath without a dtype change, and match the NumPy int64
    golden reference bit for bit (``tests/test_quant_equivalence.py``).
    """

    threshold: int = 0x03F0        # membrane-grid integer (SPI register)
    alpha_reg: int = 0x0FE         # hidden-layer leak register ("alphas LSBs")
    kappa_reg: int = 0x37          # readout leak register
    membrane_spec: QuantSpec = MEMBRANE_SPEC
    weight_spec: QuantSpec = WEIGHT_SPEC

    def __post_init__(self):
        if self.membrane_spec.frac != 0:
            raise ValueError(
                "the membrane grid is a raw integer grid (frac=0)"
            )
        if not 0 < self.threshold <= self.v_max:
            raise ValueError(
                f"threshold {self.threshold:#x} not representable on the "
                f"{self.membrane_spec.bits}-bit membrane grid "
                f"(max {self.v_max})"
            )
        if self.threshold % (1 << self.weight_spec.frac) != 0:
            raise ValueError(
                f"threshold {self.threshold:#x} must be divisible by "
                f"2**frac={1 << self.weight_spec.frac} so the weight grid "
                "lands on whole membrane LSBs (the chip's 0x03F0 does)"
            )

    # ------------------------------------------------------------ membrane
    @property
    def v_min(self) -> int:
        return int(self.membrane_spec.min_val)

    @property
    def v_max(self) -> int:
        return int(self.membrane_spec.max_val)

    # ------------------------------------------------------------ leakage
    @property
    def alpha(self) -> float:
        """The float decay the registers encode (``reg/256``) — what the
        normalised float model and the e-prop trace filters use."""
        return float(self.alpha_reg & 0xFF) / 256.0

    @property
    def kappa(self) -> float:
        return float(self.kappa_reg & 0xFF) / 256.0

    def leak(self, v: jax.Array, reg: int) -> jax.Array:
        """One hardware leak step: ``floor(v * reg / 256)``.

        ``reg/256`` is an exact power-of-two-denominator float and
        ``|v * reg| < 2**24``, so the float32 multiply is exact and the floor
        reproduces the chip's arithmetic shift (floors toward -inf for
        negative membranes, matching ``>> 8`` on two's complement).
        """
        return jnp.floor(v * (float(reg & 0xFF) / 256.0))

    def sat(self, v: jax.Array) -> jax.Array:
        """Saturate onto the signed membrane grid."""
        return jnp.clip(v, float(self.v_min), float(self.v_max))

    # ------------------------------------------------------------- weights
    @property
    def w_gain(self) -> int:
        """Membrane LSBs one weight LSB contributes (integer by the
        commensurability check in ``__post_init__``)."""
        return self.threshold >> self.weight_spec.frac

    # ------------------------------------------------------------ contract
    def contract(self) -> dict:
        """The register contract as plain JSON-able ints — what checkpoint
        manifests record so a restore can refuse a checkpoint written under
        different fixed-point registers (a silent grid mismatch would make
        the restored SRAM image meaningless)."""
        return {
            "threshold": int(self.threshold),
            "alpha_reg": int(self.alpha_reg),
            "kappa_reg": int(self.kappa_reg),
            "membrane_bits": int(self.membrane_spec.bits),
            "membrane_frac": int(self.membrane_spec.frac),
            "weight_bits": int(self.weight_spec.bits),
            "weight_frac": int(self.weight_spec.frac),
        }

    @classmethod
    def from_contract(cls, d: dict) -> "QuantizedMode":
        """Inverse of :meth:`contract` (manifest dict → mode)."""
        return cls(
            threshold=int(d["threshold"]),
            alpha_reg=int(d["alpha_reg"]),
            kappa_reg=int(d["kappa_reg"]),
            membrane_spec=QuantSpec(int(d["membrane_bits"]),
                                    int(d["membrane_frac"])),
            weight_spec=QuantSpec(int(d["weight_bits"]),
                                  int(d["weight_frac"])),
        )

    def weight_codes(self, w: jax.Array) -> jax.Array:
        """Float weights → signed SRAM codes (integer-valued float32)."""
        spec = self.weight_spec
        lo = -(2.0 ** (spec.bits - 1))
        hi = 2.0 ** (spec.bits - 1) - 1
        return jnp.clip(jnp.round(jnp.asarray(w) / spec.lsb), lo, hi)

    def to_membrane(self, w: jax.Array) -> jax.Array:
        """Float weights → membrane-grid integers the datapath accumulates."""
        return self.weight_codes(w) * float(self.w_gain)


@dataclasses.dataclass(frozen=True)
class ReckonRegs:
    """Decoded SPI parameter-bank values."""

    threshold: float
    alpha: float
    kappa: float


def from_reckon_regs(
    threshold: int = 0x03F0, alpha_lsb: int = 0x0FE, kappa: int = 0x37,
    membrane_scale: Optional[float] = None,
) -> ReckonRegs:
    """Interpret the raw SPI registers reported in the paper.

    * ``threshold`` is an integer on the membrane grid.  When
      ``membrane_scale`` is given, the threshold is mapped into float model
      units (``threshold * membrane_scale``); by default we normalise the
      grid so the threshold is 1.0 — ReckOn's dynamics are scale-free up to
      the weight grid, so normalised units are exact as long as weights are
      scaled consistently (they are: see :class:`QuantState`).
    * leakage registers are 8-bit fractional multipliers ``reg / 256``.
    """
    scale = membrane_scale if membrane_scale is not None else 1.0 / float(threshold)
    return ReckonRegs(
        threshold=float(threshold) * scale,
        alpha=float(alpha_lsb & 0xFF) / 256.0,
        kappa=float(kappa & 0xFF) / 256.0,
    )


class QuantState:
    """Accumulate-then-round weight storage (pytree of (q, acc) pairs).

    ``q``   — weights snapped to ``spec``'s grid (what the "SRAM" holds);
    ``acc`` — float residual accumulator for sub-LSB update fragments.

    ``commit`` folds the accumulator into the grid weights, carrying the
    rounding residue forward, exactly like the chip's read-modify-write of
    weight SRAM words during e-prop.
    """

    @staticmethod
    def init(params, spec: QuantSpec = WEIGHT_SPEC):
        q = jax.tree.map(spec.round_nearest, params)
        acc = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        return {"q": q, "acc": acc}

    @staticmethod
    def accumulate(state, updates):
        acc = jax.tree.map(lambda a, u: a + u, state["acc"], updates)
        return {"q": state["q"], "acc": acc}

    @staticmethod
    def commit(state, spec: QuantSpec = WEIGHT_SPEC,
               key: Optional[jax.Array] = None):
        def _commit(q, a, k=None):
            tot = q + a
            new_q = spec.round_nearest(tot) if k is None else spec.round_stochastic(tot, k)
            return new_q, tot - new_q

        if key is None:
            pairs = jax.tree.map(_commit, state["q"], state["acc"])
        else:
            leaves, treedef = jax.tree.flatten(state["q"])
            acc_leaves = jax.tree.leaves(state["acc"])
            keys = jax.random.split(key, len(leaves))
            pairs_leaves = [_commit(q, a, k) for q, a, k in zip(leaves, acc_leaves, keys)]
            pairs = jax.tree.unflatten(treedef, pairs_leaves)
        q = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        acc = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return {"q": q, "acc": acc}
