"""The AER-decoder controller — the paper's FSM as jit-able scans.

The FPGA FSM (Fig. 3 / Fig. 5) walks IDLE → READM → TICK → SPIKE/LABEL →
END_S → (END_B) → END_E, driving samples through ReckOn and committing
e-prop weight updates as it goes.  Here the walk becomes structured tensor
code, with every forward/update executed through one
:class:`repro.core.backend.ExecutionBackend` (``"kernel"`` = fused Pallas
kernels, ``"scan"`` = reference ``lax.scan``):

* the READM/TICK/SPIKE scatter is :func:`repro.core.aer.decode_batch`
  (event words → dense rasters);
* ``commit="sample"`` (END_S, X-HEEP-faithful): a ``lax.scan`` over samples
  whose carry is the weight pytree — *online*: sample ``s+1`` sees the
  weights updated by sample ``s``, exactly like the chip
  (:func:`make_train_batch_fn`);
* ``commit="batch"`` (END_B, ARM mode): the whole BRAM-sized batch runs as
  one rectangular ``(T, B, N)`` tile through the backend's fused forward +
  e-prop update, and the batch-summed ``dw`` commits once at the batch
  boundary (:func:`make_batch_commit_train_fn`) — the high-throughput mode
  ``benchmarks/bench_braille.py`` measures against the sequential loop;
* the EPOCH_ACC counter sampled by the ILA is the ``correct`` counter folded
  through the scan.

Two pipeline modes mirror the paper's two SoCs (see ``data/pipeline.py``):
``X-HEEP`` — dataset resident on device, whole epoch is one jit; ``ARM`` —
dataset streamed in batches with a BATCH_DONE/NEW_BATCH handshake.

Hardware-equivalence mode: configs with ``cfg.neuron.quant`` set (e.g.
``Presets.braille(quantized=True)``) run every forward through ReckOn's
fixed-point datapath — the backend picks the mode up from the config, and
pairing it with a quantized :class:`~repro.optim.eprop_opt.EpropSGD`
(``EpropSGDConfig(quant=WEIGHT_SPEC, stochastic_round=True)``) makes the
whole END_S/END_B walk chip-faithful: 8-bit SRAM weights, accumulate-then-
round commits, integer membranes.  A float optimizer over a quantized
config is quantization-aware training instead (float master weights,
quantized datapath).

Inference entries: :func:`make_infer_fn` is the *sequential* per-sample
classify (the FSM's TEST=1 walk, and the baseline
``benchmarks/bench_serve.py`` measures against);
:func:`make_batch_infer_fn` is its batch-capable twin.  The batched serving
runtime (:mod:`repro.serve.engine`) no longer owns its own dispatch — it
drives the same :class:`~repro.core.backend.ExecutionBackend` object, which
is how ``BatchedEngine.from_learner(learner)`` serves live weights from a
still-training learner without recompiling.
"""

from __future__ import annotations

import dataclasses
import signal
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aer, eprop
from repro.core.backend import BackendLike, ExecutionBackend, as_backend
from repro.core.rsnn import RSNNConfig, init_params, merge_trainable, trainable
from repro.distributed.checkpoint import (
    CheckpointManager,
    CheckpointPolicy,
    ReplayCursor,
)
from repro.optim.eprop_opt import EpropSGD, EpropSGDConfig


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Runtime registers of the expanded SPI parameter bank (§3.3)."""

    num_epochs: int = 10
    samples_per_epoch: int = 50
    samples_per_batch: int = 50       # BRAM buffer depth in ARM mode
    label_delay: int = 0              # delayed-supervision offset
    eval_every: int = 1               # validation cadence (paper: every 5 for Braille)
    shuffle: bool = False             # chip replays BRAM order; keep False for parity
    commit: str = "sample"            # "sample" (END_S, X-HEEP) | "batch" (END_B, ARM)

    def __post_init__(self):
        if self.commit not in ("sample", "batch"):
            raise ValueError(f"unknown commit mode {self.commit!r}")


# A decoded batch on device: {"raster": (S, T, N) sample-major rasters,
# "label": (S,), "valid": (S, T)}.  Training/eval entries transpose to the
# tick-major (T, B, N) layout the execution backend consumes.
DeviceBatch = dict


def decode_events_to_batch(
    words: jax.Array, n_in: int, num_ticks: int, label_delay: int = 0
) -> DeviceBatch:
    """AER buffer (S, L) uint32 → dense training batch (the READM+TICK path)."""
    s = aer.decode_batch(words, n_in, num_ticks)
    valid = jax.vmap(
        lambda lt, et: aer.supervision_mask(lt, et, num_ticks, label_delay)
    )(s.label_tick, s.end_tick)
    return DeviceBatch(raster=s.raster, label=s.label, valid=valid)


def make_train_batch_fn(
    cfg: RSNNConfig, opt: EpropSGD, backend: Optional[ExecutionBackend] = None
):
    """Build the jit'd END_S loop: scan over samples, online weight commit.

    Layout contract: ``batch["raster"]`` is **sample-major** ``(S, T, N)`` —
    ``lax.scan`` iterates the leading sample axis and each ``(T, N)`` sample
    is lifted to a tick-major ``(T, 1, N)`` tile for the backend.  (The seed
    code carried a no-op ``swapaxes(·, 0, 0)`` here; the transpose it gestured
    at never existed — samples arrive sample-major from the decoder.)

    Returns ``fn(weights, opt_state, batch, key) -> (weights, opt_state,
    metrics)`` where metrics carries the EPOCH_ACC-style counters.
    """
    backend = backend or ExecutionBackend(cfg, "scan")

    def sample_step(carry, sample):
        weights, opt_state, key = carry
        key, sub = jax.random.split(key)
        raster = sample["raster"][:, None, :]          # (T, N) -> (T, 1, N)
        y_star = jax.nn.one_hot(sample["label"], cfg.n_out)[None, :]
        valid = sample["valid"][:, None]
        dw, metrics = backend.train_tile(weights, raster, y_star, valid)
        weights, opt_state = opt.update(weights, dw, opt_state, sub)
        correct = (metrics["pred"][0] == sample["label"]).astype(jnp.int32)
        return (weights, opt_state, key), (correct, metrics["spike_rate"])

    @jax.jit
    def train_batch(weights, opt_state, batch: Dict[str, jax.Array], key):
        samples = {
            "raster": batch["raster"],                 # (S, T, N) sample-major
            "label": batch["label"],
            "valid": batch["valid"],
        }
        (weights, opt_state, _), (correct, rate) = jax.lax.scan(
            sample_step, (weights, opt_state, key), samples
        )
        return weights, opt_state, {
            "correct": correct.sum(),
            "count": correct.shape[0],
            "spike_rate": rate.mean(),
        }

    return train_batch


def batch_commit_update(
    cfg: RSNNConfig,
    opt: EpropSGD,
    backend: ExecutionBackend,
    weights,
    opt_state,
    batch: Dict[str, jax.Array],
    key=None,
):
    """The END_B commit core: one rectangular tile, one weight commit.

    The ARM-mode SoC streams a BRAM-sized batch through ReckOn and commits at
    the END_B boundary (§3.3, Fig. 5).  Here the whole ``(S, T, N)`` batch is
    transposed to one tick-major ``(T, S, N)`` tile, pushed through the
    backend's fused forward + e-prop update (on the kernel backend: the
    Pallas ``rsnn_step`` + ``eprop_update`` pipeline), and the batch-summed
    ``dw`` is committed once.  Every sample in the batch sees the
    batch-start weights — the defining difference from the END_S scan, where
    sample ``s+1`` sees sample ``s``'s update.

    The optimizer is told the commit represents ``S`` samples
    (``num_updates=S``) so lr decay and gradient clipping keep per-sample
    semantics across the two commit modes.

    Returns ``(weights, opt_state, dw, metrics)``; trace inside a jit
    (:func:`make_batch_commit_train_fn` and
    :func:`repro.train.eprop_step.make_eprop_commit_step` both do).
    """
    raster = jnp.swapaxes(batch["raster"], 0, 1)   # (S, T, N) -> (T, S, N)
    valid = jnp.swapaxes(batch["valid"], 0, 1)     # (S, T)    -> (T, S)
    y_star = jax.nn.one_hot(batch["label"], cfg.n_out)
    dw, metrics = backend.train_tile(weights, raster, y_star, valid)
    num = batch["label"].shape[0]
    weights, opt_state = opt.update(
        weights, dw, opt_state, key, num_updates=float(num)
    )
    return weights, opt_state, dw, metrics


def make_batch_commit_train_fn(
    cfg: RSNNConfig, opt: EpropSGD, backend: Optional[ExecutionBackend] = None
):
    """Build the jit'd END_B training entry over :func:`batch_commit_update`,
    reporting the controller's EPOCH_ACC-style counters."""
    backend = backend or ExecutionBackend(cfg, "scan")

    @jax.jit
    def train_batch(weights, opt_state, batch: Dict[str, jax.Array], key):
        weights, opt_state, _, metrics = batch_commit_update(
            cfg, opt, backend, weights, opt_state, batch, key
        )
        correct = (metrics["pred"] == batch["label"]).astype(jnp.int32)
        return weights, opt_state, {
            "correct": correct.sum(),
            "count": batch["label"].shape[0],
            "spike_rate": metrics["spike_rate"],
        }

    return train_batch


def make_eval_batch_fn(cfg: RSNNConfig, backend: Optional[ExecutionBackend] = None):
    """Inference-only epoch (TEST=1 path): one batched tile, no updates."""
    backend = backend or ExecutionBackend(cfg, "scan")

    @jax.jit
    def eval_batch(weights, batch: Dict[str, jax.Array]):
        raster = jnp.swapaxes(batch["raster"], 0, 1)       # (T, S, N_in)
        valid = jnp.swapaxes(batch["valid"], 0, 1)         # (T, S)
        out = backend.inference(weights, raster, valid)
        correct = (out["pred"] == batch["label"]).astype(jnp.int32)
        return {
            "correct": correct.sum(),
            "count": correct.shape[0],
            "spike_rate": out["spike_rate"],
        }

    return eval_batch


def make_batch_infer_fn(cfg: RSNNConfig):
    """Batch-capable inference entry: classify a padded/masked batch.

    ``fn(weights, raster (T, B, N_in), valid (T, B)) -> {"acc_y", "pred"}``.
    This is the exact per-sample math of :func:`make_eval_batch_fn`
    vectorized over the batch axis — the oracle the serving runtime
    (:mod:`repro.serve.engine`) is tested against, and the ``"scan"``
    backend of :class:`repro.core.backend.ExecutionBackend`.  Quantized
    configs thread through ``cfg.neuron.quant`` (``acc_y`` is then in
    membrane-grid units, like the backend's).
    """

    @jax.jit
    def infer_batch(weights, raster: jax.Array, valid: jax.Array):
        params = merge_trainable(
            {"alpha": jnp.asarray(cfg.neuron.alpha, raster.dtype)}, weights
        )
        out = eprop.run_sample_inference(params, raster, valid, cfg.neuron, cfg.eprop)
        return {"acc_y": out["acc_y"], "pred": out["pred"]}

    return infer_batch


def make_infer_fn(cfg: RSNNConfig):
    """Sequential single-sample classify — the chip's one-at-a-time TEST walk.

    ``fn(weights, raster (T, N_in), valid (T,)) -> {"acc_y" (O,), "pred" ()}``.
    ``benchmarks/bench_serve.py`` uses this as the baseline the batched
    engine is measured against.
    """
    batched = make_batch_infer_fn(cfg)

    @jax.jit
    def infer_one(weights, raster: jax.Array, valid: jax.Array):
        out = batched(weights, raster[:, None, :], valid[:, None])
        return {"acc_y": out["acc_y"][0], "pred": out["pred"][0]}

    return infer_one


@dataclasses.dataclass
class EpochLog:
    """The ILA trace: per-epoch accuracy counters."""

    train_acc: list
    val_acc: list

    def last(self) -> Tuple[float, float]:
        return (
            self.train_acc[-1] if self.train_acc else float("nan"),
            self.val_acc[-1] if self.val_acc else float("nan"),
        )


class OnlineLearner:
    """End-to-end controller: owns weights, optimizer state and the epoch loop.

    ``pipeline`` is any iterable-of-batches factory with the interface of
    :mod:`repro.data.pipeline` (``batches(split, epoch)`` yielding device
    batches) — ResidentPipeline replays one big batch (X-HEEP mode),
    BatchedOffloadPipeline streams BRAM-sized chunks (ARM mode).

    ``backend`` selects the execution engine every train/eval tile runs
    through: a name (``"kernel" | "scan" | "auto"``) or an existing
    :class:`~repro.core.backend.ExecutionBackend` to share (e.g. with a
    :class:`repro.serve.BatchedEngine` serving this learner's live weights).
    ``ctrl.commit`` selects the training loop: ``"sample"`` = per-sample
    END_S commit (X-HEEP-faithful), ``"batch"`` = END_B batch commit (ARM).

    ``registry``/``model_id`` attach the learner to a
    :class:`repro.serve.registry.ModelRegistry` (the multi-tenant serving
    state): the learner registers itself under ``model_id`` — sharing its
    execution backend with the registry's pool, so serving mints no new
    programs — and *publishes* its live weights into the registry every
    ``publish_every`` commits (:meth:`publish` does it on demand).  A
    serving engine routed at that model picks the new SRAM image up on its
    next launched tile: the paper's online-learning loop, mid-serve.

    ``checkpoint`` (a :class:`~repro.distributed.checkpoint.CheckpointPolicy`)
    arms durable fault tolerance: every ``policy.every``-th commit the full
    restorable state — quantized SRAM weight image, ``EpropSGD`` float
    residuals and sample count, the PRNG key, and the
    :class:`~repro.distributed.checkpoint.ReplayCursor` — is saved
    (asynchronously by default) with the backend's
    :class:`~repro.core.quant.QuantizedMode` register contract recorded in
    the manifest.  ``fit(..., resume=True)`` restores the newest complete
    checkpoint, validates the contract, and replays exactly the batches the
    interrupted run would have consumed (see ``docs/fault_tolerance.md``).
    """

    def __init__(
        self,
        cfg: RSNNConfig,
        ctrl: ControllerConfig,
        opt_cfg: EpropSGDConfig,
        key: jax.Array,
        backend: BackendLike = "auto",
        mesh=None,
        runtime=None,
        registry=None,
        model_id: Optional[str] = None,
        publish_every: int = 1,
        checkpoint: Optional[CheckpointPolicy] = None,
    ):
        self.cfg, self.ctrl = cfg, ctrl
        self.opt = EpropSGD(opt_cfg)
        params = init_params(key, cfg)
        self.weights = self.opt.quantize_init(trainable(params))
        self.alpha = params["alpha"]
        if cfg.eprop.feedback == "random":
            # random feedback matrices ride with the weights (fixed, untrained)
            self.weights["b_fb"] = params["b_fb"]
        self.opt_state = self.opt.init(self.weights)
        self.key = jax.random.fold_in(key, 1)
        # mesh: data-parallel END_B — the backend shards the sample axis and
        # psums dw, so the commit matches the single-device walk exactly.
        # runtime= (a core.backend.RuntimeConfig) is the bundled form of the
        # backend/mesh/... knobs; resolution happens in as_backend either way.
        self.backend = as_backend(
            cfg, backend, alpha=float(params["alpha"]), mesh=mesh,
            runtime=runtime,
        )
        train_builder = (
            make_batch_commit_train_fn
            if ctrl.commit == "batch"
            else make_train_batch_fn
        )
        self._train_fn = train_builder(cfg, self.opt, self.backend)
        self._eval_fn = make_eval_batch_fn(cfg, self.backend)
        self.log = EpochLog(train_acc=[], val_acc=[])
        # ---- registry attachment (duck-typed: anything with register /
        # update_weights keyed by model_id, i.e. serve.registry.ModelRegistry;
        # core stays importable without the serve layer) ------------------
        self.registry = registry
        self.model_id = model_id if model_id is not None else "default"
        self.publish_every = max(1, int(publish_every))
        self._commits = 0
        # ---- durability ------------------------------------------------
        self.policy = checkpoint
        self.ckpt: Optional[CheckpointManager] = (
            checkpoint.manager() if checkpoint is not None else None
        )
        self.cursor = ReplayCursor()
        self._stop = False            # set by the SIGTERM/SIGINT handler
        self._on_commit: Optional[Callable] = None   # chaos-harness hook
        self._old_handlers: Dict[int, object] = {}
        if registry is not None:
            if self.model_id in registry:
                registry.update_weights(self.model_id, self.inference_params())
            else:
                # share this learner's backend: registered into the pool, so
                # an engine serving this model reuses the learner's jit cache
                registry.register(
                    self.model_id, cfg, self.inference_params(),
                    backend=self.backend,
                )

    def publish(self) -> None:
        """Push the live weights into the attached registry (the SPI weight
        reload, mid-serve): engines routing ``model_id`` serve the new SRAM
        image from their next launched tile.  No recompilation — weights
        are jit arguments end to end."""
        if self.registry is None:
            raise ValueError(
                "learner has no registry attached — construct with registry="
            )
        self.registry.update_weights(self.model_id, self.inference_params())

    def train_batch(self, batch: DeviceBatch) -> Dict[str, jax.Array]:
        """Train on one device batch (one END_B commit, or one END_S scan over
        its samples, per ``ctrl.commit``) — the entry the interleaved
        train-while-serve feed (:func:`repro.data.pipeline.interleave_train_serve`)
        drives."""
        self.key, sub = jax.random.split(self.key)
        self.weights, self.opt_state, m = self._train_fn(
            self.weights, self.opt_state, batch, sub
        )
        self._commits += 1
        if self.registry is not None and self._commits % self.publish_every == 0:
            self.publish()
        if self.policy is not None and self._commits % self.policy.every == 0:
            self.save_checkpoint()
        if self._on_commit is not None:
            self._on_commit(self, self._commits)
        return m

    def train_epoch(self, pipeline, epoch: int, start_batch: int = 0) -> float:
        """One training epoch; ``start_batch`` resumes mid-epoch (replay).

        The replay cursor is advanced to ``(epoch, i + 1)`` *before* batch
        ``i`` trains, so a checkpoint cut at the commit inside
        :meth:`train_batch` records the first batch a resumed run must
        consume — never a batch twice, never a skipped one.
        """
        correct = total = 0
        it = (pipeline.batches("train", epoch, start_batch=start_batch)
              if start_batch else pipeline.batches("train", epoch))
        for i, batch in enumerate(it, start=start_batch):
            self.cursor.epoch, self.cursor.batch = epoch, i + 1
            m = self.train_batch(batch)
            correct += int(m["correct"])
            total += int(m["count"])
            if self._stop:
                break
        else:
            self.cursor.epoch, self.cursor.batch = epoch + 1, 0
        acc = correct / max(total, 1)
        self.log.train_acc.append(acc)
        return acc

    def eval_epoch(self, pipeline, epoch: int, split: str = "val") -> float:
        correct = total = 0
        for batch in pipeline.batches(split, epoch):
            m = self._eval_fn(self.weights, batch)
            correct += int(m["correct"])
            total += int(m["count"])
        acc = correct / max(total, 1)
        if split == "val":
            self.log.val_acc.append(acc)
        return acc

    def inference_params(self) -> Dict[str, jax.Array]:
        """Current weights + alpha as one pytree — what a serving engine
        (``repro.serve.BatchedEngine.from_learner``) snapshots."""
        return merge_trainable({"alpha": self.alpha}, self.weights)

    # --------------------------------------------------------- durability

    def _key_data(self) -> jax.Array:
        """The PRNG key as a plain serializable array (typed keys carry an
        extended dtype ``np.savez`` can't store)."""
        if jnp.issubdtype(self.key.dtype, jax.dtypes.prng_key):
            return jax.random.key_data(self.key)
        return self.key

    def _ckpt_state(self) -> Dict[str, object]:
        """The restorable state tree: quantized SRAM weight image (int-exact
        float32 carriers), optimizer residuals + sample count, PRNG key."""
        return {
            "weights": self.weights,
            "opt_state": self.opt_state,
            "key": self._key_data(),
        }

    def _quant_contract(self) -> Optional[Dict]:
        q = self.backend.quant
        return None if q is None else q.contract()

    def save_checkpoint(self, blocking: Optional[bool] = None) -> None:
        """Cut a checkpoint at the current commit count.

        ``blocking=None`` follows ``policy.async_save``; the async path
        overlaps disk IO with the next commits and surfaces any write error
        at the next save (see :class:`CheckpointManager`).  The manifest
        carries everything a restore validates or replays: the commit
        count, the :class:`ReplayCursor`, the commit mode, the quantized
        register contract, and the saving mesh's device count.
        """
        if self.ckpt is None:
            raise ValueError(
                "learner has no checkpoint policy — construct with checkpoint="
            )
        blocking = (
            not self.policy.async_save if blocking is None else blocking
        )
        extra = {
            "kind": "online_learner",
            "commits": int(self._commits),
            "cursor": self.cursor.as_manifest(),
            "commit_mode": self.ctrl.commit,
            "quant": self._quant_contract(),
            "mesh_devices": int(self.backend.num_devices),
            "model": self.model_id,
        }
        state = self._ckpt_state()
        if blocking:
            self.ckpt.save(self._commits, state, extra)
        else:
            self.ckpt.save_async(self._commits, state, extra)

    def restore_checkpoint(self, step: Optional[int] = None) -> bool:
        """Restore the newest complete checkpoint (or ``step``), validating
        the manifest against this learner's execution contract.

        Returns ``False`` when the directory holds no complete checkpoint
        (fresh start); raises :class:`ValueError` when the checkpoint was
        cut under a *different* quantized register contract or commit mode
        — restoring it would silently change arithmetic, the same loud-
        boundary discipline as the per-leaf shape/dtype diff in
        :meth:`CheckpointManager.restore`.  The restored weights work on
        any mesh size (they are replicated host arrays; see
        :mod:`repro.distributed.elastic`), and an attached registry is
        re-published immediately so live serve lanes pick the restored
        SRAM image up on their next tile.
        """
        if self.ckpt is None:
            raise ValueError(
                "learner has no checkpoint policy — construct with checkpoint="
            )
        if step is None:
            step = self.ckpt.latest_step()
        if step is None:
            return False
        template = jax.tree.map(np.asarray, jax.device_get(self._ckpt_state()))
        host, manifest = self.ckpt.restore(step, template)
        want = self._quant_contract()
        got = manifest.get("quant")
        if got != want:
            raise ValueError(
                "checkpoint was cut under a different quantized register "
                f"contract:\n  checkpoint: {got}\n  this learner: {want}"
            )
        if manifest.get("commit_mode") != self.ctrl.commit:
            raise ValueError(
                f"checkpoint was cut in commit={manifest.get('commit_mode')!r} "
                f"mode, this learner runs commit={self.ctrl.commit!r}"
            )
        self.weights = jax.tree.map(jnp.asarray, host["weights"])
        self.opt_state = jax.tree.map(jnp.asarray, host["opt_state"])
        k = jnp.asarray(host["key"])
        if jnp.issubdtype(self.key.dtype, jax.dtypes.prng_key):
            k = jax.random.wrap_key_data(k, impl=jax.random.key_impl(self.key))
        self.key = k
        self._commits = int(manifest["commits"])
        self.cursor = ReplayCursor.from_manifest(manifest["cursor"])
        if self.registry is not None:
            self.publish()
        return True

    def install_signal_handlers(self) -> None:
        """SIGTERM/SIGINT → finish the in-flight batch, cut a final blocking
        checkpoint, return from :meth:`fit` (``self._stop``) — the graceful
        half of the fault-tolerance story (SIGKILL is the chaos half)."""
        for s in (signal.SIGTERM, signal.SIGINT):
            self._old_handlers[s] = signal.signal(s, self._on_term)

    def _on_term(self, signum, frame) -> None:
        self._stop = True

    def restore_signal_handlers(self) -> None:
        for s, h in self._old_handlers.items():
            signal.signal(s, h)
        self._old_handlers = {}

    @property
    def stopped_by_signal(self) -> bool:
        return self._stop

    def fit(
        self,
        pipeline,
        verbose: bool = False,
        resume: bool = False,
        on_commit: Optional[Callable] = None,
    ) -> EpochLog:
        """Run the configured epochs; ``resume=True`` restores the newest
        checkpoint first and replays from its cursor.  ``on_commit`` is an
        optional ``(learner, commit_count)`` hook fired after every commit
        (checkpoint already cut) — the chaos harness's kill point."""
        if on_commit is not None:
            self._on_commit = on_commit
        if resume and self.ckpt is not None:
            self.restore_checkpoint()
        start_batch = self.cursor.batch
        for epoch in range(self.cursor.epoch, self.ctrl.num_epochs):
            tr = self.train_epoch(pipeline, epoch, start_batch=start_batch)
            start_batch = 0
            if self._stop:
                break
            va = (
                self.eval_epoch(pipeline, epoch)
                if (epoch + 1) % self.ctrl.eval_every == 0
                else float("nan")
            )
            if verbose:
                print(f"epoch {epoch:4d}  train_acc={tr:.3f}  val_acc={va:.3f}")
        if self.ckpt is not None:
            self.ckpt.wait()
            self.save_checkpoint(blocking=True)
        return self.log
