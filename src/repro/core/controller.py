"""The AER-decoder controller — the paper's FSM as jit-able scans.

The FPGA FSM (Fig. 3 / Fig. 5) walks IDLE → READM → TICK → SPIKE/LABEL →
END_S → (END_B) → END_E, driving one sample at a time through ReckOn and
committing an e-prop weight update at each end-of-sample.  Here the walk
becomes structured tensor code:

* the READM/TICK/SPIKE scatter is :func:`repro.core.aer.decode_batch`
  (event words → dense rasters);
* the per-sample END_S commit is a ``lax.scan`` over samples whose carry is
  the weight pytree — faithfully *online*: sample ``s+1`` sees the weights
  updated by sample ``s``, exactly like the chip;
* END_B (batch boundary, ARM mode) is the host-side loop of
  :class:`repro.data.pipeline.BatchedOffloadPipeline`;
* the EPOCH_ACC counter sampled by the ILA is the ``correct`` counter folded
  through the scan.

Two controller modes mirror the paper's two SoCs:

* ``X-HEEP mode``  — dataset resident on device, whole epoch is one jit;
* ``ARM mode``     — dataset streamed in batches, one jit per batch with a
  BATCH_DONE/NEW_BATCH handshake (see ``data/pipeline.py``).

Inference entries: :func:`make_infer_fn` is the *sequential* per-sample
classify (the FSM's TEST=1 walk, and the baseline
``benchmarks/bench_serve.py`` measures against);
:func:`make_batch_infer_fn` is its batch-capable twin.  The batched serving
runtime (:mod:`repro.serve`) builds on the same math via the fused Pallas
kernel (:mod:`repro.kernels.rsnn_step`) — construct one with
``BatchedEngine.from_learner(learner)``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import aer, eprop
from repro.core.rsnn import RSNNConfig, init_params, merge_trainable, trainable
from repro.optim.eprop_opt import EpropSGD, EpropSGDConfig


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Runtime registers of the expanded SPI parameter bank (§3.3)."""

    num_epochs: int = 10
    samples_per_epoch: int = 50
    samples_per_batch: int = 50       # BRAM buffer depth in ARM mode
    label_delay: int = 0              # delayed-supervision offset
    eval_every: int = 1               # validation cadence (paper: every 5 for Braille)
    shuffle: bool = False             # chip replays BRAM order; keep False for parity


# A decoded batch on device: {"raster": (S,T,N), "label": (S,), "valid": (S,T)}.
DeviceBatch = dict


def decode_events_to_batch(
    words: jax.Array, n_in: int, num_ticks: int, label_delay: int = 0
) -> DeviceBatch:
    """AER buffer (S, L) uint32 → dense training batch (the READM+TICK path)."""
    s = aer.decode_batch(words, n_in, num_ticks)
    valid = jax.vmap(
        lambda lt, et: aer.supervision_mask(lt, et, num_ticks, label_delay)
    )(s.label_tick, s.end_tick)
    return DeviceBatch(raster=s.raster, label=s.label, valid=valid)


def make_train_batch_fn(cfg: RSNNConfig, opt: EpropSGD):
    """Build the jit'd END_S loop: scan over samples, online weight commit.

    Returns ``fn(weights, opt_state, batch, key) -> (weights, opt_state,
    metrics)`` where metrics carries the EPOCH_ACC-style counters.
    """

    def sample_step(carry, sample):
        weights, opt_state, key = carry
        key, sub = jax.random.split(key)
        raster = sample["raster"][:, None, :]          # (T, 1, N_in)
        y_star = jax.nn.one_hot(sample["label"], cfg.n_out)[None, :]
        valid = sample["valid"][:, None]
        params = merge_trainable(
            {"alpha": jnp.asarray(cfg.neuron.alpha, raster.dtype)}, weights
        )
        dw, metrics = eprop.run_sample(
            params, raster, y_star, valid, cfg.neuron, cfg.eprop
        )
        weights, opt_state = opt.update(weights, dw, opt_state, sub)
        correct = (metrics["pred"][0] == sample["label"]).astype(jnp.int32)
        return (weights, opt_state, key), (correct, metrics["spike_rate"])

    @jax.jit
    def train_batch(weights, opt_state, batch: Dict[str, jax.Array], key):
        samples = {
            "raster": jnp.swapaxes(batch["raster"], 0, 0),  # (S, T, N)
            "label": batch["label"],
            "valid": batch["valid"],
        }
        (weights, opt_state, _), (correct, rate) = jax.lax.scan(
            sample_step, (weights, opt_state, key), samples
        )
        return weights, opt_state, {
            "correct": correct.sum(),
            "count": correct.shape[0],
            "spike_rate": rate.mean(),
        }

    return train_batch


def make_eval_batch_fn(cfg: RSNNConfig):
    """Inference-only epoch (TEST=1 path): vmapped over samples, no updates."""

    @jax.jit
    def eval_batch(weights, batch: Dict[str, jax.Array]):
        params = merge_trainable(
            {"alpha": jnp.asarray(cfg.neuron.alpha, batch["raster"].dtype)}, weights
        )
        raster = jnp.swapaxes(batch["raster"], 0, 1)       # (T, S, N_in)
        valid = jnp.swapaxes(batch["valid"], 0, 1)         # (T, S)
        out = eprop.run_sample_inference(params, raster, valid, cfg.neuron, cfg.eprop)
        correct = (out["pred"] == batch["label"]).astype(jnp.int32)
        return {
            "correct": correct.sum(),
            "count": correct.shape[0],
            "spike_rate": out["spike_rate"],
        }

    return eval_batch


def make_batch_infer_fn(cfg: RSNNConfig):
    """Batch-capable inference entry: classify a padded/masked batch.

    ``fn(weights, raster (T, B, N_in), valid (T, B)) -> {"acc_y", "pred"}``.
    This is the exact per-sample math of :func:`make_eval_batch_fn`
    vectorized over the batch axis — the oracle the serving runtime
    (:mod:`repro.serve.engine`) is tested against, and its ``"scan"``
    backend.
    """

    @jax.jit
    def infer_batch(weights, raster: jax.Array, valid: jax.Array):
        params = merge_trainable(
            {"alpha": jnp.asarray(cfg.neuron.alpha, raster.dtype)}, weights
        )
        out = eprop.run_sample_inference(params, raster, valid, cfg.neuron, cfg.eprop)
        return {"acc_y": out["acc_y"], "pred": out["pred"]}

    return infer_batch


def make_infer_fn(cfg: RSNNConfig):
    """Sequential single-sample classify — the chip's one-at-a-time TEST walk.

    ``fn(weights, raster (T, N_in), valid (T,)) -> {"acc_y" (O,), "pred" ()}``.
    ``benchmarks/bench_serve.py`` uses this as the baseline the batched
    engine is measured against.
    """
    batched = make_batch_infer_fn(cfg)

    @jax.jit
    def infer_one(weights, raster: jax.Array, valid: jax.Array):
        out = batched(weights, raster[:, None, :], valid[:, None])
        return {"acc_y": out["acc_y"][0], "pred": out["pred"][0]}

    return infer_one


@dataclasses.dataclass
class EpochLog:
    """The ILA trace: per-epoch accuracy counters."""

    train_acc: list
    val_acc: list

    def last(self) -> Tuple[float, float]:
        return (
            self.train_acc[-1] if self.train_acc else float("nan"),
            self.val_acc[-1] if self.val_acc else float("nan"),
        )


class OnlineLearner:
    """End-to-end controller: owns weights, optimizer state and the epoch loop.

    ``pipeline`` is any iterable-of-batches factory with the interface of
    :mod:`repro.data.pipeline` (``batches(split, epoch)`` yielding device
    batches) — ResidentPipeline replays one big batch (X-HEEP mode),
    BatchedOffloadPipeline streams BRAM-sized chunks (ARM mode).
    """

    def __init__(
        self,
        cfg: RSNNConfig,
        ctrl: ControllerConfig,
        opt_cfg: EpropSGDConfig,
        key: jax.Array,
    ):
        self.cfg, self.ctrl = cfg, ctrl
        self.opt = EpropSGD(opt_cfg)
        params = init_params(key, cfg)
        self.weights = self.opt.quantize_init(trainable(params))
        self.alpha = params["alpha"]
        self.opt_state = self.opt.init(self.weights)
        self.key = jax.random.fold_in(key, 1)
        self._train_fn = make_train_batch_fn(cfg, self.opt)
        self._eval_fn = make_eval_batch_fn(cfg)
        self.log = EpochLog(train_acc=[], val_acc=[])

    def train_epoch(self, pipeline, epoch: int) -> float:
        correct = total = 0
        for batch in pipeline.batches("train", epoch):
            self.key, sub = jax.random.split(self.key)
            self.weights, self.opt_state, m = self._train_fn(
                self.weights, self.opt_state, batch, sub
            )
            correct += int(m["correct"])
            total += int(m["count"])
        acc = correct / max(total, 1)
        self.log.train_acc.append(acc)
        return acc

    def eval_epoch(self, pipeline, epoch: int, split: str = "val") -> float:
        correct = total = 0
        for batch in pipeline.batches(split, epoch):
            m = self._eval_fn(self.weights, batch)
            correct += int(m["correct"])
            total += int(m["count"])
        acc = correct / max(total, 1)
        if split == "val":
            self.log.val_acc.append(acc)
        return acc

    def inference_params(self) -> Dict[str, jax.Array]:
        """Current weights + alpha as one pytree — what a serving engine
        (``repro.serve.BatchedEngine.from_learner``) snapshots."""
        return merge_trainable({"alpha": self.alpha}, self.weights)

    def fit(self, pipeline, verbose: bool = False) -> EpochLog:
        for epoch in range(self.ctrl.num_epochs):
            tr = self.train_epoch(pipeline, epoch)
            va = (
                self.eval_epoch(pipeline, epoch)
                if (epoch + 1) % self.ctrl.eval_every == 0
                else float("nan")
            )
            if verbose:
                print(f"epoch {epoch:4d}  train_acc={tr:.3f}  val_acc={va:.3f}")
        return self.log
