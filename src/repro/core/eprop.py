"""e-prop (eligibility propagation) for the ReckOn RSNN — two execution modes.

e-prop (Bellec et al., Nat. Comm. 2020) is the local-in-space-and-time
learning rule ReckOn implements on chip.  For a LIF recurrent layer with
per-neuron decay ``alpha`` and an LI readout with decay ``kappa``:

  presynaptic trace    eps_i[t]   = alpha * eps_i[t-1] + s_i[t]       (s = input or rec. spike)
  eligibility          e_ij[t]    = h_j[t] * eps_i[t]                 (h = pseudo-derivative)
  filtered eligibility ebar_ij[t] = kappa * ebar_ij[t-1] + e_ij[t]
  learning signal      L_j[t]     = sum_k B_jk * err_k[t]             (B = W_out or random)
  weight update        dW_ij      = - lr * sum_t L_j[t] * ebar_ij[t]

Two modes:

* ``mode="exact"`` — per-synapse filtered eligibility state, updated every
  tick.  This is bit-faithful to ReckOn's datapath (the chip streams
  ``ebar`` words from its trace SRAM each timestep) and supports per-neuron
  ``alpha`` vectors.

* ``mode="factored"`` — the TPU-native re-formulation.  Swapping the order of
  the two sums (update at end-of-sample, as the chip commits anyway)::

      sum_t L_j[t] ebar_ij[t] = sum_s eps_i[s] h_j[s] F_j[s],
      F_j[s] = sum_{t>=s} kappa^{t-s} L_j[t]      (reverse scan)

  turns the per-synapse trace SRAM into **two O(T·H) scans + one MXU
  matmul** ``eps^T (h ⊙ F)``.  Same math (asserted allclose in
  ``tests/test_eprop.py``), ~H× higher arithmetic intensity, and no O(N²)
  trace state — this is the paper's datapath re-blocked for systolic
  hardware.  Requires scalar ``alpha`` (the configuration the paper uses:
  one SPI register drives all "alphas LSBs").

Both modes share the forward LIF/LI dynamics from :mod:`repro.core.neuron`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.neuron import NeuronConfig, lif_step, li_step, pseudo_derivative
from repro.kernels.events import sparse_input_projection


@dataclasses.dataclass(frozen=True)
class EpropConfig:
    mode: str = "factored"          # "exact" | "factored"
    feedback: str = "symmetric"     # "symmetric" (B = W_out) | "random"
    error: str = "softmax"          # "softmax" | "direct"
    target_amplitude: float = 1.0   # for error="direct"
    mask_self_recurrence: bool = True
    infer_window: str = "valid"     # accumulate readout over "valid" | "all" ticks


def readout_error(y: jax.Array, y_star: jax.Array, cfg: EpropConfig) -> jax.Array:
    """Per-tick output error ``err_k[t]`` (before TARGET_VALID masking)."""
    if cfg.error == "softmax":
        return jax.nn.softmax(y, axis=-1) - y_star
    if cfg.error == "direct":
        return y - cfg.target_amplitude * y_star
    raise ValueError(cfg.error)


def _rec_mask(w_rec: jax.Array, cfg: EpropConfig) -> jax.Array:
    if cfg.mask_self_recurrence:
        return 1.0 - jnp.eye(w_rec.shape[0], dtype=w_rec.dtype)
    return jnp.ones_like(w_rec)


def _feedback(params: Dict[str, jax.Array], cfg: EpropConfig) -> jax.Array:
    return params["w_out"] if cfg.feedback == "symmetric" else params["b_fb"]


def _datapath(params: Dict[str, jax.Array], ncfg: NeuronConfig, ecfg: EpropConfig):
    """Resolve the dynamics-side weights + readout error scale per datapath.

    Float mode: weights as-is, matmuls via ``@``, errors straight off ``y``.
    Quantized mode (``ncfg.quant``): weights are snapped to their SRAM codes
    and scaled onto the membrane grid (integer values in float32 — exact),
    matmuls pin ``Precision.HIGHEST`` so the integer accumulations stay
    exact on TPU, and the readout error is evaluated on ``y / threshold``
    (normalised units) so learning-signal magnitudes — and therefore lr /
    clip settings — carry over from the float model.

    Returns ``(w_in, w_rec_masked, w_out, rec_mask, y_scale, dot)``.
    """
    rec_mask = _rec_mask(params["w_rec"], ecfg)
    q = ncfg.quant
    if q is None:
        return (
            params["w_in"], params["w_rec"] * rec_mask, params["w_out"],
            rec_mask, 1.0, lambda a, b: a @ b,
        )
    dot = functools.partial(jnp.dot, precision=jax.lax.Precision.HIGHEST)
    return (
        q.to_membrane(params["w_in"]),
        q.to_membrane(params["w_rec"]) * rec_mask,
        q.to_membrane(params["w_out"]),
        rec_mask,
        1.0 / float(q.threshold),
        dot,
    )


def _input_projection(
    raster: jax.Array, w_in_d: jax.Array, dot,
    sparse_rows: int | None = None,
) -> jax.Array:
    """Hoist the per-tick ``x_t @ w_in`` out of the scan: one
    ``(T·B, n_in) × (n_in, H)`` matmul instead of T rank-B ones.

    The scan body then only does the recurrent/readout matmuls per tick —
    the input projection runs as a single large (XLA-friendly) contraction
    up front.  In quantized mode ``dot`` carries ``Precision.HIGHEST`` and
    every operand is an exact integer in f32, so the result is bit-identical
    to the per-tick form regardless of reduction order.

    ``sparse_rows`` is the event fast path: a static active-row capacity
    (from :func:`repro.kernels.events.suggest_row_capacity`) switches the
    contraction to the row-compacted gather-matmul of
    :func:`repro.kernels.events.sparse_input_projection` — bitwise equal to
    the dense form at any density, just cheaper when most ``(tick, sample)``
    rows are quiet.
    """
    T, B, n_in = raster.shape
    if sparse_rows is not None and sparse_rows < T * B:
        proj, _ = sparse_input_projection(
            raster, w_in_d, capacity=int(sparse_rows), dot=dot
        )
        return proj
    return dot(raster.reshape(T * B, n_in), w_in_d).reshape(T, B, -1)


def _spike_rate(n_spk: jax.Array, valid: jax.Array, n_hid: int) -> jax.Array:
    """Valid-masked spike rate: spikes inside the TARGET_VALID window per
    valid tick-neuron — invariant to tick padding, identical across
    backends (regression-tested in ``tests/test_fused_kernels.py``)."""
    return jnp.sum(n_spk) / (jnp.maximum(valid.sum(), 1.0) * n_hid)


# ---------------------------------------------------------------------------
# exact mode — per-synapse trace SRAM, tick-by-tick (faithful)
# ---------------------------------------------------------------------------


def run_sample_exact(
    params: Dict[str, jax.Array],
    raster: jax.Array,       # (T, B, N_in) {0,1}
    y_star: jax.Array,       # (B, N_out) one-hot
    valid: jax.Array,        # (T, B) TARGET_VALID mask
    ncfg: NeuronConfig,
    ecfg: EpropConfig,
    sparse_rows: int | None = None,
) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """Run one sample, returning (raw weight-update sums, metrics).

    The returned ``dw`` are the *positive-gradient* sums ``sum_t L e``;
    callers apply ``w -= lr * dw`` (see :mod:`repro.optim.eprop_opt`).
    """
    T, B, n_in = raster.shape
    H = params["w_rec"].shape[0]
    n_out = params["w_out"].shape[1]
    dtype = params["w_in"].dtype

    alpha = jnp.broadcast_to(jnp.asarray(params["alpha"], dtype), (H,))
    kappa = jnp.asarray(ncfg.kappa, dtype)
    w_in_d, w_rec_d, w_out_d, rec_mask, y_scale, dot = _datapath(params, ncfg, ecfg)
    b_fb = _feedback(params, ecfg)

    in_cur = _input_projection(raster, w_in_d, dot, sparse_rows)

    def tick(carry, inp):
        (v, z, y, eps_in, eps_rec, ebar_in, ebar_rec, zbar,
         dw_in, dw_rec, dw_out, acc_y, n_spk) = carry
        x_t, in_cur_t, valid_t = inp

        current = in_cur_t + dot(z, w_rec_d)
        v_new, z_new, v_pre = lif_step(v, current, alpha, ncfg)
        y_new = li_step(y, dot(z_new, w_out_d), kappa, ncfg)

        h = pseudo_derivative(v_pre, ncfg)                       # (B, H)
        eps_in = alpha[None, None, :] * eps_in + x_t[:, :, None]   # (B, N_in, H)
        eps_rec = alpha[None, None, :] * eps_rec + z[:, :, None]   # (B, H, H)
        ebar_in = kappa * ebar_in + h[:, None, :] * eps_in
        ebar_rec = kappa * ebar_rec + h[:, None, :] * eps_rec
        zbar = kappa * zbar + z_new

        # y_scale is 1.0 in float mode (exact identity multiply)
        err = readout_error(y_new * y_scale, y_star, ecfg) * valid_t[:, None]
        L = err @ b_fb.T                                              # (B, H)

        dw_in = dw_in + jnp.einsum("bih,bh->ih", ebar_in, L)
        dw_rec = dw_rec + jnp.einsum("bkh,bh->kh", ebar_rec, L)
        dw_out = dw_out + jnp.einsum("bh,bo->ho", zbar, err)

        w_inf = valid_t[:, None] if ecfg.infer_window == "valid" else 1.0
        acc_y = acc_y + y_new * w_inf
        n_spk = n_spk + (z_new * valid_t[:, None]).sum()

        carry = (v_new, z_new, y_new, eps_in, eps_rec, ebar_in, ebar_rec,
                 zbar, dw_in, dw_rec, dw_out, acc_y, n_spk)
        return carry, None

    z0 = jnp.zeros((B, H), dtype)
    carry0 = (
        jnp.zeros((B, H), dtype), z0, jnp.zeros((B, n_out), dtype),
        jnp.zeros((B, n_in, H), dtype), jnp.zeros((B, H, H), dtype),
        jnp.zeros((B, n_in, H), dtype), jnp.zeros((B, H, H), dtype),
        jnp.zeros((B, H), dtype),
        jnp.zeros((n_in, H), dtype), jnp.zeros((H, H), dtype),
        jnp.zeros((H, n_out), dtype),
        jnp.zeros((B, n_out), dtype), jnp.zeros((), dtype),
    )
    carry, _ = jax.lax.scan(tick, carry0, (raster, in_cur, valid))
    (*_, dw_in, dw_rec, dw_out, acc_y, n_spk) = carry

    dw = {"w_in": dw_in, "w_rec": dw_rec * rec_mask, "w_out": dw_out}
    metrics = {
        "acc_y": acc_y,
        "pred": jnp.argmax(acc_y, axis=-1),
        "spike_rate": _spike_rate(n_spk, valid, H),
    }
    return dw, metrics


# ---------------------------------------------------------------------------
# factored mode — scans + MXU matmuls (TPU-native, mathematically identical)
# ---------------------------------------------------------------------------


def forward_traces(
    params: Dict[str, jax.Array],
    raster: jax.Array,      # (T, B, N_in)
    y_star: jax.Array,      # (B, N_out)
    valid: jax.Array,       # (T, B)
    ncfg: NeuronConfig,
    ecfg: EpropConfig,
    sparse_rows: int | None = None,
):
    """Forward pass storing the O(T·H) quantities the factored update needs."""
    T, B, n_in = raster.shape
    H = params["w_rec"].shape[0]
    n_out = params["w_out"].shape[1]
    dtype = params["w_in"].dtype

    alpha = jnp.asarray(params["alpha"], dtype)
    if alpha.ndim != 0:
        raise ValueError(
            "factored e-prop requires scalar alpha (see module doc)"
        )
    kappa = jnp.asarray(ncfg.kappa, dtype)
    w_in_d, w_rec_d, w_out_d, _, y_scale, dot = _datapath(params, ncfg, ecfg)

    in_cur = _input_projection(raster, w_in_d, dot, sparse_rows)

    def tick(carry, inp):
        v, z, y, xbar, pbar, zbar = carry
        x_t, in_cur_t, valid_t = inp
        current = in_cur_t + dot(z, w_rec_d)
        v_new, z_new, v_pre = lif_step(v, current, alpha, ncfg)
        y_new = li_step(y, dot(z_new, w_out_d), kappa, ncfg)
        h = pseudo_derivative(v_pre, ncfg)
        xbar = alpha * xbar + x_t        # alpha-filtered input trace   (B, N_in)
        pbar = alpha * pbar + z          # alpha-filtered presyn spikes (B, H)
        zbar = kappa * zbar + z_new      # kappa-filtered spikes        (B, H)
        err = readout_error(y_new * y_scale, y_star, ecfg) * valid_t[:, None]
        w_inf = valid_t[:, None] if ecfg.infer_window == "valid" else jnp.ones_like(valid_t)[:, None]
        outs = (h, xbar, pbar, zbar, err, y_new * w_inf,
                (z_new * valid_t[:, None]).sum())
        return (v_new, z_new, y_new, xbar, pbar, zbar), outs

    carry0 = (
        jnp.zeros((B, H), dtype), jnp.zeros((B, H), dtype),
        jnp.zeros((B, n_out), dtype), jnp.zeros((B, n_in), dtype),
        jnp.zeros((B, H), dtype), jnp.zeros((B, H), dtype),
    )
    _, (h, xbar, pbar, zbar, err, y_inf, n_spk) = jax.lax.scan(
        tick, carry0, (raster, in_cur, valid)
    )
    return h, xbar, pbar, zbar, err, y_inf, n_spk


def factored_update(
    params: Dict[str, jax.Array],
    h: jax.Array,      # (T, B, H)   pseudo-derivatives
    xbar: jax.Array,   # (T, B, N_in) alpha-filtered input traces
    pbar: jax.Array,   # (T, B, H)   alpha-filtered presyn (recurrent) traces
    zbar: jax.Array,   # (T, B, H)   kappa-filtered spikes
    err: jax.Array,    # (T, B, N_out) masked readout errors
    ncfg: NeuronConfig,
    ecfg: EpropConfig,
) -> Dict[str, jax.Array]:
    """End-of-sample update: reverse kappa-scan + three matmuls (MXU-bound)."""
    kappa = jnp.asarray(ncfg.kappa, h.dtype)
    b_fb = _feedback(params, ecfg)
    L = jnp.einsum("tbo,ho->tbh", err, b_fb)            # learning signals

    # F[s] = L[s] + kappa * F[s+1]  — reverse scan over time.
    def rev(carry, l_t):
        f = l_t + kappa * carry
        return f, f

    _, F = jax.lax.scan(rev, jnp.zeros_like(L[0]), L, reverse=True)

    G = h * F                                            # (T, B, H)
    dw_in = jnp.einsum("tbi,tbh->ih", xbar, G)
    dw_rec = jnp.einsum("tbk,tbh->kh", pbar, G)
    dw_out = jnp.einsum("tbh,tbo->ho", zbar, err)
    return {
        "w_in": dw_in,
        "w_rec": dw_rec * _rec_mask(params["w_rec"], ecfg),
        "w_out": dw_out,
    }


def run_sample_factored(
    params: Dict[str, jax.Array],
    raster: jax.Array,
    y_star: jax.Array,
    valid: jax.Array,
    ncfg: NeuronConfig,
    ecfg: EpropConfig,
    sparse_rows: int | None = None,
) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    h, xbar, pbar, zbar, err, y_inf, n_spk = forward_traces(
        params, raster, y_star, valid, ncfg, ecfg, sparse_rows
    )
    dw = factored_update(params, h, xbar, pbar, zbar, err, ncfg, ecfg)
    acc_y = y_inf.sum(axis=0)
    metrics = {
        "acc_y": acc_y,
        "pred": jnp.argmax(acc_y, axis=-1),
        "spike_rate": _spike_rate(n_spk, valid, params["w_rec"].shape[0]),
    }
    return dw, metrics


def run_sample(params, raster, y_star, valid, ncfg: NeuronConfig,
               ecfg: EpropConfig, sparse_rows: int | None = None):
    """Dispatch on ``ecfg.mode``."""
    fn = run_sample_exact if ecfg.mode == "exact" else run_sample_factored
    return fn(params, raster, y_star, valid, ncfg, ecfg, sparse_rows)


# ---------------------------------------------------------------------------
# inference-only forward (no traces) — used for validation/test epochs
# ---------------------------------------------------------------------------


def run_sample_inference(
    params: Dict[str, jax.Array],
    raster: jax.Array,
    valid: jax.Array,
    ncfg: NeuronConfig,
    ecfg: EpropConfig,
    sparse_rows: int | None = None,
) -> Dict[str, jax.Array]:
    T, B, n_in = raster.shape
    H = params["w_rec"].shape[0]
    n_out = params["w_out"].shape[1]
    dtype = params["w_in"].dtype
    alpha = jnp.broadcast_to(jnp.asarray(params["alpha"], dtype), (H,))
    kappa = jnp.asarray(ncfg.kappa, dtype)
    w_in_d, w_rec_d, w_out_d, _, _, dot = _datapath(params, ncfg, ecfg)

    in_cur = _input_projection(raster, w_in_d, dot, sparse_rows)

    def tick(carry, inp):
        v, z, y, acc_y, n_spk = carry
        in_cur_t, valid_t = inp
        current = in_cur_t + dot(z, w_rec_d)
        v_new, z_new, _ = lif_step(v, current, alpha, ncfg)
        y_new = li_step(y, dot(z_new, w_out_d), kappa, ncfg)
        w_inf = valid_t[:, None] if ecfg.infer_window == "valid" else 1.0
        return (v_new, z_new, y_new, acc_y + y_new * w_inf,
                n_spk + (z_new * valid_t[:, None]).sum()), None

    carry0 = (jnp.zeros((B, H), dtype), jnp.zeros((B, H), dtype),
              jnp.zeros((B, n_out), dtype), jnp.zeros((B, n_out), dtype),
              jnp.zeros((), dtype))
    (v, z, y, acc_y, n_spk), _ = jax.lax.scan(tick, carry0, (in_cur, valid))
    return {
        "acc_y": acc_y,
        "pred": jnp.argmax(acc_y, axis=-1),
        "spike_rate": _spike_rate(n_spk, valid, H),
    }


def run_stream_inference(
    params: Dict[str, jax.Array],
    raster: jax.Array,      # (T, B, N_in) — one tick-tile of B sessions
    live: jax.Array,        # (T, B) dynamics mask: 0 freezes a session's state
    valid: jax.Array,       # (T, B) TARGET_VALID readout-accumulation mask
    state: Dict[str, jax.Array],   # {"v","z","y","acc_y","n_spk"} carries
    ncfg: NeuronConfig,
    ecfg: EpropConfig,
    sparse_rows: int | None = None,
) -> Dict[str, jax.Array]:
    """Carry-in / carry-out inference over one streaming tick-tile.

    The session-resident twin of :func:`run_sample_inference`: instead of
    starting every sample from zero state, the LIF membranes ``v``, previous
    spikes ``z``, LI readout ``y`` and the running readout accumulator
    ``acc_y`` / spike counter ``n_spk`` are *inputs*, and their end-of-tile
    values are returned — so an unbounded per-session AER stream can be fed
    through fixed-shape ``(T, B)`` tiles chunk by chunk.

    ``live`` gates the *dynamics*: on a tick where ``live == 0`` the
    session's state is frozen exactly (``jnp.where`` select — no leak, no
    integration), which is what makes ragged per-session chunk lengths
    packable into one rectangular tile without perturbing slower sessions.
    ``valid`` gates readout *accumulation* only (``valid ⊆ live`` by
    construction in the host packer).  Chunking is carry-exact: feeding the
    same ticks in any chunking yields bit-identical final state on this
    backend (asserted in ``tests/test_streaming.py``, bit-true against the
    integer golden reference in quantized mode).
    """
    T, B, n_in = raster.shape
    H = params["w_rec"].shape[0]
    dtype = params["w_in"].dtype
    alpha = jnp.broadcast_to(jnp.asarray(params["alpha"], dtype), (H,))
    kappa = jnp.asarray(ncfg.kappa, dtype)
    w_in_d, w_rec_d, w_out_d, _, _, dot = _datapath(params, ncfg, ecfg)

    in_cur = _input_projection(raster, w_in_d, dot, sparse_rows)
    acc_all = ecfg.infer_window == "all"

    def tick(carry, inp):
        v, z, y, acc_y, n_spk = carry
        in_cur_t, live_t, valid_t = inp
        current = in_cur_t + dot(z, w_rec_d)
        v_new, z_new, _ = lif_step(v, current, alpha, ncfg)
        y_new = li_step(y, dot(z_new, w_out_d), kappa, ncfg)
        keep = live_t[:, None] > 0
        v = jnp.where(keep, v_new, v)
        z = jnp.where(keep, z_new, z)
        y = jnp.where(keep, y_new, y)
        w_acc = (live_t if acc_all else valid_t)[:, None]
        acc_y = acc_y + y_new * w_acc
        n_spk = n_spk + (z_new * valid_t[:, None]).sum(axis=1, keepdims=True)
        return (v, z, y, acc_y, n_spk), None

    carry0 = (
        jnp.asarray(state["v"], dtype), jnp.asarray(state["z"], dtype),
        jnp.asarray(state["y"], dtype), jnp.asarray(state["acc_y"], dtype),
        jnp.asarray(state["n_spk"], dtype),
    )
    (v, z, y, acc_y, n_spk), _ = jax.lax.scan(
        tick, carry0, (in_cur, live, valid)
    )
    return {"v": v, "z": z, "y": y, "acc_y": acc_y, "n_spk": n_spk}


def forward_dynamics(
    params: Dict[str, jax.Array],
    raster: jax.Array,      # (T, B, N_in)
    ncfg: NeuronConfig,
    ecfg: EpropConfig,
    sparse_rows: int | None = None,
) -> Dict[str, jax.Array]:
    """Forward pass emitting the full state trajectories — the probe the
    bit-true golden-reference equivalence tests drive.

    Returns ``{"v": post-reset membrane (T, B, H), "v_pre": pre-reset
    membrane, "z": spikes, "y": readout (T, B, O)}``.  In quantized mode
    every value is an integer on the membrane grid (carried in float32).
    """
    T, B, n_in = raster.shape
    H = params["w_rec"].shape[0]
    n_out = params["w_out"].shape[1]
    dtype = params["w_in"].dtype
    alpha = jnp.broadcast_to(jnp.asarray(params["alpha"], dtype), (H,))
    kappa = jnp.asarray(ncfg.kappa, dtype)
    w_in_d, w_rec_d, w_out_d, _, _, dot = _datapath(params, ncfg, ecfg)

    in_cur = _input_projection(raster, w_in_d, dot, sparse_rows)

    def tick(carry, in_cur_t):
        v, z, y = carry
        current = in_cur_t + dot(z, w_rec_d)
        v_new, z_new, v_pre = lif_step(v, current, alpha, ncfg)
        y_new = li_step(y, dot(z_new, w_out_d), kappa, ncfg)
        return (v_new, z_new, y_new), (v_new, v_pre, z_new, y_new)

    carry0 = (jnp.zeros((B, H), dtype), jnp.zeros((B, H), dtype),
              jnp.zeros((B, n_out), dtype))
    _, (v, v_pre, z, y) = jax.lax.scan(tick, carry0, in_cur)
    return {"v": v, "v_pre": v_pre, "z": z, "y": y}
