"""Pure-jnp oracles for every Pallas kernel (the ``ref`` side of the
per-kernel allclose tests and shape/dtype sweeps)."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# rsnn_step: full-sample RSNN forward with e-prop trace filtering
# ---------------------------------------------------------------------------


def rsnn_forward_ref(
    raster: jax.Array,   # (T, B, N_in) {0,1}
    w_in: jax.Array,     # (N_in, H)
    w_rec: jax.Array,    # (H, H) — self-recurrence already masked
    w_out: jax.Array,    # (H, O)
    alpha: float,
    kappa: float,
    v_th: float,
    *,
    reset: str = "sub",
    boxcar_width: float = 0.5,
) -> Dict[str, jax.Array]:
    """Reference for the fused RSNN-step kernel (float datapath).

    Returns per-tick tensors: spikes z (T,B,H), pseudo-derivative h,
    alpha-filtered input trace xbar (T,B,N_in), alpha-filtered presynaptic
    recurrent trace pbar (T,B,H), kappa-filtered spikes zbar (T,B,H),
    readout y (T,B,O), and post-reset membrane v (T,B,H).  The quantized
    datapath's oracle is the integer golden reference in
    :mod:`repro.core.quant_ref`, not this.
    """
    T, B, n_in = raster.shape
    H = w_rec.shape[0]
    O = w_out.shape[1]
    dt = w_in.dtype

    def tick(carry, x_t):
        v, z, y, xbar, pbar, zbar = carry
        current = x_t @ w_in + z @ w_rec
        v_pre = alpha * v + current
        z_new = (v_pre >= v_th).astype(dt)
        v_new = v_pre - z_new * v_th if reset == "sub" else v_pre * (1 - z_new)
        y_new = kappa * y + z_new @ w_out
        h = (jnp.abs(v_pre - v_th) < boxcar_width * v_th).astype(dt)
        xbar = alpha * xbar + x_t
        pbar = alpha * pbar + z          # presynaptic trace uses z BEFORE update
        zbar = kappa * zbar + z_new
        return (v_new, z_new, y_new, xbar, pbar, zbar), (
            z_new, h, xbar, pbar, zbar, y_new, v_new)

    carry0 = (
        jnp.zeros((B, H), dt), jnp.zeros((B, H), dt), jnp.zeros((B, O), dt),
        jnp.zeros((B, n_in), dt), jnp.zeros((B, H), dt), jnp.zeros((B, H), dt),
    )
    _, (z, h, xbar, pbar, zbar, y, v) = jax.lax.scan(tick, carry0, raster)
    return {"z": z, "h": h, "xbar": xbar, "pbar": pbar, "zbar": zbar, "y": y,
            "v": v}


# ---------------------------------------------------------------------------
# eprop_update: factored end-of-sample weight update
# ---------------------------------------------------------------------------


def eprop_update_ref(
    h: jax.Array,      # (T, B, H)
    xbar: jax.Array,   # (T, B, N_in)
    pbar: jax.Array,   # (T, B, H)
    zbar: jax.Array,   # (T, B, H)
    err: jax.Array,    # (T, B, O) — masked readout errors
    b_fb: jax.Array,   # (H, O)
    kappa: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Reference for the e-prop update kernel: reverse κ-scan + matmuls."""
    L = jnp.einsum("tbo,ho->tbh", err, b_fb)

    def rev(carry, l_t):
        f = l_t + kappa * carry
        return f, f

    _, F = jax.lax.scan(rev, jnp.zeros_like(L[0]), L, reverse=True)
    G = h * F
    dw_in = jnp.einsum("tbi,tbh->ih", xbar, G)
    dw_rec = jnp.einsum("tbk,tbh->kh", pbar, G)
    dw_out = jnp.einsum("tbh,tbo->ho", zbar, err)
    return dw_in, dw_rec, dw_out


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def attention_ref(
    q: jax.Array,      # (B, Sq, H, D)
    k: jax.Array,      # (B, Skv, Hkv, D)
    v: jax.Array,      # (B, Skv, Hkv, D)
    *,
    causal: bool = True,
) -> jax.Array:
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    qr = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qr, k, preferred_element_type=jnp.float32)
    s = s * (D ** -0.5)
    if causal:
        mask = jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, D).astype(q.dtype)
