"""Per-op HBM data-movement accounting for the RSNN kernels.

ReckOn's value proposition is keeping state on-chip so only spikes and
end-of-sample updates cross the memory boundary; the TPU mapping's analog of
that boundary is HBM↔VMEM traffic.  This module is the bookkeeping for it:
analytic bytes-to/from-HBM per ``(T, B)`` tile for every backend op, in both
the split two-kernel formulation and the op-specialized fused kernels.

These counts are what ``benchmarks/bench_kernels.py`` reports and gates on
for CPU CI (where the kernels run interpreted and wall-clock is
meaningless), what the serving engine's ``hbm_bytes_streamed`` stat sums,
and the source of the README performance table.

All streams are f32 (4 bytes/element).  Weights are counted once per tile
(they are VMEM-resident across the whole grid).  Per-tile stream elements:

====================  =========================================  ==============
op / kernel           reads (per tile)                           writes
====================  =========================================  ==============
forward (traces)      raster T·B·N                               z,h,pbar,zbar,
                                                                 v: 5·T·B·H +
                                                                 xbar T·B·N +
                                                                 y T·B·O
eprop_update          h,pbar,zbar 3·T·B·H + xbar T·B·N +         dw: N·H + H² +
                      err T·B·O                                  H·O
train (two-kernel)    forward + err eval (y T·B·O → err          forward writes
                      T·B·O) + eprop_update reads                + err T·B·O +
                                                                 dw
train (fused)         raster 2·T·B·N (phase-2 grid re-touch) +   dw + acc_y B·O
                      valid 2·T·B + y_star B·O                   + n_spk B
inference (streamed)  forward + acc/spike reduce reads           forward writes
                      (y T·B·O + z T·B·H)                        + acc_y B·O
inference (fused)     raster T·B·N + valid T·B                   acc_y B·O +
                                                                 n_spk B
====================  =========================================  ==============

Batch-tiled launches (``grid = (ceil(B/Bt), ·)``, any B) leave the rows
above essentially unchanged: weight blocks and the ``dw`` out-blocks have
constant grid index maps, so both stay VMEM-resident across every batch tile
(one fetch / one writeback per *launch*); the only extra movement is the
zero streams of the last tile's pad rows.  See
:func:`train_fused_tiled_bytes` / :func:`infer_fused_tiled_bytes` (the
as-executed padded counts) and the per-tile :func:`tile_table`.

**Event-driven (``stream="dma"``) variants** are density-parameterized:
the raster never enters the block pipeline — the kernel DMAs only the
*active* ``(batch-tile, tick)`` event blocks (per-block activity bitmap,
scalar-prefetched), so raster bytes scale with the measured block density
(:func:`repro.kernels.events.block_density`), and the fused train kernel
sheds its phase-2 raster re-touch entirely (read once, not twice).  The
``*_dma_tiled_bytes`` formulas below are the as-executed counts at a given
density; :func:`op_table` grows dma rows when a density is passed.

**Roofline helpers** close the loop from analytic bytes to wall-clock:
:func:`device_roofline` resolves the running device's peak HBM bandwidth
(TPU generations from ``launch/mesh.py`` constants; a coarse DDR figure as
the CPU fallback, flagged unmeasured), and :func:`bandwidth_table` turns
``(bytes, seconds)`` benchmark records into achieved-GB/s versus roofline
rows — the table ``benchmarks/bench_kernels.py`` uploads and
``benchmarks/roofline.py`` tunes ``Bt``/``vmem_budget`` against.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional

# One element-size / weight-count / tile-size source with the VMEM budget
# helpers (the batch-tiled grids derive their tile rows from the same place).
from repro.kernels.rsnn_step import DEFAULT_VMEM_BUDGET
from repro.kernels.rsnn_step import F32_BYTES as _F32
from repro.kernels.rsnn_step import (
    cdiv as _cdiv,
)
from repro.kernels.rsnn_step import (
    max_forward_tile,
    max_fused_train_tile,
    weight_elems,
)


def _weights(n_in: int, n_hid: int, n_out: int, feedback: bool = False) -> int:
    w = weight_elems(n_in, n_hid, n_out)
    if feedback:
        w += n_hid * n_out
    return w


def _dw(n_in: int, n_hid: int, n_out: int) -> int:
    return weight_elems(n_in, n_hid, n_out)


def forward_traces_bytes(T: int, B: int, n_in: int, n_hid: int, n_out: int) -> int:
    """Trace-streaming forward (``rsnn_forward``): reads the raster +
    weights, writes seven per-tick streams (z, h, xbar, pbar, zbar, y, v)."""
    reads = T * B * n_in + _weights(n_in, n_hid, n_out)
    writes = T * B * (5 * n_hid + n_in + n_out)
    return _F32 * (reads + writes)


def eprop_update_bytes(T: int, B: int, n_in: int, n_hid: int, n_out: int) -> int:
    """Split reverse pass (``eprop_update``): re-reads five trace streams,
    writes the three ``dw`` matrices."""
    reads = T * B * (3 * n_hid + n_in + n_out) + n_hid * n_out
    writes = _dw(n_in, n_hid, n_out)
    return _F32 * (reads + writes)


def train_two_kernel_bytes(T: int, B: int, n_in: int, n_hid: int, n_out: int) -> int:
    """The pre-specialization train path: trace-streaming forward, an XLA
    pass evaluating ``err`` from the streamed ``y`` (read T·B·O, write
    T·B·O), then the split reverse pass re-reading the traces."""
    err_eval = _F32 * (2 * T * B * n_out + B * n_out + T * B)  # y→err + y*/valid
    return (
        forward_traces_bytes(T, B, n_in, n_hid, n_out)
        + err_eval
        + eprop_update_bytes(T, B, n_in, n_hid, n_out)
    )


def train_fused_bytes(T: int, B: int, n_in: int, n_hid: int, n_out: int) -> int:
    """Fused train kernel (``rsnn_train``): the raster/valid tick blocks are
    touched twice (the phase-2 grid re-visits them, contents unused), targets
    and weights once; the only writes are the ``dw`` matrices, the readout
    accumulator and the spike counts — no per-tick stream ever reaches HBM."""
    reads = (
        2 * T * B * n_in                      # raster, both phases
        + 2 * T * B                           # valid, both phases
        + B * n_out                           # y_star
        + _weights(n_in, n_hid, n_out, feedback=True)
    )
    writes = _dw(n_in, n_hid, n_out) + B * n_out + B
    return _F32 * (reads + writes)


def infer_streamed_bytes(T: int, B: int, n_in: int, n_hid: int, n_out: int) -> int:
    """The pre-specialization serving path: trace-streaming forward, then an
    XLA reduction re-reading ``y`` (valid-weighted accumulate) and ``z``
    (spike count) to produce the ``(B, O)`` logits."""
    reduce_reads = _F32 * (T * B * n_out + T * B * n_hid + 2 * T * B)
    return (
        forward_traces_bytes(T, B, n_in, n_hid, n_out)
        + reduce_reads
        + _F32 * B * n_out
    )


def infer_fused_bytes(T: int, B: int, n_in: int, n_hid: int, n_out: int) -> int:
    """Inference-specialized kernel (``rsnn_infer``): reads the raster, the
    valid mask and the weights; writes one ``(B, O)`` tile + ``(B,)``
    counts."""
    reads = T * B * n_in + T * B + _weights(n_in, n_hid, n_out)
    writes = B * n_out + B
    return _F32 * (reads + writes)


def train_fused_tiled_bytes(
    T: int,
    B: int,
    n_in: int,
    n_hid: int,
    n_out: int,
    batch_tile: Optional[int] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> int:
    """Batch-tiled fused train launch (``grid=(ceil(B/Bt), 2T)``): per-tick
    streams are per-tile identical to the single-tile fused kernel, and the
    weight blocks / ``dw`` out-blocks have constant index maps, so they are
    fetched / written back exactly once per *launch* (Pallas keeps an
    unchanged block VMEM-resident across grid steps).  The only extra HBM
    movement tiling introduces is the zero streams of the last tile's pad
    rows (``bp - B`` rows)."""
    bt = batch_tile or max_fused_train_tile(T, n_in, n_hid, n_out, vmem_budget)
    bt = max(1, min(bt, B))
    bp = _cdiv(B, bt) * bt   # pad rows stream zeros but still stream
    reads = (
        2 * T * bp * n_in + 2 * T * bp + bp * n_out
        + _weights(n_in, n_hid, n_out, feedback=True)
    )
    writes = _dw(n_in, n_hid, n_out) + bp * n_out + bp
    return _F32 * (reads + writes)


def infer_fused_tiled_bytes(
    T: int,
    B: int,
    n_in: int,
    n_hid: int,
    n_out: int,
    batch_tile: Optional[int] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> int:
    """Batch-tiled inference launch (``grid=(ceil(B/Bt), T)``): identical to
    the single-tile streams up to the pad rows of the last tile (weights
    stay VMEM-resident across the whole grid — constant index map)."""
    bt = batch_tile or max_forward_tile(n_in, n_hid, n_out, vmem_budget)
    bt = max(1, min(bt, B))
    bp = _cdiv(B, bt) * bt
    reads = T * bp * n_in + T * bp + _weights(n_in, n_hid, n_out)
    writes = bp * n_out + bp
    return _F32 * (reads + writes)


def stream_step_tiled_bytes(
    T: int,
    B: int,
    n_in: int,
    n_hid: int,
    n_out: int,
    batch_tile: Optional[int] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> int:
    """Batch-tiled session-step launch (``rsnn_step_sessions``): the
    inference-fused streams plus one extra ``live`` mask stream and the
    carry round-trip — ``(2H + 2O + 1)`` state elements per session read at
    tile start and written back at tile end (the gather/scatter against the
    device-resident session pool)."""
    bt = batch_tile or max_forward_tile(n_in, n_hid, n_out, vmem_budget)
    bt = max(1, min(bt, B))
    bp = _cdiv(B, bt) * bt
    state = bp * (2 * n_hid + 2 * n_out + 1)
    reads = 2 * T * bp + T * bp * n_in + state + _weights(n_in, n_hid, n_out)
    writes = state
    return _F32 * (reads + writes)


# ---------------------------------------------------------------------------
# event-driven (stream="dma") as-executed byte formulas — density-parameterized
# ---------------------------------------------------------------------------


def _dma_tile(B: int, T: int, bt: int) -> tuple:
    """``(bp, nb, bitmap_bytes)`` shared by the dma formulas: padded rows,
    tile count, and the int32 activity bitmap's own stream (one word per
    ``(tile, tick)`` block — the scalar-prefetch argument)."""
    bp = _cdiv(B, bt) * bt
    nb = bp // bt
    return bp, nb, 4 * nb * T


def _active_blocks(nb: int, T: int, block_density: float) -> int:
    """As-executed active block count at a measured block density — rounded
    up (a partially quiet launch never moves less than its active blocks)."""
    return min(nb * T, int(math.ceil(float(block_density) * nb * T)))


def infer_dma_tiled_bytes(
    T: int,
    B: int,
    n_in: int,
    n_hid: int,
    n_out: int,
    block_density: float = 1.0,
    batch_tile: Optional[int] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> int:
    """Event-streaming inference launch (``rsnn_infer(stream="dma")``):
    only the *active* event blocks are DMA'd from HBM (quiet ticks are
    skipped via the bitmap), plus the bitmap itself and the valid mask;
    weights and the ``(B, O)`` outputs as in the blocked variant."""
    bt = batch_tile or max_forward_tile(n_in, n_hid, n_out, vmem_budget)
    bt = max(1, min(bt, B))
    bp, nb, bitmap = _dma_tile(B, T, bt)
    active = _active_blocks(nb, T, block_density)
    reads = _F32 * (
        active * bt * n_in + T * bp + _weights(n_in, n_hid, n_out)
    ) + bitmap
    writes = _F32 * (bp * n_out + bp)
    return reads + writes


def train_dma_tiled_bytes(
    T: int,
    B: int,
    n_in: int,
    n_hid: int,
    n_out: int,
    block_density: float = 1.0,
    batch_tile: Optional[int] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> int:
    """Event-streaming fused train launch (``rsnn_train(stream="dma")``):
    active event blocks are DMA'd **once** (the blocked variant's phase-2
    grid re-touch is gone), the valid mask is pinned to one block across
    phase 2 (fetched once, not twice), plus targets, weights + feedback and
    the bitmap; writes unchanged (``dw`` + readout accumulator + counts)."""
    bt = batch_tile or max_fused_train_tile(T, n_in, n_hid, n_out, vmem_budget)
    bt = max(1, min(bt, B))
    bp, nb, bitmap = _dma_tile(B, T, bt)
    active = _active_blocks(nb, T, block_density)
    reads = _F32 * (
        active * bt * n_in + T * bp + bp * n_out
        + _weights(n_in, n_hid, n_out, feedback=True)
    ) + bitmap
    writes = _F32 * (_dw(n_in, n_hid, n_out) + bp * n_out + bp)
    return reads + writes


def stream_step_dma_tiled_bytes(
    T: int,
    B: int,
    n_in: int,
    n_hid: int,
    n_out: int,
    block_density: float = 1.0,
    batch_tile: Optional[int] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> int:
    """Event-streaming session-step launch
    (``rsnn_step_sessions(stream="dma")``): the dma inference streams plus
    the ``live`` mask and the carry round-trip of the session pool."""
    bt = batch_tile or max_forward_tile(n_in, n_hid, n_out, vmem_budget)
    bt = max(1, min(bt, B))
    bp, nb, bitmap = _dma_tile(B, T, bt)
    active = _active_blocks(nb, T, block_density)
    state = bp * (2 * n_hid + 2 * n_out + 1)
    reads = _F32 * (
        active * bt * n_in + 2 * T * bp + state
        + _weights(n_in, n_hid, n_out)
    ) + bitmap
    writes = _F32 * state
    return reads + writes


def sparse_projection_bytes(
    T: int, B: int, n_in: int, n_hid: int, capacity: int
) -> int:
    """XLA-side row-compacted input projection
    (:func:`repro.kernels.events.sparse_input_projection`): one full-raster
    activity scan, the gathered ``(capacity, N)`` row buffer round-trip, the
    weight block, and the scattered ``(T·B, H)`` projection write.  Honest
    accounting — the *byte* total is close to the dense projection's (the
    output write dominates); what compaction cuts is the matmul FLOPs,
    ``T·B·N·H → capacity·N·H`` (see :func:`projection_flops`)."""
    cap = min(capacity, T * B)
    reads = T * B * n_in + 2 * cap * n_in + n_in * n_hid
    writes = cap * n_in + T * B * n_hid
    return _F32 * (reads + writes)


def projection_flops(
    T: int, B: int, n_in: int, n_hid: int, capacity: Optional[int] = None
) -> int:
    """MACs×2 of the input projection — dense ``(T·B, N) @ (N, H)``, or the
    compacted ``(capacity, N) @ (N, H)`` when a row capacity is given."""
    rows = T * B if capacity is None else min(capacity, T * B)
    return 2 * rows * n_in * n_hid


# ---------------------------------------------------------------------------
# roofline: achieved bandwidth vs device peak
# ---------------------------------------------------------------------------

# Peak HBM bandwidth / peak dense FLOP/s per chip generation, keyed by
# `jax.devices()[0].device_kind` prefix.  The v5e row re-uses the
# launch/mesh.py constants (single source); other rows are public figures.
_DEVICE_ROOFLINES = {
    "TPU v5 lite": None,   # filled from launch.mesh below (v5e)
    "TPU v5e": None,
    "TPU v4": (1.2e12, 275e12),
    "TPU v5p": (2.8e12, 459e12),
    "TPU v6": (1.6e12, 918e12),
}

# Coarse DDR figure for hosts without an accelerator: wall-clock there is
# interpret-mode and meaningless, so rows are flagged unmeasured and CI
# gates on analytic byte ratios only (same policy as the serve gate).
_CPU_FALLBACK_BW = 40e9


def device_roofline(device=None) -> Dict[str, object]:
    """Resolve the running device's roofline constants.

    Returns ``{"kind", "hbm_bw", "peak_flops", "measured"}`` —
    ``measured=False`` means wall-clock on this device says nothing about
    kernel bandwidth (CPU interpret mode) and achieved-vs-roofline columns
    are recorded for trend only, never gated."""
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

    if device is None:
        import jax

        device = jax.devices()[0]
    kind = getattr(device, "device_kind", str(device))
    for prefix, consts in _DEVICE_ROOFLINES.items():
        if kind.lower().startswith(prefix.lower()):
            hbm, flops = consts or (HBM_BW, PEAK_FLOPS_BF16)
            return {"kind": kind, "hbm_bw": hbm, "peak_flops": flops,
                    "measured": True}
    return {"kind": kind, "hbm_bw": _CPU_FALLBACK_BW, "peak_flops": 0.0,
            "measured": False}


def achieved_bandwidth(bytes_moved: int, seconds: float) -> float:
    """Bytes/s actually sustained by one timed launch."""
    return bytes_moved / seconds if seconds > 0 else 0.0


def bandwidth_table(
    records: List[Dict[str, object]],
    roofline: Optional[Dict[str, object]] = None,
) -> List[Dict[str, object]]:
    """The achieved-vs-roofline table: one row per benchmark record.

    Each record needs ``{"op", "bytes", "seconds"}`` (extra keys pass
    through); rows gain ``achieved_gbps``, ``roofline_gbps`` and
    ``roofline_frac`` — the fraction of device peak the launch sustained.
    On unmeasured devices (CPU interpret mode) ``roofline_frac`` is None.
    """
    roofline = roofline or device_roofline()
    peak = float(roofline["hbm_bw"])
    out = []
    for rec in records:
        bw = achieved_bandwidth(int(rec["bytes"]), float(rec["seconds"]))
        row = dict(rec)
        row["achieved_gbps"] = bw / 1e9
        row["roofline_gbps"] = peak / 1e9
        row["roofline_frac"] = (bw / peak) if roofline["measured"] else None
        out.append(row)
    return out


def op_table(
    T: int,
    B: int,
    n_in: int,
    n_hid: int,
    n_out: int,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    density: Optional[float] = None,
) -> Dict[str, int]:
    """The full before/after data-movement table for one launch shape.

    ``train_fused`` / ``infer_fused`` are the *as-executed* batch-tiled
    numbers (tile rows derived from ``vmem_budget``); when the whole batch
    fits one tile they coincide with the single-tile formulas above.
    Passing a measured per-(tile, tick) **block** ``density`` adds the
    event-driven rows (``train_dma`` / ``infer_dma``) at that as-executed
    density."""
    args = (T, B, n_in, n_hid, n_out)
    table = {
        "forward_traces": forward_traces_bytes(*args),
        "eprop_update": eprop_update_bytes(*args),
        "train_two_kernel": train_two_kernel_bytes(*args),
        "train_fused": train_fused_tiled_bytes(*args, vmem_budget=vmem_budget),
        "infer_streamed": infer_streamed_bytes(*args),
        "infer_fused": infer_fused_tiled_bytes(*args, vmem_budget=vmem_budget),
    }
    if density is not None:
        table["train_dma"] = train_dma_tiled_bytes(
            *args, block_density=density, vmem_budget=vmem_budget
        )
        table["infer_dma"] = infer_dma_tiled_bytes(
            *args, block_density=density, vmem_budget=vmem_budget
        )
    return table


def tile_table(
    T: int,
    B: int,
    n_in: int,
    n_hid: int,
    n_out: int,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> Dict[str, int]:
    """Per-tile sizing companion to :func:`op_table`: the derived tile rows,
    tile counts and per-tile bytes of the batch-tiled fused kernels."""
    bt_train = max_fused_train_tile(T, n_in, n_hid, n_out, vmem_budget)
    bt_infer = max_forward_tile(n_in, n_hid, n_out, vmem_budget)
    bt_train = max(1, min(bt_train, B))
    bt_infer = max(1, min(bt_infer, B))
    return {
        "train_tile_rows": bt_train,
        "train_tiles": _cdiv(B, bt_train),
        "train_bytes_per_tile": train_fused_bytes(T, bt_train, n_in, n_hid, n_out),
        "infer_tile_rows": bt_infer,
        "infer_tiles": _cdiv(B, bt_infer),
        "infer_bytes_per_tile": infer_fused_bytes(T, bt_infer, n_in, n_hid, n_out),
    }
