"""Event-driven sparsity utilities — the software analogue of AER sparsity.

ReckOn (and FeNN-DMA / SNAP-V around it) win on silicon because AER ticks
are mostly empty: Braille runs at ~2-5% per-(tick, channel) event density,
so an event-driven datapath moves and multiplies a small fraction of what a
dense one does.  This module is the TPU-mapping's bookkeeping for that
sparsity; the three consumers are

* the **scan backend's** sparse input pre-projection
  (:func:`sparse_input_projection`): gather the nonzero ``(tick, sample)``
  rows of the raster — the rows the nonzero ``(tick, sample, channel)``
  event triples land in — matmul only those against ``w_in``, and scatter
  the results back.  Row dot-products are independent, so compacting rows
  changes *which* rows are computed, never *how* — the result is **bitwise
  identical** to the dense ``(T·B, N) @ (N, H)`` projection in both float
  and quantized modes (asserted in ``tests/test_sparsity.py``).  A
  ``lax.cond`` falls back to the dense matmul in-graph when a launch's
  active-row count overflows the static capacity, so dispatch never changes
  results at any density.
* the **kernel backend's** per-tick activity bitmap
  (:func:`block_bitmap`): one int32 per ``(batch-tile, tick)`` event block,
  scalar-prefetched into the DMA-streaming kernels
  (:mod:`repro.kernels.rsnn_step`) so an all-quiet block is neither fetched
  from HBM nor multiplied through — the in-kernel tick-skip.
* the **dispatch policy** (:func:`resolve_sparsity`): densities at or below
  :data:`SPARSE_DENSITY_THRESHOLD` take the event path, denser inputs stay
  on the dense kernels — selected per backend op in
  :class:`repro.core.backend.ExecutionBackend` from the measured dataset
  density (``data.pipeline.event_density``), never guessed.

Every helper here is shape-static and jit-safe; density *measurement* from
AER word buffers lives host-side in :func:`repro.data.pipeline.event_density`.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rsnn_step import cdiv

# Event densities at or below this fraction take the sparse/event path under
# "auto" dispatch; above it the dense matmul wins (compaction overhead >
# skipped work).  The synthetic Braille surrogate measures ~0.12, cue ~0.07
# (the paper's real Braille recordings run ~0.02-0.05) — all well below.
SPARSE_DENSITY_THRESHOLD = 0.25

# Static row-capacity headroom over the expected active-row count: absorbs
# per-batch density fluctuation without tripping the in-graph dense fallback.
DEFAULT_CAPACITY_MARGIN = 1.5


def raster_density(raster: jax.Array) -> jax.Array:
    """Fraction of nonzero ``(tick, sample, channel)`` entries — the event
    density of one decoded tile (same definition the data layer measures
    from AER words)."""
    return (raster != 0).mean()


def row_density(density: float, n_in: int) -> float:
    """Expected fraction of *active* ``(tick, sample)`` rows at i.i.d.
    per-channel event density ``density``: ``1 - (1 - d)^n_in`` — the
    quantity the row-compacted projection's work actually scales with."""
    return float(1.0 - (1.0 - float(density)) ** int(n_in))


def block_density(density: float, rows: int, n_in: int) -> float:
    """Expected fraction of *active* ``(batch-tile, tick)`` event blocks of
    ``rows`` samples — what the DMA-streamed kernels' HBM fetch scales with.
    Collapses to :func:`row_density` at ``rows == 1`` (the edge single-stream
    operating point, where tick-skip bites hardest)."""
    return float(1.0 - (1.0 - float(density)) ** (int(rows) * int(n_in)))


def suggest_row_capacity(
    T: int,
    B: int,
    density: float,
    margin: float = DEFAULT_CAPACITY_MARGIN,
    n_in: Optional[int] = None,
) -> int:
    """Static active-row capacity for :func:`sparse_input_projection`.

    ``density`` is per-channel when ``n_in`` is given (converted via
    :func:`row_density`), else already per-row.  Clamped to ``[64, T·B]``;
    the margin absorbs batch-to-batch fluctuation (overflow is *correct*
    either way — the in-graph fallback runs dense — just slower).
    """
    rd = row_density(density, n_in) if n_in is not None else float(density)
    cap = int(T * B * rd * margin) + 64
    return max(64, min(int(T * B), cap))


def row_activity(raster: jax.Array) -> jax.Array:
    """``(T, B)`` bool: which ``(tick, sample)`` rows carry any event."""
    return (raster != 0).any(axis=-1)


def block_bitmap(raster_padded: jax.Array, batch_tile: int) -> jax.Array:
    """Per-``(batch-tile, tick)`` activity bitmap for a *padded* ``(T, Bp,
    N)`` raster — the scalar-prefetch argument of the DMA-streaming kernels.

    Flattened to ``(nb · T,)`` int32 in the kernels' linearized step order
    ``s = b · T + t`` (batch-tile-major, matching their grids), so
    ``bitmap[s]`` answers "does step ``s``'s event block need fetching".
    Pad rows are zero and never activate a block.
    """
    T, b_pad, _ = raster_padded.shape
    nb = cdiv(b_pad, batch_tile)
    blk = raster_padded.reshape(T, nb, batch_tile, -1)
    act = (blk != 0).any(axis=(2, 3))          # (T, nb)
    return act.T.reshape(nb * T).astype(jnp.int32)


def sparse_input_projection(
    raster: jax.Array,     # (T, B, N_in)
    w_in: jax.Array,       # (N_in, H)
    *,
    capacity: int,
    dot=None,
) -> Tuple[jax.Array, jax.Array]:
    """Row-compacted input projection: ``raster @ w_in`` at event cost.

    Gathers the active ``(tick, sample)`` rows (stable order, trash-padded
    to the static ``capacity``), runs one dense ``(capacity, N) @ (N, H)``
    matmul over them, and scatters the products back into a zero ``(T, B,
    H)`` tensor.  Each output row's dot product runs on exactly the same
    operands as in the dense projection (row reductions are independent of
    which other rows share the matmul), so the result is **bitwise equal**
    to ``dot(raster.reshape(T·B, N), w_in)`` — in float *and* quantized
    (integers-in-f32) modes.  Quiet rows contribute exactly ``+0.0``, same
    as their dense all-zero dot.

    Overflow safety: when a launch's active-row count exceeds ``capacity``,
    a ``lax.cond`` runs the dense projection instead — in-graph, no host
    sync, results unchanged (just no savings for that launch).

    Returns ``(proj (T, B, H), n_active ())`` — the count is what the
    traffic accounting and the benches record as the as-executed density.
    """
    if dot is None:
        dot = jnp.matmul
    T, B, n_in = raster.shape
    H = w_in.shape[1]
    flat = raster.reshape(T * B, n_in)
    act = (flat != 0).any(axis=1)
    n_active = act.sum(dtype=jnp.int32)

    def dense(flat):
        return dot(flat, w_in).reshape(T, B, H)

    def sparse(flat):
        # stable gather of active row ids; fill lands on a trash row
        idx = jnp.nonzero(act, size=capacity, fill_value=T * B)[0]
        live = idx < T * B
        rows = jnp.where(
            live[:, None], flat[jnp.minimum(idx, T * B - 1)], 0.0
        )
        proj_rows = dot(rows, w_in)
        out = jnp.zeros((T * B + 1, H), proj_rows.dtype).at[idx].set(proj_rows)
        return out[: T * B].reshape(T, B, H)

    if capacity >= T * B:
        # capacity covers every row — the gather is pure overhead
        return dense(flat), n_active
    proj = jax.lax.cond(n_active > capacity, dense, sparse, flat)
    return proj, n_active


def resolve_sparsity(
    sparsity: Optional[str],
    density: Optional[float],
    threshold: float = SPARSE_DENSITY_THRESHOLD,
) -> str:
    """The one density-aware dispatch rule (used by
    :class:`repro.core.backend.ExecutionBackend`):

    * ``"dense"`` / ``"event"`` — forced;
    * ``"auto"`` / ``None`` — ``"event"`` iff a measured ``density`` is
      known and at most ``threshold``, else ``"dense"`` (no density → no
      guessing: the dense kernels are the safe default).
    """
    if sparsity in ("dense", "event"):
        return sparsity
    if sparsity not in (None, "auto"):
        raise ValueError(f"unknown sparsity mode {sparsity!r}")
    if density is not None and float(density) <= threshold:
        return "event"
    return "dense"
