"""Blocked online-softmax (flash) attention kernel — causal GQA.

Layout: q (B, H, Sq, D), k/v (B, Hkv, Skv, D) — head-major so each grid cell
streams contiguous (block, D) tiles.  Grid = (B·H, Sq/bq, Skv/bk) with the
KV axis innermost: a TPU core walks KV tiles sequentially, carrying the
online-softmax statistics (m, l) and the f32 output accumulator in VMEM
scratch, and writes the normalised tile once per (q-tile) when the last KV
tile finishes.  Causal masking prunes whole tiles above the diagonal with
``pl.when`` (no wasted MXU work on skipped tiles — the tile still iterates
but performs no FLOPs; exact-causal tile scheduling is done at the wrapper
level by clamping the KV grid per q tile).

Block shapes default to (512, 512): tiles are (512·D) ≈ 128 KiB in bf16 at
D=128 — q, k, v, acc together ≲ 1 MiB of VMEM, well inside the ~128 MiB/core
budget, and every matmul dim is a multiple of the 128-lane MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,     # (1, bq, D)
    k_ref,     # (1, bk, D)
    v_ref,     # (1, bk, D)
    o_ref,     # (1, bq, D)
    m_scr,     # VMEM (bq,)
    l_scr,     # VMEM (bq,)
    acc_scr,   # VMEM (bq, D)
    *,
    scale: float,
    causal: bool,
    bq: int,
    bk: int,
    n_kv: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # Tile is fully above the diagonal ⇒ skip all compute.
        run = kj * bk <= qi * bq + (bq - 1)

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0]
        k = k_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                            # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _flush():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,   # (B, H, Sq, D)
    k: jax.Array,   # (B, Hkv, Skv, D)
    v: jax.Array,   # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    G = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    if Sq % bq != 0 or Skv % bk != 0:
        raise ValueError(
            f"sequence lengths ({Sq}, {Skv}) must divide the attention "
            f"block sizes ({bq}, {bk})"
        )
    nq, nk = Sq // bq, Skv // bk
    scale = D ** -0.5

    qr = q.reshape(B * H, Sq, D)
    kr = k.reshape(B * Hkv, Skv, D)
    vr = v.reshape(B * Hkv, Skv, D)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, bq=bq, bk=bk, n_kv=nk
    )
    out = pl.pallas_call(
        kern,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, kj, g=G: (bh // g, kj, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, kj, g=G: (bh // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, D)
