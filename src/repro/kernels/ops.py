"""jit'd public wrappers for the Pallas kernels.

On a TPU backend the kernels run compiled; anywhere else (this container's
CPU) they execute under ``interpret=True`` — the kernel body evaluated in
Python with TPU semantics — which is how the allclose tests validate them.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax

from repro.core.quant import QuantizedMode
from repro.kernels import eprop_update as _eprop
from repro.kernels import flash_attention as _flash
from repro.kernels import rsnn_step as _rsnn


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(
    jax.jit,
    static_argnames=("alpha", "kappa", "v_th", "reset", "boxcar_width", "quant",
                     "vmem_budget", "batch_tile", "stream"),
)
def rsnn_forward(
    raster: jax.Array,
    w_in: jax.Array,
    w_rec: jax.Array,
    w_out: jax.Array,
    *,
    alpha: float,
    kappa: float,
    v_th: float = 1.0,
    reset: str = "sub",
    boxcar_width: float = 0.5,
    quant: Optional[QuantizedMode] = None,   # frozen dataclass: hashable static
    vmem_budget: int = _rsnn.DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
    stream: str = "blocked",
) -> Dict[str, jax.Array]:
    return _rsnn.rsnn_forward(
        raster, w_in, w_rec, w_out,
        alpha=alpha, kappa=kappa, v_th=v_th, reset=reset,
        boxcar_width=boxcar_width, quant=quant, vmem_budget=vmem_budget,
        batch_tile=batch_tile, stream=stream, interpret=_interpret(),
    )


@partial(
    jax.jit,
    static_argnames=("alpha", "kappa", "v_th", "reset", "quant", "infer_window",
                     "vmem_budget", "batch_tile", "stream"),
)
def rsnn_infer(
    raster: jax.Array,
    valid: jax.Array,
    w_in: jax.Array,
    w_rec: jax.Array,
    w_out: jax.Array,
    *,
    alpha: float,
    kappa: float,
    v_th: float = 1.0,
    reset: str = "sub",
    quant: Optional[QuantizedMode] = None,
    infer_window: str = "valid",
    vmem_budget: int = _rsnn.DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
    stream: str = "blocked",
) -> Tuple[jax.Array, jax.Array]:
    """Inference-specialized forward (serving path): batch-tiled grid,
    VMEM-accumulated ``(acc_y, n_spk)``, no per-tick HBM streams.
    ``stream="dma"`` runs the double-buffered event-streaming variant
    (quiet tick blocks neither fetched nor projected; bit-exact)."""
    return _rsnn.rsnn_infer(
        raster, valid, w_in, w_rec, w_out,
        alpha=alpha, kappa=kappa, v_th=v_th, reset=reset, quant=quant,
        infer_window=infer_window, vmem_budget=vmem_budget,
        batch_tile=batch_tile, stream=stream, interpret=_interpret(),
    )


@partial(
    jax.jit,
    static_argnames=("alpha", "kappa", "v_th", "reset", "quant", "infer_window",
                     "vmem_budget", "batch_tile", "stream"),
)
def rsnn_step_sessions(
    raster: jax.Array,
    live: jax.Array,
    valid: jax.Array,
    v0: jax.Array,
    z0: jax.Array,
    y0: jax.Array,
    acc0: jax.Array,
    nspk0: jax.Array,
    w_in: jax.Array,
    w_rec: jax.Array,
    w_out: jax.Array,
    *,
    alpha: float,
    kappa: float,
    v_th: float = 1.0,
    reset: str = "sub",
    quant: Optional[QuantizedMode] = None,
    infer_window: str = "valid",
    vmem_budget: int = _rsnn.DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
    stream: str = "blocked",
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Session-stateful inference tile (streaming serving): carries are
    arguments and results, so the pool gather → step → scatter round-trip
    is chunk-invariant (bit-true in quantized mode)."""
    return _rsnn.rsnn_step_sessions(
        raster, live, valid, v0, z0, y0, acc0, nspk0, w_in, w_rec, w_out,
        alpha=alpha, kappa=kappa, v_th=v_th, reset=reset, quant=quant,
        infer_window=infer_window, vmem_budget=vmem_budget,
        batch_tile=batch_tile, stream=stream, interpret=_interpret(),
    )


@partial(
    jax.jit,
    static_argnames=(
        "alpha", "kappa", "v_th", "reset", "boxcar_width", "quant",
        "error", "target_amplitude", "infer_window", "vmem_budget",
        "batch_tile", "stream",
    ),
)
def rsnn_train(
    raster: jax.Array,
    y_star: jax.Array,
    valid: jax.Array,
    w_in: jax.Array,
    w_rec: jax.Array,
    w_out: jax.Array,
    b_fb: jax.Array,
    *,
    alpha: float,
    kappa: float,
    v_th: float = 1.0,
    reset: str = "sub",
    boxcar_width: float = 0.5,
    quant: Optional[QuantizedMode] = None,
    error: str = "softmax",
    target_amplitude: float = 1.0,
    infer_window: str = "valid",
    vmem_budget: int = _rsnn.DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
    stream: str = "blocked",
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused train op: forward + in-kernel readout error + reverse e-prop in
    one two-phase batch-tiled kernel, traces VMEM-resident per tile; any
    batch size runs (tile rows derived from ``vmem_budget``).
    ``stream="dma"`` double-buffers the event blocks (read once, active
    blocks only) instead of the blocked pipeline's two-phase re-touch."""
    return _eprop.rsnn_train(
        raster, y_star, valid, w_in, w_rec, w_out, b_fb,
        alpha=alpha, kappa=kappa, v_th=v_th, reset=reset,
        boxcar_width=boxcar_width, quant=quant, error=error,
        target_amplitude=target_amplitude, infer_window=infer_window,
        vmem_budget=vmem_budget, batch_tile=batch_tile, stream=stream,
        interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("kappa", "vmem_budget", "batch_tile"))
def eprop_update(
    h: jax.Array,
    xbar: jax.Array,
    pbar: jax.Array,
    zbar: jax.Array,
    err: jax.Array,
    b_fb: jax.Array,
    *,
    kappa: float,
    vmem_budget: int = _rsnn.DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    return _eprop.eprop_update(
        h, xbar, pbar, zbar, err, b_fb, kappa=kappa, vmem_budget=vmem_budget,
        batch_tile=batch_tile, interpret=_interpret()
    )


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    return _flash.flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=_interpret(),
    )
