"""Pallas TPU kernels for the compute hot-spots of the ReckOn datapath:

* ``rsnn_step``     — the fused per-tick LIF/LI update + e-prop trace
                      filtering (ReckOn's neuron-update pipeline, re-blocked
                      for VMEM/MXU);
* ``eprop_update``  — the factored end-of-sample e-prop weight update
                      (reverse κ-scan fused with the trace×signal matmuls);
* ``flash_attention`` — blocked online-softmax GQA attention for the LM
                      substrate's train/prefill path.

``ops.py`` holds the jit'd public wrappers (auto ``interpret=True`` on CPU);
``ref.py`` the pure-jnp oracles every kernel is allclose-tested against.
"""
