"""Training-side kernels: the factored e-prop update and the fused
forward+update train kernel.

Two variants serve the backend's training ops (see the data-movement table
in :mod:`repro.kernels.traffic` / README):

* :func:`eprop_update` — the split-pipeline reverse pass.  Consumes the
  per-tick traces :func:`repro.kernels.rsnn_step.rsnn_forward` streamed to
  HBM; serves the backend's ``eprop_update`` op (and the HBM-streaming
  escape hatch for tick counts whose fused trace scratch exceeds physical
  VMEM — the fused kernel rejects those loudly rather than falling back
  silently).
* :func:`rsnn_train` — the fused ``train`` op.  One batch-tiled
  ``grid=(ceil(B/Bt), 2T)`` program: per batch tile, a forward phase runs
  the tick datapath, evaluates the readout error *in-kernel*
  (``y_star``/``valid`` passed in, quantized ``y/threshold`` normalisation
  applied before the softmax) and stashes the ``h/xbar/pbar/zbar/err``
  traces in VMEM scratch; then a reverse phase folds them through the
  κ-filter into the three ``dw`` accumulators.  The tile rows ``Bt`` are
  derived from the VMEM budget
  (:func:`repro.kernels.rsnn_step.max_fused_train_tile`) so the trace
  scratch always fits — there is no fallback pipeline and no launch-level
  batch cap.  The launch's only HBM writes are the three ``dw`` matrices
  (accumulated across batch tiles directly in the output refs, which stay
  VMEM-resident for the whole grid) plus the ``(B, O)`` readout accumulator
  and ``(B, 1)`` spike counts — the ~7·T·B·H floats of intermediate trace
  traffic of the two-kernel pipeline never leave the core.

The reverse pass computes, over ticks T-1..0,

  L[t]   = err[t] @ B_fbᵀ                    (MXU)
  F[t]   = L[t] + κ·F[t+1]                   (VMEM-carried reverse filter)
  dW_in  = Σ_t xbar[t]ᵀ (h[t]∘F[t])          (MXU, accumulated in VMEM)
  dW_rec = Σ_t pbar[t]ᵀ (h[t]∘F[t])
  dW_out = Σ_t zbar[t]ᵀ err[t]

i.e. the per-synapse eligibility SRAM of the chip becomes three VMEM-resident
accumulator tiles fed by per-tick rank-B matmul updates.

Hardware-equivalence (quantized) mode needs no variant of the reverse pass:
the chip's trace arithmetic is wider than its commit grid, so the quantized
contract keeps e-prop traces float — the forward phase produces the same
float h/xbar/pbar/zbar it produces in quantized runs, with ``err`` evaluated
on the normalised readout (``y / threshold``) and ``b_fb`` in normalised
weight units.  Quantization happens at the *commit*
(:class:`repro.optim.eprop_opt.EpropSGD` accumulate-then-round), exactly as
on chip.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import QuantizedMode
from repro.kernels.rsnn_step import (
    DEFAULT_VMEM_BUDGET,
    PHYSICAL_VMEM_CEILING,
    _block_bitmap,
    _pad_batch_axis,
    _stream_events,
    _tile_batch,
    fused_train_bytes,
    max_forward_tile,
    max_fused_train_tile,
    tick_from_input_current,
    tick_transition,
)


def _flush_dw(b, acc_in_scr, acc_rec_scr, acc_out_scr,
              dw_in_ref, dw_rec_ref, dw_out_ref):
    """Fold one batch tile's VMEM dw accumulators into the output refs.

    The dw out-blocks have a constant index map, so they stay VMEM-resident
    across the whole grid and reach HBM once, after the last tile.
    """
    @pl.when(b == 0)
    def _first():
        dw_in_ref[...] = acc_in_scr[...]
        dw_rec_ref[...] = acc_rec_scr[...]
        dw_out_ref[...] = acc_out_scr[...]

    @pl.when(b > 0)
    def _rest():
        dw_in_ref[...] += acc_in_scr[...]
        dw_rec_ref[...] += acc_rec_scr[...]
        dw_out_ref[...] += acc_out_scr[...]


def _kernel(
    h_ref,        # (1, Bt, H)
    xbar_ref,     # (1, Bt, N_in)
    pbar_ref,     # (1, Bt, H)
    zbar_ref,     # (1, Bt, H)
    err_ref,      # (1, Bt, O)
    b_fb_ref,     # (H, O)
    dw_in_ref,    # (N_in, H) out
    dw_rec_ref,   # (H, H) out
    dw_out_ref,   # (H, O) out
    f_scr,        # VMEM (Bt, H)
    acc_in_scr,   # VMEM (N_in, H)
    acc_rec_scr,  # VMEM (H, H)
    acc_out_scr,  # VMEM (H, O)
    *,
    kappa: float,
    T: int,
):
    b = pl.program_id(0)   # batch tile
    i = pl.program_id(1)   # 0..T-1, visiting ticks T-1..0 via the index map

    @pl.when(i == 0)
    def _init():
        f_scr[...] = jnp.zeros_like(f_scr)
        acc_in_scr[...] = jnp.zeros_like(acc_in_scr)
        acc_rec_scr[...] = jnp.zeros_like(acc_rec_scr)
        acc_out_scr[...] = jnp.zeros_like(acc_out_scr)

    err = err_ref[0]
    L = jnp.dot(err, b_fb_ref[...].T, preferred_element_type=jnp.float32)
    F = L + kappa * f_scr[...]
    G = h_ref[0] * F

    acc_in_scr[...] += jnp.dot(
        xbar_ref[0].T, G, preferred_element_type=jnp.float32
    )
    acc_rec_scr[...] += jnp.dot(
        pbar_ref[0].T, G, preferred_element_type=jnp.float32
    )
    acc_out_scr[...] += jnp.dot(
        zbar_ref[0].T, err, preferred_element_type=jnp.float32
    )
    f_scr[...] = F

    @pl.when(i == T - 1)
    def _flush():
        _flush_dw(b, acc_in_scr, acc_rec_scr, acc_out_scr,
                  dw_in_ref, dw_rec_ref, dw_out_ref)


def eprop_update(
    h: jax.Array,      # (T, B, H)
    xbar: jax.Array,   # (T, B, N_in)
    pbar: jax.Array,   # (T, B, H)
    zbar: jax.Array,   # (T, B, H)
    err: jax.Array,    # (T, B, O)
    b_fb: jax.Array,   # (H, O)
    *,
    kappa: float,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    T, B, H = h.shape
    n_in = xbar.shape[2]
    O = err.shape[2]
    bt, nb, b_pad = _tile_batch(
        B, batch_tile or max_forward_tile(n_in, H, O, vmem_budget)
    )
    # pad rows carry zero traces and zero err -> zero dw contribution
    h, xbar, pbar, zbar, err = (
        _pad_batch_axis(x, 1, b_pad) for x in (h, xbar, pbar, zbar, err)
    )

    rev = lambda cols: pl.BlockSpec(
        (1, bt, cols), lambda b, i: (T - 1 - i, b, 0)
    )
    full = lambda shape: pl.BlockSpec(shape, lambda b, i: tuple(0 for _ in shape))

    kern = functools.partial(_kernel, kappa=float(kappa), T=T)
    dw_in, dw_rec, dw_out = pl.pallas_call(
        kern,
        grid=(nb, T),
        in_specs=[rev(H), rev(n_in), rev(H), rev(H), rev(O), full((H, O))],
        out_specs=[full((n_in, H)), full((H, H)), full((H, O))],
        out_shape=[
            jax.ShapeDtypeStruct((n_in, H), jnp.float32),
            jax.ShapeDtypeStruct((H, H), jnp.float32),
            jax.ShapeDtypeStruct((H, O), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt, H), jnp.float32),
            pltpu.VMEM((n_in, H), jnp.float32),
            pltpu.VMEM((H, H), jnp.float32),
            pltpu.VMEM((H, O), jnp.float32),
        ],
        interpret=interpret,
    )(h, xbar, pbar, zbar, err, b_fb)
    return dw_in, dw_rec, dw_out


# ---------------------------------------------------------------------------
# fused forward + e-prop train kernel (train op)
# ---------------------------------------------------------------------------


def _train_kernel(
    raster_ref,   # (1, B, N_in) — tick (i mod T)'s input spikes
    y_star_ref,   # (B, O) one-hot targets
    valid_ref,    # (1, B) TARGET_VALID mask for tick (i mod T)
    w_in_ref,     # (N_in, H)
    w_rec_ref,    # (H, H)
    w_out_ref,    # (H, O)
    b_fb_ref,     # (H, O) feedback (w_out or random B)
    dw_in_ref,    # (N_in, H) out
    dw_rec_ref,   # (H, H) out
    dw_out_ref,   # (H, O) out
    acc_y_ref,    # (B, O) out — infer-window-weighted readout accumulator
    nspk_ref,     # (B, 1) out — valid-masked per-sample spike counts
    v_scr,        # VMEM (B, H) forward carries …
    z_scr,        # VMEM (B, H)
    y_scr,        # VMEM (B, O)
    xbar_scr,     # VMEM (B, N_in)
    pbar_scr,     # VMEM (B, H)
    zbar_scr,     # VMEM (B, H)
    accy_scr,     # VMEM (B, O)
    nspk_scr,     # VMEM (B, 1)
    h_tr,         # VMEM (T, B, H)    — the on-core "trace SRAM" the
    xbar_tr,      # VMEM (T, B, N_in)   two-kernel pipeline would stream
    pbar_tr,      # VMEM (T, B, H)      through HBM
    zbar_tr,      # VMEM (T, B, H)
    err_tr,       # VMEM (T, B, O)
    f_scr,        # VMEM (B, H) reverse filter carry
    acc_in_scr,   # VMEM (N_in, H)
    acc_rec_scr,  # VMEM (H, H)
    acc_out_scr,  # VMEM (H, O)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    boxcar_width: float,
    quant: Optional[QuantizedMode],
    y_scale: float,
    error_mode: str,
    target_amplitude: float,
    infer_all: bool,
    T: int,
):
    b = pl.program_id(0)   # batch tile
    i = pl.program_id(1)   # 0..2T-1: forward ticks 0..T-1, then T-1..0

    # each batch tile is an independent forward+reverse pass over its rows
    @pl.when(i == 0)
    def _init():
        v_scr[...] = jnp.zeros_like(v_scr)
        z_scr[...] = jnp.zeros_like(z_scr)
        y_scr[...] = jnp.zeros_like(y_scr)
        xbar_scr[...] = jnp.zeros_like(xbar_scr)
        pbar_scr[...] = jnp.zeros_like(pbar_scr)
        zbar_scr[...] = jnp.zeros_like(zbar_scr)
        accy_scr[...] = jnp.zeros_like(accy_scr)
        nspk_scr[...] = jnp.zeros_like(nspk_scr)
        f_scr[...] = jnp.zeros_like(f_scr)
        acc_in_scr[...] = jnp.zeros_like(acc_in_scr)
        acc_rec_scr[...] = jnp.zeros_like(acc_rec_scr)
        acc_out_scr[...] = jnp.zeros_like(acc_out_scr)

    @pl.when(i < T)
    def _forward():
        t = i
        x_t = raster_ref[0]
        valid_t = valid_ref[0]                 # (B,)
        z = z_scr[...]

        v_new, z_new, y_new, h = tick_transition(
            x_t, v_scr[...], z, y_scr[...],
            w_in_ref[...], w_rec_ref[...], w_out_ref[...],
            alpha=alpha, kappa=kappa, v_th=v_th, reset_sub=reset_sub,
            boxcar_width=boxcar_width, quant=quant,
        )
        xbar = alpha * xbar_scr[...] + x_t
        pbar = alpha * pbar_scr[...] + z       # presyn trace: z BEFORE this tick
        zbar = kappa * zbar_scr[...] + z_new

        # readout error in-kernel: normalised units in quantized mode
        # (y_scale = 1/threshold), identity otherwise; masked by the
        # TARGET_VALID window (label_delay is already folded into `valid`).
        y_err = y_new * y_scale
        if error_mode == "softmax":
            err = jax.nn.softmax(y_err, axis=-1) - y_star_ref[...]
        else:
            err = y_err - target_amplitude * y_star_ref[...]
        err = err * valid_t[:, None]

        h_tr[pl.ds(t, 1)] = h[None]
        xbar_tr[pl.ds(t, 1)] = xbar[None]
        pbar_tr[pl.ds(t, 1)] = pbar[None]
        zbar_tr[pl.ds(t, 1)] = zbar[None]
        err_tr[pl.ds(t, 1)] = err[None]

        v_scr[...] = v_new
        z_scr[...] = z_new
        y_scr[...] = y_new
        xbar_scr[...] = xbar
        pbar_scr[...] = pbar
        zbar_scr[...] = zbar

        w_inf = 1.0 if infer_all else valid_t[:, None]
        accy_scr[...] += y_new * w_inf
        nspk_scr[...] += (z_new * valid_t[:, None]).sum(axis=1, keepdims=True)

    @pl.when(i >= T)
    def _backward():
        t = 2 * T - 1 - i
        err = err_tr[pl.ds(t, 1)][0]
        L = jnp.dot(err, b_fb_ref[...].T, preferred_element_type=jnp.float32)
        F = L + kappa * f_scr[...]
        G = h_tr[pl.ds(t, 1)][0] * F

        acc_in_scr[...] += jnp.dot(
            xbar_tr[pl.ds(t, 1)][0].T, G, preferred_element_type=jnp.float32
        )
        acc_rec_scr[...] += jnp.dot(
            pbar_tr[pl.ds(t, 1)][0].T, G, preferred_element_type=jnp.float32
        )
        acc_out_scr[...] += jnp.dot(
            zbar_tr[pl.ds(t, 1)][0].T, err, preferred_element_type=jnp.float32
        )
        f_scr[...] = F

    @pl.when(i == 2 * T - 1)
    def _flush():
        # dw accumulates across batch tiles in the (VMEM-resident) out refs;
        # acc_y / n_spk flush into this tile's own (Bt, ·) output blocks
        _flush_dw(b, acc_in_scr, acc_rec_scr, acc_out_scr,
                  dw_in_ref, dw_rec_ref, dw_out_ref)
        acc_y_ref[...] = accy_scr[...]
        nspk_ref[...] = nspk_scr[...]


def _train_dma_kernel(
    bitmap_ref,   # (nb·T,) int32 scalar-prefetch activity bitmap
    raster_hbm,   # (T, b_pad, N_in) — stays in HBM, streamed manually
    y_star_ref,   # (B, O) one-hot targets
    valid_ref,    # (1, B) TARGET_VALID mask (pinned to tick T-1 in phase 2)
    w_in_ref,     # (N_in, H)
    w_rec_ref,    # (H, H)
    w_out_ref,    # (H, O)
    b_fb_ref,     # (H, O)
    dw_in_ref,    # (N_in, H) out
    dw_rec_ref,   # (H, H) out
    dw_out_ref,   # (H, O) out
    acc_y_ref,    # (B, O) out
    nspk_ref,     # (B, 1) out
    v_scr,        # VMEM (B, H) forward carries …
    z_scr,        # VMEM (B, H)
    y_scr,        # VMEM (B, O)
    xbar_scr,     # VMEM (B, N_in)
    pbar_scr,     # VMEM (B, H)
    zbar_scr,     # VMEM (B, H)
    accy_scr,     # VMEM (B, O)
    nspk_scr,     # VMEM (B, 1)
    h_tr,         # VMEM (T, B, H)
    xbar_tr,      # VMEM (T, B, N_in)
    pbar_tr,      # VMEM (T, B, H)
    zbar_tr,      # VMEM (T, B, H)
    err_tr,       # VMEM (T, B, O)
    f_scr,        # VMEM (B, H)
    acc_in_scr,   # VMEM (N_in, H)
    acc_rec_scr,  # VMEM (H, H)
    acc_out_scr,  # VMEM (H, O)
    cur_scr,      # VMEM (B, H) — this tick's input current (zeros if quiet)
    ev_scr,       # VMEM (2, B, N_in) — the double buffer
    sem,          # DMA semaphores (2,)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    boxcar_width: float,
    quant: Optional[QuantizedMode],
    y_scale: float,
    error_mode: str,
    target_amplitude: float,
    infer_all: bool,
    T: int,
    nb: int,
    bt: int,
):
    """:func:`_train_kernel` with double-buffered event streaming.  The
    raster never enters the block pipeline: each active forward tick's
    block is DMA'd once (the blocked variant's phase-2 grid re-touch is
    gone entirely), quiet ticks skip both the copy and the input
    projection, and the last forward tick's prefetch of the *next* batch
    tile's first block stays in flight across the whole backward phase —
    the deepest compute/copy overlap in the system."""
    b = pl.program_id(0)   # batch tile
    i = pl.program_id(1)   # 0..2T-1: forward ticks 0..T-1, then T-1..0
    forward = i < T
    # linearized forward step; clamped during the backward phase (where the
    # gate disables every DMA predicate anyway)
    s = b * T + jnp.minimum(i, T - 1)

    @pl.when(i == 0)
    def _init():
        v_scr[...] = jnp.zeros_like(v_scr)
        z_scr[...] = jnp.zeros_like(z_scr)
        y_scr[...] = jnp.zeros_like(y_scr)
        xbar_scr[...] = jnp.zeros_like(xbar_scr)
        pbar_scr[...] = jnp.zeros_like(pbar_scr)
        zbar_scr[...] = jnp.zeros_like(zbar_scr)
        accy_scr[...] = jnp.zeros_like(accy_scr)
        nspk_scr[...] = jnp.zeros_like(nspk_scr)
        f_scr[...] = jnp.zeros_like(f_scr)
        acc_in_scr[...] = jnp.zeros_like(acc_in_scr)
        acc_rec_scr[...] = jnp.zeros_like(acc_rec_scr)
        acc_out_scr[...] = jnp.zeros_like(acc_out_scr)

    active, slot = _stream_events(
        bitmap_ref, raster_hbm, ev_scr, sem,
        s=s, total=nb * T, T=T, bt=bt, gate=forward,
    )
    precision = None if quant is None else jax.lax.Precision.HIGHEST

    # input projection + input trace, folded into the streaming step so a
    # quiet tick runs neither (`active` already carries the phase gate)
    @pl.when(active)
    def _project():
        x_t = ev_scr[slot]
        cur_scr[...] = jnp.dot(x_t, w_in_ref[...],
                               preferred_element_type=jnp.float32,
                               precision=precision)
        xbar_scr[...] = alpha * xbar_scr[...] + x_t

    @pl.when(forward & jnp.logical_not(active))
    def _quiet():
        cur_scr[...] = jnp.zeros_like(cur_scr)
        xbar_scr[...] = alpha * xbar_scr[...]

    @pl.when(forward)
    def _forward():
        t = i
        valid_t = valid_ref[0]                 # (B,)
        z = z_scr[...]

        v_new, z_new, y_new, h = tick_from_input_current(
            cur_scr[...], v_scr[...], z, y_scr[...],
            w_rec_ref[...], w_out_ref[...],
            alpha=alpha, kappa=kappa, v_th=v_th, reset_sub=reset_sub,
            boxcar_width=boxcar_width, quant=quant,
        )
        xbar = xbar_scr[...]                   # updated by the streaming step
        pbar = alpha * pbar_scr[...] + z       # presyn trace: z BEFORE this tick
        zbar = kappa * zbar_scr[...] + z_new

        y_err = y_new * y_scale
        if error_mode == "softmax":
            err = jax.nn.softmax(y_err, axis=-1) - y_star_ref[...]
        else:
            err = y_err - target_amplitude * y_star_ref[...]
        err = err * valid_t[:, None]

        h_tr[pl.ds(t, 1)] = h[None]
        xbar_tr[pl.ds(t, 1)] = xbar[None]
        pbar_tr[pl.ds(t, 1)] = pbar[None]
        zbar_tr[pl.ds(t, 1)] = zbar[None]
        err_tr[pl.ds(t, 1)] = err[None]

        v_scr[...] = v_new
        z_scr[...] = z_new
        y_scr[...] = y_new
        pbar_scr[...] = pbar
        zbar_scr[...] = zbar

        w_inf = 1.0 if infer_all else valid_t[:, None]
        accy_scr[...] += y_new * w_inf
        nspk_scr[...] += (z_new * valid_t[:, None]).sum(axis=1, keepdims=True)

    @pl.when(jnp.logical_not(forward))
    def _backward():
        t = 2 * T - 1 - i
        err = err_tr[pl.ds(t, 1)][0]
        L = jnp.dot(err, b_fb_ref[...].T, preferred_element_type=jnp.float32)
        F = L + kappa * f_scr[...]
        G = h_tr[pl.ds(t, 1)][0] * F

        acc_in_scr[...] += jnp.dot(
            xbar_tr[pl.ds(t, 1)][0].T, G, preferred_element_type=jnp.float32
        )
        acc_rec_scr[...] += jnp.dot(
            pbar_tr[pl.ds(t, 1)][0].T, G, preferred_element_type=jnp.float32
        )
        acc_out_scr[...] += jnp.dot(
            zbar_tr[pl.ds(t, 1)][0].T, err, preferred_element_type=jnp.float32
        )
        f_scr[...] = F

    @pl.when(i == 2 * T - 1)
    def _flush():
        _flush_dw(b, acc_in_scr, acc_rec_scr, acc_out_scr,
                  dw_in_ref, dw_rec_ref, dw_out_ref)
        acc_y_ref[...] = accy_scr[...]
        nspk_ref[...] = nspk_scr[...]


def rsnn_train(
    raster: jax.Array,   # (T, B, N_in) f32
    y_star: jax.Array,   # (B, O) one-hot targets
    valid: jax.Array,    # (T, B) f32 TARGET_VALID mask
    w_in: jax.Array,     # (N_in, H)
    w_rec: jax.Array,    # (H, H) — pre-masked
    w_out: jax.Array,    # (H, O)
    b_fb: jax.Array,     # (H, O) feedback matrix (w_out or random B)
    *,
    alpha: float,
    kappa: float,
    v_th: float = 1.0,
    reset: str = "sub",
    boxcar_width: float = 0.5,
    quant: Optional[QuantizedMode] = None,
    error: str = "softmax",
    target_amplitude: float = 1.0,
    infer_window: str = "valid",
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
    stream: str = "blocked",
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused forward + factored e-prop update over one ``(T, B)`` launch.

    A batch-tiled two-phase ``grid=(ceil(B/Bt), 2T)`` program — per batch
    tile, steps ``0..T-1`` run the forward tick datapath with the readout
    error evaluated in-kernel, steps ``T..2T-1`` run the reverse κ-filter —
    with the tile's whole ``h/xbar/pbar/zbar/err`` trace set held in VMEM
    scratch.  ``Bt`` is derived from the VMEM budget
    (:func:`repro.kernels.rsnn_step.max_fused_train_tile`, or the explicit
    ``batch_tile`` override) so the trace scratch always fits; ``dw`` is
    accumulated across batch tiles directly in the output refs.  Returns
    ``(dw_in, dw_rec, dw_out, acc_y (B, O), n_spk (B, 1))``; nothing of
    O(T·B·H) ever touches HBM and ``B`` is unbounded.

    The caller is responsible for masking ``dw_rec``'s self-recurrence
    afterwards (same contract as :func:`eprop_update`).  Quantized mode:
    pass weights through ``QuantizedMode.to_membrane`` but ``b_fb`` in
    normalised weight units — the error is evaluated on ``y / threshold``
    in-kernel so the learning signal matches the float model's scale.
    """
    T, B, n_in = raster.shape
    H = w_rec.shape[0]
    O = w_out.shape[1]
    dt = raster.dtype
    if quant is not None:
        alpha, kappa, v_th = quant.alpha, quant.kappa, float(quant.threshold)
    y_scale = 1.0 if quant is None else 1.0 / float(quant.threshold)
    bt, nb, b_pad = _tile_batch(
        B, batch_tile or max_fused_train_tile(T, n_in, H, O, vmem_budget)
    )
    # A single-row tile beyond *physical* VMEM cannot compile anywhere —
    # fail at trace time with the actionable alternative (the split
    # forward_traces + eprop_update ops stream the traces through HBM).
    tile_bytes = fused_train_bytes(T, bt, n_in, H, O)
    if tile_bytes > PHYSICAL_VMEM_CEILING:
        raise ValueError(
            f"fused train tile (T={T}, Bt={bt}) needs {tile_bytes} bytes of "
            f"trace scratch — beyond physical VMEM "
            f"({PHYSICAL_VMEM_CEILING}); shorten T or run the split "
            "forward_traces + eprop_update pipeline, which streams traces "
            "through HBM"
        )
    if stream not in ("blocked", "dma"):
        raise ValueError(f"unknown stream mode {stream!r}")
    # pad rows: zero raster + zero valid -> zero err, zero dw, zero acc_y
    raster = _pad_batch_axis(raster, 1, b_pad)
    y_star = _pad_batch_axis(y_star, 0, b_pad)
    valid = _pad_batch_axis(valid, 1, b_pad)

    consts = dict(
        alpha=float(alpha),
        kappa=float(kappa),
        v_th=float(v_th),
        reset_sub=(reset == "sub"),
        boxcar_width=float(boxcar_width),
        quant=quant,
        y_scale=y_scale,
        error_mode=error,
        target_amplitude=float(target_amplitude),
        infer_all=(infer_window == "all"),
        T=T,
    )
    out_shape = [
        jax.ShapeDtypeStruct((n_in, H), jnp.float32),
        jax.ShapeDtypeStruct((H, H), jnp.float32),
        jax.ShapeDtypeStruct((H, O), jnp.float32),
        jax.ShapeDtypeStruct((b_pad, O), dt),
        jax.ShapeDtypeStruct((b_pad, 1), dt),
    ]
    scratch = [
        pltpu.VMEM((bt, H), jnp.float32),      # v
        pltpu.VMEM((bt, H), jnp.float32),      # z
        pltpu.VMEM((bt, O), jnp.float32),      # y
        pltpu.VMEM((bt, n_in), jnp.float32),   # xbar carry
        pltpu.VMEM((bt, H), jnp.float32),      # pbar carry
        pltpu.VMEM((bt, H), jnp.float32),      # zbar carry
        pltpu.VMEM((bt, O), jnp.float32),      # acc_y
        pltpu.VMEM((bt, 1), jnp.float32),      # n_spk
        pltpu.VMEM((T, bt, H), jnp.float32),   # h trace
        pltpu.VMEM((T, bt, n_in), jnp.float32),  # xbar trace
        pltpu.VMEM((T, bt, H), jnp.float32),   # pbar trace
        pltpu.VMEM((T, bt, H), jnp.float32),   # zbar trace
        pltpu.VMEM((T, bt, O), jnp.float32),   # err trace
        pltpu.VMEM((bt, H), jnp.float32),      # F carry
        pltpu.VMEM((n_in, H), jnp.float32),    # dw_in acc
        pltpu.VMEM((H, H), jnp.float32),       # dw_rec acc
        pltpu.VMEM((H, O), jnp.float32),       # dw_out acc
    ]

    if stream == "dma":
        bitmap = _block_bitmap(raster, bt)
        kern = functools.partial(_train_dma_kernel, **consts, nb=nb, bt=bt)
        full = lambda shape: pl.BlockSpec(
            shape, lambda b, i, s_ref: tuple(0 for _ in shape)
        )
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb, 2 * T),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),   # raster stays in HBM
                pl.BlockSpec((bt, O), lambda b, i, s_ref: (b, 0)),
                # valid pins to tick T-1 across phase 2: the block index is
                # then unchanged step-to-step, so Pallas skips the re-fetch
                # the blocked variant's (i mod T) map pays for
                pl.BlockSpec(
                    (1, bt), lambda b, i, s_ref: (jnp.minimum(i, T - 1), b)
                ),
                full((n_in, H)),
                full((H, H)),
                full((H, O)),
                full((H, O)),
            ],
            out_specs=[
                full((n_in, H)), full((H, H)), full((H, O)),
                pl.BlockSpec((bt, O), lambda b, i, s_ref: (b, 0)),
                pl.BlockSpec((bt, 1), lambda b, i, s_ref: (b, 0)),
            ],
            scratch_shapes=scratch + [
                pltpu.VMEM((bt, H), jnp.float32),        # input current
                pltpu.VMEM((2, bt, n_in), jnp.float32),  # event double buffer
                pltpu.SemaphoreType.DMA((2,)),
            ],
        )
        outs = pl.pallas_call(
            kern, grid_spec=grid_spec, out_shape=out_shape,
            interpret=interpret,
        )(bitmap, raster, y_star, valid, w_in, w_rec, w_out, b_fb)
    else:
        kern = functools.partial(_train_kernel, **consts)
        # Phase 2 re-visits the tick blocks via (i mod T); their contents
        # are ignored there (the traces live in VMEM) — the index map only
        # has to be in-bounds.
        full = lambda shape: pl.BlockSpec(
            shape, lambda b, i: tuple(0 for _ in shape)
        )
        outs = pl.pallas_call(
            kern,
            grid=(nb, 2 * T),
            in_specs=[
                pl.BlockSpec((1, bt, n_in), lambda b, i: (i % T, b, 0)),
                pl.BlockSpec((bt, O), lambda b, i: (b, 0)),
                pl.BlockSpec((1, bt), lambda b, i: (i % T, b)),
                full((n_in, H)),
                full((H, H)),
                full((H, O)),
                full((H, O)),
            ],
            out_specs=[
                full((n_in, H)), full((H, H)), full((H, O)),
                pl.BlockSpec((bt, O), lambda b, i: (b, 0)),
                pl.BlockSpec((bt, 1), lambda b, i: (b, 0)),
            ],
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
        )(raster, y_star, valid, w_in, w_rec, w_out, b_fb)
    dw_in, dw_rec, dw_out, acc_y, n_spk = outs
    return dw_in, dw_rec, dw_out, acc_y[:B], n_spk[:B]
