"""Factored e-prop weight-update kernel.

Computes, in one reverse pass over the tick axis,

  L[t]   = err[t] @ B_fbᵀ                    (MXU)
  F[t]   = L[t] + κ·F[t+1]                   (VMEM-carried reverse filter)
  dW_in  = Σ_t xbar[t]ᵀ (h[t]∘F[t])          (MXU, accumulated in VMEM)
  dW_rec = Σ_t pbar[t]ᵀ (h[t]∘F[t])
  dW_out = Σ_t zbar[t]ᵀ err[t]

i.e. the per-synapse eligibility SRAM of the chip becomes three VMEM-resident
accumulator tiles fed by per-tick rank-B matmul updates.  grid=(T,) iterated
in reverse via the index map; accumulators write out on the final step.

Hardware-equivalence (quantized) mode needs no variant of this kernel: the
chip's trace arithmetic is wider than its commit grid, so the quantized
contract keeps e-prop traces float — the backend feeds this kernel the same
float h/xbar/pbar/zbar it produces in quantized runs, with ``err`` already
evaluated on the normalised readout (``y / threshold``) and ``b_fb`` in
normalised weight units.  Quantization happens at the *commit*
(:class:`repro.optim.eprop_opt.EpropSGD` accumulate-then-round), exactly as
on chip.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    h_ref,        # (1, B, H)
    xbar_ref,     # (1, B, N_in)
    pbar_ref,     # (1, B, H)
    zbar_ref,     # (1, B, H)
    err_ref,      # (1, B, O)
    b_fb_ref,     # (H, O)
    dw_in_ref,    # (N_in, H) out
    dw_rec_ref,   # (H, H) out
    dw_out_ref,   # (H, O) out
    f_scr,        # VMEM (B, H)
    acc_in_scr,   # VMEM (N_in, H)
    acc_rec_scr,  # VMEM (H, H)
    acc_out_scr,  # VMEM (H, O)
    *,
    kappa: float,
    T: int,
):
    i = pl.program_id(0)   # 0..T-1, visiting ticks T-1..0 via the index map

    @pl.when(i == 0)
    def _init():
        f_scr[...] = jnp.zeros_like(f_scr)
        acc_in_scr[...] = jnp.zeros_like(acc_in_scr)
        acc_rec_scr[...] = jnp.zeros_like(acc_rec_scr)
        acc_out_scr[...] = jnp.zeros_like(acc_out_scr)

    err = err_ref[0]
    L = jnp.dot(err, b_fb_ref[...].T, preferred_element_type=jnp.float32)
    F = L + kappa * f_scr[...]
    G = h_ref[0] * F

    acc_in_scr[...] += jnp.dot(
        xbar_ref[0].T, G, preferred_element_type=jnp.float32
    )
    acc_rec_scr[...] += jnp.dot(
        pbar_ref[0].T, G, preferred_element_type=jnp.float32
    )
    acc_out_scr[...] += jnp.dot(
        zbar_ref[0].T, err, preferred_element_type=jnp.float32
    )
    f_scr[...] = F

    @pl.when(i == T - 1)
    def _flush():
        dw_in_ref[...] = acc_in_scr[...]
        dw_rec_ref[...] = acc_rec_scr[...]
        dw_out_ref[...] = acc_out_scr[...]


def eprop_update(
    h: jax.Array,      # (T, B, H)
    xbar: jax.Array,   # (T, B, N_in)
    pbar: jax.Array,   # (T, B, H)
    zbar: jax.Array,   # (T, B, H)
    err: jax.Array,    # (T, B, O)
    b_fb: jax.Array,   # (H, O)
    *,
    kappa: float,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    T, B, H = h.shape
    n_in = xbar.shape[2]
    O = err.shape[2]

    rev = lambda cols: pl.BlockSpec((1, B, cols), lambda i: (T - 1 - i, 0, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    kern = functools.partial(_kernel, kappa=float(kappa), T=T)
    dw_in, dw_rec, dw_out = pl.pallas_call(
        kern,
        grid=(T,),
        in_specs=[rev(H), rev(n_in), rev(H), rev(H), rev(O), full((H, O))],
        out_specs=[full((n_in, H)), full((H, H)), full((H, O))],
        out_shape=[
            jax.ShapeDtypeStruct((n_in, H), jnp.float32),
            jax.ShapeDtypeStruct((H, H), jnp.float32),
            jax.ShapeDtypeStruct((H, O), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, H), jnp.float32),
            pltpu.VMEM((n_in, H), jnp.float32),
            pltpu.VMEM((H, H), jnp.float32),
            pltpu.VMEM((H, O), jnp.float32),
        ],
        interpret=interpret,
    )(h, xbar, pbar, zbar, err, b_fb)
    return dw_in, dw_rec, dw_out
