"""Forward-side RSNN kernels — ReckOn's neuron-update pipeline on the MXU.

The chip walks neurons sequentially per tick, streaming membrane/trace words
from SRAM.  The TPU-native re-blocking keeps the *whole network state
resident in VMEM* across the tick loop (grid iterations execute sequentially
on a TPU core, so VMEM scratch carries state), and turns the per-neuron
MAC loop into two MXU matmuls per tick:

  grid = (T,)                       one step per AER tick
  VMEM scratch: v, z, y, (xbar, pbar, zbar)  (the "neuron SRAM")
  per tick: current = x_t @ W_in + z @ W_rec      (MXU)
            LIF update, boxcar pseudo-derivative   (VPU)
            y = κ·y + z_new @ W_out                (MXU)
            trace filters (α, κ)                   (VPU)

Two op-specialized variants live here (one backend op each — see
:mod:`repro.core.backend` and the data-movement table in
``kernels/traffic.py`` / README):

* :func:`rsnn_forward` — serves the ``forward_traces`` and ``dynamics`` ops.
  Streams the per-tick quantities the *split* factored e-prop update needs
  (z, h, xbar, pbar, zbar, y, v) back to HBM — O(T·H) traffic per tick,
  never O(T·H²).  The fused ``train`` op (:func:`repro.kernels.eprop_update.
  rsnn_train`) supersedes it on the training path whenever the trace
  scratch fits VMEM.
* :func:`rsnn_infer` — serves the ``inference`` op.  Accumulates the
  valid-weighted readout and the valid-masked spike count *in VMEM* and
  streams **no** per-tick outputs: HBM writes drop from seven ``(T,B,·)``
  tensors to one ``(B,O)`` readout tile plus a ``(B,1)`` spike count — the
  serving hot path.

ReckOn caps N_in/H at 256 ⇒ weights (256×256 f32 = 256 KiB) sit in VMEM for
the entire sample.  Batches of any size run as *batch-tiled* grids —
``grid = (ceil(B / Bt), T)`` — where the tile rows ``Bt`` are derived from
the bytes-budget helpers below so one tile's state always fits VMEM.  The
grid walks batch-tile-major (all T ticks of tile 0, then tile 1, …); VMEM
scratch re-initialises at each tile's first tick, so tiles are independent
and a launch is never capped by VMEM — only its *tiles* are.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import QuantizedMode

# ---------------------------------------------------------------------------
# VMEM bytes budget — the single source of truth for tile sizing.
#
# Every tile-sizing decision in the system derives from these helpers — the
# per-tile row caps the batch-tiled kernel grids pick (max_forward_tile /
# max_fused_train_tile), the derived KERNEL_SAMPLE_CAP below, the backend's
# `tile_rows` accounting (repro.core.backend.ExecutionBackend), and the
# serving admission size (repro.serve.batching.max_batch_for).  Nothing else
# in src/ declares a tile-size constant — asserted by
# tests/test_fused_kernels.py::test_tile_sizing_single_source.
# ---------------------------------------------------------------------------

# Conservative slice of the ~16 MiB/core VMEM left to one kernel tile once
# double-buffered HBM streaming and compiler temporaries are accounted for.
DEFAULT_VMEM_BUDGET = 4 * 2**20

# The physical per-core ceiling: a tile whose scratch exceeds this cannot
# compile on any TPU regardless of how far the conservative budget is
# raised — the fused train wrapper fails loudly at trace time instead of
# surfacing an opaque compiler OOM (there is no silent fallback any more).
PHYSICAL_VMEM_CEILING = 16 * 2**20


def cdiv(a: int, b: int) -> int:
    """Ceiling division — the one tile-count idiom (grids, padding,
    traffic accounting all reuse it)."""
    return -(-a // b)

F32_BYTES = 4  # bytes per element; the kernels are f32 throughout
_F32 = F32_BYTES


def weight_elems(n_in: int, n_hid: int, n_out: int) -> int:
    """Elements in the weight set (w_in + w_rec + w_out) — shared by the
    VMEM budget below and the HBM traffic table (:mod:`repro.kernels.traffic`)."""
    return n_in * n_hid + n_hid * n_hid + n_hid * n_out


def weights_bytes(n_in: int, n_hid: int, n_out: int) -> int:
    """VMEM-resident weight bytes (w_in + w_rec + w_out, f32)."""
    return _F32 * weight_elems(n_in, n_hid, n_out)


def state_bytes_per_sample(n_in: int, n_hid: int, n_out: int) -> int:
    """VMEM bytes one batch row occupies inside the worst-case tick kernel
    (the trace-streaming :func:`rsnn_forward`): carry scratch
    (v, z, y, xbar, pbar, zbar) plus double-buffered per-tick input/output
    blocks (tick in + the seven streamed outputs)."""
    scratch = 4 * n_hid + n_out + n_in      # v,z,pbar,zbar (H) + y (O) + xbar (N)
    blocks = 5 * n_hid + 2 * n_in + n_out   # in (N) + outs z,h,xbar,pbar,zbar,y,v
    return _F32 * (scratch + 2 * blocks)


def max_batch_for_dims(
    n_in: int,
    n_hid: int,
    n_out: int,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    cap: Optional[int] = None,
) -> int:
    """Largest batch tile the VMEM budget admits for one network shape."""
    spare = vmem_budget - weights_bytes(n_in, n_hid, n_out)
    if spare <= 0:
        return 1
    b = spare // state_bytes_per_sample(n_in, n_hid, n_out)
    if cap is not None:
        b = min(cap, b)
    return int(max(1, b))


# The kernel's per-tile VMEM contract: the largest power-of-two batch tile
# a chip-maximal (256 in / 256 hid / 16 out) network fits in the default
# budget.  Derived, not hand-synced — evaluates to 128.  A per-*tile* bound,
# not a launch bound: the batch-tiled grids cut any B into tiles of at most
# this many rows, and the serving runtime's per-device admission
# (repro.serve.batching.max_batch_for) targets one such tile per device.
_CHIP_MAX_DIMS = (256, 256, 16)
KERNEL_SAMPLE_CAP = 1 << (max_batch_for_dims(*_CHIP_MAX_DIMS).bit_length() - 1)


def session_state_bytes(n_hid: int, n_out: int) -> int:
    """Device-pool bytes one resident session's carry state occupies
    (f32 rows of ``v, z (H)``, ``y, acc_y (O)`` and ``n_spk (1)``) — the
    capacity unit of the streaming serving runtime.  ``S_cap``-sizing
    (:func:`repro.serve.batching.max_sessions_for`) and the pool's own
    allocation both derive from this helper."""
    return _F32 * (2 * n_hid + 2 * n_out + 1)


def fused_train_bytes(T: int, B: int, n_in: int, n_hid: int, n_out: int) -> int:
    """VMEM bytes the fused train kernel
    (:func:`repro.kernels.eprop_update.rsnn_train`) needs for one ``(T, B)``
    tile: weights + feedback, the forward carry state, the ``(T, B, ·)``
    e-prop trace scratch (h, xbar, pbar, zbar, err — the tensors the
    two-kernel pipeline would round-trip through HBM), the three ``dw``
    accumulators, and the double-buffered tick input blocks."""
    weights = weights_bytes(n_in, n_hid, n_out) + _F32 * n_hid * n_out  # + b_fb
    carries = _F32 * B * (5 * n_hid + n_in + 2 * n_out + 1)  # v,z,pbar,zbar,f,xbar,y,acc_y,nspk
    traces = _F32 * T * B * (3 * n_hid + n_in + n_out)       # h,pbar,zbar + xbar + err
    accs = _F32 * (n_in * n_hid + n_hid * n_hid + n_hid * n_out)
    blocks = _F32 * 2 * B * (n_in + 1)                       # raster + valid tick blocks
    return weights + carries + traces + accs + blocks


def fused_train_fits(
    T: int,
    B: int,
    n_in: int,
    n_hid: int,
    n_out: int,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> bool:
    """Whether one ``(T, B)`` training tile's whole e-prop trace set fits
    the VMEM budget.  Byte test only: the batch-tiled train grid runs a
    fitting batch as a single tile *up to* ``KERNEL_SAMPLE_CAP`` rows —
    above the cap it still tiles even when the bytes would fit
    (``max_fused_train_tile`` applies both bounds)."""
    return fused_train_bytes(T, B, n_in, n_hid, n_out) <= vmem_budget


def max_forward_tile(
    n_in: int, n_hid: int, n_out: int, vmem_budget: int = DEFAULT_VMEM_BUDGET
) -> int:
    """Batch rows per tile of the batch-tiled forward/inference/update grids
    (``grid = (ceil(B / Bt), T)``), derived from the VMEM budget and capped
    by the kernel contract."""
    return max_batch_for_dims(
        n_in, n_hid, n_out, vmem_budget, cap=KERNEL_SAMPLE_CAP
    )


def max_fused_train_tile(
    T: int,
    n_in: int,
    n_hid: int,
    n_out: int,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> int:
    """Batch rows per tile of the batch-tiled fused train grid
    (``grid = (ceil(B / Bt), 2T)``): the largest ``Bt`` whose whole-trace
    scratch (:func:`fused_train_bytes`, linear in B) fits the budget.

    Clamped to ``>= 1``: the budget is a conservative slice of physical
    VMEM, so a single-sample tile that nominally overflows it (chip-maximal
    ``T``) still compiles in practice — there is no fallback pipeline to
    fall back to any more.  Capped by the kernel contract above.
    """
    fixed = fused_train_bytes(T, 0, n_in, n_hid, n_out)
    per_row = fused_train_bytes(T, 1, n_in, n_hid, n_out) - fixed
    b = (vmem_budget - fixed) // per_row
    return int(max(1, min(KERNEL_SAMPLE_CAP, b)))


def _tile_batch(
    B: int, tile: int
) -> Tuple[int, int, int]:
    """``(Bt, num_tiles, padded_B)`` for one launch: tile rows never exceed
    the batch, and the batch axis is zero-padded up to a whole number of
    tiles (padding rows carry zero input and zero valid — inert by the
    masking invariants, sliced off by the wrappers)."""
    bt = max(1, min(tile, B))
    nb = cdiv(B, bt)
    return bt, nb, nb * bt


def _pad_batch_axis(x: jax.Array, axis: int, target: int) -> jax.Array:
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# shared tick datapath
# ---------------------------------------------------------------------------


def tick_transition(
    x_t: jax.Array,     # (B, N_in) input spikes this tick
    v: jax.Array,       # (B, H) post-reset membrane
    z: jax.Array,       # (B, H) spikes from the previous tick
    y: jax.Array,       # (B, O) readout membrane
    w_in: jax.Array,    # (N_in, H)
    w_rec: jax.Array,   # (H, H) — pre-masked
    w_out: jax.Array,   # (H, O)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    boxcar_width: float,
    quant: Optional[QuantizedMode],
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One LIF + LI tick on the MXU/VPU — the datapath every RSNN kernel
    (forward, inference-only, fused train) shares.

    Returns ``(v_new, z_new, y_new, h)`` with ``h`` the boxcar
    pseudo-derivative evaluated at the pre-reset membrane.

    Quantized mode runs the same MXU pipeline on integer values carried in
    f32 (all exact below 2**24); ``Precision.HIGHEST`` keeps the dots exact
    on TPU (the default f32 passes would round the >bf16-mantissa weights).
    """
    precision = None if quant is None else jax.lax.Precision.HIGHEST
    in_cur = jnp.dot(x_t, w_in, preferred_element_type=jnp.float32,
                     precision=precision)
    return tick_from_input_current(
        in_cur, v, z, y, w_rec, w_out,
        alpha=alpha, kappa=kappa, v_th=v_th, reset_sub=reset_sub,
        boxcar_width=boxcar_width, quant=quant,
    )


def tick_from_input_current(
    in_cur: jax.Array,  # (B, H) precomputed input current x_t @ w_in
    v: jax.Array,       # (B, H) post-reset membrane
    z: jax.Array,       # (B, H) spikes from the previous tick
    y: jax.Array,       # (B, O) readout membrane
    w_rec: jax.Array,   # (H, H) — pre-masked
    w_out: jax.Array,   # (H, O)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    boxcar_width: float,
    quant: Optional[QuantizedMode],
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """:func:`tick_transition` with the input projection hoisted out — the
    entry point of the event-driven paths, where ``x_t @ w_in`` is either
    skipped for all-quiet tick blocks (DMA-streaming kernels) or gathered
    over active rows only (:func:`repro.kernels.events.
    sparse_input_projection`).  ``in_cur + z @ w_rec`` reproduces the
    original ``dot; +=`` operand order, so results are bit-identical to the
    one-shot form — a quiet tick's skipped projection contributes the same
    exact zeros the dense all-zero dot would.
    """
    precision = None if quant is None else jax.lax.Precision.HIGHEST
    current = in_cur + jnp.dot(z, w_rec, preferred_element_type=jnp.float32,
                               precision=precision)

    if quant is None:
        v_pre = alpha * v + current
    else:
        # sat(floor(v * alpha_reg/256) + current) on the signed membrane grid
        v_pre = quant.sat(quant.leak(v, quant.alpha_reg) + current)
    z_new = (v_pre >= v_th).astype(v_pre.dtype)
    if reset_sub:
        v_new = v_pre - z_new * v_th
    else:
        v_new = v_pre * (1.0 - z_new)
    h = (jnp.abs(v_pre - v_th) < boxcar_width * v_th).astype(v_pre.dtype)

    y_lin = jnp.dot(z_new, w_out, preferred_element_type=jnp.float32,
                    precision=precision)
    if quant is None:
        y_new = kappa * y + y_lin
    else:
        y_new = quant.sat(quant.leak(y, quant.kappa_reg) + y_lin)
    return v_new, z_new, y_new, h


# ---------------------------------------------------------------------------
# double-buffered event streaming (stream="dma" kernel variants)
#
# The software analogue of FeNN-DMA's DMA controller: instead of letting the
# Pallas pipeline fetch every tick's (Bt, N_in) event block synchronously,
# the raster stays in HBM (memory_space=ANY) and the kernel issues its own
# async copies into a 2-slot VMEM buffer — tick s's block is consumed while
# tick s+1's copy is in flight.  Steps are linearized as s = b·T + t across
# the (nb, T) grid, so the prefetch of s+1 naturally crosses batch-tile
# boundaries: tile b's last tick prefetches tile b+1's first block.
#
# A per-(tile, tick) activity bitmap rides in as a scalar-prefetch argument
# and gates both the copy and the input projection: an all-quiet block is
# neither fetched nor multiplied through (the in-kernel tick skip).  Only
# the input projection may be skipped — the recurrent current and the
# leak dynamics run every tick (membranes leak even with no input, and
# recurrent spikes persist) — which is exactly what keeps the skip
# bit-exact against the dense path.
# ---------------------------------------------------------------------------


def _block_bitmap(raster_padded: jax.Array, bt: int) -> jax.Array:
    """Per-(batch-tile, tick) activity of a padded ``(T, b_pad, N)`` raster,
    flattened to ``(nb·T,)`` int32 in linearized step order ``s = b·T + t``
    (the scalar-prefetch argument of the DMA kernels)."""
    T, b_pad, _ = raster_padded.shape
    nb = b_pad // bt
    act = (raster_padded.reshape(T, nb, bt, -1) != 0).any(axis=(2, 3))
    return act.T.reshape(nb * T).astype(jnp.int32)


def _stream_events(bitmap_ref, raster_hbm, ev_scr, sem, *, s, total, T, bt,
                   gate=None):
    """One double-buffered streaming step: warm-up copy at s=0, prefetch of
    step s+1's block into the other slot, then the blocking wait for step
    s's own copy.  Returns ``(active, slot)`` — when ``active`` (a traced
    bool) holds, ``ev_scr[slot]`` now contains step s's event block.

    Slot parity is safe with skipped steps: slot s%2 was last waited on at
    step s-2, and a copy is only ever started for a step whose bitmap bit is
    set — the same predicate that gates its wait.

    ``gate`` (optional traced bool) disables the whole step when False —
    the fused train kernel passes its forward-phase predicate so backward
    steps neither wait nor prefetch (the next tile's warm-up copy, started
    at the last forward tick, stays in flight across the entire backward
    phase).
    """
    def dma(step, slot):
        return pltpu.make_async_copy(
            raster_hbm.at[step % T, pl.ds((step // T) * bt, bt), :],
            ev_scr.at[slot],
            sem.at[slot],
        )

    active = bitmap_ref[s] > 0
    nxt = jnp.minimum(s + 1, total - 1)
    active_next = (s + 1 < total) & (bitmap_ref[nxt] > 0)
    if gate is not None:
        active = gate & active
        active_next = gate & active_next

    @pl.when((s == 0) & active)
    def _warm():
        dma(s, s % 2).start()

    @pl.when(active_next)
    def _prefetch():
        dma(s + 1, (s + 1) % 2).start()

    @pl.when(active)
    def _wait():
        dma(s, s % 2).wait()

    return active, s % 2


# ---------------------------------------------------------------------------
# trace-streaming forward (forward_traces / dynamics ops)
# ---------------------------------------------------------------------------


def _kernel(
    raster_ref,   # (1, B, N_in) — tick t's input spikes
    w_in_ref,     # (N_in, H)
    w_rec_ref,    # (H, H)
    w_out_ref,    # (H, O)
    z_out_ref,    # (1, B, H)
    h_out_ref,    # (1, B, H)
    xbar_out_ref, # (1, B, N_in)
    pbar_out_ref, # (1, B, H)
    zbar_out_ref, # (1, B, H)
    y_out_ref,    # (1, B, O)
    v_out_ref,    # (1, B, H) — post-reset membrane trajectory
    v_scr,        # VMEM (B, H)
    z_scr,        # VMEM (B, H)
    y_scr,        # VMEM (B, O)
    xbar_scr,     # VMEM (B, N_in)
    pbar_scr,     # VMEM (B, H)
    zbar_scr,     # VMEM (B, H)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    boxcar_width: float,
    quant: Optional[QuantizedMode],
):
    t = pl.program_id(1)   # tick within the current batch tile

    # each batch tile is an independent network run: re-init at its 1st tick
    @pl.when(t == 0)
    def _init():
        v_scr[...] = jnp.zeros_like(v_scr)
        z_scr[...] = jnp.zeros_like(z_scr)
        y_scr[...] = jnp.zeros_like(y_scr)
        xbar_scr[...] = jnp.zeros_like(xbar_scr)
        pbar_scr[...] = jnp.zeros_like(pbar_scr)
        zbar_scr[...] = jnp.zeros_like(zbar_scr)

    x_t = raster_ref[0]
    z = z_scr[...]

    v_new, z_new, y_new, h = tick_transition(
        x_t, v_scr[...], z, y_scr[...],
        w_in_ref[...], w_rec_ref[...], w_out_ref[...],
        alpha=alpha, kappa=kappa, v_th=v_th, reset_sub=reset_sub,
        boxcar_width=boxcar_width, quant=quant,
    )
    xbar = alpha * xbar_scr[...] + x_t
    pbar = alpha * pbar_scr[...] + z          # presyn trace: z BEFORE this tick
    zbar = kappa * zbar_scr[...] + z_new

    v_scr[...] = v_new
    z_scr[...] = z_new
    y_scr[...] = y_new
    xbar_scr[...] = xbar
    pbar_scr[...] = pbar
    zbar_scr[...] = zbar

    z_out_ref[0] = z_new
    h_out_ref[0] = h
    xbar_out_ref[0] = xbar
    pbar_out_ref[0] = pbar
    zbar_out_ref[0] = zbar
    y_out_ref[0] = y_new
    v_out_ref[0] = v_new


def _forward_dma_kernel(
    bitmap_ref,   # (nb·T,) int32 scalar-prefetch activity bitmap
    raster_hbm,   # (T, b_pad, N_in) — stays in HBM, streamed manually
    w_in_ref,     # (N_in, H)
    w_rec_ref,    # (H, H)
    w_out_ref,    # (H, O)
    z_out_ref,    # (1, B, H)
    h_out_ref,    # (1, B, H)
    xbar_out_ref, # (1, B, N_in)
    pbar_out_ref, # (1, B, H)
    zbar_out_ref, # (1, B, H)
    y_out_ref,    # (1, B, O)
    v_out_ref,    # (1, B, H)
    v_scr,        # VMEM (B, H)
    z_scr,        # VMEM (B, H)
    y_scr,        # VMEM (B, O)
    xbar_scr,     # VMEM (B, N_in)
    pbar_scr,     # VMEM (B, H)
    zbar_scr,     # VMEM (B, H)
    cur_scr,      # VMEM (B, H) — this tick's input current (zeros if quiet)
    ev_scr,       # VMEM (2, B, N_in) — the double buffer
    sem,          # DMA semaphores (2,)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    boxcar_width: float,
    quant: Optional[QuantizedMode],
    T: int,
    nb: int,
    bt: int,
):
    """:func:`_kernel` with double-buffered event streaming: the raster
    block of tick s+1 is copied in while tick s computes, and an all-quiet
    block skips both the copy and the ``x_t @ w_in`` projection (the
    recurrent current, leaks and trace filters still run — that is what
    keeps the skip bit-exact)."""
    b = pl.program_id(0)
    t = pl.program_id(1)
    s = b * T + t

    @pl.when(t == 0)
    def _init():
        v_scr[...] = jnp.zeros_like(v_scr)
        z_scr[...] = jnp.zeros_like(z_scr)
        y_scr[...] = jnp.zeros_like(y_scr)
        xbar_scr[...] = jnp.zeros_like(xbar_scr)
        pbar_scr[...] = jnp.zeros_like(pbar_scr)
        zbar_scr[...] = jnp.zeros_like(zbar_scr)

    active, slot = _stream_events(
        bitmap_ref, raster_hbm, ev_scr, sem, s=s, total=nb * T, T=T, bt=bt
    )
    precision = None if quant is None else jax.lax.Precision.HIGHEST

    @pl.when(active)
    def _project():
        x_t = ev_scr[slot]
        cur_scr[...] = jnp.dot(x_t, w_in_ref[...],
                               preferred_element_type=jnp.float32,
                               precision=precision)
        xbar_scr[...] = alpha * xbar_scr[...] + x_t

    @pl.when(jnp.logical_not(active))
    def _quiet():
        cur_scr[...] = jnp.zeros_like(cur_scr)
        xbar_scr[...] = alpha * xbar_scr[...]

    z = z_scr[...]
    v_new, z_new, y_new, h = tick_from_input_current(
        cur_scr[...], v_scr[...], z, y_scr[...],
        w_rec_ref[...], w_out_ref[...],
        alpha=alpha, kappa=kappa, v_th=v_th, reset_sub=reset_sub,
        boxcar_width=boxcar_width, quant=quant,
    )
    pbar = alpha * pbar_scr[...] + z          # presyn trace: z BEFORE this tick
    zbar = kappa * zbar_scr[...] + z_new

    v_scr[...] = v_new
    z_scr[...] = z_new
    y_scr[...] = y_new
    pbar_scr[...] = pbar
    zbar_scr[...] = zbar

    z_out_ref[0] = z_new
    h_out_ref[0] = h
    xbar_out_ref[0] = xbar_scr[...]
    pbar_out_ref[0] = pbar
    zbar_out_ref[0] = zbar
    y_out_ref[0] = y_new
    v_out_ref[0] = v_new


def rsnn_forward(
    raster: jax.Array,   # (T, B, N_in) f32
    w_in: jax.Array,     # (N_in, H)
    w_rec: jax.Array,    # (H, H) — pre-masked
    w_out: jax.Array,    # (H, O)
    *,
    alpha: float,
    kappa: float,
    v_th: float = 1.0,
    reset: str = "sub",
    boxcar_width: float = 0.5,
    quant: Optional[QuantizedMode] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
    stream: str = "blocked",
    interpret: bool = False,
) -> Dict[str, jax.Array]:
    """Fused forward over one ``(T, B)`` launch; returns per-tick tensors
    (z, h, xbar, pbar, zbar, y, v — post-reset membrane trajectory).

    The launch runs as a batch-tiled ``grid = (ceil(B / Bt), T)`` with
    ``Bt`` derived from the VMEM budget (:func:`max_forward_tile`, or the
    explicit ``batch_tile`` override), so ``B`` is unbounded — only a tile
    must fit VMEM.  This is the *trace-streaming* variant: it serves the
    backend's ``forward_traces`` op (split-pipeline training) and the
    ``dynamics`` probe.  The ``inference`` op uses :func:`rsnn_infer` (no
    per-tick streams); the ``train`` op always uses
    :func:`repro.kernels.eprop_update.rsnn_train`, which tiles the same way.

    With ``quant`` set the tick pipeline is ReckOn's fixed-point datapath
    (saturating membrane grid, register-driven floor leaks); ``alpha``,
    ``kappa`` and ``v_th`` are then taken from the registers, and the
    caller must pass weights already on the membrane grid
    (``QuantizedMode.to_membrane`` — integer values in f32).
    """
    T, B, n_in = raster.shape
    H = w_rec.shape[0]
    O = w_out.shape[1]
    dt = raster.dtype
    if quant is not None:
        alpha, kappa, v_th = quant.alpha, quant.kappa, float(quant.threshold)
    if stream not in ("blocked", "dma"):
        raise ValueError(f"unknown stream mode {stream!r}")
    bt, nb, b_pad = _tile_batch(
        B, batch_tile or max_forward_tile(n_in, H, O, vmem_budget)
    )
    raster = _pad_batch_axis(raster, 1, b_pad)

    consts = dict(
        alpha=float(alpha),
        kappa=float(kappa),
        v_th=float(v_th),
        reset_sub=(reset == "sub"),
        boxcar_width=float(boxcar_width),
        quant=quant,
    )
    out_shape = [
        jax.ShapeDtypeStruct((T, b_pad, H), dt),
        jax.ShapeDtypeStruct((T, b_pad, H), dt),
        jax.ShapeDtypeStruct((T, b_pad, n_in), dt),
        jax.ShapeDtypeStruct((T, b_pad, H), dt),
        jax.ShapeDtypeStruct((T, b_pad, H), dt),
        jax.ShapeDtypeStruct((T, b_pad, O), dt),
        jax.ShapeDtypeStruct((T, b_pad, H), dt),
    ]
    carry_scratch = [
        pltpu.VMEM((bt, H), jnp.float32),
        pltpu.VMEM((bt, H), jnp.float32),
        pltpu.VMEM((bt, O), jnp.float32),
        pltpu.VMEM((bt, n_in), jnp.float32),
        pltpu.VMEM((bt, H), jnp.float32),
        pltpu.VMEM((bt, H), jnp.float32),
    ]

    if stream == "dma":
        bitmap = _block_bitmap(raster, bt)
        kern = functools.partial(
            _forward_dma_kernel, **consts, T=T, nb=nb, bt=bt
        )
        tick_spec = lambda cols: pl.BlockSpec(
            (1, bt, cols), lambda b, t, s_ref: (t, b, 0)
        )
        full = lambda shape: pl.BlockSpec(
            shape, lambda b, t, s_ref: tuple(0 for _ in shape)
        )
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb, T),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),   # raster stays in HBM
                full((n_in, H)),
                full((H, H)),
                full((H, O)),
            ],
            out_specs=[
                tick_spec(H), tick_spec(H), tick_spec(n_in),
                tick_spec(H), tick_spec(H), tick_spec(O), tick_spec(H),
            ],
            scratch_shapes=carry_scratch + [
                pltpu.VMEM((bt, H), jnp.float32),        # input current
                pltpu.VMEM((2, bt, n_in), jnp.float32),  # event double buffer
                pltpu.SemaphoreType.DMA((2,)),
            ],
        )
        outs = pl.pallas_call(
            kern, grid_spec=grid_spec, out_shape=out_shape,
            interpret=interpret,
        )(bitmap, raster, w_in, w_rec, w_out)
    else:
        kern = functools.partial(_kernel, **consts)
        tick_spec = lambda cols: pl.BlockSpec(
            (1, bt, cols), lambda b, t: (t, b, 0)
        )
        full = lambda shape: pl.BlockSpec(
            shape, lambda b, t: tuple(0 for _ in shape)
        )
        outs = pl.pallas_call(
            kern,
            grid=(nb, T),
            in_specs=[
                tick_spec(n_in),
                full((n_in, H)),
                full((H, H)),
                full((H, O)),
            ],
            out_specs=[
                tick_spec(H), tick_spec(H), tick_spec(n_in),
                tick_spec(H), tick_spec(H), tick_spec(O), tick_spec(H),
            ],
            out_shape=out_shape,
            scratch_shapes=carry_scratch,
            interpret=interpret,
        )(raster, w_in, w_rec, w_out)
    z, h, xbar, pbar, zbar, y, v = (o[:, :B] for o in outs)
    return {"z": z, "h": h, "xbar": xbar, "pbar": pbar, "zbar": zbar, "y": y,
            "v": v}


# ---------------------------------------------------------------------------
# inference-specialized forward (inference op) — no per-tick streams
# ---------------------------------------------------------------------------


def _infer_kernel(
    raster_ref,   # (1, B, N_in)
    valid_ref,    # (1, B)
    w_in_ref,     # (N_in, H)
    w_rec_ref,    # (H, H)
    w_out_ref,    # (H, O)
    acc_y_ref,    # (B, O) out
    nspk_ref,     # (B, 1) out — valid-masked per-sample spike counts
    v_scr,        # VMEM (B, H)
    z_scr,        # VMEM (B, H)
    y_scr,        # VMEM (B, O)
    acc_scr,      # VMEM (B, O)
    nspk_scr,     # VMEM (B, 1)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    quant: Optional[QuantizedMode],
    infer_all: bool,
    T: int,
):
    t = pl.program_id(1)   # tick within the current batch tile

    # each batch tile is an independent network run: re-init at its 1st tick
    @pl.when(t == 0)
    def _init():
        v_scr[...] = jnp.zeros_like(v_scr)
        z_scr[...] = jnp.zeros_like(z_scr)
        y_scr[...] = jnp.zeros_like(y_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        nspk_scr[...] = jnp.zeros_like(nspk_scr)

    x_t = raster_ref[0]
    valid_t = valid_ref[0]                     # (B,)

    v_new, z_new, y_new, _ = tick_transition(
        x_t, v_scr[...], z_scr[...], y_scr[...],
        w_in_ref[...], w_rec_ref[...], w_out_ref[...],
        alpha=alpha, kappa=kappa, v_th=v_th, reset_sub=reset_sub,
        boxcar_width=0.5, quant=quant,
    )
    v_scr[...] = v_new
    z_scr[...] = z_new
    y_scr[...] = y_new

    w_inf = 1.0 if infer_all else valid_t[:, None]
    acc_scr[...] += y_new * w_inf
    nspk_scr[...] += (z_new * valid_t[:, None]).sum(axis=1, keepdims=True)

    # flush this batch tile's accumulators into its (Bt, ·) output blocks
    @pl.when(t == T - 1)
    def _flush():
        acc_y_ref[...] = acc_scr[...]
        nspk_ref[...] = nspk_scr[...]


def _infer_dma_kernel(
    bitmap_ref,   # (nb·T,) int32 scalar-prefetch activity bitmap
    raster_hbm,   # (T, b_pad, N_in) — stays in HBM, streamed manually
    valid_ref,    # (1, B)
    w_in_ref,     # (N_in, H)
    w_rec_ref,    # (H, H)
    w_out_ref,    # (H, O)
    acc_y_ref,    # (B, O) out
    nspk_ref,     # (B, 1) out
    v_scr,        # VMEM (B, H)
    z_scr,        # VMEM (B, H)
    y_scr,        # VMEM (B, O)
    acc_scr,      # VMEM (B, O)
    nspk_scr,     # VMEM (B, 1)
    cur_scr,      # VMEM (B, H) — this tick's input current (zeros if quiet)
    ev_scr,       # VMEM (2, B, N_in) — the double buffer
    sem,          # DMA semaphores (2,)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    quant: Optional[QuantizedMode],
    infer_all: bool,
    T: int,
    nb: int,
    bt: int,
):
    """:func:`_infer_kernel` with double-buffered event streaming and the
    in-kernel quiet-tick skip — the event-driven serving hot path."""
    b = pl.program_id(0)
    t = pl.program_id(1)
    s = b * T + t

    @pl.when(t == 0)
    def _init():
        v_scr[...] = jnp.zeros_like(v_scr)
        z_scr[...] = jnp.zeros_like(z_scr)
        y_scr[...] = jnp.zeros_like(y_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        nspk_scr[...] = jnp.zeros_like(nspk_scr)

    active, slot = _stream_events(
        bitmap_ref, raster_hbm, ev_scr, sem, s=s, total=nb * T, T=T, bt=bt
    )
    precision = None if quant is None else jax.lax.Precision.HIGHEST

    @pl.when(active)
    def _project():
        cur_scr[...] = jnp.dot(ev_scr[slot], w_in_ref[...],
                               preferred_element_type=jnp.float32,
                               precision=precision)

    @pl.when(jnp.logical_not(active))
    def _quiet():
        cur_scr[...] = jnp.zeros_like(cur_scr)

    valid_t = valid_ref[0]                     # (B,)
    v_new, z_new, y_new, _ = tick_from_input_current(
        cur_scr[...], v_scr[...], z_scr[...], y_scr[...],
        w_rec_ref[...], w_out_ref[...],
        alpha=alpha, kappa=kappa, v_th=v_th, reset_sub=reset_sub,
        boxcar_width=0.5, quant=quant,
    )
    v_scr[...] = v_new
    z_scr[...] = z_new
    y_scr[...] = y_new

    w_inf = 1.0 if infer_all else valid_t[:, None]
    acc_scr[...] += y_new * w_inf
    nspk_scr[...] += (z_new * valid_t[:, None]).sum(axis=1, keepdims=True)

    @pl.when(t == T - 1)
    def _flush():
        acc_y_ref[...] = acc_scr[...]
        nspk_ref[...] = nspk_scr[...]


def rsnn_infer(
    raster: jax.Array,   # (T, B, N_in) f32
    valid: jax.Array,    # (T, B) f32 TARGET_VALID mask
    w_in: jax.Array,     # (N_in, H)
    w_rec: jax.Array,    # (H, H) — pre-masked
    w_out: jax.Array,    # (H, O)
    *,
    alpha: float,
    kappa: float,
    v_th: float = 1.0,
    reset: str = "sub",
    quant: Optional[QuantizedMode] = None,
    infer_window: str = "valid",
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
    stream: str = "blocked",
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Inference-only forward over one ``(T, B)`` launch — the serving path.

    Runs as a batch-tiled ``grid = (ceil(B / Bt), T)``
    (:func:`max_forward_tile` sizes ``Bt`` from the VMEM budget), so serving
    batches are not VMEM-capped.  Each tile accumulates the readout
    (weighted by ``valid`` per ``infer_window``) and the valid-masked spike
    count entirely in VMEM and streams **no** per-tick tensors.  Returns
    ``(acc_y (B, O), n_spk (B, 1))`` — in quantized mode both are exact
    integers carried in f32 (bit-identical to the golden reference's
    accumulators, see ``tests/test_quant_equivalence.py``).
    """
    T, B, n_in = raster.shape
    H = w_rec.shape[0]
    O = w_out.shape[1]
    dt = raster.dtype
    if quant is not None:
        alpha, kappa, v_th = quant.alpha, quant.kappa, float(quant.threshold)
    if stream not in ("blocked", "dma"):
        raise ValueError(f"unknown stream mode {stream!r}")
    bt, nb, b_pad = _tile_batch(
        B, batch_tile or max_forward_tile(n_in, H, O, vmem_budget)
    )
    raster = _pad_batch_axis(raster, 1, b_pad)
    valid = _pad_batch_axis(valid, 1, b_pad)

    consts = dict(
        alpha=float(alpha),
        kappa=float(kappa),
        v_th=float(v_th),
        reset_sub=(reset == "sub"),
        quant=quant,
        infer_all=(infer_window == "all"),
        T=T,
    )
    out_shape = [
        jax.ShapeDtypeStruct((b_pad, O), dt),
        jax.ShapeDtypeStruct((b_pad, 1), dt),
    ]
    carry_scratch = [
        pltpu.VMEM((bt, H), jnp.float32),
        pltpu.VMEM((bt, H), jnp.float32),
        pltpu.VMEM((bt, O), jnp.float32),
        pltpu.VMEM((bt, O), jnp.float32),
        pltpu.VMEM((bt, 1), jnp.float32),
    ]

    if stream == "dma":
        bitmap = _block_bitmap(raster, bt)
        kern = functools.partial(_infer_dma_kernel, **consts, nb=nb, bt=bt)
        full = lambda shape: pl.BlockSpec(
            shape, lambda b, t, s_ref: tuple(0 for _ in shape)
        )
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb, T),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),   # raster stays in HBM
                pl.BlockSpec((1, bt), lambda b, t, s_ref: (t, b)),
                full((n_in, H)),
                full((H, H)),
                full((H, O)),
            ],
            out_specs=[
                pl.BlockSpec((bt, O), lambda b, t, s_ref: (b, 0)),
                pl.BlockSpec((bt, 1), lambda b, t, s_ref: (b, 0)),
            ],
            scratch_shapes=carry_scratch + [
                pltpu.VMEM((bt, H), jnp.float32),        # input current
                pltpu.VMEM((2, bt, n_in), jnp.float32),  # event double buffer
                pltpu.SemaphoreType.DMA((2,)),
            ],
        )
        acc_y, n_spk = pl.pallas_call(
            kern, grid_spec=grid_spec, out_shape=out_shape,
            interpret=interpret,
        )(bitmap, raster, valid, w_in, w_rec, w_out)
    else:
        kern = functools.partial(_infer_kernel, **consts)
        full = lambda shape: pl.BlockSpec(
            shape, lambda b, t: tuple(0 for _ in shape)
        )
        acc_y, n_spk = pl.pallas_call(
            kern,
            grid=(nb, T),
            in_specs=[
                pl.BlockSpec((1, bt, n_in), lambda b, t: (t, b, 0)),
                pl.BlockSpec((1, bt), lambda b, t: (t, b)),
                full((n_in, H)),
                full((H, H)),
                full((H, O)),
            ],
            out_specs=[
                pl.BlockSpec((bt, O), lambda b, t: (b, 0)),
                pl.BlockSpec((bt, 1), lambda b, t: (b, 0)),
            ],
            out_shape=out_shape,
            scratch_shapes=carry_scratch,
            interpret=interpret,
        )(raster, valid, w_in, w_rec, w_out)
    return acc_y[:B], n_spk[:B]


# ---------------------------------------------------------------------------
# session-stateful inference (step_sessions op) — carry in / carry out
# ---------------------------------------------------------------------------


def _session_kernel(
    raster_ref,   # (1, B, N_in)
    live_ref,     # (1, B) — dynamics mask (0 freezes the session this tick)
    valid_ref,    # (1, B) — readout-accumulation mask
    v0_ref,       # (B, H)  initial carries gathered from the session pool
    z0_ref,       # (B, H)
    y0_ref,       # (B, O)
    acc0_ref,     # (B, O)
    nspk0_ref,    # (B, 1)
    w_in_ref,     # (N_in, H)
    w_rec_ref,    # (H, H)
    w_out_ref,    # (H, O)
    v_out_ref,    # (B, H)  final carries, scattered back to the pool
    z_out_ref,    # (B, H)
    y_out_ref,    # (B, O)
    acc_out_ref,  # (B, O)
    nspk_out_ref, # (B, 1)
    v_scr,        # VMEM (B, H)
    z_scr,        # VMEM (B, H)
    y_scr,        # VMEM (B, O)
    acc_scr,      # VMEM (B, O)
    nspk_scr,     # VMEM (B, 1)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    quant: Optional[QuantizedMode],
    infer_all: bool,
    T: int,
):
    t = pl.program_id(1)   # tick within the current batch tile

    # unlike the whole-sample kernels, a batch tile starts from the *pool*
    # state, not zeros — load the gathered carries at its first tick
    @pl.when(t == 0)
    def _load():
        v_scr[...] = v0_ref[...]
        z_scr[...] = z0_ref[...]
        y_scr[...] = y0_ref[...]
        acc_scr[...] = acc0_ref[...]
        nspk_scr[...] = nspk0_ref[...]

    x_t = raster_ref[0]
    live_t = live_ref[0][:, None]              # (B, 1)
    valid_t = valid_ref[0][:, None]

    v_new, z_new, y_new, _ = tick_transition(
        x_t, v_scr[...], z_scr[...], y_scr[...],
        w_in_ref[...], w_rec_ref[...], w_out_ref[...],
        alpha=alpha, kappa=kappa, v_th=v_th, reset_sub=reset_sub,
        boxcar_width=0.5, quant=quant,
    )
    # live gates the dynamics: a dead tick leaves the carry untouched exactly
    # (select, not multiply — no leak is applied), so ragged per-session
    # chunk lengths pack into one rectangular tile without perturbing the
    # shorter sessions.
    keep = live_t > 0
    v_scr[...] = jnp.where(keep, v_new, v_scr[...])
    z_scr[...] = jnp.where(keep, z_new, z_scr[...])
    y_scr[...] = jnp.where(keep, y_new, y_scr[...])

    w_acc = live_t if infer_all else valid_t
    acc_scr[...] += y_new * w_acc
    nspk_scr[...] += (z_new * valid_t).sum(axis=1, keepdims=True)

    @pl.when(t == T - 1)
    def _flush():
        v_out_ref[...] = v_scr[...]
        z_out_ref[...] = z_scr[...]
        y_out_ref[...] = y_scr[...]
        acc_out_ref[...] = acc_scr[...]
        nspk_out_ref[...] = nspk_scr[...]


def _session_dma_kernel(
    bitmap_ref,   # (nb·T,) int32 scalar-prefetch activity bitmap
    raster_hbm,   # (T, b_pad, N_in) — stays in HBM, streamed manually
    live_ref,     # (1, B)
    valid_ref,    # (1, B)
    v0_ref,       # (B, H)
    z0_ref,       # (B, H)
    y0_ref,       # (B, O)
    acc0_ref,     # (B, O)
    nspk0_ref,    # (B, 1)
    w_in_ref,     # (N_in, H)
    w_rec_ref,    # (H, H)
    w_out_ref,    # (H, O)
    v_out_ref,    # (B, H)
    z_out_ref,    # (B, H)
    y_out_ref,    # (B, O)
    acc_out_ref,  # (B, O)
    nspk_out_ref, # (B, 1)
    v_scr,        # VMEM (B, H)
    z_scr,        # VMEM (B, H)
    y_scr,        # VMEM (B, O)
    acc_scr,      # VMEM (B, O)
    nspk_scr,     # VMEM (B, 1)
    cur_scr,      # VMEM (B, H) — this tick's input current (zeros if quiet)
    ev_scr,       # VMEM (2, B, N_in) — the double buffer
    sem,          # DMA semaphores (2,)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    quant: Optional[QuantizedMode],
    infer_all: bool,
    T: int,
    nb: int,
    bt: int,
):
    """:func:`_session_kernel` with double-buffered event streaming — the
    event-driven variant of the streaming-serving tick tile.  Sparse
    session traffic (idle sessions, short chunks padded into the tile)
    makes the quiet-block skip especially effective here: a tick where no
    packed session has input is neither fetched nor projected."""
    b = pl.program_id(0)
    t = pl.program_id(1)
    s = b * T + t

    @pl.when(t == 0)
    def _load():
        v_scr[...] = v0_ref[...]
        z_scr[...] = z0_ref[...]
        y_scr[...] = y0_ref[...]
        acc_scr[...] = acc0_ref[...]
        nspk_scr[...] = nspk0_ref[...]

    active, slot = _stream_events(
        bitmap_ref, raster_hbm, ev_scr, sem, s=s, total=nb * T, T=T, bt=bt
    )
    precision = None if quant is None else jax.lax.Precision.HIGHEST

    @pl.when(active)
    def _project():
        cur_scr[...] = jnp.dot(ev_scr[slot], w_in_ref[...],
                               preferred_element_type=jnp.float32,
                               precision=precision)

    @pl.when(jnp.logical_not(active))
    def _quiet():
        cur_scr[...] = jnp.zeros_like(cur_scr)

    live_t = live_ref[0][:, None]              # (B, 1)
    valid_t = valid_ref[0][:, None]

    v_new, z_new, y_new, _ = tick_from_input_current(
        cur_scr[...], v_scr[...], z_scr[...], y_scr[...],
        w_rec_ref[...], w_out_ref[...],
        alpha=alpha, kappa=kappa, v_th=v_th, reset_sub=reset_sub,
        boxcar_width=0.5, quant=quant,
    )
    keep = live_t > 0
    v_scr[...] = jnp.where(keep, v_new, v_scr[...])
    z_scr[...] = jnp.where(keep, z_new, z_scr[...])
    y_scr[...] = jnp.where(keep, y_new, y_scr[...])

    w_acc = live_t if infer_all else valid_t
    acc_scr[...] += y_new * w_acc
    nspk_scr[...] += (z_new * valid_t).sum(axis=1, keepdims=True)

    @pl.when(t == T - 1)
    def _flush():
        v_out_ref[...] = v_scr[...]
        z_out_ref[...] = z_scr[...]
        y_out_ref[...] = y_scr[...]
        acc_out_ref[...] = acc_scr[...]
        nspk_out_ref[...] = nspk_scr[...]


def rsnn_step_sessions(
    raster: jax.Array,   # (T, B, N_in) f32 — one tick-tile of B sessions
    live: jax.Array,     # (T, B) f32 dynamics mask
    valid: jax.Array,    # (T, B) f32 TARGET_VALID mask
    v0: jax.Array,       # (B, H) carried post-reset membrane
    z0: jax.Array,       # (B, H) carried previous-tick spikes
    y0: jax.Array,       # (B, O) carried LI readout membrane
    acc0: jax.Array,     # (B, O) carried readout accumulator
    nspk0: jax.Array,    # (B, 1) carried valid-masked spike count
    w_in: jax.Array,     # (N_in, H)
    w_rec: jax.Array,    # (H, H) — pre-masked
    w_out: jax.Array,    # (H, O)
    *,
    alpha: float,
    kappa: float,
    v_th: float = 1.0,
    reset: str = "sub",
    quant: Optional[QuantizedMode] = None,
    infer_window: str = "valid",
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
    stream: str = "blocked",
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Session-stateful inference over one ``(T, B)`` tick-tile — the
    streaming-serving hot path (carry in / carry out).

    A variant of :func:`rsnn_infer` whose carries are *arguments*: the tile
    starts from the gathered per-session state rows and returns the final
    ``(v, z, y, acc_y, n_spk)`` to be scattered back into the device-resident
    session pool (:class:`repro.serve.session.SessionPool`).  Batch-tiled as
    ``grid = (ceil(B / Bt), T)`` like every other kernel here; no per-tick
    HBM streams.  In quantized mode every carry is an exact integer on the
    12-bit membrane grid carried in f32, so gather → step → scatter is
    bit-true and chunk-invariant against the golden reference.
    """
    T, B, n_in = raster.shape
    H = w_rec.shape[0]
    O = w_out.shape[1]
    dt = raster.dtype
    if quant is not None:
        alpha, kappa, v_th = quant.alpha, quant.kappa, float(quant.threshold)
    if stream not in ("blocked", "dma"):
        raise ValueError(f"unknown stream mode {stream!r}")
    bt, nb, b_pad = _tile_batch(
        B, batch_tile or max_forward_tile(n_in, H, O, vmem_budget)
    )
    raster = _pad_batch_axis(raster, 1, b_pad)
    live = _pad_batch_axis(live, 1, b_pad)
    valid = _pad_batch_axis(valid, 1, b_pad)
    carries = [
        _pad_batch_axis(c, 0, b_pad) for c in (v0, z0, y0, acc0, nspk0)
    ]

    consts = dict(
        alpha=float(alpha),
        kappa=float(kappa),
        v_th=float(v_th),
        reset_sub=(reset == "sub"),
        quant=quant,
        infer_all=(infer_window == "all"),
        T=T,
    )
    out_shape = [
        jax.ShapeDtypeStruct((b_pad, H), dt),
        jax.ShapeDtypeStruct((b_pad, H), dt),
        jax.ShapeDtypeStruct((b_pad, O), dt),
        jax.ShapeDtypeStruct((b_pad, O), dt),
        jax.ShapeDtypeStruct((b_pad, 1), dt),
    ]
    carry_scratch = [
        pltpu.VMEM((bt, H), jnp.float32),
        pltpu.VMEM((bt, H), jnp.float32),
        pltpu.VMEM((bt, O), jnp.float32),
        pltpu.VMEM((bt, O), jnp.float32),
        pltpu.VMEM((bt, 1), jnp.float32),
    ]

    if stream == "dma":
        bitmap = _block_bitmap(raster, bt)
        kern = functools.partial(_session_dma_kernel, **consts, nb=nb, bt=bt)
        full = lambda shape: pl.BlockSpec(
            shape, lambda b, t, s_ref: tuple(0 for _ in shape)
        )
        row = lambda cols: pl.BlockSpec((bt, cols), lambda b, t, s_ref: (b, 0))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb, T),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),   # raster stays in HBM
                pl.BlockSpec((1, bt), lambda b, t, s_ref: (t, b)),
                pl.BlockSpec((1, bt), lambda b, t, s_ref: (t, b)),
                row(H), row(H), row(O), row(O), row(1),
                full((n_in, H)),
                full((H, H)),
                full((H, O)),
            ],
            out_specs=[row(H), row(H), row(O), row(O), row(1)],
            scratch_shapes=carry_scratch + [
                pltpu.VMEM((bt, H), jnp.float32),        # input current
                pltpu.VMEM((2, bt, n_in), jnp.float32),  # event double buffer
                pltpu.SemaphoreType.DMA((2,)),
            ],
        )
        outs = pl.pallas_call(
            kern, grid_spec=grid_spec, out_shape=out_shape,
            interpret=interpret,
        )(bitmap, raster, live, valid, *carries, w_in, w_rec, w_out)
    else:
        kern = functools.partial(_session_kernel, **consts)
        full = lambda shape: pl.BlockSpec(
            shape, lambda b, t: tuple(0 for _ in shape)
        )
        row = lambda cols: pl.BlockSpec((bt, cols), lambda b, t: (b, 0))
        outs = pl.pallas_call(
            kern,
            grid=(nb, T),
            in_specs=[
                pl.BlockSpec((1, bt, n_in), lambda b, t: (t, b, 0)),
                pl.BlockSpec((1, bt), lambda b, t: (t, b)),
                pl.BlockSpec((1, bt), lambda b, t: (t, b)),
                row(H), row(H), row(O), row(O), row(1),
                full((n_in, H)),
                full((H, H)),
                full((H, O)),
            ],
            out_specs=[row(H), row(H), row(O), row(O), row(1)],
            out_shape=out_shape,
            scratch_shapes=carry_scratch,
            interpret=interpret,
        )(raster, live, valid, *carries, w_in, w_rec, w_out)
    v, z, y, acc_y, n_spk = (o[:B] for o in outs)
    return v, z, y, acc_y, n_spk
