"""Fused RSNN-sample kernel — ReckOn's neuron-update pipeline on the MXU.

The chip walks neurons sequentially per tick, streaming membrane/trace words
from SRAM.  The TPU-native re-blocking keeps the *whole network state
resident in VMEM* across the tick loop (grid iterations execute sequentially
on a TPU core, so VMEM scratch carries state), and turns the per-neuron
MAC loop into two MXU matmuls per tick:

  grid = (T,)                       one step per AER tick
  VMEM scratch: v, z, y, xbar, pbar, zbar   (the "neuron SRAM")
  per tick: current = x_t @ W_in + z @ W_rec      (MXU)
            LIF update, boxcar pseudo-derivative   (VPU)
            y = κ·y + z_new @ W_out                (MXU)
            trace filters (α, κ)                   (VPU)

Outputs stream the per-tick quantities the factored e-prop update needs
(h, xbar, pbar, zbar, y) back to HBM — O(T·H) traffic, never O(T·H²).

ReckOn caps N_in/H at 256 ⇒ weights (256×256 f32 = 256 KiB) sit in VMEM for
the entire sample.  Batch tiles up to ~128 keep total VMEM ≲ 2 MiB — the
budget the batched serving runtime sizes its tiles against
(:func:`repro.serve.batching.max_batch_for`).  The sole consumer is the
``"kernel"`` backend of :class:`repro.core.backend.ExecutionBackend`, which
training (END_S/END_B commits), evaluation and serving all dispatch through.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional

# The kernel's VMEM contract: batch tiles up to ~128 samples keep the whole
# network state + double-buffered tick blocks ≲ 2 MiB for chip-maximal
# (256/256/16) networks.  Enforced by the execution backend for every kernel
# tile and by the serving runtime's tile sizing (repro.serve.batching).
KERNEL_SAMPLE_CAP = 128

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import QuantizedMode


def _kernel(
    raster_ref,   # (1, B, N_in) — tick t's input spikes
    w_in_ref,     # (N_in, H)
    w_rec_ref,    # (H, H)
    w_out_ref,    # (H, O)
    z_out_ref,    # (1, B, H)
    h_out_ref,    # (1, B, H)
    xbar_out_ref, # (1, B, N_in)
    pbar_out_ref, # (1, B, H)
    zbar_out_ref, # (1, B, H)
    y_out_ref,    # (1, B, O)
    v_out_ref,    # (1, B, H) — post-reset membrane trajectory
    v_scr,        # VMEM (B, H)
    z_scr,        # VMEM (B, H)
    y_scr,        # VMEM (B, O)
    xbar_scr,     # VMEM (B, N_in)
    pbar_scr,     # VMEM (B, H)
    zbar_scr,     # VMEM (B, H)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    boxcar_width: float,
    quant: Optional[QuantizedMode],
):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        v_scr[...] = jnp.zeros_like(v_scr)
        z_scr[...] = jnp.zeros_like(z_scr)
        y_scr[...] = jnp.zeros_like(y_scr)
        xbar_scr[...] = jnp.zeros_like(xbar_scr)
        pbar_scr[...] = jnp.zeros_like(pbar_scr)
        zbar_scr[...] = jnp.zeros_like(zbar_scr)

    x_t = raster_ref[0]
    z = z_scr[...]

    # Quantized mode runs the same MXU pipeline on integer values carried in
    # f32 (all exact below 2**24); Precision.HIGHEST keeps the dots exact on
    # TPU (the default f32 passes would round the >bf16-mantissa weights).
    precision = None if quant is None else jax.lax.Precision.HIGHEST
    current = jnp.dot(x_t, w_in_ref[...], preferred_element_type=jnp.float32,
                      precision=precision)
    current += jnp.dot(z, w_rec_ref[...], preferred_element_type=jnp.float32,
                       precision=precision)

    if quant is None:
        v_pre = alpha * v_scr[...] + current
    else:
        # sat(floor(v * alpha_reg/256) + current) on the signed membrane grid
        v_pre = quant.sat(quant.leak(v_scr[...], quant.alpha_reg) + current)
    z_new = (v_pre >= v_th).astype(v_pre.dtype)
    if reset_sub:
        v_new = v_pre - z_new * v_th
    else:
        v_new = v_pre * (1.0 - z_new)
    h = (jnp.abs(v_pre - v_th) < boxcar_width * v_th).astype(v_pre.dtype)

    y_lin = jnp.dot(z_new, w_out_ref[...], preferred_element_type=jnp.float32,
                    precision=precision)
    if quant is None:
        y_new = kappa * y_scr[...] + y_lin
    else:
        y_new = quant.sat(quant.leak(y_scr[...], quant.kappa_reg) + y_lin)
    xbar = alpha * xbar_scr[...] + x_t
    pbar = alpha * pbar_scr[...] + z          # presyn trace: z BEFORE this tick
    zbar = kappa * zbar_scr[...] + z_new

    v_scr[...] = v_new
    z_scr[...] = z_new
    y_scr[...] = y_new
    xbar_scr[...] = xbar
    pbar_scr[...] = pbar
    zbar_scr[...] = zbar

    z_out_ref[0] = z_new
    h_out_ref[0] = h
    xbar_out_ref[0] = xbar
    pbar_out_ref[0] = pbar
    zbar_out_ref[0] = zbar
    y_out_ref[0] = y_new
    v_out_ref[0] = v_new


def rsnn_forward(
    raster: jax.Array,   # (T, B, N_in) f32
    w_in: jax.Array,     # (N_in, H)
    w_rec: jax.Array,    # (H, H) — pre-masked
    w_out: jax.Array,    # (H, O)
    *,
    alpha: float,
    kappa: float,
    v_th: float = 1.0,
    reset: str = "sub",
    boxcar_width: float = 0.5,
    quant: Optional[QuantizedMode] = None,
    interpret: bool = False,
) -> Dict[str, jax.Array]:
    """Fused forward over one ``(T, B)`` tile; returns per-tick tensors
    (z, h, xbar, pbar, zbar, y, v — post-reset membrane trajectory).

    With ``quant`` set the tick pipeline is ReckOn's fixed-point datapath
    (saturating membrane grid, register-driven floor leaks); ``alpha``,
    ``kappa`` and ``v_th`` are then taken from the registers, and the
    caller must pass weights already on the membrane grid
    (``QuantizedMode.to_membrane`` — integer values in f32).
    """
    T, B, n_in = raster.shape
    H = w_rec.shape[0]
    O = w_out.shape[1]
    dt = raster.dtype
    if quant is not None:
        alpha, kappa, v_th = quant.alpha, quant.kappa, float(quant.threshold)

    kern = functools.partial(
        _kernel,
        alpha=float(alpha),
        kappa=float(kappa),
        v_th=float(v_th),
        reset_sub=(reset == "sub"),
        boxcar_width=float(boxcar_width),
        quant=quant,
    )
    tick_spec = lambda cols: pl.BlockSpec((1, B, cols), lambda t: (t, 0, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda t: tuple(0 for _ in shape))

    outs = pl.pallas_call(
        kern,
        grid=(T,),
        in_specs=[
            tick_spec(n_in),
            full((n_in, H)),
            full((H, H)),
            full((H, O)),
        ],
        out_specs=[
            tick_spec(H), tick_spec(H), tick_spec(n_in),
            tick_spec(H), tick_spec(H), tick_spec(O), tick_spec(H),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, B, H), dt),
            jax.ShapeDtypeStruct((T, B, H), dt),
            jax.ShapeDtypeStruct((T, B, n_in), dt),
            jax.ShapeDtypeStruct((T, B, H), dt),
            jax.ShapeDtypeStruct((T, B, H), dt),
            jax.ShapeDtypeStruct((T, B, O), dt),
            jax.ShapeDtypeStruct((T, B, H), dt),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, H), jnp.float32),
            pltpu.VMEM((B, H), jnp.float32),
            pltpu.VMEM((B, O), jnp.float32),
            pltpu.VMEM((B, n_in), jnp.float32),
            pltpu.VMEM((B, H), jnp.float32),
            pltpu.VMEM((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(raster, w_in, w_rec, w_out)
    z, h, xbar, pbar, zbar, y, v = outs
    return {"z": z, "h": h, "xbar": xbar, "pbar": pbar, "zbar": zbar, "y": y,
            "v": v}
