"""Forward-side RSNN kernels — ReckOn's neuron-update pipeline on the MXU.

The chip walks neurons sequentially per tick, streaming membrane/trace words
from SRAM.  The TPU-native re-blocking keeps the *whole network state
resident in VMEM* across the tick loop (grid iterations execute sequentially
on a TPU core, so VMEM scratch carries state), and turns the per-neuron
MAC loop into two MXU matmuls per tick:

  grid = (T,)                       one step per AER tick
  VMEM scratch: v, z, y, (xbar, pbar, zbar)  (the "neuron SRAM")
  per tick: current = x_t @ W_in + z @ W_rec      (MXU)
            LIF update, boxcar pseudo-derivative   (VPU)
            y = κ·y + z_new @ W_out                (MXU)
            trace filters (α, κ)                   (VPU)

Two op-specialized variants live here (one backend op each — see
:mod:`repro.core.backend` and the data-movement table in
``kernels/traffic.py`` / README):

* :func:`rsnn_forward` — serves the ``forward_traces`` and ``dynamics`` ops.
  Streams the per-tick quantities the *split* factored e-prop update needs
  (z, h, xbar, pbar, zbar, y, v) back to HBM — O(T·H) traffic per tick,
  never O(T·H²).  The fused ``train`` op (:func:`repro.kernels.eprop_update.
  rsnn_train`) supersedes it on the training path whenever the trace
  scratch fits VMEM.
* :func:`rsnn_infer` — serves the ``inference`` op.  Accumulates the
  valid-weighted readout and the valid-masked spike count *in VMEM* and
  streams **no** per-tick outputs: HBM writes drop from seven ``(T,B,·)``
  tensors to one ``(B,O)`` readout tile plus a ``(B,1)`` spike count — the
  serving hot path.

ReckOn caps N_in/H at 256 ⇒ weights (256×256 f32 = 256 KiB) sit in VMEM for
the entire sample.  Batches of any size run as *batch-tiled* grids —
``grid = (ceil(B / Bt), T)`` — where the tile rows ``Bt`` are derived from
the bytes-budget helpers below so one tile's state always fits VMEM.  The
grid walks batch-tile-major (all T ticks of tile 0, then tile 1, …); VMEM
scratch re-initialises at each tile's first tick, so tiles are independent
and a launch is never capped by VMEM — only its *tiles* are.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import QuantizedMode

# ---------------------------------------------------------------------------
# VMEM bytes budget — the single source of truth for tile sizing.
#
# Every tile-sizing decision in the system derives from these helpers — the
# per-tile row caps the batch-tiled kernel grids pick (max_forward_tile /
# max_fused_train_tile), the derived KERNEL_SAMPLE_CAP below, the backend's
# `tile_rows` accounting (repro.core.backend.ExecutionBackend), and the
# serving admission size (repro.serve.batching.max_batch_for).  Nothing else
# in src/ declares a tile-size constant — asserted by
# tests/test_fused_kernels.py::test_tile_sizing_single_source.
# ---------------------------------------------------------------------------

# Conservative slice of the ~16 MiB/core VMEM left to one kernel tile once
# double-buffered HBM streaming and compiler temporaries are accounted for.
DEFAULT_VMEM_BUDGET = 4 * 2**20

# The physical per-core ceiling: a tile whose scratch exceeds this cannot
# compile on any TPU regardless of how far the conservative budget is
# raised — the fused train wrapper fails loudly at trace time instead of
# surfacing an opaque compiler OOM (there is no silent fallback any more).
PHYSICAL_VMEM_CEILING = 16 * 2**20


def cdiv(a: int, b: int) -> int:
    """Ceiling division — the one tile-count idiom (grids, padding,
    traffic accounting all reuse it)."""
    return -(-a // b)

F32_BYTES = 4  # bytes per element; the kernels are f32 throughout
_F32 = F32_BYTES


def weight_elems(n_in: int, n_hid: int, n_out: int) -> int:
    """Elements in the weight set (w_in + w_rec + w_out) — shared by the
    VMEM budget below and the HBM traffic table (:mod:`repro.kernels.traffic`)."""
    return n_in * n_hid + n_hid * n_hid + n_hid * n_out


def weights_bytes(n_in: int, n_hid: int, n_out: int) -> int:
    """VMEM-resident weight bytes (w_in + w_rec + w_out, f32)."""
    return _F32 * weight_elems(n_in, n_hid, n_out)


def state_bytes_per_sample(n_in: int, n_hid: int, n_out: int) -> int:
    """VMEM bytes one batch row occupies inside the worst-case tick kernel
    (the trace-streaming :func:`rsnn_forward`): carry scratch
    (v, z, y, xbar, pbar, zbar) plus double-buffered per-tick input/output
    blocks (tick in + the seven streamed outputs)."""
    scratch = 4 * n_hid + n_out + n_in      # v,z,pbar,zbar (H) + y (O) + xbar (N)
    blocks = 5 * n_hid + 2 * n_in + n_out   # in (N) + outs z,h,xbar,pbar,zbar,y,v
    return _F32 * (scratch + 2 * blocks)


def max_batch_for_dims(
    n_in: int,
    n_hid: int,
    n_out: int,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    cap: Optional[int] = None,
) -> int:
    """Largest batch tile the VMEM budget admits for one network shape."""
    spare = vmem_budget - weights_bytes(n_in, n_hid, n_out)
    if spare <= 0:
        return 1
    b = spare // state_bytes_per_sample(n_in, n_hid, n_out)
    if cap is not None:
        b = min(cap, b)
    return int(max(1, b))


# The kernel's per-tile VMEM contract: the largest power-of-two batch tile
# a chip-maximal (256 in / 256 hid / 16 out) network fits in the default
# budget.  Derived, not hand-synced — evaluates to 128.  A per-*tile* bound,
# not a launch bound: the batch-tiled grids cut any B into tiles of at most
# this many rows, and the serving runtime's per-device admission
# (repro.serve.batching.max_batch_for) targets one such tile per device.
_CHIP_MAX_DIMS = (256, 256, 16)
KERNEL_SAMPLE_CAP = 1 << (max_batch_for_dims(*_CHIP_MAX_DIMS).bit_length() - 1)


def session_state_bytes(n_hid: int, n_out: int) -> int:
    """Device-pool bytes one resident session's carry state occupies
    (f32 rows of ``v, z (H)``, ``y, acc_y (O)`` and ``n_spk (1)``) — the
    capacity unit of the streaming serving runtime.  ``S_cap``-sizing
    (:func:`repro.serve.batching.max_sessions_for`) and the pool's own
    allocation both derive from this helper."""
    return _F32 * (2 * n_hid + 2 * n_out + 1)


def fused_train_bytes(T: int, B: int, n_in: int, n_hid: int, n_out: int) -> int:
    """VMEM bytes the fused train kernel
    (:func:`repro.kernels.eprop_update.rsnn_train`) needs for one ``(T, B)``
    tile: weights + feedback, the forward carry state, the ``(T, B, ·)``
    e-prop trace scratch (h, xbar, pbar, zbar, err — the tensors the
    two-kernel pipeline would round-trip through HBM), the three ``dw``
    accumulators, and the double-buffered tick input blocks."""
    weights = weights_bytes(n_in, n_hid, n_out) + _F32 * n_hid * n_out  # + b_fb
    carries = _F32 * B * (5 * n_hid + n_in + 2 * n_out + 1)  # v,z,pbar,zbar,f,xbar,y,acc_y,nspk
    traces = _F32 * T * B * (3 * n_hid + n_in + n_out)       # h,pbar,zbar + xbar + err
    accs = _F32 * (n_in * n_hid + n_hid * n_hid + n_hid * n_out)
    blocks = _F32 * 2 * B * (n_in + 1)                       # raster + valid tick blocks
    return weights + carries + traces + accs + blocks


def fused_train_fits(
    T: int,
    B: int,
    n_in: int,
    n_hid: int,
    n_out: int,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> bool:
    """Whether one ``(T, B)`` training tile's whole e-prop trace set fits
    the VMEM budget.  Byte test only: the batch-tiled train grid runs a
    fitting batch as a single tile *up to* ``KERNEL_SAMPLE_CAP`` rows —
    above the cap it still tiles even when the bytes would fit
    (``max_fused_train_tile`` applies both bounds)."""
    return fused_train_bytes(T, B, n_in, n_hid, n_out) <= vmem_budget


def max_forward_tile(
    n_in: int, n_hid: int, n_out: int, vmem_budget: int = DEFAULT_VMEM_BUDGET
) -> int:
    """Batch rows per tile of the batch-tiled forward/inference/update grids
    (``grid = (ceil(B / Bt), T)``), derived from the VMEM budget and capped
    by the kernel contract."""
    return max_batch_for_dims(
        n_in, n_hid, n_out, vmem_budget, cap=KERNEL_SAMPLE_CAP
    )


def max_fused_train_tile(
    T: int,
    n_in: int,
    n_hid: int,
    n_out: int,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> int:
    """Batch rows per tile of the batch-tiled fused train grid
    (``grid = (ceil(B / Bt), 2T)``): the largest ``Bt`` whose whole-trace
    scratch (:func:`fused_train_bytes`, linear in B) fits the budget.

    Clamped to ``>= 1``: the budget is a conservative slice of physical
    VMEM, so a single-sample tile that nominally overflows it (chip-maximal
    ``T``) still compiles in practice — there is no fallback pipeline to
    fall back to any more.  Capped by the kernel contract above.
    """
    fixed = fused_train_bytes(T, 0, n_in, n_hid, n_out)
    per_row = fused_train_bytes(T, 1, n_in, n_hid, n_out) - fixed
    b = (vmem_budget - fixed) // per_row
    return int(max(1, min(KERNEL_SAMPLE_CAP, b)))


def _tile_batch(
    B: int, tile: int
) -> Tuple[int, int, int]:
    """``(Bt, num_tiles, padded_B)`` for one launch: tile rows never exceed
    the batch, and the batch axis is zero-padded up to a whole number of
    tiles (padding rows carry zero input and zero valid — inert by the
    masking invariants, sliced off by the wrappers)."""
    bt = max(1, min(tile, B))
    nb = cdiv(B, bt)
    return bt, nb, nb * bt


def _pad_batch_axis(x: jax.Array, axis: int, target: int) -> jax.Array:
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# shared tick datapath
# ---------------------------------------------------------------------------


def tick_transition(
    x_t: jax.Array,     # (B, N_in) input spikes this tick
    v: jax.Array,       # (B, H) post-reset membrane
    z: jax.Array,       # (B, H) spikes from the previous tick
    y: jax.Array,       # (B, O) readout membrane
    w_in: jax.Array,    # (N_in, H)
    w_rec: jax.Array,   # (H, H) — pre-masked
    w_out: jax.Array,   # (H, O)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    boxcar_width: float,
    quant: Optional[QuantizedMode],
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One LIF + LI tick on the MXU/VPU — the datapath every RSNN kernel
    (forward, inference-only, fused train) shares.

    Returns ``(v_new, z_new, y_new, h)`` with ``h`` the boxcar
    pseudo-derivative evaluated at the pre-reset membrane.

    Quantized mode runs the same MXU pipeline on integer values carried in
    f32 (all exact below 2**24); ``Precision.HIGHEST`` keeps the dots exact
    on TPU (the default f32 passes would round the >bf16-mantissa weights).
    """
    precision = None if quant is None else jax.lax.Precision.HIGHEST
    current = jnp.dot(x_t, w_in, preferred_element_type=jnp.float32,
                      precision=precision)
    current += jnp.dot(z, w_rec, preferred_element_type=jnp.float32,
                       precision=precision)

    if quant is None:
        v_pre = alpha * v + current
    else:
        # sat(floor(v * alpha_reg/256) + current) on the signed membrane grid
        v_pre = quant.sat(quant.leak(v, quant.alpha_reg) + current)
    z_new = (v_pre >= v_th).astype(v_pre.dtype)
    if reset_sub:
        v_new = v_pre - z_new * v_th
    else:
        v_new = v_pre * (1.0 - z_new)
    h = (jnp.abs(v_pre - v_th) < boxcar_width * v_th).astype(v_pre.dtype)

    y_lin = jnp.dot(z_new, w_out, preferred_element_type=jnp.float32,
                    precision=precision)
    if quant is None:
        y_new = kappa * y + y_lin
    else:
        y_new = quant.sat(quant.leak(y, quant.kappa_reg) + y_lin)
    return v_new, z_new, y_new, h


# ---------------------------------------------------------------------------
# trace-streaming forward (forward_traces / dynamics ops)
# ---------------------------------------------------------------------------


def _kernel(
    raster_ref,   # (1, B, N_in) — tick t's input spikes
    w_in_ref,     # (N_in, H)
    w_rec_ref,    # (H, H)
    w_out_ref,    # (H, O)
    z_out_ref,    # (1, B, H)
    h_out_ref,    # (1, B, H)
    xbar_out_ref, # (1, B, N_in)
    pbar_out_ref, # (1, B, H)
    zbar_out_ref, # (1, B, H)
    y_out_ref,    # (1, B, O)
    v_out_ref,    # (1, B, H) — post-reset membrane trajectory
    v_scr,        # VMEM (B, H)
    z_scr,        # VMEM (B, H)
    y_scr,        # VMEM (B, O)
    xbar_scr,     # VMEM (B, N_in)
    pbar_scr,     # VMEM (B, H)
    zbar_scr,     # VMEM (B, H)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    boxcar_width: float,
    quant: Optional[QuantizedMode],
):
    t = pl.program_id(1)   # tick within the current batch tile

    # each batch tile is an independent network run: re-init at its 1st tick
    @pl.when(t == 0)
    def _init():
        v_scr[...] = jnp.zeros_like(v_scr)
        z_scr[...] = jnp.zeros_like(z_scr)
        y_scr[...] = jnp.zeros_like(y_scr)
        xbar_scr[...] = jnp.zeros_like(xbar_scr)
        pbar_scr[...] = jnp.zeros_like(pbar_scr)
        zbar_scr[...] = jnp.zeros_like(zbar_scr)

    x_t = raster_ref[0]
    z = z_scr[...]

    v_new, z_new, y_new, h = tick_transition(
        x_t, v_scr[...], z, y_scr[...],
        w_in_ref[...], w_rec_ref[...], w_out_ref[...],
        alpha=alpha, kappa=kappa, v_th=v_th, reset_sub=reset_sub,
        boxcar_width=boxcar_width, quant=quant,
    )
    xbar = alpha * xbar_scr[...] + x_t
    pbar = alpha * pbar_scr[...] + z          # presyn trace: z BEFORE this tick
    zbar = kappa * zbar_scr[...] + z_new

    v_scr[...] = v_new
    z_scr[...] = z_new
    y_scr[...] = y_new
    xbar_scr[...] = xbar
    pbar_scr[...] = pbar
    zbar_scr[...] = zbar

    z_out_ref[0] = z_new
    h_out_ref[0] = h
    xbar_out_ref[0] = xbar
    pbar_out_ref[0] = pbar
    zbar_out_ref[0] = zbar
    y_out_ref[0] = y_new
    v_out_ref[0] = v_new


def rsnn_forward(
    raster: jax.Array,   # (T, B, N_in) f32
    w_in: jax.Array,     # (N_in, H)
    w_rec: jax.Array,    # (H, H) — pre-masked
    w_out: jax.Array,    # (H, O)
    *,
    alpha: float,
    kappa: float,
    v_th: float = 1.0,
    reset: str = "sub",
    boxcar_width: float = 0.5,
    quant: Optional[QuantizedMode] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
    interpret: bool = False,
) -> Dict[str, jax.Array]:
    """Fused forward over one ``(T, B)`` launch; returns per-tick tensors
    (z, h, xbar, pbar, zbar, y, v — post-reset membrane trajectory).

    The launch runs as a batch-tiled ``grid = (ceil(B / Bt), T)`` with
    ``Bt`` derived from the VMEM budget (:func:`max_forward_tile`, or the
    explicit ``batch_tile`` override), so ``B`` is unbounded — only a tile
    must fit VMEM.  This is the *trace-streaming* variant: it serves the
    backend's ``forward_traces`` op (split-pipeline training) and the
    ``dynamics`` probe.  The ``inference`` op uses :func:`rsnn_infer` (no
    per-tick streams); the ``train`` op always uses
    :func:`repro.kernels.eprop_update.rsnn_train`, which tiles the same way.

    With ``quant`` set the tick pipeline is ReckOn's fixed-point datapath
    (saturating membrane grid, register-driven floor leaks); ``alpha``,
    ``kappa`` and ``v_th`` are then taken from the registers, and the
    caller must pass weights already on the membrane grid
    (``QuantizedMode.to_membrane`` — integer values in f32).
    """
    T, B, n_in = raster.shape
    H = w_rec.shape[0]
    O = w_out.shape[1]
    dt = raster.dtype
    if quant is not None:
        alpha, kappa, v_th = quant.alpha, quant.kappa, float(quant.threshold)
    bt, nb, b_pad = _tile_batch(
        B, batch_tile or max_forward_tile(n_in, H, O, vmem_budget)
    )
    raster = _pad_batch_axis(raster, 1, b_pad)

    kern = functools.partial(
        _kernel,
        alpha=float(alpha),
        kappa=float(kappa),
        v_th=float(v_th),
        reset_sub=(reset == "sub"),
        boxcar_width=float(boxcar_width),
        quant=quant,
    )
    tick_spec = lambda cols: pl.BlockSpec((1, bt, cols), lambda b, t: (t, b, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda b, t: tuple(0 for _ in shape))

    outs = pl.pallas_call(
        kern,
        grid=(nb, T),
        in_specs=[
            tick_spec(n_in),
            full((n_in, H)),
            full((H, H)),
            full((H, O)),
        ],
        out_specs=[
            tick_spec(H), tick_spec(H), tick_spec(n_in),
            tick_spec(H), tick_spec(H), tick_spec(O), tick_spec(H),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, b_pad, H), dt),
            jax.ShapeDtypeStruct((T, b_pad, H), dt),
            jax.ShapeDtypeStruct((T, b_pad, n_in), dt),
            jax.ShapeDtypeStruct((T, b_pad, H), dt),
            jax.ShapeDtypeStruct((T, b_pad, H), dt),
            jax.ShapeDtypeStruct((T, b_pad, O), dt),
            jax.ShapeDtypeStruct((T, b_pad, H), dt),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt, H), jnp.float32),
            pltpu.VMEM((bt, H), jnp.float32),
            pltpu.VMEM((bt, O), jnp.float32),
            pltpu.VMEM((bt, n_in), jnp.float32),
            pltpu.VMEM((bt, H), jnp.float32),
            pltpu.VMEM((bt, H), jnp.float32),
        ],
        interpret=interpret,
    )(raster, w_in, w_rec, w_out)
    z, h, xbar, pbar, zbar, y, v = (o[:, :B] for o in outs)
    return {"z": z, "h": h, "xbar": xbar, "pbar": pbar, "zbar": zbar, "y": y,
            "v": v}


# ---------------------------------------------------------------------------
# inference-specialized forward (inference op) — no per-tick streams
# ---------------------------------------------------------------------------


def _infer_kernel(
    raster_ref,   # (1, B, N_in)
    valid_ref,    # (1, B)
    w_in_ref,     # (N_in, H)
    w_rec_ref,    # (H, H)
    w_out_ref,    # (H, O)
    acc_y_ref,    # (B, O) out
    nspk_ref,     # (B, 1) out — valid-masked per-sample spike counts
    v_scr,        # VMEM (B, H)
    z_scr,        # VMEM (B, H)
    y_scr,        # VMEM (B, O)
    acc_scr,      # VMEM (B, O)
    nspk_scr,     # VMEM (B, 1)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    quant: Optional[QuantizedMode],
    infer_all: bool,
    T: int,
):
    t = pl.program_id(1)   # tick within the current batch tile

    # each batch tile is an independent network run: re-init at its 1st tick
    @pl.when(t == 0)
    def _init():
        v_scr[...] = jnp.zeros_like(v_scr)
        z_scr[...] = jnp.zeros_like(z_scr)
        y_scr[...] = jnp.zeros_like(y_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        nspk_scr[...] = jnp.zeros_like(nspk_scr)

    x_t = raster_ref[0]
    valid_t = valid_ref[0]                     # (B,)

    v_new, z_new, y_new, _ = tick_transition(
        x_t, v_scr[...], z_scr[...], y_scr[...],
        w_in_ref[...], w_rec_ref[...], w_out_ref[...],
        alpha=alpha, kappa=kappa, v_th=v_th, reset_sub=reset_sub,
        boxcar_width=0.5, quant=quant,
    )
    v_scr[...] = v_new
    z_scr[...] = z_new
    y_scr[...] = y_new

    w_inf = 1.0 if infer_all else valid_t[:, None]
    acc_scr[...] += y_new * w_inf
    nspk_scr[...] += (z_new * valid_t[:, None]).sum(axis=1, keepdims=True)

    # flush this batch tile's accumulators into its (Bt, ·) output blocks
    @pl.when(t == T - 1)
    def _flush():
        acc_y_ref[...] = acc_scr[...]
        nspk_ref[...] = nspk_scr[...]


def rsnn_infer(
    raster: jax.Array,   # (T, B, N_in) f32
    valid: jax.Array,    # (T, B) f32 TARGET_VALID mask
    w_in: jax.Array,     # (N_in, H)
    w_rec: jax.Array,    # (H, H) — pre-masked
    w_out: jax.Array,    # (H, O)
    *,
    alpha: float,
    kappa: float,
    v_th: float = 1.0,
    reset: str = "sub",
    quant: Optional[QuantizedMode] = None,
    infer_window: str = "valid",
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Inference-only forward over one ``(T, B)`` launch — the serving path.

    Runs as a batch-tiled ``grid = (ceil(B / Bt), T)``
    (:func:`max_forward_tile` sizes ``Bt`` from the VMEM budget), so serving
    batches are not VMEM-capped.  Each tile accumulates the readout
    (weighted by ``valid`` per ``infer_window``) and the valid-masked spike
    count entirely in VMEM and streams **no** per-tick tensors.  Returns
    ``(acc_y (B, O), n_spk (B, 1))`` — in quantized mode both are exact
    integers carried in f32 (bit-identical to the golden reference's
    accumulators, see ``tests/test_quant_equivalence.py``).
    """
    T, B, n_in = raster.shape
    H = w_rec.shape[0]
    O = w_out.shape[1]
    dt = raster.dtype
    if quant is not None:
        alpha, kappa, v_th = quant.alpha, quant.kappa, float(quant.threshold)
    bt, nb, b_pad = _tile_batch(
        B, batch_tile or max_forward_tile(n_in, H, O, vmem_budget)
    )
    raster = _pad_batch_axis(raster, 1, b_pad)
    valid = _pad_batch_axis(valid, 1, b_pad)

    kern = functools.partial(
        _infer_kernel,
        alpha=float(alpha),
        kappa=float(kappa),
        v_th=float(v_th),
        reset_sub=(reset == "sub"),
        quant=quant,
        infer_all=(infer_window == "all"),
        T=T,
    )
    full = lambda shape: pl.BlockSpec(shape, lambda b, t: tuple(0 for _ in shape))

    acc_y, n_spk = pl.pallas_call(
        kern,
        grid=(nb, T),
        in_specs=[
            pl.BlockSpec((1, bt, n_in), lambda b, t: (t, b, 0)),
            pl.BlockSpec((1, bt), lambda b, t: (t, b)),
            full((n_in, H)),
            full((H, H)),
            full((H, O)),
        ],
        out_specs=[
            pl.BlockSpec((bt, O), lambda b, t: (b, 0)),
            pl.BlockSpec((bt, 1), lambda b, t: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, O), dt),
            jax.ShapeDtypeStruct((b_pad, 1), dt),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt, H), jnp.float32),
            pltpu.VMEM((bt, H), jnp.float32),
            pltpu.VMEM((bt, O), jnp.float32),
            pltpu.VMEM((bt, O), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
        ],
        interpret=interpret,
    )(raster, valid, w_in, w_rec, w_out)
    return acc_y[:B], n_spk[:B]


# ---------------------------------------------------------------------------
# session-stateful inference (step_sessions op) — carry in / carry out
# ---------------------------------------------------------------------------


def _session_kernel(
    raster_ref,   # (1, B, N_in)
    live_ref,     # (1, B) — dynamics mask (0 freezes the session this tick)
    valid_ref,    # (1, B) — readout-accumulation mask
    v0_ref,       # (B, H)  initial carries gathered from the session pool
    z0_ref,       # (B, H)
    y0_ref,       # (B, O)
    acc0_ref,     # (B, O)
    nspk0_ref,    # (B, 1)
    w_in_ref,     # (N_in, H)
    w_rec_ref,    # (H, H)
    w_out_ref,    # (H, O)
    v_out_ref,    # (B, H)  final carries, scattered back to the pool
    z_out_ref,    # (B, H)
    y_out_ref,    # (B, O)
    acc_out_ref,  # (B, O)
    nspk_out_ref, # (B, 1)
    v_scr,        # VMEM (B, H)
    z_scr,        # VMEM (B, H)
    y_scr,        # VMEM (B, O)
    acc_scr,      # VMEM (B, O)
    nspk_scr,     # VMEM (B, 1)
    *,
    alpha: float,
    kappa: float,
    v_th: float,
    reset_sub: bool,
    quant: Optional[QuantizedMode],
    infer_all: bool,
    T: int,
):
    t = pl.program_id(1)   # tick within the current batch tile

    # unlike the whole-sample kernels, a batch tile starts from the *pool*
    # state, not zeros — load the gathered carries at its first tick
    @pl.when(t == 0)
    def _load():
        v_scr[...] = v0_ref[...]
        z_scr[...] = z0_ref[...]
        y_scr[...] = y0_ref[...]
        acc_scr[...] = acc0_ref[...]
        nspk_scr[...] = nspk0_ref[...]

    x_t = raster_ref[0]
    live_t = live_ref[0][:, None]              # (B, 1)
    valid_t = valid_ref[0][:, None]

    v_new, z_new, y_new, _ = tick_transition(
        x_t, v_scr[...], z_scr[...], y_scr[...],
        w_in_ref[...], w_rec_ref[...], w_out_ref[...],
        alpha=alpha, kappa=kappa, v_th=v_th, reset_sub=reset_sub,
        boxcar_width=0.5, quant=quant,
    )
    # live gates the dynamics: a dead tick leaves the carry untouched exactly
    # (select, not multiply — no leak is applied), so ragged per-session
    # chunk lengths pack into one rectangular tile without perturbing the
    # shorter sessions.
    keep = live_t > 0
    v_scr[...] = jnp.where(keep, v_new, v_scr[...])
    z_scr[...] = jnp.where(keep, z_new, z_scr[...])
    y_scr[...] = jnp.where(keep, y_new, y_scr[...])

    w_acc = live_t if infer_all else valid_t
    acc_scr[...] += y_new * w_acc
    nspk_scr[...] += (z_new * valid_t).sum(axis=1, keepdims=True)

    @pl.when(t == T - 1)
    def _flush():
        v_out_ref[...] = v_scr[...]
        z_out_ref[...] = z_scr[...]
        y_out_ref[...] = y_scr[...]
        acc_out_ref[...] = acc_scr[...]
        nspk_out_ref[...] = nspk_scr[...]


def rsnn_step_sessions(
    raster: jax.Array,   # (T, B, N_in) f32 — one tick-tile of B sessions
    live: jax.Array,     # (T, B) f32 dynamics mask
    valid: jax.Array,    # (T, B) f32 TARGET_VALID mask
    v0: jax.Array,       # (B, H) carried post-reset membrane
    z0: jax.Array,       # (B, H) carried previous-tick spikes
    y0: jax.Array,       # (B, O) carried LI readout membrane
    acc0: jax.Array,     # (B, O) carried readout accumulator
    nspk0: jax.Array,    # (B, 1) carried valid-masked spike count
    w_in: jax.Array,     # (N_in, H)
    w_rec: jax.Array,    # (H, H) — pre-masked
    w_out: jax.Array,    # (H, O)
    *,
    alpha: float,
    kappa: float,
    v_th: float = 1.0,
    reset: str = "sub",
    quant: Optional[QuantizedMode] = None,
    infer_window: str = "valid",
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    batch_tile: Optional[int] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Session-stateful inference over one ``(T, B)`` tick-tile — the
    streaming-serving hot path (carry in / carry out).

    A variant of :func:`rsnn_infer` whose carries are *arguments*: the tile
    starts from the gathered per-session state rows and returns the final
    ``(v, z, y, acc_y, n_spk)`` to be scattered back into the device-resident
    session pool (:class:`repro.serve.session.SessionPool`).  Batch-tiled as
    ``grid = (ceil(B / Bt), T)`` like every other kernel here; no per-tick
    HBM streams.  In quantized mode every carry is an exact integer on the
    12-bit membrane grid carried in f32, so gather → step → scatter is
    bit-true and chunk-invariant against the golden reference.
    """
    T, B, n_in = raster.shape
    H = w_rec.shape[0]
    O = w_out.shape[1]
    dt = raster.dtype
    if quant is not None:
        alpha, kappa, v_th = quant.alpha, quant.kappa, float(quant.threshold)
    bt, nb, b_pad = _tile_batch(
        B, batch_tile or max_forward_tile(n_in, H, O, vmem_budget)
    )
    raster = _pad_batch_axis(raster, 1, b_pad)
    live = _pad_batch_axis(live, 1, b_pad)
    valid = _pad_batch_axis(valid, 1, b_pad)
    carries = [
        _pad_batch_axis(c, 0, b_pad) for c in (v0, z0, y0, acc0, nspk0)
    ]

    kern = functools.partial(
        _session_kernel,
        alpha=float(alpha),
        kappa=float(kappa),
        v_th=float(v_th),
        reset_sub=(reset == "sub"),
        quant=quant,
        infer_all=(infer_window == "all"),
        T=T,
    )
    full = lambda shape: pl.BlockSpec(shape, lambda b, t: tuple(0 for _ in shape))
    row = lambda cols: pl.BlockSpec((bt, cols), lambda b, t: (b, 0))

    outs = pl.pallas_call(
        kern,
        grid=(nb, T),
        in_specs=[
            pl.BlockSpec((1, bt, n_in), lambda b, t: (t, b, 0)),
            pl.BlockSpec((1, bt), lambda b, t: (t, b)),
            pl.BlockSpec((1, bt), lambda b, t: (t, b)),
            row(H), row(H), row(O), row(O), row(1),
            full((n_in, H)),
            full((H, H)),
            full((H, O)),
        ],
        out_specs=[row(H), row(H), row(O), row(O), row(1)],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, H), dt),
            jax.ShapeDtypeStruct((b_pad, H), dt),
            jax.ShapeDtypeStruct((b_pad, O), dt),
            jax.ShapeDtypeStruct((b_pad, O), dt),
            jax.ShapeDtypeStruct((b_pad, 1), dt),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt, H), jnp.float32),
            pltpu.VMEM((bt, H), jnp.float32),
            pltpu.VMEM((bt, O), jnp.float32),
            pltpu.VMEM((bt, O), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
        ],
        interpret=interpret,
    )(raster, live, valid, *carries, w_in, w_rec, w_out)
    v, z, y, acc_y, n_spk = (o[:B] for o in outs)
    return v, z, y, acc_y, n_spk
