"""MoE dispatch correctness: the capacity/sort dispatch must equal the dense
soft-combine oracle when capacity is large enough that nothing drops."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as moe_mod
from repro.models.moe import MoEConfig


@dataclasses.dataclass(frozen=True)
class _Cfg:
    d_model: int
    moe: MoEConfig
    np_dtype: object = jnp.float32


def _setup(key, n_experts=8, top_k=2, d=16, f=32, B=2, S=24, cf=8.0, n_shared=0):
    cfg = _Cfg(d_model=d, moe=MoEConfig(
        n_experts=n_experts, top_k=top_k, d_ff_expert=f,
        capacity_factor=cf, n_shared=n_shared,
    ))
    p = moe_mod.init_moe(key, cfg)
    params = jax.tree.map(lambda l: l[0] if isinstance(l, tuple) else l, p,
                          is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d)) * 0.5
    return cfg, params, x


@pytest.mark.parametrize("n_experts,top_k", [(4, 1), (8, 2), (8, 6)])
def test_dispatch_matches_dense_oracle(n_experts, top_k):
    cfg, params, x = _setup(jax.random.key(0), n_experts, top_k)
    y, aux = moe_mod.moe_forward(params, x, cfg)
    y_ref = moe_mod.moe_forward_dense_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


def test_shared_experts_added():
    cfg, params, x = _setup(jax.random.key(1), n_shared=2)
    y, _ = moe_mod.moe_forward(params, x, cfg)
    y_ref = moe_mod.moe_forward_dense_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens():
    """With capacity_factor << 1 some tokens must be dropped (zero output),
    never mis-routed."""
    cfg, params, x = _setup(jax.random.key(2), cf=0.25)
    y, _ = moe_mod.moe_forward(params, x, cfg)
    y_ref = moe_mod.moe_forward_dense_ref(params, x, cfg)
    diff = np.abs(np.asarray(y) - np.asarray(y_ref)).max(axis=-1).ravel()
    matches = (diff < 2e-4)
    # some tokens routed fully, some dropped — but y is finite everywhere
    assert np.isfinite(np.asarray(y)).all()
    assert matches.sum() >= 1


def test_router_gates_normalised():
    xf = jax.random.normal(jax.random.key(3), (64, 16))
    w = jax.random.normal(jax.random.key(4), (16, 8))
    gates, experts, aux, z = moe_mod._route(xf, w, 3)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(experts.max()) < 8
    assert float(aux) >= 1.0 - 1e-3   # ≥1 by Cauchy-Schwarz, =1 when balanced


def test_grouped_dispatch_matches_global():
    """§Perf lever: dp-grouped dispatch must be numerically identical to the
    global-sort dispatch (same gates, per-group capacity ≥ demand)."""
    cfg, params, x = _setup(jax.random.key(7), n_experts=8, top_k=2, cf=8.0)
    y0, _ = moe_mod.moe_forward(params, x, cfg)
    cfg_g = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=8))
    yg, _ = moe_mod.moe_forward(params, x, cfg_g)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yg), rtol=2e-4, atol=2e-4)


def test_grad_flows_through_dispatch():
    cfg, params, x = _setup(jax.random.key(5))

    def loss(p):
        y, aux = moe_mod.moe_forward(p, x, cfg)
        return (y.astype(jnp.float32) ** 2).mean() + aux

    g = jax.grad(loss)(params)
    total = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0
