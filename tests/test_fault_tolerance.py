"""Fault-tolerant online learning (ISSUE 9): bit-exact checkpoint/resume,
replay cursors, elastic mesh resize, and the chaos harness.

The headline gate: a Braille END_B training run SIGKILL-ed at randomized
commit boundaries (and mid-save, leaving torn ``.tmp`` dirs), restarted from
its checkpoints, must finish with final quantized weights **bitwise
identical** to an uninterrupted run — on the same mesh always, and across an
8→4 device shrink when the integer commit grid
(:data:`repro.core.quant.DW_COMMIT_SPEC`) is armed.
"""

import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import ExecutionBackend, RuntimeConfig
from repro.core.quant import DW_COMMIT_SPEC, WEIGHT_SPEC, QuantizedMode
from repro.core.rsnn import Presets, init_params
from repro.data.braille import BrailleConfig, make_braille_dataset
from repro.data.pipeline import EventStream, make_pipeline
from repro.distributed.checkpoint import (
    CheckpointManager,
    CheckpointPolicy,
    ReplayCursor,
)
from repro.optim.eprop_opt import EpropSGD, EpropSGDConfig
from repro.train import chaos
from repro.train.eprop_step import epoch_batches

# ------------------------------------------------------------------ manager


def _tree():
    return {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.ones((4,), np.int32)}


def test_async_save_error_surfaces_at_next_save(tmp_path, monkeypatch):
    """A failed background write is re-raised at the *next* save entry —
    blocking or async — not silently swallowed until an explicit wait()."""
    from repro.distributed import checkpoint as ckpt_mod

    mgr = CheckpointManager(tmp_path, keep=0)
    mgr.save(1, _tree())

    real = ckpt_mod.np.savez

    def boom(*a, **kw):
        raise OSError("disk gone")

    monkeypatch.setattr(ckpt_mod.np, "savez", boom)
    mgr.save_async(2, _tree())          # fails on the writer thread
    mgr._queue.join()                   # let the failure land (no raise yet)
    monkeypatch.setattr(ckpt_mod.np, "savez", real)
    with pytest.raises(OSError, match="disk gone"):
        mgr.save_async(3, _tree())      # surfaced here, at the next save
    mgr.wait()
    mgr.save_async(4, _tree())          # error was cleared once raised
    mgr.wait()

    monkeypatch.setattr(ckpt_mod.np, "savez", boom)
    mgr.save_async(5, _tree())
    mgr._queue.join()
    monkeypatch.setattr(ckpt_mod.np, "savez", real)
    with pytest.raises(OSError, match="disk gone"):
        mgr.save(6, _tree())            # blocking entry surfaces it too
    assert mgr.latest_step() == 4       # torn steps never became restorable


def test_prune_keep_zero_keeps_all(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=0)
    for s in range(1, 6):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [1, 2, 3, 4, 5]

    mgr3 = CheckpointManager(tmp_path / "k3", keep=3)
    for s in range(1, 6):
        mgr3.save(s, _tree())
    assert mgr3.all_steps() == [3, 4, 5]


def test_restore_validates_every_leaf(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())

    bad_shape = {"a": np.zeros((3, 2), np.float32), "b": np.ones((4,), np.int32)}
    with pytest.raises(ValueError, match=r"\['a'\]"):
        mgr.restore(1, bad_shape)

    bad_dtype = {"a": np.zeros((2, 3), np.float32), "b": np.ones((4,), np.float32)}
    with pytest.raises(ValueError, match=r"\['b'\].*int32"):
        mgr.restore(1, bad_dtype)

    with pytest.raises(KeyError, match="missing leaf"):
        mgr.restore(1, {"a": np.zeros((2, 3), np.float32),
                        "c": np.zeros((1,), np.float32)})

    tree, manifest = mgr.restore(1, _tree())
    assert manifest["step"] == 1
    np.testing.assert_array_equal(tree["a"], _tree()["a"])


def test_torn_tmp_and_corrupt_latest_fall_back(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=0)
    mgr.save(1, _tree())
    mgr.save(2, _tree())

    # a crashed process left a torn .tmp and scribbled over LATEST
    torn = tmp_path / "step_000000007.tmp"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"partial garbage")
    (tmp_path / "LATEST").write_text("step_not_a_number")

    mgr2 = CheckpointManager(tmp_path, keep=0)
    assert not torn.exists()                 # swept at construction
    assert mgr2.latest_step() == 2           # newest *complete* step wins
    assert mgr2.all_steps() == [1, 2]

    # stale pointer at a pruned/deleted step also falls back
    (tmp_path / "LATEST").write_text("step_000000099")
    assert mgr2.latest_step() == 2


def test_quantized_residuals_roundtrip_bitwise(tmp_path):
    """EpropSGD quantized state (int-exact weight grid + float residual
    accumulators + int32 sample count) survives a save/restore bit-for-bit."""
    opt = EpropSGD(EpropSGDConfig(lr=0.01, quant=WEIGHT_SPEC,
                                  stochastic_round=True))
    w = opt.quantize_init({"w": jnp.asarray(
        np.random.default_rng(0).normal(0, 0.3, (6, 5)).astype(np.float32))})
    state = opt.init(w)
    key = jax.random.key(0)
    for i in range(5):
        key, sub = jax.random.split(key)
        dw = {"w": jnp.asarray(
            np.random.default_rng(i).normal(0, 1e-2, (6, 5)).astype(np.float32))}
        w, state = opt.update(w, dw, state, sub)
    assert state["count"].dtype == jnp.int32 and int(state["count"]) == 5

    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": w, "state": state})
    back, _ = mgr.restore(1, jax.tree.map(
        np.asarray, jax.device_get({"w": w, "state": state})))
    for leaf, orig in zip(jax.tree.leaves(back),
                          jax.tree.leaves({"w": w, "state": state})):
        np.testing.assert_array_equal(leaf, np.asarray(orig))


# ------------------------------------------------------------------- cursors


def _pipe(seed=3, spb=8):
    data = make_braille_dataset(
        "AEU", BrailleConfig(samples_per_class=8, num_ticks=24))
    return make_pipeline("arm", data, samples_per_batch=spb,
                         shuffle_train=True, seed=seed), data


def test_pipeline_order_pure_in_seed_epoch():
    pipe, _ = _pipe()
    o1 = pipe._order("train", 24, epoch=2)
    # consuming other epochs must not perturb epoch 2's order
    pipe._order("train", 24, epoch=0)
    pipe._order("train", 24, epoch=1)
    o2 = pipe._order("train", 24, epoch=2)
    np.testing.assert_array_equal(o1, o2)
    assert not np.array_equal(o1, pipe._order("train", 24, epoch=3))


def test_pipeline_start_batch_replays_exact_suffix():
    pipe, _ = _pipe()
    full = [np.asarray(b["label"]) for b in pipe.batches("train", 1)]
    t0 = pipe.stats.transfers
    tail = [np.asarray(b["label"]) for b in
            pipe.batches("train", 1, start_batch=2)]
    assert len(tail) == len(full) - 2
    for a, b in zip(full[2:], tail):
        np.testing.assert_array_equal(a, b)
    # skipped batches were never offloaded
    assert pipe.stats.transfers - t0 == len(tail)


def test_event_stream_cursor_roundtrip():
    _, data = _pipe()
    s1 = EventStream(data, "test", repeat=2, shuffle=True, seed=5)
    it = iter(s1)
    consumed = [next(it) for _ in range(7)]
    assert len(consumed) == 7
    state = s1.state()

    s2 = EventStream(data, "test", repeat=2, shuffle=True, seed=5)
    s2.seek(state)
    rest_replayed = list(s2)
    rest_original = list(it)
    assert len(rest_replayed) == len(rest_original) == len(s1) - 7
    for a, b in zip(rest_original, rest_replayed):
        np.testing.assert_array_equal(a, b)

    with pytest.raises(ValueError, match="seed"):
        EventStream(data, "test", seed=6).seek(state)


def test_epoch_batches_cursor_manifest_roundtrip(tmp_path):
    pipe, _ = _pipe()
    cur = ReplayCursor()
    it = epoch_batches(pipe, max_epochs=3, cursor=cur)
    seen = [np.asarray(next(it)["label"]) for _ in range(5)]
    assert len(seen) == 5

    # the cursor rides a manifest and comes back identical
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, {"x": np.zeros(1, np.float32)},
             extra={"cursor": cur.as_manifest()})
    _, manifest = mgr.restore(5, {"x": np.zeros(1, np.float32)})
    restored = ReplayCursor.from_manifest(manifest["cursor"])
    assert (restored.epoch, restored.batch) == (cur.epoch, cur.batch)

    # a fresh iterator at the restored cursor replays the exact remainder
    pipe2, _ = _pipe()
    it2 = epoch_batches(pipe2, max_epochs=3, cursor=restored)
    rest_original = [np.asarray(b["label"]) for b in it]
    rest_replayed = [np.asarray(b["label"]) for b in it2]
    assert len(rest_original) == len(rest_replayed) > 0
    for a, b in zip(rest_original, rest_replayed):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------- commit grid


def test_commit_grid_batch_split_invariance():
    """Grid-snapped END_B commits are exact integer sums: committing one
    8-sample batch equals summing a 5/3 split's commits, bit for bit."""
    cfg = Presets.braille(n_classes=3, num_ticks=24, quantized=True)
    params = init_params(jax.random.key(0), cfg)
    w = {k: params[k] for k in ("w_in", "w_rec", "w_out")}
    rng = np.random.default_rng(0)
    T, B = 24, 8
    raster = jnp.asarray((rng.random((T, B, cfg.n_in)) < 0.08)
                         .astype(np.float32))
    y_star = jax.nn.one_hot(jnp.asarray(rng.integers(0, 3, B)), cfg.n_out)
    valid = jnp.ones((T, B), jnp.float32)

    be = ExecutionBackend(cfg, runtime=RuntimeConfig(
        backend="scan", commit_grid=DW_COMMIT_SPEC))
    assert be.runtime.commit_grid == DW_COMMIT_SPEC
    full, _ = be.train_tile(w, raster, y_star, valid)
    a, _ = be.train_tile(w, raster[:, :5], y_star[:5], valid[:, :5])
    b, _ = be.train_tile(w, raster[:, 5:], y_star[5:], valid[:, 5:])
    for k in full:
        np.testing.assert_array_equal(
            np.asarray(a[k]) + np.asarray(b[k]), np.asarray(full[k]))


def test_backend_resize_identity_and_contract():
    cfg = Presets.braille(n_classes=3, num_ticks=24)
    be = ExecutionBackend(cfg, runtime=RuntimeConfig(backend="scan"))
    assert be.resize(None) is be
    with pytest.raises(ValueError, match="commit grid"):
        be.check_compatible(RuntimeConfig(commit_grid=DW_COMMIT_SPEC))


# --------------------------------------------------------------- learner


def test_learner_checkpoint_resume_bitwise(tmp_path):
    """In-process: a run interrupted at a commit boundary and resumed from
    its checkpoint finishes bitwise equal to the uninterrupted run —
    weights, optimizer residuals and the int32 sample count."""
    kw = dict(epochs=2, samples_per_class=8, num_ticks=24, spb=12)
    gold = chaos.golden_run(**kw)

    class Interrupt(Exception):
        pass

    def kill(lrn, commits):
        if commits >= 2:
            raise Interrupt

    a, pipe_a = chaos.build_learner(str(tmp_path), async_save=False, **kw)
    with pytest.raises(Interrupt):
        a.fit(pipe_a, on_commit=kill)

    b, pipe_b = chaos.build_learner(str(tmp_path), async_save=False, **kw)
    b.fit(pipe_b, resume=True)
    for k, gw in gold.items():
        np.testing.assert_array_equal(np.asarray(b.weights[k]), gw)
    for k, acc in b.opt_state["acc"].items():
        assert np.isfinite(np.asarray(acc)).all()
    assert b.opt_state["count"].dtype == jnp.int32


def test_learner_restore_rejects_contract_mismatch(tmp_path):
    kw = dict(epochs=1, samples_per_class=6, num_ticks=24, spb=9)
    a, pipe = chaos.build_learner(str(tmp_path), async_save=False, **kw)
    a.fit(pipe)

    # float learner (no QuantizedMode contract) must refuse the checkpoint
    f, _ = chaos.build_learner(str(tmp_path), quantized=False, **kw)
    with pytest.raises(ValueError, match="register contract"):
        f.restore_checkpoint()

    # different register values are a different chip — also refused
    q, _ = chaos.build_learner(str(tmp_path), **kw)
    q.backend = ExecutionBackend(
        q.cfg, runtime=RuntimeConfig(
            backend="scan",
            quant=QuantizedMode(threshold=0x03F0, alpha_reg=0x0FE,
                                kappa_reg=0x40)))
    with pytest.raises(ValueError, match="register contract"):
        q.restore_checkpoint()


def test_learner_restore_publishes_to_live_serve_lanes(tmp_path):
    """Learn-while-serve recovery: a restored learner re-publishes its SRAM
    image into the registry, and an engine routing that model serves the
    restored weights on its next tile."""
    from repro.serve import BatchedEngine
    from repro.serve.registry import ModelRegistry

    kw = dict(epochs=1, samples_per_class=6, num_ticks=24, spb=9)
    a, pipe = chaos.build_learner(str(tmp_path), async_save=False, **kw)
    a.fit(pipe)
    final = {k: np.asarray(v) for k, v in a.weights.items()}

    reg = ModelRegistry()
    b, _ = chaos.build_learner(str(tmp_path), registry=reg, seed=17, **kw)
    eng = BatchedEngine(registry=reg, model_id=b.model_id,
                        max_batch=4, tick_granularity=24)
    assert b.restore_checkpoint()
    for k, v in final.items():
        np.testing.assert_array_equal(np.asarray(b.weights[k]), v)

    data = make_braille_dataset(
        "AEU", BrailleConfig(samples_per_class=6, num_ticks=24))
    reqs = list(EventStream(data, "test"))
    res, _ = eng.serve(iter(reqs))
    # the engine's lane reads live registry weights: predictions must match
    # direct inference at the restored (== pre-crash final) weights
    from repro.serve.batching import decode_events_host
    from repro.core.controller import make_infer_fn

    infer = make_infer_fn(b.cfg)
    oracle_w = {k: b.weights[k] for k in ("w_in", "w_rec", "w_out")}
    for r, ev in zip(res, reqs):
        raster, valid, _ = decode_events_host(
            [ev], b.cfg.n_in, r.bucket_ticks, b.cfg.label_delay)
        o = infer(oracle_w, raster[:, 0], valid[:, 0])
        assert r.pred == int(o["pred"])


# --------------------------------------------------------------- trainer


def _quadratic_step(term_at=None):
    def step(params, opt_state, batch):
        new = jax.tree.map(lambda w: w - 0.1 * (2 * w), params)
        if term_at is not None and int(batch["i"]) == term_at:
            os.kill(os.getpid(), signal.SIGTERM)
        loss = sum(jnp.sum(w ** 2) for w in jax.tree.leaves(params))
        return new, {"step": opt_state["step"] + 1}, {
            "loss": loss, "grad_norm": jnp.float32(1.0)}
    return step


def _counter_data():
    i = 0
    while True:
        yield {"i": jnp.int32(i)}
        i += 1


def test_trainer_sigterm_cuts_final_checkpoint(tmp_path):
    from repro.train.trainer import Trainer, TrainerConfig

    params = {"w": jnp.ones((4,))}
    tr = Trainer(_quadratic_step(term_at=3), params, {"step": jnp.int32(0)},
                 _counter_data(),
                 TrainerConfig(total_steps=100, ckpt_every=1000,
                               ckpt_dir=str(tmp_path)))
    tr.install_signal_handlers()
    try:
        out = tr.run()
    finally:
        tr.restore_signal_handlers()
    assert out["stopped_by_signal"]
    assert 0 < out["step"] < 100
    assert tr.ckpt.latest_step() == out["step"]   # final blocking save landed

    tr2 = Trainer(_quadratic_step(), {"w": jnp.ones((4,))},
                  {"step": jnp.int32(0)}, _counter_data(),
                  TrainerConfig(total_steps=100, ckpt_dir=str(tmp_path)))
    assert tr2.restore()
    assert tr2.step == out["step"]
    np.testing.assert_array_equal(np.asarray(tr2.params["w"]),
                                  np.asarray(tr.params["w"]))


def test_trainer_checkpoint_policy_and_cursor(tmp_path):
    from repro.train.trainer import Trainer, TrainerConfig

    policy = CheckpointPolicy(directory=tmp_path, every=2, keep=0,
                              async_save=False)
    cur = ReplayCursor()
    pipe, _ = _pipe()
    data = epoch_batches(pipe, max_epochs=100, cursor=cur)

    def step(params, opt_state, batch):
        return params, {"step": opt_state["step"] + 1}, {
            "loss": jnp.float32(1.0), "grad_norm": jnp.float32(1.0)}

    tr = Trainer(step, {"w": jnp.ones((2,))}, {"step": jnp.int32(0)}, data,
                 TrainerConfig(total_steps=5), checkpoint=policy, cursor=cur)
    tr.run()
    assert tr.ckpt.all_steps() == [2, 4, 5]      # policy cadence + final save

    cur2 = ReplayCursor()
    tr2 = Trainer(step, {"w": jnp.ones((2,))}, {"step": jnp.int32(0)},
                  iter([]), TrainerConfig(total_steps=5),
                  checkpoint=policy, cursor=cur2)
    assert tr2.restore()
    assert (cur2.epoch, cur2.batch) == (cur.epoch, cur.batch)


# ------------------------------------------------------------ chaos (subproc)


WARGS = ["--epochs", "2", "--samples-per-class", "8", "--ticks", "32",
         "--spb", "12"]
GOLD_KW = dict(epochs=2, samples_per_class=8, num_ticks=32, spb=12)


def _assert_bitwise(gold, out):
    got = chaos.load_result_weights(out)
    assert sorted(got) == sorted(gold)
    for k in gold:
        np.testing.assert_array_equal(got[k], gold[k])


def test_chaos_sigkill_at_commit_boundary(tmp_path):
    """Subprocess SIGKILL at a randomized commit boundary; restart resumes
    from the survived checkpoints and ends bitwise equal to golden."""
    gold = chaos.golden_run(**GOLD_KW)
    kill_at = int(np.random.default_rng().integers(1, 4))
    out = str(tmp_path / "result")
    res = chaos.run_chaos(str(tmp_path / "ck"), out,
                          ["--kill-at-commit", kill_at], WARGS)
    assert res["restarts"] >= 1 and res["resumed_from"] is not None
    _assert_bitwise(gold, out)


def test_chaos_sigkill_mid_save_torn_tmp(tmp_path):
    """SIGKILL inside the checkpoint write (before the atomic rename): the
    restart sweeps the torn ``.tmp``, resumes from the newest complete step,
    and still lands bitwise on golden."""
    gold = chaos.golden_run(**GOLD_KW)
    out = str(tmp_path / "result")
    res = chaos.run_chaos(str(tmp_path / "ck"), out,
                          ["--kill-mid-save-step", 2], WARGS)
    ck = tmp_path / "ck"
    assert not list(ck.glob("*.tmp"))
    assert res["resumed_from"] is not None and res["resumed_from"] < 2
    _assert_bitwise(gold, out)


def test_chaos_sigterm_graceful_drill(tmp_path):
    """SIGTERM preemption: the worker finishes the batch, cuts a final
    blocking checkpoint, exits with STOPPED_RC; the restart completes
    bitwise on golden."""
    gold = chaos.golden_run(**GOLD_KW)
    out = str(tmp_path / "result")
    res = chaos.run_chaos(str(tmp_path / "ck"), out,
                          ["--sigterm-at-commit", 2], WARGS)
    assert res["resumed_from"] is not None
    _assert_bitwise(gold, out)


@pytest.mark.slow
def test_chaos_kernel_backend(tmp_path):
    """The same SIGKILL drill through the Pallas kernel backend (interpret
    mode on CPU): checkpoint/resume is backend-agnostic, bitwise."""
    gold = chaos.golden_run(backend="kernel", **GOLD_KW)
    out = str(tmp_path / "result")
    chaos.run_chaos(str(tmp_path / "ck"), out, ["--kill-at-commit", 2],
                    WARGS + ["--backend", "kernel"])
    _assert_bitwise(gold, out)


def test_chaos_elastic_shrink_8_to_4(tmp_path):
    """The elastic drill: crash on an 8-virtual-device data mesh, restart on
    4 survivors.  With the integer commit grid armed, the shrunk run's END_B
    commits are order-invariant — the final weights are bitwise equal to a
    single-device golden run."""
    gold = chaos.golden_run(deterministic=True, **GOLD_KW)
    out = str(tmp_path / "result")
    res = chaos.run_chaos(
        str(tmp_path / "ck"), out, ["--kill-at-commit", 2],
        WARGS + ["--deterministic"],
        mesh_devices=8, restart_mesh_devices=4,
    )
    assert res["resumed_from"] is not None
    _assert_bitwise(gold, out)
    manifest = json.loads((tmp_path / "result.json").read_text())
    assert manifest["commits"] == res["commits"]


def test_survive_data_failure_resizes_backend():
    """elastic.survive_data_failure: drop device ids, get a resized backend
    over the survivors' ("data",) mesh (or no mesh for one survivor)."""
    from repro.distributed.elastic import best_data_mesh_from, survive_data_failure

    cfg = Presets.braille(n_classes=3, num_ticks=24)
    be = ExecutionBackend(cfg, runtime=RuntimeConfig(backend="scan"))
    n = len(jax.devices())
    resized, mesh = survive_data_failure(be, failed_ids=[])
    if n == 1:
        assert mesh is None and resized is be
    with pytest.raises(ValueError, match="no surviving"):
        best_data_mesh_from([])
