"""Property tests for the 32-bit AER event codec (paper §3.1 word format)."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # declared in requirements.txt; CI installs the real thing
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import aer


@given(
    kind=st.sampled_from([aer.EVT_SPIKE, aer.EVT_LABEL, aer.EVT_END]),
    addr=st.integers(0, aer.MAX_ADDR),
    tick=st.integers(0, aer.MAX_TICK),
)
@settings(max_examples=200, deadline=None)
def test_pack_unpack_roundtrip(kind, addr, tick):
    word = aer.pack(kind, addr, tick)
    k, a, t = aer.unpack(word)
    assert (int(k), int(a), int(t)) == (kind, addr, tick)


def test_word_layout_matches_paper():
    # "0x03 identifies a spike ... bits 23..12 the address ... 12 LSBs the tick"
    w = int(aer.pack(aer.EVT_SPIKE, 0xAB, 0x123))
    assert w == (0x03 << 24) | (0xAB << 12) | 0x123


@given(
    t=st.integers(2, 40),
    n=st.integers(1, 32),
    density=st.floats(0.0, 0.5),
    label=st.integers(0, 15),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_encode_decode_roundtrip(t, n, density, label, seed):
    rng = np.random.default_rng(seed)
    raster = (rng.random((t, n)) < density).astype(np.float32)
    label_tick = int(rng.integers(0, t))
    words = aer.encode_sample(raster, label, label_tick)
    s = aer.decode_sample(jnp.asarray(words), n, t)
    np.testing.assert_array_equal(np.asarray(s.raster), raster)
    assert int(s.label) == label
    assert int(s.label_tick) == label_tick
    assert int(s.end_tick) == t - 1


@given(
    t=st.integers(2, 40),
    n=st.integers(1, 32),
    density=st.floats(0.0, 0.5),
    label=st.integers(0, 15),
    end_frac=st.floats(0.0, 1.0),
    pad=st.integers(0, 16),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_encode_decode_roundtrip_full_fields(t, n, density, label, end_frac,
                                             pad, seed):
    """Full-field round trip: raster *and* label/label_tick/end_tick survive
    encode → (zero-pad) → decode for arbitrary valid rasters, including the
    zero-spike raster (density=0 is a generated edge case) and padded
    buffers (pad > 0 appends 0x0 words, which decode must ignore)."""
    rng = np.random.default_rng(seed)
    raster = (rng.random((t, n)) < density).astype(np.float32)
    label_tick = int(rng.integers(0, t))
    end_tick = int(round(end_frac * (t - 1)))
    words = aer.encode_sample(raster, label, label_tick, end_tick)
    if pad:
        words = aer.pad_events([words], len(words) + pad)[0]
    s = aer.decode_sample(jnp.asarray(words), n, t)
    np.testing.assert_array_equal(np.asarray(s.raster), raster)
    assert int(s.label) == label
    assert int(s.label_tick) == label_tick
    assert int(s.end_tick) == end_tick


def test_encode_sample_masks_and_validates_fields():
    """Regression: label_word/end_word used to be OR'd without & MAX_ADDR /
    & MAX_TICK, so out-of-range values bled into the type byte.  Max legal
    values must keep their type bytes; out-of-range must assert."""
    raster = np.zeros((4, 2), np.float32)
    words = aer.encode_sample(raster, aer.MAX_ADDR, aer.MAX_TICK, end_tick=3)
    kinds = np.asarray(words) >> 24
    assert set(kinds.tolist()) == {aer.EVT_LABEL, aer.EVT_END}
    s = aer.decode_sample(jnp.asarray(words), 2, 4)
    assert int(s.label) == aer.MAX_ADDR and int(s.label_tick) == aer.MAX_TICK

    for bad in (
        dict(label=aer.MAX_ADDR + 1, label_tick=0),
        dict(label=-1, label_tick=0),
        dict(label=0, label_tick=aer.MAX_TICK + 1),
        dict(label=0, label_tick=0, end_tick=aer.MAX_TICK + 1),
        dict(label=0, label_tick=0, end_tick=-1),
    ):
        with pytest.raises(aer.AEREncodingError):
            aer.encode_sample(raster, **bad)


def test_events_sorted_by_tick():
    rng = np.random.default_rng(0)
    raster = (rng.random((20, 8)) < 0.3).astype(np.float32)
    words = aer.encode_sample(raster, 1, 5)
    ticks = np.asarray(words[:-1]) & aer.MAX_TICK  # excluding end word
    assert (np.diff(ticks.astype(np.int64)) >= 0).all()
    assert int(words[-1]) >> 24 == aer.EVT_END


def test_decode_batch_padding_ignored():
    rng = np.random.default_rng(1)
    r1 = (rng.random((10, 4)) < 0.4).astype(np.float32)
    r2 = (rng.random((10, 4)) < 0.1).astype(np.float32)
    b1 = aer.encode_sample(r1, 0, 3)
    b2 = aer.encode_sample(r2, 1, 7)
    padded = aer.pad_events([b1, b2])
    s = aer.decode_batch(jnp.asarray(padded), 4, 10)
    np.testing.assert_array_equal(np.asarray(s.raster[0]), r1)
    np.testing.assert_array_equal(np.asarray(s.raster[1]), r2)
    assert s.label.tolist() == [0, 1]


@given(
    label_tick=st.integers(0, 20),
    end_tick=st.integers(0, 20),
    delay=st.integers(0, 5),
)
@settings(max_examples=60, deadline=None)
def test_supervision_mask(label_tick, end_tick, delay):
    t = 21
    mask = np.asarray(aer.supervision_mask(
        jnp.int32(label_tick), jnp.int32(end_tick), t, delay))
    for i in range(t):
        expected = 1.0 if (label_tick + delay <= i <= end_tick) else 0.0
        assert mask[i] == expected
