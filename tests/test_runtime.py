"""Runtime layers: checkpointing, elastic reshard, trainer fault drills,
pipelines, compression, optimizers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.elastic import best_mesh_from, reshard
from repro.distributed.sharding import BASE_RULES, ShardingRules
from repro.launch.mesh import make_debug_mesh
from repro.optim.adamw import AdamW, AdamWConfig, schedule
from repro.optim.compression import (
    dequantize_int8,
    quantize_int8,
    wire_bytes_f32_allreduce,
    wire_bytes_int8_allgather,
)
from repro.train.metrics import StragglerWatchdog
from repro.train.trainer import Trainer, TrainerConfig


# ---------------------------------------------------------------- checkpoint

def _tree(key=0):
    k = jax.random.key(key)
    return {
        "a": jax.random.normal(k, (8, 4)),
        "b": {"c": jnp.arange(6, dtype=jnp.int32), "d": jnp.float32(3.5)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    mgr.save(5, t, {"note": "x"})
    restored, manifest = mgr.restore(5, jax.tree.map(np.asarray, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), b)
    assert manifest["step"] == 5 and manifest["note"] == "x"


def test_checkpoint_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save_async(7, _tree(7))
    mgr.wait()
    assert mgr.latest_step() == 7
    # a stale .tmp dir (simulated crash mid-save) must not be visible
    (tmp_path / "step_000000009.tmp").mkdir()
    assert mgr.latest_step() == 7
    assert 9 not in mgr.all_steps()


def test_checkpoint_restore_ignores_corrupt_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(3, _tree())
    (tmp_path / "LATEST").write_text("step_000000099")  # dangling pointer
    assert mgr.latest_step() == 3                       # falls back to scan


# ---------------------------------------------------------------- elastic

def test_reshard_preserves_values():
    mesh = make_debug_mesh(1, 1)
    rules = ShardingRules(BASE_RULES)
    host = {"w": np.arange(32, dtype=np.float32).reshape(8, 4)}
    specs = {"w": ("embed", "mlp")}
    placed = reshard(host, specs, mesh, rules)
    np.testing.assert_array_equal(np.asarray(placed["w"]), host["w"])


def test_best_mesh_from_survivors():
    devs = jax.devices() * 8  # simulate 8 "devices" on CPU
    mesh = best_mesh_from(devs, model_parallel=2)
    assert mesh.shape["model"] == 2 and mesh.shape["data"] == 4
    with pytest.raises(ValueError):
        best_mesh_from(devs[:1], model_parallel=2)


# ---------------------------------------------------------------- trainer

def _quadratic_step(nan_at=None):
    """Minimal step_fn: minimise |w|² with SGD; inject NaN at a given step."""

    def step(params, opt_state, batch):
        g = jax.tree.map(lambda w: 2 * w, params)
        new = jax.tree.map(lambda w, gg: w - 0.1 * gg, params, g)
        step_no = opt_state["step"] + 1
        loss = sum(jnp.sum(w ** 2) for w in jax.tree.leaves(params))
        if nan_at is not None:
            loss = jnp.where(batch["i"] == nan_at, jnp.nan, loss)
        return new, {"step": step_no}, {"loss": loss, "grad_norm": jnp.float32(1.0)}

    return step


def _data():
    i = 0
    while True:
        yield {"i": jnp.int32(i)}
        i += 1


def test_trainer_runs_and_checkpoints(tmp_path):
    params = {"w": jnp.ones((4,))}
    tr = Trainer(_quadratic_step(), params, {"step": jnp.int32(0)}, _data(),
                 TrainerConfig(total_steps=12, ckpt_every=5, ckpt_dir=str(tmp_path),
                               log_every=1))
    out = tr.run()
    assert out["step"] == 12
    assert tr.ckpt.latest_step() == 12
    losses = [s.metrics["loss"] for s in tr.metrics.history]
    assert losses[-1] < losses[0]


def test_trainer_rejects_nan_steps(tmp_path):
    params = {"w": jnp.ones((4,))}
    tr = Trainer(_quadratic_step(nan_at=3), params, {"step": jnp.int32(0)}, _data(),
                 TrainerConfig(total_steps=6, ckpt_every=100, ckpt_dir=str(tmp_path)))
    out = tr.run()
    assert out["step"] == 6
    assert out["rejected_steps"] == 1      # batch 3 skipped, training continued
    assert np.isfinite(np.asarray(tr.params["w"])).all()


def test_trainer_aborts_after_max_bad_steps(tmp_path):
    params = {"w": jnp.ones((4,))}

    def always_nan(params, opt_state, batch):
        return params, opt_state, {"loss": jnp.nan, "grad_norm": jnp.float32(1)}

    tr = Trainer(always_nan, params, {"step": jnp.int32(0)}, _data(),
                 TrainerConfig(total_steps=10, max_bad_steps=3,
                               ckpt_dir=str(tmp_path)))
    with pytest.raises(RuntimeError):
        tr.run()


def test_trainer_resume(tmp_path):
    params = {"w": jnp.ones((4,))}
    tr = Trainer(_quadratic_step(), params, {"step": jnp.int32(0)}, _data(),
                 TrainerConfig(total_steps=7, ckpt_every=5, ckpt_dir=str(tmp_path)))
    tr.run()
    w_end = np.asarray(tr.params["w"]).copy()

    tr2 = Trainer(_quadratic_step(), {"w": jnp.ones((4,))}, {"step": jnp.int32(0)},
                  _data(), TrainerConfig(total_steps=7, ckpt_dir=str(tmp_path)))
    assert tr2.restore()
    assert tr2.step == 7
    np.testing.assert_allclose(np.asarray(tr2.params["w"]), w_end)


def test_straggler_watchdog_flags_outlier():
    wd = StragglerWatchdog(k=3.0, warmup=4)
    flagged = []
    for i in range(30):
        dt = 1.0 + 0.01 * np.sin(i)
        if i == 20:
            dt = 5.0
        if wd.observe(i, dt):
            flagged.append(i)
    assert 20 in flagged and len(flagged) <= 2


# ---------------------------------------------------------------- compression

def test_int8_quantization_error_bound():
    x = np.random.default_rng(0).standard_normal(1000).astype(np.float32)
    q, s = quantize_int8(jnp.asarray(x))
    err = np.abs(dequantize_int8(q, s) - x)
    assert err.max() <= float(s) / 2 + 1e-6


def test_compressed_mean_with_error_feedback_converges():
    """EF makes the time-averaged compressed mean equal the true mean."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((64,)).astype(np.float32))
    r = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    steps = 50
    for _ in range(steps):
        g32 = g + r
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        r = g32 - deq
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / steps), np.asarray(g),
                               atol=float(s) / steps + 1e-4)


def test_wire_bytes_accounting():
    n = 1_000_000
    f32 = wire_bytes_f32_allreduce(n, 2)
    int8 = wire_bytes_int8_allgather(n, 2)
    assert f32 / int8 >= 3.9          # ≈4× compression at pod=2


# ---------------------------------------------------------------- optimizers

def test_adamw_schedule_and_descent():
    cfg = AdamWConfig(lr=5e-2, warmup_steps=5, decay_steps=200, weight_decay=0.0,
                      clip=None)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.int32(5))) - 5e-2) < 1e-9
    opt = AdamW(cfg)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.update(params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5
    assert np.isfinite(float(m["grad_norm"]))


def test_adamw_clip():
    opt = AdamW(AdamWConfig(clip=1.0, warmup_steps=0, decay_steps=10))
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    _, _, m = opt.update(params, {"w": jnp.full((3,), 1e6)}, state)
    assert float(m["grad_norm"]) > 1e5   # reported pre-clip
