"""Spike-sparsity fast path + double-buffered event streaming (PR 7).

The contract under test: the event path — XLA-side row compaction on the
scan backend, DMA block-skipping on the kernel backend — is **bit-exact**
with the dense path in float and quantized modes, across every edge the
tiling can hit: all-quiet samples, ``B=1``, a ragged last batch tile,
delayed supervision, and capacity overflow (which must fall back to the
dense projection, not truncate events).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant_ref
from repro.core.backend import ExecutionBackend
from repro.core.rsnn import Presets, init_params, trainable
from repro.kernels import events, ops

ALPHA, KAPPA = 0.99, 0.78


def _cfg(T=24, quantized=False):
    return Presets.braille(n_classes=3, num_ticks=T, quantized=quantized)


def _tile(key, cfg, B, density=0.05):
    ks = jax.random.split(key, 3)
    weights = trainable(init_params(ks[0], cfg))
    T = cfg.num_ticks
    raster = (jax.random.uniform(ks[1], (T, B, cfg.n_in)) < density).astype(
        jnp.float32
    )
    label = jax.random.randint(ks[2], (B,), 0, cfg.n_out)
    y_star = jax.nn.one_hot(label, cfg.n_out)
    valid = ((jnp.arange(T)[:, None] >= T // 3) * jnp.ones((T, B))).astype(
        jnp.float32
    )
    return weights, raster, y_star, valid


def _pair(cfg, backend, raster):
    """(dense, event) backend pair — event forced at the tile's density."""
    d = float(events.raster_density(raster))
    return (
        ExecutionBackend(cfg, backend, sparsity="dense"),
        ExecutionBackend(cfg, backend, sparsity="event", event_density=d),
    )


def _assert_same_tree(a, b, msg=""):
    ta, tb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(ta) == len(tb)
    for x, y in zip(ta, tb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


# ---------------------------------------------------------------- edge tiles


@pytest.mark.parametrize("backend", ["scan", "kernel"])
def test_all_quiet_samples_bit_exact(backend):
    """A tile with zero events anywhere: every block is skipped on the DMA
    path and the compacted projection is empty — outputs still match the
    dense path exactly (leak-only dynamics are not shortcut)."""
    cfg = _cfg()
    weights, raster, y_star, valid = _tile(jax.random.key(0), cfg, B=6)
    raster = jnp.zeros_like(raster)
    be_d, be_e = _pair(cfg, backend, raster)
    assert be_e.sparsity == "event"  # forced, density 0.0
    _assert_same_tree(be_d.inference(weights, raster, valid),
                      be_e.inference(weights, raster, valid), "inference")
    _assert_same_tree(be_d.train_tile(weights, raster, y_star, valid),
                      be_e.train_tile(weights, raster, y_star, valid), "train")


@pytest.mark.parametrize("backend", ["scan", "kernel"])
def test_single_sample_tile_bit_exact(backend):
    """B=1: one batch row per tile, degenerate bitmap/compaction shapes."""
    cfg = _cfg()
    weights, raster, y_star, valid = _tile(jax.random.key(1), cfg, B=1)
    be_d, be_e = _pair(cfg, backend, raster)
    _assert_same_tree(be_d.inference(weights, raster, valid),
                      be_e.inference(weights, raster, valid), "inference")
    _assert_same_tree(be_d.train_tile(weights, raster, y_star, valid),
                      be_e.train_tile(weights, raster, y_star, valid), "train")


def test_ragged_last_tile_bit_exact():
    """B=10 with batch_tile=4 → tiles of 4+4+2; padded rows in the last
    tile are all-quiet, so the DMA path's bitmap must treat them exactly
    like the blocked path's zero padding."""
    cfg = _cfg()
    weights, raster, y_star, valid = _tile(jax.random.key(2), cfg, B=10)
    w_in, w_rec, w_out = weights["w_in"], weights["w_rec"], weights["w_out"]
    kw = dict(alpha=ALPHA, kappa=KAPPA, batch_tile=4)
    out_b = ops.rsnn_infer(raster, valid, w_in, w_rec, w_out,
                           stream="blocked", **kw)
    out_d = ops.rsnn_infer(raster, valid, w_in, w_rec, w_out,
                           stream="dma", **kw)
    _assert_same_tree(out_b, out_d, "infer ragged")
    b_fb = w_out
    tr_b = ops.rsnn_train(raster, y_star, valid, w_in, w_rec, w_out, b_fb,
                          stream="blocked", **kw)
    tr_d = ops.rsnn_train(raster, y_star, valid, w_in, w_rec, w_out, b_fb,
                          stream="dma", **kw)
    _assert_same_tree(tr_b, tr_d, "train ragged")


@pytest.mark.parametrize("backend", ["scan", "kernel"])
def test_label_delay_valid_window_bit_exact(backend):
    """Delayed supervision (label_delay > 0): the valid window opens later,
    so early active ticks contribute dynamics but no readout — the event
    path must not confuse activity gating with supervision gating."""
    delay = 6
    cfg = dataclasses.replace(_cfg(), label_delay=delay)
    weights, raster, y_star, _ = _tile(jax.random.key(3), cfg, B=5)
    T, B = cfg.num_ticks, 5
    lt = T // 3
    valid = ((jnp.arange(T)[:, None] >= lt + delay) * jnp.ones((T, B))
             ).astype(jnp.float32)
    be_d, be_e = _pair(cfg, backend, raster)
    out_d = be_d.inference(weights, raster, valid)
    out_e = be_e.inference(weights, raster, valid)
    _assert_same_tree(out_d, out_e, "inference")
    _assert_same_tree(be_d.train_tile(weights, raster, y_star, valid),
                      be_e.train_tile(weights, raster, y_star, valid), "train")


# ----------------------------------------------------------- quantized golden


@pytest.mark.parametrize("backend", ["scan", "kernel"])
def test_quantized_event_path_matches_golden(backend):
    """Quantized mode at Braille-like sparsity: the event path reproduces
    the integer golden reference bit for bit (`core/quant_ref.py` is the
    oracle — same bar the dense path already clears)."""
    cfg = _cfg(T=32, quantized=True)
    weights, raster, _, valid = _tile(jax.random.key(4), cfg, B=12,
                                      density=0.05)
    be = ExecutionBackend(cfg, backend, sparsity="event",
                          event_density=float(events.raster_density(raster)))
    mask = 1.0 - np.eye(cfg.n_hid, dtype=np.float32)
    g = quant_ref.golden_forward(
        np.asarray(raster),
        np.asarray(weights["w_in"]),
        np.asarray(weights["w_rec"]) * mask,
        np.asarray(weights["w_out"]),
        cfg.neuron.quant,
        reset=cfg.neuron.reset,
        boxcar_width=cfg.neuron.boxcar_width,
        valid=np.asarray(valid),
    )
    dyn = be.dynamics(weights, raster)
    for k in ("v", "z", "y"):
        np.testing.assert_array_equal(
            np.asarray(dyn[k]).astype(np.int64), g[k], err_msg=f"{backend}:{k}"
        )
    out = be.inference(weights, raster, valid)
    np.testing.assert_array_equal(
        np.asarray(out["acc_y"]).astype(np.int64), g["acc_y"])
    np.testing.assert_array_equal(np.asarray(out["pred"]), g["pred"])


# ---------------------------------------------------- dispatch + density sweep


def test_density_sweep_dispatch_invariance():
    """Outputs are invariant to the dense/event dispatch decision across a
    density sweep spanning both sides of the threshold — auto mode can never
    change results, only bytes."""
    cfg = _cfg()
    thr = events.SPARSE_DENSITY_THRESHOLD
    for i, d in enumerate([0.0, 0.05, 0.2, 0.5, 0.9]):
        weights, raster, y_star, valid = _tile(
            jax.random.key(10 + i), cfg, B=4, density=d)
        be_dense = ExecutionBackend(cfg, "scan", sparsity="dense")
        be_auto = ExecutionBackend(cfg, "scan", sparsity="auto",
                                   event_density=d)
        assert be_auto.sparsity == ("event" if d <= thr else "dense")
        _assert_same_tree(
            be_dense.train_tile(weights, raster, y_star, valid),
            be_auto.train_tile(weights, raster, y_star, valid),
            f"density={d}")


def test_resolve_sparsity_policy():
    thr = events.SPARSE_DENSITY_THRESHOLD
    assert events.resolve_sparsity("dense", 0.01) == "dense"
    assert events.resolve_sparsity("event", 0.99) == "event"
    assert events.resolve_sparsity("auto", thr) == "event"
    assert events.resolve_sparsity("auto", thr + 0.01) == "dense"
    assert events.resolve_sparsity(None, 0.1) == "event"
    # no density measurement → stay dense unless forced
    assert events.resolve_sparsity(None, None) == "dense"
    assert events.resolve_sparsity("event", None) == "event"
    with pytest.raises(ValueError):
        events.resolve_sparsity("bogus", 0.1)


# ------------------------------------------------- compaction capacity limits


def test_capacity_overflow_falls_back_dense():
    """More active rows than capacity: the projection must return the dense
    result (cond fallback), never a truncated event set."""
    key = jax.random.key(5)
    T, B, n_in, H = 8, 4, 12, 16
    raster = (jax.random.uniform(key, (T, B, n_in)) < 0.9).astype(jnp.float32)
    w_in = jax.random.normal(jax.random.key(6), (n_in, H))
    dense = jnp.dot(raster.reshape(T * B, n_in), w_in).reshape(T, B, H)
    n_act = int(events.row_activity(raster).sum())
    assert n_act > 4  # the sweep below crosses the overflow boundary
    for cap in (2, n_act - 1, n_act, n_act + 3, T * B):
        proj, n_active = events.sparse_input_projection(
            raster, w_in, capacity=cap)
        assert int(n_active) == n_act
        np.testing.assert_array_equal(np.asarray(proj), np.asarray(dense),
                                      err_msg=f"capacity={cap}")


def test_suggest_row_capacity_bounds():
    T, B, n_in = 100, 16, 12
    cap = events.suggest_row_capacity(T, B, 0.05, n_in=n_in)
    rd = events.row_density(0.05, n_in)
    assert cap >= int(T * B * rd)       # at least the expected active rows
    assert cap <= T * B                 # never more than dense
    assert events.suggest_row_capacity(T, B, 1.0, n_in=n_in) == T * B
    assert events.suggest_row_capacity(T, B, 0.0, n_in=n_in) >= 64


def test_block_bitmap_matches_numpy():
    key = jax.random.key(7)
    T, B, n_in, bt = 10, 9, 12, 4
    raster = (jax.random.uniform(key, (T, B, n_in)) < 0.02).astype(jnp.float32)
    b_pad = 12  # 3 tiles of 4 — last real tile ragged, pad rows quiet
    padded = jnp.zeros((T, b_pad, n_in)).at[:, :B].set(raster)
    bm = np.asarray(events.block_bitmap(padded, bt))
    nb = b_pad // bt
    act = np.asarray(padded).reshape(T, nb, bt * n_in).sum(-1) > 0  # (T, nb)
    ref = act.T.reshape(nb * T)  # linearized step order s = b*T + t
    np.testing.assert_array_equal(bm.astype(bool), ref)


# -------------------------------------------- DMA vs blocked, all four kernels


def test_dma_parity_forward_and_sessions():
    """stream="dma" vs "blocked" for the two kernels the backend-level tests
    above don't reach directly: the trace-emitting forward and the
    session-stateful streaming step (with dead rows in the live mask)."""
    cfg = _cfg()
    weights, raster, _, valid = _tile(jax.random.key(8), cfg, B=6)
    w_in, w_rec, w_out = weights["w_in"], weights["w_rec"], weights["w_out"]
    kw = dict(alpha=ALPHA, kappa=KAPPA, batch_tile=4)
    f_b = ops.rsnn_forward(raster, w_in, w_rec, w_out, stream="blocked", **kw)
    f_d = ops.rsnn_forward(raster, w_in, w_rec, w_out, stream="dma", **kw)
    _assert_same_tree(f_b, f_d, "forward")

    T, B = raster.shape[:2]
    live = jnp.ones((T, B)).at[:, 0].set(0.0)   # one dead session row
    live = live.at[T // 2:, 3].set(0.0)          # one that ends mid-tile
    valid = valid * live
    state = ExecutionBackend(cfg, "kernel").init_session_state(B)
    carry = (state["v"], state["z"], state["y"], state["acc_y"],
             state["n_spk"])
    s_b = ops.rsnn_step_sessions(raster, live, valid, *carry,
                                 w_in, w_rec, w_out, stream="blocked", **kw)
    s_d = ops.rsnn_step_sessions(raster, live, valid, *carry,
                                 w_in, w_rec, w_out, stream="dma", **kw)
    _assert_same_tree(s_b, s_d, "step_sessions")
