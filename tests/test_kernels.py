"""Per-kernel allclose vs the ref.py oracles — shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("T,B,N,H,O", [(8, 1, 8, 16, 2), (33, 4, 40, 100, 2),
                                       (16, 8, 12, 38, 4)])
@pytest.mark.parametrize("reset", ["sub", "zero"])
def test_rsnn_step_sweep(T, B, N, H, O, reset):
    ks = jax.random.split(jax.random.key(T * B + H), 4)
    raster = (jax.random.uniform(ks[0], (T, B, N)) < 0.25).astype(jnp.float32)
    w_in = jax.random.normal(ks[1], (N, H)) * 0.5
    w_rec = jax.random.normal(ks[2], (H, H)) * 0.2 * (1 - jnp.eye(H))
    w_out = jax.random.normal(ks[3], (H, O)) * 0.3
    out_k = ops.rsnn_forward(raster, w_in, w_rec, w_out,
                             alpha=0.95, kappa=0.6, reset=reset)
    out_r = ref.rsnn_forward_ref(raster, w_in, w_rec, w_out, 0.95, 0.6, 1.0,
                                 reset=reset)
    for key in out_r:
        np.testing.assert_allclose(out_k[key], out_r[key], rtol=3e-5, atol=3e-5,
                                   err_msg=key)


@pytest.mark.parametrize("T,B,N,H,O", [(8, 2, 8, 16, 2), (40, 4, 40, 100, 2)])
@pytest.mark.parametrize("kappa", [0.0, 0.21, 0.9])
def test_eprop_update_sweep(T, B, N, H, O, kappa):
    ks = jax.random.split(jax.random.key(T + H), 6)
    h = (jax.random.uniform(ks[0], (T, B, H)) < 0.3).astype(jnp.float32)
    xbar = jax.random.normal(ks[1], (T, B, N))
    pbar = jax.random.normal(ks[2], (T, B, H))
    zbar = jax.random.normal(ks[3], (T, B, H))
    err = jax.random.normal(ks[4], (T, B, O)) * 0.2
    b_fb = jax.random.normal(ks[5], (H, O)) * 0.4
    dk = ops.eprop_update(h, xbar, pbar, zbar, err, b_fb, kappa=kappa)
    dr = ref.eprop_update_ref(h, xbar, pbar, zbar, err, b_fb, kappa)
    for a, b in zip(dk, dr):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_kernel_pipeline_equals_factored_eprop():
    """rsnn_step + eprop_update kernels == core.eprop factored mode."""
    from repro.core import eprop as ce
    from repro.core.eprop import EpropConfig
    from repro.core.neuron import NeuronConfig

    T, B, N, H, O = 20, 3, 10, 24, 2
    ks = jax.random.split(jax.random.key(5), 5)
    params = {
        "w_in": jax.random.normal(ks[0], (N, H)) * 0.5,
        "w_rec": jax.random.normal(ks[1], (H, H)) * 0.2,
        "w_out": jax.random.normal(ks[2], (H, O)) * 0.3,
        "alpha": jnp.float32(0.9),
    }
    ncfg = NeuronConfig(alpha=0.9, kappa=0.5)
    ecfg = EpropConfig(mode="factored")
    raster = (jax.random.uniform(ks[3], (T, B, N)) < 0.3).astype(jnp.float32)
    label = jax.random.randint(ks[4], (B,), 0, O)
    y_star = jax.nn.one_hot(label, O)
    valid = jnp.ones((T, B))

    dw_core, _ = ce.run_sample(params, raster, y_star, valid, ncfg, ecfg)

    mask = 1 - jnp.eye(H)
    out = ops.rsnn_forward(raster, params["w_in"], params["w_rec"] * mask,
                           params["w_out"], alpha=0.9, kappa=0.5)
    err = (jax.nn.softmax(out["y"], axis=-1) - y_star[None]) * valid[..., None]
    dw_k = ops.eprop_update(out["h"], out["xbar"], out["pbar"], out["zbar"],
                            err, params["w_out"], kappa=0.5)
    np.testing.assert_allclose(dw_k[0], dw_core["w_in"], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(dw_k[1] * mask, dw_core["w_rec"], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(dw_k[2], dw_core["w_out"], rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,H,Hkv,S,D,bq,bk", [
    (1, 2, 1, 128, 32, 64, 64),
    (2, 4, 2, 128, 64, 32, 64),
    (1, 8, 8, 64, 16, 64, 64),   # MHA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, Hkv, S, D, bq, bk, causal):
    ks = jax.random.split(jax.random.key(B * S + D), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32) * 0.3
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32) * 0.3
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32) * 0.3
    o_k = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    o_r = ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(o_k, o_r, rtol=3e-5, atol=3e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.key(0), 3)
    q = (jax.random.normal(ks[0], (1, 2, 64, 32)) * 0.3).astype(jnp.bfloat16)
    k = (jax.random.normal(ks[1], (1, 2, 64, 32)) * 0.3).astype(jnp.bfloat16)
    v = (jax.random.normal(ks[2], (1, 2, 64, 32)) * 0.3).astype(jnp.bfloat16)
    o_k = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    o_r = ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        o_k.astype(jnp.float32), o_r.astype(jnp.float32), rtol=3e-2, atol=3e-2
    )
