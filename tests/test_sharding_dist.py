"""Sharding rules, train-step equivalences, HLO collective parsing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_reduced
from repro.distributed.sharding import (
    BASE_RULES,
    ShardingRules,
    logical_spec,
    param_shardings,
    use_mesh,
)
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_debug_mesh
from repro.models.model import build
from repro.optim.adamw import AdamW, AdamWConfig
from repro.train.train_step import make_train_step


def test_logical_spec_resolution():
    mesh = make_debug_mesh(1, 1)
    rules = ShardingRules(BASE_RULES)
    assert logical_spec(("batch", "act_seq"), mesh, rules) == P(("data",), None)
    assert logical_spec(("vocab", "embed"), mesh, rules) == P("model", "data")
    assert logical_spec((None, "norm"), mesh, rules) == P(None, None)
    with pytest.raises(KeyError):
        logical_spec(("nonsense",), mesh, rules)


def test_rules_override_and_missing_axes():
    mesh = make_debug_mesh(1, 1)  # no 'pod' axis
    rules = ShardingRules(BASE_RULES).override(kv_cache_seq="model")
    # 'pod' silently dropped when absent from the mesh
    assert logical_spec(("batch",), mesh, rules) == P(("data",))
    assert logical_spec(("kv_cache_seq",), mesh, rules) == P("model")


def test_param_shardings_tree():
    mesh = make_debug_mesh(1, 1)
    specs = {"w": ("embed", "mlp"), "sub": {"g": ("norm",)}}
    sh = param_shardings(specs, mesh, ShardingRules(BASE_RULES))
    assert sh["w"].spec == P("data", "model")
    assert sh["sub"]["g"].spec == P(None)


def test_microbatched_grads_equal_full_batch():
    cfg = get_reduced("qwen3-1.7b")
    model = build(cfg)
    params = model.init(jax.random.key(0))
    opt = AdamW(AdamWConfig(lr=0.0, weight_decay=0.0, warmup_steps=0, decay_steps=1))
    rng = jax.random.key(1)
    batch = {
        "tokens": jax.random.randint(rng, (4, 32), 0, cfg.vocab, jnp.int32),
        "targets": jax.random.randint(rng, (4, 32), 0, cfg.vocab, jnp.int32),
    }
    s1 = make_train_step(model, opt, n_micro=1)
    s2 = make_train_step(model, opt, n_micro=2)
    _, st1, m1 = jax.jit(s1)(params, opt.init(params), batch)
    _, st2, m2 = jax.jit(s2)(params, opt.init(params), batch)
    # moments are grad-derived: compare first-moment trees
    for a, b in zip(jax.tree.leaves(st1["mu"]), jax.tree.leaves(st2["mu"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[16,4096]{1,0} all-gather(bf16[1,4096]{1,0} %p), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %q), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = bf16[64]{0} reduce-scatter(bf16[1024]{0} %r), replica_groups=[1,16]<=[16], dimensions={0}
  %cp = f32[8,8]{1,0} collective-permute(f32[8,8]{1,0} %s), source_target_pairs={{0,1}}
"""
    stats = collective_bytes(hlo, 256)
    ag = stats.bytes_by_op["all-gather"]
    assert abs(ag - (15 / 16) * 16 * 4096 * 2) < 1
    ar = stats.bytes_by_op["all-reduce"]
    assert abs(ar - 2 * (3 / 4) * 1024 * 4) < 1
    rs = stats.bytes_by_op["reduce-scatter"]
    assert abs(rs - (15 / 16) * 1024 * 2) < 1
    assert stats.bytes_by_op["collective-permute"] == 8 * 8 * 4
    assert stats.count_by_op == {
        "all-gather": 1, "all-reduce": 1, "reduce-scatter": 1, "collective-permute": 1
    }


def test_sharded_train_step_on_debug_mesh():
    """Full sharded jit path on a 1×1 mesh: in/out shardings + shard()."""
    cfg = get_reduced("llama3-8b")
    model = build(cfg)
    mesh = make_debug_mesh(1, 1)
    rules = ShardingRules(BASE_RULES)
    with use_mesh(mesh, rules):
        params = model.init(jax.random.key(0))
        _, specs = model.abstract()
        p_shard = param_shardings(specs, mesh, rules)
        opt = AdamW(AdamWConfig(warmup_steps=1, decay_steps=10))
        step = jax.jit(
            make_train_step(model, opt),
            in_shardings=(p_shard, None, None),
            out_shardings=(p_shard, None, None),
        )
        batch = {
            "tokens": jnp.ones((2, 16), jnp.int32),
            "targets": jnp.ones((2, 16), jnp.int32),
        }
        params2, _, metrics = step(params, opt.init(params), batch)
        assert np.isfinite(float(metrics["loss"]))
