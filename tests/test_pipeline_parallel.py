"""GPipe pipeline parallelism: numeric equivalence + multi-device compile.

The multi-device case needs >1 host device, which requires XLA_FLAGS before
jax init — so it runs in a subprocess (same pattern as the dry-run)."""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.pipeline import gpipe, reference_pipeline
from jax.sharding import Mesh


def test_gpipe_single_stage_matches_reference():
    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    k = jax.random.key(0)
    params = {"w": jax.random.normal(k, (1, 8, 8)) * 0.5}
    x = jax.random.normal(jax.random.fold_in(k, 1), (4, 2, 8))
    fn = lambda p, xb: jnp.tanh(xb @ p["w"])
    out = gpipe(fn, params, x, mesh=mesh, axis="pod")
    ref = reference_pipeline(fn, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_gpipe_multi_stage_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed.pipeline import gpipe, reference_pipeline

        mesh = Mesh(np.array(jax.devices()).reshape(4), ("pod",))
        k = jax.random.key(0)
        params = {"w": jax.random.normal(k, (4, 8, 8)) * 0.5}
        x = jax.random.normal(jax.random.fold_in(k, 1), (6, 2, 8))
        fn = lambda p, xb: jnp.tanh(xb @ p["w"])
        out = gpipe(fn, params, x, mesh=mesh, axis="pod")
        ref = reference_pipeline(fn, params, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        hlo = jax.jit(lambda p, xx: gpipe(fn, p, xx, mesh=mesh, axis="pod")
                      ).lower(params, x).compile().as_text()
        assert "collective-permute" in hlo, "handoff must be a collective-permute"
        print("GPIPE_OK")
    """) % str(Path(__file__).resolve().parents[1] / "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       timeout=300)
    assert "GPIPE_OK" in r.stdout, r.stdout + r.stderr
